"""Mutation-under-traffic benchmark: the §8.3 readwrite scenario
through a versioned `DistanceServer` (docs/MUTATION.md). Reports swap
latency percentiles, read latency during writes, and sustained QPS,
and embeds two exactness gates that raise AssertionError on failure
(so `benchmarks.run` exits nonzero):

  * zero compiled-shape growth across every version swap, and
  * served reads on the final version bitwise-equal to a from-scratch
    `ISLabelIndex.build` over the mutated edge set.

Results accumulate in ``BENCH_mutation.json``.

  PYTHONPATH=src python -m benchmarks.bench_mutation [--full]
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def _mirror_edges(n, src, dst, w, writes):
    """Replay a trace's write batches onto host edge lists, the same
    bookkeeping the launcher's ``--audit rebuild`` uses."""
    es = [int(x) for x in src] + [int(x) for x in dst]
    ed = [int(x) for x in dst] + [int(x) for x in src]
    ew = [float(x) for x in w] * 2
    live: list[int] = []
    for ops in writes:
        if not ops:
            continue
        for op in ops:
            if op.kind == "insert":
                for v, wt in zip(op.nbrs, op.ws):
                    es += [op.u, int(v)]
                    ed += [int(v), op.u]
                    ew += [float(wt), float(wt)]
                live.append(op.u)
            else:
                keep = [i for i in range(len(es))
                        if es[i] != op.u and ed[i] != op.u]
                es = [es[i] for i in keep]
                ed = [ed[i] for i in keep]
                ew = [ew[i] for i in keep]
                live.remove(op.u)
    return (np.asarray(es, np.int32), np.asarray(ed, np.int32),
            np.asarray(ew, np.float32), live)


def main(full: bool = False) -> None:
    from repro.core import ISLabelIndex, IndexConfig
    from repro.graphs import generators as gen
    from repro.serve import DistanceServer, make_trace

    if full:
        n_base, n_req, spares, write_ratio = 1 << 10, 4096, 32, 0.04
    else:
        n_base, n_req, spares, write_ratio = 160, 420, 12, 0.06
    nb, src, dst, w = gen.er_graph(n_base, 2.4, seed=3)
    n = nb + spares
    cfg = IndexConfig(l_cap=256, label_chunk=128)
    idx = ISLabelIndex.build(n, src, dst, w, cfg)

    server = DistanceServer(idx, buckets=(16, 64), max_wait_ms=2.0,
                            cache_size=4096, versioned=True)
    server.warmup()
    pre = server.compile_cache_sizes()
    trace = make_trace("readwrite", n=n, num_requests=n_req,
                       rate_qps=50_000.0, seed=0, write_ratio=write_ratio,
                       n_read=nb, spares=range(nb, n),
                       attach_to=idx.core_ids)
    answers, vids = server.serve_readwrite_trace(trace)
    post = server.compile_cache_sizes()
    snap = server.stats()

    assert post == pre, \
        f"recompiles during readwrite serving: {pre} -> {post}"

    # Exactness gate: a fresh read batch on the final live version must
    # match a from-scratch rebuild of the mutated graph bitwise.
    es, ed, ew, live = _mirror_edges(n, src, dst, w, trace.writes)
    ref = ISLabelIndex.build(n, es, ed, ew, cfg)
    rng = np.random.default_rng(7)
    q = 256 if not full else 1024
    qs = rng.integers(0, nb, q).astype(np.int32)
    qt = rng.integers(0, nb, q).astype(np.int32)
    if live:
        qs[: len(live)] = np.asarray(live, np.int32)
    check = make_trace("uniform", n=nb, num_requests=q, rate_qps=50_000.0,
                       seed=1)
    check.s[:], check.t[:] = qs, qt
    got = server.serve_trace(check)
    want = np.asarray(ref.engine.query(qs, qt), np.float32)
    ok = np.array_equal(got, want)
    assert ok, (
        f"final-version served reads != scratch rebuild "
        f"({int(np.sum(got != want))}/{q} mismatches)")
    post2 = server.compile_cache_sizes()
    assert post2 == pre, \
        f"recompiles on post-swap read batch: {pre} -> {post2}"
    server.drain()

    sw = snap["swap_ms"]
    meta = trace.meta
    us = 1e6 / snap["qps_compute"] if snap["qps_compute"] else 0.0
    common.row("mutation", "readwrite-full" if full else "readwrite", us,
               qps=round(snap["qps_compute"]),
               p99_ms=round(snap["latency_ms"]["p99"], 2),
               swaps=snap["mutations"],
               ops=snap["mutation_ops"],
               swap_p50_ms=round(sw["p50"], 2),
               swap_p95_ms=round(sw["p95"], 2))
    common.write_json("mutation", {
        "graph": {"kind": "er10" if full else "er160", "n": int(n),
                  "n_read": int(nb), "m": int(len(src)),
                  "spares": int(spares)},
        "index": {"k": idx.k, "n_core": int(idx.stats.n_core),
                  "core_cap": snap["versions"]["core_cap"],
                  "edge_cap": snap["versions"]["edge_cap"]},
        "full": full,
        "trace": {"requests": n_req, "write_ratio": write_ratio,
                  "writes": meta["writes"], "inserts": meta["inserts"],
                  "deletes": meta["deletes"]},
        "qps_compute": snap["qps_compute"],
        "latency_ms": snap["latency_ms"],
        "swap_ms": sw,
        "mutations": snap["mutations"],
        "mutation_ops": snap["mutation_ops"],
        "compiled_shapes": {"before": pre, "after": post2},
        "exactness": {"final_version_bitwise": bool(ok),
                      "checked_reads": int(q),
                      "live_inserted": len(live)},
    })


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
