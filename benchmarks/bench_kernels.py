"""Kernel microbenchmarks: dispatch (Pallas kernel) vs jnp-reference
paths side by side at serving shapes, with parity asserted between them
and a roofline position (bytes/FLOPs model from
``benchmarks.roofline_report``) merged into every row.

On TPU the kernel rows measure compiled pallas_call; off-TPU they run
interpret mode (same program, jnp evaluation) so the comparison is about
correctness there, while the reference rows track what ``auto`` dispatch
actually serves on this container.

CI runs this standalone as the kernel-parity gate:

  PYTHONPATH=src python -m benchmarks.bench_kernels \
      --preset tiny --backend interpret --strict-roofline

Any backend-parity mismatch raises AssertionError (nonzero exit);
``--strict-roofline`` additionally fails if any emitted row lacks a
roofline model, so new kernel rows can't silently skip the accounting.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from benchmarks.roofline_report import roofline_fields
from repro.core.dispatch import (_core_relax_dense, _core_relax_ell,
                                 _core_relax_fused, CoreRelaxer, core_relax)
from repro.core.labels import LabelRows, decode_ids, encode_labels, \
    encoded_nbytes
from repro.core.query import label_intersect_mu
from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.label_intersect.ops import (label_intersect,
                                               label_intersect_rows)
from repro.kernels.minplus_matmul.ops import minplus_matmul
from repro.kernels.minplus_matmul.ref import minplus_matmul_ref
from repro.kernels.spmv_relax.ops import coo_to_ell, spmv_relax
from repro.kernels.spmv_relax.ref import spmv_relax_ref

# q/l/n: label-intersect batch;  m: minplus GEMM edge;  v/qb: core-relax
# vertex count (n_core+1, kept a multiple of 128 so no lane padding) and
# stacked frontier rows;  dv/dq: the small dense-core route's shapes.
PRESETS = {
    "tiny": dict(q=128, l=64, n=1 << 16, m=128, v=1 << 10, qb=16,
                 dv=256, dq=16),
    "default": dict(q=512, l=64, n=1 << 16, m=256, v=1 << 12, qb=64,
                    dv=256, dq=16),
    "full": dict(q=4096, l=64, n=1 << 20, m=512, v=1 << 13, qb=256,
                 dv=512, dq=32),
}
MAXR = 64          # static round cap for the relax sections


def _bitwise(a, b, what: str):
    a, b = np.asarray(a), np.asarray(b)
    fin = np.isfinite(a)
    assert (np.isfinite(b) == fin).all() and np.array_equal(a[fin], b[fin]), \
        f"{what} parity failed"


def _core_graph(rng, v: int):
    """Degree-8-regular (in-degree) core graph on n_core = v-1 vertices:
    max in-degree 8 keeps the ELL width at exactly ELL_D_WIDTH=16, so
    the spmv/fused roofline models describe the real layout."""
    n_core = v - 1
    e = 8 * n_core
    dst = np.repeat(np.arange(n_core), 8)
    src = rng.integers(0, n_core, e)
    w = rng.integers(1, 5, e).astype(np.float32)
    return n_core, src, dst, w


def _seeds(rng, qh: int, v: int):
    s = np.full((qh, v), np.inf, np.float32)
    s[np.arange(qh), rng.integers(0, v, qh)] = 0.0
    return jnp.asarray(s)


def main(full: bool = False, preset: str | None = None,
         backend: str | None = None, strict_roofline: bool = False):
    p = PRESETS[preset or ("full" if full else "default")]
    r = np.random.default_rng(0)
    kernel_backend = backend or (
        "pallas" if jax.default_backend() == "tpu" else "interpret")
    interp = pallas_interpret(kernel_backend)
    print(f"# auto dispatch resolves to: {resolve_backend(None)}; "
          f"kernel rows use backend={kernel_backend}")

    unmodeled: list[str] = []

    def krow(name: str, us: float, **derived):
        fields = roofline_fields(name, us)
        if fields is None:
            unmodeled.append(name)
        else:
            derived = {**derived, **fields}
        row("kernels", name, us, **derived)

    # ---- label intersection at serving shape: engine / reference /
    # kernel. Ids must be unique per row (real label rows are): on
    # duplicates the searchsorted reference keeps only the first
    # occurrence while the equality-join kernel min-reduces over all,
    # so μ would differ.
    q, l, n = p["q"], p["l"], p["n"]

    def _rows():
        return np.sort(np.stack([r.choice(n, l, replace=False)
                                 for _ in range(q)]), 1).astype(np.int32)

    ids_s = _rows()
    ids_t = _rows()
    d_s = r.random((q, l)).astype(np.float32)
    d_t = r.random((q, l)).astype(np.float32)
    args = (jnp.asarray(ids_s), jnp.asarray(d_s),
            jnp.asarray(ids_t), jnp.asarray(d_t))
    f = jax.jit(lambda a, b, c, d: label_intersect_mu(a, b, c, d, n, l))
    us, _ = timeit(f, *args)
    krow(f"label_intersect_engine[{q}x{l}]", us / q * 1e6,
         total_ms=round(us * 1e3, 3))
    g = jax.jit(lambda a, b, c, d: label_intersect(a, b, c, d, n,
                                                   backend="reference"))
    us_ref, mu_ref = timeit(g, *args)
    krow(f"label_intersect_ref[{q}x{l}]", us_ref / q * 1e6)
    h = jax.jit(lambda a, b, c, d: label_intersect(a, b, c, d, n,
                                                   backend=kernel_backend))
    us_ker, mu_ker = timeit(h, *args)
    krow(f"label_intersect_kernel[{q}x{l}]", us_ker / q * 1e6,
         backend=kernel_backend,
         speedup_vs_ref=round(us_ref / us_ker, 2))
    _bitwise(mu_ref, mu_ker, "label_intersect dispatch")

    # ---- packed (delta16-compressed) label intersection: decode fused
    # into the join kernel. Rows are built delta-encodable by
    # construction (bounded gaps) with a tail of pad slots on half the
    # rows; integral distances exercise the int32 distance plane.
    step_hi = max(2, (n // 2) // l)
    pid = (r.integers(0, n // 4, (q, 1))
           + np.cumsum(r.integers(1, step_hi, (q, l)), axis=1)
           ).astype(np.int32)
    pd = r.integers(0, 100, (q, l)).astype(np.float32)
    pid[::2, l - 4:] = n                      # contiguous pad tail
    pd[::2, l - 4:] = np.inf
    pid_t = np.roll(pid, 1, axis=0)           # forces real intersections
    pd_t = np.roll(pd, 1, axis=0)
    enc_s = encode_labels(pid, pd, n)
    enc_t = encode_labels(pid_t, pd_t, n)
    rows_s = LabelRows(*(jnp.asarray(x) for x in enc_s))
    rows_t = LabelRows(*(jnp.asarray(x) for x in enc_t))
    plain = jax.jit(lambda a, b, c, d: label_intersect(
        a, b, c, d, n, backend=kernel_backend))
    us_plain, mu_plain = timeit(
        plain, jnp.asarray(pid), jnp.asarray(pd),
        jnp.asarray(pid_t), jnp.asarray(pd_t))
    packed = jax.jit(lambda a, b: label_intersect_rows(
        a, b, n, codec="delta16", backend=kernel_backend))
    us_pk, mu_pk = timeit(packed, rows_s, rows_t)
    nb_plain = pid.nbytes + pd.nbytes
    krow(f"label_intersect_packed[{q}x{l}]", us_pk / q * 1e6,
         backend=kernel_backend,
         speedup_vs_fp32=round(us_plain / us_pk, 2),
         bytes_saved_pct=round(
             100.0 * (1 - encoded_nbytes(*enc_s) / nb_plain), 1))
    _bitwise(mu_plain, mu_pk, "label_intersect packed-codec")
    _bitwise(pid, decode_ids(rows_s.ids, rows_s.base, n),
             "delta16 id roundtrip")

    # ---- minplus matmul (dense-core building block): ref vs kernel
    m = p["m"]
    a2 = (r.random((m, m)) * 9).astype(np.float32)
    b2 = (r.random((m, m)) * 9).astype(np.float32)
    f = jax.jit(minplus_matmul_ref)
    us_ref, mp_ref = timeit(f, jnp.asarray(a2), jnp.asarray(b2))
    krow(f"minplus_ref[{m}^3]", us_ref * 1e6)
    g = jax.jit(lambda x, y: minplus_matmul(x, y, backend=kernel_backend))
    us_ker, mp_ker = timeit(g, jnp.asarray(a2), jnp.asarray(b2))
    krow(f"minplus_kernel[{m}^3]", us_ker * 1e6, backend=kernel_backend,
         speedup_vs_ref=round(us_ref / us_ker, 2))
    np.testing.assert_allclose(np.asarray(mp_ref), np.asarray(mp_ker),
                               rtol=1e-6)

    # ---- one relaxation round at core-graph shape: ref vs kernel
    v, qb = p["v"], p["qb"]
    n_core, src, dst, w = _core_graph(r, v)
    e = len(src)
    ids, ws = coo_to_ell(v, src, dst, w, d_width=16)
    dist = np.full((qb, v), np.inf, np.float32)
    dist[np.arange(qb), r.integers(0, v, qb)] = 0.0
    f = jax.jit(spmv_relax_ref)
    us_ref, rx_ref = timeit(f, jnp.asarray(dist), ids, ws)
    krow(f"spmv_relax_ref[q{qb},v{v}]", us_ref * 1e6,
         edges_per_s=round(qb * e / us_ref / 1e6, 1))
    g = jax.jit(lambda d, i, w_: spmv_relax(d, i, w_, backend=kernel_backend))
    us_ker, rx_ker = timeit(g, jnp.asarray(dist), ids, ws)
    krow(f"spmv_relax_kernel[q{qb},v{v}]", us_ker * 1e6,
         backend=kernel_backend,
         edges_per_s=round(qb * e / us_ker / 1e6, 1))
    _bitwise(rx_ref, rx_ker, "spmv_relax dispatch")

    # ---- whole core search, fused kernel vs per-round launch loop:
    # the same graph relaxed to its fixed point. Distances, the μ
    # answer, and the round count must agree bitwise (max over
    # per-block in-kernel exits == loop rounds); both checked against
    # the COO reference.
    qh = qb // 2
    seed_s = _seeds(r, qh, v)
    seed_t = _seeds(r, qh, v)
    mu = jnp.full((qh,), jnp.inf, jnp.float32)

    def fused_call(a, b):
        return _core_relax_fused(a, b, ids, ws, mu, n_core, MAXR, interp, 8)

    def loop_call(a, b):
        return _core_relax_ell(a, b, ids, ws, mu, n_core, MAXR, interp,
                               8, 128)

    us_fu, (ans_fu, ds_fu, dt_fu, r_fu) = timeit(fused_call, seed_s, seed_t)
    us_lp, (ans_lp, ds_lp, dt_lp, r_lp) = timeit(loop_call, seed_s, seed_t)
    rounds = int(r_fu)
    assert rounds == int(r_lp), \
        f"fused/loop round-count parity failed ({rounds} != {int(r_lp)})"
    for pair in ((ans_fu, ans_lp), (ds_fu, ds_lp), (dt_fu, dt_lp)):
        _bitwise(pair[1], pair[0], "fused core-relax")
    ans_ref, ds_ref, dt_ref, r_ref = core_relax(
        seed_s, seed_t, jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(w), mu, n_core, MAXR)
    assert rounds == int(r_ref), "fused/reference round-count parity failed"
    for kr, rr in ((ans_fu, ans_ref), (ds_fu, ds_ref), (dt_fu, dt_ref)):
        _bitwise(rr, kr, "fused-vs-reference core-relax")
    krow(f"relax_loop_kernel[q{qb},v{v},r{rounds}]", us_lp * 1e6,
         backend=kernel_backend, rounds=rounds)
    krow(f"fused_relax_kernel[q{qb},v{v},r{rounds}]", us_fu * 1e6,
         backend=kernel_backend, rounds=rounds,
         speedup_vs_loop=round(us_lp / us_fu, 2))

    # ---- dense-core route: small dense core relaxed via the
    # minplus_matmul tropical GEMM, parity vs the fused route
    dv, dq = p["dv"], p["dq"]
    dn_core = dv - 1
    de = int(0.08 * dn_core * dn_core)
    dsrc = r.integers(0, dn_core, de)
    ddst = r.integers(0, dn_core, de)
    dw = r.integers(1, 5, de).astype(np.float32)
    relaxer = CoreRelaxer(dsrc, ddst, dw, dn_core)
    assert relaxer.mode == "dense", \
        f"dense-core dispatch expected 'dense', got {relaxer.mode!r}"
    adj = relaxer.dense_adj()
    vp = adj.shape[0]
    dqh = dq // 2
    dseed_s = _seeds(r, dqh, dn_core + 1)
    dseed_t = _seeds(r, dqh, dn_core + 1)
    dmu = jnp.full((dqh,), jnp.inf, jnp.float32)

    def dense_call(a, b):
        return _core_relax_dense(a, b, adj, dmu, dn_core, MAXR, interp, 8)

    us_de, (ans_de, ds_de, dt_de, r_de) = timeit(dense_call, dseed_s, dseed_t)
    fu2 = CoreRelaxer(dsrc, ddst, dw, dn_core, dense_threshold=2.0)
    assert fu2.mode == "fused", \
        f"dense-core fallback expected 'fused', got {fu2.mode!r}"
    ans_f2, ds_f2, dt_f2, r_f2 = fu2.run(dseed_s, dseed_t, dmu, MAXR,
                                         kernel_backend)
    assert int(r_de) == int(r_f2), "dense/fused round-count parity failed"
    for kr, rr in ((ans_de, ans_f2), (ds_de, ds_f2), (dt_de, dt_f2)):
        _bitwise(rr, kr, "dense-vs-fused core-relax")
    krow(f"dense_relax_kernel[q{dq},v{vp},r{int(r_de)}]", us_de * 1e6,
         backend=kernel_backend, rounds=int(r_de),
         density=round(relaxer.density, 3))

    if strict_roofline and unmodeled:
        raise RuntimeError(
            "kernel rows without a roofline model: " + ", ".join(unmodeled))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default=None)
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "interpret", "reference"])
    ap.add_argument("--strict-roofline", action="store_true",
                    help="fail if any emitted row lacks a roofline model")
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()
    main(full=a.full, preset=a.preset, backend=a.backend,
         strict_roofline=a.strict_roofline)
