"""Kernel microbenchmarks: the jnp reference paths (the CPU-measurable
proxies) at serving shapes + interpret-mode parity checks. On TPU the
pallas_call paths replace the refs; CPU timings here track the *jnp*
implementations the engine actually runs on this container."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.query import label_intersect_mu
from repro.kernels.label_intersect.ref import label_intersect_ref
from repro.kernels.minplus_matmul.ref import minplus_matmul_ref
from repro.kernels.spmv_relax.ops import coo_to_ell
from repro.kernels.spmv_relax.ref import spmv_relax_ref


def main(full: bool = False):
    r = np.random.default_rng(0)
    # label intersection at serving shape
    q, l, n = (4096, 64, 1 << 20) if full else (512, 64, 1 << 16)
    ids_s = np.sort(r.integers(0, n, (q, l)).astype(np.int32), 1)
    ids_t = np.sort(r.integers(0, n, (q, l)).astype(np.int32), 1)
    d_s = r.random((q, l)).astype(np.float32)
    d_t = r.random((q, l)).astype(np.float32)
    f = jax.jit(lambda a, b, c, d: label_intersect_mu(a, b, c, d, n, l))
    us, _ = timeit(f, jnp.asarray(ids_s), jnp.asarray(d_s),
                   jnp.asarray(ids_t), jnp.asarray(d_t))
    row("kernels", f"label_intersect_engine[{q}x{l}]", us / q * 1e6,
        total_ms=round(us * 1e3, 3))
    g = jax.jit(lambda a, b, c, d: label_intersect_ref(a, b, c, d, n))
    us2, _ = timeit(g, jnp.asarray(ids_s), jnp.asarray(d_s),
                    jnp.asarray(ids_t), jnp.asarray(d_t))
    row("kernels", f"label_intersect_ref[{q}x{l}]", us2 / q * 1e6)

    # minplus matmul (core-search building block)
    m = 512 if full else 256
    a = (r.random((m, m)) * 9).astype(np.float32)
    b = (r.random((m, m)) * 9).astype(np.float32)
    f = jax.jit(minplus_matmul_ref)
    us, _ = timeit(f, jnp.asarray(a), jnp.asarray(b))
    row("kernels", f"minplus_ref[{m}^3]", us * 1e6,
        gflops=round(2 * m ** 3 / us / 1e9, 2))

    # relaxation round at core-graph shape
    v, e, qb = (1 << 15, 1 << 18, 256) if full else (1 << 12, 1 << 15, 64)
    src = r.integers(0, v, e)
    dst = r.integers(0, v, e)
    w = r.integers(1, 5, e).astype(np.float32)
    ids, ws = coo_to_ell(v, src, dst, w, d_width=16)
    dist = np.full((qb, v), np.inf, np.float32)
    dist[np.arange(qb), r.integers(0, v, qb)] = 0.0
    f = jax.jit(spmv_relax_ref)
    us, _ = timeit(f, jnp.asarray(dist), ids, ws)
    row("kernels", f"spmv_relax_ref[q{qb},v{v}]", us * 1e6,
        edges_per_s=round(qb * e / us / 1e6, 1))


if __name__ == "__main__":
    main()
