"""Kernel microbenchmarks: dispatch (Pallas kernel) vs jnp-reference
paths side by side at serving shapes, with parity asserted between them.
On TPU the kernel rows measure compiled pallas_call; off-TPU they run
interpret mode (same program, jnp evaluation) so the comparison is about
correctness there, while the reference rows track what ``auto`` dispatch
actually serves on this container."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.query import label_intersect_mu
from repro.kernels.backend import resolve_backend
from repro.kernels.label_intersect.ops import label_intersect
from repro.kernels.minplus_matmul.ops import minplus_matmul
from repro.kernels.minplus_matmul.ref import minplus_matmul_ref
from repro.kernels.spmv_relax.ops import coo_to_ell, spmv_relax
from repro.kernels.spmv_relax.ref import spmv_relax_ref


def main(full: bool = False):
    r = np.random.default_rng(0)
    kernel_backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    print(f"# auto dispatch resolves to: {resolve_backend(None)}; "
          f"kernel rows use backend={kernel_backend}")

    # label intersection at serving shape: engine / reference / kernel.
    # Ids must be unique per row (real label rows are): on duplicates the
    # searchsorted reference keeps only the first occurrence while the
    # equality-join kernel min-reduces over all, so μ would differ.
    q, l, n = (4096, 64, 1 << 20) if full else (512, 64, 1 << 16)

    def _rows():
        return np.sort(np.stack([r.choice(n, l, replace=False)
                                 for _ in range(q)]), 1).astype(np.int32)

    ids_s = _rows()
    ids_t = _rows()
    d_s = r.random((q, l)).astype(np.float32)
    d_t = r.random((q, l)).astype(np.float32)
    args = (jnp.asarray(ids_s), jnp.asarray(d_s),
            jnp.asarray(ids_t), jnp.asarray(d_t))
    f = jax.jit(lambda a, b, c, d: label_intersect_mu(a, b, c, d, n, l))
    us, _ = timeit(f, *args)
    row("kernels", f"label_intersect_engine[{q}x{l}]", us / q * 1e6,
        total_ms=round(us * 1e3, 3))
    g = jax.jit(lambda a, b, c, d: label_intersect(a, b, c, d, n,
                                                   backend="reference"))
    us_ref, mu_ref = timeit(g, *args)
    row("kernels", f"label_intersect_ref[{q}x{l}]", us_ref / q * 1e6)
    h = jax.jit(lambda a, b, c, d: label_intersect(a, b, c, d, n,
                                                   backend=kernel_backend))
    us_ker, mu_ker = timeit(h, *args)
    row("kernels", f"label_intersect_kernel[{q}x{l}]", us_ker / q * 1e6,
        backend=kernel_backend,
        speedup_vs_ref=round(us_ref / us_ker, 2))
    a, b = np.asarray(mu_ref), np.asarray(mu_ker)
    fin = np.isfinite(a)
    assert (np.isfinite(b) == fin).all() and np.array_equal(a[fin], b[fin]), \
        "label_intersect dispatch parity failed"

    # minplus matmul (core-search building block): reference vs kernel
    m = 512 if full else 256
    a2 = (r.random((m, m)) * 9).astype(np.float32)
    b2 = (r.random((m, m)) * 9).astype(np.float32)
    f = jax.jit(minplus_matmul_ref)
    us_ref, mp_ref = timeit(f, jnp.asarray(a2), jnp.asarray(b2))
    row("kernels", f"minplus_ref[{m}^3]", us_ref * 1e6,
        gflops=round(2 * m ** 3 / us_ref / 1e9, 2))
    g = jax.jit(lambda x, y: minplus_matmul(x, y, backend=kernel_backend))
    us_ker, mp_ker = timeit(g, jnp.asarray(a2), jnp.asarray(b2))
    row("kernels", f"minplus_kernel[{m}^3]", us_ker * 1e6,
        backend=kernel_backend, gflops=round(2 * m ** 3 / us_ker / 1e9, 2))
    np.testing.assert_allclose(np.asarray(mp_ref), np.asarray(mp_ker),
                               rtol=1e-6)

    # relaxation round at core-graph shape: reference vs kernel
    v, e, qb = (1 << 15, 1 << 18, 256) if full else (1 << 12, 1 << 15, 64)
    src = r.integers(0, v, e)
    dst = r.integers(0, v, e)
    w = r.integers(1, 5, e).astype(np.float32)
    ids, ws = coo_to_ell(v, src, dst, w, d_width=16)
    dist = np.full((qb, v), np.inf, np.float32)
    dist[np.arange(qb), r.integers(0, v, qb)] = 0.0
    f = jax.jit(spmv_relax_ref)
    us_ref, rx_ref = timeit(f, jnp.asarray(dist), ids, ws)
    row("kernels", f"spmv_relax_ref[q{qb},v{v}]", us_ref * 1e6,
        edges_per_s=round(qb * e / us_ref / 1e6, 1))
    g = jax.jit(lambda d, i, w_: spmv_relax(d, i, w_, backend=kernel_backend))
    us_ker, rx_ker = timeit(g, jnp.asarray(dist), ids, ws)
    row("kernels", f"spmv_relax_kernel[q{qb},v{v}]", us_ker * 1e6,
        backend=kernel_backend,
        edges_per_s=round(qb * e / us_ker / 1e6, 1))
    a, b = np.asarray(rx_ref), np.asarray(rx_ker)
    fin = np.isfinite(a)
    assert (np.isfinite(b) == fin).all() and np.array_equal(a[fin], b[fin]), \
        "spmv_relax dispatch parity failed"


if __name__ == "__main__":
    main()
