"""Serving benchmark: scenarios × bucket configurations through the
``repro.serve`` engine. Seeds the perf trajectory: results accumulate
in ``BENCH_serving.json`` (QPS, p50/p95/p99 latency, batch fill, cache
hit rate, lane split per cell), alongside the usual CSV rows.

Also measures observability overhead: one scenario is replayed twice,
untraced vs. with a live ``Tracer``, and the qps_compute ratio is
reported (``obs_overhead`` in the JSON doc) — the acceptance bound is
<5% (docs/OBSERVABILITY.md; tracer calls sit outside the timed device
windows, so the expected overhead is ~0).

The ``frontend`` section sweeps offered QPS through the HTTP front end
(docs/SERVICE.md): a real ``ServiceFrontend`` on an ephemeral port, a
paced open-loop client batching queries over one keep-alive
connection. Latency is measured from each batch's *scheduled* arrival
(coordinated-omission safe: once the service saturates, backlog shows
up as p99 growth, not as a silently lower offered rate), and every
answer that crossed the wire is asserted bitwise against the index.

  PYTHONPATH=src python -m benchmarks.bench_serving [--full]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common


SCENARIOS = ("uniform", "hotspot", "bursty", "repeated")


def _obs_overhead(idx, n, n_req, rate) -> dict:
    """qps_compute untraced vs. traced on the same trace/buckets."""
    from repro.obs import Tracer
    from repro.serve import DistanceServer, make_trace
    trace = make_trace("uniform", n=n, num_requests=n_req, rate_qps=rate,
                       seed=7)
    qps = {}
    for tag, tracer in (("plain", None), ("traced", Tracer())):
        server = DistanceServer(idx, buckets=(64,), max_wait_ms=2.0,
                                cache_size=65536, tracer=tracer)
        server.serve_trace(trace)
        qps[tag] = server.stats()["qps_compute"]
    ratio = qps["plain"] / qps["traced"] if qps["traced"] else 0.0
    overhead = max(0.0, ratio - 1.0)
    common.row("serving", "obs-overhead", 0.0,
               qps_plain=round(qps["plain"]),
               qps_traced=round(qps["traced"]),
               overhead_pct=round(overhead * 100, 2))
    return {"qps_plain": qps["plain"], "qps_traced": qps["traced"],
            "overhead_frac": overhead}


def _frontend_sweep(idx, n: int, full: bool) -> list:
    """Offered QPS vs end-to-end percentile latency over HTTP."""
    from repro.obs import REGISTRY
    from repro.serve import (HttpClient, IndexRegistry, ServiceFrontend,
                             make_trace)
    rates = (2000.0, 8000.0, 32000.0) if full else (500.0, 2000.0, 8000.0)
    n_req = 4096 if full else 512
    batch = 16
    out = []
    with REGISTRY.isolated():
        registry = IndexRegistry()
        registry.register("default", idx, buckets=(32, 128),
                          max_wait_ms=2.0, cache_size=65536)
        fe = ServiceFrontend(registry)
        host, port = fe.start_background()
        try:
            with HttpClient(host, port) as client:
                for k, rate in enumerate(rates):
                    # distinct seed per rate: identical pairs would turn
                    # the later sweeps into pure LRU-cache replays
                    trace = make_trace("uniform", n=n, num_requests=n_req,
                                       rate_qps=rate, seed=3 + k)
                    # one throwaway batch outside the clock: first-touch
                    # costs (connection, result plumbing) are not load
                    client.query_batch(list(zip(trace.s[:batch].tolist(),
                                                trace.t[:batch].tolist())))
                    lat, got = [], np.empty(n_req, np.float32)
                    t0 = time.perf_counter()
                    for lo in range(0, n_req, batch):
                        hi = min(lo + batch, n_req)
                        sched = t0 + float(trace.arrival_s[lo])
                        wait = sched - time.perf_counter()
                        if wait > 0:
                            time.sleep(wait)
                        got[lo:hi] = client.query_batch(list(zip(
                            trace.s[lo:hi].tolist(),
                            trace.t[lo:hi].tolist())))
                        lat.append(time.perf_counter() - sched)
                    span = time.perf_counter() - t0
                    # bitwise audit after the clock stops (an idx.query
                    # inside the paced loop would charge audit time to
                    # the service as scheduling lateness)
                    want = np.asarray(idx.query(trace.s, trace.t),
                                      np.float32)
                    assert np.array_equal(got, want), \
                        f"HTTP answers != index (rate={rate})"
                    v = np.asarray(lat, np.float64) * 1e3
                    achieved = n_req / span
                    common.row("serving", f"http-rate{int(rate)}",
                               1e6 / achieved,
                               qps_offered=round(rate),
                               qps_achieved=round(achieved),
                               p50_ms=round(float(np.quantile(v, 0.5)), 2),
                               p99_ms=round(float(np.quantile(v, 0.99)),
                                            2))
                    out.append({
                        "rate_offered_qps": rate,
                        "qps_achieved": achieved,
                        "requests": n_req,
                        "batch": batch,
                        "latency_ms": {
                            "p50": float(np.quantile(v, 0.50)),
                            "p95": float(np.quantile(v, 0.95)),
                            "p99": float(np.quantile(v, 0.99)),
                            "mean": float(v.mean()),
                        },
                    })
        finally:
            fe.stop()
    return out


def _bucket_sets(full: bool):
    if full:
        return [(64,), (256,), (1024,), (64, 256, 1024)]
    return [(32,), (128,), (32, 128)]


def main(full: bool = False) -> None:
    from repro.core import ISLabelIndex, IndexConfig
    from repro.graphs import generators as gen
    from repro.serve import DistanceServer, make_trace

    if full:
        n, src, dst, w = gen.rmat_graph(14, avg_deg=6.0, seed=1)
        n_req, rate = 16384, 200_000.0
    else:
        n, src, dst, w = gen.er_graph(1 << 10, 2.2, seed=2)
        n_req, rate = 2048, 100_000.0
    idx = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=512))

    results = []
    for buckets in _bucket_sets(full):
        for scenario in SCENARIOS:
            server = DistanceServer(idx, buckets=buckets, max_wait_ms=2.0,
                                    cache_size=65536)
            trace = make_trace(scenario, n=n, num_requests=n_req,
                               rate_qps=rate, seed=0)
            served = server.serve_trace(trace)
            want = np.asarray(idx.query(trace.s, trace.t), np.float32)
            assert np.array_equal(served, want), \
                f"served != index answers ({scenario}, buckets={buckets})"
            snap = server.stats()
            name = f"{scenario}-b{'x'.join(str(b) for b in buckets)}"
            us = 1e6 / snap["qps_compute"] if snap["qps_compute"] else 0.0
            common.row("serving", name, us,
                       qps=round(snap["qps_compute"]),
                       p50_ms=round(snap["latency_ms"]["p50"], 2),
                       p99_ms=round(snap["latency_ms"]["p99"], 2),
                       fill=round(snap["batch_fill_ratio"], 3),
                       cache=round(snap["cache_hit_rate"], 3))
            results.append({
                "scenario": scenario,
                "buckets": list(buckets),
                "requests": n_req,
                "rate_qps": rate,
                "qps_compute": snap["qps_compute"],
                "qps_offered": snap["qps_offered"],
                "latency_ms": snap["latency_ms"],
                "batch_fill_ratio": snap["batch_fill_ratio"],
                "cache_hit_rate": snap["cache_hit_rate"],
                "lanes": snap["lanes"],
                "warmup_seconds": snap["warmup_seconds"],
            })
    overhead = _obs_overhead(idx, n, n_req, rate)
    frontend = _frontend_sweep(idx, n, full)
    common.write_json("serving", {
        "graph": {"kind": "rmat14" if full else "er10", "n": int(n),
                  "m": int(len(src))},
        "index": {"k": idx.k, "n_core": int(idx.stats.n_core),
                  "label_entries": int(idx.stats.label_entries)},
        "full": full,
        "results": results,
        "obs_overhead": overhead,
        "frontend": frontend,
    })


if __name__ == "__main__":
    main()
