"""Paper Table 3 + Table 7: index construction — k, |V_Gk|, |E_Gk|,
label size, indexing time — plus the device-builder gates and the
million-vertex scaling trajectory (docs/CONSTRUCTION.md).

Two sections:

* **gate rows** (always; CI's bench-smoke diffs them against the
  committed baseline): the tiny presets at sigma 0.95/0.90, each built
  by BOTH level-loop builders. Hard-asserted here, and re-gated as
  behavior metrics by bench-gate:
    - ``bitwise_equal`` — the device-resident builder's full index
      (levels, up-edges, core, labels) is bitwise-identical to the
      host reference loop at fixed seed;
    - ``syncs_per_level`` <= 1 — one blocking device→host read per
      peeled level in the device builder;
    - ``overflow`` == 0.
* **trajectory** (``--full``): 10^4 → 10^6-vertex builds through the
  device builder, written to the ``trajectory`` payload of
  ``BENCH_table3_construction.json`` (payload keys are invisible to the
  bench-gate row diff, so the committed million-vertex record never
  fights the tiny CI rerun).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import graphs_for_scale, row, write_json
from repro.core import ISLabelIndex, IndexConfig

GATE_SIGMAS = (0.95, 0.90)

# 10^4 -> 10^6 trajectory, BTC-like low-degree regime (avg deg 2.2 —
# the paper's billion-edge dataset is degree-2.19; this is the regime a
# single container core can take to a million vertices end-to-end).
TRAJECTORY = [
    # l_cap=64: the sigma=0.95 stop rule keeps this regime's hierarchy
    # shallow (k~4) — measured max label fill is 19 at both 10^4 and
    # 10^5; the label-join cost is linear in l_cap, so the cap stays
    # tight with >3x headroom (overflow raises, never truncates).
    ("er1e4", "er:10000:2.2@1", dict(l_cap=64, label_chunk=4096)),
    ("er1e5", "er:100000:2.2@1", dict(l_cap=64, label_chunk=8192)),
    ("er1e6", "er:1000000:2.2@1", dict(l_cap=64, label_chunk=8192)),
]


def _index_arrays(idx: ISLabelIndex) -> dict:
    return {
        "k": np.int32(idx.k), "level": idx.level,
        "up_ids": idx.up_ids, "up_w": idx.up_w, "up_via": idx.up_via,
        "core_src": idx.core_src, "core_dst": idx.core_dst,
        "core_w": idx.core_w, "core_via": idx.core_via,
        "lbl_ids": np.asarray(idx.lbl_ids), "lbl_d": np.asarray(idx.lbl_d),
        "lbl_pred": np.asarray(idx.lbl_pred),
        "level_sizes": np.asarray(idx.stats.level_sizes),
        "graph_sizes": np.asarray(idx.stats.graph_sizes),
        "mis_rounds": np.asarray(idx.stats.mis_rounds),
    }


def bitwise_diff(a: ISLabelIndex, b: ISLabelIndex) -> list[str]:
    """Field names on which the two indexes are not bitwise-identical."""
    da, db = _index_arrays(a), _index_arrays(b)
    return [name for name in da
            if not np.array_equal(da[name], db[name], equal_nan=True)]


def _build(n, src, dst, w, cfg):
    t0 = time.perf_counter()
    idx = ISLabelIndex.build(n, src, dst, w, cfg)
    return idx, time.perf_counter() - t0


def _sync_metrics(idx: ISLabelIndex) -> tuple[float, int]:
    st = idx.stats
    per_level = st.peel_loop_syncs / max(1, st.peel_iters)
    return per_level, st.peel_iters


def gate_rows():
    """Tiny-preset dual-builder gate — the CI-diffed section."""
    for sigma in GATE_SIGMAS:
        for name, (n, src, dst, w) in graphs_for_scale(False):
            base = dict(sigma=sigma, l_cap=256, label_chunk=2048)
            idx_dev, dt = _build(n, src, dst, w,
                                 IndexConfig(builder="device", **base))
            idx_host, _ = _build(n, src, dst, w,
                                 IndexConfig(builder="host", **base))
            mismatch = bitwise_diff(idx_dev, idx_host)
            assert not mismatch, (
                f"device builder diverged from host reference on "
                f"{name}@{sigma}: {mismatch}")
            spl, iters = _sync_metrics(idx_dev)
            assert spl <= 1.0, (
                f"{name}@{sigma}: {idx_dev.stats.peel_loop_syncs} blocking "
                f"syncs over {iters} peeled levels (gate: <= 1 per level)")
            st = idx_dev.stats
            row("table3_construction", f"{name}@{sigma}", dt * 1e6,
                n=n, m=len(src) // 2, k=st.k, V_Gk=st.n_core,
                E_Gk=st.m_core // 2, label_entries=st.label_entries,
                label_MB=round(st.label_bytes / 1e6, 2),
                build_s=round(dt, 2), peel_s=round(st.peel_seconds, 2),
                label_s=round(st.label_seconds, 2),
                bitwise_equal=1, overflow=0,
                syncs_per_level=round(spl, 4),
                mis_rounds_total=int(sum(st.mis_rounds)))


def trajectory_point(name: str, spec: str, overrides: dict) -> dict:
    from repro.data.pipeline import graph_from_spec
    t0 = time.perf_counter()
    n, src, dst, w = graph_from_spec(spec)
    gen_s = time.perf_counter() - t0
    cfg = IndexConfig(builder="device", **overrides)
    idx, dt = _build(n, src, dst, w, cfg)
    st = idx.stats
    spl, iters = _sync_metrics(idx)
    assert spl <= 1.0, f"{name}: syncs_per_level {spl} > 1"
    point = {
        "name": name, "spec": spec, "n": n, "m": len(src) // 2,
        "gen_s": round(gen_s, 2), "build_s": round(dt, 2),
        "peel_s": round(st.peel_seconds, 2),
        "label_s": round(st.label_seconds, 2),
        "k": st.k, "V_Gk": st.n_core, "E_Gk": st.m_core // 2,
        "levels_peeled": len(st.level_sizes),
        "label_entries": st.label_entries,
        "label_MB": round(st.label_bytes / 1e6, 2),
        "host_syncs": st.host_syncs,
        "peel_loop_syncs": st.peel_loop_syncs,
        "syncs_per_level": round(spl, 4),
        "peak_device_MB": round(st.peak_device_bytes / 1e6, 1),
        "l_cap": cfg.l_cap,
    }
    print("# trajectory " + " ".join(f"{k}={v}" for k, v in point.items()))
    return point


def main(full: bool = False):
    gate_rows()
    traj = [trajectory_point(*p) for p in TRAJECTORY] if full else []
    write_json("table3_construction", {"trajectory": traj})


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="run the 10^4 -> 10^6 scaling trajectory "
                         "(slow; ~minutes for the 10^6 build)")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_table3_construction.json")
    args = ap.parse_args()
    common.OUT_DIR = args.out
    print("table,name,us_per_call,derived")
    main(full=args.full)
