"""Paper Table 3 + Table 7: index construction — k, |V_Gk|, |E_Gk|,
label size, indexing time; at thresholds sigma=0.95 and 0.90."""
from __future__ import annotations

import time

from benchmarks.common import graphs_for_scale, row
from repro.core import ISLabelIndex, IndexConfig


def main(full: bool = False):
    for sigma in (0.95, 0.90):
        for name, (n, src, dst, w) in graphs_for_scale(full):
            cfg = IndexConfig(sigma=sigma, l_cap=1024, label_chunk=2048)
            t0 = time.perf_counter()
            idx = ISLabelIndex.build(n, src, dst, w, cfg)
            dt = time.perf_counter() - t0
            st = idx.stats
            row("table3_construction", f"{name}@{sigma}", dt * 1e6,
                n=n, m=len(src) // 2, k=st.k, V_Gk=st.n_core,
                E_Gk=st.m_core // 2, label_entries=st.label_entries,
                label_MB=round(st.label_bytes / 1e6, 2),
                build_s=round(dt, 2))


if __name__ == "__main__":
    main()
