"""Paper Table 6: construction + query cost as k varies (fixed k around
the sigma-chosen one)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import graphs_for_scale, row
from repro.core import ISLabelIndex, IndexConfig


def main(full: bool = False):
    name, (n, src, dst, w) = graphs_for_scale(full)[0]
    base = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=1024,
                                                          label_chunk=2048))
    k_auto = base.stats.k
    for k in sorted({max(2, k_auto - 1), k_auto, k_auto + 1}):
        cfg = IndexConfig(k_force=k, l_cap=2048, label_chunk=2048)
        t0 = time.perf_counter()
        idx = ISLabelIndex.build(n, src, dst, w, cfg)
        build = time.perf_counter() - t0
        r = np.random.default_rng(0)
        s = r.integers(0, n, 1000).astype(np.int32)
        t = r.integers(0, n, 1000).astype(np.int32)
        jax.block_until_ready(idx.query(s, t))
        t0 = time.perf_counter()
        jax.block_until_ready(idx.query(s, t))
        q = time.perf_counter() - t0
        st = idx.stats
        row("table6_k_sweep", f"{name}/k={k}", q / 1000 * 1e6,
            V_Gk=st.n_core, E_Gk=st.m_core // 2,
            label_entries=st.label_entries, build_s=round(build, 2),
            query_ms_per_1k=round(q * 1e3, 2), auto_k=k_auto)


if __name__ == "__main__":
    main()
