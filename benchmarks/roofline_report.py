"""Roofline accounting for the serving kernels (ROADMAP "raw speed").

Attaches an analytic bytes/FLOPs model to every kernel-suite row, so
each optimization PR can state its roofline position — arithmetic
intensity plus achieved GB/s and GFLOP/s at the measured
``us_per_call`` — before/after. ``bench_kernels`` merges these fields
directly into its ``BENCH_kernels.json`` rows via ``roofline_fields``
(and asserts coverage under ``--strict-roofline``); this module's
``main`` additionally emits a standalone ``roofline`` table.

Reads the kernel rows from the current driver run when available
(``benchmarks.run`` executes the kernels suite first) and falls back to
a previously written ``BENCH_kernels.json`` under ``--out``/cwd, so
``--only roofline`` works against the last kernel run:

  PYTHONPATH=src python -m benchmarks.run --only kernels --out bench-out
  PYTHONPATH=src python -m benchmarks.run --only roofline --out bench-out

Traffic models (compulsory bytes, fp32/int32 = 4 B):

* ``label_intersect[q x l]`` (per query): two id rows + two distance
  rows stream in (``16·l`` B) and the l×l equality join does a compare
  + candidate min-add per pair (``2·l²`` flops) — intensity grows as
  ``l/8``, so serving-shape label widths sit near the knee.
* ``label_intersect_packed[q x l]`` (per query): compressed rows
  (core/labels.py delta16) stream int16 deltas + int32 distances + a
  base scalar per side (``2·(6l+4)`` B); decode is in-register, join
  flops unchanged — intensity ~2.6x the fp32 rows.
* ``spmv_relax[q, v]`` (per round): dense distance block read+written
  (``8·q·v``) over a shared ELL structure (``8·v·d``), relaxing
  ``2·q·v·d`` flops — intensity bounded by ``d/4``, memory-bound.
* ``fused_relax[q, v, r]`` (whole search): the dist block crosses HBM
  ONCE (``8·q·v + 8·v·d``) while all ``r`` rounds' flops
  (``2·q·v·d·r``) run out of VMEM — intensity scales with rounds,
  which is the point of the fusion. ``relax_loop[...]`` is the same
  search through per-round launches: ``r×`` the bytes at equal flops.
* ``minplus[m^3]``: dense tropical GEMM, ``4·3·m²`` B compulsory,
  ``2·m³`` flops. ``dense_relax[q, v, r]``: r tropical GEMM rounds of
  the [q, v]×[v, v] frontier product (q = both frontiers stacked).
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks import common
from benchmarks.common import row

ELL_D_WIDTH = 16        # matches bench_kernels.py's coo_to_ell(d_width=16)


def label_intersect_model(q: int, l: int) -> tuple[float, float]:
    """(bytes, flops) per *query* — these rows report µs per query."""
    return 16.0 * l, 2.0 * l * l


def label_intersect_packed_model(q: int, l: int) -> tuple[float, float]:
    """Compressed rows per query: int16 delta (2l) + d plane (4l) +
    int32 base (4) per side; decode cumsum + join."""
    return 2.0 * (6.0 * l + 4.0), 2.0 * l * l + 4.0 * l


def spmv_relax_model(q: int, v: int,
                     d_width: int = ELL_D_WIDTH) -> tuple[float, float]:
    """(bytes, flops) for ONE relaxation round over the whole batch."""
    return 8.0 * q * v + 8.0 * v * d_width, 2.0 * q * v * d_width


def fused_relax_model(q: int, v: int, rounds: int,
                      d_width: int = ELL_D_WIDTH) -> tuple[float, float]:
    """Whole fused search: one HBM pass of dist + ELL, r rounds of
    flops in VMEM."""
    b, f = spmv_relax_model(q, v, d_width)
    return b, f * max(rounds, 1)


def relax_loop_model(q: int, v: int, rounds: int,
                     d_width: int = ELL_D_WIDTH) -> tuple[float, float]:
    """The same search as per-round launches: r× the HBM traffic."""
    b, f = spmv_relax_model(q, v, d_width)
    r = max(rounds, 1)
    return b * r, f * r


def minplus_model(m: int) -> tuple[float, float]:
    return 4.0 * 3.0 * m * m, 2.0 * m ** 3


def dense_relax_model(q: int, v: int, rounds: int) -> tuple[float, float]:
    """r rounds of the [q, v] × [v, v] tropical frontier GEMM (q = both
    query frontiers stacked, matching the relax row names)."""
    r = max(rounds, 1)
    return (4.0 * (q * v + v * v + q * v) * r,
            2.0 * q * v * v * r)


# name-pattern -> (bytes, flops); first match wins, so more specific
# patterns (packed, fused) come before their prefixes
MODELS = [
    (re.compile(r"label_intersect_packed\w*\[(\d+)x(\d+)\]"),
     lambda m: label_intersect_packed_model(int(m[1]), int(m[2]))),
    (re.compile(r"label_intersect_\w+\[(\d+)x(\d+)\]"),
     lambda m: label_intersect_model(int(m[1]), int(m[2]))),
    (re.compile(r"fused_relax\w*\[q(\d+),v(\d+),r(\d+)\]"),
     lambda m: fused_relax_model(int(m[1]), int(m[2]), int(m[3]))),
    (re.compile(r"relax_loop\w*\[q(\d+),v(\d+),r(\d+)\]"),
     lambda m: relax_loop_model(int(m[1]), int(m[2]), int(m[3]))),
    (re.compile(r"dense_relax\w*\[q(\d+),v(\d+),r(\d+)\]"),
     lambda m: dense_relax_model(int(m[1]), int(m[2]), int(m[3]))),
    (re.compile(r"spmv_relax_\w+\[q(\d+),v(\d+)\]"),
     lambda m: spmv_relax_model(int(m[1]), int(m[2]))),
    (re.compile(r"minplus_\w+\[(\d+)\^3\]"),
     lambda m: minplus_model(int(m[1]))),
]


def roofline_fields(name: str, us: float) -> dict | None:
    """Roofline-derived fields for a kernel row, or None when no model
    matches the row name. ``bench_kernels`` merges this into every row
    it emits (bytes/flops per call, intensity, achieved GB/s, GFLOP/s)."""
    for pat, model in MODELS:
        m = pat.match(name)
        if m:
            nbytes, flops = model(m)
            s = max(us, 1e-3) * 1e-6
            return {
                "bytes_per_call": nbytes,
                "flops_per_call": flops,
                "intensity": round(flops / nbytes, 3),
                "gbytes_per_s": round(nbytes / s / 1e9, 3),
                "gflops_per_s": round(flops / s / 1e9, 3),
            }
    return None


def _kernel_rows(out_dir: str) -> list[dict]:
    rows = [r for r in common._ROWS if r["table"] == "kernels"]
    if rows:
        return rows
    for base in (out_dir, "."):
        path = Path(base) / "BENCH_kernels.json"
        if path.exists():
            return json.loads(path.read_text()).get("rows", [])
    return []


def main(full: bool = False):
    rows = _kernel_rows(common.OUT_DIR)
    if not rows:
        print("# roofline: no kernel rows — run the kernels suite first "
              "(python -m benchmarks.run --only kernels, same --out)")
        return
    for r in rows:
        fields = roofline_fields(r["name"], r["us_per_call"])
        if fields is not None:
            row("roofline", r["name"], r["us_per_call"], **fields)


if __name__ == "__main__":
    main()
