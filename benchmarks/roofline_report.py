"""Roofline report (deliverable g): reads experiments/dryrun/*.json and
emits the per-(arch x shape x mesh) table with the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS utilization, and
HBM-fit verdicts. v5e model: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

HBM_PER_CHIP = 16e9


def model_flops(arch: str, shape: str) -> float | None:
    """Useful-work FLOPs: 6·N·D train (N_active for MoE), 2·N_active per
    decoded/prefilled token."""
    from repro.configs import registry
    spec = registry.get_spec(arch)
    if spec.family == "lm":
        cfg = spec.model_cfg
        shp = spec.shape(shape)
        tokens = shp.global_batch * shp.seq_len
        n_act = cfg.active_param_count()
        if shp.kind == "train":
            return 6.0 * n_act * tokens
        if shp.kind == "prefill":
            return 2.0 * n_act * tokens
        return 2.0 * n_act * shp.global_batch        # decode: 1 token/seq
    if spec.family == "recsys":
        shp = spec.shape(shape)
        cfg = spec.model_cfg
        per_ex = (cfg.seq_len * 2 * 3 * (cfg.d_behavior + cfg.gru_dim)
                  * cfg.gru_dim * 2        # two GRUs
                  + 2 * (cfg.gru_dim + 2 * cfg.d_behavior + 18) * 200
                  + 2 * 200 * 80)
        mult = 3.0 if shp.kind == "train" else 1.0
        if shp.kind == "retrieval":
            return 2.0 * shp.n_candidates * cfg.embed_dim
        return mult * per_ex * shp.batch
    if spec.family == "gnn":
        shp = spec.shape(shape)
        cfg = spec.model_cfg
        e = 2 * shp.n_edges if shp.kind != "molecule" else \
            2 * shp.batch_graphs * shp.n_edges
        nn = shp.n_nodes if shp.kind != "molecule" else \
            shp.batch_graphs * shp.n_nodes
        h = getattr(cfg, "d_hidden", 64)
        nl = getattr(cfg, "n_layers", getattr(cfg, "n_blocks", 2))
        # train fwd+bwd ~ 3x(SpMM gather+dense)
        return 3.0 * nl * (2.0 * e * h + 2.0 * nn * h * h)
    return None


def load(out_dir="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(f"{out_dir}/*.json")):
        recs.append(json.loads(Path(f).read_text()))
    return recs


def report(out_dir="experiments/dryrun", csv=True):
    rows = []
    for r in load(out_dir):
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "ok": False,
                         "error": r.get("error", "?")[:80]})
            continue
        dev = r["devices"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["flops_per_device"] * dev
        mem = r.get("mem") or {}
        hbm_need = (mem.get("argument_size_in_bytes") or 0) + \
            (mem.get("temp_size_in_bytes") or 0)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "ok": True,
            "t_compute_s": r["t_compute_s"],
            "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "dominant": r["dominant"],
            "model_flops": mf,
            "useful_ratio": (mf / hlo_total) if mf and hlo_total else None,
            "bytes_per_device": hbm_need,
            "fits_hbm": hbm_need <= HBM_PER_CHIP if mem else None,
            "roofline_frac": None,
        })
    # roofline fraction: useful-compute time / dominant-term time
    for row_ in rows:
        if row_.get("ok") and row_.get("model_flops"):
            t_useful = row_["model_flops"] / (197e12 *
                                              _dev(row_["mesh"]))
            t_bound = max(row_["t_compute_s"], row_["t_memory_s"],
                          row_["t_collective_s"])
            row_["roofline_frac"] = t_useful / t_bound if t_bound else None
    if csv:
        hdr = ["arch", "shape", "mesh", "dominant", "t_compute_s",
               "t_memory_s", "t_collective_s", "useful_ratio",
               "roofline_frac", "fits_hbm"]
        print(",".join(hdr))
        for row_ in rows:
            if not row_.get("ok"):
                print(f"{row_['arch']},{row_['shape']},{row_['mesh']},"
                      f"FAIL,,,,,,{row_.get('error')}")
                continue
            print(",".join(_fmt(row_.get(h)) for h in hdr))
    return rows


def _dev(mesh: str) -> int:
    out = 1
    for p in mesh.split("x"):
        out *= int(p)
    return out


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def main(full: bool = False):
    report()


if __name__ == "__main__":
    main()
