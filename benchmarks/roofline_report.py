"""Roofline accounting for the serving kernels (ROADMAP "raw speed").

Attaches an analytic bytes/FLOPs model to the ``label_intersect`` and
``spmv_relax`` rows the kernel suite emits, so each optimization PR can
state its roofline position — arithmetic intensity plus achieved GB/s
and GFLOP/s at the measured ``us_per_call`` — before/after. Rows land
in ``BENCH_roofline.json`` next to the other trajectory files.

Reads the kernel rows from the current driver run when available
(``benchmarks.run`` executes the kernels suite first) and falls back to
a previously written ``BENCH_kernels.json`` under ``--out``/cwd, so
``--only roofline`` works against the last kernel run:

  PYTHONPATH=src python -m benchmarks.run --only kernels --out bench-out
  PYTHONPATH=src python -m benchmarks.run --only roofline --out bench-out

Traffic model (compulsory bytes, fp32/int32):

* ``label_intersect[q x l]``: per query, two id rows and two distance
  rows stream in (``16·l`` bytes) and the l×l equality join does a
  compare + candidate min-add per pair (``2·l²`` flops) — intensity
  grows as ``l/8``, so serving-shape label widths sit near the
  memory/compute knee.
* ``spmv_relax[q x v]``: per round the dense distance block is read
  and written (``8·q·v``) over a shared ELL structure
  (``8·v·d_width``), relaxing ``2·q·v·d_width`` flops — intensity is
  bounded by ``d_width/4``, firmly memory-bound.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks import common
from benchmarks.common import row

ELL_D_WIDTH = 16        # matches bench_kernels.py's coo_to_ell(d_width=16)


def label_intersect_model(q: int, l: int) -> tuple[float, float]:
    """(bytes, flops) per *query* — kernel rows report µs per query."""
    return 16.0 * l, 2.0 * l * l


def spmv_relax_model(q: int, v: int,
                     d_width: int = ELL_D_WIDTH) -> tuple[float, float]:
    """(bytes, flops) per relaxation call over the whole batch."""
    bytes_ = 8.0 * q * v + 8.0 * v * d_width
    return bytes_, 2.0 * q * v * d_width


def _kernel_rows(out_dir: str) -> list[dict]:
    rows = [r for r in common._ROWS if r["table"] == "kernels"]
    if rows:
        return rows
    for base in (out_dir, "."):
        path = Path(base) / "BENCH_kernels.json"
        if path.exists():
            return json.loads(path.read_text()).get("rows", [])
    return []


def main(full: bool = False):
    rows = _kernel_rows(common.OUT_DIR)
    if not rows:
        print("# roofline: no kernel rows — run the kernels suite first "
              "(python -m benchmarks.run --only kernels, same --out)")
        return
    for r in rows:
        name, us = r["name"], r["us_per_call"]
        if m := re.match(r"(label_intersect_\w+)\[(\d+)x(\d+)\]", name):
            nbytes, flops = label_intersect_model(int(m[2]), int(m[3]))
        elif m := re.match(r"(spmv_relax_\w+)\[q(\d+),v(\d+)\]", name):
            nbytes, flops = spmv_relax_model(int(m[2]), int(m[3]))
        else:
            continue                  # minplus rows carry gflops already
        s = us * 1e-6
        row("roofline", name, us,
            bytes_per_call=nbytes, flops_per_call=flops,
            intensity=round(flops / nbytes, 3),
            gbytes_per_s=round(nbytes / s / 1e9, 3),
            gflops_per_s=round(flops / s / 1e9, 3))


if __name__ == "__main__":
    main()
