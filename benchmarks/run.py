"""Benchmark driver: one module per paper table. Prints
``table,name,us_per_call,derived`` CSV rows and writes one
machine-readable ``BENCH_<table>.json`` per suite (``--out``, default
cwd) so the perf trajectory accumulates across PRs.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only tableX]
"""
from __future__ import annotations

import argparse
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graphs (slow)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_<table>.json files")
    args = ap.parse_args()

    from benchmarks import (bench_baselines, bench_construction,
                            bench_k_sweep, bench_kernels, bench_query,
                            bench_serving, bench_shard, common,
                            roofline_report)
    suites = {
        "table3_construction": bench_construction.main,
        "table4_5_query": bench_query.main,
        "table6_k_sweep": bench_k_sweep.main,
        "table8_baselines": bench_baselines.main,
        "kernels": bench_kernels.main,
        "serving": bench_serving.main,
        "shard": bench_shard.main,
        "roofline": roofline_report.main,
    }
    common.OUT_DIR = args.out
    print("table,name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        try:
            fn(full=args.full)
        except Exception as e:
            print(f"{name},ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc()
    for path in common.flush_rows(args.out):
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
