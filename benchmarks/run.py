"""Benchmark driver: one module per paper table. Prints
``table,name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only tableX]
"""
from __future__ import annotations

import argparse
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graphs (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_baselines, bench_construction,
                            bench_k_sweep, bench_kernels, bench_query,
                            roofline_report)
    suites = {
        "table3_construction": bench_construction.main,
        "table4_5_query": bench_query.main,
        "table6_k_sweep": bench_k_sweep.main,
        "table8_baselines": bench_baselines.main,
        "kernels": bench_kernels.main,
        "roofline": roofline_report.main,
    }
    print("table,name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        try:
            fn(full=args.full)
        except Exception as e:
            print(f"{name},ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
