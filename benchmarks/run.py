"""Benchmark driver: one module per paper table. Prints
``table,name,us_per_call,derived`` CSV rows and writes one
machine-readable ``BENCH_<table>.json`` per suite (``--out``, default
cwd) so the perf trajectory accumulates across PRs.

A suite that raises (including an exactness-gate AssertionError, e.g.
``bench_shard``'s bitwise gate or ``bench_path``'s path validation)
is reported as an ERROR row and the driver exits nonzero — CI's
``bench-smoke`` job relies on this to fail on any gate violation while
still uploading every ``BENCH_*.json`` produced. A suite that returns
without emitting a single row is treated the same way (EmptySuite):
a silently-empty ``BENCH_*.json`` would make the downstream
``bench-gate`` regression check vacuously green.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only tableX]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graphs (slow)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_<table>.json files")
    args = ap.parse_args()

    from benchmarks import (bench_baselines, bench_construction,
                            bench_k_sweep, bench_kernels, bench_mutation,
                            bench_path, bench_query, bench_serving,
                            bench_shard, common, roofline_report)
    suites = {
        "table3_construction": bench_construction.main,
        "table4_5_query": bench_query.main,
        "table6_k_sweep": bench_k_sweep.main,
        "table8_baselines": bench_baselines.main,
        "kernels": bench_kernels.main,
        "serving": bench_serving.main,
        "shard": bench_shard.main,
        "path": bench_path.main,
        "mutation": bench_mutation.main,
        "roofline": roofline_report.main,
    }
    common.OUT_DIR = args.out
    print("table,name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        before = len(common._ROWS)
        try:
            fn(full=args.full)
        except Exception as e:
            print(f"{name},ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc()
            failed.append(name)
            continue
        if len(common._ROWS) == before:
            print(f"{name},ERROR,0,EmptySuite:suite emitted zero rows")
            failed.append(name)
    for path in common.flush_rows(args.out):
        print(f"# wrote {path}")
    if failed:
        print(f"# FAILED suites: {','.join(failed)}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
