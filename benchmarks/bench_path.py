"""Path-retrieval benchmark: batch sizes × hop_cap tiers through the
``repro.paths`` engine, every cell gated on exactness — each
reconstructed path must have the queried endpoints, consist of real
original-graph edges, and its weight sum must equal the served distance
bitwise (integer-valued generator weights make float sums exact). A
sample of endpoints is additionally verified against the host Dijkstra
oracle.

Also times the scalar host oracle (``ISLabelIndex.shortest_path``) on a
sample to report the batched engine's speedup — the acceptance bar is
>= 10x at batch >= 64. Results accumulate in ``BENCH_path.json``.

  PYTHONPATH=src python -m benchmarks.bench_path [--full] [--out DIR]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks import common


def _sweep(full: bool):
    if full:
        return (64, 256, 1024), (64, 128, 256)
    return (64, 256), (64, 128)


def main(full: bool = False) -> None:
    import jax

    from repro.core import ISLabelIndex, IndexConfig, ref
    from repro.graphs import generators as gen
    from repro.paths import check_path_batch, edge_weight_map

    if full:
        n, src, dst, w = gen.rmat_graph(14, avg_deg=6.0, seed=1)
        kind = "rmat14"
    else:
        n, src, dst, w = gen.er_graph(1 << 10, 2.2, seed=2)
        kind = "er10"
    idx = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=512))
    engine = idx.path_engine()
    edges = edge_weight_map(src, dst, w)
    rng = np.random.default_rng(0)

    # scalar host-oracle baseline (the pre-batching hot path)
    n_scalar = 32
    ss = rng.integers(0, n, n_scalar)
    tt = rng.integers(0, n, n_scalar)
    idx.shortest_path(int(ss[0]), int(tt[0]))        # warm host caches
    t0 = time.perf_counter()
    for a, b in zip(ss, tt):
        idx.shortest_path(int(a), int(b))
    scalar_us = (time.perf_counter() - t0) / n_scalar * 1e6
    common.row("path", "scalar-oracle", scalar_us, batch=1)

    batches, hop_caps = _sweep(full)
    results, gate_passed, speedup_at_64 = [], True, 0.0
    for hop_cap in hop_caps:
        for batch in batches:
            s = rng.integers(0, n, batch).astype(np.int32)
            t = rng.integers(0, n, batch).astype(np.int32)
            fn = engine.path_batch_fn(hop_cap)
            sec, out = common.timeit(fn, s, t)
            out = jax.block_until_ready(out)
            # exactness gate 1: dist bitwise vs the query hot path
            want = np.asarray(idx.query(s, t), np.float32)
            dist_exact = np.array_equal(np.asarray(out.dist), want,
                                        equal_nan=True)
            # exactness gate 2: every non-overflowed path valid, weight
            # sum bitwise-equal to the served distance
            rep = check_path_batch(edges, s, t, out)
            # gate 3: sampled endpoints against the Dijkstra oracle
            k = min(batch, 64)
            srcs, inv = np.unique(s[:k], return_inverse=True)
            oracle = ref.dijkstra_oracle(n, src, dst, w, srcs)
            want_o = oracle[inv, t[:k]].astype(np.float32)
            fin = np.isfinite(want_o)
            got_k = np.asarray(out.dist)[:k]
            oracle_ok = bool(np.allclose(got_k[fin], want_o[fin])
                             and not np.isfinite(got_k[~fin]).any())
            cell_ok = (dist_exact and oracle_ok
                       and not rep["violations"])
            gate_passed &= cell_ok
            us_q = sec * 1e6 / batch
            speedup = scalar_us / us_q if us_q else 0.0
            if batch == 64 and speedup > speedup_at_64:
                speedup_at_64 = speedup
            common.row("path", f"b{batch}-h{hop_cap}", us_q,
                       batch=batch, hop_cap=hop_cap,
                       overflowed=rep["overflowed"],
                       speedup=round(speedup, 1), exact=cell_ok)
            results.append({
                "batch": batch, "hop_cap": hop_cap,
                "us_per_path": us_q, "speedup_vs_scalar": speedup,
                "checked": rep["checked"],
                "overflowed": rep["overflowed"],
                "violations": rep["violations"][:10],
                "dist_bitwise_vs_query": bool(dist_exact),
                "oracle_sample_ok": oracle_ok,
                "exact": bool(cell_ok),
            })
    common.write_json("path", {
        "graph": {"kind": kind, "n": int(n), "m": int(len(src))},
        "index": {"k": idx.k, "n_core": int(idx.stats.n_core),
                  "label_entries": int(idx.stats.label_entries)},
        "scalar_oracle_us": scalar_us,
        "speedup_at_batch64": speedup_at_64,
        "full": full,
        "gate": ("endpoints + real edges + weight sum bitwise == served "
                 "distance; dist bitwise vs QueryEngine; Dijkstra sample"),
        "gate_passed": bool(gate_passed),
        "results": results,
    })
    # fail after writing so a broken sweep still records which cells
    # diverged in BENCH_path.json
    if not gate_passed:
        bad = [(r["batch"], r["hop_cap"]) for r in results if not r["exact"]]
        raise AssertionError(f"path exactness gate failed for cells {bad}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=".")
    args = ap.parse_args()
    common.OUT_DIR = args.out
    main(full=args.full)
