"""Sharded-query benchmark: shard counts × batch sizes through the
``repro.shard`` subsystem, each cell gated on bitwise exactness vs the
unsharded engine (full path *and* μ lane). Results accumulate in
``BENCH_shard.json``.

Shard counts > the real device count need simulated devices, and
``XLA_FLAGS`` must be set before jax initializes — so when the process
has too few devices this suite re-execs itself in a subprocess with
``--xla_force_host_platform_device_count=<max shards>`` and streams the
child's CSV rows through (the child writes the JSON).

  PYTHONPATH=src python -m benchmarks.bench_shard [--full] [--out DIR]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path

import numpy as np

from benchmarks import common

SHARD_COUNTS = (1, 2, 4)


def _batch_sizes(full: bool):
    return (64, 256, 1024) if full else (64, 256)


def _reexec_with_devices(full: bool, n_dev: int) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_dev}"
                        ).strip()
    env["_BENCH_SHARD_CHILD"] = "1"
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.bench_shard",
           "--out", str(Path(common.OUT_DIR).resolve())] \
        + (["--full"] if full else [])
    r = subprocess.run(cmd, env=env, text=True, capture_output=True,
                       cwd=str(Path(__file__).resolve().parents[1]))
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(
            f"bench_shard subprocess failed:\n{r.stderr[-2000:]}")


def main(full: bool = False) -> None:
    import jax
    if len(jax.devices()) < max(SHARD_COUNTS):
        if os.environ.get("_BENCH_SHARD_CHILD"):
            raise RuntimeError(
                "forced device count did not take effect in the subprocess")
        _reexec_with_devices(full, max(SHARD_COUNTS))
        return
    _run(full)


def _run(full: bool) -> None:
    import jax
    from repro.core import ISLabelIndex, IndexConfig
    from repro.graphs import generators as gen
    from repro.shard import ShardedIndex

    if full:
        n, src, dst, w = gen.rmat_graph(14, avg_deg=6.0, seed=1)
        kind = "rmat14"
    else:
        n, src, dst, w = gen.er_graph(1 << 10, 2.2, seed=2)
        kind = "er10"
    idx = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=512))
    rng = np.random.default_rng(0)

    results, gate_passed = [], True
    for shards in SHARD_COUNTS:
        sidx = ShardedIndex.from_index(idx, shards, strategy="level")
        for batch in _batch_sizes(full):
            s = rng.integers(0, n, batch).astype(np.int32)
            t = rng.integers(0, n, batch).astype(np.int32)
            base_fn = idx.engine.batch_fn()
            shard_fn = sidx.engine.batch_fn()
            # exactness gate: full path (ans + rounds) and the μ lane
            want_ans, want_rounds = base_fn(s, t)
            got_ans, got_rounds = shard_fn(s, t)
            exact = (np.array_equal(np.asarray(got_ans),
                                    np.asarray(want_ans))
                     and int(got_rounds) == int(want_rounds)
                     and np.array_equal(
                         np.asarray(sidx.engine.mu_batch_fn()(s, t)),
                         np.asarray(idx.engine.mu_batch_fn()(s, t))))
            gate_passed &= exact
            us_base, _ = common.timeit(base_fn, s, t)
            us_shard, _ = common.timeit(shard_fn, s, t)
            us_base *= 1e6
            us_shard *= 1e6
            collectives = sidx.engine.collective_count(batch)
            common.row("shard", f"p{shards}-q{batch}", us_shard,
                       base_us=round(us_base, 1),
                       rel=round(us_shard / us_base, 3) if us_base else 0.0,
                       collectives=collectives,
                       cap=sidx.engine.cap, exact=exact)
            results.append({
                "shards": shards, "batch": batch,
                "us_sharded": us_shard, "us_unsharded": us_base,
                "cap_per_shard": int(sidx.engine.cap),
                "entries_per_shard": sidx.shard_entry_counts().tolist(),
                "collectives_per_batch": collectives,
                "exact_vs_unsharded": bool(exact),
            })
    common.write_json("shard", {
        "graph": {"kind": kind, "n": int(n), "m": int(len(src))},
        "index": {"k": idx.k, "n_core": int(idx.stats.n_core),
                  "label_entries": int(idx.stats.label_entries),
                  "l_cap": int(idx.cfg.l_cap)},
        "devices": len(jax.devices()),
        "strategy": "level",
        "full": full,
        "gate": "bitwise vs QueryEngine.batch_fn/mu_batch_fn",
        "gate_passed": bool(gate_passed),
        "results": results,
    })
    # fail after writing, so a diverging sweep still records which
    # cells broke (exact_vs_unsharded=False) in BENCH_shard.json
    if not gate_passed:
        bad = [(r["shards"], r["batch"]) for r in results
               if not r["exact_vs_unsharded"]]
        raise AssertionError(f"sharded != unsharded for (P, Q) in {bad}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=".")
    args = ap.parse_args()
    common.OUT_DIR = args.out
    main(full=args.full)
