"""Paper Tables 4 + 5: query time over 1000 random queries, split into
Time(a) label fetch+intersection vs Time(b) core search, and broken down
by endpoint type (1: both core, 2: one core, 3: neither).

Each graph is measured through BOTH dispatch paths side by side:

  * ``reference`` — the jnp searchsorted merge + COO scatter relaxation,
    one dense [Q, n_core+1] frontier per direction for the whole batch.
  * ``kernel``    — the Pallas label-intersect + ELL spmv_relax kernels,
    query-chunked so the stage-2 frontier is [chunk, n_core+1] and the
    full batch never materializes a dense [Q, n_core+1] matrix in one
    launch. On TPU this is the compiled production path over the full
    batch; off-TPU it runs interpret mode (same program, jnp evaluation,
    ~1000x slower), so it is measured on a smaller query subset — the
    row is a correctness demonstration there, not a speed claim.

Every path's answers are checked *exactly* (integer edge weights, no
rounding slack) against the core/ref.py Dijkstra oracle before its row
is printed; a mismatch aborts the benchmark.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import graphs_for_scale, row
from repro.core import ISLabelIndex, IndexConfig, ref


def _verify_exact(name, got, want):
    got = np.asarray(got)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all(), f"{name}: reachability mismatch"
    if not np.array_equal(got[fin], want[fin].astype(np.float32)):
        bad = np.flatnonzero(got[fin] != want[fin].astype(np.float32))
        raise AssertionError(
            f"{name}: {len(bad)} answers differ from Dijkstra oracle")


def main(full: bool = False):
    n_q = 1000
    on_tpu = jax.default_backend() == "tpu"
    # (row label, backend, query_chunk, n queries routed through the path)
    paths = [("reference", "reference", 0, n_q),
             ("kernel", "pallas", 256, n_q) if on_tpu else
             ("kernel", "interpret", 128, 256)]
    for name, (n, src, dst, w) in graphs_for_scale(full):
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=1024, label_chunk=2048))
        r = np.random.default_rng(0)
        s = r.integers(0, n, n_q).astype(np.int32)
        t = r.integers(0, n, n_q).astype(np.int32)
        want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(n_q), t]

        for label, backend, chunk, nq in paths:
            sj, tj = jnp.asarray(s[:nq]), jnp.asarray(t[:nq])
            # warmup (compile) — doubles as the exactness-gated run
            ans = idx.engine.query(sj, tj, backend=backend, query_chunk=chunk)
            jax.block_until_ready(ans)
            _verify_exact(f"{name}/{label}", ans, want[:nq])

            # Time (a): label gather + intersection only
            t0 = time.perf_counter()
            mu = idx.engine.query_mu_only(sj, tj, backend=backend)
            jax.block_until_ready(mu)
            ta = time.perf_counter() - t0

            # total
            t0 = time.perf_counter()
            ans = idx.engine.query(sj, tj, backend=backend, query_chunk=chunk)
            jax.block_until_ready(ans)
            tot = time.perf_counter() - t0
            tb = max(tot - ta, 0.0)
            row("table4_query", f"{name}/{label}", tot / nq * 1e6,
                backend=backend, query_chunk=chunk, n_queries=nq,
                total_ms=round(tot * 1e3, 2),
                time_a_ms=round(ta * 1e3, 2), time_b_ms=round(tb * 1e3, 2),
                relax_rounds=idx.engine._last_rounds, exact_vs_dijkstra=1)

        # Table 5: by endpoint type (default engine path)
        types = idx.query_types(s, t)
        for ty in (1, 2, 3):
            m = types == ty
            if m.sum() == 0:
                continue
            sq, tq = jnp.asarray(s[m]), jnp.asarray(t[m])
            jax.block_until_ready(idx.query(sq, tq))
            t0 = time.perf_counter()
            jax.block_until_ready(idx.query(sq, tq))
            dt = time.perf_counter() - t0
            row("table5_by_type", f"{name}/type{ty}",
                dt / max(int(m.sum()), 1) * 1e6, n_queries=int(m.sum()))


if __name__ == "__main__":
    main()
