"""Paper Tables 4 + 5: query time over 1000 random queries, split into
Time(a) label fetch+intersection vs Time(b) core search, and broken down
by endpoint type (1: both core, 2: one core, 3: neither).

Each graph is measured through BOTH dispatch paths side by side:

  * ``reference`` — the jnp searchsorted merge + COO scatter relaxation,
    one dense [Q, n_core+1] frontier per direction for the whole batch.
  * ``kernel``    — the Pallas label-intersect + ELL spmv_relax kernels,
    query-chunked so the stage-2 frontier is [chunk, n_core+1] and the
    full batch never materializes a dense [Q, n_core+1] matrix in one
    launch. On TPU this is the compiled production path over the full
    batch; off-TPU it runs interpret mode (same program, jnp evaluation,
    ~1000x slower), so it is measured on a smaller query subset — the
    row is a correctness demonstration there, not a speed claim.

Every path's answers are checked *exactly* (integer edge weights, no
rounding slack) against the core/ref.py Dijkstra oracle before its row
is printed; a mismatch aborts the benchmark.

Two extra row families on the first graph gate this PR's optimizations:

  * ``relax_fused`` vs ``relax_loop`` — the same batch-64 query run
    with the stage-2 dispatcher pinned to the fused all-rounds kernel
    vs the legacy one-launch-per-round loop; answers and round counts
    asserted bitwise-equal before the speedup is reported.
  * ``compressed`` — a ``label_dtype="auto"`` index (delta16 ids +
    int32 distances, decode fused into the kernels) Dijkstra-verified
    end to end, with the label-plane bytes saved.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import graphs_for_scale, row
from repro.core import ISLabelIndex, IndexConfig, ref
from repro.core.dispatch import CoreRelaxer
from repro.core.labels import encoded_nbytes


def _verify_exact(name, got, want):
    got = np.asarray(got)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all(), f"{name}: reachability mismatch"
    if not np.array_equal(got[fin], want[fin].astype(np.float32)):
        bad = np.flatnonzero(got[fin] != want[fin].astype(np.float32))
        raise AssertionError(
            f"{name}: {len(bad)} answers differ from Dijkstra oracle")


def _fused_vs_loop(name, eng, kb, s, t, want):
    """Batch-64 query through the fused stage-2 kernel vs the per-round
    launch loop (same engine, relaxer pinned per run): bitwise-equal
    answers and rounds asserted, speedup reported."""
    qf = 64
    sj, tj = jnp.asarray(s[:qf]), jnp.asarray(t[:qf])
    fused_rx = CoreRelaxer(eng.ce_src, eng.ce_dst, eng.ce_w, eng.n_core,
                           dense_threshold=2.0)
    if fused_rx.mode != "fused":
        if kb == "pallas":
            # real VMEM: the graph's ELL width doesn't fit the fused
            # budget — the ell_loop fallback IS the production route
            # here, so there is no fused row to measure.
            print(f"# {name}: fused working set over VMEM budget, "
                  "skipping fused-vs-loop row")
            return
        # interpret mode has no real VMEM; widen the budget so the
        # comparison still runs on wide-ELL graphs
        fused_rx = CoreRelaxer(eng.ce_src, eng.ce_dst, eng.ce_w,
                               eng.n_core, dense_threshold=2.0,
                               vmem_budget=1 << 62)
    loop_rx = CoreRelaxer(eng.ce_src, eng.ce_dst, eng.ce_w, eng.n_core,
                          fused=False, dense_threshold=2.0)
    assert fused_rx.mode == "fused" and loop_rx.mode == "ell_loop"
    orig = eng.relaxer
    out = {}
    try:
        for label, rx in (("relax_loop", loop_rx), ("relax_fused", fused_rx)):
            eng.relaxer = rx
            ans = eng.query(sj, tj, backend=kb, query_chunk=0)
            jax.block_until_ready(ans)             # compile + exactness run
            _verify_exact(f"{name}/{label}", ans, want[:qf])
            t0 = time.perf_counter()
            ans = eng.query(sj, tj, backend=kb, query_chunk=0)
            jax.block_until_ready(ans)
            out[label] = (time.perf_counter() - t0, np.asarray(ans),
                          eng._last_rounds)
    finally:
        eng.relaxer = orig
    tl, ans_l, r_l = out["relax_loop"]
    tf, ans_f, r_f = out["relax_fused"]
    assert r_f == r_l, f"{name}: fused/loop rounds differ ({r_f} != {r_l})"
    fin = np.isfinite(ans_l)
    assert (np.isfinite(ans_f) == fin).all() \
        and np.array_equal(ans_f[fin], ans_l[fin]), \
        f"{name}: fused/loop answers not bitwise-equal"
    row("table4_query", f"{name}/relax_loop", tl / qf * 1e6,
        backend=kb, batch=qf, relax_rounds=r_l, exact_vs_dijkstra=1)
    row("table4_query", f"{name}/relax_fused", tf / qf * 1e6,
        backend=kb, batch=qf, relax_rounds=r_f, exact_vs_dijkstra=1,
        bitwise_vs_loop=1, speedup_vs_loop=round(tl / tf, 2))


def _compressed_row(name, n, src, dst, w, backend, chunk, nq, s, t, want):
    """label_dtype="auto" index served end to end, Dijkstra-verified."""
    idx = ISLabelIndex.build(
        n, src, dst, w,
        IndexConfig(l_cap=1024, label_chunk=2048, label_dtype="auto"))
    eng = idx.engine
    sj, tj = jnp.asarray(s[:nq]), jnp.asarray(t[:nq])
    ans = eng.query(sj, tj, backend=backend, query_chunk=chunk)
    jax.block_until_ready(ans)
    _verify_exact(f"{name}/compressed", ans, want[:nq])
    t0 = time.perf_counter()
    ans = eng.query(sj, tj, backend=backend, query_chunk=chunk)
    jax.block_until_ready(ans)
    tot = time.perf_counter() - t0
    saved = 0.0
    if eng.codec != "none":
        nb_fp32 = np.asarray(eng.lbl_ids).nbytes + np.asarray(eng.lbl_d).nbytes
        nb_enc = encoded_nbytes(eng.enc_ids, eng.enc_base, eng.enc_d)
        saved = round(100.0 * (1 - nb_enc / nb_fp32), 1)
    row("table4_query", f"{name}/compressed", tot / nq * 1e6,
        backend=backend, query_chunk=chunk, n_queries=nq, codec=eng.codec,
        label_bytes_saved_pct=saved, exact_vs_dijkstra=1)


def main(full: bool = False):
    n_q = 1000
    on_tpu = jax.default_backend() == "tpu"
    # (row label, backend, query_chunk, n queries routed through the path)
    paths = [("reference", "reference", 0, n_q),
             ("kernel", "pallas", 256, n_q) if on_tpu else
             ("kernel", "interpret", 128, 256)]
    first = True
    for name, (n, src, dst, w) in graphs_for_scale(full):
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=1024, label_chunk=2048))
        r = np.random.default_rng(0)
        s = r.integers(0, n, n_q).astype(np.int32)
        t = r.integers(0, n, n_q).astype(np.int32)
        want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(n_q), t]

        for label, backend, chunk, nq in paths:
            sj, tj = jnp.asarray(s[:nq]), jnp.asarray(t[:nq])
            # warmup (compile) — doubles as the exactness-gated run
            ans = idx.engine.query(sj, tj, backend=backend, query_chunk=chunk)
            jax.block_until_ready(ans)
            _verify_exact(f"{name}/{label}", ans, want[:nq])

            # Time (a): label gather + intersection only
            t0 = time.perf_counter()
            mu = idx.engine.query_mu_only(sj, tj, backend=backend)
            jax.block_until_ready(mu)
            ta = time.perf_counter() - t0

            # total
            t0 = time.perf_counter()
            ans = idx.engine.query(sj, tj, backend=backend, query_chunk=chunk)
            jax.block_until_ready(ans)
            tot = time.perf_counter() - t0
            tb = max(tot - ta, 0.0)
            row("table4_query", f"{name}/{label}", tot / nq * 1e6,
                backend=backend, query_chunk=chunk, n_queries=nq,
                total_ms=round(tot * 1e3, 2),
                time_a_ms=round(ta * 1e3, 2), time_b_ms=round(tb * 1e3, 2),
                relax_rounds=idx.engine._last_rounds, exact_vs_dijkstra=1)

        if first and idx.engine.n_core > 0:
            _, kb, chunk, nq = paths[-1]
            _fused_vs_loop(name, idx.engine, kb, s, t, want)
            _compressed_row(name, n, src, dst, w, kb, chunk,
                            min(nq, 256), s, t, want)
            first = False

        # Table 5: by endpoint type (default engine path)
        types = idx.query_types(s, t)
        for ty in (1, 2, 3):
            m = types == ty
            if m.sum() == 0:
                continue
            sq, tq = jnp.asarray(s[m]), jnp.asarray(t[m])
            jax.block_until_ready(idx.query(sq, tq))
            t0 = time.perf_counter()
            jax.block_until_ready(idx.query(sq, tq))
            dt = time.perf_counter() - t0
            row("table5_by_type", f"{name}/type{ty}",
                dt / max(int(m.sum()), 1) * 1e6, n_queries=int(m.sum()))


if __name__ == "__main__":
    main()
