"""Paper Tables 4 + 5: query time over 1000 random queries, split into
Time(a) label fetch+intersection vs Time(b) core search, and broken down
by endpoint type (1: both core, 2: one core, 3: neither)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import graphs_for_scale, row
from repro.core import ISLabelIndex, IndexConfig
from repro.core.query import label_intersect_mu


def main(full: bool = False):
    n_q = 1000
    for name, (n, src, dst, w) in graphs_for_scale(full):
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=1024, label_chunk=2048))
        r = np.random.default_rng(0)
        s = r.integers(0, n, n_q).astype(np.int32)
        t = r.integers(0, n, n_q).astype(np.int32)

        # warmup (compile)
        jax.block_until_ready(idx.query(s, t))

        # Time (a): label gather + intersection only
        sj, tj = jnp.asarray(s), jnp.asarray(t)
        t0 = time.perf_counter()
        mu = idx.engine.query_mu_only(sj, tj)
        jax.block_until_ready(mu)
        ta = time.perf_counter() - t0

        # total
        t0 = time.perf_counter()
        ans = idx.query(sj, tj)
        jax.block_until_ready(ans)
        tot = time.perf_counter() - t0
        tb = max(tot - ta, 0.0)
        row("table4_query", name, tot / n_q * 1e6,
            total_ms_per_1k=round(tot * 1e3, 2),
            time_a_ms=round(ta * 1e3, 2), time_b_ms=round(tb * 1e3, 2),
            relax_rounds=idx.engine._last_rounds)

        # Table 5: by endpoint type
        types = idx.query_types(s, t)
        for ty in (1, 2, 3):
            m = types == ty
            if m.sum() == 0:
                continue
            sq, tq = jnp.asarray(s[m]), jnp.asarray(t[m])
            jax.block_until_ready(idx.query(sq, tq))
            t0 = time.perf_counter()
            jax.block_until_ready(idx.query(sq, tq))
            dt = time.perf_counter() - t0
            row("table5_by_type", f"{name}/type{ty}",
                dt / max(int(m.sum()), 1) * 1e6, n_queries=int(m.sum()))


if __name__ == "__main__":
    main()
