"""Paper Table 8: IS-LABEL vs baselines.

* IM-DIJ — in-memory bidirectional Dijkstra (the paper's baseline),
* DIJ    — early-exit unidirectional Dijkstra,
* BF-JAX — label-free batched Bellman-Ford over the *full* graph (what
  a TPU implementation without the paper's index would do; the honest
  'no-index' device baseline).

IS-LABEL serves batched queries; baselines are per-query — we report
per-query microseconds for all.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import graphs_for_scale, row
from repro.core import ISLabelIndex, IndexConfig, ref


def bf_jax_batch(n, src, dst, w, s, t, rounds=64):
    import repro.graphs.segment_ops as sops
    q = len(s)
    dist = jnp.full((q, n), jnp.inf, jnp.float32)
    dist = dist.at[jnp.arange(q), jnp.asarray(s)].set(0.0)
    srcj, dstj = jnp.asarray(src), jnp.asarray(dst)
    wj = jnp.asarray(w)

    def body(d, _):
        cand = d[:, srcj] + wj[None, :]
        return d.at[:, dstj].min(cand), None
    dist, _ = jax.lax.scan(body, dist, None, length=rounds)
    return dist[jnp.arange(q), jnp.asarray(t)]


def main(full: bool = False):
    n_q = 200 if not full else 500
    for name, (n, src, dst, w) in graphs_for_scale(full):
        r = np.random.default_rng(0)
        s = r.integers(0, n, n_q).astype(np.int32)
        t = r.integers(0, n, n_q).astype(np.int32)

        t0 = time.perf_counter()
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=1024, label_chunk=2048))
        build = time.perf_counter() - t0
        jax.block_until_ready(idx.query(s, t))
        t0 = time.perf_counter()
        ans = idx.query(s, t)
        jax.block_until_ready(ans)
        t_isl = (time.perf_counter() - t0) / n_q
        row("table8_baselines", f"{name}/IS-LABEL", t_isl * 1e6,
            build_s=round(build, 2))

        # IM-DIJ on a subset (python-loop baseline is slow)
        k = min(n_q, 50)
        t0 = time.perf_counter()
        im = [ref.bidijkstra(n, src, dst, w, int(s[i]), int(t[i]))
              for i in range(k)]
        t_im = (time.perf_counter() - t0) / k
        row("table8_baselines", f"{name}/IM-DIJ", t_im * 1e6,
            speedup=round(t_im / max(t_isl, 1e-9), 1))

        t0 = time.perf_counter()
        dj = [ref.dijkstra_p2p(n, src, dst, w, int(s[i]), int(t[i]))
              for i in range(k)]
        t_dj = (time.perf_counter() - t0) / k
        row("table8_baselines", f"{name}/DIJ", t_dj * 1e6,
            speedup=round(t_dj / max(t_isl, 1e-9), 1))

        # correctness cross-check among all methods
        a = np.asarray(ans[:k])
        for nm, other in (("IM-DIJ", im), ("DIJ", dj)):
            o = np.asarray(other)
            fin = np.isfinite(o)
            assert (np.isfinite(a) == fin).all(), f"{nm} connectivity"
            np.testing.assert_allclose(a[fin], o[fin], rtol=1e-5)

        # VC-Index-style baseline: one-level hierarchy (k=2) — the
        # vertex-cover special case of IS-LABEL (see core/vc_baseline.py)
        from repro.core.vc_baseline import build_vc_index
        t0 = time.perf_counter()
        vc = build_vc_index(n, src, dst, w,
                            IndexConfig(l_cap=1024, label_chunk=2048))
        vc_build = time.perf_counter() - t0
        jax.block_until_ready(vc.query(s, t))
        t0 = time.perf_counter()
        vans = vc.query(s, t)
        jax.block_until_ready(vans)
        t_vc = (time.perf_counter() - t0) / n_q
        row("table8_baselines", f"{name}/VC-Index(k=2)", t_vc * 1e6,
            build_s=round(vc_build, 2), V_core=vc.stats.n_core,
            speedup=round(t_vc / max(t_isl, 1e-9), 1))
        o = np.asarray(vans[:k])
        fin = np.isfinite(o)
        np.testing.assert_allclose(a[fin], o[fin], rtol=1e-5)

        # no-index device baseline
        bf = jax.jit(lambda sq, tq: bf_jax_batch(n, src, dst, w, sq, tq))
        jax.block_until_ready(bf(s[:64], t[:64]))
        t0 = time.perf_counter()
        jax.block_until_ready(bf(s[:64], t[:64]))
        t_bf = (time.perf_counter() - t0) / 64
        row("table8_baselines", f"{name}/BF-JAX-noindex", t_bf * 1e6,
            speedup=round(t_bf / max(t_isl, 1e-9), 1))


if __name__ == "__main__":
    main()
