"""Shared benchmark utilities. Every table prints CSV rows:
``table,name,us_per_call,derived...`` — and every row is also collected
so the driver can write machine-readable ``BENCH_<table>.json`` files
(the cross-PR perf trajectory; see ``run.py`` / ``bench_serving.py``).
"""
from __future__ import annotations

import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

_ROWS: list[dict] = []
_WRITTEN: set[str] = set()
OUT_DIR = "."          # run.py --out overrides; suites write through here


def _env() -> dict:
    return {
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args)) if _is_jax(fn, args) else fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def _is_jax(fn, args):
    return True


def row(table, name, us, **derived):
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{table},{name},{us:.1f},{extra}")
    keep = {k: v if isinstance(v, (int, float, bool)) or v is None else str(v)
            for k, v in derived.items()}
    _ROWS.append({"table": table, "name": name, "us_per_call": float(us),
                  **keep})


def write_json(table: str, payload: dict, out_dir=None) -> Path:
    """Write ``BENCH_<table>.json``: the given payload plus this run's
    collected CSV rows for the table and environment info. Tables
    written here are skipped by ``flush_rows``."""
    out = Path(OUT_DIR if out_dir is None else out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"BENCH_{table}.json"
    doc = {"table": table, "env": _env(),
           "rows": [r for r in _ROWS if r["table"] == table], **payload}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    _WRITTEN.add(table)
    return path


def flush_rows(out_dir=None) -> list[Path]:
    """One ``BENCH_<table>.json`` per table that only emitted CSV rows."""
    out = []
    for table in sorted({r["table"] for r in _ROWS} - _WRITTEN):
        out.append(write_json(table, {}, out_dir))
    return out


def graphs_for_scale(full: bool):
    """Benchmark graph suite: (name, (n, src, dst, w)). Mirrors the
    paper's dataset regimes (Table 2) at container scale."""
    from repro.graphs import generators as gen
    if full:
        specs = [("rmat17-web", lambda: gen.rmat_graph(17, 8.0, seed=1)),
                 ("rmat15", lambda: gen.rmat_graph(15, 8.0, seed=1)),
                 ("er16-btc", lambda: gen.er_graph(1 << 16, 2.2, seed=2)),
                 ("grid181-road", lambda: gen.grid_graph(181, seed=3))]
    else:
        specs = [("rmat12-web", lambda: gen.rmat_graph(12, 8.0, seed=1)),
                 ("er12-btc", lambda: gen.er_graph(1 << 12, 2.2, seed=2)),
                 ("grid64-road", lambda: gen.grid_graph(64, seed=3))]
    return [(name, mk()) for name, mk in specs]
