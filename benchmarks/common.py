"""Shared benchmark utilities. Every table prints CSV rows:
``table,name,us_per_call,derived...``"""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args)) if _is_jax(fn, args) else fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def _is_jax(fn, args):
    return True


def row(table, name, us, **derived):
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{table},{name},{us:.1f},{extra}")


def graphs_for_scale(full: bool):
    """Benchmark graph suite: (name, (n, src, dst, w)). Mirrors the
    paper's dataset regimes (Table 2) at container scale."""
    from repro.graphs import generators as gen
    if full:
        specs = [("rmat17-web", lambda: gen.rmat_graph(17, 8.0, seed=1)),
                 ("rmat15", lambda: gen.rmat_graph(15, 8.0, seed=1)),
                 ("er16-btc", lambda: gen.er_graph(1 << 16, 2.2, seed=2)),
                 ("grid181-road", lambda: gen.grid_graph(181, seed=3))]
    else:
        specs = [("rmat12-web", lambda: gen.rmat_graph(12, 8.0, seed=1)),
                 ("er12-btc", lambda: gen.er_graph(1 << 12, 2.2, seed=2)),
                 ("grid64-road", lambda: gen.grid_graph(64, seed=3))]
    return [(name, mk()) for name, mk in specs]
