"""Logical-axis -> mesh-axis sharding rules, per model family.

Params carry logical axis names (see models/*.py ``init_*``); the rules
below produce PartitionSpecs/NamedShardings. Conventions:

* LM: tensor-parallel over ``model`` (heads / ffn / vocab / experts),
  FSDP over ``data`` (the ``embed`` dim of weight matrices), pure DP
  over ``pod`` (weights replicated across pods; gradients reduced
  cross-pod, optionally compressed). Batch over (pod, data).
* GNN: edge/node arrays sharded over all mesh axes flattened; model
  params replicated (they are tiny).
* RecSys: embedding-table rows over ``model``; batch over (pod, data);
  dense tower params replicated.
* graph_index (IS-LABEL): label-partition blocks over the 1-D ``shard``
  axis (``repro.shard``); everything whose consistency the core search
  depends on — vertex-indexed rows, hierarchy levels, the core graph —
  is replicated so the Equation-1 partial minima are the only
  cross-shard traffic (one collective per batch; docs/SHARDING.md).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

LM_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "experts_router": None,
    "embed": "data",          # FSDP shard of the weight's embed dim
    "layers": None,
}

GNN_RULES = {k: None for k in
             ("gnn_in", "gnn_hidden", "rbf", "sbf", "bilinear",
              "mlp_in", "mlp_out")}

RECSYS_RULES = {
    "table_rows": "model",
    "table_dim": None,
    "gru_in": None, "gru_h": None,
    "mlp_in": None, "mlp_out": None,
}

# IS-LABEL partitioned index (repro.shard.ShardedIndex): label blocks
# are stacked [P, n+1, cap_s] with the leading label-partition axis laid
# over the mesh's "shard" axis; per-vertex rows ("vertex"), label slots,
# hierarchy levels, and the whole core graph stay replicated — the core
# search runs shard-locally (top levels are replicated into every label
# block) and only the Equation-1 partial minima cross shards.
GRAPH_INDEX_RULES = {
    "label_shard": "shard",   # one label partition per mesh slice
    "vertex": None,           # [n+1] rows: every shard sees all vertices
    "label_slot": None,       # padded per-shard label columns
    "level": None,            # hierarchy levels: replicated
    "core_vertex": None,      # core_pos / seed columns: replicated
    "core_edge": None,        # G_k COO arrays: replicated
}

FAMILY_RULES = {"lm": LM_RULES, "gnn": GNN_RULES, "recsys": RECSYS_RULES,
                "graph_index": GRAPH_INDEX_RULES}


def spec_for_axes(axes: tuple, rules: dict) -> P:
    parts = []
    for ax in axes:
        r = rules.get(ax, None)
        parts.append(r)
    return P(*parts)


def tree_shardings(axes_tree, rules: dict, mesh):
    """Map a logical-axes tree to NamedShardings."""
    def one(ax):
        return NamedSharding(mesh, spec_for_axes(ax, rules))
    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def like_tree(tree, sharding):
    """Uniform sharding for every leaf of an (abstract) tree."""
    return jax.tree.map(lambda _: sharding, tree)


def opt_state_shardings(opt_name: str, params_abs, param_shardings, mesh):
    """Optimizer state shards exactly like its param (ZeRO); Adafactor's
    factored stats drop the reduced dim from the spec."""
    if opt_name == "adamw":
        return {"mu": param_shardings, "nu": param_shardings}
    assert opt_name == "adafactor"

    def one(p_abs, psh):
        nd = len(p_abs.shape)
        spec = tuple(psh.spec) + (None,) * (nd - len(psh.spec))
        if nd >= 2:
            return {"vr": NamedSharding(mesh, P(*spec[:-1])),
                    "vc": NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))}
        return {"v": NamedSharding(mesh, P(*spec))}

    return jax.tree.map(one, params_abs, param_shardings,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
