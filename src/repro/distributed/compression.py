"""Cross-pod gradient compression with error feedback.

The pod axis is the slow interconnect (DCN between pods vs ICI inside a
pod). Baseline multi-pod training all-reduces fp32 gradients across
pods; this module replaces that with **error-feedback int8**:

  1. residual-corrected gradient g' = g + e  (error feedback state e)
  2. per-tensor scale s = max|g'| / 127 shared via a tiny f32 all-reduce
  3. q = round(g'/s) as int8, all-gathered across the pod axis
     (int8 gather = P*N bytes vs fp32 ring all-reduce ~ 2*4*N bytes:
     4x less cross-pod traffic at P=2, plus 4x smaller messages)
  4. dequantized mean becomes the update; e' = g' - dequant(q)

Used inside a ``shard_map`` over the 'pod' axis only — within-pod
reduction stays fp32. The error-feedback state makes the compression
unbiased over time (Karimireddy et al., arXiv:1901.09847).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(g, scale):
    return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_pod(grads, err, mesh, axis: str = "pod"):
    """grads/err: pytrees already reduced within pod, replicated across
    the non-pod axes. Returns (mean_grads, new_err)."""
    n_pods = mesh.shape[axis]

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis) / 127.0 + 1e-12
        q = quantize_int8(gf, scale)
        # all-gather int8 across pods, then local mean (cross-pod bytes:
        # N int8 per pod vs 2N fp32 for ring all-reduce)
        allq = jax.lax.all_gather(q, axis)              # [P, ...]
        mean = jnp.mean(dequantize_int8(allq, scale), axis=0)
        new_e = gf - dequantize_int8(q, scale)
        return mean.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def make_compressed_grad_fn(loss_and_grad_fn, mesh):
    """Wrap a per-pod loss/grad fn with cross-pod compressed reduction.

    loss_and_grad_fn(params, batch) must return (loss, grads); the batch
    is sharded over the pod axis, params replicated across pods, and the
    error-feedback state carries a leading per-pod axis (each pod owns
    its own residual). Runs under shard_map on the pod axis with the
    data/model axes left to GSPMD (auto)."""
    from jax import shard_map

    def fn(params, err_stacked, batch):
        def inner(params, err, batch):
            err = jax.tree.map(lambda e: e[0], err)          # drop pod dim
            loss, grads = loss_and_grad_fn(params, batch)
            grads, new_err = compressed_psum_pod(grads, err, mesh)
            loss = jax.lax.pmean(loss, "pod")
            return loss, grads, jax.tree.map(lambda e: e[None], new_err)

        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P("pod"), P("pod")),
            out_specs=(P(), P(), P("pod")),
            check_vma=False, axis_names=frozenset({"pod"}),
        )(params, err_stacked, batch)

    return fn


def init_error_feedback(params, n_pods: int = 1):
    """Per-pod residual state: leading axis = pod."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
