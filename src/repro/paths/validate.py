"""Host-side path validation — the exactness gate shared by tests,
``benchmarks/bench_path.py``, and the ``launch/serve.py --mode path``
audit.

A reconstructed path is *valid* iff: its endpoints are the queried
(s, t); every consecutive pair is an edge of the original graph with
the weight the engine reported; and the weight sum reproduces the
served distance. With the repo's integer-valued weights (graph
generators emit 1..max_w) every sum is exactly representable, so the
distance check is bitwise; for general float weights it falls back to a
relative tolerance.
"""
from __future__ import annotations

import numpy as np


def edge_weight_map(src, dst, w) -> dict:
    """(u, v) -> min edge weight over parallel edges (float)."""
    out: dict = {}
    for a, b, ww in zip(np.asarray(src), np.asarray(dst), np.asarray(w)):
        key = (int(a), int(b))
        ww = float(ww)
        if key not in out or ww < out[key]:
            out[key] = ww
    return out


def integral_weights(edges: dict) -> bool:
    """True when every edge weight is integer-valued (float32 sums are
    then exact, so the distance comparison can be bitwise)."""
    return all(float(w).is_integer() for w in edges.values())


def check_vertex_path(edges: dict, s: int, t: int, dist: float, path,
                      rtol: float = 1e-5,
                      exact: bool | None = None) -> list[str]:
    """Violations for one plain vertex-list path (empty list = valid):
    correct endpoints, every hop a real edge, weight sum equal to the
    served distance — bitwise when ``exact`` (default: iff every graph
    weight is integer-valued), else within ``rtol``. Shared by the
    engine-output gate below and the serving/CLI audits.
    """
    errors: list[str] = []
    if not np.isfinite(dist):
        if len(path):
            errors.append(f"unreachable ({s},{t}) returned a "
                          f"{len(path)}-vertex path")
        return errors
    if len(path) < 1:
        return [f"({s},{t}): finite distance {dist} but empty path"]
    if path[0] != s or path[-1] != t:
        errors.append(f"({s},{t}): endpoints {path[0]}..{path[-1]}")
    total = 0.0
    for i, (a, b) in enumerate(zip(path[:-1], path[1:])):
        want_w = edges.get((a, b))
        if want_w is None:
            errors.append(f"({s},{t}): non-edge ({a},{b}) at hop {i}")
            continue
        total += want_w
    dist32 = np.float32(dist)
    sum32 = np.float32(total)
    if exact is None:
        exact = integral_weights(edges)
    exact_ok = sum32 == dist32 if exact else \
        np.isclose(sum32, dist32, rtol=rtol)
    if errors == [] and not exact_ok:
        errors.append(f"({s},{t}): weight sum {sum32} != distance {dist32}")
    return errors


def check_path(edges: dict, s: int, t: int, dist: float, verts, weights,
               length: int, ok: bool, rtol: float = 1e-5,
               exact: bool | None = None) -> list[str]:
    """Violations for one reconstructed ``PathBatch`` entry (empty list
    = valid): the vertex-path gate above plus agreement of the
    engine-reported per-edge weight plane with the graph.

    Overflowed paths (``ok=False``) are not judged — the caller decides
    whether an overflow at its hop_cap tier is acceptable.
    """
    if not ok:
        return []
    vs = [int(v) for v in np.asarray(verts)[:length]]
    errors = check_vertex_path(edges, s, t, dist, vs, rtol=rtol, exact=exact)
    for i, (a, b) in enumerate(zip(vs[:-1], vs[1:])):
        want_w = edges.get((a, b))
        got_w = float(np.asarray(weights)[i])
        if want_w is not None and got_w != want_w:
            errors.append(f"({s},{t}): edge ({a},{b}) weight {got_w} != "
                          f"graph weight {want_w}")
    return errors


def check_path_batch(edges: dict, s, t, batch, rtol: float = 1e-5) -> dict:
    """Gate a whole ``PathBatch`` (or host tuples with the same
    fields). Returns {"checked", "overflowed", "violations": [...]}.
    """
    s = np.atleast_1d(np.asarray(s))
    t = np.atleast_1d(np.asarray(t))
    dist = np.asarray(batch.dist)
    verts = np.asarray(batch.verts)
    weights = np.asarray(batch.weights)
    lens = np.asarray(batch.lens)
    ok = np.asarray(batch.ok)
    violations: list[str] = []
    checked = overflowed = 0
    exact = integral_weights(edges)
    for i in range(len(s)):
        if not ok[i]:
            overflowed += 1
            continue
        checked += 1
        violations += check_path(edges, int(s[i]), int(t[i]),
                                 float(dist[i]), verts[i], weights[i],
                                 int(lens[i]), True, rtol=rtol, exact=exact)
    return {"checked": checked, "overflowed": overflowed,
            "violations": violations}
