"""Batched device-side shortest-path reconstruction (paper §8.1).

The host oracle (``ISLabelIndex.shortest_path``) walks the label pred
chain and the ``via`` bookkeeping with Python recursion — exact, but
one query at a time. This module is the fixed-shape, jitted analogue:
every stage operates on a whole ``[Q]`` batch at once and every array
has a static shape, so a single compiled executable serves any batch of
that shape (the serving contract mirrors ``QueryEngine``).

Stages (all inside one jitted function, see ``engine.PathEngine``):

  1. *meet* — Equation 1 (``label_intersect_mu``) gives μ and the
     meeting ancestor; the label-seeded core relaxation (the same
     ``CoreRelaxer`` dispatch the query hot path uses) gives the fixed
     point DS/DT, and ``argmin(DS + DT)`` the meeting core vertex.
     A query takes the *label route* when μ ≤ the core term, the *core
     route* otherwise (ties prefer the label route, like the oracle).

  2. *core parent chase* — predecessors are recovered from the fixed
     point itself: u is a parent of v iff ``DS[u] + w(u, v) == DS[v]``
     (exact float equality — at the Bellman-Ford fixed point the min is
     attained, so a parent always exists unless v is a label seed,
     ``DS[v] == seed[v]``, which ends the chase). Each chase step is a
     ``[Q, D]`` gather over the same ELL layout ``spmv_relax`` consumes
     (with a via plane added), so no ``[Q, V, D]`` tensor is ever
     materialized and no extra state is carried through the relaxation.

  3. *stitch* — label hops of s, the reversed s-side core segment, the
     forward t-side core segment, and the reversed label hops of t are
     scattered into one ``[Q, hop_cap]`` edge list (vertex, via, w).

  4. *via expansion* — the recursive §8.1 expansion becomes an
     iterative insertion loop: every augmenting edge (a, b) with
     ``via = c`` splits into (a, c) + (c, b), whose vias/weights come
     from c's up-adjacency row. One round expands *every* pending edge
     in the batch via a prefix-sum scatter; nesting depth is bounded by
     the hierarchy height k, so the loop runs at most k rounds.

Fixed capacities: label chases are bounded by k (levels strictly
increase along the pred chain), core chases and the final path by
``hop_cap``. Overflow never aborts the batch — the query's ``ok`` flag
drops and the caller escalates to a larger ``hop_cap`` (the serving
layer shape-buckets on it; see docs/PATHS.md).

Weights are carried *per edge* through every split, so the returned
``[Q, hop_cap]`` weight plane holds original-graph edge weights whose
sum reproduces the served distance — the exactness gate asserted in
tests and ``benchmarks/bench_path.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def label_chase(lbl_ids, lbl_pred, up_ids, up_w, up_via, start, target,
                active, chase_cap: int, n: int):
    """Walk the label pred chain ``start -> target`` for a batch.

    Returns ``(hop_v, hop_via, hop_w, hops, ok)`` with ``hop_v[q, i]``
    the i-th path vertex (the edge i leads to vertex i+1; the final
    vertex ``target`` is implicit) and ``hops[q]`` the hop count.
    Queries with ``active=False`` report zero hops. ``ok`` drops when
    the chain is inconsistent or longer than ``chase_cap``.
    """
    q = start.shape[0]
    l_cap = lbl_ids.shape[1]
    hop_v = jnp.full((q, chase_cap), n, jnp.int32)
    hop_via = jnp.full((q, chase_cap), -1, jnp.int32)
    hop_w = jnp.zeros((q, chase_cap), jnp.float32)

    def cond(st):
        _, i, _, _, _, _, _, act = st
        return jnp.any(act) & (i < chase_cap)

    def body(st):
        cur, i, hv, hvia, hw, hops, ok, act = st
        row_ids = lbl_ids[cur]                          # [Q, L]
        j = jax.vmap(jnp.searchsorted)(row_ids, target)
        j = jnp.minimum(j, l_cap - 1)
        found = jnp.take_along_axis(row_ids, j[:, None], 1)[:, 0] == target
        u = jnp.take_along_axis(lbl_pred[cur], j[:, None], 1)[:, 0]
        urow = up_ids[cur]                              # [Q, d_cap]
        hit = urow == u[:, None]
        slot = jnp.argmax(hit, axis=1)
        step_ok = found & (u >= 0) & jnp.any(hit, axis=1)
        via = jnp.take_along_axis(up_via[cur], slot[:, None], 1)[:, 0]
        w = jnp.take_along_axis(up_w[cur], slot[:, None], 1)[:, 0]
        write = act & step_ok
        hv = hv.at[:, i].set(jnp.where(write, cur, hv[:, i]))
        hvia = hvia.at[:, i].set(jnp.where(write, via, hvia[:, i]))
        hw = hw.at[:, i].set(jnp.where(write, w, hw[:, i]))
        hops = hops + write.astype(jnp.int32)
        ok = ok & (~act | step_ok)
        cur = jnp.where(write, u, cur)
        act = write & (cur != target)
        return cur, i + 1, hv, hvia, hw, hops, ok, act

    act0 = active & (start != target)
    st = (start, jnp.int32(0), hop_v, hop_via, hop_w,
          jnp.zeros(q, jnp.int32), jnp.ones(q, bool), act0)
    cur, _, hop_v, hop_via, hop_w, hops, ok, act = jax.lax.while_loop(
        cond, body, st)
    ok = ok & ~act                  # ran out of chase_cap before target
    return hop_v, hop_via, hop_w, hops, ok


def core_chase(dvec, seed, ell_ids, ell_w, ell_via, core_gid, vstar, active,
               core_cap: int, n: int):
    """Parent-chase one direction's fixed point from ``vstar`` (local
    core index) back to a label seed.

    Step i records the parent edge walked: ``pv[q, i]`` the parent
    (global id), ``pvia``/``pw`` the via/weight of the edge between the
    previous chase vertex and that parent. Returns
    ``(pv, pvia, pw, steps, r_local, ok)`` — ``r_local`` is the seed
    core vertex the chase ended on (== ``vstar`` for zero steps).
    """
    q = dvec.shape[0]
    pv = jnp.full((q, core_cap), n, jnp.int32)
    pvia = jnp.full((q, core_cap), -1, jnp.int32)
    pw = jnp.zeros((q, core_cap), jnp.float32)

    def cond(st):
        _, i, _, _, _, _, _, act = st
        return jnp.any(act) & (i < core_cap)

    def body(st):
        cur, i, v, via_a, w_a, steps, ok, act = st
        dv = jnp.take_along_axis(dvec, cur[:, None], 1)[:, 0]
        sv = jnp.take_along_axis(seed, cur[:, None], 1)[:, 0]
        at_seed = dv == sv
        nbr = ell_ids[cur]                              # [Q, D]
        wr = ell_w[cur]
        vr = ell_via[cur]
        dnbr = jnp.take_along_axis(dvec, nbr, axis=1)
        cand = (dnbr + wr) == dv[:, None]
        hit = jnp.any(cand, axis=1)
        jsel = jnp.argmax(cand, axis=1)
        par = jnp.take_along_axis(nbr, jsel[:, None], 1)[:, 0]
        via = jnp.take_along_axis(vr, jsel[:, None], 1)[:, 0]
        w = jnp.take_along_axis(wr, jsel[:, None], 1)[:, 0]
        write = act & ~at_seed & hit
        v = v.at[:, i].set(jnp.where(write, core_gid[par], v[:, i]))
        via_a = via_a.at[:, i].set(jnp.where(write, via, via_a[:, i]))
        w_a = w_a.at[:, i].set(jnp.where(write, w, w_a[:, i]))
        steps = steps + write.astype(jnp.int32)
        ok = ok & (~act | at_seed | hit)
        cur = jnp.where(write, par, cur)
        act = write
        return cur, i + 1, v, via_a, w_a, steps, ok, act

    st = (vstar, jnp.int32(0), pv, pvia, pw, jnp.zeros(q, jnp.int32),
          jnp.ones(q, bool), active)
    cur, _, pv, pvia, pw, steps, ok, act = jax.lax.while_loop(cond, body, st)
    # a chase still active after core_cap steps never reached a seed
    dv = jnp.take_along_axis(dvec, cur[:, None], 1)[:, 0]
    sv = jnp.take_along_axis(seed, cur[:, None], 1)[:, 0]
    ok = ok & (~act | (dv == sv))
    return pv, pvia, pw, steps, cur, ok


def _scatter_rows(buf, vals, start, count, fill):
    """Write ``vals[q, :count[q]]`` at columns ``start[q] + i`` of the
    ``[Q, H+1]`` buffer (column H is the drop scratch)."""
    q, c = vals.shape
    h = buf.shape[1] - 1
    cols = jnp.arange(c)[None, :]
    valid = cols < count[:, None]
    tgt = jnp.minimum(jnp.where(valid, start[:, None] + cols, h), h)
    rows = jnp.broadcast_to(jnp.arange(q)[:, None], tgt.shape)
    return buf.at[rows, tgt].set(jnp.where(valid, vals, fill))


def _reverse_gather(arr, count, fill):
    """``out[q, j] = arr[q, count[q]-1-j]`` for j < count (fill after)."""
    q, c = arr.shape
    cols = jnp.arange(c)[None, :]
    idx = jnp.clip(count[:, None] - 1 - cols, 0, c - 1)
    out = jnp.take_along_axis(arr, idx, axis=1)
    return jnp.where(cols < count[:, None], out, fill)


def stitch(s, t, finite, hop_cap: int, n: int,
           ls_v, ls_via, ls_w, p_s,
           seg_s_v, seg_s_via, seg_s_w, m_s,
           vstar_g, seg_t_v, seg_t_via, seg_t_w, m_t,
           lt_v, lt_via, lt_w, p_t, x_t):
    """Assemble the four path pieces into one ``[Q, hop_cap]`` edge
    list. Pieces (forward order): label hops of s · reversed s-side
    core segment · forward t-side core segment · reversed label hops of
    t · the final vertex t. Returns ``(verts, evia, ew, length, ok)``
    with ``length`` the vertex count (0 for unreachable pairs)."""
    q = s.shape[0]
    h = hop_cap
    edges = p_s + m_s + m_t + p_t
    length = jnp.where(finite, edges + 1, 0)
    ok = length <= h

    verts = jnp.full((q, h + 1), n, jnp.int32)
    evia = jnp.full((q, h + 1), -1, jnp.int32)
    ew = jnp.zeros((q, h + 1), jnp.float32)

    zero = jnp.zeros(q, jnp.int32)
    p_s = jnp.where(finite, p_s, zero)
    m_s = jnp.where(finite, m_s, zero)
    m_t = jnp.where(finite, m_t, zero)
    p_t = jnp.where(finite, p_t, zero)

    # piece 1: label hops of s, forward
    verts = _scatter_rows(verts, ls_v, zero, p_s, n)
    evia = _scatter_rows(evia, ls_via, zero, p_s, -1)
    ew = _scatter_rows(ew, ls_w, zero, p_s, 0.0)
    # piece 2: s-side core segment, reversed (seed -> vstar)
    off = p_s
    verts = _scatter_rows(verts, _reverse_gather(seg_s_v, m_s, n),
                          off, m_s, n)
    evia = _scatter_rows(evia, _reverse_gather(seg_s_via, m_s, -1),
                         off, m_s, -1)
    ew = _scatter_rows(ew, _reverse_gather(seg_s_w, m_s, 0.0),
                       off, m_s, 0.0)
    # piece 3: t-side core segment, forward from vstar
    off = off + m_s
    v3 = jnp.concatenate([vstar_g[:, None], seg_t_v[:, :-1]], axis=1) \
        if seg_t_v.shape[1] > 0 else seg_t_v
    verts = _scatter_rows(verts, v3, off, m_t, n)
    evia = _scatter_rows(evia, seg_t_via, off, m_t, -1)
    ew = _scatter_rows(ew, seg_t_w, off, m_t, 0.0)
    # piece 4: label hops of t, reversed (x_t -> t); vertex j is
    # b_{p_t - j}: x_t at j = 0, then the chase vertices reversed
    off = off + m_t
    cols = jnp.arange(lt_v.shape[1])[None, :]
    idx = jnp.clip(p_t[:, None] - cols, 0, lt_v.shape[1] - 1)
    v4 = jnp.where(cols == 0, x_t[:, None],
                   jnp.take_along_axis(lt_v, idx, axis=1))
    verts = _scatter_rows(verts, v4, off, p_t, n)
    evia = _scatter_rows(evia, _reverse_gather(lt_via, p_t, -1),
                         off, p_t, -1)
    ew = _scatter_rows(ew, _reverse_gather(lt_w, p_t, 0.0), off, p_t, 0.0)
    # final vertex t
    tcol = jnp.minimum(jnp.where(finite, edges, h), h)
    verts = verts.at[jnp.arange(q), tcol].set(
        jnp.where(finite, t, verts[jnp.arange(q), tcol]))
    return verts[:, :h], evia[:, :h], ew[:, :h], length, ok


def expand_vias(verts, evia, ew, length, ok, up_ids, up_w, up_via,
                n: int, max_rounds: int):
    """Iteratively expand every augmenting edge in place (§8.1).

    Each round splits every edge (a, b) with ``via = c >= 0`` into
    (a, c) + (c, b) via a prefix-sum insertion scatter; sub-edge vias
    and weights come from c's up-adjacency row. Terminates in at most
    ``max_rounds`` (the hierarchy height bounds the nesting depth).
    """
    q, h = verts.shape
    rows = jnp.arange(q)

    def cond(st):
        _, evia_, _, _, _, it = st
        return jnp.any(evia_ >= 0) & (it < max_rounds)

    def body(st):
        v, evia_, ew_, length_, ok_, it = st
        edge_valid = jnp.arange(h)[None, :] < (length_[:, None] - 1)
        need = (evia_ >= 0) & edge_valid
        grow = need.astype(jnp.int32)
        shift = jnp.cumsum(grow, axis=1) - grow
        new_pos = jnp.arange(h)[None, :] + shift
        new_len = length_ + jnp.sum(grow, axis=1)
        ok_ = ok_ & (new_len <= h)

        b = jnp.concatenate([v[:, 1:], jnp.full((q, 1), n, jnp.int32)], 1)
        c = jnp.where(need, evia_, 0)
        crow = up_ids[c]                                # [Q, H, D]
        hit_a = crow == v[..., None]
        hit_b = crow == b[..., None]
        sa = jnp.argmax(hit_a, -1)[..., None]
        sb = jnp.argmax(hit_b, -1)[..., None]
        ok_ = ok_ & ~jnp.any(
            need & ~(jnp.any(hit_a, -1) & jnp.any(hit_b, -1)), axis=1)
        cvia = up_via[c]
        cw = up_w[c]
        via_ac = jnp.take_along_axis(cvia, sa, -1)[..., 0]
        w_ac = jnp.take_along_axis(cw, sa, -1)[..., 0]
        via_cb = jnp.take_along_axis(cvia, sb, -1)[..., 0]
        w_cb = jnp.take_along_axis(cw, sb, -1)[..., 0]

        vert_valid = jnp.arange(h)[None, :] < length_[:, None]
        tgt = jnp.minimum(jnp.where(vert_valid, new_pos, h), h)
        rr = jnp.broadcast_to(rows[:, None], tgt.shape)
        nv = jnp.full((q, h + 1), n, jnp.int32).at[rr, tgt].set(v)
        nvia = jnp.full((q, h + 1), -1, jnp.int32).at[rr, tgt].set(
            jnp.where(need, via_ac, evia_))
        nw = jnp.zeros((q, h + 1), jnp.float32).at[rr, tgt].set(
            jnp.where(need, w_ac, ew_))
        ins = jnp.minimum(jnp.where(need, new_pos + 1, h), h)
        nv = nv.at[rr, ins].set(jnp.where(need, c, nv[rr, ins]))
        nvia = nvia.at[rr, ins].set(jnp.where(need, via_cb, nvia[rr, ins]))
        nw = nw.at[rr, ins].set(jnp.where(need, w_cb, nw[rr, ins]))
        return (nv[:, :h], nvia[:, :h], nw[:, :h],
                jnp.minimum(new_len, h), ok_, it + 1)

    st = (verts, evia, ew, length, ok, jnp.int32(0))
    verts, evia, ew, length, ok, _ = jax.lax.while_loop(cond, body, st)
    # any via still pending means the round bound was hit (inconsistent
    # index) — never report such a path as valid
    ok = ok & ~jnp.any(evia >= 0, axis=1)
    return verts, ew, length, ok
