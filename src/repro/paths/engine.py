"""`PathEngine` — batched shortest-path retrieval over an IS-LABEL
index (docs/PATHS.md).

Mirrors the ``QueryEngine`` serving contract: ``path_batch_fn`` returns
a jitted fixed-shape callable (one compile per (batch, hop_cap) shape,
memoized per resolved backend), ``warmup`` pre-compiles every serving
shape, and all stages run through the same kernel dispatch layer the
distance hot path uses (``label_intersect_mu`` for the meet,
``CoreRelaxer`` for the fixed point the parents are read from).

Construction is array-explicit so the same engine serves both index
layouts: ``PathEngine.from_index`` wraps an ``ISLabelIndex`` directly;
``ShardedIndex.path_engine()`` gathers the owning shards' label blocks
(``unpartition_labels`` — bit-exact) and builds the identical engine,
so sharded and unsharded path answers agree bitwise.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import CoreRelaxer
from repro.core.query import QueryEngine, label_intersect_mu
from repro.kernels.backend import resolve_backend
from repro.obs.registry import REGISTRY
from repro.kernels.spmv_relax.ops import ell_layout
from repro.paths.reconstruct import (core_chase, expand_vias, label_chase,
                                     stitch)

DEFAULT_HOP_CAP = 256


class PathBatch(NamedTuple):
    """One batch of reconstructed paths (fixed shapes, device arrays).

    ``verts[q, :lens[q]]`` is the vertex sequence (sentinel-n padded),
    ``weights[q, i]`` the original-graph weight of edge
    ``(verts[q, i], verts[q, i+1])`` (0 beyond the path), ``lens[q]``
    the vertex count (0 = unreachable), ``ok[q]`` False when the path
    overflowed ``hop_cap`` (escalate and retry; ``dist`` stays exact).
    """
    dist: jax.Array        # float32[Q]
    verts: jax.Array       # int32[Q, hop_cap]
    weights: jax.Array     # float32[Q, hop_cap]
    lens: jax.Array        # int32[Q]
    ok: jax.Array          # bool[Q]
    rounds: jax.Array      # int32 scalar (core relaxation rounds)


class PathEngine:
    """Device-resident path-reconstruction state + compiled entry
    points. ``hop_cap`` is per compiled function (static), not per
    engine — one engine serves every hop_cap tier."""

    def __init__(self, *, n: int, k: int, lbl_ids, lbl_d, lbl_pred,
                 up_ids, up_w, up_via, core_ids, core_pos, core_src,
                 core_dst, core_w, core_via, max_rounds: int = 0,
                 backend: str = "auto", d_width: int = 16, relaxer=None):
        self.n = n
        self.k = k
        self.backend = backend
        self.lbl_ids = jnp.asarray(lbl_ids)
        self.lbl_d = jnp.asarray(lbl_d)
        self.lbl_pred = jnp.asarray(lbl_pred)
        self.l_cap = self.lbl_ids.shape[1]
        self.up_ids = jnp.asarray(up_ids)
        self.up_w = jnp.asarray(up_w)
        self.up_via = jnp.asarray(up_via)
        core_ids = np.asarray(core_ids, np.int32)
        self.n_core = len(core_ids)
        self.core_gid = jnp.asarray(np.append(core_ids, n).astype(np.int32))
        self.core_pos = jnp.asarray(np.asarray(core_pos, np.int32))
        self.max_rounds = max_rounds if max_rounds > 0 else max(self.n_core, 1)
        self.chase_cap = max(k, 1)
        self.expand_rounds = k + 1
        if self.n_core > 0:
            cpos = np.asarray(core_pos)
            ce_src = cpos[np.asarray(core_src)].astype(np.int32)
            ce_dst = cpos[np.asarray(core_dst)].astype(np.int32)
            ce_w = np.asarray(core_w, np.float32)
            # share the query engine's relaxer when offered — same
            # arrays, same class, so the fixed point the parents are
            # read from is the one the served distances came from
            self.relaxer = relaxer if relaxer is not None else CoreRelaxer(
                jnp.asarray(ce_src), jnp.asarray(ce_dst),
                jnp.asarray(ce_w), self.n_core)
            # ELL planes aligned slot-for-slot (ids, w, via) so the
            # parent chase reads edge vias with the same gather
            order, rows, slots, width = ell_layout(self.n_core + 1, ce_dst,
                                                   d_width)
            ids = np.zeros((self.n_core + 1, width), np.int32)
            ws = np.full((self.n_core + 1, width), np.inf, np.float32)
            vias = np.full((self.n_core + 1, width), -1, np.int32)
            if len(ce_src):
                ids[rows, slots] = ce_src[order]
                ws[rows, slots] = ce_w[order]
                vias[rows, slots] = np.asarray(core_via, np.int32)[order]
            self.ell_ids = jnp.asarray(ids)
            self.ell_w = jnp.asarray(ws)
            self.ell_via = jnp.asarray(vias)
        else:
            self.relaxer = None
        self._fns: dict = {}

    # ------------------------------------------------------------ builders
    @staticmethod
    def from_index(index, backend: str | None = None) -> "PathEngine":
        """Wrap an ``ISLabelIndex`` (shares its device label arrays)."""
        return PathEngine(
            n=index.n, k=index.k, lbl_ids=index.lbl_ids, lbl_d=index.lbl_d,
            lbl_pred=index.lbl_pred, up_ids=index.up_ids, up_w=index.up_w,
            up_via=index.up_via, core_ids=index.core_ids,
            core_pos=index.core_pos_host, core_src=index.core_src,
            core_dst=index.core_dst, core_w=index.core_w,
            core_via=index.core_via, max_rounds=index.cfg.max_relax_rounds,
            backend=backend or index.cfg.query_backend,
            relaxer=index.engine.relaxer)

    # Seed scatter shared with QueryEngine (as in ShardedQueryEngine)
    # so the frontier the parents are chased over cannot drift from the
    # one the served distances were computed with.
    _seed = QueryEngine._seed

    # ----------------------------------------------------------- core fn
    def _run(self, s, t, hop_cap: int, backend: str) -> PathBatch:
        n, n_core = self.n, self.n_core
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        q = s.shape[0]
        ids_s, d_s = self.lbl_ids[s], self.lbl_d[s]
        ids_t, d_t = self.lbl_ids[t], self.lbl_d[t]
        mu, meet = label_intersect_mu(ids_s, d_s, ids_t, d_t, n, self.l_cap)
        meet = jnp.asarray(meet, jnp.int32)
        core_cap = min(n_core, hop_cap)
        if n_core > 0:
            seed_s = self._seed(ids_s, d_s)
            seed_t = self._seed(ids_t, d_t)
            _, ds, dt, rounds = self.relaxer.run(seed_s, seed_t, mu,
                                                 self.max_rounds, backend)
            sum_st = ds[:, :n_core] + dt[:, :n_core]
            vstar = jnp.argmin(sum_st, axis=1).astype(jnp.int32)
            through = jnp.take_along_axis(sum_st, vstar[:, None], 1)[:, 0]
            dist = jnp.minimum(mu, through)
        else:
            rounds = jnp.int32(0)
            through = jnp.full(q, jnp.inf, jnp.float32)
            vstar = jnp.zeros(q, jnp.int32)
            dist = mu
        finite = jnp.isfinite(dist)
        # ties prefer the label route, matching the host oracle
        use_label = finite & (mu <= through)
        ok = jnp.ones(q, bool)

        if n_core > 0:
            core_act = finite & ~use_label
            seg_s_v, seg_s_via, seg_s_w, m_s, r_s, ok_s = core_chase(
                ds, seed_s, self.ell_ids, self.ell_w, self.ell_via,
                self.core_gid, vstar, core_act, core_cap, n)
            seg_t_v, seg_t_via, seg_t_w, m_t, r_t, ok_t = core_chase(
                dt, seed_t, self.ell_ids, self.ell_w, self.ell_via,
                self.core_gid, vstar, core_act, core_cap, n)
            ok = ok & ok_s & ok_t
            x_s = jnp.where(use_label, meet, self.core_gid[r_s])
            x_t = jnp.where(use_label, meet, self.core_gid[r_t])
        else:
            zero_i = jnp.zeros((q, 0), jnp.int32)
            zero_f = jnp.zeros((q, 0), jnp.float32)
            seg_s_v = seg_t_v = zero_i
            seg_s_via = seg_t_via = zero_i
            seg_s_w = seg_t_w = zero_f
            m_s = m_t = jnp.zeros(q, jnp.int32)
            x_s = x_t = meet
        vstar_g = self.core_gid[vstar] if n_core > 0 else s

        ls_v, ls_via, ls_w, p_s, ok_ls = label_chase(
            self.lbl_ids, self.lbl_pred, self.up_ids, self.up_w,
            self.up_via, s, x_s, finite, self.chase_cap, n)
        lt_v, lt_via, lt_w, p_t, ok_lt = label_chase(
            self.lbl_ids, self.lbl_pred, self.up_ids, self.up_w,
            self.up_via, t, x_t, finite, self.chase_cap, n)
        ok = ok & ok_ls & ok_lt

        verts, evia, ew, length, ok_st = stitch(
            s, t, finite, hop_cap, n,
            ls_v, ls_via, ls_w, p_s,
            seg_s_v, seg_s_via, seg_s_w, m_s,
            vstar_g, seg_t_v, seg_t_via, seg_t_w, m_t,
            lt_v, lt_via, lt_w, p_t, x_t)
        verts, weights, length, ok_ex = expand_vias(
            verts, evia, ew, length, ok & ok_st, self.up_ids, self.up_w,
            self.up_via, n, self.expand_rounds)
        return PathBatch(dist, verts, weights, length, ok_ex, rounds)

    # ------------------------------------------------------- serving APIs
    def path_batch_fn(self, hop_cap: int = DEFAULT_HOP_CAP,
                      backend: str | None = None):
        """Jitted ``run(s, t) -> PathBatch`` with static ``hop_cap``.

        Memoized per (resolved backend, hop_cap); no host sync inside —
        the serving layer owns blocking, timing, and hop_cap
        escalation. Same contract as ``QueryEngine.batch_fn``.
        """
        backend = resolve_backend(self.backend if backend is None else backend)
        key = (backend, int(hop_cap))
        if key not in self._fns:
            hc = int(hop_cap)

            def run(s, t):
                with jax.named_scope("islabel.path_batch"):
                    return self._run(s, t, hc, backend)
            jitted = jax.jit(run)
            calls = REGISTRY.counter("path.batches",
                                     "path-lane batch dispatches")

            # host-side dispatch counter per hop_cap tier; the jit
            # _cache_size probe is forwarded so the zero-compile audits
            # see through the wrap
            def counted(s, t):
                calls.inc(1, hop_cap=str(hc))
                return jitted(s, t)

            if hasattr(jitted, "_cache_size"):
                counted._cache_size = jitted._cache_size
            counted.__wrapped__ = jitted
            self._fns[key] = counted
        return self._fns[key]

    def warmup(self, batch_sizes, hop_caps=(DEFAULT_HOP_CAP,),
               backend: str | None = None) -> dict:
        """Pre-compile every (batch, hop_cap) entry point. Returns
        {(size, hop_cap): seconds}."""
        out = {}
        for hc in hop_caps:
            fn = self.path_batch_fn(hc, backend)
            for size in batch_sizes:
                z = jnp.zeros(int(size), jnp.int32)
                t0 = time.perf_counter()
                jax.block_until_ready(fn(z, z))
                out[(int(size), int(hc))] = time.perf_counter() - t0
        return out

    # -------------------------------------------------------- host APIs
    def paths(self, s, t, hop_cap: int = DEFAULT_HOP_CAP,
              backend: str | None = None, max_escalations: int = 4):
        """Host convenience: batched paths as Python lists.

        Escalates hop_cap (doubling, up to ``max_escalations`` times)
        until every reconstructed path fits. Returns
        ``(dist float32[Q], paths list[list[int]], ok bool[Q])`` —
        unreachable pairs get an empty list.
        """
        s = np.atleast_1d(np.asarray(s, np.int32))
        t = np.atleast_1d(np.asarray(t, np.int32))
        hc = int(hop_cap)
        for _ in range(max_escalations + 1):
            out = jax.block_until_ready(
                self.path_batch_fn(hc, backend)(s, t))
            ok = np.asarray(out.ok)
            if ok.all():
                break
            hc *= 2
        dist = np.asarray(out.dist)
        verts = np.asarray(out.verts)
        lens = np.asarray(out.lens)
        paths = [verts[i, :lens[i]].tolist() if ok[i] else []
                 for i in range(len(s))]
        return dist, paths, ok
