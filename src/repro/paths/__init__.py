# repro.paths — batched device-side shortest-path reconstruction over
# IS-LABEL indexes: the fixed-shape jitted replacement for the scalar
# host oracle (docs/PATHS.md), plus the host-side validation gate.
from repro.paths.engine import DEFAULT_HOP_CAP, PathBatch, PathEngine
from repro.paths.validate import (check_path, check_path_batch,
                                  check_vertex_path, edge_weight_map,
                                  integral_weights)

__all__ = [
    "DEFAULT_HOP_CAP", "PathBatch", "PathEngine",
    "check_path", "check_path_batch", "check_vertex_path",
    "edge_weight_map", "integral_weights",
]
