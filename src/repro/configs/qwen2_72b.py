"""qwen2-72b — assigned LM architecture.

GQA, QKV bias [arXiv:2407.10671; hf]
"""
from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, tiny_like

MOE = None
CONFIG = LMConfig(
    name="qwen2-72b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True, moe=MOE, q_chunk=512)


def get_spec() -> ArchSpec:
    return ArchSpec(arch_id="qwen2-72b", family="lm", model_cfg=CONFIG,
                    shapes=dict(LM_SHAPES), optimizer="adamw",
                    smoke_cfg_fn=lambda: tiny_like(CONFIG),
                    notes='GQA, QKV bias [arXiv:2407.10671; hf]')
