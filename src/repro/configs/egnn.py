"""egnn — assigned GNN architecture.

4-layer E(n)-equivariant GNN, d_hidden=64 [arXiv:2102.09844; paper].
Scalar-distance messages + equivariant coordinate updates; no spherical
harmonics. Coordinates for non-molecular shape cells are synthesized
node attributes (DESIGN.md §4).
"""
import dataclasses

from repro.configs.base import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import EGNNConfig

CONFIG = EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_in=16, n_out=1)


def get_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="egnn", family="gnn", model_cfg=CONFIG,
        shapes=dict(GNN_SHAPES),
        smoke_cfg_fn=lambda: dataclasses.replace(CONFIG, d_in=8, d_hidden=8,
                                                 n_layers=2),
        notes="[arXiv:2102.09844; paper]")
