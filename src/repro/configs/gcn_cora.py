"""gcn-cora — assigned GNN architecture.

2-layer GCN, d_hidden=16, mean/sym-norm aggregation [arXiv:1609.02907;
paper]. Kernel regime: SpMM via segment_sum over the edge index.
"""
import dataclasses

from repro.configs.base import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GCNConfig

CONFIG = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16, d_in=1433,
                   n_classes=7, norm="sym")


def get_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="gcn-cora", family="gnn", model_cfg=CONFIG,
        shapes=dict(GNN_SHAPES),
        smoke_cfg_fn=lambda: dataclasses.replace(CONFIG, d_in=8, d_hidden=8,
                                                 n_classes=4),
        notes="[arXiv:1609.02907; paper]")
