"""islabel — the paper's own workload as a servable config.

Query serving over a distance-label index (labels sharded by vertex,
core graph replicated per pod, query batches data-parallel) and one
hierarchy-peeling build level (edge-sharded).
"""
import dataclasses

from repro.configs.base import ArchSpec
from repro.configs.shapes import ISLABEL_SHAPES, IndexShape
from repro.core.config import IndexConfig

CONFIG = IndexConfig()


def get_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="islabel", family="graph_index", model_cfg=CONFIG,
        shapes=dict(ISLABEL_SHAPES),
        smoke_cfg_fn=lambda: dataclasses.replace(CONFIG, l_cap=64,
                                                 label_chunk=256),
        notes="IS-LABEL query/build serving (the paper's technique)")
