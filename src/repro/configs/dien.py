"""dien — assigned recsys architecture.

embed_dim=18, seq_len=100, gru_dim=108, MLP 200-80, AUGRU interaction
[arXiv:1809.03672; unverified]. Embedding tables are the recsys-scale
hot path (67M item rows, mod-sharded over the model axis).
"""
import dataclasses

from repro.configs.base import ArchSpec
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.dien import DIENConfig

CONFIG = DIENConfig(name="dien", embed_dim=18, seq_len=100, gru_dim=108,
                    mlp_dims=(200, 80))


def get_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dien", family="recsys", model_cfg=CONFIG,
        shapes=dict(RECSYS_SHAPES),
        smoke_cfg_fn=lambda: dataclasses.replace(
            CONFIG, n_items=1000, n_cats=50, n_users=100, seq_len=12),
        notes="[arXiv:1809.03672; unverified]")
