"""kimi-k2-1t-a32b — assigned LM architecture.

Kimi K2 trillion-param MoE [arXiv:2501.kimi2; unverified]; assignment specifies GQA kv=8 (not MLA)
"""
from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, tiny_like

MOE = MoEConfig(n_experts=384, top_k=8, d_expert_ff=2048,
                n_shared=1, d_shared_ff=2048)
CONFIG = LMConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, qkv_bias=False, moe=MOE, q_chunk=512)


def get_spec() -> ArchSpec:
    return ArchSpec(arch_id="kimi-k2-1t-a32b", family="lm", model_cfg=CONFIG,
                    shapes=dict(LM_SHAPES), optimizer="adafactor",
                    smoke_cfg_fn=lambda: tiny_like(CONFIG),
                    fsdp_over_pod=True, param_dtype="bfloat16",
                    notes='Kimi K2 trillion-param MoE [arXiv:2501.kimi2; unverified]; assignment specifies GQA kv=8 (not MLA)')
