"""yi-34b — assigned LM architecture.

llama-arch GQA [arXiv:2403.04652; hf]
"""
from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, tiny_like

MOE = None
CONFIG = LMConfig(
    name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, qkv_bias=False, moe=MOE, q_chunk=512)


def get_spec() -> ArchSpec:
    return ArchSpec(arch_id="yi-34b", family="lm", model_cfg=CONFIG,
                    shapes=dict(LM_SHAPES), optimizer="adamw",
                    smoke_cfg_fn=lambda: tiny_like(CONFIG),
                    notes='llama-arch GQA [arXiv:2403.04652; hf]')
