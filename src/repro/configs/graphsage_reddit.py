"""graphsage-reddit — assigned GNN architecture.

2-layer GraphSAGE, d_hidden=128, mean aggregator, sample_sizes=25-10
[arXiv:1706.02216; paper]. Minibatch cells use a real host-side
neighbor sampler (repro.graphs.sampler).
"""
import dataclasses

from repro.configs.base import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import SAGEConfig

CONFIG = SAGEConfig(name="graphsage-reddit", n_layers=2, d_hidden=128,
                    d_in=602, n_classes=41, aggregator="mean",
                    fanouts=(25, 10))


def get_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="graphsage-reddit", family="gnn", model_cfg=CONFIG,
        shapes=dict(GNN_SHAPES),
        smoke_cfg_fn=lambda: dataclasses.replace(CONFIG, d_in=8, d_hidden=8,
                                                 n_classes=4, fanouts=(3, 2)),
        notes="[arXiv:1706.02216; paper]")
