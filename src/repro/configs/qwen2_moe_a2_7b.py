"""qwen2-moe-a2.7b — assigned LM architecture.

4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, tiny_like

MOE = MoEConfig(n_experts=60, top_k=4, d_expert_ff=1408,
                n_shared=4, d_shared_ff=5632)
CONFIG = LMConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, qkv_bias=True, moe=MOE, q_chunk=512)


def get_spec() -> ArchSpec:
    return ArchSpec(arch_id="qwen2-moe-a2.7b", family="lm", model_cfg=CONFIG,
                    shapes=dict(LM_SHAPES), optimizer="adamw",
                    smoke_cfg_fn=lambda: tiny_like(CONFIG),
                    notes='4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]')
