"""Assigned input-shape sets, verbatim from the assignment.

Each family has its own shape vocabulary; ``ArchSpec.input_specs``
translates (arch, shape) into concrete ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    subquadratic_required: bool = False


LM_SHAPES = {
    "train_4k": LMShape("train_4k", "train", 4096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32768, 128),
    # long_500k requires sub-quadratic attention; all five assigned LM archs
    # are pure full-attention (GQA) -> skipped per assignment (DESIGN.md §4).
    "long_500k": LMShape("long_500k", "decode", 524288, 1,
                         subquadratic_required=True),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str            # full | minibatch | molecule
    n_nodes: int
    n_edges: int
    d_feat: int
    batch_nodes: int = 0
    fanout: tuple = ()
    batch_graphs: int = 0
    n_classes: int = 47


GNN_SHAPES = {
    "full_graph_sm": GNNShape("full_graph_sm", "full", 2708, 10556, 1433,
                              n_classes=7),
    "minibatch_lg": GNNShape("minibatch_lg", "minibatch", 232965, 114615892,
                             602, batch_nodes=1024, fanout=(15, 10),
                             n_classes=41),
    "ogb_products": GNNShape("ogb_products", "full", 2449029, 61859140, 100,
                             n_classes=47),
    "molecule": GNNShape("molecule", "molecule", 30, 64, 16, batch_graphs=128,
                         n_classes=1),
}


@dataclasses.dataclass(frozen=True)
class RecShape:
    name: str
    kind: str            # train | serve | retrieval
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = {
    "train_batch": RecShape("train_batch", "train", 65536),
    "serve_p99": RecShape("serve_p99", "serve", 512),
    "serve_bulk": RecShape("serve_bulk", "serve", 262144),
    "retrieval_cand": RecShape("retrieval_cand", "retrieval", 1,
                               n_candidates=1_000_000),
}


@dataclasses.dataclass(frozen=True)
class IndexShape:
    """Shapes for the paper's own workload (IS-LABEL query serving)."""
    name: str
    kind: str            # query | build_level
    n_vertices: int
    l_cap: int
    n_core: int
    core_edges: int
    q_batch: int = 0
    e_cap: int = 0
    d_cap: int = 16


ISLABEL_SHAPES = {
    "serve_1m": IndexShape("serve_1m", "query", 1 << 20, 64, 1 << 17,
                           1 << 22, q_batch=4096),
    "serve_128m": IndexShape("serve_128m", "query", 1 << 27, 32, 1 << 20,
                             1 << 24, q_batch=16384),
    # peel-level working set = e_cap + (e_cap/2)*d_cap elements; keep the
    # flattened size under 2^31 (XLA int32 iota) -> 16M vertices here.
    "build_16m": IndexShape("build_16m", "build_level", 1 << 24, 64, 0, 0,
                            e_cap=1 << 26),
}
