"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib

_MODULES = {
    # LM family
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "granite-8b": "repro.configs.granite_8b",
    "yi-34b": "repro.configs.yi_34b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    # GNN family
    "dimenet": "repro.configs.dimenet",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "gcn-cora": "repro.configs.gcn_cora",
    "egnn": "repro.configs.egnn",
    # recsys
    "dien": "repro.configs.dien",
    # the paper's own workload
    "islabel": "repro.configs.islabel",
}

ASSIGNED = [a for a in _MODULES if a != "islabel"]


def get_spec(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).get_spec()


def all_cells(include_islabel: bool = False):
    """Every runnable (arch, shape) pair — the dry-run/roofline table."""
    out = []
    for arch in (list(_MODULES) if include_islabel else ASSIGNED):
        spec = get_spec(arch)
        for shape in spec.runnable_cells():
            out.append((arch, shape))
    return out
