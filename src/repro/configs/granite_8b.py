"""granite-8b — assigned LM architecture.

llama-arch, code [arXiv:2405.04324; hf]
"""
from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, tiny_like

MOE = None
CONFIG = LMConfig(
    name="granite-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, qkv_bias=False, moe=MOE, q_chunk=512)


def get_spec() -> ArchSpec:
    return ArchSpec(arch_id="granite-8b", family="lm", model_cfg=CONFIG,
                    shapes=dict(LM_SHAPES), optimizer="adamw",
                    smoke_cfg_fn=lambda: tiny_like(CONFIG),
                    notes='llama-arch, code [arXiv:2405.04324; hf]')
