"""ArchSpec: binds an architecture config to its shape set, input specs,
and step functions. One per assigned architecture (+ the paper's own
IS-LABEL workload).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import shapes as SH


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def r512(x: int) -> int:
    """Round up to a multiple of 512 (= lcm of every mesh size we shard
    over) so explicitly-sharded leading dims always divide the mesh."""
    return -(-int(x) // 512) * 512


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # lm | gnn | recsys | graph_index
    model_cfg: Any
    shapes: dict
    optimizer: str = "adamw"          # adamw | adafactor
    smoke_cfg_fn: Callable | None = None
    notes: str = ""
    fsdp_over_pod: bool = False       # 1T-class models: FSDP across pods
    param_dtype: str = "float32"

    def shape(self, name: str):
        return self.shapes[name]

    def input_specs(self, shape_name: str) -> dict:
        shp = self.shapes[shape_name]
        if self.family == "lm":
            return lm_input_specs(self.model_cfg, shp)
        if self.family == "gnn":
            return gnn_input_specs(self.model_cfg, shp)
        if self.family == "recsys":
            return recsys_input_specs(self.model_cfg, shp)
        if self.family == "graph_index":
            return islabel_input_specs(self.model_cfg, shp)
        raise KeyError(self.family)

    def runnable_cells(self):
        """Shape names that apply to this arch (assignment skip rules)."""
        out = []
        for name, shp in self.shapes.items():
            if getattr(shp, "subquadratic_required", False) \
                    and self.family == "lm":
                continue   # pure full-attention archs skip long_500k
            out.append(name)
        return out


# ----------------------------------------------------------------- LM specs
def lm_input_specs(cfg, shp: SH.LMShape) -> dict:
    from repro.models.transformer import abstract_cache
    b, s = shp.global_batch, shp.seq_len
    if shp.kind == "train":
        return {"tokens": sds((b, s), jnp.int32),
                "targets": sds((b, s), jnp.int32)}
    if shp.kind == "prefill":
        return {"tokens": sds((b, s), jnp.int32)}
    if shp.kind == "decode":
        return {"cache": abstract_cache(cfg, b, s),
                "last_tokens": sds((b, 1), jnp.int32)}
    raise KeyError(shp.kind)


# ---------------------------------------------------------------- GNN specs
def gnn_minibatch_dims(shp: SH.GNNShape):
    """Padded sampled-subgraph dims for minibatch shapes."""
    b = shp.batch_nodes
    f1, f2 = shp.fanout
    n_sub = b * (1 + f1 + f1 * f2) + 1
    e_sub = 2 * (b * f1 + b * f1 * f2)
    return n_sub, e_sub


def gnn_input_specs(cfg, shp: SH.GNNShape) -> dict:
    need_coords = type(cfg).__name__ in ("EGNNConfig", "DimeNetConfig")
    if shp.kind == "full":
        n1, e = r512(shp.n_nodes + 1), r512(2 * shp.n_edges)
    elif shp.kind == "minibatch":
        n1, e = gnn_minibatch_dims(shp)
        n1, e = r512(n1), r512(e)
    elif shp.kind == "molecule":
        n1 = r512(shp.batch_graphs * shp.n_nodes + 1)
        e = r512(2 * shp.batch_graphs * shp.n_edges)
    else:
        raise KeyError(shp.kind)
    d = {"feats": sds((n1, shp.d_feat), jnp.float32),
         "edge_src": sds((e,), jnp.int32),
         "edge_dst": sds((e,), jnp.int32),
         "deg": sds((n1,), jnp.float32)}
    if shp.kind == "molecule":
        d["graph_ids"] = sds((n1,), jnp.int32)
        d["targets"] = sds((shp.batch_graphs,), jnp.float32)
    else:
        d["labels"] = sds((n1,), jnp.int32)
        d["mask"] = sds((n1,), jnp.float32)
    if need_coords:
        d["coords"] = sds((n1, 3), jnp.float32)
    if type(cfg).__name__ == "DimeNetConfig":
        t_cap = min(r512(4 * e), 1 << 28)   # capped triplet list (DESIGN §4)
        d["trip_kj"] = sds((t_cap,), jnp.int32)
        d["trip_ji"] = sds((t_cap,), jnp.int32)
        d["atom_z"] = sds((n1,), jnp.int32)
    return d


# ------------------------------------------------------------- recsys specs
def recsys_input_specs(cfg, shp: SH.RecShape) -> dict:
    b, s = shp.batch, cfg.seq_len
    d = {"user": sds((b,), jnp.int32),
         "hist_items": sds((b, s), jnp.int32),
         "hist_cats": sds((b, s), jnp.int32),
         "hist_mask": sds((b, s), jnp.float32),
         "target_item": sds((b,), jnp.int32),
         "target_cat": sds((b,), jnp.int32)}
    if shp.kind == "train":
        d["label"] = sds((b,), jnp.int32)
    if shp.kind == "retrieval":
        # 1M candidates padded to 2^20 for even sharding (DESIGN.md §4)
        d["cand_items"] = sds((r512(shp.n_candidates),), jnp.int32)
    return d


# ----------------------------------------------------- IS-LABEL (the paper)
def islabel_input_specs(cfg, shp: SH.IndexShape) -> dict:
    if shp.kind == "query":
        nrows = r512(shp.n_vertices + 1)
        return {"lbl_ids": sds((nrows, shp.l_cap), jnp.int32),
                "lbl_d": sds((nrows, shp.l_cap), jnp.float32),
                "core_pos": sds((nrows,), jnp.int32),
                "ce_src": sds((shp.core_edges,), jnp.int32),
                "ce_dst": sds((shp.core_edges,), jnp.int32),
                "ce_w": sds((shp.core_edges,), jnp.float32),
                "s": sds((shp.q_batch,), jnp.int32),
                "t": sds((shp.q_batch,), jnp.int32)}
    if shp.kind == "build_level":
        return {"src": sds((shp.e_cap,), jnp.int32),
                "dst": sds((shp.e_cap,), jnp.int32),
                "w": sds((shp.e_cap,), jnp.float32),
                "via": sds((shp.e_cap,), jnp.int32),
                "active": sds((shp.n_vertices,), jnp.bool_)}
    raise KeyError(shp.kind)
