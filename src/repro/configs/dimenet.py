"""dimenet — assigned GNN architecture.

6 interaction blocks, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6 [arXiv:2003.03123; unverified]. Kernel regime: triplet
gather (directed edge messages modulated by angular basis). Triplet
lists on the large web-graph shape cells are capped/sampled
(DESIGN.md §4) — sum-of-degree-squared triplet counts are a molecular
assumption that does not transfer.
"""
import dataclasses

from repro.configs.base import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.dimenet import DimeNetConfig

CONFIG = DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                       n_bilinear=8, n_spherical=7, n_radial=6)


def get_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dimenet", family="gnn", model_cfg=CONFIG,
        shapes=dict(GNN_SHAPES),
        smoke_cfg_fn=lambda: dataclasses.replace(CONFIG, n_blocks=2,
                                                 d_hidden=16, n_bilinear=2),
        notes="[arXiv:2003.03123; unverified]")
