"""Step builders: (ArchSpec, shape, mesh) -> jit-able step + shardings.

Every (architecture x input-shape) cell resolves here to a ``StepBundle``
the dry-run launcher can ``jit(...).lower(...).compile()`` and the real
launchers (train.py / serve.py) can execute. One code path for both.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchSpec, sds
from repro.distributed import sharding as SHD
from repro.launch.mesh import dp_axes
from repro.models import layers as L
from repro.optim import adafactor, adamw, warmup_cosine


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args_abs: tuple                  # abstract args (trees of SDS)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    static_meta: dict = dataclasses.field(default_factory=dict)

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.args_abs)


def _ns(mesh, *parts):
    return NamedSharding(mesh, P(*parts))


def make_optimizer(name: str, total_steps: int = 100_000,
                   warmup: int = 2000):
    sched = warmup_cosine(warmup, total_steps)
    if name == "adafactor":
        return adafactor(lr=1e-2, schedule=sched)
    return adamw(lr=3e-4, schedule=sched)


def abstract_opt_state(opt, params_abs):
    return jax.eval_shape(opt.init, params_abs)


# ============================================================ LM family
def lm_rules(spec: ArchSpec, mesh) -> dict:
    cfg = spec.model_cfg
    rules = dict(SHD.LM_RULES)
    if getattr(spec, "fsdp_over_pod", False) and "pod" in mesh.axis_names:
        rules["embed"] = ("pod", "data")
    if cfg.moe is not None:
        # EP over the model axis when the expert count divides it;
        # otherwise TP inside each expert's ffn dim (qwen2-moe: 60 % 16 != 0)
        if cfg.moe.n_total % mesh.shape["model"] == 0:
            rules["experts"], rules["expert_mlp"] = "model", None
        else:
            rules["experts"], rules["expert_mlp"] = None, "model"
    return rules


def _lm_state(spec: ArchSpec, mesh, ov=None):
    from repro.models.transformer import abstract_params, lm_axes
    ov = ov or {}
    cfg = spec.model_cfg
    rules = lm_rules(spec, mesh)
    params_abs = abstract_params(cfg)
    if spec.param_dtype != "float32":
        pd = jnp.dtype(spec.param_dtype)
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, pd), params_abs)
    param_sh = SHD.tree_shardings(lm_axes(cfg), rules, mesh)
    opt = make_optimizer(spec.optimizer, warmup=int(ov.get("warmup", 2000)))
    opt_abs = abstract_opt_state(opt, params_abs)
    opt_sh = SHD.opt_state_shardings(spec.optimizer, params_abs, param_sh,
                                     mesh)
    state_abs = {"params": params_abs, "opt": opt_abs,
                 "step": sds((), jnp.int32)}
    state_sh = {"params": param_sh, "opt": opt_sh, "step": _ns(mesh)}
    return cfg, opt, state_abs, state_sh


def build_lm_bundle(spec: ArchSpec, shape_name: str, mesh,
                    overrides: dict | None = None) -> StepBundle:
    from repro.models import transformer as T
    shp = spec.shape(shape_name)
    cfg = spec.model_cfg
    dp = dp_axes(mesh)
    batch_abs = spec.input_specs(shape_name)
    ov = overrides or {}
    if cfg.act_shard:
        T.set_act_shard_mesh(mesh)
    if cfg.moe is not None and cfg.moe.dispatch_shard:
        from repro.models.moe import set_dispatch_mesh
        set_dispatch_mesh(mesh)

    if shp.kind == "train":
        cfg, opt, state_abs, state_sh = _lm_state(spec, mesh, ov)
        batch_sh = {k: _ns(mesh, dp, None) for k in batch_abs}
        accum = int(ov.get("grad_accum", 1))
        compress = bool(ov.get("compress_pods")) and "pod" in mesh.axis_names

        def loss_fn(p, tokens, targets):
            return T.lm_loss(p, cfg, tokens, targets)

        if compress:
            from repro.distributed.compression import make_compressed_grad_fn
            n_pods = mesh.shape["pod"]
            state_abs["err"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_pods,) + s.shape,
                                               jnp.float32),
                state_abs["params"])
            state_sh["err"] = jax.tree.map(
                lambda sh: NamedSharding(mesh, P("pod", *sh.spec)),
                state_sh["params"])
            cg = make_compressed_grad_fn(
                lambda p, b: jax.value_and_grad(loss_fn)(
                    p, b["tokens"], b["targets"]), mesh)

            def train_step(state, batch):
                loss, grads, new_err = cg(state["params"], state["err"],
                                          batch)
                new_p, new_opt, gnorm = opt.update(
                    grads, state["opt"], state["params"], state["step"])
                return ({"params": new_p, "opt": new_opt, "err": new_err,
                         "step": state["step"] + 1},
                        {"loss": loss, "gnorm": gnorm})

            metrics_sh = {"loss": _ns(mesh), "gnorm": _ns(mesh)}
            return StepBundle(
                name=f"{spec.arch_id}:{shape_name}:train+int8pods",
                fn=train_step, args_abs=(state_abs, batch_abs),
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metrics_sh), donate_argnums=(0,))

        def train_step(state, batch):
            if accum > 1:
                b = batch["tokens"].shape[0]
                mb = b // accum
                tok = batch["tokens"].reshape(accum, mb, -1)
                tgt = batch["targets"].reshape(accum, mb, -1)

                def micro(carry, xs):
                    gsum, lsum = carry
                    t_, y_ = xs
                    l_, g_ = jax.value_and_grad(loss_fn)(state["params"],
                                                         t_, y_)
                    return (jax.tree.map(jnp.add, gsum, g_), lsum + l_), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                (gs, ls), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)),
                                           (tok, tgt),
                                           unroll=bool(ov.get(
                                               "accum_unroll", False)))
                grads = jax.tree.map(lambda g: g / accum, gs)
                loss = ls / accum
            else:
                loss, grads = jax.value_and_grad(loss_fn)(
                    state["params"], batch["tokens"], batch["targets"])
            new_p, new_opt, gnorm = opt.update(grads, state["opt"],
                                               state["params"], state["step"])
            new_state = {"params": new_p, "opt": new_opt,
                         "step": state["step"] + 1}
            return new_state, {"loss": loss, "gnorm": gnorm}

        metrics_sh = {"loss": _ns(mesh), "gnorm": _ns(mesh)}
        return StepBundle(
            name=f"{spec.arch_id}:{shape_name}:train",
            fn=train_step, args_abs=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh), donate_argnums=(0,))

    from repro.models.transformer import abstract_params, lm_axes
    params_abs = abstract_params(cfg)
    param_sh = SHD.tree_shardings(lm_axes(cfg), lm_rules(spec, mesh), mesh)
    # KV cache [L, B, S, KV, Dh]: batch over dp, *sequence* over model —
    # kv_heads (8) doesn't divide the model axis (16), and for 32k+
    # contexts the cache is the memory hog, so sequence-parallel KV is
    # both legal and the right memory split.
    cache_sh = {"k": _ns(mesh, None, dp, "model", None, None),
                "v": _ns(mesh, None, dp, "model", None, None),
                "len": _ns(mesh)}

    if shp.kind == "prefill":
        def prefill_step(params, batch):
            return T.prefill(params, cfg, batch["tokens"], shp.seq_len)
        return StepBundle(
            name=f"{spec.arch_id}:{shape_name}:prefill",
            fn=prefill_step, args_abs=(params_abs, batch_abs),
            in_shardings=(param_sh, {"tokens": _ns(mesh, dp, None)}),
            out_shardings=(_ns(mesh, dp, None, "model"), cache_sh))

    if shp.kind == "decode":
        def decode_step(params, cache, last_tokens):
            return T.decode_step(params, cfg, cache, last_tokens)
        return StepBundle(
            name=f"{spec.arch_id}:{shape_name}:decode",
            fn=decode_step,
            args_abs=(params_abs, batch_abs["cache"],
                      batch_abs["last_tokens"]),
            in_shardings=(param_sh, cache_sh, _ns(mesh, dp, None)),
            out_shardings=(_ns(mesh, dp, None, "model"), cache_sh),
            donate_argnums=(1,))
    raise KeyError(shp.kind)


# =========================================================== GNN family
def _adapt_gnn_cfg(cfg, shp):
    import dataclasses as dc
    t = type(cfg).__name__
    if t == "GCNConfig":
        return dc.replace(cfg, d_in=shp.d_feat,
                          n_classes=max(shp.n_classes, 1))
    if t == "SAGEConfig":
        return dc.replace(cfg, d_in=shp.d_feat,
                          n_classes=max(shp.n_classes, 1))
    if t == "EGNNConfig":
        return dc.replace(cfg, d_in=shp.d_feat,
                          n_out=max(shp.n_classes, 1))
    return cfg    # DimeNet: n_out adapts below via out blocks (n_out=1)


def _gnn_node_out(params, cfg, batch):
    from repro.models import dimenet as DN
    from repro.models import gnn as G
    t = type(cfg).__name__
    if t == "GCNConfig":
        return G.gcn_forward(params, cfg, batch["feats"], batch["edge_src"],
                             batch["edge_dst"], batch["deg"])
    if t == "SAGEConfig":
        return G.sage_forward_full(params, cfg, batch["feats"],
                                   batch["edge_src"], batch["edge_dst"])
    if t == "EGNNConfig":
        out, _ = G.egnn_forward(params, cfg, batch["feats"], batch["coords"],
                                batch["edge_src"], batch["edge_dst"])
        return out
    if t == "DimeNetConfig":
        out, _ = DN.dimenet_forward(params, cfg, batch["atom_z"],
                                    batch["coords"], batch["edge_src"],
                                    batch["edge_dst"], batch["trip_kj"],
                                    batch["trip_ji"])
        return out
    raise KeyError(t)


def _gnn_init(cfg, key):
    from repro.models import dimenet as DN
    from repro.models import gnn as G
    t = type(cfg).__name__
    if t == "GCNConfig":
        return G.init_gcn(key, cfg)
    if t == "SAGEConfig":
        return G.init_sage(key, cfg)
    if t == "EGNNConfig":
        return G.init_egnn(key, cfg)
    return DN.init_dimenet(key, cfg)


def gnn_loss(params, cfg, batch, kind: str, n_classes: int):
    node_out = _gnn_node_out(params, cfg, batch)
    if kind in ("full", "minibatch"):
        if type(cfg).__name__ == "DimeNetConfig":
            # DimeNet emits n_out=1; project by broadcasting for CE is
            # meaningless — use regression-on-degree proxy target instead.
            pred = node_out[..., 0]
            tgt = batch["labels"].astype(jnp.float32)
            per = jnp.square(pred - tgt)
            return jnp.sum(per * batch["mask"]) / jnp.maximum(
                jnp.sum(batch["mask"]), 1.0)
        ce = L.softmax_cross_entropy(node_out, batch["labels"])
        return jnp.sum(ce * batch["mask"]) / jnp.maximum(
            jnp.sum(batch["mask"]), 1.0)
    # molecule: graph-level regression (sum-pool over graph_ids)
    from repro.graphs import segment_ops as sops
    b = batch["targets"].shape[0]
    pooled = sops.segment_sum(node_out[..., 0], batch["graph_ids"], b + 1)[:b]
    return jnp.mean(jnp.square(pooled - batch["targets"]))


def build_gnn_bundle(spec: ArchSpec, shape_name: str, mesh) -> StepBundle:
    shp = spec.shape(shape_name)
    cfg = _adapt_gnn_cfg(spec.model_cfg, shp)
    allx = tuple(mesh.axis_names)
    batch_abs = spec.input_specs(shape_name)
    batch_sh = {k: _ns(mesh, allx, *([None] * (len(v.shape) - 1)))
                for k, v in batch_abs.items()}
    if "targets" in batch_sh:
        batch_sh["targets"] = _ns(mesh, None)

    params_abs = jax.eval_shape(lambda k: _gnn_init(cfg, k)[0],
                                jax.random.PRNGKey(0))
    param_sh = SHD.like_tree(params_abs, _ns(mesh))     # replicated (tiny)
    opt = make_optimizer(spec.optimizer)
    opt_abs = abstract_opt_state(opt, params_abs)
    opt_sh = SHD.like_tree(opt_abs, _ns(mesh))
    state_abs = {"params": params_abs, "opt": opt_abs,
                 "step": sds((), jnp.int32)}
    state_sh = {"params": param_sh, "opt": opt_sh, "step": _ns(mesh)}

    def train_step(state, batch):
        def loss_fn(p):
            return gnn_loss(p, cfg, batch, shp.kind, shp.n_classes)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        new_p, new_opt, gnorm = opt.update(grads, state["opt"],
                                           state["params"], state["step"])
        return ({"params": new_p, "opt": new_opt, "step": state["step"] + 1},
                {"loss": loss, "gnorm": gnorm})

    return StepBundle(
        name=f"{spec.arch_id}:{shape_name}:train",
        fn=train_step, args_abs=(state_abs, batch_abs),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, {"loss": _ns(mesh), "gnorm": _ns(mesh)}),
        donate_argnums=(0,), static_meta={"cfg": cfg})


# ======================================================== recsys family
def build_recsys_bundle(spec: ArchSpec, shape_name: str, mesh) -> StepBundle:
    from repro.models import dien as D
    shp = spec.shape(shape_name)
    cfg = spec.model_cfg
    dp = dp_axes(mesh)
    batch_abs = spec.input_specs(shape_name)

    params_abs = jax.eval_shape(lambda k: D.init_dien(k, cfg)[0],
                                jax.random.PRNGKey(0))
    axes = D.init_dien(jax.random.PRNGKey(0), spec.smoke_cfg_fn())[1]
    param_sh = SHD.tree_shardings(axes, SHD.RECSYS_RULES, mesh)

    import numpy as _np
    dp_size = int(_np.prod([mesh.shape[a] for a in dp]))

    def bsh(v, name):
        if name == "cand_items":
            return _ns(mesh, tuple(mesh.axis_names))
        if v.shape[0] % dp_size:          # tiny batch (retrieval): replicate
            return _ns(mesh, *([None] * len(v.shape)))
        return _ns(mesh, dp, *([None] * (len(v.shape) - 1)))
    batch_sh = {k: bsh(v, k) for k, v in batch_abs.items()}

    if shp.kind == "train":
        opt = make_optimizer(spec.optimizer)
        opt_abs = abstract_opt_state(opt, params_abs)
        opt_sh = SHD.opt_state_shardings(spec.optimizer, params_abs,
                                         param_sh, mesh)
        state_abs = {"params": params_abs, "opt": opt_abs,
                     "step": sds((), jnp.int32)}
        state_sh = {"params": param_sh, "opt": opt_sh, "step": _ns(mesh)}

        def train_step(state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: D.dien_loss(p, cfg, batch))(state["params"])
            new_p, new_opt, gnorm = opt.update(grads, state["opt"],
                                               state["params"],
                                               state["step"])
            return ({"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1},
                    {"loss": loss, "gnorm": gnorm})

        return StepBundle(
            name=f"{spec.arch_id}:{shape_name}:train",
            fn=train_step, args_abs=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, {"loss": _ns(mesh), "gnorm": _ns(mesh)}),
            donate_argnums=(0,))

    if shp.kind == "serve":
        def serve_step(params, batch):
            logit, _ = D.dien_forward(params, cfg, batch)
            return jax.nn.sigmoid(logit)
        return StepBundle(
            name=f"{spec.arch_id}:{shape_name}:serve",
            fn=serve_step, args_abs=(params_abs, batch_abs),
            in_shardings=(param_sh, batch_sh),
            out_shardings=_ns(mesh, dp))

    if shp.kind == "retrieval":
        def retrieval_step(params, batch):
            return D.retrieval_scores(params, cfg, batch)
        return StepBundle(
            name=f"{spec.arch_id}:{shape_name}:retrieval",
            fn=retrieval_step, args_abs=(params_abs, batch_abs),
            in_shardings=(param_sh, batch_sh),
            out_shardings=_ns(mesh, None, tuple(mesh.axis_names)))
    raise KeyError(shp.kind)


# ================================================= IS-LABEL (the paper)
def build_islabel_bundle(spec: ArchSpec, shape_name: str, mesh,
                         overrides: dict | None = None) -> StepBundle:
    from repro.core.query import label_intersect_mu
    shp = spec.shape(shape_name)
    dp = dp_axes(mesh)
    allx = tuple(mesh.axis_names)
    batch_abs = spec.input_specs(shape_name)
    ov = overrides or {}

    if shp.kind == "query":
        n, l_cap, n_core = shp.n_vertices, shp.l_cap, shp.n_core
        # statically-unrolled relaxation rounds so the dry-run cost
        # analysis reflects a typical converged search (the serving path
        # uses the improvement-driven while_loop in core.query instead)
        relax_rounds = int(ov.get("relax_rounds", 8))
        # hillclimb knobs: chunked edge relaxation bounds the [Q, E_k]
        # gather temp; bf16 labels halve label-fetch traffic
        relax_chunks = int(ov.get("relax_chunks", 0))
        if ov.get("lbl_dtype"):
            batch_abs = dict(batch_abs)
            batch_abs["lbl_d"] = jax.ShapeDtypeStruct(
                batch_abs["lbl_d"].shape, jnp.dtype(ov["lbl_dtype"]))

        def one_round(d, ce_src, ce_dst, ce_w):
            if not relax_chunks:
                return d.at[:, ce_dst].min(d[:, ce_src] + ce_w[None, :])
            e = ce_src.shape[0]
            chunk = e // relax_chunks

            def body(dd, i):
                s_ = jax.lax.dynamic_slice_in_dim(ce_src, i * chunk, chunk)
                t_ = jax.lax.dynamic_slice_in_dim(ce_dst, i * chunk, chunk)
                w_ = jax.lax.dynamic_slice_in_dim(ce_w, i * chunk, chunk)
                return dd.at[:, t_].min(dd[:, s_] + w_[None, :]), None
            d, _ = jax.lax.scan(body, d, jnp.arange(relax_chunks))
            return d

        def query_step(batch):
            ids_s = batch["lbl_ids"][batch["s"]]
            d_s = batch["lbl_d"][batch["s"]].astype(jnp.float32)
            ids_t = batch["lbl_ids"][batch["t"]]
            d_t = batch["lbl_d"][batch["t"]].astype(jnp.float32)
            mu, _ = label_intersect_mu(ids_s, d_s, ids_t, d_t, n, l_cap)
            q = ids_s.shape[0]
            cpos_s = batch["core_pos"][jnp.minimum(ids_s, n)]
            cpos_t = batch["core_pos"][jnp.minimum(ids_t, n)]
            ridx = jnp.broadcast_to(jnp.arange(q)[:, None], cpos_s.shape)
            ds = jnp.full((q, n_core + 1), jnp.inf, jnp.float32) \
                .at[ridx, cpos_s].min(jnp.where(ids_s < n, d_s, jnp.inf))
            dt = jnp.full((q, n_core + 1), jnp.inf, jnp.float32) \
                .at[ridx, cpos_t].min(jnp.where(ids_t < n, d_t, jnp.inf))
            for _ in range(relax_rounds):
                ds = one_round(ds, batch["ce_src"], batch["ce_dst"],
                               batch["ce_w"])
                dt = one_round(dt, batch["ce_src"], batch["ce_dst"],
                               batch["ce_w"])
            through = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
            return jnp.minimum(mu, through)

        batch_sh = {
            "lbl_ids": _ns(mesh, allx, None), "lbl_d": _ns(mesh, allx, None),
            "core_pos": _ns(mesh, allx), "ce_src": _ns(mesh, allx),
            "ce_dst": _ns(mesh, allx), "ce_w": _ns(mesh, allx),
            "s": _ns(mesh, dp), "t": _ns(mesh, dp)}
        return StepBundle(
            name=f"islabel:{shape_name}:query", fn=query_step,
            args_abs=(batch_abs,), in_shardings=(batch_sh,),
            out_shardings=_ns(mesh, dp))

    if shp.kind == "build_level":
        from repro.core.hierarchy import peel_level
        n = shp.n_vertices
        d_cap = shp.d_cap
        aug_cap = shp.e_cap // 2

        def build_step(batch, key_data):
            key = jax.random.wrap_key_data(key_data)
            return peel_level(batch["src"], batch["dst"], batch["w"],
                              batch["via"], batch["active"], key, n, d_cap,
                              aug_cap)[:5]

        batch_sh = {"src": _ns(mesh, allx), "dst": _ns(mesh, allx),
                    "w": _ns(mesh, allx), "via": _ns(mesh, allx),
                    "active": _ns(mesh, allx)}
        return StepBundle(
            name=f"islabel:{shape_name}:build", fn=build_step,
            args_abs=(batch_abs, sds((2,), jnp.uint32)),
            in_shardings=(batch_sh, _ns(mesh)),
            out_shardings=None)
    raise KeyError(shp.kind)


# ------------------------------------------------------------- dispatcher
def build_bundle(spec: ArchSpec, shape_name: str, mesh,
                 overrides: dict | None = None) -> StepBundle:
    if spec.family == "lm":
        return build_lm_bundle(spec, shape_name, mesh, overrides)
    if spec.family == "gnn":
        return build_gnn_bundle(spec, shape_name, mesh)
    if spec.family == "recsys":
        return build_recsys_bundle(spec, shape_name, mesh)
    if spec.family == "graph_index":
        return build_islabel_bundle(spec, shape_name, mesh, overrides)
    raise KeyError(spec.family)
