"""Padded fixed-shape edge-list / CSR containers.

Conventions (used across core/, models/gnn, kernels/):
  * ``n`` real vertices; vertex id ``n`` is the *sentinel* — every padded
    edge has ``src = dst = n`` and ``weight = +inf`` so that segment ops
    with ``num_segments = n + 1`` park padding in a throwaway row.
  * Undirected graphs store both (u,v) and (v,u).
  * ``via`` carries the intermediate vertex of an augmenting edge
    (paper §8.1 path reconstruction); -1 = original edge.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import segment_ops as sops


@partial(jax.tree_util.register_dataclass,
         data_fields=["src", "dst", "weight", "via"],
         meta_fields=["n_nodes"])
@dataclasses.dataclass(frozen=True)
class EdgeList:
    src: jax.Array      # int32[e_cap]
    dst: jax.Array      # int32[e_cap]
    weight: jax.Array   # float32[e_cap], +inf padding
    via: jax.Array      # int32[e_cap], -1 = original edge
    n_nodes: int        # static

    @property
    def e_cap(self) -> int:
        return self.src.shape[0]

    @property
    def sentinel(self) -> int:
        return self.n_nodes

    def valid(self) -> jax.Array:
        return self.src < self.n_nodes

    def n_edges(self) -> jax.Array:
        return jnp.sum(self.valid().astype(jnp.int32))


def from_host_edges(src, dst, weight, n_nodes: int, e_cap: int | None = None,
                    via=None) -> EdgeList:
    """Build a padded EdgeList from host numpy arrays."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    weight = np.asarray(weight, np.float32)
    e = src.shape[0]
    if e_cap is None:
        e_cap = max(1, e)
    if e > e_cap:
        raise ValueError(f"e_cap={e_cap} < {e} edges")
    pad = e_cap - e
    s = np.concatenate([src, np.full(pad, n_nodes, np.int32)])
    d = np.concatenate([dst, np.full(pad, n_nodes, np.int32)])
    w = np.concatenate([weight, np.full(pad, np.inf, np.float32)])
    if via is None:
        via = np.full(e, -1, np.int32)
    v = np.concatenate([np.asarray(via, np.int32), np.full(pad, -1, np.int32)])
    return EdgeList(jnp.asarray(s), jnp.asarray(d), jnp.asarray(w), jnp.asarray(v),
                    n_nodes=n_nodes)


def degrees(g: EdgeList) -> jax.Array:
    """Out-degree per vertex (== degree for symmetric edge lists)."""
    return sops.count_per_segment(g.src, g.n_nodes + 1, mask=g.valid())[: g.n_nodes]


def neighbor_matrix(g: EdgeList, d_cap: int):
    """Dense padded adjacency: for each vertex a row of up to ``d_cap``
    (neighbor, weight, via) triples. Vertices with degree > d_cap keep an
    arbitrary d_cap-subset with ``overflow[v] = True``.

    Returns (nbr_ids [n+1, d_cap] int32 (sentinel pad), nbr_w, nbr_via,
    overflow [n] bool).  This is the paper's ``ADJ(L_i)`` in fixed shape:
    only rows of IS vertices (degree <= d_cap by eligibility) are ever
    consumed, so the subset truncation never loses information in use.
    """
    n, e_cap = g.n_nodes, g.e_cap
    order = jnp.argsort(g.src, stable=True)          # group edges by src
    s_sorted = g.src[order]
    # rank within the group = position - first position of the group
    idx = jnp.arange(e_cap, dtype=jnp.int32)
    first_of_group = sops.segment_min(idx, s_sorted, n + 1)
    rank = idx - first_of_group[s_sorted]
    ok = (s_sorted < n) & (rank < d_cap)
    flat = jnp.where(ok, s_sorted * d_cap + rank, n * d_cap)  # park at sentinel row
    nbr_ids = jnp.full(((n + 1) * d_cap,), n, jnp.int32).at[flat].set(
        jnp.where(ok, g.dst[order], n), mode="drop")
    nbr_w = jnp.full(((n + 1) * d_cap,), jnp.inf, jnp.float32).at[flat].set(
        jnp.where(ok, g.weight[order], jnp.inf), mode="drop")
    nbr_via = jnp.full(((n + 1) * d_cap,), -1, jnp.int32).at[flat].set(
        jnp.where(ok, g.via[order], -1), mode="drop")
    deg = degrees(g)
    overflow = deg > d_cap
    return (nbr_ids.reshape(n + 1, d_cap), nbr_w.reshape(n + 1, d_cap),
            nbr_via.reshape(n + 1, d_cap), overflow)


def dedup_min_edges(src, dst, weight, via, n_nodes: int, out_cap: int):
    """Sort (src,dst) pairs, collapse duplicates keeping min weight (and
    its ``via``), compact into fixed ``out_cap`` arrays.

    The TPU-native version of the paper's external sort-merge (Alg. 3
    lines 7-8): sort + segment_min instead of disk merge passes.
    Returns (src, dst, w, via, n_unique) — n_unique may exceed out_cap,
    callers must check (overflow detection).
    """
    t = src.shape[0]
    order = jnp.lexsort((dst, src))
    s, d, w, v = src[order], dst[order], weight[order], via[order]
    is_first = jnp.concatenate([jnp.array([True]),
                                (s[1:] != s[:-1]) | (d[1:] != d[:-1])])
    gid = jnp.cumsum(is_first.astype(jnp.int32)) - 1          # group index
    gmin = sops.segment_min(w, gid, t)
    gvia = sops.segment_argmin_take(w, v, gid, t)
    valid_group = is_first & (s < n_nodes)
    pos = jnp.cumsum(valid_group.astype(jnp.int32)) - 1
    tgt = jnp.where(valid_group & (pos < out_cap), pos, out_cap)
    o_src = jnp.full((out_cap + 1,), n_nodes, jnp.int32).at[tgt].set(
        jnp.where(valid_group, s, n_nodes), mode="drop")[:out_cap]
    o_dst = jnp.full((out_cap + 1,), n_nodes, jnp.int32).at[tgt].set(
        jnp.where(valid_group, d, n_nodes), mode="drop")[:out_cap]
    o_w = jnp.full((out_cap + 1,), jnp.inf, jnp.float32).at[tgt].set(
        jnp.where(valid_group, gmin[gid], jnp.inf), mode="drop")[:out_cap]
    o_via = jnp.full((out_cap + 1,), -1, jnp.int32).at[tgt].set(
        jnp.where(valid_group, gvia[gid], -1), mode="drop")[:out_cap]
    n_unique = jnp.sum(valid_group.astype(jnp.int32))
    return o_src, o_dst, o_w, o_via, n_unique


def to_host_coo(g: EdgeList):
    """Pull the valid edges back to host numpy (benchmark/oracle use)."""
    src = np.asarray(g.src)
    mask = src < g.n_nodes
    return (src[mask], np.asarray(g.dst)[mask], np.asarray(g.weight)[mask],
            np.asarray(g.via)[mask])
