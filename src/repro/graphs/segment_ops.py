"""Segment reductions — the scatter/gather substrate for everything graph.

JAX has no CSR/CSC sparse and no EmbeddingBag: all message passing in
this framework (GNNs, IS-LABEL construction, wavefront relaxation,
embedding bags) is expressed as ``gather -> elementwise -> segment_*``
over an edge index. These wrappers pin ``num_segments`` static and fix
the fill values for empty segments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.inf


def segment_sum(data, segment_ids, num_segments: int):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_min(data, segment_ids, num_segments: int):
    """Min-reduce; empty segments = +inf (float) / dtype max (int)."""
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments)


def segment_max(data, segment_ids, num_segments: int):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_mean(data, segment_ids, num_segments: int):
    tot = segment_sum(data, segment_ids, num_segments)
    cnt = segment_sum(jnp.ones(data.shape[:1], data.dtype), segment_ids, num_segments)
    return tot / jnp.maximum(cnt, 1.0).reshape((-1,) + (1,) * (data.ndim - 1))


def segment_softmax(logits, segment_ids, num_segments: int):
    """Numerically-stable softmax within segments (edge-softmax for GAT)."""
    seg_max = segment_max(logits, segment_ids, num_segments)
    shifted = logits - jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)[segment_ids]
    ex = jnp.exp(shifted)
    denom = segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-30)


def scatter_min(target, idx, vals):
    """target[idx] = min(target[idx], vals) with duplicate idx allowed."""
    return target.at[idx].min(vals)


def segment_argmin_take(data, payload, segment_ids, num_segments: int):
    """For each segment return payload of (one) element achieving the min.

    Used for keeping the ``via`` vertex of the min-weight duplicate edge.
    Deterministic: among ties picks the largest payload.
    """
    seg_min = segment_min(data, segment_ids, num_segments)
    is_min = data == seg_min[segment_ids]
    return segment_max(jnp.where(is_min, payload, -1), segment_ids, num_segments)


def count_per_segment(segment_ids, num_segments: int, mask=None):
    ones = jnp.ones(segment_ids.shape, jnp.int32)
    if mask is not None:
        ones = jnp.where(mask, ones, 0)
    return segment_sum(ones, segment_ids, num_segments)
