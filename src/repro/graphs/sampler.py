"""Host-side neighbor sampler for GraphSAGE-style minibatch training.

Produces DGL-style "blocks": for a batch of seed nodes and fanouts
(outer->inner, e.g. [10, 15] for sample_sizes=25-10 two-layer SAGE), each
block is a bipartite (src_local -> dst_local) edge set with fixed padded
shapes so the device step compiles once.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class HostCSR:
    n: int
    indptr: np.ndarray   # int64[n+1]
    indices: np.ndarray  # int32[e]

    @staticmethod
    def from_coo(n: int, src, dst) -> "HostCSR":
        order = np.argsort(src, kind="stable")
        s, d = np.asarray(src)[order], np.asarray(dst)[order]
        indptr = np.zeros(n + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        indptr = np.cumsum(indptr)
        return HostCSR(n, indptr, d.astype(np.int32))

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


@dataclasses.dataclass
class Block:
    """Bipartite message block. Arrays are padded to fixed shapes."""
    src_ids: np.ndarray    # int32[n_src_cap] global ids (pad = -1)
    dst_ids: np.ndarray    # int32[n_dst_cap]
    edge_src: np.ndarray   # int32[e_cap] local index into src_ids (pad -> n_src_cap)
    edge_dst: np.ndarray   # int32[e_cap] local index into dst_ids
    n_src_cap: int
    n_dst_cap: int


def sample_blocks(csr: HostCSR, seeds: np.ndarray, fanouts: list[int],
                  rng: np.random.Generator) -> list[Block]:
    """Sample inner-to-outer: returns blocks ordered outermost first, so a
    forward pass folds them left-to-right into the seeds."""
    blocks: list[Block] = []
    frontier = np.asarray(seeds, np.int32)
    for fanout in fanouts:                      # innermost (near seeds) first
        n_dst = len(frontier)
        e_cap = n_dst * fanout
        edge_src_g = np.full(e_cap, -1, np.int64)
        edge_dst_l = np.full(e_cap, n_dst, np.int32)
        for i, v in enumerate(frontier):
            nbr = csr.neighbors(int(v))
            if len(nbr) == 0:
                continue
            take = rng.choice(nbr, size=min(fanout, len(nbr)),
                              replace=len(nbr) < fanout)
            edge_src_g[i * fanout:i * fanout + len(take)] = take
            edge_dst_l[i * fanout:i * fanout + len(take)] = i
        uniq, inv = np.unique(
            np.concatenate([frontier.astype(np.int64),
                            edge_src_g[edge_src_g >= 0]]), return_inverse=True)
        src_ids = uniq.astype(np.int32)
        n_src_cap = n_dst * (fanout + 1)        # fixed cap
        pad_src = np.full(n_src_cap, -1, np.int32)
        pad_src[:len(src_ids)] = src_ids
        edge_src_l = np.full(e_cap, n_src_cap, np.int32)
        lut = {int(g): i for i, g in enumerate(src_ids)}
        valid = edge_src_g >= 0
        edge_src_l[valid] = [lut[int(g)] for g in edge_src_g[valid]]
        dst_pad = np.full(n_dst, -1, np.int32)
        dst_pad[:n_dst] = frontier
        blocks.append(Block(pad_src, dst_pad, edge_src_l, edge_dst_l,
                            n_src_cap, n_dst))
        frontier = src_ids                       # expand outward
    return blocks[::-1]                          # outermost first


def sampled_batch_arrays(csr: HostCSR, seeds, fanouts, rng, feats, labels):
    """Convenience: blocks + gathered input features for the outermost
    node set + labels for seeds, all numpy."""
    blocks = sample_blocks(csr, seeds, fanouts, rng)
    outer = blocks[0].src_ids
    x = np.zeros((len(outer), feats.shape[1]), feats.dtype)
    ok = outer >= 0
    x[ok] = feats[outer[ok]]
    return blocks, x, labels[np.asarray(seeds)]
