from repro.graphs.csr import EdgeList, from_host_edges, degrees, neighbor_matrix
from repro.graphs import generators, segment_ops, sampler
