"""Host-side synthetic graph generators (numpy).

Real datasets from the paper (BTC, UK-Web, as-Skitter, wiki-Talk,
web-Google) are not available offline; these generators reproduce their
*regimes*: sparse power-law (rmat ~ web/social), low-degree semantic
(sparse ER ~ BTC with avg deg 2.19), meshes (grid), and community
graphs (caveman). All return (n, src, dst, weight) with both edge
directions, no self loops, no duplicates, integer-valued float weights.
"""
from __future__ import annotations

import numpy as np


def _finalize(n, und_edges, rng, max_w, weights=None):
    """und_edges: (m,2) undirected unique pairs u<v."""
    und_edges = np.unique(und_edges[und_edges[:, 0] != und_edges[:, 1]], axis=0)
    u, v = und_edges[:, 0], und_edges[:, 1]
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    pairs = np.unique(np.stack([lo, hi], 1), axis=0)
    m = pairs.shape[0]
    if weights is None:
        weights = rng.integers(1, max_w + 1, size=m).astype(np.float32)
    src = np.concatenate([pairs[:, 0], pairs[:, 1]]).astype(np.int32)
    dst = np.concatenate([pairs[:, 1], pairs[:, 0]]).astype(np.int32)
    w = np.concatenate([weights, weights]).astype(np.float32)
    return n, src, dst, w


def er_graph(n: int, avg_deg: float = 3.0, max_w: int = 4, seed: int = 0):
    """Sparse Erdos-Renyi — the BTC-like low-degree regime."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    e = rng.integers(0, n, size=(int(m * 1.2), 2))
    return _finalize(n, e, rng, max_w)


def rmat_graph(n_pow: int, avg_deg: float = 8.0, max_w: int = 4, seed: int = 0,
               a=0.57, b=0.19, c=0.19):
    """R-MAT power-law graph (web/social regime). n = 2**n_pow."""
    n = 1 << n_pow
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for _ in range(n_pow):
        q = rng.random(m)
        sbit = (q >= a + b).astype(np.int64)          # quadrants c,d
        dbit = ((q >= a) & (q < a + b) | (q >= a + b + c)).astype(np.int64)
        src = (src << 1) | sbit
        dst = (dst << 1) | dbit
    e = np.stack([src, dst], 1)
    return _finalize(n, e, rng, max_w)


def grid_graph(side: int, max_w: int = 4, seed: int = 0):
    """2D grid — road-network-like regime (max degree 4)."""
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n).reshape(side, side)
    h = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1)
    v = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1)
    return _finalize(n, np.concatenate([h, v]), rng, max_w)


def caveman_graph(n_communities: int, size: int, p_rewire: float = 0.05,
                  max_w: int = 4, seed: int = 0):
    """Connected-caveman — community structure regime."""
    rng = np.random.default_rng(seed)
    n = n_communities * size
    edges = []
    for ci in range(n_communities):
        base = ci * size
        for i in range(size):
            for j in range(i + 1, size):
                edges.append((base + i, base + j))
        edges.append((base + size - 1, (base + size) % n))  # ring link
    e = np.array(edges, np.int64)
    rw = rng.random(len(e)) < p_rewire
    e[rw, 1] = rng.integers(0, n, rw.sum())
    return _finalize(n, e, rng, max_w)


def unit_weights(n, src, dst, w):
    return n, src, dst, np.ones_like(w)


def largest_component_queries(n, src, dst, n_q, seed=0):
    """Sample query endpoints biased to the largest connected component
    (mirrors the paper's random 1000-query workloads)."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg
    rng = np.random.default_rng(seed)
    adj = sp.coo_matrix((np.ones(len(src)), (src, dst)), shape=(n, n))
    _, comp = csg.connected_components(adj, directed=False)
    counts = np.bincount(comp)
    big = np.flatnonzero(comp == counts.argmax())
    s = rng.choice(big, n_q)
    t = rng.choice(big, n_q)
    return s.astype(np.int32), t.astype(np.int32)
