"""Host-side synthetic graph generators (numpy).

Real datasets from the paper (BTC, UK-Web, as-Skitter, wiki-Talk,
web-Google) are not available offline; these generators reproduce their
*regimes*: sparse power-law (rmat ~ web/social), low-degree semantic
(sparse ER ~ BTC with avg deg 2.19), meshes (grid), and community
graphs (caveman). All return (n, src, dst, weight) with both edge
directions, no self loops, no duplicates, integer-valued float weights.
"""
from __future__ import annotations

import numpy as np


def _pack_pairs(n, u, v):
    """Self-loop-free canonical (lo < hi) pairs as *sorted unique* int64
    keys ``lo * n + hi`` — one 1-D sort replaces the old row-wise
    ``np.unique(..., axis=0)``; key order equals lexicographic (lo, hi)
    order, so decoded pair sets are bitwise-unchanged."""
    keep = u != v
    lo = np.minimum(u[keep], v[keep]).astype(np.int64)
    hi = np.maximum(u[keep], v[keep]).astype(np.int64)
    return np.unique(lo * np.int64(n) + hi)


def _unpack_keys(n, keys):
    return np.stack([keys // n, keys % n], 1)


def _finalize(n, und_edges, rng, max_w, weights=None):
    """und_edges: (m,2) possibly-duplicated undirected pairs, any order.

    Canonicalizes to (lo < hi) *before* the dedup: the old order deduped
    the raw (u, v) rows first, so reversed duplicates survived the first
    pass and the full O(m log m) sort ran twice — on the critical path
    of every 10^6-edge generator."""
    pairs = _unpack_keys(n, _pack_pairs(n, und_edges[:, 0], und_edges[:, 1]))
    m = pairs.shape[0]
    if weights is None:
        weights = rng.integers(1, max_w + 1, size=m).astype(np.float32)
    src = np.concatenate([pairs[:, 0], pairs[:, 1]]).astype(np.int32)
    dst = np.concatenate([pairs[:, 1], pairs[:, 0]]).astype(np.int32)
    w = np.concatenate([weights, weights]).astype(np.float32)
    return n, src, dst, w


def er_graph(n: int, avg_deg: float = 3.0, max_w: int = 4, seed: int = 0):
    """Sparse Erdos-Renyi — the BTC-like low-degree regime."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    e = rng.integers(0, n, size=(int(m * 1.2), 2))
    return _finalize(n, e, rng, max_w)


def _rmat_chunk(rng, m: int, n_pow: int, a, b, c):
    """Sample m raw R-MAT (src, dst) pairs (recursive quadrant walk)."""
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for _ in range(n_pow):
        q = rng.random(m)
        sbit = (q >= a + b).astype(np.int64)          # quadrants c,d
        dbit = ((q >= a) & (q < a + b) | (q >= a + b + c)).astype(np.int64)
        src = (src << 1) | sbit
        dst = (dst << 1) | dbit
    return src, dst


def rmat_graph(n_pow: int, avg_deg: float = 8.0, max_w: int = 4, seed: int = 0,
               a=0.57, b=0.19, c=0.19, chunk_edges: int = 2_000_000):
    """R-MAT power-law graph (web/social regime). n = 2**n_pow.

    Raw pairs are sampled in ``chunk_edges``-sized chunks, each chunk
    canonicalized + deduped on arrival, so peak host memory is one raw
    chunk plus the surviving unique keys — the 10^6–10^7-vertex regime
    never materializes all ``n_pow`` bit-planes of the full edge list at
    once. Graphs with m <= chunk_edges are bitwise-identical to the
    unchunked generator at the same seed (one chunk = one rng stream).
    """
    n = 1 << n_pow
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    keys = []
    for lo in range(0, m, chunk_edges):
        src, dst = _rmat_chunk(rng, min(chunk_edges, m - lo), n_pow, a, b, c)
        keys.append(_pack_pairs(n, src, dst))
    pairs = _unpack_keys(n, np.unique(np.concatenate(keys))
                         if len(keys) > 1 else keys[0])
    return _finalize(n, pairs, rng, max_w)


def grid_graph(side: int, max_w: int = 4, seed: int = 0):
    """2D grid — road-network-like regime (max degree 4)."""
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n).reshape(side, side)
    h = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1)
    v = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1)
    return _finalize(n, np.concatenate([h, v]), rng, max_w)


def pa_graph(n: int, m_per: int = 2, max_w: int = 4, seed: int = 0,
             chunk: int = 500_000):
    """Chunked preferential attachment (Barabási–Albert, scale-free
    social regime) at 10^6–10^7 vertices.

    The serial BA chain (each vertex attaches to endpoints of the graph
    built so far, proportional to degree) is vectorized per chunk: all
    vertices of a chunk sample their ``m_per`` targets uniformly from
    the *endpoint pool* (every edge contributes both endpoints, so pool
    frequency == degree) as it stood before the chunk — the standard
    copy-model approximation. Chunks ramp geometrically (a chunk never
    more than doubles the vertex count, capped at ``chunk``) so the
    no-feedback window stays a constant fraction of the graph,
    preserving the power-law tail while keeping generation O(m)
    vectorized numpy.
    """
    rng = np.random.default_rng(seed)
    s0 = m_per + 1
    if n <= s0:
        raise ValueError(f"n must exceed m_per + 1 = {s0}")
    # seed clique: every early vertex reachable, pool seeded with degree
    ii, jj = np.triu_indices(s0, k=1)
    edges = [np.stack([ii.astype(np.int64), jj.astype(np.int64)], 1)]
    pool = [np.concatenate([ii, jj]).astype(np.int32)]
    lo = s0
    while lo < n:
        hi = min(lo + min(chunk, max(64, lo)), n)
        flat_pool = np.concatenate(pool) if len(pool) > 1 else pool[0]
        pool = [flat_pool]
        new = np.repeat(np.arange(lo, hi, dtype=np.int64), m_per)
        tgt = flat_pool[rng.integers(0, len(flat_pool), size=len(new))]
        edges.append(np.stack([new, tgt.astype(np.int64)], 1))
        pool.append(np.concatenate([new.astype(np.int32),
                                    tgt.astype(np.int32)]))
        lo = hi
    return _finalize(n, np.concatenate(edges), rng, max_w)


def caveman_graph(n_communities: int, size: int, p_rewire: float = 0.05,
                  max_w: int = 4, seed: int = 0):
    """Connected-caveman — community structure regime."""
    rng = np.random.default_rng(seed)
    n = n_communities * size
    edges = []
    for ci in range(n_communities):
        base = ci * size
        for i in range(size):
            for j in range(i + 1, size):
                edges.append((base + i, base + j))
        edges.append((base + size - 1, (base + size) % n))  # ring link
    e = np.array(edges, np.int64)
    rw = rng.random(len(e)) < p_rewire
    e[rw, 1] = rng.integers(0, n, rw.sum())
    return _finalize(n, e, rng, max_w)


def unit_weights(n, src, dst, w):
    return n, src, dst, np.ones_like(w)


def largest_component_queries(n, src, dst, n_q, seed=0):
    """Sample query endpoints biased to the largest connected component
    (mirrors the paper's random 1000-query workloads)."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg
    rng = np.random.default_rng(seed)
    adj = sp.coo_matrix((np.ones(len(src)), (src, dst)), shape=(n, n))
    _, comp = csg.connected_components(adj, directed=False)
    counts = np.bincount(comp)
    big = np.flatnonzero(comp == counts.argmax())
    s = rng.choice(big, n_q)
    t = rng.choice(big, n_q)
    return s.astype(np.int32), t.astype(np.int32)
