"""Double-buffered host->device prefetch pipeline.

A worker thread keeps ``depth`` batches ahead of the training loop
(generation + device_put overlap with the device step). The pipeline is
seekable (``reset(step)``) for fault-tolerant replay.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

import jax


class PrefetchPipeline:
    def __init__(self, make_batch: Callable[[int], object], depth: int = 2,
                 device_put: bool = True, shardings=None):
        self.make_batch = make_batch
        self.depth = depth
        self.device_put = device_put
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._next_step = 0

    def _put(self, batch):
        if not self.device_put:
            return batch
        if self.shardings is not None:
            return jax.tree.map(jax.device_put, batch, self.shardings)
        return jax.tree.map(jax.device_put, batch)

    def _worker(self, start: int):
        step = start
        while not self._stop.is_set():
            try:
                b = self._put(self.make_batch(step))
            except Exception as e:
                self._q.put(("error", e))
                return
            self._q.put(("ok", (step, b)))
            step += 1

    def reset(self, step: int = 0):
        self.stop()
        self._stop = threading.Event()
        self._q = queue.Queue(maxsize=self.depth)
        self._next_step = step
        self._thread = threading.Thread(target=self._worker, args=(step,),
                                        daemon=True)
        self._thread.start()

    def __call__(self, step: int):
        """Fetch the batch for ``step`` (seek-aware)."""
        if self._thread is None or step != self._next_step:
            self.reset(step)
        kind, payload = self._q.get()
        if kind == "error":
            raise payload
        got_step, batch = payload
        assert got_step == step, (got_step, step)
        self._next_step = step + 1
        return batch

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5)
            self._thread = None
