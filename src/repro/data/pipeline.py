"""Host-side data pipelines.

Two halves:

* ``PrefetchPipeline`` — double-buffered host->device prefetch for the
  training loop (generation + device_put overlap with the device step;
  seekable via ``reset(step)`` for fault-tolerant replay).
* graph sources for index construction at 10^6–10^7 vertices
  (docs/CONSTRUCTION.md): a chunked SNAP-format edge-list loader for
  real graphs (``load_snap_edgelist``/``save_snap_edgelist``) and
  ``graph_from_spec``, the one-string front door the construction bench
  and launch tools use to name any generator or on-disk dataset.
"""
from __future__ import annotations

import queue
import threading
from pathlib import Path
from typing import Callable

import jax
import numpy as np


class PrefetchPipeline:
    def __init__(self, make_batch: Callable[[int], object], depth: int = 2,
                 device_put: bool = True, shardings=None):
        self.make_batch = make_batch
        self.depth = depth
        self.device_put = device_put
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._next_step = 0

    def _put(self, batch):
        if not self.device_put:
            return batch
        if self.shardings is not None:
            return jax.tree.map(jax.device_put, batch, self.shardings)
        return jax.tree.map(jax.device_put, batch)

    def _worker(self, start: int):
        step = start
        while not self._stop.is_set():
            try:
                b = self._put(self.make_batch(step))
            except Exception as e:
                self._q.put(("error", e))
                return
            self._q.put(("ok", (step, b)))
            step += 1

    def reset(self, step: int = 0):
        self.stop()
        self._stop = threading.Event()
        self._q = queue.Queue(maxsize=self.depth)
        self._next_step = step
        self._thread = threading.Thread(target=self._worker, args=(step,),
                                        daemon=True)
        self._thread.start()

    def __call__(self, step: int):
        """Fetch the batch for ``step`` (seek-aware)."""
        if self._thread is None or step != self._next_step:
            self.reset(step)
        kind, payload = self._q.get()
        if kind == "error":
            raise payload
        got_step, batch = payload
        assert got_step == step, (got_step, step)
        self._next_step = step + 1
        return batch

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5)
            self._thread = None


# ---------------------------------------------------------------------------
# Graph sources for million-vertex index construction (docs/CONSTRUCTION.md)


def load_snap_edgelist(path, max_w: int = 1, seed: int = 0,
                       chunk_lines: int = 2_000_000, relabel: bool = True):
    """Load a SNAP-format edge list: ``# comment`` header lines, then one
    ``u v`` (or ``u v w``) pair per line, whitespace-separated.

    The file is parsed in ``chunk_lines``-line blocks (a 10^7-edge file
    never materializes all its token strings at once); each block is
    canonicalized to (lo < hi) and deduped on arrival, mirroring the
    chunked generators. SNAP ids are sparse, so ``relabel`` compacts
    them to [0, n) (order-preserving). Files without a weight column get
    unit weights when ``max_w == 1``, else integer weights in
    [1, max_w] from ``seed`` — same convention as the generators.

    Returns ``(n, src, dst, w)`` with both edge directions.
    """
    from repro.graphs.generators import _finalize, _pack_pairs, _unpack_keys

    raw_max = 0
    cols = None
    key_chunks, weighted_edges = [], []
    with open(path) as fh:
        while True:
            lines = fh.readlines(chunk_lines * 16)   # ~16 bytes/line hint
            if not lines:
                break
            toks = " ".join(ln for ln in lines if not ln.startswith(("#", "%"))).split()
            if not toks:
                continue
            if cols is None:
                # column count from the first data line
                first = next(ln for ln in lines
                             if not ln.startswith(("#", "%")) and ln.strip())
                cols = len(first.split())
                if cols not in (2, 3):
                    raise ValueError(
                        f"SNAP edge list needs 2 or 3 columns, got {cols}")
            arr = np.array(toks, np.float64).reshape(-1, cols)
            uv = arr[:, :2].astype(np.int64)
            raw_max = max(raw_max, int(uv.max()) + 1 if len(uv) else 0)
            if cols == 3:
                weighted_edges.append((uv, arr[:, 2].astype(np.float32)))
            else:
                key_chunks.append(uv)
    if cols == 3:
        uv = np.concatenate([e for e, _ in weighted_edges])
        wt = np.concatenate([w for _, w in weighted_edges])
        u, v = uv[:, 0], uv[:, 1]
        if relabel:
            uniq, inv = np.unique(uv.reshape(-1), return_inverse=True)
            u, v = inv.reshape(-1, 2).T
            raw_max = len(uniq)
        keep = u != v
        lo = np.minimum(u[keep], v[keep]).astype(np.int64)
        hi = np.maximum(u[keep], v[keep]).astype(np.int64)
        # min weight per canonical pair (duplicate rows keep the cheapest)
        order = np.lexsort((wt[keep], lo * np.int64(raw_max) + hi))
        key = (lo * np.int64(raw_max) + hi)[order]
        first = np.concatenate([[True], key[1:] != key[:-1]])
        pairs = np.stack([key[first] // raw_max, key[first] % raw_max], 1)
        n = raw_max
        rng = np.random.default_rng(seed)
        return _finalize(n, pairs, rng, max_w, weights=wt[keep][order][first])
    keys = [_pack_pairs(raw_max, c[:, 0], c[:, 1]) for c in key_chunks]
    keys = np.unique(np.concatenate(keys)) if len(keys) > 1 else keys[0]
    pairs = _unpack_keys(raw_max, keys)
    n = raw_max
    if relabel:
        uniq, inv = np.unique(pairs.reshape(-1), return_inverse=True)
        pairs = inv.reshape(-1, 2)
        n = len(uniq)
    rng = np.random.default_rng(seed)
    weights = (np.ones(len(pairs), np.float32) if max_w <= 1
               else rng.integers(1, max_w + 1, size=len(pairs)).astype(np.float32))
    return _finalize(n, pairs, rng, max_w, weights=weights)


def save_snap_edgelist(path, n, src, dst, w=None, comment: str = ""):
    """Write the canonical (u < v) edges as a SNAP-format text file —
    the round-trip partner of ``load_snap_edgelist`` (u v [w] rows)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    keep = src < dst                       # one row per undirected edge
    rows = np.stack([src[keep], dst[keep]], 1)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as fh:
        fh.write(f"# {comment or 'repro graph'}\n# Nodes: {n} Edges: {keep.sum()}\n")
        if w is None:
            np.savetxt(fh, rows, fmt="%d")
        else:
            np.savetxt(fh, np.concatenate(
                [rows, np.asarray(w)[keep][:, None]], 1), fmt="%d %d %g")
    return path


def graph_from_spec(spec: str):
    """Build ``(n, src, dst, w)`` from a one-string spec.

    Formats: ``er:<n>[:avg_deg]``, ``rmat:<n_pow>[:avg_deg]``,
    ``pa:<n>[:m_per]``, ``grid:<side>``, ``snap:<path>`` — each with an
    optional trailing ``@seed`` (default 0).
    """
    from repro.graphs import generators as gen

    spec, _, seed_s = spec.partition("@")
    seed = int(seed_s) if seed_s else 0
    kind, *args = spec.split(":")
    if kind == "er":
        n = int(args[0])
        deg = float(args[1]) if len(args) > 1 else 3.0
        return gen.er_graph(n, deg, seed=seed)
    if kind == "rmat":
        p = int(args[0])
        deg = float(args[1]) if len(args) > 1 else 8.0
        return gen.rmat_graph(p, deg, seed=seed)
    if kind == "pa":
        n = int(args[0])
        m_per = int(args[1]) if len(args) > 1 else 2
        return gen.pa_graph(n, m_per, seed=seed)
    if kind == "grid":
        return gen.grid_graph(int(args[0]), seed=seed)
    if kind == "snap":
        return load_snap_edgelist(":".join(args), seed=seed)
    raise ValueError(f"unknown graph spec kind: {kind!r} (in {spec!r})")
