from repro.data.pipeline import PrefetchPipeline
from repro.data import synthetic
