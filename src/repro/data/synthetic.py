"""Deterministic, seekable synthetic data — the data substrate for
training runs and fault-injection tests.

Every generator is a pure function of (seed, step) so a rollback replays
or skips data windows deterministically (FaultTolerantRunner contract),
and each host can generate exactly its addressable shard.
"""
from __future__ import annotations

import numpy as np


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
             host_slice: slice | None = None):
    """Zipf-ish token stream with next-token targets."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    u = rng.random((batch, seq + 1))
    toks = np.minimum((u ** 2.5 * vocab).astype(np.int32), vocab - 1)
    if host_slice is not None:
        toks = toks[host_slice]
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def dien_batch(seed: int, step: int, batch: int, seq: int, n_items: int,
               n_cats: int, n_users: int):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    return {
        "user": rng.integers(0, n_users, batch).astype(np.int32),
        "hist_items": rng.integers(0, n_items, (batch, seq)).astype(np.int32),
        "hist_cats": rng.integers(0, n_cats, (batch, seq)).astype(np.int32),
        "hist_mask": (rng.random((batch, seq)) > 0.1).astype(np.float32),
        "target_item": rng.integers(0, n_items, batch).astype(np.int32),
        "target_cat": rng.integers(0, n_cats, batch).astype(np.int32),
        "label": rng.integers(0, 2, batch).astype(np.int32),
    }


def gnn_full_batch(seed: int, n: int, avg_deg: float, d_feat: int,
                   n_classes: int, n_pad: int, e_pad: int,
                   with_coords: bool = False):
    """Random sparse graph padded to fixed caps (sentinel = n)."""
    from repro.graphs import generators as gen
    n, src, dst, w = gen.er_graph(n, avg_deg=avg_deg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    e = len(src)
    assert e <= e_pad and n + 1 <= n_pad
    es = np.full(e_pad, n, np.int32)
    ed = np.full(e_pad, n, np.int32)
    es[:e], ed[:e] = src, dst
    deg = np.zeros(n_pad, np.float32)
    np.add.at(deg, es[:e], 1.0)
    feats = np.zeros((n_pad, d_feat), np.float32)
    feats[:n] = rng.standard_normal((n, d_feat)).astype(np.float32)
    labels = np.zeros(n_pad, np.int32)
    labels[:n] = rng.integers(0, n_classes, n)
    mask = np.zeros(n_pad, np.float32)
    mask[:n] = (rng.random(n) < 0.6)
    out = {"feats": feats, "edge_src": es, "edge_dst": ed, "deg": deg,
           "labels": labels, "mask": mask}
    if with_coords:
        coords = np.zeros((n_pad, 3), np.float32)
        coords[:n] = rng.standard_normal((n, 3)).astype(np.float32)
        out["coords"] = coords
    return out


def molecule_batch(seed: int, n_graphs: int, n_atoms: int, n_edges: int,
                   d_feat: int, n_pad: int, e_pad: int, t_cap: int = 0):
    """Batched random molecules flattened block-diagonally."""
    rng = np.random.default_rng(seed)
    n_tot = n_graphs * n_atoms
    feats = rng.standard_normal((n_pad, d_feat)).astype(np.float32)
    coords = rng.standard_normal((n_pad, 3)).astype(np.float32)
    es = np.full(e_pad, n_tot, np.int32)
    ed = np.full(e_pad, n_tot, np.int32)
    k = 0
    for g in range(n_graphs):
        base = g * n_atoms
        for _ in range(n_edges):
            a, b = rng.integers(0, n_atoms, 2)
            if a == b:
                continue
            es[k], ed[k] = base + a, base + b
            es[k + 1], ed[k + 1] = base + b, base + a
            k += 2
    graph_ids = np.full(n_pad, n_graphs, np.int32)
    for g in range(n_graphs):
        graph_ids[g * n_atoms:(g + 1) * n_atoms] = g
    deg = np.zeros(n_pad, np.float32)
    np.add.at(deg, es[:k], 1.0)
    targets = rng.standard_normal(n_graphs).astype(np.float32)
    out = {"feats": feats, "edge_src": es, "edge_dst": ed, "deg": deg,
           "graph_ids": graph_ids, "targets": targets, "coords": coords,
           "atom_z": np.minimum(np.abs(feats[:, 0] * 10).astype(np.int32), 94)}
    if t_cap:
        from repro.models.dimenet import build_triplets
        tkj, tji = build_triplets(es[:k], ed[:k], n_tot, t_cap)
        tkj = np.where(tkj == k, e_pad, tkj)
        tji = np.where(tji == k, e_pad, tji)
        out["trip_kj"], out["trip_ji"] = tkj, tji
    return out
