"""Index registry: one server process, many named graphs.

Each registered name owns a `DistanceServer` (its own lanes, cache,
metrics, and pre-warmed compiled shapes) over one `ISLabelIndex`; the
registry is just the name → server map plus aggregate stats, so a
multi-tenant front end routes on name and the per-graph engines stay
independent.
"""
from __future__ import annotations

from repro.serve.engine import DistanceServer


class IndexRegistry:
    def __init__(self):
        self._servers: dict[str, DistanceServer] = {}

    def register(self, name: str, index, **server_kwargs) -> DistanceServer:
        """Wrap ``index`` in a DistanceServer under ``name`` and return
        it. Replacing an existing holder of the name goes through the
        version-drain path (``install``) — never a silent swap that
        drops in-flight requests or leaks pinned versions."""
        server = DistanceServer(index, name=name, **server_kwargs)
        return self.install(name, server)

    def install(self, name: str, server: DistanceServer) -> DistanceServer:
        """Atomically publish ``server`` under ``name``. Any previous
        holder is drained first: its pending batches execute to
        completion (in-flight requests are answered, on their own
        versions) and its retired index versions are released. Only
        then does the name flip to the new server."""
        old = self._servers.get(name)
        if old is not None and old is not server:
            old.drain()
        self._servers[name] = server
        return server

    def unregister(self, name: str) -> None:
        self._servers.pop(name).drain()

    def get(self, name: str) -> DistanceServer:
        try:
            return self._servers[name]
        except KeyError:
            raise KeyError(
                f"no index named {name!r}; registered: {sorted(self._servers)}")

    def names(self) -> list[str]:
        return sorted(self._servers)

    def __len__(self) -> int:
        return len(self._servers)

    def __contains__(self, name: str) -> bool:
        return name in self._servers

    def stats(self) -> dict:
        return {name: srv.stats() for name, srv in self._servers.items()}
