"""Request queue + shape-bucket micro-batcher.

One ``MicroBatcher`` per routing lane. Requests accumulate in arrival
order; ``drain`` releases a batch when either

  * enough requests are pending to fill the largest bucket (throughput
    regime: always launch full, maximally-shaped batches), or
  * the oldest pending request has waited ``max_wait_s`` (latency
    regime: launch a partially-filled batch padded up to the smallest
    bucket that holds it, so tail latency is bounded under low load).

Buckets are the *only* shapes that ever reach the compiled query
functions — the serving layer pads every drained batch up to its bucket
— so after one warmup pass per bucket no XLA compile can happen on the
serving path.

The batcher is clock-driven (callers pass ``now``), which makes serving
runs deterministic and lets traces replay on a simulated clock; a
thread/asyncio front end only needs to call ``add``/``drain`` under its
own lock with wall-clock ``now``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class PendingRequest:
    """One enqueued query: request id + endpoints + arrival time."""
    rid: int
    s: int
    t: int
    t_arrival: float


@dataclasses.dataclass
class Batch:
    """A drained batch: the requests, the shape bucket they will be
    padded to, and the (possibly simulated) instant the flush fired."""
    requests: list
    bucket: int
    t_flush: float

    @property
    def fill(self) -> float:
        return len(self.requests) / self.bucket


class MicroBatcher:
    """Accumulates requests into fixed shape-bucket batches."""

    def __init__(self, buckets=(64, 256, 1024), max_wait_s: float = 0.002):
        buckets = tuple(sorted(int(b) for b in buckets))
        if not buckets or buckets[0] <= 0:
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self.buckets = buckets
        self.max_wait_s = float(max_wait_s)
        self._pending: list[PendingRequest] = []

    def __len__(self) -> int:
        return len(self._pending)

    def add(self, req: PendingRequest) -> None:
        self._pending.append(req)

    def next_deadline(self) -> float | None:
        """Instant at which the oldest pending request must flush."""
        if not self._pending:
            return None
        return self._pending[0].t_arrival + self.max_wait_s

    def _bucket_for(self, count: int) -> int:
        for b in self.buckets:
            if count <= b:
                return b
        return self.buckets[-1]

    def drain(self, now: float, force: bool = False) -> Batch | None:
        """Release the next ready batch, or None.

        Call in a loop until None — a deep queue can yield several
        largest-bucket batches per pump. ``force`` flushes whatever is
        pending (end of trace / shutdown).
        """
        p = len(self._pending)
        if p == 0:
            return None
        top = self.buckets[-1]
        if p >= top:
            reqs, self._pending = self._pending[:top], self._pending[top:]
            # the bucket filled the moment its last request arrived
            return Batch(reqs, top, max(now, reqs[-1].t_arrival))
        deadline = self._pending[0].t_arrival + self.max_wait_s
        if force or deadline <= now:
            reqs, self._pending = self._pending, []
            t_flush = now if force else deadline
            return Batch(reqs, self._bucket_for(p), t_flush)
        return None
