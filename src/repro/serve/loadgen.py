"""Scenario load generator: synthetic request traces for the serving
subsystem and its benchmarks.

A trace is (arrival_s, s, t) arrays sorted by arrival time; the server
replays it on a simulated clock (`DistanceServer.serve_trace`), so the
same trace is exactly reproducible across runs, backends, and saved /
loaded indexes.

Scenarios (endpoint distribution × arrival process):

  * ``uniform``  — endpoints uniform over V, Poisson arrivals. The
    paper's random-query evaluation regime (Table 4/5).
  * ``hotspot``  — endpoints Zipf-distributed over a random permutation
    of V (a small hot set receives most traffic), Poisson arrivals.
    Social/web traffic shape; exercises the result cache and skewed
    label rows.
  * ``bursty``   — uniform endpoints, arrivals in on/off bursts: a
    burst of B requests back-to-back, then an idle gap. Exercises both
    batcher regimes (full buckets inside a burst, deadline flushes at
    the gap edges).
  * ``repeated`` — requests drawn from a small fixed pool of (s, t)
    pairs, Poisson arrivals. Dashboard/monitoring shape; upper-bounds
    cache effectiveness.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Trace:
    name: str
    arrival_s: np.ndarray    # float64[R], sorted, seconds from 0
    s: np.ndarray            # int32[R]
    t: np.ndarray            # int32[R]
    meta: dict

    def __len__(self) -> int:
        return len(self.arrival_s)

    @property
    def span_s(self) -> float:
        return float(self.arrival_s[-1]) if len(self.arrival_s) else 0.0


def _poisson_arrivals(rng, num_requests: int, rate_qps: float) -> np.ndarray:
    gaps = rng.exponential(1.0 / rate_qps, num_requests)
    out = np.cumsum(gaps)
    return out - out[0]


def _zipf_endpoints(rng, n: int, size: int, alpha: float) -> np.ndarray:
    """Zipf ranks clipped to [1, n], mapped through a random permutation
    so the hot set is scattered over vertex ids."""
    ranks = np.minimum(rng.zipf(alpha, size), n) - 1
    perm = rng.permutation(n)
    return perm[ranks].astype(np.int32)


def uniform_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                  seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    return Trace(
        "uniform", _poisson_arrivals(rng, num_requests, rate_qps),
        rng.integers(0, n, num_requests).astype(np.int32),
        rng.integers(0, n, num_requests).astype(np.int32),
        {"n": n, "rate_qps": rate_qps, "seed": seed})


def hotspot_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                  seed: int = 0, alpha: float = 1.2) -> Trace:
    rng = np.random.default_rng(seed)
    return Trace(
        "hotspot", _poisson_arrivals(rng, num_requests, rate_qps),
        _zipf_endpoints(rng, n, num_requests, alpha),
        _zipf_endpoints(rng, n, num_requests, alpha),
        {"n": n, "rate_qps": rate_qps, "seed": seed, "alpha": alpha})


def bursty_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                 seed: int = 0, burst: int = 128,
                 duty_cycle: float = 0.1) -> Trace:
    """Bursts of ``burst`` requests at ``rate_qps / duty_cycle`` within
    the burst, separated by idle gaps so the long-run rate is
    ``rate_qps``."""
    rng = np.random.default_rng(seed)
    in_burst_gap = duty_cycle / rate_qps
    gaps = np.full(num_requests, in_burst_gap)
    # total idle budget spread over the interior gaps (the trace starts
    # at t=0, so there are n_bursts-1 of them — without the correction
    # the realized rate overshoots rate_qps by ~1/n_bursts)
    n_bursts = -(-num_requests // burst)
    idle_total = (burst / rate_qps) * (1.0 - duty_cycle) * n_bursts
    gaps[::burst] = idle_total / max(n_bursts - 1, 1)
    gaps[0] = 0.0
    return Trace(
        "bursty", np.cumsum(gaps),
        rng.integers(0, n, num_requests).astype(np.int32),
        rng.integers(0, n, num_requests).astype(np.int32),
        {"n": n, "rate_qps": rate_qps, "seed": seed, "burst": burst,
         "duty_cycle": duty_cycle})


def repeated_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                   seed: int = 0, pool: int = 256) -> Trace:
    rng = np.random.default_rng(seed)
    ps = rng.integers(0, n, pool).astype(np.int32)
    pt = rng.integers(0, n, pool).astype(np.int32)
    pick = rng.integers(0, pool, num_requests)
    return Trace(
        "repeated", _poisson_arrivals(rng, num_requests, rate_qps),
        ps[pick], pt[pick],
        {"n": n, "rate_qps": rate_qps, "seed": seed, "pool": pool})


SCENARIOS = {
    "uniform": uniform_trace,
    "hotspot": hotspot_trace,
    "bursty": bursty_trace,
    "repeated": repeated_trace,
}


def make_trace(scenario: str, n: int, num_requests: int, **kw) -> Trace:
    try:
        fn = SCENARIOS[scenario]
    except KeyError:
        raise ValueError(
            f"unknown scenario {scenario!r}; one of {sorted(SCENARIOS)}")
    return fn(n, num_requests, **kw)
