"""Scenario load generator: synthetic request traces for the serving
subsystem and its benchmarks.

A trace is (arrival_s, s, t) arrays sorted by arrival time; the server
replays it on a simulated clock (`DistanceServer.serve_trace`), so the
same trace is exactly reproducible across runs, backends, and saved /
loaded indexes.

Scenarios (endpoint distribution × arrival process):

  * ``uniform``  — endpoints uniform over V, Poisson arrivals. The
    paper's random-query evaluation regime (Table 4/5).
  * ``hotspot``  — endpoints Zipf-distributed over a random permutation
    of V (a small hot set receives most traffic), Poisson arrivals.
    Social/web traffic shape; exercises the result cache and skewed
    label rows.
  * ``bursty``   — uniform endpoints, arrivals in on/off bursts: a
    burst of B requests back-to-back, then an idle gap. Exercises both
    batcher regimes (full buckets inside a burst, deadline flushes at
    the gap edges).
  * ``repeated`` — requests drawn from a small fixed pool of (s, t)
    pairs, Poisson arrivals. Dashboard/monitoring shape; upper-bounds
    cache effectiveness.
  * ``straggler`` — uniform endpoints and arrivals, plus a
    failure-injection plan in ``meta["inject"]``: one replica of a
    ``ReplicaSet`` is given a synthetic per-batch stall
    (``DistanceServer.exec_delay_s``), so replaying the same trace with
    and without injection is the clean/degraded pair the SLO burn-rate
    alert tests and the CI http-serving smoke compare. Answers stay
    bitwise exact — only timing degrades.
  * ``readwrite`` — uniform reads with §8.3 mutation batches mixed in
    at ``write_ratio``: inserts draw a vertex from a spare pool and
    attach it to core vertices (initial core + live inserted — the
    rebuild-exact domain, docs/MUTATION.md), deletes remove a live
    inserted vertex back into the pool. Reads never target a dead
    spare, so every read is rebuild-auditable. Replayed with
    ``DistanceServer.serve_readwrite_trace`` on a versioned server.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.versions import MutationOp


@dataclasses.dataclass
class Trace:
    name: str
    arrival_s: np.ndarray    # float64[R], sorted, seconds from 0
    s: np.ndarray            # int32[R]
    t: np.ndarray            # int32[R]
    meta: dict
    # per-request mutation batch: None = read; a list of MutationOp
    # makes request i a write (s/t are placeholder zeros for writes)
    writes: list | None = None

    def __len__(self) -> int:
        return len(self.arrival_s)

    @property
    def span_s(self) -> float:
        return float(self.arrival_s[-1]) if len(self.arrival_s) else 0.0


def _poisson_arrivals(rng, num_requests: int, rate_qps: float) -> np.ndarray:
    gaps = rng.exponential(1.0 / rate_qps, num_requests)
    out = np.cumsum(gaps)
    return out - out[0]


def _zipf_endpoints(rng, n: int, size: int, alpha: float) -> np.ndarray:
    """Zipf ranks clipped to [1, n], mapped through a random permutation
    so the hot set is scattered over vertex ids."""
    ranks = np.minimum(rng.zipf(alpha, size), n) - 1
    perm = rng.permutation(n)
    return perm[ranks].astype(np.int32)


def uniform_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                  seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    return Trace(
        "uniform", _poisson_arrivals(rng, num_requests, rate_qps),
        rng.integers(0, n, num_requests).astype(np.int32),
        rng.integers(0, n, num_requests).astype(np.int32),
        {"n": n, "rate_qps": rate_qps, "seed": seed})


def hotspot_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                  seed: int = 0, alpha: float = 1.2) -> Trace:
    rng = np.random.default_rng(seed)
    return Trace(
        "hotspot", _poisson_arrivals(rng, num_requests, rate_qps),
        _zipf_endpoints(rng, n, num_requests, alpha),
        _zipf_endpoints(rng, n, num_requests, alpha),
        {"n": n, "rate_qps": rate_qps, "seed": seed, "alpha": alpha})


def bursty_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                 seed: int = 0, burst: int = 128,
                 duty_cycle: float = 0.1) -> Trace:
    """Bursts of ``burst`` requests at ``rate_qps / duty_cycle`` within
    the burst, separated by idle gaps so the long-run rate is
    ``rate_qps``."""
    rng = np.random.default_rng(seed)
    in_burst_gap = duty_cycle / rate_qps
    gaps = np.full(num_requests, in_burst_gap)
    # total idle budget spread over the interior gaps (the trace starts
    # at t=0, so there are n_bursts-1 of them — without the correction
    # the realized rate overshoots rate_qps by ~1/n_bursts)
    n_bursts = -(-num_requests // burst)
    idle_total = (burst / rate_qps) * (1.0 - duty_cycle) * n_bursts
    gaps[::burst] = idle_total / max(n_bursts - 1, 1)
    gaps[0] = 0.0
    return Trace(
        "bursty", np.cumsum(gaps),
        rng.integers(0, n, num_requests).astype(np.int32),
        rng.integers(0, n, num_requests).astype(np.int32),
        {"n": n, "rate_qps": rate_qps, "seed": seed, "burst": burst,
         "duty_cycle": duty_cycle})


def repeated_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                   seed: int = 0, pool: int = 256) -> Trace:
    rng = np.random.default_rng(seed)
    ps = rng.integers(0, n, pool).astype(np.int32)
    pt = rng.integers(0, n, pool).astype(np.int32)
    pick = rng.integers(0, pool, num_requests)
    return Trace(
        "repeated", _poisson_arrivals(rng, num_requests, rate_qps),
        ps[pick], pt[pick],
        {"n": n, "rate_qps": rate_qps, "seed": seed, "pool": pool})


def readwrite_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                    seed: int = 0, write_ratio: float = 0.05,
                    write_batch: int = 2, n_read: int | None = None,
                    spares=(), attach_to=(), max_deg: int = 3,
                    max_w: int = 8) -> Trace:
    """Reads mixed with §8.3 mutation batches (the serving-under-
    mutation scenario, docs/MUTATION.md).

    ``spares`` are preallocated vertex ids outside the read range that
    inserts draw from (and deletes return to); ``attach_to`` are the
    index's initial core ids. The generator mirrors the manager's
    strict domain: inserts attach only to attach_to + currently-live
    spares, deletes target only live spares, reads sample the
    ``n_read`` base vertices (always live) plus occasionally a live
    spare. Weights are integer-valued floats so float32 path sums stay
    exact and the rebuild audit can demand bitwise equality.
    """
    rng = np.random.default_rng(seed)
    spares = [int(u) for u in spares]
    attach = [int(c) for c in attach_to]
    if write_ratio > 0 and (not spares or not attach):
        raise ValueError("readwrite with write_ratio > 0 needs spare "
                         "vertex ids and core attach_to candidates")
    n_read = n if n_read is None else int(n_read)
    pool, live = list(spares), []
    arrivals = _poisson_arrivals(rng, num_requests, rate_qps)
    s = np.zeros(num_requests, np.int32)
    t = np.zeros(num_requests, np.int32)
    writes: list = [None] * num_requests
    n_writes = n_ins = n_del = 0

    def read_endpoint():
        if live and rng.random() < 0.15:
            return int(live[int(rng.integers(0, len(live)))])
        return int(rng.integers(0, n_read))

    for i in range(num_requests):
        if rng.random() < write_ratio and (pool or live):
            ops = []
            for _ in range(int(rng.integers(1, write_batch + 1))):
                if pool and (not live or rng.random() < 0.6):
                    u = pool.pop(int(rng.integers(0, len(pool))))
                    cands = attach + live
                    deg = int(rng.integers(1, min(max_deg, len(cands)) + 1))
                    picks = rng.choice(len(cands), size=deg, replace=False)
                    ops.append(MutationOp(
                        "insert", u,
                        tuple(int(cands[j]) for j in picks),
                        tuple(float(x)
                              for x in rng.integers(1, max_w + 1, deg))))
                    live.append(u)
                    n_ins += 1
                elif live:
                    u = live.pop(int(rng.integers(0, len(live))))
                    ops.append(MutationOp("delete", u))
                    pool.append(u)
                    n_del += 1
            writes[i] = ops
            n_writes += 1
        else:
            s[i] = read_endpoint()
            t[i] = read_endpoint()
    return Trace(
        "readwrite", arrivals, s, t,
        {"n": n, "rate_qps": rate_qps, "seed": seed,
         "write_ratio": write_ratio, "writes": n_writes,
         "inserts": n_ins, "deletes": n_del, "spares": len(spares)},
        writes=writes)


def straggler_trace(n: int, num_requests: int, rate_qps: float = 50_000.0,
                    seed: int = 0, stall_replica: int = 0,
                    stall_s: float = 5.0) -> Trace:
    """Uniform load with a straggler-injection plan: ``stall_replica``
    of the serving ``ReplicaSet`` gets ``stall_s`` of synthetic stall
    charged to every distance batch it executes
    (``ReplicaSet.apply_injection`` reads ``meta["inject"]``)."""
    base = uniform_trace(n, num_requests, rate_qps, seed)
    return Trace(
        "straggler", base.arrival_s, base.s, base.t,
        {**base.meta,
         "inject": {"replica": int(stall_replica),
                    "stall_s": float(stall_s)}})


SCENARIOS = {
    "uniform": uniform_trace,
    "hotspot": hotspot_trace,
    "bursty": bursty_trace,
    "repeated": repeated_trace,
    "readwrite": readwrite_trace,
    "straggler": straggler_trace,
}


def make_trace(scenario: str, n: int, num_requests: int, **kw) -> Trace:
    try:
        fn = SCENARIOS[scenario]
    except KeyError:
        raise ValueError(
            f"unknown scenario {scenario!r}; one of {sorted(SCENARIOS)}")
    return fn(n, num_requests, **kw)
