"""`DistanceServer` — the serving engine over one `ISLabelIndex`.

Pipeline (request → answer):

  submit ──► LRU cache probe ──hit──► answer (zero latency)
     │ miss
     ▼
  routing: μ-exact pairs → "mu" lane, everything else → "full" lane
     ▼
  per-lane MicroBatcher (shape buckets + max-wait deadline)
     ▼
  pump: drained batches padded to their bucket, run through the
  pre-warmed jitted entry points (QueryEngine.batch_fn / mu_batch_fn)
     ▼
  answers + metrics (+ cache fill)

Routing soundness. The full answer is ``min(μ, min_v DS[v] + DT[v])``
(Algorithm 1). We route a pair through the Equation-1-only fast path
only when the core term is *provably* +inf: at least one endpoint's
label contains no finite-distance core vertex, so its stage-2 seed
vector is all-inf and the core search cannot contribute. The paper's
§5.2 endpoint classification (`classify`) alone cannot certify this —
a Type-3 pair (neither endpoint in the core) may still meet in the
core — so `classify` feeds the served type-mix metric while the label
mask decides the lane. This keeps the serving guarantee bitwise: every
served answer equals ``ISLabelIndex.query`` exactly, whichever lane it
took. On indexes whose hierarchy consumed the whole graph
(n_core == 0) every request is μ-exact and the full lane stays idle.

Sharded lane. The server accepts a ``repro.shard.ShardedIndex``
wherever it accepts an ``ISLabelIndex``: the same pre-warmed per-bucket
entry points then run the shard_map query path (per-shard Equation 1 +
shard-local core search, one collective per batch; docs/SHARDING.md),
and every guarantee above — bitwise equality with the unsharded index,
μ-routing soundness, zero compiles after warmup — holds unchanged. A
registry can host sharded and unsharded graphs side by side.

The engine is clock-driven and deterministic: callers pass ``now``
(simulated or wall time) to ``submit``/``pump``. ``serve_trace`` replays
a loadgen trace on its own clock — queue waits come from the trace
timeline, execution times from the device. A thread or asyncio front
end owns its lock and calls the same three methods with wall time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.batcher import MicroBatcher, PendingRequest
from repro.serve.cache import LRUCache
from repro.serve.metrics import ServeMetrics

LANES = ("mu", "full")


def mu_exact_mask(index) -> np.ndarray:
    """bool[n+1]: vertex v's label has no finite-distance core entry.

    For such v, stage 2's seed vector is all +inf, so for any pair with
    ``mask[s] or mask[t]`` the core term is +inf and μ alone is the
    exact (bitwise-identical) answer.

    Accepts both label layouts: unsharded ``[n+1, l_cap]`` rows and a
    ``ShardedIndex``'s stacked ``[P, n+1, cap_s]`` partition blocks
    (core entries are replicated into every block, so reducing over the
    shard axis too yields the identical mask).
    """
    n, k = index.n, index.k
    lev_pad = jnp.asarray(np.append(index.level, k + 1).astype(np.int32))
    entry_core = ((index.lbl_ids < n)
                  & (lev_pad[jnp.minimum(index.lbl_ids, n)] == k)
                  & jnp.isfinite(index.lbl_d))
    axes = (0, 2) if entry_core.ndim == 3 else (1,)
    return ~np.asarray(jnp.any(entry_core, axis=axes))


class DistanceServer:
    """Micro-batching, routing, caching distance server for one index."""

    def __init__(self, index, *, name: str = "default",
                 buckets=(64, 256, 1024), max_wait_ms: float = 2.0,
                 cache_size: int = 65536, cache_symmetric: bool = False,
                 backend: str | None = None, warmup: bool = True):
        self.index = index
        self.name = name
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self.backend = backend
        self.metrics = ServeMetrics()
        self.cache = LRUCache(cache_size, symmetric=cache_symmetric)
        self.lanes = {lane: MicroBatcher(self.buckets, self.max_wait_s)
                      for lane in LANES}
        self._no_core_entry = mu_exact_mask(index)
        self._fns = {"mu": index.engine.mu_batch_fn(backend),
                     "full": index.engine.batch_fn(backend)}
        self._results: dict[int, float] = {}
        self._next_rid = 0
        self.warmup_seconds = 0.0
        if warmup:
            self.warmup()

    def refresh(self, warmup: bool = True) -> None:
        """Re-sync with the index after an in-place mutation (§8.3
        ``insert_vertex``/``delete_vertex``): drops every cached
        answer, recomputes the routing mask, and rebinds (and by
        default re-warms) the compiled entry points — the mutators
        install a fresh ``QueryEngine``."""
        self.cache.clear()
        self._no_core_entry = mu_exact_mask(self.index)
        self._fns = {"mu": self.index.engine.mu_batch_fn(self.backend),
                     "full": self.index.engine.batch_fn(self.backend)}
        if warmup:
            self.warmup()

    # ----------------------------------------------------------- warmup
    def warmup(self) -> dict:
        """Compile every (lane, bucket) entry point up front so no XLA
        compile happens on the serving path (asserted in tests via the
        jit cache sizes)."""
        t0 = time.perf_counter()
        timings = self.index.engine.warmup(self.buckets, self.backend)
        self.warmup_seconds = time.perf_counter() - t0
        return timings

    def compile_cache_sizes(self) -> dict:
        """Per-lane jit cache entry counts (one per compiled shape).

        The jitted entry points are memoized per (index engine,
        backend) and therefore *shared* by every server over the same
        index — another server's warmup can grow these counts. The
        zero-compile-on-the-serving-path guarantee is the delta: the
        counts do not change across any amount of serving (asserted in
        tests/test_serving.py). Counts are -1 when the running JAX
        stops exposing the (private) cache-size probe."""
        out = {}
        for lane, fn in self._fns.items():
            probe = getattr(fn, "_cache_size", None)
            out[lane] = int(probe()) if callable(probe) else -1
        return out

    # ---------------------------------------------------------- routing
    def route(self, s, t) -> np.ndarray:
        """Lane per pair: "mu" where Equation 1 is provably exact.

        Also tallies the paper's §5.2 endpoint classes (``classify``:
        1 = both core, 2 = one, 3 = neither) into the metrics — class 1
        pairs are never μ-eligible (each core endpoint holds itself as
        a core label entry), class 2/3 only when the mask proves the
        core term is +inf."""
        s = np.atleast_1d(np.asarray(s, np.int64))
        t = np.atleast_1d(np.asarray(t, np.int64))
        cls = self.index.engine.classify(s, t, self.index.level, self.index.k)
        self.metrics.record_types(cls)
        eligible = self._no_core_entry[s] | self._no_core_entry[t]
        return np.where(eligible, "mu", "full")

    # ------------------------------------------------------ request path
    def submit(self, s: int, t: int, now: float,
               lane: str | None = None) -> int:
        """Enqueue one query; returns its request id. Cache hits are
        answered immediately (the rid is already resolved)."""
        rid = self._next_rid
        self._next_rid += 1
        hit = self.cache.get(s, t)
        if hit is not None:
            self._results[rid] = hit
            self.metrics.record_cache_hit()
            return rid
        if lane is None:
            lane = str(self.route(s, t)[0])
        self.lanes[lane].add(PendingRequest(rid, int(s), int(t), float(now)))
        return rid

    def pump(self, now: float, force: bool = False) -> int:
        """Execute every batch that is ready at ``now`` (bucket filled,
        deadline expired, or ``force``). Returns requests completed."""
        done = 0
        for lane_name, lane in self.lanes.items():
            while (batch := lane.drain(now, force=force)) is not None:
                done += self._execute(lane_name, batch)
        return done

    def take_result(self, rid: int) -> float | None:
        return self._results.pop(rid, None)

    def _execute(self, lane: str, batch) -> int:
        reqs = batch.requests
        p = len(reqs)
        s = np.fromiter((r.s for r in reqs), np.int32, p)
        t = np.fromiter((r.t for r in reqs), np.int32, p)
        pad = batch.bucket - p                  # edge-pad: replays last req
        s_pad = jnp.asarray(np.pad(s, (0, pad), mode="edge"))
        t_pad = jnp.asarray(np.pad(t, (0, pad), mode="edge"))
        t0 = time.perf_counter()
        out = self._fns[lane](s_pad, t_pad)
        out = jax.block_until_ready(out)
        exec_s = time.perf_counter() - t0
        if lane == "full":
            ans, rounds = np.asarray(out[0]), int(out[1])
        else:
            ans, rounds = np.asarray(out), 0
        for i, r in enumerate(reqs):
            val = float(ans[i])
            self._results[r.rid] = val
            self.cache.put(r.s, r.t, val)
            # clamp: with sparse wall-clock pumps a request can arrive
            # after the oldest's deadline (the stamped flush instant)
            wait = max(0.0, batch.t_flush - r.t_arrival)
            self.metrics.record_latency(wait + exec_s)
        self.metrics.record_batch(lane, batch.bucket, p, exec_s, rounds)
        return p

    # ------------------------------------------------------ trace replay
    def serve_trace(self, trace) -> np.ndarray:
        """Replay a loadgen trace on its simulated clock. Returns
        float32 answers aligned with the trace; metrics accumulate on
        ``self.metrics``."""
        n_req = len(trace)
        lanes = self.route(trace.s, trace.t)
        rids = np.empty(n_req, np.int64)
        for i in range(n_req):
            now = float(trace.arrival_s[i])
            self.pump(now)
            rids[i] = self.submit(int(trace.s[i]), int(trace.t[i]), now,
                                  lane=str(lanes[i]))
            self.pump(now)
        self.pump(trace.span_s, force=True)
        self.metrics.trace_span_s += trace.span_s
        answers = np.empty(n_req, np.float32)
        for i in range(n_req):
            answers[i] = self._results.pop(int(rids[i]))
        return answers

    # ----------------------------------------------------------- status
    def stats(self) -> dict:
        return {
            "name": self.name,
            "graph": {"n": self.index.n, "k": self.index.k,
                      "n_core": int(self.index.stats.n_core),
                      "shards": int(getattr(self.index, "num_shards", 1))},
            "buckets": list(self.buckets),
            "max_wait_ms": self.max_wait_s * 1e3,
            "backend": self.backend or "auto",
            "warmup_seconds": self.warmup_seconds,
            "compiled_shapes": self.compile_cache_sizes(),
            **self.metrics.snapshot(),
        }
