"""`DistanceServer` — the serving engine over one `ISLabelIndex`.

Pipeline (request → answer):

  submit ──► LRU cache probe ──hit──► answer (zero latency)
     │ miss
     ▼
  routing: μ-exact pairs → "mu" lane, everything else → "full" lane
     ▼
  per-lane MicroBatcher (shape buckets + max-wait deadline)
     ▼
  pump: drained batches padded to their bucket, run through the
  pre-warmed jitted entry points (QueryEngine.batch_fn / mu_batch_fn)
     ▼
  answers + metrics (+ cache fill)

Routing soundness. The full answer is ``min(μ, min_v DS[v] + DT[v])``
(Algorithm 1). We route a pair through the Equation-1-only fast path
only when the core term is *provably* +inf: at least one endpoint's
label contains no finite-distance core vertex, so its stage-2 seed
vector is all-inf and the core search cannot contribute. The paper's
§5.2 endpoint classification (`classify`) alone cannot certify this —
a Type-3 pair (neither endpoint in the core) may still meet in the
core — so `classify` feeds the served type-mix metric while the label
mask decides the lane. This keeps the serving guarantee bitwise: every
served answer equals ``ISLabelIndex.query`` exactly, whichever lane it
took. On indexes whose hierarchy consumed the whole graph
(n_core == 0) every request is μ-exact and the full lane stays idle.

Sharded lane. The server accepts a ``repro.shard.ShardedIndex``
wherever it accepts an ``ISLabelIndex``: the same pre-warmed per-bucket
entry points then run the shard_map query path (per-shard Equation 1 +
shard-local core search, one collective per batch; docs/SHARDING.md),
and every guarantee above — bitwise equality with the unsharded index,
μ-routing soundness, zero compiles after warmup — holds unchanged. A
registry can host sharded and unsharded graphs side by side.

Path lane. Constructing with ``path_hop_caps=(h1, h2, ...)`` opens a
third request lane serving full shortest-*path* retrieval
(docs/PATHS.md): ``submit_path``/``serve_path_trace`` micro-batch into
the same shape buckets, run the pre-warmed ``PathEngine`` entry points
(jitted per (bucket, hop_cap) shape), and escalate through the hop_cap
tiers when a path overflows — falling back to the exact host oracle
(``index.shortest_path``) for the rare path longer than every tier.
Path answers are cached separately from distances (a path is a
strictly larger object with its own hit economics).

Mutation lane (versioned mode). Constructing with ``versioned=True``
routes the compiled entry points through a ``VersionFamily``
(docs/MUTATION.md): the jitted fns take the index state as a traced
pytree argument instead of closing over it, so ``submit_mutation``
applies a §8.3 insert/delete batch copy-on-write, hot-swaps the
published version between micro-batches, and the pre-warmed
executables survive — zero recompiles under concurrent read/write
traffic. Pending read batches are force-flushed before the swap (they
complete on the version current when they were submitted), the LRU
cache and routing mask are per-version (cleared/replaced on swap), and
old versions are refcount-drained before release. Versioned mode is
unsharded-distance-only: the path lane and ``ShardedIndex`` keep the
close-over-arrays entry points (mutate via
``ShardedIndex.apply_mutations`` + re-register).

The engine is clock-driven and deterministic: callers pass ``now``
(simulated or wall time) to ``submit``/``pump``. ``serve_trace`` replays
a loadgen trace on its own clock — queue waits come from the trace
timeline, execution times from the device. A thread or asyncio front
end owns its lock and calls the same three methods with wall time.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.profiler import compile_region
from repro.obs.registry import REGISTRY
from repro.obs.trace import NULL_TRACER
from repro.serve.batcher import MicroBatcher, PendingRequest
from repro.serve.cache import LRUCache
from repro.serve.metrics import ServeMetrics

LANES = ("mu", "full")
PATH_LANE = "path"


class PathAnswer(NamedTuple):
    """One served path request: exact distance, vertex list (empty when
    unreachable), and whether the path itself is trustworthy (False
    only if every hop_cap tier and the host fallback failed)."""
    dist: float
    path: tuple
    valid: bool


def mu_exact_mask(index) -> np.ndarray:
    """bool[n+1]: vertex v's label has no finite-distance core entry.

    For such v, stage 2's seed vector is all +inf, so for any pair with
    ``mask[s] or mask[t]`` the core term is +inf and μ alone is the
    exact (bitwise-identical) answer.

    Accepts both label layouts: unsharded ``[n+1, l_cap]`` rows and a
    ``ShardedIndex``'s stacked ``[P, n+1, cap_s]`` partition blocks
    (core entries are replicated into every block, so reducing over the
    shard axis too yields the identical mask).
    """
    n, k = index.n, index.k
    lev_pad = jnp.asarray(np.append(index.level, k + 1).astype(np.int32))
    entry_core = ((index.lbl_ids < n)
                  & (lev_pad[jnp.minimum(index.lbl_ids, n)] == k)
                  & jnp.isfinite(index.lbl_d))
    axes = (0, 2) if entry_core.ndim == 3 else (1,)
    return ~np.asarray(jnp.any(entry_core, axis=axes))


class DistanceServer:
    """Micro-batching, routing, caching distance server for one index."""

    def __init__(self, index, *, name: str = "default",
                 buckets=(64, 256, 1024), max_wait_ms: float = 2.0,
                 cache_size: int = 65536, cache_symmetric: bool = False,
                 backend: str | None = None, warmup: bool = True,
                 path_hop_caps=None, versioned: bool = False,
                 version_kwargs: dict | None = None,
                 tracer=None, registry=None):
        self.index = index
        self.name = name
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self.backend = backend
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.registry = registry if registry is not None else REGISTRY
        self.metrics = ServeMetrics(server=name, registry=self.registry)
        self.cache = LRUCache(cache_size, symmetric=cache_symmetric)
        self.lanes = {lane: MicroBatcher(self.buckets, self.max_wait_s)
                      for lane in LANES}
        self.versions = None
        if versioned:
            if path_hop_caps:
                raise ValueError(
                    "versioned serving does not cover the path lane; "
                    "serve paths from a non-versioned server")
            if hasattr(index, "num_shards"):
                raise ValueError(
                    "versioned serving is unsharded-only; mutate a "
                    "ShardedIndex via apply_mutations and re-register")
            from repro.serve.versions import VersionManager
            with compile_region("warmup"):
                self.versions = VersionManager.from_index(
                    index, **(version_kwargs or {}))
            self._no_core_entry = self.versions.current.mu_mask
            self._fns = {"mu": self.versions.family.mu_fn(backend),
                         "full": self.versions.family.full_fn(backend)}
        else:
            with compile_region("warmup"):
                self._no_core_entry = mu_exact_mask(index)
            self._fns = {"mu": index.engine.mu_batch_fn(backend),
                         "full": index.engine.batch_fn(backend)}
        self.path_hop_caps = (tuple(sorted(int(h) for h in path_hop_caps))
                              if path_hop_caps else ())
        self._path_fns = {}
        if self.path_hop_caps:
            # never symmetric: distances commute on undirected graphs
            # but a path vertex list is directional — a (t, s) hit
            # would serve the (s, t) list with reversed endpoints
            self.path_cache = LRUCache(cache_size, symmetric=False)
            self.lanes[PATH_LANE] = MicroBatcher(self.buckets,
                                                 self.max_wait_s)
            engine = index.path_engine()
            self._path_fns = {h: engine.path_batch_fn(h, backend)
                              for h in self.path_hop_caps}
        self._results: dict[int, object] = {}
        self._next_rid = 0
        self.warmup_seconds = 0.0
        # fault-injection hook (repro.serve.replicas): synthetic stall
        # added to every distance batch's charged execution time. Purely
        # accounting-side — no real sleep — so straggler scenarios stay
        # deterministic on the serving clock while latency metrics,
        # straggler monitors, and SLO burn rates all see the slowdown.
        self.exec_delay_s = 0.0
        if warmup:
            self.warmup()

    def refresh(self, warmup: bool = True) -> None:
        """Re-sync with the index after an in-place mutation (§8.3
        ``insert_vertex``/``delete_vertex``): drops every cached
        answer, recomputes the routing mask, and rebinds (and by
        default re-warms) the compiled entry points — the mutators
        install a fresh ``QueryEngine``."""
        if self.versions is not None:
            raise ValueError("versioned server: mutate through "
                             "submit_mutation(ops, now) instead")
        self.cache.clear()
        with compile_region("warmup"):
            self._no_core_entry = mu_exact_mask(self.index)
        self._fns = {"mu": self.index.engine.mu_batch_fn(self.backend),
                     "full": self.index.engine.batch_fn(self.backend)}
        if self.path_hop_caps:
            self.path_cache.clear()
            engine = self.index.path_engine()
            self._path_fns = {h: engine.path_batch_fn(h, self.backend)
                              for h in self.path_hop_caps}
        if warmup:
            self.warmup()

    # ----------------------------------------------------------- warmup
    def warmup(self) -> dict:
        """Compile every (lane, bucket) entry point up front so no XLA
        compile happens on the serving path (asserted in tests via the
        jit cache sizes). With a path lane, every (bucket, hop_cap)
        tier is pre-compiled too."""
        t0 = time.perf_counter()
        with compile_region("warmup"):
            if self.versions is not None:
                timings = self.versions.warmup(self.buckets, self.backend)
            else:
                timings = self.index.engine.warmup(self.buckets,
                                                   self.backend)
            if self.path_hop_caps:
                timings.update(self.index.path_engine().warmup(
                    self.buckets, self.path_hop_caps, self.backend))
        self.warmup_seconds = time.perf_counter() - t0
        return timings

    def compile_cache_sizes(self) -> dict:
        """Per-lane jit cache entry counts (one per compiled shape).

        The jitted entry points are memoized per (index engine,
        backend) and therefore *shared* by every server over the same
        index — another server's warmup can grow these counts. The
        zero-compile-on-the-serving-path guarantee is the delta: the
        counts do not change across any amount of serving (asserted in
        tests/test_serving.py). Counts are -1 when the running JAX
        stops exposing the (private) cache-size probe."""
        out = {}
        for lane, fn in self._fns.items():
            probe = getattr(fn, "_cache_size", None)
            out[lane] = int(probe()) if callable(probe) else -1
        for h, fn in self._path_fns.items():
            probe = getattr(fn, "_cache_size", None)
            out[f"path{h}"] = int(probe()) if callable(probe) else -1
        return out

    # ---------------------------------------------------------- routing
    def route(self, s, t) -> np.ndarray:
        """Lane per pair: "mu" where Equation 1 is provably exact.

        Also tallies the paper's §5.2 endpoint classes (``classify``:
        1 = both core, 2 = one, 3 = neither) into the metrics — class 1
        pairs are never μ-eligible (each core endpoint holds itself as
        a core label entry), class 2/3 only when the mask proves the
        core term is +inf."""
        s = np.atleast_1d(np.asarray(s, np.int64))
        t = np.atleast_1d(np.asarray(t, np.int64))
        cls = self.index.engine.classify(s, t, self.index.level, self.index.k)
        self.metrics.record_types(cls)
        eligible = self._no_core_entry[s] | self._no_core_entry[t]
        return np.where(eligible, "mu", "full")

    # ------------------------------------------------------ request path
    def submit(self, s: int, t: int, now: float,
               lane: str | None = None) -> int:
        """Enqueue one query; returns its request id. Cache hits are
        answered immediately (the rid is already resolved)."""
        rid = self._next_rid
        self._next_rid += 1
        hit = self.cache.get(s, t)
        if hit is not None:
            self._results[rid] = hit
            self.metrics.record_cache_hit()
            self.tracer.event("cache_hit", now, cat="request",
                              trace_id=rid, track="lane:cache",
                              s=int(s), t=int(t))
            return rid
        if lane is None:
            lane = str(self.route(s, t)[0])
        self.lanes[lane].add(PendingRequest(rid, int(s), int(t), float(now)))
        return rid

    def submit_path(self, s: int, t: int, now: float) -> int:
        """Enqueue one shortest-path request on the path lane (requires
        ``path_hop_caps``); returns its request id. The resolved value
        is a ``PathAnswer``. Cache hits resolve immediately."""
        if not self.path_hop_caps:
            raise ValueError("server built without path_hop_caps; "
                             "path lane is disabled")
        rid = self._next_rid
        self._next_rid += 1
        hit = self.path_cache.get(s, t)
        if hit is not None:
            self._results[rid] = hit
            self.metrics.record_cache_hit()
            self.tracer.event("cache_hit", now, cat="request",
                              trace_id=rid, track="lane:cache",
                              s=int(s), t=int(t), lane="path")
            return rid
        self.lanes[PATH_LANE].add(
            PendingRequest(rid, int(s), int(t), float(now)))
        return rid

    def pump(self, now: float, force: bool = False) -> int:
        """Execute every batch that is ready at ``now`` (bucket filled,
        deadline expired, or ``force``). Returns requests completed."""
        done = 0
        for lane_name, lane in self.lanes.items():
            while (batch := lane.drain(now, force=force)) is not None:
                if lane_name == PATH_LANE:
                    done += self._execute_path(batch)
                else:
                    done += self._execute(lane_name, batch)
        return done

    def take_result(self, rid: int):
        return self._results.pop(rid, None)

    @staticmethod
    def _batch_arrays(batch):
        """Shared batch prologue: endpoint arrays edge-padded up to the
        bucket shape (padding replays the last request, so escalation
        and routing decisions see only real endpoints)."""
        reqs = batch.requests
        p = len(reqs)
        s = np.fromiter((r.s for r in reqs), np.int32, p)
        t = np.fromiter((r.t for r in reqs), np.int32, p)
        pad = batch.bucket - p
        return (reqs, p, jnp.asarray(np.pad(s, (0, pad), mode="edge")),
                jnp.asarray(np.pad(t, (0, pad), mode="edge")))

    def _trace_batch(self, lane: str, batch, reqs, exec_s: float,
                     **exec_args) -> None:
        """Emit the request-lifecycle spans for one executed batch.
        Sits entirely outside the timed execution window, so tracing
        cost never lands in ``exec_s`` (and thus never in qps_compute).

        Timeline semantics (docs/OBSERVABILITY.md): queue waits live on
        the serving clock, the measured device execution is charged as
        an interval starting at the flush instant — so every request
        span's duration equals its recorded latency exactly, and its
        queue_wait + device_exec children cover all of it."""
        tr = self.tracer
        if not tr.enabled:
            return
        track = f"lane:{lane}"
        for r in reqs:
            flush = max(r.t_arrival, batch.t_flush)
            sp = tr.start("request", r.t_arrival, cat="request",
                          trace_id=r.rid, track=track, lane=lane,
                          s=r.s, t=r.t, bucket=batch.bucket)
            tr.add("queue_wait", r.t_arrival, flush, cat="wait",
                   trace_id=r.rid, parent=sp, track=track)
            tr.add("device_exec", flush, flush + exec_s, cat="exec",
                   trace_id=r.rid, parent=sp, track=track, **exec_args)
            tr.end(sp, flush + exec_s)

    def _execute(self, lane: str, batch) -> int:
        reqs, p, s_pad, t_pad = self._batch_arrays(batch)
        version = None if self.versions is None else self.versions.acquire()
        with compile_region("serve_read"):
            t0 = time.perf_counter()
            if version is not None:
                out = self._fns[lane](version.state, s_pad, t_pad)
            else:
                out = self._fns[lane](s_pad, t_pad)
            out = jax.block_until_ready(out)
            exec_s = time.perf_counter() - t0 + self.exec_delay_s
        if version is not None:
            self.versions.release(version)
        if lane == "full":
            ans, rounds = np.asarray(out[0]), int(out[1])
        else:
            ans, rounds = np.asarray(out), 0
        for i, r in enumerate(reqs):
            val = float(ans[i])
            self._results[r.rid] = val
            self.cache.put(r.s, r.t, val)
            # clamp: with sparse wall-clock pumps a request can arrive
            # after the oldest's deadline (the stamped flush instant)
            wait = max(0.0, batch.t_flush - r.t_arrival)
            self.metrics.record_latency(wait + exec_s)
        self.metrics.record_batch(lane, batch.bucket, p, exec_s, rounds)
        self._trace_batch(lane, batch, reqs, exec_s, rounds=rounds,
                          vid=None if version is None else version.vid)
        return p

    def _execute_path(self, batch) -> int:
        """Run one path-lane batch: lowest hop_cap tier first, escalate
        to the next pre-warmed tier while any path overflows, host
        oracle for anything longer than every tier. Note the fallback
        is a metered slow path: for a ShardedIndex it runs the batched
        engine at unwarmed scalar shapes and may therefore compile —
        the zero-compile guarantee covers the pre-warmed tiers, and the
        fallback's full cost (compiles included) is charged to the
        batch's execution time below."""
        reqs, p, s_pad, t_pad = self._batch_arrays(batch)
        tr = self.tracer
        exec_s, out = 0.0, None
        for hop_cap in self.path_hop_caps:
            with compile_region("serve_path"):
                t0 = time.perf_counter()
                out = jax.block_until_ready(
                    self._path_fns[hop_cap](s_pad, t_pad))
                tier_s = time.perf_counter() - t0
            tr.add(f"tier:h{hop_cap}", batch.t_flush + exec_s,
                   batch.t_flush + exec_s + tier_s, cat="batch",
                   track="lane:path", hop_cap=hop_cap, bucket=batch.bucket)
            exec_s += tier_s
            if bool(np.asarray(out.ok)[:p].all()):
                break
            self.metrics.record_path_overflow()
            tr.event("escalate", batch.t_flush + exec_s, cat="batch",
                     track="lane:path", hop_cap=hop_cap)
        dist = np.asarray(out.dist)
        verts = np.asarray(out.verts)
        lens = np.asarray(out.lens)
        ok = np.asarray(out.ok)
        answers = {}
        n_fallback = 0
        t0 = time.perf_counter()
        for i, r in enumerate(reqs):
            if ok[i]:
                answers[i] = PathAnswer(
                    float(dist[i]), tuple(verts[i, :lens[i]].tolist()), True)
            else:
                # longer than every warmed tier: exact host oracle. A
                # finite distance with an empty path means even the
                # oracle's escalation ceiling was hit (sharded fallback)
                # — never report that as a trustworthy path.
                n_fallback += 1
                d_host, path = self.index.shortest_path(r.s, r.t)
                answers[i] = PathAnswer(
                    float(d_host), tuple(path),
                    bool(path) or not np.isfinite(d_host))
        # the fallback is part of what this batch cost the server —
        # charge it to the batch's execution time, not to nobody
        host_s = time.perf_counter() - t0
        if n_fallback:
            tr.add("host_fallback", batch.t_flush + exec_s,
                   batch.t_flush + exec_s + host_s, cat="batch",
                   track="lane:path", requests=n_fallback)
        exec_s += host_s
        for i, r in enumerate(reqs):
            self._results[r.rid] = answers[i]
            self.path_cache.put(r.s, r.t, answers[i])
            wait = max(0.0, batch.t_flush - r.t_arrival)
            self.metrics.record_latency(wait + exec_s)
        self.metrics.record_batch(PATH_LANE, batch.bucket, p, exec_s,
                                  int(out.rounds))
        self._trace_batch(PATH_LANE, batch, reqs, exec_s,
                          rounds=int(out.rounds))
        return p

    # ----------------------------------------------------- mutation lane
    def submit_mutation(self, ops, now: float):
        """Apply a §8.3 insert/delete batch between micro-batches.

        Pending read batches are force-flushed first, so every already-
        submitted request completes on the version that was current at
        its submit time (hot-swap atomicity). Then the batch applies
        copy-on-write, the new version publishes atomically, the
        per-version caches (LRU answers, routing mask, the host oracle
        the audits read via ``self.index``) move to the new version, and
        the old version is retired — dropped now if no reader pins it,
        else when the last in-flight ``release`` lands. The compiled
        entry points are untouched: same family, same shapes, zero
        recompiles. Returns the new ``IndexVersion``."""
        if self.versions is None:
            raise ValueError("server not versioned: pass versioned=True "
                             "(or use ISLabelIndex.insert_vertex + "
                             "refresh() and eat the recompiles)")
        tr = self.tracer
        t0 = time.perf_counter()
        self.pump(now, force=True)
        flush_s = time.perf_counter() - t0
        old = self.versions.current
        with compile_region("mutation"):
            version = self.versions.apply(ops)
        t1 = time.perf_counter()
        self.index = version.index
        self._no_core_entry = version.mu_mask
        self.cache.clear()
        self.versions.retire(old)
        retire_s = time.perf_counter() - t1
        self.metrics.record_mutation(len(ops), version.swap_seconds)
        if tr.enabled:
            # mutation-lane spans on the serving clock: wall-clock stage
            # durations laid out end to end from the submit instant
            msp = tr.start("mutation", now, cat="mutation",
                           track="lane:mutation", trace_id=version.vid,
                           ops=len(ops), vid=version.vid)
            cursor = now
            stages = [("flush_pending", flush_s)]
            stages += [(k, version.stage_seconds.get(k, 0.0))
                       for k in ("cow_apply", "device_update", "publish")]
            stages.append(("retire", retire_s))
            for sname, dur in stages:
                tr.add(sname, cursor, cursor + dur, cat="mutation",
                       trace_id=version.vid, parent=msp,
                       track="lane:mutation")
                cursor += dur
            tr.end(msp, cursor)
        return version

    def drain(self, now: float | None = None) -> int:
        """Flush every pending batch and retire all non-current
        versions. Returns requests completed; raises if a retired
        version is still pinned (a reader leaked an ``acquire``)."""
        done = self.pump(float("inf") if now is None else now, force=True)
        if self.versions is not None:
            leftover = self.versions.drain()
            if leftover:
                raise RuntimeError(
                    f"versions {leftover} still pinned after drain")
        return done

    def serve_readwrite_trace(self, trace):
        """Replay a ``readwrite`` loadgen trace: reads micro-batch as
        usual, write rows apply through ``submit_mutation`` on the
        trace clock. Returns ``(answers float32[R], vids int64[R])`` —
        NaN answers on write rows, and per-row the version id the
        request was served under (write rows report the version they
        published), so a differential audit can replay every read
        against the exact snapshot that answered it."""
        if self.versions is None:
            raise ValueError("serve_readwrite_trace needs versioned=True")
        if trace.writes is None:
            raise ValueError("trace has no writes; use serve_trace")
        n_req = len(trace)
        rids = np.full(n_req, -1, np.int64)
        vids = np.zeros(n_req, np.int64)
        for i in range(n_req):
            now = float(trace.arrival_s[i])
            self.pump(now)
            if trace.writes[i] is not None:
                vids[i] = self.submit_mutation(trace.writes[i], now).vid
            else:
                vids[i] = self.versions.current.vid
                rids[i] = self.submit(int(trace.s[i]), int(trace.t[i]), now)
            self.pump(now)
        self.pump(trace.span_s, force=True)
        self.metrics.trace_span_s += trace.span_s
        answers = np.full(n_req, np.nan, np.float32)
        for i in range(n_req):
            if rids[i] >= 0:
                answers[i] = self._results.pop(int(rids[i]))
        return answers, vids

    # ------------------------------------------------------ trace replay
    def _replay(self, trace, submit_fn) -> np.ndarray:
        """Shared replay loop: drive the batcher on the trace's
        simulated clock, submitting each request via ``submit_fn(i, s,
        t, now)``. Returns the request ids."""
        n_req = len(trace)
        rids = np.empty(n_req, np.int64)
        for i in range(n_req):
            now = float(trace.arrival_s[i])
            self.pump(now)
            rids[i] = submit_fn(i, int(trace.s[i]), int(trace.t[i]), now)
            self.pump(now)
        self.pump(trace.span_s, force=True)
        self.metrics.trace_span_s += trace.span_s
        return rids

    def serve_trace(self, trace) -> np.ndarray:
        """Replay a loadgen trace on its simulated clock. Returns
        float32 answers aligned with the trace; metrics accumulate on
        ``self.metrics``."""
        lanes = self.route(trace.s, trace.t)
        rids = self._replay(
            trace, lambda i, s, t, now: self.submit(s, t, now,
                                                    lane=str(lanes[i])))
        answers = np.empty(len(trace), np.float32)
        for i in range(len(trace)):
            answers[i] = self._results.pop(int(rids[i]))
        return answers

    def serve_path_trace(self, trace):
        """Replay a loadgen trace as shortest-*path* requests. Returns
        ``(dist float32[R], paths list of vertex lists, valid bool[R])``
        aligned with the trace; metrics accumulate under the "path"
        lane."""
        rids = self._replay(
            trace, lambda i, s, t, now: self.submit_path(s, t, now))
        n_req = len(trace)
        dist = np.empty(n_req, np.float32)
        paths, valid = [], np.empty(n_req, bool)
        for i in range(n_req):
            ans = self._results.pop(int(rids[i]))
            dist[i] = ans.dist
            paths.append(list(ans.path))
            valid[i] = ans.valid
        return dist, paths, valid

    # ----------------------------------------------------------- status
    def stats(self) -> dict:
        return {
            "name": self.name,
            "graph": {"n": self.index.n, "k": self.index.k,
                      "n_core": int(self.index.stats.n_core),
                      "shards": int(getattr(self.index, "num_shards", 1))},
            "buckets": list(self.buckets),
            "path_hop_caps": list(self.path_hop_caps),
            "max_wait_ms": self.max_wait_s * 1e3,
            "backend": self.backend or "auto",
            "warmup_seconds": self.warmup_seconds,
            "compiled_shapes": self.compile_cache_sizes(),
            "versions": (None if self.versions is None else {
                "current": self.versions.current.vid,
                "live": self.versions.live_versions(),
                "core_cap": self.versions.family.core_cap,
                "edge_cap": self.versions.family.edge_cap,
            }),
            # process-wide registry sections: fault-tolerance counters
            # (repro.fault reports straggler/retry stats here — satellite
            # visibility through the serving surface) and the compile /
            # memory observability gauges (docs/OBSERVABILITY.md)
            "fault": self.registry.section("fault.") or None,
            "obs": self.registry.section("obs.") or None,
            **self.metrics.snapshot(),
        }
