"""Asyncio HTTP front end over the serving stack (docs/SERVICE.md).

Stdlib-only (``asyncio.start_server`` + hand-rolled HTTP/1.1 framing —
no web framework dependency): one event-loop thread owns every
``DistanceServer``/``ReplicaSet`` in the ``IndexRegistry``, so the
engines need no locks, and concurrent HTTP requests micro-batch exactly
like in-process callers — submit on arrival, a periodic pump task
flushes shape buckets on their deadlines.

Lanes / endpoints:

  ``POST /query``   {"s", "t"} or {"pairs": [[s, t], ...]} (+"graph")
                    → {"answers": [...], "vid": ...}. Distances ride the
                    same μ-routed micro-batch path as in-process
                    serving; float32 answers round-trip JSON bitwise
                    (float32→float64 is exact, ``repr`` round-trips,
                    ``Infinity`` is legal in Python's JSON).
  ``POST /path``    {"s", "t"} → {"dist", "path", "valid"} via the
                    shortest-path lane (requires ``path_hop_caps``).
  ``POST /mutate``  {"ops": [{"kind", "u", "nbrs", "ws"}, ...]} →
                    {"vid"}: a §8.3 write batch through the versioned
                    COW lane; pending reads force-flush first, so a
                    sequential client observes the identical version
                    sequence as ``serve_readwrite_trace``.
  ``GET /stats``    aggregate + per-graph stats JSON (plus SLO state).
  ``GET /metrics``  Prometheus text exposition of the whole registry.
  ``GET /events``   Server-Sent Events: periodic ``metrics`` frames
                    (servers changed), live ``slo_alert`` events relayed
                    from the ``EventLog``, comment heartbeats when idle.
  ``GET /healthz``  liveness probe.

Observability: every request lands in ``http.requests`` (route/code)
and ``http.request_seconds``; an attached ``SLOEngine`` is stepped on
the pump cadence with the wall clock (its availability source reads the
``http.*`` counters, its latency source the ``serve.*`` histograms), so
burn-rate alerts fire while the service runs and stream out over
``/events``.
"""
from __future__ import annotations

import asyncio
import http.client
import json
import threading
import time

import numpy as np

from repro.obs.registry import REGISTRY
from repro.serve.versions import MutationOp

__all__ = ["ServiceFrontend", "HttpClient", "replay_http"]

_JSON_HDR = "application/json"
_SSE_HDR = "text/event-stream"
_PROM_HDR = "text/plain; version=0.0.4"


class _HttpError(Exception):
    def __init__(self, code: int, message: str):
        super().__init__(message)
        self.code = code


class ServiceFrontend:
    """One process-wide HTTP front end over an ``IndexRegistry``.

    The loop thread is the sole owner of every registered server: HTTP
    handlers submit/await, the pump task flushes batch deadlines and
    steps the SLO engine. ``start_background()`` runs the loop in a
    daemon thread and returns the bound ``(host, port)`` — the test and
    ``launch/serve.py --mode http`` entry point.
    """

    def __init__(self, registry, *, slo=None, log=None, metrics=None,
                 host: str = "127.0.0.1", port: int = 0,
                 pump_interval_s: float = 0.002,
                 slo_interval_s: float = 0.05,
                 sse_interval_s: float = 0.2,
                 heartbeat_s: float = 2.0):
        self.index_registry = registry
        self.slo = slo
        self.log = log
        self.metrics_registry = metrics if metrics is not None else REGISTRY
        self.host = host
        self.port = int(port)
        self.pump_interval_s = float(pump_interval_s)
        self.slo_interval_s = float(slo_interval_s)
        self.sse_interval_s = float(sse_interval_s)
        self.heartbeat_s = float(heartbeat_s)
        self._t0 = time.monotonic()
        self._server = None
        self._loop = None
        self._thread = None
        self._pump_task = None
        self._waiters: dict = {}        # (graph, rid) -> Future
        self._next_slo = 0.0
        r = self.metrics_registry
        self._req_c = r.counter("http.requests",
                                "front-end requests by route and status")
        self._req_h = r.histogram("http.request_seconds",
                                  "front-end request wall time")
        self._sse_g = r.gauge("http.sse_clients",
                              "connected /events streams")

    # ------------------------------------------------------------ clock
    def _now(self) -> float:
        """Serving clock: wall seconds since front-end start (matches
        the trace-replay convention of a clock starting at 0)."""
        return time.monotonic() - self._t0

    # ------------------------------------------------------- lifecycle
    async def start(self):
        """Bind and start serving on the current event loop."""
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._pump_task = asyncio.ensure_future(self._pump_loop())
        if self.log is not None:
            self.log.log("frontend_start", ts=self._now(),
                         host=self.host, port=self.port,
                         graphs=self.index_registry.names())
        return self

    async def stop_async(self):
        if self._pump_task is not None:
            self._pump_task.cancel()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        for srv in self._servers():
            srv.drain(self._now())
        self._deliver()

    def start_background(self):
        """Run the loop in a daemon thread; returns ``(host, port)``."""
        started = threading.Event()

        def run():
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)
            self._loop = loop
            loop.run_until_complete(self.start())
            started.set()
            loop.run_forever()
            loop.run_until_complete(self.stop_async())
            # cancel lingering keep-alive connection handlers before
            # the loop closes (they wait forever on the next request)
            pending = asyncio.all_tasks(loop)
            for t in pending:
                t.cancel()
            loop.run_until_complete(
                asyncio.gather(*pending, return_exceptions=True))
            loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="frontend")
        self._thread.start()
        if not started.wait(timeout=60):
            raise RuntimeError("front end failed to start")
        return self.host, self.port

    def stop(self):
        """Stop a ``start_background`` front end and join its thread."""
        if self._loop is not None and self._thread is not None:
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(timeout=30)
            self._thread = None

    # ------------------------------------------------------- pump task
    def _servers(self):
        return [self.index_registry.get(n)
                for n in self.index_registry.names()]

    def _deliver(self) -> None:
        """Resolve waiter futures whose results have landed."""
        done = []
        for key, fut in self._waiters.items():
            val = self.index_registry.get(key[0]).take_result(key[1])
            if val is not None:
                if not fut.done():
                    fut.set_result(val)
                done.append(key)
        for key in done:
            del self._waiters[key]

    async def _pump_loop(self):
        while True:
            now = self._now()
            for srv in self._servers():
                srv.pump(now)
            if self._waiters:
                self._deliver()
            if self.slo is not None and now >= self._next_slo:
                self.slo.step(now)
                self._next_slo = now + self.slo_interval_s
            await asyncio.sleep(self.pump_interval_s)

    async def _await_result(self, graph: str, srv, rid: int):
        """Wait for one submitted request (immediate on cache hits)."""
        val = srv.take_result(rid)
        if val is not None:
            return val
        fut = self._loop.create_future()
        self._waiters[(graph, rid)] = fut
        return await fut

    # ---------------------------------------------------- HTTP framing
    async def _handle_conn(self, reader, writer):
        try:
            while True:
                req = await self._read_request(reader)
                if req is None:
                    break
                method, target, body = req
                keep = await self._dispatch(method, target, body, writer)
                if not keep:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass

    @staticmethod
    async def _read_request(reader):
        line = await reader.readline()
        if not line or line in (b"\r\n", b"\n"):
            return None
        try:
            method, target, _ = line.decode("latin-1").split(None, 2)
        except ValueError:
            return None
        length = 0
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, value = h.decode("latin-1").partition(":")
            if name.strip().lower() == "content-length":
                length = int(value.strip())
        body = await reader.readexactly(length) if length else b""
        return method.upper(), target, body

    @staticmethod
    def _write_response(writer, code: int, content_type: str,
                        payload: bytes, extra: str = "") -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  500: "Internal Server Error"}.get(code, "OK")
        head = (f"HTTP/1.1 {code} {reason}\r\n"
                f"Content-Type: {content_type}\r\n"
                f"Content-Length: {len(payload)}\r\n"
                f"{extra}Connection: keep-alive\r\n\r\n")
        writer.write(head.encode("latin-1") + payload)

    async def _dispatch(self, method, target, body, writer) -> bool:
        """Route one request; returns False to drop the connection
        (only the SSE stream, which owns it until the client leaves)."""
        path, _, query = target.partition("?")
        route = f"{method} {path}"
        t_start = time.monotonic()
        code = 200
        try:
            if route == "GET /events":
                await self._serve_sse(writer)
                return False
            payload, ctype = await self._route(method, path, query, body)
            self._write_response(writer, 200, ctype, payload)
        except _HttpError as e:
            code = e.code
            self._write_response(
                writer, e.code, _JSON_HDR,
                json.dumps({"error": str(e)}).encode())
        except Exception as e:           # noqa: BLE001 — 500, keep serving
            code = 500
            self._write_response(
                writer, 500, _JSON_HDR,
                json.dumps({"error": f"{type(e).__name__}: {e}"}).encode())
        await writer.drain()
        self._req_c.inc(1, route=path, code=str(code))
        self._req_h.observe(time.monotonic() - t_start, route=path)
        if self.slo is not None and "availability" in self.slo.specs:
            ok = code < 500
            self.slo.record("availability", self._now(),
                            good=int(ok), bad=int(not ok))
        return True

    async def _route(self, method, path, query, body):
        if method == "GET" and path == "/healthz":
            return self._json({"ok": True, "uptime_s": self._now()})
        if method == "GET" and path == "/stats":
            return self._json(self._stats())
        if method == "GET" and path == "/metrics":
            text = self.metrics_registry.render_prometheus()
            return text.encode(), _PROM_HDR
        if method == "POST" and path == "/query":
            return self._json(await self._query(self._body(body)))
        if method == "POST" and path == "/path":
            return self._json(await self._path(self._body(body)))
        if method == "POST" and path == "/mutate":
            return self._json(self._mutate(self._body(body)))
        raise _HttpError(404, f"no route {method} {path}")

    @staticmethod
    def _json(obj):
        return json.dumps(obj).encode(), _JSON_HDR

    @staticmethod
    def _body(raw: bytes) -> dict:
        if not raw:
            return {}
        try:
            out = json.loads(raw)
        except json.JSONDecodeError as e:
            raise _HttpError(400, f"bad JSON body: {e}")
        if not isinstance(out, dict):
            raise _HttpError(400, "body must be a JSON object")
        return out

    def _graph(self, body: dict):
        name = str(body.get("graph", "default"))
        try:
            return name, self.index_registry.get(name)
        except KeyError as e:
            raise _HttpError(404, str(e))

    # ------------------------------------------------------- endpoints
    async def _query(self, body: dict) -> dict:
        name, srv = self._graph(body)
        if "pairs" in body:
            pairs = [(int(s), int(t)) for s, t in body["pairs"]]
        elif "s" in body and "t" in body:
            pairs = [(int(body["s"]), int(body["t"]))]
        else:
            raise _HttpError(400, 'need "s"/"t" or "pairs"')
        now = self._now()
        vid = None if srv.versions is None else srv.versions.current.vid
        rids = [srv.submit(s, t, now) for s, t in pairs]
        srv.pump(self._now())
        answers = [float(np.float32(await self._await_result(name, srv, r)))
                   for r in rids]
        out = {"answers": answers}
        if vid is not None:
            out["vid"] = int(vid)
        return out

    async def _path(self, body: dict) -> dict:
        name, srv = self._graph(body)
        if "s" not in body or "t" not in body:
            raise _HttpError(400, 'need "s" and "t"')
        if not getattr(srv, "path_hop_caps", ()):
            raise _HttpError(400, f"graph {name!r} serves no path lane "
                                  "(built without path_hop_caps)")
        rid = srv.submit_path(int(body["s"]), int(body["t"]), self._now())
        srv.pump(self._now())
        ans = await self._await_result(name, srv, rid)
        return {"dist": float(np.float32(ans.dist)),
                "path": [int(v) for v in ans.path],
                "valid": bool(ans.valid)}

    def _mutate(self, body: dict) -> dict:
        name, srv = self._graph(body)
        if srv.versions is None:
            raise _HttpError(400, f"graph {name!r} is not versioned; "
                                  "register with versioned=True")
        try:
            ops = [MutationOp(str(o["kind"]), int(o["u"]),
                              tuple(int(v) for v in o.get("nbrs", ())),
                              tuple(float(w) for w in o.get("ws", ())))
                   for o in body.get("ops", [])]
        except (KeyError, TypeError, ValueError) as e:
            raise _HttpError(400, f"bad mutation ops: {e}")
        if not ops:
            raise _HttpError(400, 'need non-empty "ops"')
        version = srv.submit_mutation(ops, self._now())
        self._deliver()        # the force-flush completed pending reads
        return {"vid": int(version.vid), "ops": len(ops)}

    def _stats(self) -> dict:
        out = {"uptime_s": self._now(),
               "graphs": self.index_registry.stats()}
        if self.slo is not None:
            out["slo"] = self.slo.snapshot()
            out["slo_breaches"] = self.slo.breach_summary()
        return out

    # ------------------------------------------------------------- SSE
    def _metrics_frame(self) -> dict:
        frame = {"ts": round(self._now(), 6), "graphs": {}}
        for gname in self.index_registry.names():
            srv = self.index_registry.get(gname)
            m = srv.metrics
            frame["graphs"][gname] = {
                "served": m.served,
                "cache_hits": m.cache_hits,
                "batches": len(m.batches),
            }
        if self.slo is not None:
            frame["slo"] = self.slo.snapshot()
        return frame

    async def _serve_sse(self, writer):
        """Stream metric frames + SLO alerts until the client leaves.

        Framing (one block per message, blank-line terminated):
        ``event: metrics`` / ``event: slo_alert`` + one ``data:`` JSON
        line; ``: heartbeat`` comment lines keep idle connections alive
        (and are how a consumer distinguishes a quiet healthy server
        from a dead one).
        """
        writer.write((
            "HTTP/1.1 200 OK\r\n"
            f"Content-Type: {_SSE_HDR}\r\n"
            "Cache-Control: no-cache\r\nConnection: keep-alive\r\n\r\n"
        ).encode("latin-1"))
        await writer.drain()
        self._sse_g.inc(1)
        last_seq = -1
        if self.log is not None and self.log.recent:
            last_seq = self.log.recent[-1]["seq"]
        last_frame = None
        last_sent = time.monotonic()
        try:
            while True:
                sent = False
                if self.log is not None:
                    for ev in self.log.recent:
                        if (ev["seq"] > last_seq
                                and ev["kind"] == "slo_alert"):
                            writer.write(_sse_block("slo_alert", ev))
                            sent = True
                    if self.log.recent:
                        last_seq = self.log.recent[-1]["seq"]
                frame = self._metrics_frame()
                comparable = {k: v for k, v in frame.items() if k != "ts"}
                if comparable != last_frame:
                    writer.write(_sse_block("metrics", frame))
                    last_frame = comparable
                    sent = True
                if sent:
                    last_sent = time.monotonic()
                elif time.monotonic() - last_sent >= self.heartbeat_s:
                    writer.write(b": heartbeat\n\n")
                    last_sent = time.monotonic()
                await writer.drain()
                await asyncio.sleep(self.sse_interval_s)
        finally:
            self._sse_g.inc(-1)


def _sse_block(event: str, data: dict) -> bytes:
    return (f"event: {event}\ndata: {json.dumps(data)}\n\n").encode()


# ------------------------------------------------------------------ client
class HttpClient:
    """Minimal blocking client for the front end (stdlib http.client,
    one keep-alive connection). The loadgen replay path: sequential
    requests, so a versioned server observes the identical
    submit/mutate order — and therefore the identical version
    assignment — as the in-process ``serve_readwrite_trace``."""

    def __init__(self, host: str, port: int, graph: str = "default",
                 timeout_s: float = 60.0):
        self.graph = graph
        self._conn = http.client.HTTPConnection(host, port,
                                                timeout=timeout_s)

    def close(self):
        self._conn.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _call(self, method: str, path: str, body=None):
        payload = None if body is None else json.dumps(body).encode()
        headers = {"Content-Type": _JSON_HDR} if payload else {}
        self._conn.request(method, path, body=payload, headers=headers)
        resp = self._conn.getresponse()
        raw = resp.read()
        if resp.status != 200:
            raise RuntimeError(f"{method} {path} -> {resp.status}: "
                               f"{raw[:200].decode(errors='replace')}")
        ctype = resp.getheader("Content-Type", "")
        return raw.decode() if "json" not in ctype else json.loads(raw)

    def query(self, s: int, t: int):
        out = self._call("POST", "/query",
                         {"graph": self.graph, "s": int(s), "t": int(t)})
        return np.float32(out["answers"][0]), out.get("vid")

    def query_batch(self, pairs) -> np.ndarray:
        out = self._call("POST", "/query",
                         {"graph": self.graph,
                          "pairs": [[int(s), int(t)] for s, t in pairs]})
        return np.asarray(out["answers"], np.float32)

    def path(self, s: int, t: int) -> dict:
        return self._call("POST", "/path",
                          {"graph": self.graph, "s": int(s), "t": int(t)})

    def mutate(self, ops) -> int:
        body = {"graph": self.graph,
                "ops": [{"kind": op.kind, "u": int(op.u),
                         "nbrs": [int(v) for v in op.nbrs],
                         "ws": [float(w) for w in op.ws]}
                        for op in ops]}
        return int(self._call("POST", "/mutate", body)["vid"])

    def stats(self) -> dict:
        return self._call("GET", "/stats")

    def metrics_text(self) -> str:
        return self._call("GET", "/metrics")

    def healthz(self) -> dict:
        return self._call("GET", "/healthz")


def replay_http(client: HttpClient, trace, batch: int = 0):
    """Replay a loadgen trace over the wire.

    Read-only traces go as ``/query`` calls (single pair, or ``batch``
    pairs per request when > 0); a ``readwrite`` trace interleaves
    ``/mutate`` for write rows — strictly sequentially, which pins the
    version sequence to the in-process replay's. Returns ``answers``
    (float32, NaN on write rows) or ``(answers, vids)`` when the trace
    carries writes, shaped exactly like ``serve_readwrite_trace`` so
    the caller can diff the two bitwise.
    """
    n_req = len(trace)
    answers = np.full(n_req, np.nan, np.float32)
    if trace.writes is not None:
        vids = np.zeros(n_req, np.int64)
        for i in range(n_req):
            if trace.writes[i] is not None:
                vids[i] = client.mutate(trace.writes[i])
            else:
                answers[i], vid = client.query(int(trace.s[i]),
                                               int(trace.t[i]))
                vids[i] = -1 if vid is None else vid
        return answers, vids
    if batch > 1:
        for lo in range(0, n_req, batch):
            hi = min(lo + batch, n_req)
            answers[lo:hi] = client.query_batch(
                list(zip(trace.s[lo:hi].tolist(),
                         trace.t[lo:hi].tolist())))
    else:
        for i in range(n_req):
            answers[i], _ = client.query(int(trace.s[i]), int(trace.t[i]))
    return answers


class SSEReader:
    """Blocking reader over a ``/events`` stream (tests + CI smoke
    artifact capture): collects parsed ``(event, data_or_None)`` tuples
    — heartbeats appear as ``("comment", None)`` — until closed."""

    def __init__(self, host: str, port: int, timeout_s: float = 30.0):
        self._conn = http.client.HTTPConnection(host, port,
                                                timeout=timeout_s)
        self._conn.request("GET", "/events")
        self._resp = self._conn.getresponse()
        if self._resp.status != 200:
            raise RuntimeError(f"/events -> {self._resp.status}")

    def read_events(self, max_events: int = 16,
                    max_s: float = 10.0) -> list:
        out = []
        deadline = time.monotonic() + max_s
        event, data = None, []
        while len(out) < max_events and time.monotonic() < deadline:
            try:
                line = self._resp.fp.readline()
            except (TimeoutError, OSError):
                break
            if not line:
                break
            line = line.decode().rstrip("\n").rstrip("\r")
            if line.startswith(":"):
                out.append(("comment", None))
            elif line.startswith("event:"):
                event = line[6:].strip()
            elif line.startswith("data:"):
                data.append(line[5:].strip())
            elif line == "" and (event or data):
                out.append((event or "message",
                            json.loads("\n".join(data)) if data else None))
                event, data = None, []
        return out

    def close(self):
        self._conn.close()
