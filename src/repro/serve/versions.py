"""Versioned copy-on-write label blocks for live mutation under traffic.

The serving stack (docs/SERVING.md) compiles its entry points against a
fixed index: ``QueryEngine.batch_fn`` closes over the device label
arrays, so swapping in a mutated index means new closures and therefore
new XLA compiles on the read path — exactly what the
zero-compiles-after-warmup discipline forbids. This module inverts the
binding: the mutable state becomes a *traced argument*.

``VersionFamily`` fixes, once, every shape the query computation touches

  * ``core_cap``  — core-vertex slots (initial core + insert headroom),
  * ``edge_cap``  — COO core-edge slots (padded with ∞-weight sentinel
    edges between sentinel slots: min-plus no-ops),
  * ``ell_width``/``vp`` — the pinned ELL layout for the kernel path
    (``ell_layout`` widths are data-dependent, so the family asserts
    the post-mutation width still fits),

and jits ``run(state, s, t)`` entry points over a ``VersionState``
pytree. Every version of the index is a new pytree with identical
treedef/shapes/dtypes, so a hot swap is a pointer change — the compiled
executables survive untouched. Unused capacity is inert by min-plus
algebra: empty core slots hold +inf seeds (never the argmin), sentinel
edges add +inf (never relax anything).

§8.3 mutations are applied copy-on-write through the shared host
mutators in ``repro.core.index`` (``apply_insert_host`` /
``apply_delete_host``): ``LabelBlockStore`` keeps the [n+1, l_cap]
label planes as immutable row blocks; a mutation materializes writable
copies, and ``commit`` shares every block the touched rows missed.
Device propagation is an incremental row scatter, not a re-upload.

``VersionManager`` strings this together: ``apply(ops)`` produces a new
immutable ``IndexVersion`` (monotonic vid, cloned host oracle for
audits, fresh state pytree, committed store) and atomically republishes
``current``; readers pin versions with ``acquire``/``release`` so a
retired version is only dropped once its last in-flight batch drains.

Exactness domain (validated by tests/test_mutation_diff.py): in strict
mode the manager admits *core-attached* inserts (every neighbor at
level k — initial core vertices or live inserted ones) and deletes of
previously-inserted vertices. Within that domain every served distance
is bitwise equal to a from-scratch rebuild; see docs/MUTATION.md for
why arbitrary attachments are lazily-correct but not rebuild-identical.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import (FUSED_VMEM_BUDGET, _core_relax_ell,
                                 _core_relax_fused, core_relax,
                                 label_intersect_rows_dispatch)
from repro.core.index import (ISLabelIndex, apply_delete_host,
                              apply_insert_host)
from repro.core.labels import (LabelCompressionError, LabelRows,
                               decode_rows, encode_labels)
from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.spmv_relax.kernel import fused_vmem_bytes
from repro.kernels.spmv_relax.ops import ell_layout

__all__ = [
    "MutationOp", "VersionState", "VersionFamily", "FamilyCapacityError",
    "LabelBlockStore", "IndexVersion", "VersionManager",
]


class FamilyCapacityError(RuntimeError):
    """A mutation outgrew the family's fixed shapes — the serving
    process must rebuild a wider family (recompiles) to admit it."""


class MutationOp(NamedTuple):
    """One §8.3 mutation. kind ∈ {"insert", "delete"}; nbrs/ws describe
    the inserted vertex's edges (ignored for deletes)."""
    kind: str
    u: int
    nbrs: tuple = ()
    ws: tuple = ()


class VersionState(NamedTuple):
    """The traced-argument pytree a jitted family entry point consumes.

    All leaves are device arrays with family-fixed shapes:
      lbl_ids/lbl_d   [n+1, l_cap]      label planes — in a compressed
                      family these hold the *encoded* planes (int16
                      deltas / int32 distances, core/labels.py)
      lbl_base        [n+1]             delta16 row bases; None in an
                      uncompressed family (a None leaf is an empty
                      pytree subtree, so the treedef stays fixed per
                      family and COW swaps never recompile)
      core_slot       [n+1]             vertex -> core slot (core_cap = none)
      ce_src/ce_dst   [edge_cap]        COO slot edges, sentinel-padded
      ce_w            [edge_cap]        weights, +inf padding
      nbr_ids/nbr_w   [vp, ell_width]   pinned ELL planes (kernel path)
    """
    lbl_ids: jnp.ndarray
    lbl_d: jnp.ndarray
    core_slot: jnp.ndarray
    ce_src: jnp.ndarray
    ce_dst: jnp.ndarray
    ce_w: jnp.ndarray
    nbr_ids: jnp.ndarray
    nbr_w: jnp.ndarray
    lbl_base: jnp.ndarray | None = None


class VersionFamily:
    """Fixed-shape compiled query family shared by all versions.

    ``mu_fn``/``full_fn`` mirror ``QueryEngine.mu_batch_fn``/``batch_fn``
    (same kernels, same two stages of Algorithm 1) but take the
    ``VersionState`` as an argument instead of closing over it. One
    compile per (entry point, backend, batch shape) for the lifetime of
    the family, regardless of how many versions flow through.
    """

    def __init__(self, n: int, core_cap: int, edge_cap: int,
                 ell_width: int, *, bq: int = 8, bv: int = 128,
                 codec: str = "none", d_dtype: str | None = None):
        if core_cap < 1:
            raise ValueError("core_cap must be >= 1")
        self.n = n
        self.core_cap = core_cap
        self.edge_cap = edge_cap
        self.ell_width = ell_width
        self.bq = bq
        self.bv = bv
        self.vp = -(-(core_cap + 1) // bv) * bv
        self.max_rounds = core_cap          # while_loop exits at fixpoint
        # label codec pin: every version of the family must encode the
        # same way or the state dtypes (and the compiled fns) would move
        self.codec = codec
        self.d_dtype = d_dtype
        # fused single-launch relaxation unless the family's pinned ELL
        # working set exceeds the VMEM budget (then per-round launches)
        self.relax_mode = ("fused" if fused_vmem_bytes(
            self.vp, ell_width, bq) <= FUSED_VMEM_BUDGET else "ell_loop")
        self._mu_fns: dict = {}
        self._full_fns: dict = {}

    def _rows(self, state: VersionState, idx) -> LabelRows:
        if self.codec == "none":
            return LabelRows(state.lbl_ids[idx], None, state.lbl_d[idx])
        return LabelRows(state.lbl_ids[idx], state.lbl_base[idx],
                         state.lbl_d[idx])

    # ------------------------------------------------------- entry points
    def mu_fn(self, backend: str | None = None):
        """Jitted ``run(state, s, t) -> mu float32[Q]`` (Equation 1)."""
        backend = resolve_backend(backend)
        if backend not in self._mu_fns:
            n, codec = self.n, self.codec

            def run(state, s, t):
                return label_intersect_rows_dispatch(
                    self._rows(state, s), self._rows(state, t), n, codec,
                    backend)

            self._mu_fns[backend] = jax.jit(run)
        return self._mu_fns[backend]

    def full_fn(self, backend: str | None = None):
        """Jitted ``run(state, s, t) -> (ans float32[Q], rounds int32)``
        — both stages of Algorithm 1 over the family shapes."""
        backend = resolve_backend(backend)
        if backend not in self._full_fns:
            n, cap, codec = self.n, self.core_cap, self.codec
            max_rounds, bq, bv = self.max_rounds, self.bq, self.bv
            interp = False if backend == "reference" \
                else pallas_interpret(backend)

            def seed(state, ids, d):
                q = ids.shape[0]
                slot = state.core_slot[jnp.minimum(ids, n)]
                out = jnp.full((q, cap + 1), jnp.inf, jnp.float32)
                ridx = jnp.broadcast_to(jnp.arange(q)[:, None], slot.shape)
                return out.at[ridx, slot].min(
                    jnp.where(ids < n, d, jnp.inf))

            def run(state, s, t):
                rows_s = self._rows(state, s)
                rows_t = self._rows(state, t)
                mu = label_intersect_rows_dispatch(rows_s, rows_t, n,
                                                   codec, backend)
                ids_s, d_s = decode_rows(rows_s, n, codec)
                ids_t, d_t = decode_rows(rows_t, n, codec)
                seed_s = seed(state, ids_s, d_s)
                seed_t = seed(state, ids_t, d_t)
                if backend == "reference":
                    ans, _, _, rounds = core_relax(
                        seed_s, seed_t, state.ce_src, state.ce_dst,
                        state.ce_w, mu, cap, max_rounds)
                elif self.relax_mode == "fused":
                    ans, _, _, rounds = _core_relax_fused(
                        seed_s, seed_t, state.nbr_ids, state.nbr_w, mu,
                        cap, max_rounds, interp, bq)
                else:
                    ans, _, _, rounds = _core_relax_ell(
                        seed_s, seed_t, state.nbr_ids, state.nbr_w, mu,
                        cap, max_rounds, interp, bq, bv)
                return ans, rounds

            self._full_fns[backend] = jax.jit(run)
        return self._full_fns[backend]

    def cache_sizes(self, backend: str | None = None) -> dict:
        """Compiled-shape counts per entry point (the zero-recompile
        probe: serving must never grow these after warmup)."""
        backend = resolve_backend(backend)
        out = {}
        for name, fns in (("mu", self._mu_fns), ("full", self._full_fns)):
            fn = fns.get(backend)
            out[name] = int(fn._cache_size()) if fn is not None else 0
        return out

    # ---------------------------------------------------------- state build
    def build_ell(self, src_slots, dst_slots, w):
        """Scatter real slot-edges into the family's pinned ELL planes.

        ``ell_layout`` picks a data-dependent width; the family asserts
        it still fits ``ell_width`` so kernel-path shapes never move.
        """
        dst_slots = np.asarray(dst_slots, np.int64)
        order, rows, slots, width = ell_layout(self.core_cap + 1, dst_slots)
        if width > self.ell_width:
            raise FamilyCapacityError(
                f"core in-degree needs ELL width {width} > family "
                f"{self.ell_width}; rebuild with more ell_headroom")
        ids = np.zeros((self.vp, self.ell_width), np.int32)
        ws = np.full((self.vp, self.ell_width), np.inf, np.float32)
        if len(dst_slots):
            ids[rows, slots] = np.asarray(src_slots, np.int32)[order]
            ws[rows, slots] = np.asarray(w, np.float32)[order]
        return jnp.asarray(ids), jnp.asarray(ws)

    def pad_coo(self, src_slots, dst_slots, w):
        """COO slot-edges padded to ``edge_cap`` with sentinel->sentinel
        +inf edges (scatter-min no-ops on the parked column)."""
        m = len(src_slots)
        if m > self.edge_cap:
            raise FamilyCapacityError(
                f"{m} core edges exceed family edge_cap {self.edge_cap}; "
                f"rebuild with more edge_headroom")
        ce_src = np.full(self.edge_cap, self.core_cap, np.int32)
        ce_dst = np.full(self.edge_cap, self.core_cap, np.int32)
        ce_w = np.full(self.edge_cap, np.inf, np.float32)
        ce_src[:m] = np.asarray(src_slots, np.int32)
        ce_dst[:m] = np.asarray(dst_slots, np.int32)
        ce_w[:m] = np.asarray(w, np.float32)
        return ce_src, ce_dst, ce_w


class LabelBlockStore:
    """Immutable blocked view of the [n+1, l_cap] label planes.

    ``writable()`` materializes full writable copies for the host
    mutators; ``commit(rows)`` builds the successor store, re-slicing
    only the blocks containing touched rows and *sharing* every other
    block object with this store (copy-on-write at block granularity).
    """

    def __init__(self, blocks: list, n_rows: int, block_rows: int):
        self._blocks = blocks        # [(ids, d, pred)] read-only np arrays
        self.n_rows = n_rows
        self.block_rows = block_rows

    @staticmethod
    def from_arrays(ids, d, pred, block_rows: int = 256) -> "LabelBlockStore":
        ids = np.asarray(ids)
        d = np.asarray(d)
        pred = np.asarray(pred)
        n_rows = ids.shape[0]
        blocks = []
        for lo in range(0, n_rows, block_rows):
            hi = min(lo + block_rows, n_rows)
            blk = (ids[lo:hi].copy(), d[lo:hi].copy(), pred[lo:hi].copy())
            for a in blk:
                a.setflags(write=False)
            blocks.append(blk)
        return LabelBlockStore(blocks, n_rows, block_rows)

    @property
    def num_blocks(self) -> int:
        return len(self._blocks)

    def arrays(self):
        """Read-only concatenated (ids, d, pred) planes."""
        ids = np.concatenate([b[0] for b in self._blocks])
        d = np.concatenate([b[1] for b in self._blocks])
        pred = np.concatenate([b[2] for b in self._blocks])
        return ids, d, pred

    def writable(self):
        """Fresh writable full copies for the host mutators."""
        ids, d, pred = self.arrays()
        return ids.copy(), d.copy(), pred.copy()

    def commit(self, ids_h, d_h, pred_h, rows) -> "LabelBlockStore":
        """Successor store: dirty blocks re-sliced from the mutated host
        arrays, clean blocks shared by reference."""
        dirty = {int(r) // self.block_rows for r in np.asarray(rows).ravel()}
        blocks = []
        for i, blk in enumerate(self._blocks):
            if i in dirty:
                lo = i * self.block_rows
                hi = min(lo + self.block_rows, self.n_rows)
                nb = (ids_h[lo:hi].copy(), d_h[lo:hi].copy(),
                      pred_h[lo:hi].copy())
                for a in nb:
                    a.setflags(write=False)
                blocks.append(nb)
            else:
                blocks.append(blk)
        return LabelBlockStore(blocks, self.n_rows, self.block_rows)

    def shared_blocks(self, other: "LabelBlockStore") -> int:
        """How many block objects two stores share (COW accounting)."""
        mine = {id(b[0]) for b in self._blocks}
        return sum(1 for b in other._blocks if id(b[0]) in mine)


@dataclasses.dataclass
class IndexVersion:
    """One immutable snapshot: the state pytree the compiled family
    consumes, the COW store it came from, and a cloned ``ISLabelIndex``
    whose host oracle answers audit queries for exactly this version."""
    vid: int
    index: ISLabelIndex
    state: VersionState
    store: LabelBlockStore
    mu_mask: np.ndarray          # bool[n]: Type-1-safe endpoints
    touched_rows: np.ndarray     # rows rewritten vs the parent version
    swap_seconds: float = 0.0
    # per-stage wall time of the apply that produced this version
    # (cow_apply / device_update / publish) — the mutation-lane trace
    # spans (docs/OBSERVABILITY.md) are cut from these
    stage_seconds: dict = dataclasses.field(default_factory=dict)

    @property
    def n_core(self) -> int:
        return len(self.index.core_ids)


def _clone_index(index: ISLabelIndex) -> ISLabelIndex:
    """Snapshot clone sharing immutable arrays. ``level`` is the one
    array the host mutators write in place, so it is copied; the core
    COO arrays are rebound (concatenate/filter), never mutated. The
    replace() resets the lazy caches (init=False fields)."""
    clone = dataclasses.replace(index)
    clone.level = index.level.copy()
    return clone


class VersionManager:
    """Monotonic version chain with refcounted drain-before-release.

    Single-writer: ``apply`` runs on the serving thread between
    micro-batches. ``current`` republishes atomically (one reference
    assignment); readers ``acquire()`` the version they execute against
    and ``release()`` it after the batch completes, so ``retire``-ing an
    old version only drops it once no in-flight batch pins it.
    """

    def __init__(self, family: VersionFamily, v0: IndexVersion, *,
                 strict: bool = True):
        self.family = family
        self.strict = strict
        self.current = v0
        self._versions = {v0.vid: v0}
        self._refs = {v0.vid: 0}
        self._retired: set = set()
        self._next_vid = v0.vid + 1
        self._core_slot = None       # int32[n+1], set by from_index
        self._next_slot = 0
        self._inserted_live: set = set()

    # ------------------------------------------------------------- build
    @staticmethod
    def from_index(index: ISLabelIndex, *, core_headroom: int = 64,
                   edge_headroom: int = 512, ell_headroom: int = 32,
                   block_rows: int = 256,
                   strict: bool = True) -> "VersionManager":
        from repro.serve.engine import mu_exact_mask
        n_core0 = len(index.core_ids)
        if n_core0 == 0:
            raise ValueError("versioned serving needs a non-empty core: "
                             "strict-mode inserts attach to core vertices")
        core_cap = n_core0 + core_headroom
        edge_cap = len(index.core_src) + edge_headroom
        slot = np.full(index.n + 1, core_cap, np.int32)
        slot[index.core_ids] = np.arange(n_core0, dtype=np.int32)
        _, _, _, base_w = ell_layout(core_cap + 1, slot[index.core_dst])
        ell_width = -(-(base_w + ell_headroom) // 16) * 16
        # the family pins the index's label codec: compressed versions
        # flow through COW swaps with the same state dtypes/treedef
        eng = index.engine
        codec = eng.codec
        d_dtype = None
        if codec != "none":
            d_dtype = ("int32" if eng.enc_d.dtype == jnp.int32
                       else "float32")
        family = VersionFamily(index.n, core_cap, edge_cap, ell_width,
                               codec=codec, d_dtype=d_dtype)
        store = LabelBlockStore.from_arrays(
            np.asarray(index.lbl_ids), np.asarray(index.lbl_d),
            np.asarray(index.lbl_pred), block_rows=block_rows)
        mgr = VersionManager(family, IndexVersion(
            vid=0, index=index, state=None, store=store,
            mu_mask=mu_exact_mask(index),
            touched_rows=np.zeros(0, np.int64)), strict=strict)
        mgr._core_slot = slot
        mgr._next_slot = n_core0
        mgr.current.state = mgr._build_state(
            eng.enc_ids, eng.enc_d, index, slot, lbl_base=eng.enc_base)
        return mgr

    def _build_state(self, lbl_ids_dev, lbl_d_dev, index, slot,
                     lbl_base=None) -> VersionState:
        src_slots = slot[index.core_src]
        dst_slots = slot[index.core_dst]
        ce_src, ce_dst, ce_w = self.family.pad_coo(src_slots, dst_slots,
                                                   index.core_w)
        nbr_ids, nbr_w = self.family.build_ell(src_slots, dst_slots,
                                               index.core_w)
        return VersionState(
            lbl_ids=lbl_ids_dev, lbl_d=lbl_d_dev, lbl_base=lbl_base,
            core_slot=jnp.asarray(slot),
            ce_src=jnp.asarray(ce_src), ce_dst=jnp.asarray(ce_dst),
            ce_w=jnp.asarray(ce_w), nbr_ids=nbr_ids, nbr_w=nbr_w)

    # ------------------------------------------------------------- apply
    def apply(self, ops) -> IndexVersion:
        """Copy-on-write §8.3 batch -> new published version.

        On any failure (capacity, strict-domain violation) the manager
        and the current version are untouched — mutations land in local
        copies and commit only on success.
        """
        from repro.serve.engine import mu_exact_mask
        t0 = time.perf_counter()
        cur = self.current
        fam = self.family
        clone = _clone_index(cur.index)
        ids_h, d_h, pred_h = cur.store.writable()
        slot = self._core_slot.copy()
        next_slot = self._next_slot
        live = set(self._inserted_live)
        touched: set = set()
        for op in ops:
            u = int(op.u)
            if op.kind == "insert":
                if self.strict:
                    bad = [int(v) for v in op.nbrs
                           if clone.level[int(v)] != clone.k]
                    if bad:
                        raise ValueError(
                            f"strict mode: insert({u}) attaches to "
                            f"non-core vertices {bad}; only core-attached "
                            f"inserts are rebuild-exact (docs/MUTATION.md)")
                apply_insert_host(clone, ids_h, d_h, pred_h, u,
                                  [int(v) for v in op.nbrs],
                                  [float(x) for x in op.ws], touched)
                if slot[u] == fam.core_cap:
                    if next_slot >= fam.core_cap:
                        raise FamilyCapacityError(
                            "core slots exhausted; rebuild with more "
                            "core_headroom")
                    slot[u] = next_slot
                    next_slot += 1
                live.add(u)
            elif op.kind == "delete":
                if self.strict and u not in live:
                    raise ValueError(
                        f"strict mode: delete({u}) targets a build-time "
                        f"vertex; only previously-inserted vertices delete "
                        f"rebuild-exactly (docs/MUTATION.md)")
                apply_delete_host(clone, ids_h, d_h, pred_h, u, touched)
                live.discard(u)
            else:
                raise ValueError(f"unknown mutation kind {op.kind!r}")
        t_host = time.perf_counter()
        rows = np.asarray(sorted(touched), np.int64)
        lbl_ids_dev, lbl_d_dev, lbl_pred_dev = self._scatter_rows(
            cur, ids_h, d_h, pred_h, rows)
        clone._install_labels(lbl_ids_dev, lbl_d_dev, lbl_pred_dev,
                              host=(ids_h, d_h, pred_h))
        if self.family.codec == "none":
            state = self._build_state(lbl_ids_dev, lbl_d_dev, clone, slot)
        else:
            enc_ids, enc_base, enc_d = self._scatter_state_rows(
                cur, ids_h, d_h, rows)
            state = self._build_state(enc_ids, enc_d, clone, slot,
                                      lbl_base=enc_base)
        version = IndexVersion(
            vid=self._next_vid, index=clone, state=state,
            store=cur.store.commit(ids_h, d_h, pred_h, rows),
            mu_mask=mu_exact_mask(clone), touched_rows=rows)
        t_dev = time.perf_counter()
        # success: commit manager state, then publish atomically
        self._core_slot, self._next_slot = slot, next_slot
        self._inserted_live = live
        self._next_vid += 1
        self._versions[version.vid] = version
        self._refs[version.vid] = 0
        self.current = version
        t_pub = time.perf_counter()
        version.swap_seconds = t_pub - t0
        version.stage_seconds = {"cow_apply": t_host - t0,
                                 "device_update": t_dev - t_host,
                                 "publish": t_pub - t_dev}
        return version

    def _scatter_rows(self, cur, ids_h, d_h, pred_h, rows):
        """Incremental device update: scatter only the touched rows into
        the parent version's device planes (allocating new arrays — the
        parent stays valid). Row counts are padded to the next power of
        two (repeating a row; identical payload, so duplicate scatter
        indices are deterministic) to bound the compile-shape count of
        this off-hot-path scatter."""
        if rows.size == 0:
            return cur.index.lbl_ids, cur.index.lbl_d, cur.index.lbl_pred
        pad = 1 << (int(rows.size) - 1).bit_length()
        r = np.concatenate([rows, np.full(pad - rows.size, rows[0],
                                          np.int64)])
        rj = jnp.asarray(r, jnp.int32)
        return (cur.index.lbl_ids.at[rj].set(jnp.asarray(ids_h[r])),
                cur.index.lbl_d.at[rj].set(jnp.asarray(d_h[r])),
                cur.index.lbl_pred.at[rj].set(jnp.asarray(pred_h[r])))

    def _scatter_state_rows(self, cur, ids_h, d_h, rows):
        """Compressed-family twin of ``_scatter_rows``: re-encode the
        touched rows (delta16 is row-local, so per-row re-encode under
        the family's pinned distance dtype is exact) and scatter them
        into the parent's encoded planes — same power-of-two row
        padding, same new-arrays-parent-stays-valid contract. A row
        that no longer fits the codec is a capacity failure, mirroring
        ELL-width overflow."""
        st = cur.state
        if rows.size == 0:
            return st.lbl_ids, st.lbl_base, st.lbl_d
        pad = 1 << (int(rows.size) - 1).bit_length()
        r = np.concatenate([rows, np.full(pad - rows.size, rows[0],
                                          np.int64)])
        try:
            delta, base, d_enc = encode_labels(
                ids_h[r], d_h[r], self.family.n,
                d_dtype=self.family.d_dtype)
        except LabelCompressionError as e:
            raise FamilyCapacityError(
                f"mutated label rows no longer fit the family's delta16 "
                f"codec ({e}); rebuild the family uncompressed") from e
        rj = jnp.asarray(r, jnp.int32)
        return (st.lbl_ids.at[rj].set(jnp.asarray(delta)),
                st.lbl_base.at[rj].set(jnp.asarray(base)),
                st.lbl_d.at[rj].set(jnp.asarray(d_enc)))

    # ---------------------------------------------------------- lifecycle
    def acquire(self) -> IndexVersion:
        """Pin and return the current version (refcount++)."""
        v = self.current
        self._refs[v.vid] += 1
        return v

    def release(self, version: IndexVersion):
        """Unpin; a retired version drops once its last reader leaves."""
        vid = version.vid
        if vid not in self._refs:
            return
        self._refs[vid] -= 1
        if self._refs[vid] <= 0 and vid in self._retired:
            self._drop(vid)

    def retire(self, version: IndexVersion):
        """Mark for release; dropped immediately if unpinned, otherwise
        when the last in-flight reader calls ``release``."""
        vid = version.vid
        if vid == self.current.vid:
            raise ValueError("cannot retire the current version")
        self._retired.add(vid)
        if self._refs.get(vid, 0) <= 0:
            self._drop(vid)

    def _drop(self, vid: int):
        self._versions.pop(vid, None)
        self._refs.pop(vid, None)
        self._retired.discard(vid)

    def drain(self) -> list:
        """Retire every non-current version; returns the vids still
        pinned by in-flight readers (empty = fully drained)."""
        for vid in list(self._versions):
            if vid != self.current.vid and vid not in self._retired:
                self.retire(self._versions[vid])
        return [vid for vid in self._versions if vid != self.current.vid]

    def live_versions(self) -> list:
        return sorted(self._versions)

    def refcount(self, version: IndexVersion) -> int:
        return self._refs.get(version.vid, 0)

    # ------------------------------------------------------------- warmup
    def warmup(self, batch_sizes, backend: str | None = None,
               mu_only: bool = False) -> dict:
        """Pre-compile the family entry points for every batch size
        (mirrors ``QueryEngine.warmup``); later versions reuse these
        executables — that is the point of the family."""
        state = self.current.state
        fns = [("mu", self.family.mu_fn(backend))]
        if not mu_only:
            fns.append(("full", self.family.full_fn(backend)))
        out = {}
        for name, fn in fns:
            for size in batch_sizes:
                z = jnp.zeros(int(size), jnp.int32)
                t0 = time.perf_counter()
                jax.block_until_ready(fn(state, z, z))
                out[(name, int(size))] = time.perf_counter() - t0
        return out
