# repro.serve — the distance/path-serving subsystem over ISLabelIndex:
# shape-bucket micro-batching, μ-exact routing, LRU caching, metrics,
# a multi-graph registry, a scenario load generator, a batched
# shortest-path lane (docs/PATHS.md), versioned copy-on-write index
# mutation under live traffic (docs/MUTATION.md), replica groups with
# straggler health (docs/SERVICE.md), and an asyncio HTTP front end.
from repro.serve.batcher import Batch, MicroBatcher, PendingRequest
from repro.serve.cache import LRUCache
from repro.serve.engine import DistanceServer, PathAnswer, mu_exact_mask
from repro.serve.frontend import (HttpClient, ServiceFrontend, SSEReader,
                                  replay_http)
from repro.serve.loadgen import SCENARIOS, Trace, make_trace
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import IndexRegistry
from repro.serve.replicas import ReplicaSet
from repro.serve.versions import (FamilyCapacityError, IndexVersion,
                                  LabelBlockStore, MutationOp, VersionFamily,
                                  VersionManager, VersionState)

__all__ = [
    "Batch", "MicroBatcher", "PendingRequest", "LRUCache",
    "DistanceServer", "PathAnswer", "mu_exact_mask", "SCENARIOS", "Trace",
    "make_trace", "ServeMetrics", "IndexRegistry", "ReplicaSet",
    "ServiceFrontend", "HttpClient", "SSEReader", "replay_http",
    "FamilyCapacityError", "IndexVersion", "LabelBlockStore", "MutationOp",
    "VersionFamily", "VersionManager", "VersionState",
]
