# repro.serve — the distance-serving subsystem over ISLabelIndex:
# shape-bucket micro-batching, μ-exact routing, LRU caching, metrics,
# a multi-graph registry, and a scenario load generator.
from repro.serve.batcher import Batch, MicroBatcher, PendingRequest
from repro.serve.cache import LRUCache
from repro.serve.engine import DistanceServer, mu_exact_mask
from repro.serve.loadgen import SCENARIOS, Trace, make_trace
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import IndexRegistry

__all__ = [
    "Batch", "MicroBatcher", "PendingRequest", "LRUCache",
    "DistanceServer", "mu_exact_mask", "SCENARIOS", "Trace", "make_trace",
    "ServeMetrics", "IndexRegistry",
]
