# repro.serve — the distance/path-serving subsystem over ISLabelIndex:
# shape-bucket micro-batching, μ-exact routing, LRU caching, metrics,
# a multi-graph registry, a scenario load generator, and a batched
# shortest-path lane (docs/PATHS.md).
from repro.serve.batcher import Batch, MicroBatcher, PendingRequest
from repro.serve.cache import LRUCache
from repro.serve.engine import DistanceServer, PathAnswer, mu_exact_mask
from repro.serve.loadgen import SCENARIOS, Trace, make_trace
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import IndexRegistry

__all__ = [
    "Batch", "MicroBatcher", "PendingRequest", "LRUCache",
    "DistanceServer", "PathAnswer", "mu_exact_mask", "SCENARIOS", "Trace",
    "make_trace", "ServeMetrics", "IndexRegistry",
]
