"""Serving metrics: QPS, latency percentiles, batch fill, cache hits,
relaxation rounds — with JSON export so benchmark runs accumulate a
machine-readable perf trajectory (``BENCH_serving.json``).

Since the observability layer landed, ``ServeMetrics`` is a per-server
*view* over the process-wide metric registry (``repro.obs.REGISTRY``,
docs/OBSERVABILITY.md): every observation is recorded as a labeled
series (``server=<name>, sid=<instance>``) on shared ``serve.*``
counters/histograms, ``snapshot()`` reads those series back, and a
single ``repro.obs.write_metrics`` dump therefore carries every
server's series next to the versions/shard/path/fault metrics. The
``sid`` label keeps instances isolated — two servers over the same
graph name never alias each other's series.

Latency accounting: a request's latency is queue wait (flush instant −
arrival, on the trace's clock) plus the measured wall-clock execution
time of the batch that served it. Cache hits have zero latency. QPS is
reported two ways: ``qps_compute`` (device-path requests / summed
device execution time — what the hardware sustains; cache hits are
excluded from the numerator since they consume no device time) and
``qps_offered`` (all served requests / trace span — what the scenario
asked for).
"""
from __future__ import annotations

import dataclasses
import itertools
import json

import numpy as np

from repro.obs.registry import REGISTRY

# Lanes that always appear in the per-lane report, even when idle.
# Observed lanes are unioned in (snapshot derives the set from the
# recorded BatchRecords), so a new lane's batches are never dropped.
KNOWN_LANES = ("mu", "full", "path")


@dataclasses.dataclass
class BatchRecord:
    lane: str          # "mu" | "full" | "path" | any future lane
    bucket: int
    n_real: int
    exec_s: float
    rounds: int

    @property
    def fill(self) -> float:
        return self.n_real / self.bucket


class ServeMetrics:
    """Accumulates per-request and per-batch observations into the
    registry; keeps the raw ``BatchRecord`` list for the per-lane and
    per-bucket breakdowns."""

    _sid = itertools.count()

    def __init__(self, server: str = "default", registry=None):
        self.server = server
        self.registry = registry if registry is not None else REGISTRY
        # per-instance series isolation within the shared registry
        self._lbl = {"server": server, "sid": str(next(ServeMetrics._sid))}
        r = self.registry
        self._served = r.counter("serve.served", "requests answered")
        self._batches = r.counter("serve.batches", "device batches run")
        self._exec_seconds = r.counter(
            "serve.exec_seconds", "summed device batch execution time")
        self._cache_hits = r.counter("serve.cache_hits", "LRU cache hits")
        self._path_overflows = r.counter(
            "serve.path_overflows", "path-lane hop_cap tier escalations")
        self._mutations = r.counter(
            "serve.mutations", "applied §8.3 write batches (version swaps)")
        self._mutation_ops = r.counter(
            "serve.mutation_ops", "individual insert/delete ops")
        self._types = r.counter(
            "serve.query_types", "paper §5.2 endpoint classes served")
        self._latency = r.histogram(
            "serve.latency_seconds", "request latency (wait + exec)")
        self._swap = r.histogram(
            "serve.swap_seconds", "COW apply + hot-swap wall time")
        self._span = r.gauge(
            "serve.trace_span_seconds", "summed replayed trace spans")
        self.batches: list[BatchRecord] = []

    # ---------------------------------------------- registry-view props
    @property
    def served(self) -> int:
        return int(self._served.value(**self._lbl))

    @property
    def cache_hits(self) -> int:
        return int(self._cache_hits.value(**self._lbl))

    @property
    def path_overflows(self) -> int:
        return int(self._path_overflows.value(**self._lbl))

    @property
    def mutations(self) -> int:
        return int(self._mutations.value(**self._lbl))

    @property
    def mutation_ops(self) -> int:
        return int(self._mutation_ops.value(**self._lbl))

    @property
    def latencies(self) -> list:
        return self._latency.values(**self._lbl)

    @property
    def swap_seconds(self) -> list:
        return self._swap.values(**self._lbl)

    @property
    def type_counts(self) -> dict:
        out = {c: 0 for c in (1, 2, 3)}
        for labels in self._types.labels_seen():
            if all(labels.get(k) == v for k, v in self._lbl.items()):
                out[int(labels["cls"])] = int(self._types.value(**labels))
        return out

    @property
    def trace_span_s(self) -> float:
        return self._span.value(**self._lbl)

    @trace_span_s.setter
    def trace_span_s(self, value: float) -> None:
        self._span.set(float(value), **self._lbl)

    # ------------------------------------------------------------ record
    def record_batch(self, lane: str, bucket: int, n_real: int,
                     exec_s: float, rounds: int) -> None:
        self.batches.append(BatchRecord(lane, bucket, n_real, exec_s,
                                        rounds))
        self._batches.inc(1, lane=lane, **self._lbl)
        self._exec_seconds.inc(float(exec_s), lane=lane, **self._lbl)
        self._served.inc(n_real, **self._lbl)

    def record_latency(self, seconds: float) -> None:
        self._latency.observe(float(seconds), **self._lbl)

    def record_cache_hit(self) -> None:
        self._cache_hits.inc(1, **self._lbl)
        self._served.inc(1, **self._lbl)
        self._latency.observe(0.0, **self._lbl)

    def record_path_overflow(self) -> None:
        self._path_overflows.inc(1, **self._lbl)

    def record_mutation(self, n_ops: int, swap_s: float) -> None:
        """One applied §8.3 write batch: ``n_ops`` insert/delete ops,
        ``swap_s`` = copy-on-write apply + hot-swap wall time."""
        self._mutations.inc(1, **self._lbl)
        self._mutation_ops.inc(int(n_ops), **self._lbl)
        self._swap.observe(float(swap_s), **self._lbl)

    def record_types(self, classes) -> None:
        for c, cnt in zip(*np.unique(np.asarray(classes),
                                     return_counts=True)):
            self._types.inc(int(cnt), cls=str(int(c)), **self._lbl)

    # ----------------------------------------------------------- export
    def snapshot(self) -> dict:
        lat = self._latency
        sw = self._swap
        lbl = self._lbl
        exec_total = sum(b.exec_s for b in self.batches)
        # per-lane breakdown over the lanes actually observed (plus the
        # standing ones) — a new lane shows up instead of vanishing
        lanes = {}
        for lane in sorted(set(KNOWN_LANES)
                           | {b.lane for b in self.batches}):
            bs = [b for b in self.batches if b.lane == lane]
            lanes[lane] = {
                "batches": len(bs),
                "requests": sum(b.n_real for b in bs),
                "fill_ratio": (float(np.mean([b.fill for b in bs]))
                               if bs else 0.0),
                "rounds_per_batch": (float(np.mean([b.rounds for b in bs]))
                                     if bs else 0.0),
            }
        total = self.served
        batch_served = sum(b.n_real for b in self.batches)
        bucket_counts: dict[str, int] = {}
        for b in self.batches:
            bucket_counts[str(b.bucket)] = bucket_counts.get(str(b.bucket),
                                                             0) + 1
        has_lat = lat.count(**lbl) > 0
        has_sw = sw.count(**lbl) > 0
        return {
            "served": total,
            "batches": len(self.batches),
            "cache_hits": self.cache_hits,
            "cache_hit_rate": self.cache_hits / total if total else 0.0,
            "path_overflows": self.path_overflows,
            # device-path requests only: cache hits consume no device
            # time and must not inflate the hardware-throughput figure
            "qps_compute": batch_served / exec_total if exec_total else 0.0,
            "qps_offered": (total / self.trace_span_s
                            if self.trace_span_s else 0.0),
            "latency_ms": {
                "p50": lat.quantile(0.50, **lbl) * 1e3 if has_lat else 0.0,
                "p95": lat.quantile(0.95, **lbl) * 1e3 if has_lat else 0.0,
                "p99": lat.quantile(0.99, **lbl) * 1e3 if has_lat else 0.0,
                "mean": lat.mean(**lbl) * 1e3 if has_lat else 0.0,
            },
            "batch_fill_ratio": (float(np.mean([b.fill
                                                for b in self.batches]))
                                 if self.batches else 0.0),
            "bucket_counts": bucket_counts,
            "lanes": lanes,
            "query_types": {str(k): v for k, v in self.type_counts.items()},
            "mutations": self.mutations,
            "mutation_ops": self.mutation_ops,
            "swap_ms": {
                "p50": sw.quantile(0.50, **lbl) * 1e3 if has_sw else 0.0,
                "p95": sw.quantile(0.95, **lbl) * 1e3 if has_sw else 0.0,
                "max": sw.max(**lbl) * 1e3 if has_sw else 0.0,
                "mean": sw.mean(**lbl) * 1e3 if has_sw else 0.0,
            },
        }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.snapshot(), **extra}, indent=2,
                          sort_keys=True)
