"""Serving metrics: QPS, latency percentiles, batch fill, cache hits,
relaxation rounds — with JSON export so benchmark runs accumulate a
machine-readable perf trajectory (``BENCH_serving.json``).

Latency accounting: a request's latency is queue wait (flush instant −
arrival, on the trace's clock) plus the measured wall-clock execution
time of the batch that served it. Cache hits have zero latency. QPS is
reported two ways: ``qps_compute`` (device-path requests / summed
device execution time — what the hardware sustains; cache hits are
excluded from the numerator since they consume no device time) and
``qps_offered`` (all served requests / trace span — what the scenario
asked for).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np


@dataclasses.dataclass
class BatchRecord:
    lane: str          # "full" | "mu"
    bucket: int
    n_real: int
    exec_s: float
    rounds: int

    @property
    def fill(self) -> float:
        return self.n_real / self.bucket


class ServeMetrics:
    """Accumulates per-request and per-batch observations."""

    def __init__(self):
        self.batches: list[BatchRecord] = []
        self.latencies: list[float] = []
        self.served = 0
        self.cache_hits = 0
        self.path_overflows = 0    # hop_cap tier escalations (path lane)
        self.trace_span_s = 0.0
        self.type_counts = {1: 0, 2: 0, 3: 0}   # paper §5.2 endpoint classes
        self.mutations = 0         # §8.3 write batches (version swaps)
        self.mutation_ops = 0      # individual insert/delete ops
        self.swap_seconds: list[float] = []

    # ------------------------------------------------------------ record
    def record_batch(self, lane: str, bucket: int, n_real: int,
                     exec_s: float, rounds: int) -> None:
        self.batches.append(BatchRecord(lane, bucket, n_real, exec_s, rounds))
        self.served += n_real

    def record_latency(self, seconds: float) -> None:
        self.latencies.append(seconds)

    def record_cache_hit(self) -> None:
        self.cache_hits += 1
        self.served += 1
        self.latencies.append(0.0)

    def record_path_overflow(self) -> None:
        self.path_overflows += 1

    def record_mutation(self, n_ops: int, swap_s: float) -> None:
        """One applied §8.3 write batch: ``n_ops`` insert/delete ops,
        ``swap_s`` = copy-on-write apply + hot-swap wall time."""
        self.mutations += 1
        self.mutation_ops += int(n_ops)
        self.swap_seconds.append(float(swap_s))

    def record_types(self, classes) -> None:
        for c, cnt in zip(*np.unique(np.asarray(classes), return_counts=True)):
            self.type_counts[int(c)] += int(cnt)

    # ----------------------------------------------------------- export
    def snapshot(self) -> dict:
        lat = np.asarray(self.latencies, np.float64)
        sw = np.asarray(self.swap_seconds, np.float64)
        exec_total = sum(b.exec_s for b in self.batches)
        lanes = {}
        for lane in ("mu", "full", "path"):
            bs = [b for b in self.batches if b.lane == lane]
            lanes[lane] = {
                "batches": len(bs),
                "requests": sum(b.n_real for b in bs),
                "fill_ratio": float(np.mean([b.fill for b in bs])) if bs else 0.0,
                "rounds_per_batch": float(np.mean([b.rounds for b in bs])) if bs else 0.0,
            }
        total = self.served
        batch_served = sum(b.n_real for b in self.batches)
        bucket_counts: dict[str, int] = {}
        for b in self.batches:
            bucket_counts[str(b.bucket)] = bucket_counts.get(str(b.bucket), 0) + 1
        return {
            "served": total,
            "batches": len(self.batches),
            "cache_hits": self.cache_hits,
            "cache_hit_rate": self.cache_hits / total if total else 0.0,
            "path_overflows": self.path_overflows,
            # device-path requests only: cache hits consume no device
            # time and must not inflate the hardware-throughput figure
            "qps_compute": batch_served / exec_total if exec_total else 0.0,
            "qps_offered": (total / self.trace_span_s
                            if self.trace_span_s else 0.0),
            "latency_ms": {
                "p50": float(np.quantile(lat, 0.50) * 1e3) if len(lat) else 0.0,
                "p95": float(np.quantile(lat, 0.95) * 1e3) if len(lat) else 0.0,
                "p99": float(np.quantile(lat, 0.99) * 1e3) if len(lat) else 0.0,
                "mean": float(lat.mean() * 1e3) if len(lat) else 0.0,
            },
            "batch_fill_ratio": (float(np.mean([b.fill for b in self.batches]))
                                 if self.batches else 0.0),
            "bucket_counts": bucket_counts,
            "lanes": lanes,
            "query_types": {str(k): v for k, v in self.type_counts.items()},
            "mutations": self.mutations,
            "mutation_ops": self.mutation_ops,
            "swap_ms": {
                "p50": float(np.quantile(sw, 0.50) * 1e3) if len(sw) else 0.0,
                "p95": float(np.quantile(sw, 0.95) * 1e3) if len(sw) else 0.0,
                "max": float(sw.max() * 1e3) if len(sw) else 0.0,
                "mean": float(sw.mean() * 1e3) if len(sw) else 0.0,
            },
        }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.snapshot(), **extra}, indent=2, sort_keys=True)
