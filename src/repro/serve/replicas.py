"""Replica groups: N ``DistanceServer`` replicas over one index with
straggler-health observability (docs/SERVICE.md).

A ``ReplicaSet`` duck-types the server API the front end and the
``IndexRegistry`` drive (``submit``/``pump``/``take_result``/``route``/
``serve_trace``/``stats``/``drain``), dispatching each request to one
replica round-robin. Every replica runs the same pre-warmed compiled
entry points over the same index (the jitted fns are memoized per
(engine, backend), so N replicas share one set of executables and
answers are bitwise identical regardless of which replica serves them —
replication changes *timing*, never *values*).

Health: after every pump, each replica's new per-batch execution times
feed the ``repro.fault`` straggler machinery — one ``StragglerMonitor``
per replica under a ``HostTimingAggregator`` fleet view. The two
detectors are complementary: the per-replica EMA flags *degradation
onset* (a replica that was fast and got slow), the fleet-median
comparison catches *steady-state outliers* (a replica slow from its
first batch, whose own EMA never saw a fast baseline). Eviction is
keyed on the fleet view — ``evict_after`` consecutive health rounds
above ``fleet_threshold`` × the fleet-median EMA removes the replica
from the dispatch rotation (in-flight work still completes; dispatch
just stops choosing it) — recorded by the ``serve.replica_evictions``
counter and per-replica ``serve.replica_healthy`` gauge next to the
``fault.*`` series from stragglers.py.

Determinism: fed timings are clamped below at ``min_step_s`` — µs-scale
batch wall times on an idle graph are indistinguishable scheduler noise
and would otherwise produce flaky ratios. Above the floor (real fleets,
injected stalls) the clamp is a no-op. With the floor, a clean run
feeds identical values for every replica, so the fleet comparison is
exactly quiet; a 2-replica fleet's median is the mean of both EMAs,
bounding any outlier's ratio below 2.0 — hence the default
``fleet_threshold`` of 1.5, not the aggregator's whole-fleet 1.3.

Failure injection: ``set_stall(replica, stall_s)`` charges a synthetic
stall to every distance batch the replica executes
(``DistanceServer.exec_delay_s`` — accounting-only, no real sleep), and
``apply_injection(meta)`` wires a ``straggler`` loadgen scenario's
``meta["inject"]`` plan. The injected replica's latencies and straggler
flags degrade deterministically on the serving clock while answers stay
bitwise exact — the clean/degraded pair the SLO burn-rate tests gate.
"""
from __future__ import annotations

import numpy as np

from repro.fault.stragglers import HostTimingAggregator, StragglerMonitor
from repro.obs.registry import REGISTRY
from repro.serve.engine import DistanceServer

__all__ = ["ReplicaSet"]


class ReplicaSet:
    """Round-robin dispatch over N bitwise-identical replicas."""

    def __init__(self, index, n_replicas: int = 2, *, name: str = "default",
                 straggler_threshold: float = 4.0, evict_after: int = 5,
                 fleet_threshold: float = 1.5, min_step_s: float = 0.01,
                 registry=None, **server_kwargs):
        if n_replicas < 1:
            raise ValueError(f"need >= 1 replica, got {n_replicas}")
        self.name = name
        self.registry = registry if registry is not None else REGISTRY
        self.replicas = [
            DistanceServer(index, name=f"{name}/r{i}",
                           registry=self.registry, **server_kwargs)
            for i in range(n_replicas)
        ]
        self.index = self.replicas[0].index
        self.versions = None          # replica groups are read-only
        self.evict_after = int(evict_after)
        self.min_step_s = float(min_step_s)
        self.aggregator = HostTimingAggregator(threshold=fleet_threshold)
        for i, srv in enumerate(self.replicas):
            self.aggregator.hosts[srv.name] = StragglerMonitor(
                host=srv.name, threshold=straggler_threshold,
                evict_after=evict_after)
        self.healthy = [True] * n_replicas
        self._rr = 0
        self._owner: dict[int, int] = {}      # rid -> replica idx
        self._batches_seen = [0] * n_replicas
        self._fleet_streak = [0] * n_replicas
        r = self.registry
        self._evictions = r.counter(
            "serve.replica_evictions",
            "replicas removed from dispatch after straggler streaks")
        self._healthy_g = r.gauge(
            "serve.replica_healthy", "1 while the replica is in rotation")
        self._straggler_g = r.gauge(
            "serve.replica_straggler",
            "1 while the replica's last batch was flagged")
        for srv in self.replicas:
            self._healthy_g.set(1.0, replica=srv.name)
            self._straggler_g.set(0.0, replica=srv.name)

    # -------------------------------------------------------- properties
    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def server_names(self) -> list:
        return [srv.name for srv in self.replicas]

    @property
    def buckets(self):
        return self.replicas[0].buckets

    @property
    def metrics(self):
        """Primary replica's metrics view (per-replica views live on
        each ``replicas[i].metrics``)."""
        return self.replicas[0].metrics

    # -------------------------------------------------- fault injection
    def set_stall(self, replica: int, stall_s: float) -> None:
        """Charge ``stall_s`` of synthetic stall to every distance
        batch replica ``replica`` executes from now on."""
        self.replicas[replica].exec_delay_s = float(stall_s)

    def apply_injection(self, meta: dict) -> None:
        """Wire a loadgen ``straggler`` scenario's injection plan."""
        inject = (meta or {}).get("inject")
        if inject:
            self.set_stall(int(inject["replica"]),
                           float(inject["stall_s"]))

    # ------------------------------------------------------ request path
    def _pick(self) -> int:
        n = len(self.replicas)
        for _ in range(n):
            i = self._rr % n
            self._rr += 1
            if self.healthy[i]:
                return i
        return self._rr % n           # all evicted: degrade, keep serving

    def submit(self, s: int, t: int, now: float,
               lane: str | None = None) -> int:
        i = self._pick()
        rid = self.replicas[i].submit(s, t, now, lane=lane)
        key = self._key(i, rid)
        self._owner[key] = i
        return key

    def take_result(self, rid: int):
        # keep the rid -> replica mapping until the result actually
        # lands: callers poll take_result before the batch flushes
        i = self._owner.get(rid)
        if i is None:
            return None
        val = self.replicas[i].take_result(self._unkey(rid))
        if val is not None:
            del self._owner[rid]
        return val

    def route(self, s, t):
        return self.replicas[0].route(s, t)

    def pump(self, now: float, force: bool = False) -> int:
        done = 0
        for srv in self.replicas:
            done += srv.pump(now, force=force)
        self._collect_timings()
        return done

    def drain(self, now: float | None = None) -> int:
        done = 0
        for srv in self.replicas:
            done += srv.drain(now)
        self._collect_timings()
        return done

    def _key(self, i: int, rid: int) -> int:
        # per-replica rid spaces interleaved into one global space
        return rid * len(self.replicas) + i

    def _unkey(self, key: int) -> int:
        return key // len(self.replicas)

    # ----------------------------------------------------- health intake
    def _collect_timings(self) -> None:
        """One health round: feed every replica's new per-batch
        execution times (floored at ``min_step_s``) into its straggler
        monitor, then compare EMAs against the fleet median. A replica
        above ``fleet_threshold`` × median for ``evict_after``
        consecutive rounds-with-data is evicted from rotation."""
        fed = False
        for i, srv in enumerate(self.replicas):
            batches = srv.metrics.batches
            for b in batches[self._batches_seen[i]:]:
                self.aggregator.record(srv.name,
                                       max(b.exec_s, self.min_step_s))
                fed = True
            self._batches_seen[i] = len(batches)
        if not fed:
            return
        flagged = set(self.aggregator.stragglers())
        for i, srv in enumerate(self.replicas):
            slow = srv.name in flagged
            self._straggler_g.set(1.0 if slow else 0.0, replica=srv.name)
            self._fleet_streak[i] = self._fleet_streak[i] + 1 if slow else 0
            if (slow and self.healthy[i]
                    and self._fleet_streak[i] >= self.evict_after):
                self.healthy[i] = False
                self._evictions.inc(1, replica=srv.name)
                self._healthy_g.set(0.0, replica=srv.name)

    # ------------------------------------------------------ trace replay
    def serve_trace(self, trace, slo=None, eval_interval_s: float | None =
                    None) -> np.ndarray:
        """Replay a loadgen trace across the replica group on its
        simulated clock (applies the trace's injection plan first). With
        an ``SLOEngine``, polls + evaluates it every
        ``eval_interval_s`` of trace time (default: fast_window / 4 of
        the tightest spec), so burn-rate alerts fire *during* the replay
        exactly as they would behind the live front end."""
        self.apply_injection(trace.meta)
        if slo is not None and eval_interval_s is None:
            eval_interval_s = min(s.fast_window_s
                                  for s in slo.specs.values()) / 4.0
        lanes = self.route(trace.s, trace.t)
        n_req = len(trace)
        rids = np.empty(n_req, np.int64)
        next_eval = 0.0
        for i in range(n_req):
            now = float(trace.arrival_s[i])
            self.pump(now)
            if slo is not None and now >= next_eval:
                slo.step(now)
                next_eval = now + eval_interval_s
            rids[i] = self.submit(int(trace.s[i]), int(trace.t[i]), now,
                                  lane=str(lanes[i]))
            self.pump(now)
        self.pump(trace.span_s, force=True)
        if slo is not None:
            slo.step(trace.span_s)
        for srv in self.replicas:
            srv.metrics.trace_span_s += trace.span_s
        answers = np.empty(n_req, np.float32)
        for i in range(n_req):
            answers[i] = self.take_result(int(rids[i]))
        return answers

    # ----------------------------------------------------------- status
    def stats(self) -> dict:
        agg = {
            "name": self.name,
            "replicas": {
                srv.name: {
                    "healthy": self.healthy[i],
                    "served": srv.metrics.served,
                    "batches": len(srv.metrics.batches),
                    "exec_delay_s": srv.exec_delay_s,
                    "ema_s": self.aggregator.hosts[srv.name].ema,
                    "flag_streak": self.aggregator.hosts[srv.name].flags,
                    "fleet_streak": self._fleet_streak[i],
                } for i, srv in enumerate(self.replicas)
            },
            "fleet_stragglers": self.aggregator.stragglers(),
        }
        primary = self.replicas[0].stats()
        # group-level roll-up: sum served/hits, merge latency via the
        # shared registry histogram (per-replica series stay exported)
        agg["served"] = sum(srv.metrics.served for srv in self.replicas)
        agg["cache_hits"] = sum(srv.metrics.cache_hits
                                for srv in self.replicas)
        lat = self.registry.get("serve.latency_seconds")
        vals: list = []
        if lat is not None:
            names = set(self.server_names)
            for labels in lat.labels_seen():
                if labels.get("server") in names:
                    vals.extend(lat.values(**labels))
        if vals:
            v = np.asarray(vals, np.float64)
            agg["latency_ms"] = {
                "p50": float(np.quantile(v, 0.50)) * 1e3,
                "p95": float(np.quantile(v, 0.95)) * 1e3,
                "p99": float(np.quantile(v, 0.99)) * 1e3,
                "mean": float(v.mean()) * 1e3,
            }
        else:
            agg["latency_ms"] = primary["latency_ms"]
        for key in ("graph", "buckets", "backend", "compiled_shapes",
                    "fault", "obs"):
            agg[key] = primary[key]
        agg["qps_compute"] = (
            agg["served"] / es if (es := sum(
                b.exec_s for srv in self.replicas
                for b in srv.metrics.batches)) else 0.0)
        return agg
