"""LRU result cache for (s, t) distance answers.

Distances are immutable for a given index generation, so caching is
sound. Anything that mutates the index in place (§8.3
``insert_vertex``/``delete_vertex``) invalidates it — call
``DistanceServer.refresh()`` afterwards. Keys are exact (s, t) pairs;
construct with ``symmetric=True`` (``DistanceServer(...,
cache_symmetric=True)``) for undirected indexes so (t, s) hits too.
"""
from __future__ import annotations

from collections import OrderedDict


class LRUCache:
    """Bounded LRU map from (s, t) to a float distance.

    ``capacity <= 0`` disables the cache (every get misses, puts are
    dropped) so call sites need no branching.
    """

    def __init__(self, capacity: int, symmetric: bool = False):
        self.capacity = int(capacity)
        self.symmetric = bool(symmetric)
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def _key(self, s: int, t: int):
        if self.symmetric and t < s:
            return (t, s)
        return (s, t)

    def get(self, s: int, t: int):
        if self.capacity <= 0:
            self.misses += 1
            return None
        key = self._key(s, t)
        val = self._d.get(key)
        if val is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return val

    def put(self, s: int, t: int, value: float) -> None:
        if self.capacity <= 0:
            return
        key = self._key(s, t)
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def clear(self) -> None:
        self._d.clear()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
