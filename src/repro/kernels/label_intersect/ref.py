"""Pure-jnp oracle: μ via per-row searchsorted merge (same math as
repro.core.query.label_intersect_mu)."""
import jax
import jax.numpy as jnp


def label_intersect_ref(ids_s, d_s, ids_t, d_t, n_sentinel: int):
    pos = jax.vmap(jnp.searchsorted)(ids_t, ids_s)
    pos_c = jnp.minimum(pos, ids_t.shape[1] - 1)
    hit = (jnp.take_along_axis(ids_t, pos_c, 1) == ids_s) & (ids_s < n_sentinel)
    tot = jnp.where(hit, d_s + jnp.take_along_axis(d_t, pos_c, 1), jnp.inf)
    return jnp.min(tot, axis=1)
