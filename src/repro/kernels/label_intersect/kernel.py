"""Batched label-intersection Pallas kernel (the query hot path).

Per query q: μ[q] = min over common ancestor ids of d_s + d_t, over two
id-sorted label rows (paper Equation 1). The paper's sequential sorted
merge is branch-heavy; on TPU we do a *tiled equality join*: compare a
[bq, L] id tile of s against t in 128-wide column chunks, min-reducing
d_s+d_t where ids match. O(L^2/lane_width) fully-vectorized VPU work
beats a data-dependent merge on this hardware.

``label_intersect_packed_kernel`` is the same join over *compressed*
label rows (``repro.core.labels`` delta16 codec): int16 delta planes +
int32 row bases (+ int32 distances when weights are integral) stream in
at 2–4 bytes per entry instead of 8, and the decode — a cumsum over the
row axis — happens in-register before the join. Serving reads the
compressed blocks directly; nothing materializes the fp32 planes in HBM.

VMEM per block: 4 x [bq, L] operands + [bq, L, 128] intermediate
(bq=8, L=512 -> ~2 MB), well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.labels import decode_d, decode_ids


def _equality_join(ids_s, d_s, ids_t, d_t, *, n_sentinel, chunk):
    """μ over one [bq, L] tile pair — shared by both kernel variants."""
    l = ids_s.shape[1]

    def body(c, mu):
        it = jax.lax.dynamic_slice(ids_t, (0, c * chunk),
                                   (ids_t.shape[0], chunk))   # [bq, ck]
        dt = jax.lax.dynamic_slice(d_t, (0, c * chunk),
                                   (d_t.shape[0], chunk))
        eq = (ids_s[:, :, None] == it[:, None, :]) & \
             (ids_s[:, :, None] < n_sentinel)
        tot = jnp.where(eq, d_s[:, :, None] + dt[:, None, :], jnp.inf)
        return jnp.minimum(mu, jnp.min(tot, axis=(1, 2)))

    return jax.lax.fori_loop(0, l // chunk, body,
                             jnp.full((ids_s.shape[0],), jnp.inf,
                                      jnp.float32))


def _intersect_kernel(ids_s_ref, d_s_ref, ids_t_ref, d_t_ref, mu_ref, *,
                      n_sentinel, chunk):
    mu_ref[...] = _equality_join(ids_s_ref[...], d_s_ref[...],
                                 ids_t_ref[...], d_t_ref[...],
                                 n_sentinel=n_sentinel, chunk=chunk)


@functools.partial(jax.jit,
                   static_argnames=("n_sentinel", "bq", "chunk", "interpret"))
def label_intersect_kernel(ids_s, d_s, ids_t, d_t, *, n_sentinel: int,
                           bq=8, chunk=128, interpret=False):
    """ids_*: int32[Q, L] sorted ancestor ids (pad = n_sentinel);
    d_*: float32[Q, L]. Q % bq == 0, L % chunk == 0 (ops.py pads).
    Returns mu float32[Q]."""
    q, l = ids_s.shape
    assert q % bq == 0 and l % chunk == 0
    kern = functools.partial(_intersect_kernel, n_sentinel=n_sentinel,
                             chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(q // bq,),
        in_specs=[
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=interpret,
    )(ids_s, d_s, ids_t, d_t)


def _intersect_packed_kernel(delta_s_ref, base_s_ref, d_s_ref,
                             delta_t_ref, base_t_ref, d_t_ref, mu_ref, *,
                             n_sentinel, chunk):
    ids_s = decode_ids(delta_s_ref[...], base_s_ref[...], n_sentinel)
    ids_t = decode_ids(delta_t_ref[...], base_t_ref[...], n_sentinel)
    mu_ref[...] = _equality_join(ids_s, decode_d(d_s_ref[...]),
                                 ids_t, decode_d(d_t_ref[...]),
                                 n_sentinel=n_sentinel, chunk=chunk)


@functools.partial(jax.jit,
                   static_argnames=("n_sentinel", "bq", "chunk", "interpret"))
def label_intersect_packed_kernel(delta_s, base_s, d_s, delta_t, base_t,
                                  d_t, *, n_sentinel: int, bq=16,
                                  chunk=128, interpret=False):
    """Compressed-row variant: delta_*: int16[Q, L] (pad marker -1),
    base_*: int32[Q], d_*: int32 (pad -1 = +inf) or float32[Q, L].
    Decode is fused before the join — the fp32 planes never exist in
    HBM. bq defaults to 16: int16 operands tile at (16, 128) on TPU.
    Returns mu float32[Q]."""
    q, l = delta_s.shape
    assert q % bq == 0 and l % chunk == 0
    kern = functools.partial(_intersect_packed_kernel, n_sentinel=n_sentinel,
                             chunk=chunk)
    row_spec = pl.BlockSpec((bq, l), lambda i: (i, 0))
    base_spec = pl.BlockSpec((bq,), lambda i: (i,))
    return pl.pallas_call(
        kern,
        grid=(q // bq,),
        in_specs=[row_spec, base_spec, row_spec,
                  row_spec, base_spec, row_spec],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=interpret,
    )(delta_s, base_s, d_s, delta_t, base_t, d_t)
