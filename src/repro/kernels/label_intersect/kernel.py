"""Batched label-intersection Pallas kernel (the query hot path).

Per query q: μ[q] = min over common ancestor ids of d_s + d_t, over two
id-sorted label rows (paper Equation 1). The paper's sequential sorted
merge is branch-heavy; on TPU we do a *tiled equality join*: compare a
[bq, L] id tile of s against t in 128-wide column chunks, min-reducing
d_s+d_t where ids match. O(L^2/lane_width) fully-vectorized VPU work
beats a data-dependent merge on this hardware.

VMEM per block: 4 x [bq, L] operands + [bq, L, 128] intermediate
(bq=8, L=512 -> ~2 MB), well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersect_kernel(ids_s_ref, d_s_ref, ids_t_ref, d_t_ref, mu_ref, *,
                      n_sentinel, chunk):
    ids_s = ids_s_ref[...]          # [bq, L] int32, sorted, pad = n_sentinel
    d_s = d_s_ref[...]
    ids_t = ids_t_ref[...]
    d_t = d_t_ref[...]
    l = ids_s.shape[1]

    def body(c, mu):
        sl = slice(None)
        it = jax.lax.dynamic_slice(ids_t, (0, c * chunk),
                                   (ids_t.shape[0], chunk))   # [bq, ck]
        dt = jax.lax.dynamic_slice(d_t, (0, c * chunk),
                                   (d_t.shape[0], chunk))
        eq = (ids_s[:, :, None] == it[:, None, :]) & \
             (ids_s[:, :, None] < n_sentinel)
        tot = jnp.where(eq, d_s[:, :, None] + dt[:, None, :], jnp.inf)
        return jnp.minimum(mu, jnp.min(tot, axis=(1, 2)))

    mu = jax.lax.fori_loop(0, l // chunk, body,
                           jnp.full((ids_s.shape[0],), jnp.inf, jnp.float32))
    mu_ref[...] = mu


@functools.partial(jax.jit,
                   static_argnames=("n_sentinel", "bq", "chunk", "interpret"))
def label_intersect_kernel(ids_s, d_s, ids_t, d_t, *, n_sentinel: int,
                           bq=8, chunk=128, interpret=False):
    """ids_*: int32[Q, L] sorted ancestor ids (pad = n_sentinel);
    d_*: float32[Q, L]. Q % bq == 0, L % chunk == 0 (ops.py pads).
    Returns mu float32[Q]."""
    q, l = ids_s.shape
    assert q % bq == 0 and l % chunk == 0
    kern = functools.partial(_intersect_kernel, n_sentinel=n_sentinel,
                             chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(q // bq,),
        in_specs=[
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
            pl.BlockSpec((bq, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=interpret,
    )(ids_s, d_s, ids_t, d_t)
