"""jit'd wrapper with shape padding for the label-intersect kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.label_intersect.kernel import label_intersect_kernel


def label_intersect(ids_s, d_s, ids_t, d_t, n_sentinel: int, *,
                    bq=8, chunk=128, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, l = ids_s.shape
    qp = -(-q // bq) * bq
    lp = -(-l // chunk) * chunk

    def padi(x):
        return jnp.pad(x, ((0, qp - q), (0, lp - l)),
                       constant_values=n_sentinel)

    def padd(x):
        return jnp.pad(x, ((0, qp - q), (0, lp - l)), constant_values=jnp.inf)

    mu = label_intersect_kernel(
        padi(ids_s.astype(jnp.int32)), padd(d_s.astype(jnp.float32)),
        padi(ids_t.astype(jnp.int32)), padd(d_t.astype(jnp.float32)),
        n_sentinel=n_sentinel, bq=bq, chunk=chunk, interpret=interpret)
    return mu[:q]
