"""Backend-aware wrapper with shape padding for the label-intersect
kernel. ``backend`` selects pallas / interpret / jnp-reference (see
``repro.kernels.backend``); the legacy ``interpret=`` kwarg still forces
the pallas program when given explicitly."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.labels import PAD_D, PAD_DELTA, LabelRows, decode_rows
from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.label_intersect.kernel import (
    label_intersect_kernel, label_intersect_packed_kernel)
from repro.kernels.label_intersect.ref import label_intersect_ref


def label_intersect(ids_s, d_s, ids_t, d_t, n_sentinel: int, *,
                    bq=8, chunk=128, backend=None, interpret=None):
    backend = resolve_backend(backend, interpret)
    if backend == "reference":
        return label_intersect_ref(ids_s.astype(jnp.int32),
                                   d_s.astype(jnp.float32),
                                   ids_t.astype(jnp.int32),
                                   d_t.astype(jnp.float32), n_sentinel)
    q, l = ids_s.shape
    qp = -(-q // bq) * bq
    lp = -(-l // chunk) * chunk

    def padi(x):
        return jnp.pad(x, ((0, qp - q), (0, lp - l)),
                       constant_values=n_sentinel)

    def padd(x):
        return jnp.pad(x, ((0, qp - q), (0, lp - l)), constant_values=jnp.inf)

    mu = label_intersect_kernel(
        padi(ids_s.astype(jnp.int32)), padd(d_s.astype(jnp.float32)),
        padi(ids_t.astype(jnp.int32)), padd(d_t.astype(jnp.float32)),
        n_sentinel=n_sentinel, bq=bq, chunk=chunk,
        interpret=pallas_interpret(backend))
    return mu[:q]


def label_intersect_rows(rows_s: LabelRows, rows_t: LabelRows,
                         n_sentinel: int, *, codec: str = "none",
                         bq=8, chunk=128, backend=None):
    """μ over gathered ``LabelRows`` in either codec.

    codec "none" routes to the plain wrapper; "delta16" pads the
    compressed planes (delta pad = -1 marker, so padded slots decode to
    the sentinel) and runs the fused decode+join kernel — the reference
    backend decodes with jnp and reuses the searchsorted merge."""
    if codec == "none":
        return label_intersect(rows_s.ids, rows_s.d, rows_t.ids, rows_t.d,
                               n_sentinel, bq=bq, chunk=chunk,
                               backend=backend)
    backend = resolve_backend(backend)
    if backend == "reference":
        ids_s, d_s = decode_rows(rows_s, n_sentinel, codec)
        ids_t, d_t = decode_rows(rows_t, n_sentinel, codec)
        return label_intersect_ref(ids_s, d_s, ids_t, d_t, n_sentinel)
    bq = max(bq, 16)                 # int16 planes tile at (16, 128)
    q, l = rows_s.ids.shape
    qp = -(-q // bq) * bq
    lp = -(-l // chunk) * chunk

    def pad_delta(x):
        return jnp.pad(x, ((0, qp - q), (0, lp - l)),
                       constant_values=PAD_DELTA)

    def pad_d(x):
        fill = jnp.inf if x.dtype == jnp.float32 else PAD_D
        return jnp.pad(x, ((0, qp - q), (0, lp - l)), constant_values=fill)

    def pad_base(x):
        return jnp.pad(x, (0, qp - q))

    mu = label_intersect_packed_kernel(
        pad_delta(rows_s.ids), pad_base(rows_s.base), pad_d(rows_s.d),
        pad_delta(rows_t.ids), pad_base(rows_t.base), pad_d(rows_t.d),
        n_sentinel=n_sentinel, bq=bq, chunk=chunk,
        interpret=pallas_interpret(backend))
    return mu[:q]
