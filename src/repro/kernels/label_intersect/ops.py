"""Backend-aware wrapper with shape padding for the label-intersect
kernel. ``backend`` selects pallas / interpret / jnp-reference (see
``repro.kernels.backend``); the legacy ``interpret=`` kwarg still forces
the pallas program when given explicitly."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.label_intersect.kernel import label_intersect_kernel
from repro.kernels.label_intersect.ref import label_intersect_ref


def label_intersect(ids_s, d_s, ids_t, d_t, n_sentinel: int, *,
                    bq=8, chunk=128, backend=None, interpret=None):
    backend = resolve_backend(backend, interpret)
    if backend == "reference":
        return label_intersect_ref(ids_s.astype(jnp.int32),
                                   d_s.astype(jnp.float32),
                                   ids_t.astype(jnp.int32),
                                   d_t.astype(jnp.float32), n_sentinel)
    q, l = ids_s.shape
    qp = -(-q // bq) * bq
    lp = -(-l // chunk) * chunk

    def padi(x):
        return jnp.pad(x, ((0, qp - q), (0, lp - l)),
                       constant_values=n_sentinel)

    def padd(x):
        return jnp.pad(x, ((0, qp - q), (0, lp - l)), constant_values=jnp.inf)

    mu = label_intersect_kernel(
        padi(ids_s.astype(jnp.int32)), padd(d_s.astype(jnp.float32)),
        padi(ids_t.astype(jnp.int32)), padd(d_t.astype(jnp.float32)),
        n_sentinel=n_sentinel, bq=bq, chunk=chunk,
        interpret=pallas_interpret(backend))
    return mu[:q]
