"""Pure-jnp oracle for the ELL min-plus relaxation round."""
import jax.numpy as jnp


def spmv_relax_ref(dist, nbr_ids, nbr_w):
    gathered = dist[:, nbr_ids]                     # [Q, V, D]
    cand = jnp.min(gathered + nbr_w[None], axis=2)  # [Q, V]
    return jnp.minimum(dist, cand)
