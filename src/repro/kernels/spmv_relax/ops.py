"""Wrapper: COO core graph -> ELL (row-split for high-degree vertices) +
padding + jit'd kernel invocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmv_relax.kernel import spmv_relax_kernel


def coo_to_ell(n_v: int, src, dst, w, d_width: int = 16):
    """Convert COO (src -> dst relaxation direction) into ELL rows of
    width d_width. Vertices with in-degree > d_width get *duplicate ELL
    row groups* folded via extra virtual rounds — here we instead grow
    the width to the max in-degree rounded up to a multiple of d_width
    (simple and exact; G_k degrees are bounded in practice)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = np.asarray(w, np.float32)
    indeg = np.bincount(dst, minlength=n_v)
    width = max(d_width, int(-(-max(1, indeg.max()) // d_width) * d_width))
    ids = np.zeros((n_v, width), np.int32)
    ws = np.full((n_v, width), np.inf, np.float32)
    fill = np.zeros(n_v, np.int64)
    for e in range(len(src)):
        v = dst[e]
        ids[v, fill[v]] = src[e]
        ws[v, fill[v]] = w[e]
        fill[v] += 1
    return jnp.asarray(ids), jnp.asarray(ws)


def spmv_relax(dist, nbr_ids, nbr_w, *, bq=8, bv=128, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, v = dist.shape
    qp = -(-q // bq) * bq
    vp = -(-v // bv) * bv
    dist_p = jnp.pad(dist.astype(jnp.float32), ((0, qp - q), (0, vp - v)),
                     constant_values=jnp.inf)
    ids_p = jnp.pad(nbr_ids, ((0, vp - v), (0, 0)))
    w_p = jnp.pad(nbr_w, ((0, vp - v), (0, 0)), constant_values=jnp.inf)
    out = spmv_relax_kernel(dist_p, ids_p, w_p, bq=bq, bv=bv,
                            interpret=interpret)
    return out[:q, :v]
