"""Wrapper: COO core graph -> ELL (fixed-width in-neighbor lists) +
padding + backend-aware kernel invocation."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.spmv_relax.kernel import spmv_relax_kernel
from repro.kernels.spmv_relax.ref import spmv_relax_ref


def ell_layout(n_v: int, dst, d_width: int = 16):
    """Slot assignment for the ELL conversion: stable-sort edges by dst,
    each edge's slot is its rank within the dst group (position minus
    the group's CSR offset). Returns ``(order, rows, slots, width)`` so
    callers can scatter any per-edge payload (weights, via vertices for
    path reconstruction) into identically-aligned ELL planes.
    """
    dst = np.asarray(dst, np.int64)
    indeg = np.bincount(dst, minlength=n_v)
    width = max(d_width, int(-(-max(1, indeg.max(initial=0)) // d_width)
                             * d_width))
    if len(dst) == 0:
        empty = np.zeros(0, np.int64)
        return empty, empty, empty, width
    order = np.argsort(dst, kind="stable")
    d_sorted = dst[order]
    indptr = np.concatenate([[0], np.cumsum(indeg)])
    rank = np.arange(len(dst), dtype=np.int64) - indptr[d_sorted]
    return order, d_sorted, rank, width


def coo_to_ell(n_v: int, src, dst, w, d_width: int = 16):
    """Convert COO (src -> dst relaxation direction) into ELL rows of
    width d_width. Vertices with in-degree > d_width get *duplicate ELL
    row groups* folded via extra virtual rounds — here we instead grow
    the width to the max in-degree rounded up to a multiple of d_width
    (simple and exact; G_k degrees are bounded in practice).
    """
    src = np.asarray(src, np.int32)
    w = np.asarray(w, np.float32)
    order, rows, slots, width = ell_layout(n_v, dst, d_width)
    ids = np.zeros((n_v, width), np.int32)
    ws = np.full((n_v, width), np.inf, np.float32)
    if len(src):
        ids[rows, slots] = src[order]
        ws[rows, slots] = w[order]
    return jnp.asarray(ids), jnp.asarray(ws)


def spmv_relax(dist, nbr_ids, nbr_w, *, bq=8, bv=128, backend=None,
               interpret=None):
    backend = resolve_backend(backend, interpret)
    if backend == "reference":
        return spmv_relax_ref(dist.astype(jnp.float32), nbr_ids, nbr_w)
    q, v = dist.shape
    qp = -(-q // bq) * bq
    vp = -(-v // bv) * bv
    dist_p = jnp.pad(dist.astype(jnp.float32), ((0, qp - q), (0, vp - v)),
                     constant_values=jnp.inf)
    ids_p = jnp.pad(nbr_ids, ((0, vp - v), (0, 0)))
    w_p = jnp.pad(nbr_w, ((0, vp - v), (0, 0)), constant_values=jnp.inf)
    out = spmv_relax_kernel(dist_p, ids_p, w_p, bq=bq, bv=bv,
                            interpret=pallas_interpret(backend))
    return out[:q, :v]
