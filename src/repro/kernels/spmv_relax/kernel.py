"""ELL min-plus SpMV Pallas kernel — one wavefront-relaxation round.

new_dist[q, v] = min(dist[q, v], min_j dist[q, nbr[v, j]] + w[v, j])

This is the inner loop of the label-seeded core search (paper Alg. 1
stage 2) for a batch of queries: the core graph G_k in ELL layout
(fixed-width in-neighbor lists — G_k is degree-bounded after peeling;
overflow rows are split by the wrapper). The whole per-query distance
row stays VMEM-resident (G_k is small by construction — the paper's
central design point) while output vertex tiles stream through the grid.

TPU note: the inner gather is a VMEM-local vector gather (Mosaic
`dynamic_gather`); on hardware this kernel is gather-bound, which is
still far better than HBM-scatter Bellman-Ford since dist rows never
leave VMEM between rounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relax_kernel(dist_row_ref, dist_tile_ref, nbr_ref, w_ref, o_ref):
    dist_row = dist_row_ref[...]          # [bq, V]
    ids = nbr_ref[...]                    # [bv, D] int32 (pad -> col 0)
    w = w_ref[...]                        # [bv, D] float32 (pad -> inf)
    bq = dist_row.shape[0]
    bv, d = ids.shape
    flat = ids.reshape(-1)                # [bv*D]
    gathered = jnp.take(dist_row, flat, axis=1).reshape(bq, bv, d)
    cand = jnp.min(gathered + w[None, :, :], axis=2)       # [bq, bv]
    o_ref[...] = jnp.minimum(dist_tile_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("bq", "bv", "interpret"))
def spmv_relax_kernel(dist, nbr_ids, nbr_w, *, bq=8, bv=128, interpret=False):
    """dist: [Q, V] f32; nbr_ids: [V, D] int32 in [0, V); nbr_w: [V, D]
    (+inf padding). Q % bq == 0, V % bv == 0. Returns relaxed [Q, V]."""
    q, v = dist.shape
    v2, d = nbr_ids.shape
    assert v == v2 and q % bq == 0 and v % bv == 0
    return pl.pallas_call(
        _relax_kernel,
        grid=(q // bq, v // bv),
        in_specs=[
            pl.BlockSpec((bq, v), lambda i, j: (i, 0)),   # full dist rows
            pl.BlockSpec((bq, bv), lambda i, j: (i, j)),  # self tile
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, v), jnp.float32),
        interpret=interpret,
    )(dist, dist, nbr_ids, nbr_w)
