"""ELL min-plus SpMV Pallas kernels — wavefront relaxation rounds.

new_dist[q, v] = min(dist[q, v], min_j dist[q, nbr[v, j]] + w[v, j])

This is the inner loop of the label-seeded core search (paper Alg. 1
stage 2) for a batch of queries: the core graph G_k in ELL layout
(fixed-width in-neighbor lists — G_k is degree-bounded after peeling;
overflow rows are split by the wrapper).

Two kernels:

``spmv_relax_kernel`` — ONE round per launch. The whole per-query
distance row stays VMEM-resident (G_k is small by construction — the
paper's central design point) while output vertex tiles stream through
the grid; the round loop lives outside in ``lax.while_loop``
(`dispatch._core_relax_ell`), re-reading dist from HBM every round.

``fused_relax_kernel`` — ALL rounds in one launch. Each grid step owns
a [bq, V] block of stacked query frontiers; the block, the ELL planes,
and the round loop live entirely in VMEM, with the fixed-point early
exit (``improved & it < max_rounds``) inside the kernel. Per-block
round counts come out as a second output; their max equals the global
round count (rows relax independently, so a block at its fixed point
stays bitwise-frozen through extra rounds elsewhere). Compulsory HBM
traffic drops from O(rounds · Q·V) to O(Q·V) — see
benchmarks/roofline_report.py and docs/KERNELS.md.

TPU note: the inner gather is a VMEM-local vector gather (Mosaic
`dynamic_gather`); on hardware these kernels are gather-bound, which is
still far better than HBM-scatter Bellman-Ford since dist rows never
leave VMEM between (fused: during) rounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relax_kernel(dist_row_ref, dist_tile_ref, nbr_ref, w_ref, o_ref):
    dist_row = dist_row_ref[...]          # [bq, V]
    ids = nbr_ref[...]                    # [bv, D] int32 (pad -> col 0)
    w = w_ref[...]                        # [bv, D] float32 (pad -> inf)
    bq = dist_row.shape[0]
    bv, d = ids.shape
    flat = ids.reshape(-1)                # [bv*D]
    gathered = jnp.take(dist_row, flat, axis=1).reshape(bq, bv, d)
    cand = jnp.min(gathered + w[None, :, :], axis=2)       # [bq, bv]
    o_ref[...] = jnp.minimum(dist_tile_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("bq", "bv", "interpret"))
def spmv_relax_kernel(dist, nbr_ids, nbr_w, *, bq=8, bv=128, interpret=False):
    """dist: [Q, V] f32; nbr_ids: [V, D] int32 in [0, V); nbr_w: [V, D]
    (+inf padding). Q % bq == 0, V % bv == 0. Returns relaxed [Q, V]."""
    q, v = dist.shape
    v2, d = nbr_ids.shape
    assert v == v2 and q % bq == 0 and v % bv == 0
    return pl.pallas_call(
        _relax_kernel,
        grid=(q // bq, v // bv),
        in_specs=[
            pl.BlockSpec((bq, v), lambda i, j: (i, 0)),   # full dist rows
            pl.BlockSpec((bq, bv), lambda i, j: (i, j)),  # self tile
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, v), jnp.float32),
        interpret=interpret,
    )(dist, dist, nbr_ids, nbr_w)


def _fused_kernel(dist_ref, nbr_ref, w_ref, o_ref, rounds_ref, *,
                  max_rounds):
    d0 = dist_ref[...]                    # [bq, V] persistent block
    ids = nbr_ref[...]                    # [V, D] int32 (pad -> col 0)
    w = w_ref[...]                        # [V, D] float32 (pad -> inf)
    bq = d0.shape[0]
    v, dcap = ids.shape
    flat = ids.reshape(-1)

    # Jacobi rounds: every candidate reads the *previous* round's
    # distances, exactly like the per-round kernel — that synchronous
    # semantics is what makes all relaxation paths bitwise-equal.
    def round_(state):
        d, it, _ = state
        gathered = jnp.take(d, flat, axis=1).reshape(bq, v, dcap)
        cand = jnp.min(gathered + w[None, :, :], axis=2)
        d2 = jnp.minimum(d, cand)
        return d2, it + 1, jnp.any(d2 < d)

    def cond(state):
        _, it, improved = state
        return improved & (it < max_rounds)

    d, it, _ = jax.lax.while_loop(cond, round_,
                                  (d0, jnp.int32(0), jnp.bool_(True)))
    o_ref[...] = d
    rounds_ref[...] = jnp.full(rounds_ref.shape, it, jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("max_rounds", "bq", "interpret"))
def fused_relax_kernel(dist, nbr_ids, nbr_w, *, max_rounds: int, bq=8,
                       interpret=False):
    """All relaxation rounds in one launch. dist: [Q, V] f32 seeds
    (Q % bq == 0); nbr_ids/nbr_w: [V, D] ELL planes. Returns
    (fixed-point dist [Q, V], per-block rounds int32[Q // bq]) —
    ``max(rounds)`` is the batch's round count, bitwise-identical to
    the per-round loop's."""
    q, v = dist.shape
    v2, d = nbr_ids.shape
    assert v == v2 and q % bq == 0
    kern = functools.partial(_fused_kernel, max_rounds=max_rounds)
    return pl.pallas_call(
        kern,
        grid=(q // bq,),
        in_specs=[
            pl.BlockSpec((bq, v), lambda i: (i, 0)),
            pl.BlockSpec((v, d), lambda i: (0, 0)),
            pl.BlockSpec((v, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, v), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, v), jnp.float32),
            jax.ShapeDtypeStruct((q // bq,), jnp.int32),
        ],
        interpret=interpret,
    )(dist, nbr_ids, nbr_w)


def fused_vmem_bytes(v: int, d_width: int, bq: int = 8) -> int:
    """Working-set estimate for one fused-kernel grid step: the [bq, V]
    block (x2 for the carry copy), the ELL planes, and the gather
    intermediate [bq, V, D]. The dispatch layer falls back to the
    per-round loop when this exceeds its VMEM budget."""
    return 4 * (2 * bq * v + 2 * v * d_width + bq * v * d_width)
