"""Tropical (min-plus) matmul Pallas kernel: C[i,j] = min_k A[i,k]+B[k,j].

This is the compute hot-spot of IS-LABEL re-expressed for the TPU: the
paper's block-nested-loop label join (Alg. 4) and the label-seeded core
search are both min-plus products (distance vectors × distance-preserving
adjacency). The MXU only does mul-add, so min-plus runs on the VPU —
the tiling below keeps operand tiles VMEM-resident and hardware-aligned
(multiples of 8×128 lanes) exactly like a dense GEMM, with the k-grid
dimension innermost so each (i,j) output tile accumulates in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minplus_kernel(a_ref, b_ref, o_ref):
    """Grid = (M/bm, N/bn, K/bk); K innermost (default row-major order)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    a = a_ref[...]                      # [bm, bk]
    b = b_ref[...]                      # [bk, bn]
    # min over k of a[i,k]+b[k,j]; fori over bk keeps the VMEM footprint
    # at bm*bn instead of bm*bk*bn.
    def body(k, acc):
        return jnp.minimum(acc, a[:, k][:, None] + b[k, :][None, :])
    acc = jax.lax.fori_loop(0, a.shape[1], body,
                            jnp.full(o_ref.shape, jnp.inf, o_ref.dtype))
    o_ref[...] = jnp.minimum(o_ref[...], acc)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_matmul_kernel(a, b, *, bm=128, bn=128, bk=128, interpret=False):
    """A: [M, K], B: [K, N] (M, N, K multiples of the block shape —
    callers pad with +inf; inf is the min-plus zero so padding is exact).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
