"""Pure-jnp oracle for the min-plus matmul."""
import jax.numpy as jnp


def minplus_matmul_ref(a, b):
    """C[i,j] = min_k A[i,k] + B[k,j] (naive; test shapes only)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)
