"""Public backend-aware wrapper: arbitrary shapes via +inf padding (the
min-plus identity); pallas / interpret / jnp-reference selection."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.minplus_matmul.kernel import minplus_matmul_kernel
from repro.kernels.minplus_matmul.ref import minplus_matmul_ref


def _pad_to(x, rows, cols, fill):
    r, c = x.shape
    return jnp.pad(x, ((0, rows - r), (0, cols - c)), constant_values=fill)


def minplus_matmul(a, b, *, bm=128, bn=128, bk=128, backend=None,
                   interpret=None):
    """min-plus product for arbitrary [M,K]x[K,N] float32 inputs."""
    backend = resolve_backend(backend, interpret)
    if backend == "reference":
        return minplus_matmul_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    m, k = a.shape
    _, n = b.shape
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    ap = _pad_to(a.astype(jnp.float32), mp, kp, jnp.inf)
    bp = _pad_to(b.astype(jnp.float32), kp, np_, jnp.inf)
    out = minplus_matmul_kernel(ap, bp, bm=bm, bn=bn, bk=bk,
                                interpret=pallas_interpret(backend))
    return out[:m, :n]
