"""Public jit'd wrapper: arbitrary shapes via +inf padding (the min-plus
identity), interpret-mode fallback on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.minplus_matmul.kernel import minplus_matmul_kernel


def _pad_to(x, rows, cols, fill):
    r, c = x.shape
    return jnp.pad(x, ((0, rows - r), (0, cols - c)), constant_values=fill)


def minplus_matmul(a, b, *, bm=128, bn=128, bk=128, interpret=None):
    """min-plus product for arbitrary [M,K]x[K,N] float32 inputs."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = a.shape
    _, n = b.shape
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    ap = _pad_to(a.astype(jnp.float32), mp, kp, jnp.inf)
    bp = _pad_to(b.astype(jnp.float32), kp, np_, jnp.inf)
    out = minplus_matmul_kernel(ap, bp, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)
    return out[:m, :n]
