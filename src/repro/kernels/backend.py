"""Kernel backend resolution shared by every ``kernels/*/ops.py`` wrapper.

Three execution backends, one policy point:

  * ``"pallas"``    — compiled ``pallas_call`` (TPU; the production path).
  * ``"interpret"`` — ``pallas_call(interpret=True)``: the same kernel
    program evaluated with jnp ops. Bit-identical to ``"pallas"`` logic,
    runs anywhere; used for off-TPU parity tests and debugging.
  * ``"reference"`` — the pure-jnp oracle in ``kernels/*/ref.py``
    (searchsorted merge, dense gather). Fastest off-TPU, and the
    numerical baseline every kernel is validated against.

``"auto"`` (the default) picks ``"pallas"`` on TPU and ``"reference"``
elsewhere, so CPU containers never pay interpret-mode overhead unless a
caller asks for it. The ``ISLABEL_BACKEND`` environment variable
overrides ``"auto"`` globally (serving knob; no code change needed).
"""
from __future__ import annotations

import os

import jax

BACKENDS = ("pallas", "interpret", "reference")
ENV_VAR = "ISLABEL_BACKEND"


def resolve_backend(backend: str | None = None,
                    interpret: bool | None = None) -> str:
    """Map a requested backend (or None/"auto") to a concrete one.

    ``interpret`` is the kernel wrappers' legacy explicit override: when
    given, it forces the pallas program (interpret or compiled) and
    ``backend`` is ignored.
    """
    if interpret is not None:
        return "interpret" if interpret else "pallas"
    if backend in (None, "auto"):
        backend = os.environ.get(ENV_VAR, "auto")
    if backend in (None, "auto"):
        backend = "pallas" if jax.default_backend() == "tpu" else "reference"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS} or 'auto'")
    return backend


def pallas_interpret(backend: str) -> bool:
    """``interpret`` flag for a pallas_call under a resolved backend.

    Callers must only use this for backends in {"pallas", "interpret"}.
    """
    return backend != "pallas"
