"""`ShardedQueryEngine` — Algorithm 1 over P label partitions.

Per batch, every shard runs both stages on its own block through the
same kernel dispatch layer the unsharded `QueryEngine` uses:

  stage 1  μ_p = Equation 1 over the shard's label block
           (``label_intersect_dispatch``). Ancestor-partitioned blocks
           make every (s, t) match shard-local, so μ = min_p μ_p.
  stage 2  the label-seeded core relaxation, shard-locally: the top
           hierarchy levels are replicated into every block
           (partition.py), so each shard scatters the *complete* core
           seed frontier and relaxes G_k to the identical fixed point —
           bit-for-bit the unsharded ds/dt (the sentinel column may
           hold different parked non-core entries per shard, but no
           core edge reads or writes it and ``through_core`` excludes
           it).

  answer   ans_p = min(μ_p, through_core); one ``lax.pmin`` over the
           mesh's shard axis — the batch's single collective — yields
           min_p ans_p = min(μ, through_core) = ``QueryEngine.batch_fn``
           bitwise (float min is exact under any grouping). ``rounds``
           is identical on every shard (same seeds, same rounds), so it
           leaves the shard_map as a replicated output, not a second
           collective.

Serving contract mirrors `QueryEngine`: ``batch_fn``/``mu_batch_fn``
return jitted fixed-shape callables memoized per resolved backend with
no host sync inside, and ``warmup`` pre-compiles every batch size so
the serving path never triggers XLA compilation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dispatch import (CoreRelaxer,
                                 label_intersect_rows_dispatch)
from repro.core.labels import LabelRows, decode_rows
from repro.core.query import QueryEngine
from repro.kernels.backend import resolve_backend
from repro.obs.registry import REGISTRY

__all__ = ["ShardedQueryEngine"]


class ShardedQueryEngine:
    """Device-resident sharded query state + compiled entry points.

    ``lbl_ids``/``lbl_d``: [P, n+1, cap_s] blocks laid out over the
    mesh's ``shard`` axis (one partition per device slice); core state
    (``core_pos`` and the local-index COO edges) replicated.

    ``enc``/``codec``: compressed label planes (``repro.core.labels``
    delta16) sharded identically — per-shard blocks encode row-locally,
    so each shard decodes its own block in-kernel and the pmin'd answer
    stays bitwise-equal to the unsharded engine.
    """

    def __init__(self, lbl_ids, lbl_d, core_pos, core_local_edges, n: int,
                 n_core: int, mesh, max_rounds: int = 0,
                 backend: str = "auto", enc=None, codec: str = "none"):
        self.lbl_ids = lbl_ids
        self.lbl_d = lbl_d
        self.core_pos = core_pos
        self.ce_src, self.ce_dst, self.ce_w = core_local_edges
        self.n = n
        self.n_core = n_core
        self.mesh = mesh
        self.axis = mesh.axis_names[0]
        self.num_shards = mesh.shape[self.axis]
        self.cap = lbl_ids.shape[2]
        self.max_rounds = max_rounds if max_rounds > 0 else max(n_core, 1)
        self.backend = backend
        self.codec = codec
        if codec == "none":
            self.enc_ids, self.enc_base, self.enc_d = lbl_ids, None, lbl_d
        else:
            self.enc_ids, self.enc_base, self.enc_d = enc
        self.relaxer = CoreRelaxer(self.ce_src, self.ce_dst, self.ce_w,
                                   n_core) if n_core > 0 else None
        self._batch_fns: dict = {}
        self._mu_batch_fns: dict = {}

    # ------------------------------------------------------ shard-local
    # The unsharded seed scatter applied to one shard's label rows
    # yields a frontier identical on every shard in the real columns
    # (core ancestors are replicated into every block); non-core
    # entries park in the sentinel column n_core, which stage 2
    # ignores. Shared with QueryEngine so the bitwise contract cannot
    # drift between the twins.
    _seed = QueryEngine._seed

    def _shard_block(self, blk: LabelRows, s, t, backend: str,
                     mu_only: bool):
        """Both stages on one shard's block (``blk``: the shard's label
        planes in the active codec). Runs inside shard_map; the only
        collective is the final pmin over the shard axis."""
        with jax.named_scope("islabel.shard_block"):
            rows_s = LabelRows(
                blk.ids[s], None if blk.base is None else blk.base[s],
                blk.d[s])
            rows_t = LabelRows(
                blk.ids[t], None if blk.base is None else blk.base[t],
                blk.d[t])
            mu = label_intersect_rows_dispatch(rows_s, rows_t, self.n,
                                               self.codec, backend)
            if mu_only:
                return jax.lax.pmin(mu, self.axis)
            if self.n_core == 0:
                return jax.lax.pmin(mu, self.axis), jnp.int32(0)
            ids_s, d_s = decode_rows(rows_s, self.n, self.codec)
            ids_t, d_t = decode_rows(rows_t, self.n, self.codec)
            seed_s = self._seed(ids_s, d_s)
            seed_t = self._seed(ids_t, d_t)
            ans, _, _, rounds = self.relaxer.run(seed_s, seed_t, mu,
                                                 self.max_rounds, backend)
            return jax.lax.pmin(ans, self.axis), rounds

    def _make_fn(self, backend: str, mu_only: bool):
        blocks = P(self.axis, None, None)
        out_specs = P() if mu_only else (P(), P())

        # rounds is bitwise-identical across shards (identical seeds in
        # the real columns -> identical relaxation), so out_spec P()
        # with check_rep=False just adopts the replicated value.
        if self.codec == "none":
            def shard_fn(blk_ids, blk_d, s, t):
                # the per-device block keeps a leading axis of size 1
                return self._shard_block(
                    LabelRows(blk_ids[0], None, blk_d[0]), s, t,
                    backend, mu_only)

            mapped = shard_map(shard_fn, mesh=self.mesh,
                               in_specs=(blocks, blocks, P(), P()),
                               out_specs=out_specs, check_rep=False)

            def run(s, t):
                return mapped(self.lbl_ids, self.lbl_d,
                              jnp.asarray(s, jnp.int32),
                              jnp.asarray(t, jnp.int32))
        else:
            base_blocks = P(self.axis, None)

            def shard_fn(blk_ids, blk_base, blk_d, s, t):
                return self._shard_block(
                    LabelRows(blk_ids[0], blk_base[0], blk_d[0]), s, t,
                    backend, mu_only)

            mapped = shard_map(
                shard_fn, mesh=self.mesh,
                in_specs=(blocks, base_blocks, blocks, P(), P()),
                out_specs=out_specs, check_rep=False)

            def run(s, t):
                return mapped(self.enc_ids, self.enc_base, self.enc_d,
                              jnp.asarray(s, jnp.int32),
                              jnp.asarray(t, jnp.int32))
        return self._counted(jax.jit(run), "mu" if mu_only else "full")

    def _counted(self, fn, path: str):
        """Host-side dispatch counter around a jitted entry point:
        ``shard.batches{path,shards}`` in the process registry. The jit
        ``_cache_size`` probe is forwarded so the zero-compile audits
        (``DistanceServer.compile_cache_sizes``) see through the wrap."""
        calls = REGISTRY.counter("shard.batches",
                                 "sharded batch dispatches")
        labels = {"path": path, "shards": str(self.num_shards)}

        def run(s, t):
            calls.inc(1, **labels)
            return fn(s, t)

        if hasattr(fn, "_cache_size"):
            run._cache_size = fn._cache_size
        run.__wrapped__ = fn
        return run

    # ------------------------------------------------------- serving APIs
    def batch_fn(self, backend: str | None = None):
        """Jitted ``run(s, t) -> (ans float32[Q], rounds int32 scalar)``
        — the sharded twin of ``QueryEngine.batch_fn`` (bitwise-equal
        answers), memoized per resolved backend."""
        backend = resolve_backend(self.backend if backend is None else backend)
        if backend not in self._batch_fns:
            self._batch_fns[backend] = self._make_fn(backend, mu_only=False)
        return self._batch_fns[backend]

    def mu_batch_fn(self, backend: str | None = None):
        """Jitted Equation-1-only ``run(s, t) -> ans float32[Q]`` — the
        μ-exact routed lane, sharded (per-shard partial μ + one pmin)."""
        backend = resolve_backend(self.backend if backend is None else backend)
        if backend not in self._mu_batch_fns:
            self._mu_batch_fns[backend] = self._make_fn(backend, mu_only=True)
        return self._mu_batch_fns[backend]

    def query(self, s, t, backend: str | None = None):
        """Batched distances (compiles per distinct batch shape; serving
        goes through the pre-warmed bucketed ``batch_fn`` instead)."""
        ans, _ = self.batch_fn(backend)(s, t)
        return ans

    def query_mu_only(self, s, t, backend: str | None = None):
        return self.mu_batch_fn(backend)(s, t)

    # warmup pre-compiles the *sharded* entry points per batch size
    # (same contract, same {(path, size): seconds} report); classify
    # reads no engine state — both reuse the QueryEngine logic.
    warmup = QueryEngine.warmup
    classify = QueryEngine.classify

    def collective_count(self, batch_size: int = 8,
                         backend: str | None = None) -> int:
        """Number of cross-shard collectives in one full-path batch —
        asserted to be exactly 1 in tests (the closed-jaxpr pmin count;
        no per-shard host round trips by construction)."""
        fn = self.batch_fn(backend)
        z = jnp.zeros(int(batch_size), jnp.int32)
        jaxpr = jax.make_jaxpr(lambda s, t: fn(s, t))(z, z)
        text = str(jaxpr)
        count = sum(text.count(f"{prim}[")
                    for prim in ("pmin", "pmax", "psum"))
        REGISTRY.gauge("shard.collectives_per_batch",
                       "cross-shard collectives per full-path batch").set(
            count, shards=str(self.num_shards))
        return count
