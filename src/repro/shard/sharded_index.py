"""`ShardedIndex` — an IS-LABEL index hosted as P label partitions.

  sidx = ShardedIndex.from_index(idx, num_shards=4)      # slice + place
  sidx = ShardedIndex.build(n, src, dst, w, cfg, num_shards=4)
  ans, rounds = sidx.engine.batch_fn()(s, t)   # bitwise == unsharded
  sidx.save(dir); ShardedIndex.load(dir)
  DistanceServer(sidx)                         # serving, sharded lane

No device holds the full label table: shard p's block carries its
ancestor partition plus the replicated top hierarchy levels
(``partition.py``), stacked [P, n+1, cap_s] and laid over a 1-D
``jax.sharding.Mesh`` shard axis via the ``"graph_index"`` logical-axis
rules in ``repro.distributed.sharding`` (label_shard → mesh shard;
vertex rows, levels, and the core graph replicated). Queries run
through ``ShardedQueryEngine`` (shard_map + one pmin per batch).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.config import BuildStats, IndexConfig
from repro.distributed.sharding import FAMILY_RULES, tree_shardings
from repro.shard.partition import (LabelBlocks, assign_shards,
                                   partition_labels)
from repro.shard.query import ShardedQueryEngine

# logical axes of every placed leaf, resolved through the family rules
_AXES_TREE = {
    "lbl_ids": ("label_shard", "vertex", "label_slot"),
    "lbl_d": ("label_shard", "vertex", "label_slot"),
    # compressed planes (core/labels.py delta16) shard like the planes
    # they encode; the per-row base drops the slot axis
    "lbl_delta": ("label_shard", "vertex", "label_slot"),
    "lbl_base": ("label_shard", "vertex"),
    "lbl_denc": ("label_shard", "vertex", "label_slot"),
    "core_pos": ("vertex",),
    "ce_src": ("core_edge",),
    "ce_dst": ("core_edge",),
    "ce_w": ("core_edge",),
}


def make_shard_mesh(num_shards: int) -> Mesh:
    """1-D mesh over the first ``num_shards`` local devices."""
    devs = jax.devices()
    if num_shards > len(devs):
        raise ValueError(
            f"num_shards={num_shards} exceeds the {len(devs)} available "
            f"device(s); simulate more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return Mesh(np.asarray(devs[:num_shards]), ("shard",))


@dataclasses.dataclass
class ShardedIndex:
    """Duck-types the `ISLabelIndex` surface the serving layer uses
    (n/k/level/stats/engine/query), with partitioned label state."""
    n: int
    k: int
    num_shards: int
    strategy: str
    replicate_top: int
    cfg: IndexConfig
    level: np.ndarray            # int32[n] (host, replicated concern)
    shard_of: np.ndarray         # int32[n+1], REPLICATED = -1
    entries_per_shard: np.ndarray  # int64[P]: owned+replicated per shard
    # per-shard label blocks [P, n+1, cap_s]; ids/d sharded over the
    # mesh, pred host-only (queries never read it — like the up-edge
    # matrix it exists for path reconstruction and save/load)
    lbl_ids: jnp.ndarray
    lbl_d: jnp.ndarray
    lbl_pred: np.ndarray
    # core graph (host, global ids) + host core position map
    core_ids: np.ndarray
    core_pos_host: np.ndarray
    core_src: np.ndarray
    core_dst: np.ndarray
    core_w: np.ndarray
    mesh: Mesh
    engine: ShardedQueryEngine
    stats: BuildStats
    # path-reconstruction state (host; queries never read it): the
    # core via bookkeeping and the up-adjacency matrices. None on
    # indexes saved before path support — path queries then raise.
    core_via: np.ndarray | None = None
    up_ids: np.ndarray | None = None
    up_w: np.ndarray | None = None
    up_via: np.ndarray | None = None
    _paths: object = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    # ---------------------------------------------------------- builders
    @staticmethod
    def build(n, src, dst, w, cfg: IndexConfig = IndexConfig(), *,
              num_shards: int = 1, strategy: str = "level",
              replicate_top: int = 1, mesh: Mesh | None = None
              ) -> "ShardedIndex":
        from repro.core.index import ISLabelIndex
        idx = ISLabelIndex.build(n, src, dst, w, cfg)
        return ShardedIndex.from_index(idx, num_shards, strategy=strategy,
                                       replicate_top=replicate_top, mesh=mesh)

    @staticmethod
    def from_index(index, num_shards: int, *, strategy: str = "level",
                   replicate_top: int = 1, mesh: Mesh | None = None
                   ) -> "ShardedIndex":
        """Partition an existing `ISLabelIndex` and place it on devices."""
        shard_of = assign_shards(index.level, index.k, num_shards,
                                 strategy=strategy,
                                 replicate_top=replicate_top)
        blocks = partition_labels(index.lbl_ids, index.lbl_d, index.lbl_pred,
                                  index.n, shard_of, num_shards)
        return ShardedIndex._assemble(
            n=index.n, k=index.k, cfg=index.cfg, level=index.level,
            shard_of=shard_of, blocks=blocks, core_ids=index.core_ids,
            core_pos=index.core_pos_host, core_src=index.core_src,
            core_dst=index.core_dst, core_w=index.core_w,
            stats=index.stats, strategy=strategy,
            replicate_top=replicate_top, mesh=mesh,
            core_via=index.core_via, up_ids=index.up_ids,
            up_w=index.up_w, up_via=index.up_via)

    @staticmethod
    def _assemble(*, n, k, cfg, level, shard_of, blocks: LabelBlocks,
                  core_ids, core_pos, core_src, core_dst, core_w, stats,
                  strategy, replicate_top, mesh, core_via=None,
                  up_ids=None, up_w=None, up_via=None) -> "ShardedIndex":
        num_shards = blocks.num_shards
        if mesh is None:
            mesh = make_shard_mesh(num_shards)
        axis = mesh.axis_names[0]
        if mesh.shape[axis] != num_shards:
            raise ValueError(f"mesh axis {axis!r} has size "
                             f"{mesh.shape[axis]}, need {num_shards}")
        shardings = tree_shardings(_AXES_TREE, FAMILY_RULES["graph_index"],
                                   mesh)
        host = {
            "lbl_ids": blocks.ids, "lbl_d": blocks.d,
            "core_pos": core_pos,
            "ce_src": core_pos[core_src].astype(np.int32),
            "ce_dst": core_pos[core_dst].astype(np.int32),
            "ce_w": np.asarray(core_w, np.float32),
        }
        # per-shard blocks keep the [reals..., pads] row layout the
        # codec requires (partition_labels compacts in source order), so
        # compressed blocks encode row-locally per shard
        codec = "none"
        if cfg.label_dtype != "fp32":
            from repro.core.labels import encode_labels, try_encode_labels
            encode = (encode_labels if cfg.label_dtype == "compressed"
                      else try_encode_labels)
            enc = encode(blocks.ids, blocks.d, n)
            if enc is not None:
                codec = "delta16"
                host["lbl_delta"], host["lbl_base"], host["lbl_denc"] = enc
        dev = {name: jax.device_put(arr, shardings[name])
               for name, arr in host.items()}
        engine = ShardedQueryEngine(
            dev["lbl_ids"], dev["lbl_d"], dev["core_pos"],
            (dev["ce_src"], dev["ce_dst"], dev["ce_w"]),
            n=n, n_core=len(core_ids), mesh=mesh,
            max_rounds=cfg.max_relax_rounds, backend=cfg.query_backend,
            codec=codec,
            enc=None if codec == "none" else (dev["lbl_delta"],
                                              dev["lbl_base"],
                                              dev["lbl_denc"]))
        return ShardedIndex(
            n=n, k=k, num_shards=num_shards, strategy=strategy,
            replicate_top=replicate_top, cfg=cfg, level=np.asarray(level),
            shard_of=shard_of, entries_per_shard=np.asarray(blocks.entries),
            lbl_ids=dev["lbl_ids"], lbl_d=dev["lbl_d"],
            lbl_pred=np.asarray(blocks.pred), core_ids=np.asarray(core_ids),
            core_pos_host=np.asarray(core_pos),
            core_src=np.asarray(core_src), core_dst=np.asarray(core_dst),
            core_w=np.asarray(core_w), mesh=mesh, engine=engine, stats=stats,
            core_via=None if core_via is None else np.asarray(core_via),
            up_ids=None if up_ids is None else np.asarray(up_ids),
            up_w=None if up_w is None else np.asarray(up_w),
            up_via=None if up_via is None else np.asarray(up_via))

    # ------------------------------------------------------------- query
    def query(self, s, t, backend: str | None = None):
        """Exact batched distances — bitwise-equal to the unsharded
        ``ISLabelIndex.query`` on every backend."""
        return self.engine.query(s, t, backend)

    def query_host(self, s, t) -> np.ndarray:
        return np.asarray(self.query(np.atleast_1d(s), np.atleast_1d(t)))

    def query_types(self, s, t):
        return self.engine.classify(s, t, self.level, self.k)

    def shard_entry_counts(self) -> np.ndarray:
        """int64[P]: label entries held per shard (owned + replicated),
        recorded at partition time — no device round trip."""
        return self.entries_per_shard.copy()

    # ------------------------------------------------------------- paths
    def gather_label_rows(self):
        """Reassemble full ``[n+1, l_cap]`` label arrays by gathering
        every vertex's entries from the shard that owns their ancestor
        (plus the replicated top levels) — the bit-exact
        ``unpartition_labels`` inverse asserted in tests. Host-side."""
        from repro.shard.partition import unpartition_labels
        blocks = LabelBlocks(ids=np.asarray(self.lbl_ids),
                             d=np.asarray(self.lbl_d),
                             pred=np.asarray(self.lbl_pred),
                             entries=self.entries_per_shard)
        return unpartition_labels(blocks, self.n, self.cfg.l_cap)

    def path_engine(self):
        """Batched path reconstruction over the sharded index
        (docs/PATHS.md): label rows are gathered once from the owning
        shards' blocks and the identical ``repro.paths.PathEngine`` is
        built over them, so sharded and unsharded path answers agree
        bitwise. Paths are a lower-QPS workload than distances; the
        distance hot path keeps the labels partitioned."""
        if self._paths is None:
            if self.up_ids is None:
                raise ValueError(
                    "this ShardedIndex was saved without path state "
                    "(up-edge matrices); rebuild with "
                    "ShardedIndex.from_index to serve path queries")
            from repro.paths import PathEngine
            ids, d, pred = self.gather_label_rows()
            self._paths = PathEngine(
                n=self.n, k=self.k, lbl_ids=ids, lbl_d=d, lbl_pred=pred,
                up_ids=self.up_ids, up_w=self.up_w, up_via=self.up_via,
                core_ids=self.core_ids, core_pos=self.core_pos_host,
                core_src=self.core_src, core_dst=self.core_dst,
                core_w=self.core_w, core_via=self.core_via,
                max_rounds=self.cfg.max_relax_rounds,
                backend=self.cfg.query_backend,
                relaxer=self.engine.relaxer)
        return self._paths

    def shortest_paths(self, s, t, hop_cap: int = 256,
                       backend: str | None = None):
        """Batched shortest paths — same contract as
        ``ISLabelIndex.shortest_paths``."""
        return self.path_engine().paths(s, t, hop_cap=hop_cap,
                                        backend=backend)

    def shortest_path(self, s: int, t: int):
        """Scalar convenience mirroring ``ISLabelIndex.shortest_path``
        (used as the serving fallback for hop_cap overflows). Unlike
        the host-recursive oracle this runs the batched engine with
        escalating hop_cap — a finite distance with an empty path means
        the escalation ceiling was hit and no path was recovered."""
        dist, paths, ok = self.shortest_paths([s], [t])
        return float(dist[0]), paths[0]

    # --------------------------------------------------------- mutations
    def apply_mutations(self, ops):
        """§8.3 insert/delete batch over the partitioned label blocks.

        Functional: returns ``(new_index, info)`` and leaves this index
        untouched (callers re-register, e.g. through
        ``IndexRegistry.install``'s drain path). The shared host
        mutators (``repro.core.index``) run over the gathered label
        rows, then the change propagates *per touched row, per owning
        shard*: a block row is rewritten only where its kept-entry
        slice actually changed, so a delete of a shard-owned ancestor
        touches exactly that shard's block while mutated replicated
        (core-level) entries — every insert, since inserted vertices
        join the core — rebuild the touched rows of all blocks. Every
        other block row is bitwise-preserved (asserted in tests).

        The vertex→shard map keeps its original assignment (re-running
        ``assign_shards`` would reshuffle the round-robin ranks and
        spuriously migrate untouched entries); inserted vertices become
        core and are marked REPLICATED. The rebuilt
        ``ShardedQueryEngine`` compiles fresh entry points — sharded
        mutation is a swap-and-rewarm operation, not a zero-recompile
        one (docs/MUTATION.md).

        ``info``: {"touched_rows", "touched_shards", "inserted"}.
        """
        from types import SimpleNamespace

        from repro.core.index import apply_delete_host, apply_insert_host
        from repro.shard.partition import REPLICATED
        if self.up_ids is None:
            raise ValueError(
                "this ShardedIndex was saved without the up-edge "
                "matrices; §8.3 mutations need them — rebuild with "
                "ShardedIndex.from_index")
        ids_h, d_h, pred_h = self.gather_label_rows()
        st = SimpleNamespace(
            n=self.n, k=self.k, level=self.level.copy(),
            up_ids=self.up_ids, up_w=self.up_w,
            core_src=self.core_src.copy(), core_dst=self.core_dst.copy(),
            core_w=self.core_w.copy(), core_via=self.core_via.copy(),
            core_ids=self.core_ids.copy())
        shard_of = self.shard_of.copy()
        touched: set = set()
        inserted = []
        for op in ops:
            u = int(op.u)
            if op.kind == "insert":
                apply_insert_host(st, ids_h, d_h, pred_h, u,
                                  [int(v) for v in op.nbrs],
                                  [float(x) for x in op.ws], touched)
                shard_of[u] = REPLICATED        # u joined the core
                inserted.append(u)
            elif op.kind == "delete":
                apply_delete_host(st, ids_h, d_h, pred_h, u, touched)
            else:
                raise ValueError(f"unknown mutation kind {op.kind!r}")
        rows = np.asarray(sorted(touched), np.int64)

        blk_ids = np.asarray(self.lbl_ids).copy()
        blk_d = np.asarray(self.lbl_d).copy()
        blk_pred = self.lbl_pred.copy()
        entries = self.entries_per_shard.copy()
        cap = blk_ids.shape[2]
        touched_shards: set = set()
        for r in rows:
            valid = ids_h[r] < self.n
            owner = shard_of[np.minimum(ids_h[r], self.n)]
            for p in range(self.num_shards):
                # boolean-mask compaction keeps source order — the same
                # stable layout partition_labels produces
                keep = valid & ((owner == p) | (owner == REPLICATED))
                cnt = int(keep.sum())
                if cnt > cap:
                    raise RuntimeError(
                        f"shard {p} row {r}: {cnt} entries exceed the "
                        f"block cap {cap}; repartition the index")
                new_ids = np.full(cap, self.n, np.int32)
                new_d = np.full(cap, np.inf, np.float32)
                new_pred = np.full(cap, -1, np.int32)
                new_ids[:cnt] = ids_h[r][keep]
                new_d[:cnt] = d_h[r][keep]
                new_pred[:cnt] = pred_h[r][keep]
                if not (np.array_equal(blk_ids[p, r], new_ids)
                        and np.array_equal(blk_d[p, r], new_d)):
                    if r < self.n:
                        entries[p] += cnt - int(
                            (blk_ids[p, r] < self.n).sum())
                    blk_ids[p, r] = new_ids
                    blk_d[p, r] = new_d
                    blk_pred[p, r] = new_pred
                    touched_shards.add(p)
        core_ids = np.flatnonzero(st.level == self.k).astype(np.int32)
        core_pos = np.full(self.n + 1, len(core_ids), np.int32)
        core_pos[core_ids] = np.arange(len(core_ids), dtype=np.int32)
        stats = dataclasses.replace(
            self.stats, n_core=len(core_ids), m_core=len(st.core_src),
            label_entries=int((ids_h[:self.n] < self.n).sum()))
        new = ShardedIndex._assemble(
            n=self.n, k=self.k, cfg=self.cfg, level=st.level,
            shard_of=shard_of,
            blocks=LabelBlocks(ids=blk_ids, d=blk_d, pred=blk_pred,
                               entries=entries),
            core_ids=core_ids, core_pos=core_pos, core_src=st.core_src,
            core_dst=st.core_dst, core_w=st.core_w, stats=stats,
            strategy=self.strategy, replicate_top=self.replicate_top,
            mesh=self.mesh, core_via=st.core_via, up_ids=self.up_ids,
            up_w=self.up_w, up_via=self.up_via)
        info = {"touched_rows": rows,
                "touched_shards": sorted(touched_shards),
                "inserted": inserted}
        return new, info

    # ---------------------------------------------------------------- io
    def save(self, path) -> None:
        p = Path(path)
        p.mkdir(parents=True, exist_ok=True)
        path_state = {}
        if self.up_ids is not None:
            path_state = {"core_via": self.core_via, "up_ids": self.up_ids,
                          "up_w": self.up_w, "up_via": self.up_via}
        np.savez_compressed(
            p / "shards.npz", level=self.level, shard_of=self.shard_of,
            lbl_ids=np.asarray(self.lbl_ids), lbl_d=np.asarray(self.lbl_d),
            lbl_pred=np.asarray(self.lbl_pred), core_ids=self.core_ids,
            core_pos=self.core_pos_host, core_src=self.core_src,
            core_dst=self.core_dst, core_w=self.core_w, **path_state)
        meta = {"n": self.n, "k": self.k, "num_shards": self.num_shards,
                "strategy": self.strategy,
                "replicate_top": self.replicate_top,
                "cfg": dataclasses.asdict(self.cfg),
                "stats": dataclasses.asdict(self.stats)}
        (p / "meta.json").write_text(json.dumps(meta))

    @staticmethod
    def load(path, mesh: Mesh | None = None) -> "ShardedIndex":
        p = Path(path)
        meta = json.loads((p / "meta.json").read_text())
        z = np.load(p / "shards.npz")
        blocks = LabelBlocks(
            ids=z["lbl_ids"], d=z["lbl_d"], pred=z["lbl_pred"],
            entries=(z["lbl_ids"][:, :meta["n"]] < meta["n"])
            .sum(axis=(1, 2)).astype(np.int64))
        has_paths = "up_ids" in z.files
        idx = ShardedIndex._assemble(
            n=meta["n"], k=meta["k"], cfg=IndexConfig(**meta["cfg"]),
            level=z["level"], shard_of=z["shard_of"], blocks=blocks,
            core_ids=z["core_ids"], core_pos=z["core_pos"],
            core_src=z["core_src"], core_dst=z["core_dst"],
            core_w=z["core_w"], stats=BuildStats(**meta["stats"]),
            strategy=meta["strategy"], replicate_top=meta["replicate_top"],
            mesh=mesh,
            core_via=z["core_via"] if has_paths else None,
            up_ids=z["up_ids"] if has_paths else None,
            up_w=z["up_w"] if has_paths else None,
            up_via=z["up_via"] if has_paths else None)
        return idx
