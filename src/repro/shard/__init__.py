# repro.shard — partitioned IS-LABEL indexes with multi-device batched
# querying: ancestor-partitioned label blocks (top hierarchy levels
# replicated), shard_map query path with one collective per batch,
# bitwise-equal to the unsharded QueryEngine. See docs/SHARDING.md.
from repro.shard.partition import (REPLICATED, STRATEGIES, LabelBlocks,
                                   assign_shards, partition_labels,
                                   unpartition_labels)
from repro.shard.query import ShardedQueryEngine
from repro.shard.sharded_index import ShardedIndex, make_shard_mesh

__all__ = [
    "REPLICATED", "STRATEGIES", "LabelBlocks", "assign_shards",
    "partition_labels", "unpartition_labels", "ShardedQueryEngine",
    "ShardedIndex", "make_shard_mesh",
]
