"""Label partitioning for `ShardedIndex` (docs/SHARDING.md).

A label entry (v, w, d) — ancestor w with distance d in label(v) — is
owned by the shard of its *ancestor* w, not of v: Equation 1 matches an
entry of label(s) against an entry of label(t) only when both reference
the same ancestor, so partitioning by ancestor keeps every match
shard-local and the global μ is the plain min of the per-shard partial
minima (float min is exact, so the reduction is bitwise-order-free).

Two deterministic vertex→shard strategies, both with the top
``replicate_top`` hierarchy levels (at minimum the core, level k)
REPLICATED on every shard:

* ``"hash"``  — Knuth multiplicative hash of the vertex id. Oblivious
  to the hierarchy; what a KV-store would do.
* ``"level"`` — round-robin by rank within each level, so every shard
  carries the same per-level slice of ancestors. Labels draw their
  ancestors level by level (paper §4.2), which makes this the balanced
  choice by construction.

Replicating the top levels is what keeps the stage-2 core search
shard-local: every shard's block contains *all* core-ancestor entries,
so each shard scatters the complete seed frontier and relaxes G_k to
the identical fixed point — no cross-shard traffic until the final
single-collective min over the per-shard answers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

REPLICATED = -1               # shard id meaning "present on every shard"
STRATEGIES = ("hash", "level")
_KNUTH = np.uint64(2654435761)


def assign_shards(level, k: int, num_shards: int, strategy: str = "level",
                  replicate_top: int = 1) -> np.ndarray:
    """Deterministic vertex→shard map: int32[n+1], REPLICATED for the
    top ``replicate_top`` hierarchy levels (the sentinel row n is
    REPLICATED too; partitioning masks it out by id)."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r}; expected one of {STRATEGIES}")
    if replicate_top < 1:
        raise ValueError("replicate_top must be >= 1: the core level must "
                         "be replicated or the core search crosses shards")
    level = np.asarray(level, np.int32)
    n = len(level)
    out = np.full(n + 1, REPLICATED, np.int32)
    movable = level <= k - replicate_top
    if strategy == "hash":
        ids = np.arange(n, dtype=np.uint64)
        h = (ids * _KNUTH) % np.uint64(2 ** 32)
        out[:n][movable] = (h[movable] % np.uint64(num_shards)).astype(np.int32)
    else:
        for lv in np.unique(level[movable]):
            ids_lv = np.flatnonzero(level == lv)
            out[ids_lv] = np.arange(len(ids_lv), dtype=np.int32) % num_shards
    return out


@dataclasses.dataclass
class LabelBlocks:
    """Per-shard padded label blocks: [P, n+1, cap_s] host arrays.

    Rows keep the source order (id-sorted), pad with the sentinel id n /
    +inf / -1 — exactly the unsharded row convention, so every kernel
    backend consumes a block unchanged.
    """
    ids: np.ndarray            # int32 [P, n+1, cap_s]
    d: np.ndarray              # float32 [P, n+1, cap_s]
    pred: np.ndarray           # int32 [P, n+1, cap_s]
    entries: np.ndarray        # int64 [P]: owned+replicated entries per shard

    @property
    def num_shards(self) -> int:
        return self.ids.shape[0]

    @property
    def cap(self) -> int:
        return self.ids.shape[2]


def partition_labels(lbl_ids, lbl_d, lbl_pred, n: int, shard_of: np.ndarray,
                     num_shards: int, pad_to: int = 8) -> LabelBlocks:
    """Slice [n+1, l_cap] label arrays into per-shard padded blocks.

    Shard p keeps the entries whose ancestor it owns plus every
    REPLICATED entry; cap_s is the max kept-per-row count over all
    shards, rounded up to a ``pad_to`` multiple.
    """
    ids = np.asarray(lbl_ids, np.int32)
    d = np.asarray(lbl_d, np.float32)
    pred = np.asarray(lbl_pred, np.int32)
    rows, l_cap = ids.shape
    if rows != n + 1:
        raise ValueError(f"label arrays must have n+1={n + 1} rows, "
                         f"got {rows}")
    valid = ids < n
    owner = shard_of[np.minimum(ids, n)]
    keeps = [valid & ((owner == p) | (owner == REPLICATED))
             for p in range(num_shards)]
    cap = max(int(k.sum(axis=1).max(initial=0)) for k in keeps)
    cap = max(pad_to, -(-cap // pad_to) * pad_to)

    out_ids = np.full((num_shards, rows, cap), n, np.int32)
    out_d = np.full((num_shards, rows, cap), np.inf, np.float32)
    out_pred = np.full((num_shards, rows, cap), -1, np.int32)
    entries = np.zeros(num_shards, np.int64)
    col = np.arange(l_cap)[None, :]
    for p, keep in enumerate(keeps):
        # stable sort on ~keep compacts kept entries left, order intact
        order = np.argsort(~keep, axis=1, kind="stable")
        cnt = keep.sum(axis=1, keepdims=True)
        g_ids = np.where(col < cnt, np.take_along_axis(ids, order, 1), n)
        g_d = np.where(col < cnt, np.take_along_axis(d, order, 1), np.inf)
        g_pred = np.where(col < cnt, np.take_along_axis(pred, order, 1), -1)
        width = min(cap, l_cap)
        out_ids[p, :, :width] = g_ids[:, :width]
        out_d[p, :, :width] = g_d[:, :width]
        out_pred[p, :, :width] = g_pred[:, :width]
        entries[p] = int(cnt[:n].sum())
    return LabelBlocks(ids=out_ids, d=out_d, pred=out_pred, entries=entries)


def unpartition_labels(blocks: LabelBlocks, n: int, l_cap: int):
    """Reassemble full [n+1, l_cap] label arrays from per-shard blocks
    (replicated entries deduped by ancestor id). The round-trip
    ``unpartition(partition(x)) == x`` is asserted in tests."""
    p, rows, cap = blocks.ids.shape
    flat_ids = blocks.ids.transpose(1, 0, 2).reshape(rows, p * cap)
    flat_d = blocks.d.transpose(1, 0, 2).reshape(rows, p * cap)
    flat_pred = blocks.pred.transpose(1, 0, 2).reshape(rows, p * cap)
    out_ids = np.full((rows, l_cap), n, np.int32)
    out_d = np.full((rows, l_cap), np.inf, np.float32)
    out_pred = np.full((rows, l_cap), -1, np.int32)
    for r in range(rows):
        m = flat_ids[r] < n
        u, first = np.unique(flat_ids[r][m], return_index=True)
        if len(u) > l_cap:
            raise ValueError(f"row {r}: {len(u)} entries exceed l_cap={l_cap}")
        out_ids[r, :len(u)] = u
        out_d[r, :len(u)] = flat_d[r][m][first]
        out_pred[r, :len(u)] = flat_pred[r][m][first]
    return out_ids, out_d, out_pred
