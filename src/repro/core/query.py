"""Batched P2P distance query engine (paper §4.3, §5.2, Algorithm 1).

Two stages, exactly the paper's:
  1. label intersection -> upper bound μ (Equation 1); exact and final
     for queries whose shortest path never enters the core G_k.
  2. label-seeded core search: the paper's bidirectional Dijkstra on G_k
     becomes *batched bidirectional Bellman-Ford*: both frontiers' dist
     vectors over the core are relaxed each round; loop exits when no
     entry in the batch improves (exact convergence — same fixed point
     Dijkstra reaches). answer = min(μ, min_v DS[v] + DT[v]).

Priority queues do not vectorize; synchronous wavefront relaxation is
the standard data-parallel SSSP formulation and serves thousands of
queries per launch. μ still prunes: converged queries stop contributing
improvements, and the final min with μ implements Line 19.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("l_cap",))
def label_intersect_mu(ids_s, d_s, ids_t, d_t, n: int, l_cap: int):
    """Equation 1 over sorted label rows: μ[q] = min_{w∈X} d(s,w)+d(w,t).

    Also returns the meeting ancestor (global id; n if none) — used for
    path reconstruction and Type classification.
    """
    del l_cap
    pos = jax.vmap(jnp.searchsorted)(ids_t, ids_s)          # [Q, L]
    pos_c = jnp.minimum(pos, ids_t.shape[1] - 1)
    hit = (jnp.take_along_axis(ids_t, pos_c, 1) == ids_s) & (ids_s < n)
    tot = jnp.where(hit, d_s + jnp.take_along_axis(d_t, pos_c, 1), jnp.inf)
    j = jnp.argmin(tot, axis=1)
    mu = jnp.take_along_axis(tot, j[:, None], 1)[:, 0]
    meet = jnp.where(jnp.isfinite(mu),
                     jnp.take_along_axis(ids_s, j[:, None], 1)[:, 0], n)
    return mu, meet


@partial(jax.jit, static_argnames=("n_core", "max_rounds"))
def core_relax(seed_s, seed_t, ce_src, ce_dst, ce_w, mu,
               n_core: int, max_rounds: int):
    """Bidirectional label-seeded relaxation on G_k (Alg. 1 stage 2).

    seed_s/seed_t: [Q, n_core+1] initial distance vectors (+inf default,
    label distances scattered in, sentinel column n_core).
    Returns (ans [Q], ds, dt) with ans = min(μ, min_v ds+dt).
    """
    def body(state):
        ds, dt, it, _ = state
        cs = ds[:, ce_src] + ce_w[None, :]
        ds2 = ds.at[:, ce_dst].min(cs)
        ct = dt[:, ce_src] + ce_w[None, :]
        dt2 = dt.at[:, ce_dst].min(ct)
        improved = jnp.any(ds2 < ds) | jnp.any(dt2 < dt)
        return ds2, dt2, it + 1, improved

    def cond(state):
        _, _, it, improved = state
        return improved & (it < max_rounds)

    ds, dt, rounds, _ = jax.lax.while_loop(
        cond, body, (seed_s, seed_t, jnp.int32(0), jnp.bool_(True)))
    # the sentinel column n_core parks non-core label entries — exclude it
    through_core = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
    return jnp.minimum(mu, through_core), ds, dt, rounds


class QueryEngine:
    """Holds the device-resident index state and compiled query fns."""

    def __init__(self, lbl_ids, lbl_d, core_pos, core_local_edges, n: int,
                 n_core: int, max_rounds: int = 0):
        self.lbl_ids = lbl_ids
        self.lbl_d = lbl_d
        self.core_pos = core_pos              # int32[n+1] -> [0..n_core]
        self.ce_src, self.ce_dst, self.ce_w = core_local_edges
        self.n = n
        self.n_core = n_core
        self.l_cap = lbl_ids.shape[1]
        self.max_rounds = max_rounds if max_rounds > 0 else max(n_core, 1)
        self._last_rounds = 0

    def _seed(self, ids, d):
        q = ids.shape[0]
        cpos = self.core_pos[jnp.minimum(ids, self.n)]       # [Q, L]
        seed = jnp.full((q, self.n_core + 1), jnp.inf, jnp.float32)
        ridx = jnp.broadcast_to(jnp.arange(q)[:, None], cpos.shape)
        return seed.at[ridx, cpos].min(jnp.where(ids < self.n, d, jnp.inf))

    def query(self, s, t):
        """Batched distances. s, t: int32[Q] device/host arrays."""
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        ids_s, d_s = self.lbl_ids[s], self.lbl_d[s]
        ids_t, d_t = self.lbl_ids[t], self.lbl_d[t]
        mu, meet = label_intersect_mu(ids_s, d_s, ids_t, d_t, self.n, self.l_cap)
        if self.n_core == 0:
            return mu
        seed_s = self._seed(ids_s, d_s)
        seed_t = self._seed(ids_t, d_t)
        ans, _, _, rounds = core_relax(seed_s, seed_t, self.ce_src, self.ce_dst,
                                       self.ce_w, mu, self.n_core,
                                       self.max_rounds)
        self._last_rounds = int(rounds)
        return ans

    def query_mu_only(self, s, t):
        """Equation-1-only answers (exact for §5.2 Type-1 queries)."""
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        mu, _ = label_intersect_mu(self.lbl_ids[s], self.lbl_d[s],
                                   self.lbl_ids[t], self.lbl_d[t],
                                   self.n, self.l_cap)
        return mu

    def classify(self, s, t, level, k):
        """Paper Table 5 endpoint classes: 1 = both core, 2 = one core,
        3 = neither."""
        import numpy as np
        in_core = (np.asarray(level)[np.asarray(s)] == k).astype(int) + \
                  (np.asarray(level)[np.asarray(t)] == k).astype(int)
        return 3 - in_core
