"""Batched P2P distance query engine (paper §4.3, §5.2, Algorithm 1).

Two stages, exactly the paper's:
  1. label intersection -> upper bound μ (Equation 1); exact and final
     for queries whose shortest path never enters the core G_k.
  2. label-seeded core search: the paper's bidirectional Dijkstra on G_k
     becomes *batched bidirectional Bellman-Ford*: both frontiers' dist
     vectors over the core are relaxed each round; loop exits when no
     entry in the batch improves (exact convergence — same fixed point
     Dijkstra reaches). answer = min(μ, min_v DS[v] + DT[v]).

Priority queues do not vectorize; synchronous wavefront relaxation is
the standard data-parallel SSSP formulation and serves thousands of
queries per launch. μ still prunes: converged queries stop contributing
improvements, and the final min with μ implements Line 19.

Both stages execute through the kernel dispatch layer
(``repro.core.dispatch``): stage 1 via the tiled-equality-join Pallas
label-intersect kernel (jnp searchsorted reference off-TPU), stage 2 via
the ELL min-plus ``spmv_relax`` kernel (COO scatter reference off-TPU).
``query_chunk`` tiles large batches so the dense per-direction frontier
is ``[chunk, n_core+1]``, never ``[Q, n_core+1]``.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import (CoreRelaxer, core_relax,
                                 label_intersect_rows_dispatch)
from repro.core.labels import (LabelRows, decode_rows, encode_labels,
                               try_encode_labels)
from repro.kernels.backend import resolve_backend

__all__ = ["QueryEngine", "label_intersect_mu", "core_relax"]


@partial(jax.jit, static_argnames=("l_cap",))
def label_intersect_mu(ids_s, d_s, ids_t, d_t, n: int, l_cap: int):
    """Equation 1 over sorted label rows: μ[q] = min_{w∈X} d(s,w)+d(w,t).

    Also returns the meeting ancestor (global id; n if none) — used for
    path reconstruction and Type classification. The serving hot path
    goes through ``dispatch.label_intersect_dispatch`` instead (the
    kernel returns μ only); this stays the oracle for paths/updates.
    """
    del l_cap
    pos = jax.vmap(jnp.searchsorted)(ids_t, ids_s)          # [Q, L]
    pos_c = jnp.minimum(pos, ids_t.shape[1] - 1)
    hit = (jnp.take_along_axis(ids_t, pos_c, 1) == ids_s) & (ids_s < n)
    tot = jnp.where(hit, d_s + jnp.take_along_axis(d_t, pos_c, 1), jnp.inf)
    j = jnp.argmin(tot, axis=1)
    mu = jnp.take_along_axis(tot, j[:, None], 1)[:, 0]
    meet = jnp.where(jnp.isfinite(mu),
                     jnp.take_along_axis(ids_s, j[:, None], 1)[:, 0], n)
    return mu, meet


class QueryEngine:
    """Holds the device-resident index state and compiled query fns.

    ``backend`` selects the kernel execution path ("auto" resolves to
    Pallas on TPU, jnp reference elsewhere; see ``repro.kernels.backend``).
    ``query_chunk`` > 0 tiles query batches into fixed-size chunks.
    ``label_dtype`` ("fp32" | "compressed" | "auto") selects the label
    storage codec (``repro.core.labels``): "compressed" encodes delta16
    ids (+ int32 distances when integral) and raises if the planes don't
    fit; "auto" compresses when possible and silently keeps fp32
    otherwise. Serving gathers the compressed planes directly; decode is
    fused into the intersect kernel and the stage-2 seed scatter.
    """

    def __init__(self, lbl_ids, lbl_d, core_pos, core_local_edges, n: int,
                 n_core: int, max_rounds: int = 0, backend: str = "auto",
                 query_chunk: int = 0, label_dtype: str = "fp32"):
        self.lbl_ids = lbl_ids
        self.lbl_d = lbl_d
        self.core_pos = core_pos              # int32[n+1] -> [0..n_core]
        self.ce_src, self.ce_dst, self.ce_w = core_local_edges
        self.n = n
        self.n_core = n_core
        self.l_cap = lbl_ids.shape[1]
        self.max_rounds = max_rounds if max_rounds > 0 else max(n_core, 1)
        self.backend = backend
        self.query_chunk = query_chunk
        if label_dtype not in ("fp32", "compressed", "auto"):
            raise ValueError(f"unknown label_dtype {label_dtype!r}")
        self.label_dtype = label_dtype
        self.codec = "none"
        self.enc_ids, self.enc_base, self.enc_d = lbl_ids, None, lbl_d
        if label_dtype != "fp32":
            encode = (encode_labels if label_dtype == "compressed"
                      else try_encode_labels)
            enc = encode(np.asarray(lbl_ids), np.asarray(lbl_d), n)
            if enc is not None:
                delta, base, denc = enc
                self.codec = "delta16"
                self.enc_ids = jnp.asarray(delta)
                self.enc_base = jnp.asarray(base)
                self.enc_d = jnp.asarray(denc)
        self.relaxer = CoreRelaxer(self.ce_src, self.ce_dst, self.ce_w,
                                   n_core) if n_core > 0 else None
        self._last_rounds = 0
        self._batch_fns: dict = {}     # backend -> jitted serving callable
        self._mu_batch_fns: dict = {}

    def _rows(self, idx) -> LabelRows:
        """Gather label rows for a vertex batch in the active codec."""
        if self.codec == "none":
            return LabelRows(self.lbl_ids[idx], None, self.lbl_d[idx])
        return LabelRows(self.enc_ids[idx], self.enc_base[idx],
                         self.enc_d[idx])

    def _seed(self, ids, d):
        q = ids.shape[0]
        cpos = self.core_pos[jnp.minimum(ids, self.n)]       # [Q, L]
        seed = jnp.full((q, self.n_core + 1), jnp.inf, jnp.float32)
        ridx = jnp.broadcast_to(jnp.arange(q)[:, None], cpos.shape)
        return seed.at[ridx, cpos].min(jnp.where(ids < self.n, d, jnp.inf))

    def _query_block(self, s, t, backend: str):
        """One fixed-size block through both stages. Returns (ans,
        rounds) with rounds a device scalar (None when there is no
        core) — callers reduce it lazily so chunked batches never sync
        to host between launches."""
        rows_s, rows_t = self._rows(s), self._rows(t)
        mu = label_intersect_rows_dispatch(rows_s, rows_t, self.n,
                                           self.codec, backend)
        if self.n_core == 0:
            return mu, None
        ids_s, d_s = decode_rows(rows_s, self.n, self.codec)
        ids_t, d_t = decode_rows(rows_t, self.n, self.codec)
        seed_s = self._seed(ids_s, d_s)
        seed_t = self._seed(ids_t, d_t)
        ans, _, _, rounds = self.relaxer.run(seed_s, seed_t, mu,
                                             self.max_rounds, backend)
        return ans, rounds

    def query(self, s, t, backend: str | None = None,
              query_chunk: int | None = None):
        """Batched distances. s, t: int32[Q] device/host arrays."""
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        backend = resolve_backend(self.backend if backend is None else backend)
        chunk = self.query_chunk if query_chunk is None else query_chunk
        q = s.shape[0]
        if chunk <= 0 or chunk >= q:
            ans, rounds = self._query_block(s, t, backend)
            self._last_rounds = 0 if rounds is None else int(rounds)
            return ans
        outs, rounds_all = [], []
        for start in range(0, q, chunk):
            size = min(chunk, q - start)
            sb, tb = s[start:start + size], t[start:start + size]
            if size < chunk:          # fixed shapes: no per-tail recompile
                sb = jnp.pad(sb, (0, chunk - size), mode="edge")
                tb = jnp.pad(tb, (0, chunk - size), mode="edge")
            ans, rounds = self._query_block(sb, tb, backend)
            outs.append(ans[:size])
            if rounds is not None:
                rounds_all.append(rounds)
        out = jnp.concatenate(outs)
        self._last_rounds = max((int(r) for r in rounds_all), default=0)
        return out

    def query_mu_only(self, s, t, backend: str | None = None):
        """Equation-1-only answers (exact for §5.2 Type-1 queries)."""
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        backend = resolve_backend(self.backend if backend is None else backend)
        return label_intersect_rows_dispatch(self._rows(s), self._rows(t),
                                             self.n, self.codec, backend)

    def classify(self, s, t, level, k):
        """Paper Table 5 endpoint classes: 1 = both core, 2 = one core,
        3 = neither. Accepts host or device arrays (and scalars) for
        every argument; always returns a host int array."""
        s = np.atleast_1d(np.asarray(s, np.int64))
        t = np.atleast_1d(np.asarray(t, np.int64))
        level = np.asarray(level)
        in_core = (level[s] == k).astype(np.int32) + \
                  (level[t] == k).astype(np.int32)
        return 3 - in_core

    # ------------------------------------------------------- serving APIs
    def batch_fn(self, backend: str | None = None):
        """Jitted fixed-shape batched query callable for serving.

        Returns ``run(s, t) -> (ans float32[Q], rounds int32 scalar)``
        with no host sync inside — the serving layer owns blocking and
        timing. One compilation per distinct batch shape; the returned
        object is memoized per resolved backend on this engine (shared
        by every server over the index), so its jit cache counts the
        engine's compiled shapes — serving must never grow them after
        warmup.
        """
        backend = resolve_backend(self.backend if backend is None else backend)
        if backend not in self._batch_fns:
            def run(s, t):
                ans, rounds = self._query_block(s, t, backend)
                return ans, (jnp.int32(0) if rounds is None else rounds)
            self._batch_fns[backend] = jax.jit(run)
        return self._batch_fns[backend]

    def mu_batch_fn(self, backend: str | None = None):
        """Jitted fixed-shape Equation-1-only callable (Type-1 fast
        path): ``run(s, t) -> ans float32[Q]``. Memoized per backend,
        same contract as ``batch_fn``."""
        backend = resolve_backend(self.backend if backend is None else backend)
        if backend not in self._mu_batch_fns:
            def run(s, t):
                return label_intersect_rows_dispatch(
                    self._rows(s), self._rows(t), self.n, self.codec,
                    backend)
            self._mu_batch_fns[backend] = jax.jit(run)
        return self._mu_batch_fns[backend]

    def warmup(self, batch_sizes, backend: str | None = None,
               mu_only: bool = False) -> dict:
        """Pre-compile the serving entry points for every batch size.

        Runs one dummy batch per (path, size) through ``batch_fn`` /
        ``mu_batch_fn`` so no XLA compile happens on the serving path.
        Returns {(path, size): seconds} compile+run timings.
        """
        fns = [("mu", self.mu_batch_fn(backend))]
        if not mu_only:
            fns.append(("full", self.batch_fn(backend)))
        out = {}
        for name, fn in fns:
            for size in batch_sizes:
                z = jnp.zeros(int(size), jnp.int32)
                t0 = time.perf_counter()
                jax.block_until_ready(fn(z, z))
                out[(name, int(size))] = time.perf_counter() - t0
        return out
