"""Directed-graph IS-LABEL (paper §8.2).

Same vertex hierarchy (independence ignores direction) but distance
preservation creates an augmenting edge (u, w) only for directed 2-paths
u -> v -> w through a removed v. Two label families per vertex:
*out-labels* over out-ancestors (edges low->high level) and *in-labels*
over in-ancestors; a query (s, t) intersects out(s) with in(t) and the
core search relaxes forward from s-seeds and backward from t-seeds.

Implementation: the in-label machinery is exactly the out-label
machinery on the reversed graph, so build_labels is reused verbatim with
a reversed Hierarchy view. This module also answers *reachability*
(dist < inf), the paper's closing claim.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import IndexConfig
from repro.core.hierarchy import Hierarchy
from repro.core.labeling import build_labels
from repro.core.mis import independent_set
from repro.core.query import core_relax, label_intersect_mu
from repro.graphs import csr as gcsr
from repro.graphs import segment_ops as sops


@partial(jax.jit, static_argnames=("n", "d_cap", "aug_cap"))
def peel_level_directed(src, dst, w, via, active, rng, n: int, d_cap: int,
                        aug_cap: int):
    """One directed hierarchy level. Degree/eligibility use the union
    (in+out) adjacency; augmenting pairs are IN(v) x OUT(v)."""
    e_cap = src.shape[0]
    valid = src < n
    # symmetrized view for the MIS (independence ignores direction)
    sym_src = jnp.concatenate([src, dst])
    sym_dst = jnp.concatenate([dst, src])
    sym_valid = jnp.concatenate([valid, valid])
    in_is, rounds = independent_set(sym_src, sym_dst, sym_valid, active,
                                    rng, n, d_cap)

    g_fwd = gcsr.EdgeList(src, dst, w, via, n_nodes=n)
    g_bwd = gcsr.EdgeList(dst, src, w, via, n_nodes=n)
    out_ids, out_w, out_via, _ = gcsr.neighbor_matrix(g_fwd, d_cap)
    in_ids, in_w, in_via, _ = gcsr.neighbor_matrix(g_bwd, d_cap)

    # edges OUT of IS vertices: (v -> u); pair with v's IN neighbors
    is_out = in_is[jnp.where(valid, src, 0)] & valid
    pos = jnp.cumsum(is_out.astype(jnp.int32)) - 1
    tgt = jnp.where(is_out & (pos < aug_cap), pos, aug_cap)

    def compact(vals, fill):
        buf = jnp.full((aug_cap + 1,), fill, vals.dtype)
        return buf.at[tgt].set(jnp.where(is_out, vals, fill),
                               mode="drop")[:aug_cap]

    a_v = compact(src, n)
    a_u = compact(dst, n)
    a_w = compact(w, jnp.inf)
    n_is_edges = jnp.sum(is_out.astype(jnp.int32))

    p_ids = in_ids[a_v]                       # in-neighbors of v [aug, d]
    p_w = in_w[a_v]
    pair_ok = (p_ids < n) & (p_ids != a_u[:, None]) & (a_u[:, None] < n)
    pair_src = jnp.where(pair_ok, p_ids, n)                      # win -> u
    pair_dst = jnp.where(pair_ok,
                         jnp.broadcast_to(a_u[:, None], p_ids.shape), n)
    pair_w = jnp.where(pair_ok, p_w + a_w[:, None], jnp.inf)
    pair_via = jnp.where(pair_ok,
                         jnp.broadcast_to(a_v[:, None], p_ids.shape), -1)

    drop = in_is[jnp.where(valid, src, 0)] | in_is[jnp.where(valid, dst, 0)]
    keep = valid & ~drop
    all_src = jnp.concatenate([jnp.where(keep, src, n), pair_src.reshape(-1)])
    all_dst = jnp.concatenate([jnp.where(keep, dst, n), pair_dst.reshape(-1)])
    all_w = jnp.concatenate([jnp.where(keep, w, jnp.inf), pair_w.reshape(-1)])
    all_via = jnp.concatenate([jnp.where(keep, via, -1),
                               pair_via.reshape(-1)])
    o_src, o_dst, o_w, o_via, n_unique = gcsr.dedup_min_edges(
        all_src, all_dst, all_w, all_via, n, e_cap)
    n_is = jnp.sum(in_is.astype(jnp.int32))
    return (o_src, o_dst, o_w, o_via, in_is, out_ids, out_w, out_via,
            in_ids, in_w, in_via, n_unique, n_is, n_is_edges, rounds)


@partial(jax.jit, static_argnames=("n_core",))
def _relax_one(seed, es, ed, ew, n_core: int):
    """One-directional Bellman-Ford on the (possibly reversed) core."""
    def body(state):
        d, it, _ = state
        d2 = d.at[:, ed].min(d[:, es] + ew[None, :])
        return d2, it + 1, jnp.any(d2 < d)

    def cond(state):
        return state[2] & (state[1] < n_core)

    d, _, _ = jax.lax.while_loop(
        cond, body, (seed, jnp.int32(0), jnp.bool_(True)))
    return d


@dataclasses.dataclass
class DiISLabelIndex:
    n: int
    k: int
    cfg: IndexConfig
    level: np.ndarray
    out_lbl: tuple      # (ids, d, pred) device arrays (out-ancestors)
    in_lbl: tuple
    core_pos: np.ndarray
    core_edges: tuple   # fwd local (src, dst, w)
    n_core: int

    @staticmethod
    def build(n, src, dst, w, cfg: IndexConfig = IndexConfig()):
        if (cfg.d_cap + 2) * (n + 1) >= 2 ** 32:
            raise ValueError("n too large for uint32 MIS keys")
        m0 = len(src)
        e_cap, aug_cap = cfg.e_cap(m0), cfg.aug_cap(m0)
        g = gcsr.from_host_edges(src, dst, w, n, e_cap)
        rng = jax.random.PRNGKey(cfg.seed)
        level = np.zeros(n, np.int32)
        ups = {d: (np.full((n + 1, cfg.d_cap), n, np.int32),
                   np.full((n + 1, cfg.d_cap), np.inf, np.float32),
                   np.full((n + 1, cfg.d_cap), -1, np.int32))
               for d in ("out", "in")}
        active = jnp.ones(n, bool)
        cs, cd, cw, cv = g.src, g.dst, g.weight, g.via
        sizes = [n + m0]
        k = 1
        for i in range(1, cfg.k_max + 1):
            rng, sub = jax.random.split(rng)
            (o_src, o_dst, o_w, o_via, in_is, out_ids, out_w, out_via,
             in_ids, in_w, in_via, n_unique, n_is, n_is_e, _) = \
                peel_level_directed(cs, cd, cw, cv, active, sub, n,
                                    cfg.d_cap, aug_cap)
            if int(n_unique) > e_cap or int(n_is_e) > aug_cap:
                raise RuntimeError("capacity overflow; raise e_cap_factor")
            if int(n_is) == 0:
                k = i
                break
            mask = np.asarray(in_is)
            level[mask] = i
            for key_, (ids_a, w_a, via_a) in zip(
                    ("out", "in"),
                    ((out_ids, out_w, out_via), (in_ids, in_w, in_via))):
                ups[key_][0][:n][mask] = np.asarray(ids_a)[:n][mask]
                ups[key_][1][:n][mask] = np.asarray(w_a)[:n][mask]
                ups[key_][2][:n][mask] = np.asarray(via_a)[:n][mask]
            active = active & ~in_is
            cs, cd, cw, cv = o_src, o_dst, o_w, o_via
            k = i + 1
            new_size = int((np.asarray(cs) < n).sum()) + n - int(level.astype(bool).sum())
            sizes.append(new_size)
            if cfg.k_force:
                if k >= cfg.k_force:
                    break
            elif new_size > cfg.sigma * sizes[-2]:
                break
        level[level == 0] = k

        ce_s, ce_d, ce_w, _ = gcsr.to_host_coo(
            gcsr.EdgeList(cs, cd, cw, cv, n_nodes=n))

        def labels_for(direction):
            hier = Hierarchy(
                n=n, k=k, level=level, up_ids=ups[direction][0],
                up_w=ups[direction][1], up_via=ups[direction][2],
                core_src=ce_s, core_dst=ce_d, core_w=ce_w,
                core_via=np.zeros_like(ce_s), level_sizes=[],
                graph_sizes=[], mis_rounds=[])
            return build_labels(hier, cfg)

        out_lbl = labels_for("out")
        in_lbl = labels_for("in")
        core_ids = np.flatnonzero(level == k).astype(np.int32)
        core_pos = np.full(n + 1, len(core_ids), np.int32)
        core_pos[core_ids] = np.arange(len(core_ids), dtype=np.int32)
        return DiISLabelIndex(
            n=n, k=k, cfg=cfg, level=level, out_lbl=out_lbl, in_lbl=in_lbl,
            core_pos=core_pos,
            core_edges=(jnp.asarray(core_pos[ce_s]),
                        jnp.asarray(core_pos[ce_d]), jnp.asarray(ce_w)),
            n_core=len(core_ids))

    def query(self, s, t):
        """Directed distances dist(s -> t), batched."""
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        ids_s, d_s = self.out_lbl[0][s], self.out_lbl[1][s]
        ids_t, d_t = self.in_lbl[0][t], self.in_lbl[1][t]
        mu, _ = label_intersect_mu(ids_s, d_s, ids_t, d_t, self.n,
                                   ids_s.shape[1])
        if self.n_core == 0:
            return mu
        cpos = jnp.asarray(self.core_pos)
        q = s.shape[0]
        ridx = jnp.broadcast_to(jnp.arange(q)[:, None], ids_s.shape)
        seed_s = jnp.full((q, self.n_core + 1), jnp.inf, jnp.float32).at[
            ridx, cpos[jnp.minimum(ids_s, self.n)]].min(
            jnp.where(ids_s < self.n, d_s, jnp.inf))
        seed_t = jnp.full((q, self.n_core + 1), jnp.inf, jnp.float32).at[
            ridx, cpos[jnp.minimum(ids_t, self.n)]].min(
            jnp.where(ids_t < self.n, d_t, jnp.inf))
        es, ed, ew = self.core_edges
        # forward relax for DS; DT relaxes on the reversed core graph
        ds = _relax_one(seed_s, es, ed, ew, self.n_core)
        dt = _relax_one(seed_t, ed, es, ew, self.n_core)
        through = jnp.min(ds[:, :self.n_core] + dt[:, :self.n_core], axis=1)
        return jnp.minimum(mu, through)

    def query_host(self, s, t):
        return np.asarray(self.query(np.atleast_1d(s), np.atleast_1d(t)))

    def reachable(self, s, t):
        return np.isfinite(self.query_host(s, t))
