"""Directed-graph IS-LABEL (paper §8.2).

Same vertex hierarchy (independence ignores direction) but distance
preservation creates an augmenting edge (u, w) only for directed 2-paths
u -> v -> w through a removed v. Two label families per vertex:
*out-labels* over out-ancestors (edges low->high level) and *in-labels*
over in-ancestors; a query (s, t) intersects out(s) with in(t) and the
core search relaxes forward from s-seeds and backward from t-seeds.

Implementation: the in-label machinery is exactly the out-label
machinery on the reversed graph, so build_labels is reused verbatim with
a reversed Hierarchy view. This module also answers *reachability*
(dist < inf), the paper's closing claim.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import IndexConfig
from repro.core.hierarchy import Hierarchy
from repro.core.labeling import build_labels
from repro.core.mis import independent_set
from repro.core.query import core_relax, label_intersect_mu
from repro.graphs import csr as gcsr
from repro.graphs import segment_ops as sops


@partial(jax.jit, static_argnames=("n", "d_cap", "aug_cap"))
def peel_level_directed(src, dst, w, via, active, rng, n: int, d_cap: int,
                        aug_cap: int):
    """One directed hierarchy level. Degree/eligibility use the union
    (in+out) adjacency; augmenting pairs are IN(v) x OUT(v)."""
    e_cap = src.shape[0]
    valid = src < n
    # symmetrized view for the MIS (independence ignores direction)
    sym_src = jnp.concatenate([src, dst])
    sym_dst = jnp.concatenate([dst, src])
    sym_valid = jnp.concatenate([valid, valid])
    in_is, rounds = independent_set(sym_src, sym_dst, sym_valid, active,
                                    rng, n, d_cap)

    g_fwd = gcsr.EdgeList(src, dst, w, via, n_nodes=n)
    g_bwd = gcsr.EdgeList(dst, src, w, via, n_nodes=n)
    out_ids, out_w, out_via, _ = gcsr.neighbor_matrix(g_fwd, d_cap)
    in_ids, in_w, in_via, _ = gcsr.neighbor_matrix(g_bwd, d_cap)

    # edges OUT of IS vertices: (v -> u); pair with v's IN neighbors
    is_out = in_is[jnp.where(valid, src, 0)] & valid
    pos = jnp.cumsum(is_out.astype(jnp.int32)) - 1
    tgt = jnp.where(is_out & (pos < aug_cap), pos, aug_cap)

    def compact(vals, fill):
        buf = jnp.full((aug_cap + 1,), fill, vals.dtype)
        return buf.at[tgt].set(jnp.where(is_out, vals, fill),
                               mode="drop")[:aug_cap]

    a_v = compact(src, n)
    a_u = compact(dst, n)
    a_w = compact(w, jnp.inf)
    n_is_edges = jnp.sum(is_out.astype(jnp.int32))

    p_ids = in_ids[a_v]                       # in-neighbors of v [aug, d]
    p_w = in_w[a_v]
    pair_ok = (p_ids < n) & (p_ids != a_u[:, None]) & (a_u[:, None] < n)
    pair_src = jnp.where(pair_ok, p_ids, n)                      # win -> u
    pair_dst = jnp.where(pair_ok,
                         jnp.broadcast_to(a_u[:, None], p_ids.shape), n)
    pair_w = jnp.where(pair_ok, p_w + a_w[:, None], jnp.inf)
    pair_via = jnp.where(pair_ok,
                         jnp.broadcast_to(a_v[:, None], p_ids.shape), -1)

    drop = in_is[jnp.where(valid, src, 0)] | in_is[jnp.where(valid, dst, 0)]
    keep = valid & ~drop
    all_src = jnp.concatenate([jnp.where(keep, src, n), pair_src.reshape(-1)])
    all_dst = jnp.concatenate([jnp.where(keep, dst, n), pair_dst.reshape(-1)])
    all_w = jnp.concatenate([jnp.where(keep, w, jnp.inf), pair_w.reshape(-1)])
    all_via = jnp.concatenate([jnp.where(keep, via, -1),
                               pair_via.reshape(-1)])
    o_src, o_dst, o_w, o_via, n_unique = gcsr.dedup_min_edges(
        all_src, all_dst, all_w, all_via, n, e_cap)
    n_is = jnp.sum(in_is.astype(jnp.int32))
    return (o_src, o_dst, o_w, o_via, in_is, out_ids, out_w, out_via,
            in_ids, in_w, in_via, n_unique, n_is, n_is_edges, rounds)


@partial(jax.jit, static_argnames=("n_core",))
def _relax_one(seed, es, ed, ew, n_core: int):
    """One-directional Bellman-Ford on the (possibly reversed) core."""
    def body(state):
        d, it, _ = state
        d2 = d.at[:, ed].min(d[:, es] + ew[None, :])
        return d2, it + 1, jnp.any(d2 < d)

    def cond(state):
        return state[2] & (state[1] < n_core)

    d, _, _ = jax.lax.while_loop(
        cond, body, (seed, jnp.int32(0), jnp.bool_(True)))
    return d


@dataclasses.dataclass
class DiISLabelIndex:
    n: int
    k: int
    cfg: IndexConfig
    level: np.ndarray
    out_lbl: tuple      # (ids, d, pred) device arrays (out-ancestors)
    in_lbl: tuple
    core_pos: np.ndarray
    core_edges: tuple   # fwd local (src, dst, w)
    n_core: int
    # host state for §8.1/§8.2 path reconstruction: the out/in
    # up-adjacency matrices ((ids, w, via) triples) and the core COO in
    # global ids with its via bookkeeping
    up_out: tuple = None
    up_in: tuple = None
    core_host: tuple = None     # (src, dst, w, via) global ids
    # lazy per-call-cost hoists (host label copies, sorted core
    # adjacencies) — the directed index has no in-place mutators, so
    # these never need invalidation
    _host_lbl: dict = dataclasses.field(default=None, init=False,
                                        repr=False, compare=False)
    _core_adj: dict = dataclasses.field(default=None, init=False,
                                        repr=False, compare=False)

    @staticmethod
    def build(n, src, dst, w, cfg: IndexConfig = IndexConfig()):
        # no key-width guard: the MIS compares (deg, perm) as two words
        # (core/mis.py), so million-vertex builds need no uint32 budget
        m0 = len(src)
        e_cap, aug_cap = cfg.e_cap(m0), cfg.aug_cap(m0)
        g = gcsr.from_host_edges(src, dst, w, n, e_cap)
        rng = jax.random.PRNGKey(cfg.seed)
        level = np.zeros(n, np.int32)
        ups = {d: (np.full((n + 1, cfg.d_cap), n, np.int32),
                   np.full((n + 1, cfg.d_cap), np.inf, np.float32),
                   np.full((n + 1, cfg.d_cap), -1, np.int32))
               for d in ("out", "in")}
        active = jnp.ones(n, bool)
        cs, cd, cw, cv = g.src, g.dst, g.weight, g.via
        sizes = [n + m0]
        k = 1
        for i in range(1, cfg.k_max + 1):
            rng, sub = jax.random.split(rng)
            (o_src, o_dst, o_w, o_via, in_is, out_ids, out_w, out_via,
             in_ids, in_w, in_via, n_unique, n_is, n_is_e, _) = \
                peel_level_directed(cs, cd, cw, cv, active, sub, n,
                                    cfg.d_cap, aug_cap)
            if int(n_unique) > e_cap or int(n_is_e) > aug_cap:
                raise RuntimeError("capacity overflow; raise e_cap_factor")
            if int(n_is) == 0:
                k = i
                break
            mask = np.asarray(in_is)
            level[mask] = i
            for key_, (ids_a, w_a, via_a) in zip(
                    ("out", "in"),
                    ((out_ids, out_w, out_via), (in_ids, in_w, in_via))):
                ups[key_][0][:n][mask] = np.asarray(ids_a)[:n][mask]
                ups[key_][1][:n][mask] = np.asarray(w_a)[:n][mask]
                ups[key_][2][:n][mask] = np.asarray(via_a)[:n][mask]
            active = active & ~in_is
            cs, cd, cw, cv = o_src, o_dst, o_w, o_via
            k = i + 1
            new_size = int((np.asarray(cs) < n).sum()) + n - int(level.astype(bool).sum())
            sizes.append(new_size)
            if cfg.k_force:
                if k >= cfg.k_force:
                    break
            elif new_size > cfg.sigma * sizes[-2]:
                break
        level[level == 0] = k

        ce_s, ce_d, ce_w, ce_v = gcsr.to_host_coo(
            gcsr.EdgeList(cs, cd, cw, cv, n_nodes=n))

        def labels_for(direction):
            hier = Hierarchy(
                n=n, k=k, level=level, up_ids=ups[direction][0],
                up_w=ups[direction][1], up_via=ups[direction][2],
                core_src=ce_s, core_dst=ce_d, core_w=ce_w,
                core_via=np.zeros_like(ce_s), level_sizes=[],
                graph_sizes=[], mis_rounds=[])
            return build_labels(hier, cfg)

        out_lbl = labels_for("out")
        in_lbl = labels_for("in")
        core_ids = np.flatnonzero(level == k).astype(np.int32)
        core_pos = np.full(n + 1, len(core_ids), np.int32)
        core_pos[core_ids] = np.arange(len(core_ids), dtype=np.int32)
        return DiISLabelIndex(
            n=n, k=k, cfg=cfg, level=level, out_lbl=out_lbl, in_lbl=in_lbl,
            core_pos=core_pos,
            core_edges=(jnp.asarray(core_pos[ce_s]),
                        jnp.asarray(core_pos[ce_d]), jnp.asarray(ce_w)),
            n_core=len(core_ids),
            up_out=ups["out"], up_in=ups["in"],
            core_host=(ce_s, ce_d, ce_w, ce_v))

    def query(self, s, t):
        """Directed distances dist(s -> t), batched."""
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        ids_s, d_s = self.out_lbl[0][s], self.out_lbl[1][s]
        ids_t, d_t = self.in_lbl[0][t], self.in_lbl[1][t]
        mu, _ = label_intersect_mu(ids_s, d_s, ids_t, d_t, self.n,
                                   ids_s.shape[1])
        if self.n_core == 0:
            return mu
        cpos = jnp.asarray(self.core_pos)
        q = s.shape[0]
        ridx = jnp.broadcast_to(jnp.arange(q)[:, None], ids_s.shape)
        seed_s = jnp.full((q, self.n_core + 1), jnp.inf, jnp.float32).at[
            ridx, cpos[jnp.minimum(ids_s, self.n)]].min(
            jnp.where(ids_s < self.n, d_s, jnp.inf))
        seed_t = jnp.full((q, self.n_core + 1), jnp.inf, jnp.float32).at[
            ridx, cpos[jnp.minimum(ids_t, self.n)]].min(
            jnp.where(ids_t < self.n, d_t, jnp.inf))
        es, ed, ew = self.core_edges
        # forward relax for DS; DT relaxes on the reversed core graph
        ds = _relax_one(seed_s, es, ed, ew, self.n_core)
        dt = _relax_one(seed_t, ed, es, ew, self.n_core)
        through = jnp.min(ds[:, :self.n_core] + dt[:, :self.n_core], axis=1)
        return jnp.minimum(mu, through)

    def query_host(self, s, t):
        return np.asarray(self.query(np.atleast_1d(s), np.atleast_1d(t)))

    def reachable(self, s, t):
        return np.isfinite(self.query_host(s, t))

    # ------------------------------------------------------- §8.1/§8.2 paths
    def _label_host(self, family: str):
        """Cached host copies of one label family's (ids, d, pred)."""
        if self._host_lbl is None:
            self._host_lbl = {}
        if family not in self._host_lbl:
            lbl = self.out_lbl if family == "out" else self.in_lbl
            self._host_lbl[family] = tuple(np.asarray(a) for a in lbl)
        return self._host_lbl[family]

    def _core_adjacency(self, reverse: bool = False):
        """Cached src-sorted core adjacency, forward or reversed."""
        if self._core_adj is None:
            self._core_adj = {}
        if reverse not in self._core_adj:
            from repro.core.ref import sorted_adjacency
            ce_s, ce_d, ce_w, ce_v = self.core_host
            src, dst = (ce_d, ce_s) if reverse else (ce_s, ce_d)
            self._core_adj[reverse] = sorted_adjacency(self.n, src, dst,
                                                       ce_w, ce_v)
        return self._core_adj[reverse]

    # Directed via expansion: an augmenting edge (a, b) through a
    # removed c stands for the 2-path a -> c -> b, so a sits in c's
    # *in*-adjacency and b in its *out*-adjacency.
    def _expand_dir(self, a: int, b: int, via: int) -> list[int]:
        """Original-graph vertices [a..b) of the directed edge a -> b."""
        if via < 0:
            return [a]
        sa = self._slot(self.up_in, via, a)
        sb = self._slot(self.up_out, via, b)
        if sa < 0 or sb < 0:
            return [a]
        return (self._expand_dir(a, via, int(self.up_in[2][via, sa]))
                + self._expand_dir(via, b, int(self.up_out[2][via, sb])))

    @staticmethod
    def _slot(up, v: int, u: int) -> int:
        slots = np.flatnonzero(up[0][v] == u)
        return int(slots[0]) if len(slots) else -1

    def _chase(self, v: int, x: int, family: str) -> list[int]:
        """Real-graph vertices of the label path between v and x.

        ``family="out"``: returns [v..x) of the path v -> x (chasing
        out-labels forward). ``family="in"``: returns [x..v) of the
        path x -> v (every in-label hop is a real edge INTO v).
        """
        if v == x:
            return []
        lbl = self._label_host(family)
        up = self.up_out if family == "out" else self.up_in
        row = lbl[0][v]
        j = int(np.searchsorted(row, x))
        if j >= len(row) or row[j] != x:
            raise ValueError(f"{x} is not a {family}-ancestor of {v}")
        u = int(lbl[2][v][j])
        slot = self._slot(up, v, u)
        if u < 0 or slot < 0:
            raise ValueError("inconsistent pred chain")
        via = int(up[2][v, slot])
        if family == "out":
            return self._expand_dir(v, u, via) + self._chase(u, x, "out")
        return self._chase(u, x, "in") + self._expand_dir(u, v, via)

    def shortest_path(self, s: int, t: int):
        """Return (dist(s -> t), [s..t] vertex list in the original
        directed graph) — the directed analogue of
        ``ISLabelIndex.shortest_path``."""
        dist = float(self.query_host([s], [t])[0])
        if not np.isfinite(dist):
            return dist, []
        from repro.core.ref import host_meet
        out_h, in_h = self._label_host("out"), self._label_host("in")
        mu, w = host_meet(out_h[0][s], out_h[1][s], in_h[0][t], in_h[1][t],
                          self.n)
        if mu <= dist + 1e-6 and w >= 0:
            return dist, (self._chase(s, w, "out")
                          + self._chase(t, w, "in") + [t])
        return dist, self._core_path_dir(s, t)

    def _core_path_dir(self, s: int, t: int) -> list[int]:
        from repro.core.ref import seeded_sssp

        def seeds(family, v):
            lbl = self._label_host(family)
            row_i, row_d = lbl[0][v], lbl[1][v]
            return {int(u): float(d) for u, d in zip(row_i, row_d)
                    if int(u) < self.n and self.level[int(u)] == self.k}

        ds, ps = seeded_sssp(seeds("out", s),
                             *self._core_adjacency(reverse=False))
        dt, pt = seeded_sssp(seeds("in", t),
                             *self._core_adjacency(reverse=True))
        meet = min((ds.get(u, np.inf) + dt.get(u, np.inf), u)
                   for u in ds)[1]
        # forward side: unwind par edges (u -> v) back to the s seed
        fwd, v = [], meet
        while ps[v][0] is not None:
            u, via = ps[v]
            fwd = self._expand_dir(u, v, via) + fwd
            v = u
        left = self._chase(s, v, "out") + fwd
        # backward side: par edges are real (v -> u), already forward
        bwd, v = [], meet
        while pt[v][0] is not None:
            u, via = pt[v]
            bwd = bwd + self._expand_dir(v, u, via)
            v = u
        return left + bwd + self._chase(t, v, "in") + [t]
