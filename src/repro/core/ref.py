"""Reference oracles + paper baselines (host-side, exact).

* ``dijkstra_oracle``: scipy multi-source exact distances — the ground
  truth every index answer is checked against.
* ``bidijkstra``: the paper's IM-DIJ baseline (Table 8) — textbook
  bidirectional Dijkstra with the standard top(F)+top(R) >= μ stop rule.
* ``dijkstra_p2p``: plain early-exit Dijkstra (online search baseline).
"""
from __future__ import annotations

import heapq

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csg


def build_csr(n, src, dst, w):
    # scipy COO->CSR SUMS duplicate entries; parallel edges must keep the
    # MIN weight instead — dedup first.
    key = np.asarray(src, np.int64) * n + np.asarray(dst, np.int64)
    order = np.lexsort((np.asarray(w), key))
    key_s, w_s = key[order], np.asarray(w, np.float64)[order]
    first = np.concatenate([[True], key_s[1:] != key_s[:-1]])
    key_u, w_u = key_s[first], w_s[first]
    return sp.csr_matrix((w_u, (key_u // n, key_u % n)), shape=(n, n))


def dijkstra_oracle(n, src, dst, w, sources):
    """Exact distances from each source to all vertices. [S, n] float64."""
    mat = build_csr(n, src, dst, w)
    return csg.dijkstra(mat, directed=True, indices=np.asarray(sources))


def _adj_lists(n, src, dst, w):
    order = np.argsort(src, kind="stable")
    s, d, ww = np.asarray(src)[order], np.asarray(dst)[order], np.asarray(w)[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    return np.cumsum(indptr), d, ww


def dijkstra_p2p(n, src, dst, w, s, t):
    """Early-exit unidirectional Dijkstra."""
    indptr, nbr, ww = _adj_lists(n, src, dst, w)
    dist = {s: 0.0}
    pq = [(0.0, s)]
    done = set()
    while pq:
        du, u = heapq.heappop(pq)
        if u in done:
            continue
        if u == t:
            return du
        done.add(u)
        for e in range(indptr[u], indptr[u + 1]):
            v, alt = int(nbr[e]), du + float(ww[e])
            if alt < dist.get(v, np.inf):
                dist[v] = alt
                heapq.heappush(pq, (alt, v))
    return np.inf


def bidijkstra(n, src, dst, w, s, t):
    """IM-DIJ baseline: bidirectional Dijkstra (undirected edge lists)."""
    if s == t:
        return 0.0
    indptr, nbr, ww = _adj_lists(n, src, dst, w)
    dist = [{s: 0.0}, {t: 0.0}]
    done = [set(), set()]
    pq = [[(0.0, s)], [(0.0, t)]]
    mu = np.inf
    while pq[0] and pq[1]:
        if pq[0][0][0] + pq[1][0][0] >= mu:
            break
        side = 0 if pq[0][0][0] <= pq[1][0][0] else 1
        du, u = heapq.heappop(pq[side])
        if u in done[side]:
            continue
        done[side].add(u)
        for e in range(indptr[u], indptr[u + 1]):
            v, alt = int(nbr[e]), du + float(ww[e])
            if alt < dist[side].get(v, np.inf):
                dist[side][v] = alt
                heapq.heappush(pq[side], (alt, v))
            if v in dist[1 - side]:
                mu = min(mu, alt + dist[1 - side][v])
    return mu


def host_meet(row_s, d_s, row_t, d_t, n):
    """Host Equation 1 over two sorted label rows: returns
    ``(mu, meet_id)`` with ``meet_id = -1`` when the labels share no
    finite ancestor. Shared by the undirected and directed host path
    oracles so their tie rule (argmin over the s-row order, matching
    the device engine) cannot drift apart."""
    pos = np.minimum(np.searchsorted(row_t, row_s), len(row_t) - 1)
    hit = (row_t[pos] == row_s) & (row_s < n)
    tot = np.where(hit, d_s + d_t[pos], np.inf)
    j = int(np.argmin(tot))
    return float(tot[j]), (int(row_s[j]) if hit[j] else -1)


def sorted_adjacency(n, src, dst, w, via):
    """Src-sorted CSR-ish adjacency ``(indptr, dst, w, via)`` — the
    representation both host path oracles cache per index."""
    order = np.argsort(src, kind="stable")
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, np.asarray(src)[order] + 1, 1)
    return (np.cumsum(indptr), np.asarray(dst)[order],
            np.asarray(w)[order], np.asarray(via)[order])


def seeded_sssp(seeds, indptr, nbr, w, via):
    """Dijkstra from a multi-source seed dict over a sorted adjacency.
    Returns ``(dist dict, parent dict)`` with ``parent[v] = (u, via)``
    (``(None, -1)`` at seeds) — the label-seeded core search both host
    path oracles unwind."""
    dd, par = dict(seeds), {u: (None, -1) for u in seeds}
    pq = [(d, u) for u, d in seeds.items()]
    heapq.heapify(pq)
    done = set()
    while pq:
        du, u = heapq.heappop(pq)
        if u in done:
            continue
        done.add(u)
        for e in range(indptr[u], indptr[u + 1]):
            v2, alt = int(nbr[e]), du + float(w[e])
            if alt < dd.get(v2, np.inf):
                dd[v2] = alt
                par[v2] = (u, int(via[e]))
                heapq.heappush(pq, (alt, v2))
    return dd, par


def bfs_hops(n, src, dst, s, t):
    """Unweighted BFS hop distance (sanity baseline)."""
    indptr, nbr, _ = _adj_lists(n, src, dst, np.ones(len(src)))
    from collections import deque
    seen = {s: 0}
    q = deque([s])
    while q:
        u = q.popleft()
        if u == t:
            return seen[u]
        for e in range(indptr[u], indptr[u + 1]):
            v = int(nbr[e])
            if v not in seen:
                seen[v] = seen[u] + 1
                q.append(v)
    return np.inf
