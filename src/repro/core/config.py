"""IS-LABEL index configuration.

The fixed capacities play the role of the paper's disk buffers: every
device computation is fixed-shape; overflows are detected and reported
(grow the cap and rebuild) instead of silently truncating.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    # -- hierarchy construction -------------------------------------------
    sigma: float = 0.95        # k-selection: stop when |G_{i+1}| > sigma*|G_i|
    k_force: int = 0           # >0: fixed k (paper Table 6 sweeps)
    k_max: int = 64            # hard cap on hierarchy height
    d_cap: int = 16            # IS eligibility degree cap (paper: greedy
                               # min-degree; we peel only deg<=d_cap vertices)
    e_cap_factor: float = 2.0  # edge capacity = factor * initial |E|
    aug_cap_factor: float = 1.0  # IS-incident edge buffer = factor * |E|
    builder: str = "device"    # level loop: device (sync-free, one stat
                               # read per level) | host (reference loop;
                               # bitwise-equal, docs/CONSTRUCTION.md)
    # -- labeling ----------------------------------------------------------
    l_cap: int = 256           # max label entries per vertex
    label_chunk: int = 4096    # vertices labeled per jitted chunk
    sync_every: int = 8        # labeling overflow-check cadence: one
                               # deferred device read per this many levels
    # -- query -------------------------------------------------------------
    max_relax_rounds: int = 0  # 0 = bound by n_core (exact Bellman-Ford)
    query_backend: str = "auto"  # kernel dispatch: auto | pallas |
                                 # interpret | reference (kernels/backend.py)
    query_chunk: int = 0       # >0: tile query batches so the stage-2
                               # frontier is [chunk, n_core+1], not [Q, ...]
    label_dtype: str = "fp32"  # label storage codec (core/labels.py):
                               # fp32 | compressed (delta16, raise if
                               # unfit) | auto (compress when possible)
    seed: int = 0

    def e_cap(self, n_edges: int) -> int:
        return max(64, int(self.e_cap_factor * n_edges))

    def aug_cap(self, n_edges: int) -> int:
        return max(64, int(self.aug_cap_factor * n_edges))


@dataclasses.dataclass
class BuildStats:
    """Per-build record mirroring the paper's Tables 3/6/7 columns."""
    n: int = 0
    m: int = 0                      # directed edge count of input
    k: int = 0
    n_core: int = 0                 # |V_{G_k}|
    m_core: int = 0                 # |E_{G_k}| (directed count)
    level_sizes: list = dataclasses.field(default_factory=list)
    graph_sizes: list = dataclasses.field(default_factory=list)  # |V|+|E| per level
    label_entries: int = 0          # total (u, d) pairs over all labels
    label_bytes: int = 0
    build_seconds: float = 0.0
    mis_rounds: list = dataclasses.field(default_factory=list)
    # construction-phase split + sync accounting (docs/CONSTRUCTION.md)
    peel_seconds: float = 0.0       # hierarchy (peel) phase wall time
    label_seconds: float = 0.0      # labeling phase wall time
    host_syncs: int = 0             # blocking device→host reads during build
    peel_loop_syncs: int = 0        # blocking reads inside the level loop
    peel_iters: int = 0             # level-loop iterations; the bench gates
                                    # peel_loop_syncs / peel_iters <= 1
    peak_device_bytes: int = 0      # max live device bytes observed (sampled)

    def summary(self) -> str:
        return (f"n={self.n} m={self.m} k={self.k} |V_Gk|={self.n_core} "
                f"|E_Gk|={self.m_core} label_entries={self.label_entries} "
                f"label_MB={self.label_bytes / 1e6:.2f} "
                f"build_s={self.build_seconds:.2f} "
                f"(peel {self.peel_seconds:.2f} + label {self.label_seconds:.2f}) "
                f"host_syncs={self.host_syncs}")
