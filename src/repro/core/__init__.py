# IS-LABEL: the paper's primary contribution, as a composable JAX module.
from repro.core.config import IndexConfig, BuildStats
from repro.core.dispatch import CoreRelaxer, label_intersect_dispatch
from repro.core.index import ISLabelIndex
from repro.core.query import QueryEngine, label_intersect_mu, core_relax
from repro.core.hierarchy import build_hierarchy, Hierarchy
from repro.core.labeling import build_labels
from repro.core import ref
