"""Vertex-hierarchy construction (paper §4.1, §5.1; Algorithms 2+3).

Each level: pick an independent set L_i of G_i (mis.py), record the
adjacency of L_i at removal time (``ADJ(L_i)`` — these become the
*up-edges* used for labeling and path reconstruction), then rebuild the
edge list: surviving edges + augmenting edges (u,w) for every 2-path
u-v-w through a removed v, deduped keeping min weight (Alg. 3's external
sort-merge, expressed as lexsort + segment_min).

The level loop is host-driven; each step is one fixed-shape jitted call.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import IndexConfig
from repro.core.mis import independent_set
from repro.graphs import csr as gcsr
from repro.graphs import segment_ops as sops


@dataclasses.dataclass
class Hierarchy:
    """Host-side result of the peeling loop."""
    n: int
    k: int                      # level of the core (vertices in G_k)
    level: np.ndarray           # int32[n], 1..k
    # up-edges: for every non-core v, its adjacency in G_{level(v)}
    up_ids: np.ndarray          # int32[n+1, d_cap], sentinel n
    up_w: np.ndarray            # float32[n+1, d_cap], inf pad
    up_via: np.ndarray          # int32[n+1, d_cap], -1 = original edge
    # core graph (G_k) in *global* vertex ids
    core_src: np.ndarray
    core_dst: np.ndarray
    core_w: np.ndarray
    core_via: np.ndarray
    level_sizes: list
    graph_sizes: list
    mis_rounds: list


@partial(jax.jit, static_argnames=("n", "d_cap", "aug_cap"))
def peel_level(src, dst, w, via, active, rng, n: int, d_cap: int, aug_cap: int):
    """One hierarchy level. Returns the new edge list + bookkeeping.

    All arrays fixed-shape; counters returned for host-side overflow
    checks. e_cap is implied by src.shape.
    """
    e_cap = src.shape[0]
    valid = src < n
    in_is, rounds = independent_set(src, dst, valid, active, rng, n, d_cap)

    # --- ADJ(L_i): neighbor matrix rows of IS vertices --------------------
    nbr_ids, nbr_w, nbr_via, _ = gcsr.neighbor_matrix(
        gcsr.EdgeList(src, dst, w, via, n_nodes=n), d_cap)

    # --- compact IS-incident edges into the augmentation buffer -----------
    is_src = in_is[jnp.where(valid, src, 0)] & valid   # edge (v,u), v in L_i
    pos = jnp.cumsum(is_src.astype(jnp.int32)) - 1
    tgt = jnp.where(is_src & (pos < aug_cap), pos, aug_cap)
    a_v = jnp.full((aug_cap + 1,), n, jnp.int32).at[tgt].set(
        jnp.where(is_src, src, n), mode="drop")[:aug_cap]
    a_u = jnp.full((aug_cap + 1,), n, jnp.int32).at[tgt].set(
        jnp.where(is_src, dst, n), mode="drop")[:aug_cap]
    a_w = jnp.full((aug_cap + 1,), jnp.inf, jnp.float32).at[tgt].set(
        jnp.where(is_src, w, jnp.inf), mode="drop")[:aug_cap]
    n_is_edges = jnp.sum(is_src.astype(jnp.int32))

    # --- augmenting pairs: (u, partner) for each partner slot of v --------
    # a_* rows: edge (v, u); partners = nbr rows of v
    p_ids = nbr_ids[a_v]                    # [aug_cap, d_cap]
    p_w = nbr_w[a_v]
    pair_ok = (p_ids < n) & (p_ids != a_u[:, None]) & (a_u[:, None] < n)
    pair_src = jnp.where(pair_ok, jnp.broadcast_to(a_u[:, None], p_ids.shape), n)
    pair_dst = jnp.where(pair_ok, p_ids, n)
    pair_w = jnp.where(pair_ok, a_w[:, None] + p_w, jnp.inf)
    pair_via = jnp.where(pair_ok, jnp.broadcast_to(a_v[:, None], p_ids.shape), -1)

    # --- surviving edges ---------------------------------------------------
    drop = in_is[jnp.where(valid, src, 0)] | in_is[jnp.where(valid, dst, 0)]
    keep = valid & ~drop
    k_src = jnp.where(keep, src, n)
    k_dst = jnp.where(keep, dst, n)
    k_w = jnp.where(keep, w, jnp.inf)
    k_via = jnp.where(keep, via, -1)

    all_src = jnp.concatenate([k_src, pair_src.reshape(-1)])
    all_dst = jnp.concatenate([k_dst, pair_dst.reshape(-1)])
    all_w = jnp.concatenate([k_w, pair_w.reshape(-1)])
    all_via = jnp.concatenate([k_via, pair_via.reshape(-1)])

    o_src, o_dst, o_w, o_via, n_unique = gcsr.dedup_min_edges(
        all_src, all_dst, all_w, all_via, n, e_cap)

    n_is = jnp.sum(in_is.astype(jnp.int32))
    return (o_src, o_dst, o_w, o_via, in_is, nbr_ids, nbr_w, nbr_via,
            n_unique, n_is, n_is_edges, rounds)


def build_hierarchy(n: int, src, dst, w, cfg: IndexConfig) -> Hierarchy:
    """Host loop: peel levels until the size-reduction stop rule (§5.1)."""
    if (cfg.d_cap + 2) * (n + 1) >= 2 ** 32:
        raise ValueError("n too large for uint32 MIS keys; lower d_cap or shard")
    m0 = len(src)
    e_cap = cfg.e_cap(m0)
    aug_cap = cfg.aug_cap(m0)
    g = gcsr.from_host_edges(src, dst, w, n, e_cap)
    rng = jax.random.PRNGKey(cfg.seed)

    level = np.zeros(n, np.int32)
    up_ids = np.full((n + 1, cfg.d_cap), n, np.int32)
    up_w = np.full((n + 1, cfg.d_cap), np.inf, np.float32)
    up_via = np.full((n + 1, cfg.d_cap), -1, np.int32)
    active = jnp.ones(n, bool)

    cur_src, cur_dst, cur_w, cur_via = g.src, g.dst, g.weight, g.via
    n_verts = n
    n_edges = m0
    graph_sizes = [n_verts + n_edges // 2]
    level_sizes, mis_rounds = [], []
    k = 1
    for i in range(1, cfg.k_max + 1):
        rng, sub = jax.random.split(rng)
        (o_src, o_dst, o_w, o_via, in_is, nbr_ids, nbr_w, nbr_via,
         n_unique, n_is, n_is_edges, rounds) = peel_level(
            cur_src, cur_dst, cur_w, cur_via, active, sub, n, cfg.d_cap, aug_cap)
        n_is_h = int(n_is)
        if int(n_unique) > e_cap:
            raise RuntimeError(
                f"edge capacity overflow at level {i}: {int(n_unique)} > {e_cap}; "
                f"raise IndexConfig.e_cap_factor")
        if int(n_is_edges) > aug_cap:
            raise RuntimeError(
                f"augmentation buffer overflow at level {i}; raise aug_cap_factor")
        if n_is_h == 0:
            k = i
            break
        # record level + up-edges on host
        is_mask = np.asarray(in_is)
        level[is_mask] = i
        up_ids[:n][is_mask] = np.asarray(nbr_ids)[:n][is_mask]
        up_w[:n][is_mask] = np.asarray(nbr_w)[:n][is_mask]
        up_via[:n][is_mask] = np.asarray(nbr_via)[:n][is_mask]
        active = active & ~in_is
        level_sizes.append(n_is_h)
        mis_rounds.append(int(rounds))

        n_verts -= n_is_h
        n_edges = int(n_unique)
        new_size = n_verts + n_edges // 2
        cur_src, cur_dst, cur_w, cur_via = o_src, o_dst, o_w, o_via
        k = i + 1
        graph_sizes.append(new_size)
        if cfg.k_force:
            if k >= cfg.k_force:
                break
        elif new_size > cfg.sigma * graph_sizes[-2]:
            break

    level[level == 0] = k

    c_src, c_dst, c_w, c_via = gcsr.to_host_coo(
        gcsr.EdgeList(cur_src, cur_dst, cur_w, cur_via, n_nodes=n))
    return Hierarchy(n=n, k=k, level=level, up_ids=up_ids, up_w=up_w,
                     up_via=up_via, core_src=c_src, core_dst=c_dst,
                     core_w=c_w, core_via=c_via, level_sizes=level_sizes,
                     graph_sizes=graph_sizes, mis_rounds=mis_rounds)
