"""Vertex-hierarchy construction (paper §4.1, §5.1; Algorithms 2+3).

Each level: pick an independent set L_i of G_i (mis.py), record the
adjacency of L_i at removal time (``ADJ(L_i)`` — these become the
*up-edges* used for labeling and path reconstruction), then rebuild the
edge list: surviving edges + augmenting edges (u,w) for every 2-path
u-v-w through a removed v, deduped keeping min weight (Alg. 3's external
sort-merge, expressed as lexsort + segment_min).

Two builders share the level loop semantics (docs/CONSTRUCTION.md):

``build_hierarchy_device`` (default) keeps every buffer device-resident
across levels: level assignment and up-edge recording happen inside the
jitted ``_peel_step`` (donated buffers, masked ``where`` under the IS
mask), and the only blocking host transfer per level is one int32[5]
stat vector — IS size, deduped edge count, augmentation fill, MIS
rounds, next graph size — from which the host applies the stop rule and
the overflow checks (the overflow flags ride the same transfer, so the
check costs no extra sync and still raises with the offending level).
Level/up-edge/core arrays come back to host in one final pull.

``build_hierarchy_host`` is the original loop — one ``peel_level`` call
per level with per-level scalar syncs and full neighbor-matrix round
trips through numpy. It is kept as the reference the construction bench
gates the device builder against, bitwise, at fixed seed.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sync as hsync
from repro.core.config import IndexConfig
from repro.core.mis import independent_set
from repro.graphs import csr as gcsr


@dataclasses.dataclass
class Hierarchy:
    """Host-side result of the peeling loop."""
    n: int
    k: int                      # level of the core (vertices in G_k)
    level: np.ndarray           # int32[n], 1..k
    # up-edges: for every non-core v, its adjacency in G_{level(v)}
    up_ids: np.ndarray          # int32[n+1, d_cap], sentinel n
    up_w: np.ndarray            # float32[n+1, d_cap], inf pad
    up_via: np.ndarray          # int32[n+1, d_cap], -1 = original edge
    # core graph (G_k) in *global* vertex ids
    core_src: np.ndarray
    core_dst: np.ndarray
    core_w: np.ndarray
    core_via: np.ndarray
    level_sizes: list
    graph_sizes: list
    mis_rounds: list
    host_syncs: int = 0         # blocking device→host reads in the level loop
    peel_iters: int = 0         # level-loop iterations (peel_level calls) —
                                # the bench gate is host_syncs <= peel_iters


@partial(jax.jit, static_argnames=("n", "d_cap", "aug_cap"))
def peel_level(src, dst, w, via, active, rng, n: int, d_cap: int, aug_cap: int):
    """One hierarchy level. Returns the new edge list + bookkeeping.

    All arrays fixed-shape; counters returned for host-side overflow
    checks. e_cap is implied by src.shape.
    """
    e_cap = src.shape[0]
    valid = src < n
    in_is, rounds = independent_set(src, dst, valid, active, rng, n, d_cap)

    # --- ADJ(L_i): neighbor matrix rows of IS vertices --------------------
    nbr_ids, nbr_w, nbr_via, _ = gcsr.neighbor_matrix(
        gcsr.EdgeList(src, dst, w, via, n_nodes=n), d_cap)

    # --- compact IS-incident edges into the augmentation buffer -----------
    is_src = in_is[jnp.where(valid, src, 0)] & valid   # edge (v,u), v in L_i
    pos = jnp.cumsum(is_src.astype(jnp.int32)) - 1
    tgt = jnp.where(is_src & (pos < aug_cap), pos, aug_cap)
    a_v = jnp.full((aug_cap + 1,), n, jnp.int32).at[tgt].set(
        jnp.where(is_src, src, n), mode="drop")[:aug_cap]
    a_u = jnp.full((aug_cap + 1,), n, jnp.int32).at[tgt].set(
        jnp.where(is_src, dst, n), mode="drop")[:aug_cap]
    a_w = jnp.full((aug_cap + 1,), jnp.inf, jnp.float32).at[tgt].set(
        jnp.where(is_src, w, jnp.inf), mode="drop")[:aug_cap]
    n_is_edges = jnp.sum(is_src.astype(jnp.int32))

    # --- augmenting pairs: (u, partner) for each partner slot of v --------
    # a_* rows: edge (v, u); partners = nbr rows of v
    p_ids = nbr_ids[a_v]                    # [aug_cap, d_cap]
    p_w = nbr_w[a_v]
    pair_ok = (p_ids < n) & (p_ids != a_u[:, None]) & (a_u[:, None] < n)
    pair_src = jnp.where(pair_ok, jnp.broadcast_to(a_u[:, None], p_ids.shape), n)
    pair_dst = jnp.where(pair_ok, p_ids, n)
    pair_w = jnp.where(pair_ok, a_w[:, None] + p_w, jnp.inf)
    pair_via = jnp.where(pair_ok, jnp.broadcast_to(a_v[:, None], p_ids.shape), -1)

    # --- surviving edges ---------------------------------------------------
    drop = in_is[jnp.where(valid, src, 0)] | in_is[jnp.where(valid, dst, 0)]
    keep = valid & ~drop
    k_src = jnp.where(keep, src, n)
    k_dst = jnp.where(keep, dst, n)
    k_w = jnp.where(keep, w, jnp.inf)
    k_via = jnp.where(keep, via, -1)

    all_src = jnp.concatenate([k_src, pair_src.reshape(-1)])
    all_dst = jnp.concatenate([k_dst, pair_dst.reshape(-1)])
    all_w = jnp.concatenate([k_w, pair_w.reshape(-1)])
    all_via = jnp.concatenate([k_via, pair_via.reshape(-1)])

    o_src, o_dst, o_w, o_via, n_unique = gcsr.dedup_min_edges(
        all_src, all_dst, all_w, all_via, n, e_cap)

    n_is = jnp.sum(in_is.astype(jnp.int32))
    return (o_src, o_dst, o_w, o_via, in_is, nbr_ids, nbr_w, nbr_via,
            n_unique, n_is, n_is_edges, rounds)


@partial(jax.jit, static_argnames=("n", "d_cap", "aug_cap"),
         donate_argnames=("src", "dst", "w", "via", "active", "level_dev",
                          "up_ids", "up_w", "up_via"))
def _peel_step(src, dst, w, via, active, level_dev, up_ids, up_w, up_via,
               rng, n_verts, lvl, n: int, d_cap: int, aug_cap: int):
    """One device-resident hierarchy level.

    Runs ``peel_level`` and folds the host-side bookkeeping of the
    original loop into the same jitted call: level recording and up-edge
    recording under the IS mask, active-set update, and the running
    ``|V|+|E|/2`` size for the stop rule. ``lvl`` and ``n_verts`` are
    traced scalars so the call compiles once per (n, d_cap, aug_cap).

    Returns the updated state plus ``stats`` int32[5] =
    ``[n_is, n_unique, n_is_edges, mis_rounds, new_size]`` — the one
    small per-level transfer the host reads. When the IS is empty the
    state update is the identity (the host then stops at level ``lvl``
    with the pre-step graph as the core, exactly like the host loop
    that breaks before recording).
    """
    rng, sub = jax.random.split(rng)
    (o_src, o_dst, o_w, o_via, in_is, nbr_ids, nbr_w, nbr_via,
     n_unique, n_is, n_is_edges, rounds) = peel_level(
        src, dst, w, via, active, sub, n, d_cap, aug_cap)

    has_is = n_is > 0
    # record level + up-edges under the IS mask (row n of up_* is the
    # sentinel row — the mask is False there by construction)
    rec = jnp.concatenate([in_is, jnp.zeros((1,), bool)])
    level_dev = jnp.where(in_is, lvl.astype(jnp.int32), level_dev)
    up_ids = jnp.where(rec[:, None], nbr_ids, up_ids)
    up_w = jnp.where(rec[:, None], nbr_w, up_w)
    up_via = jnp.where(rec[:, None], nbr_via, up_via)
    active = active & ~in_is
    # keep the pre-step edge list when the IS is empty: that graph IS the
    # core (dedup of an already-deduped list is value-identical, but the
    # guard makes the no-op explicit)
    src = jnp.where(has_is, o_src, src)
    dst = jnp.where(has_is, o_dst, dst)
    w = jnp.where(has_is, o_w, w)
    via = jnp.where(has_is, o_via, via)

    n_verts = n_verts - n_is
    new_size = n_verts + n_unique // 2
    stats = jnp.stack([n_is, n_unique, n_is_edges, rounds, new_size])
    return (src, dst, w, via, active, level_dev, up_ids, up_w, up_via,
            rng, n_verts, stats)


def build_hierarchy_device(n: int, src, dst, w, cfg: IndexConfig) -> Hierarchy:
    """Device-resident level loop: one blocking host sync per level.

    All state (edge list, active set, level assignment, up-edge matrix)
    stays on device across levels in donated buffers; the host reads one
    int32[5] stat vector per level to apply the §5.1 stop rule and the
    capacity checks, then pulls everything once after the loop.
    """
    m0 = len(src)
    e_cap = cfg.e_cap(m0)
    aug_cap = cfg.aug_cap(m0)
    g = gcsr.from_host_edges(src, dst, w, n, e_cap)

    state = (g.src, g.dst, g.weight, g.via,
             jnp.ones(n, bool),                              # active
             jnp.zeros(n, jnp.int32),                        # level
             jnp.full((n + 1, cfg.d_cap), n, jnp.int32),     # up_ids
             jnp.full((n + 1, cfg.d_cap), jnp.inf, jnp.float32),
             jnp.full((n + 1, cfg.d_cap), -1, jnp.int32),
             jax.random.PRNGKey(cfg.seed),
             jnp.int32(n))                                   # n_verts

    graph_sizes = [n + m0 // 2]
    level_sizes, mis_rounds = [], []
    k = 1
    peel_iters = 0
    with hsync.sync_span() as span:
        for i in range(1, cfg.k_max + 1):
            peel_iters = i
            *state, stats = _peel_step(*state, jnp.int32(i), n,
                                       cfg.d_cap, aug_cap)
            # the single blocking transfer of the level: stop-rule scalar
            # + overflow flags in one int32[5] read
            n_is, n_unique, n_is_edges, rounds, new_size = (
                int(x) for x in hsync.host_read(stats))
            if n_unique > e_cap:
                raise RuntimeError(
                    f"edge capacity overflow at level {i}: {n_unique} > "
                    f"{e_cap}; raise IndexConfig.e_cap_factor")
            if n_is_edges > aug_cap:
                raise RuntimeError(
                    f"augmentation buffer overflow at level {i}; raise "
                    f"aug_cap_factor")
            if n_is == 0:
                k = i
                break
            level_sizes.append(n_is)
            mis_rounds.append(rounds)
            k = i + 1
            graph_sizes.append(new_size)
            if cfg.k_force:
                if k >= cfg.k_force:
                    break
            elif new_size > cfg.sigma * graph_sizes[-2]:
                break
    loop_syncs = span.count

    # one final pull of the whole hierarchy state
    (cur_src, cur_dst, cur_w, cur_via, _active, level_dev,
     up_ids_d, up_w_d, up_via_d, _rng, _nv) = state
    level, up_ids, up_w, up_via, c_src_p, c_dst_p, c_w_p, c_via_p = (
        hsync.host_read((level_dev, up_ids_d, up_w_d, up_via_d,
                         cur_src, cur_dst, cur_w, cur_via)))
    level = np.array(level)
    level[level == 0] = k
    mask = c_src_p < n
    return Hierarchy(n=n, k=k, level=level, up_ids=np.array(up_ids),
                     up_w=np.array(up_w), up_via=np.array(up_via),
                     core_src=c_src_p[mask], core_dst=c_dst_p[mask],
                     core_w=c_w_p[mask], core_via=c_via_p[mask],
                     level_sizes=level_sizes, graph_sizes=graph_sizes,
                     mis_rounds=mis_rounds, host_syncs=loop_syncs,
                     peel_iters=peel_iters)


def build_hierarchy_host(n: int, src, dst, w, cfg: IndexConfig) -> Hierarchy:
    """Original host-driven loop (reference for the bitwise build gate):
    per-level scalar syncs + full neighbor-matrix round trips to numpy."""
    m0 = len(src)
    e_cap = cfg.e_cap(m0)
    aug_cap = cfg.aug_cap(m0)
    g = gcsr.from_host_edges(src, dst, w, n, e_cap)
    rng = jax.random.PRNGKey(cfg.seed)

    level = np.zeros(n, np.int32)
    up_ids = np.full((n + 1, cfg.d_cap), n, np.int32)
    up_w = np.full((n + 1, cfg.d_cap), np.inf, np.float32)
    up_via = np.full((n + 1, cfg.d_cap), -1, np.int32)
    active = jnp.ones(n, bool)

    cur_src, cur_dst, cur_w, cur_via = g.src, g.dst, g.weight, g.via
    n_verts = n
    n_edges = m0
    graph_sizes = [n_verts + n_edges // 2]
    level_sizes, mis_rounds = [], []
    k = 1
    peel_iters = 0
    with hsync.sync_span() as span:
        for i in range(1, cfg.k_max + 1):
            peel_iters = i
            rng, sub = jax.random.split(rng)
            (o_src, o_dst, o_w, o_via, in_is, nbr_ids, nbr_w, nbr_via,
             n_unique, n_is, n_is_edges, rounds) = peel_level(
                cur_src, cur_dst, cur_w, cur_via, active, sub, n, cfg.d_cap,
                aug_cap)
            n_is_h = int(hsync.host_read(n_is))
            n_unique_h = int(hsync.host_read(n_unique))
            if n_unique_h > e_cap:
                raise RuntimeError(
                    f"edge capacity overflow at level {i}: "
                    f"{n_unique_h} > {e_cap}; "
                    f"raise IndexConfig.e_cap_factor")
            if int(hsync.host_read(n_is_edges)) > aug_cap:
                raise RuntimeError(
                    f"augmentation buffer overflow at level {i}; raise "
                    f"aug_cap_factor")
            if n_is_h == 0:
                k = i
                break
            # record level + up-edges on host
            is_mask = hsync.host_read(in_is)
            level[is_mask] = i
            up_ids[:n][is_mask] = hsync.host_read(nbr_ids)[:n][is_mask]
            up_w[:n][is_mask] = hsync.host_read(nbr_w)[:n][is_mask]
            up_via[:n][is_mask] = hsync.host_read(nbr_via)[:n][is_mask]
            active = active & ~in_is
            level_sizes.append(n_is_h)
            mis_rounds.append(int(hsync.host_read(rounds)))

            n_verts -= n_is_h
            n_edges = n_unique_h
            new_size = n_verts + n_edges // 2
            cur_src, cur_dst, cur_w, cur_via = o_src, o_dst, o_w, o_via
            k = i + 1
            graph_sizes.append(new_size)
            if cfg.k_force:
                if k >= cfg.k_force:
                    break
            elif new_size > cfg.sigma * graph_sizes[-2]:
                break
    loop_syncs = span.count

    level[level == 0] = k

    c_src, c_dst, c_w, c_via = gcsr.to_host_coo(
        gcsr.EdgeList(cur_src, cur_dst, cur_w, cur_via, n_nodes=n))
    return Hierarchy(n=n, k=k, level=level, up_ids=up_ids, up_w=up_w,
                     up_via=up_via, core_src=c_src, core_dst=c_dst,
                     core_w=c_w, core_via=c_via, level_sizes=level_sizes,
                     graph_sizes=graph_sizes, mis_rounds=mis_rounds,
                     host_syncs=loop_syncs, peel_iters=peel_iters)


def build_hierarchy(n: int, src, dst, w, cfg: IndexConfig) -> Hierarchy:
    """Peel levels until the size-reduction stop rule (§5.1).

    Dispatches on ``cfg.builder``: ``device`` (default, sync-free level
    loop) or ``host`` (the original reference loop). Both are
    bitwise-identical at fixed seed — gated by ``bench_construction``.
    """
    if cfg.builder == "host":
        return build_hierarchy_host(n, src, dst, w, cfg)
    if cfg.builder != "device":
        raise ValueError(f"unknown IndexConfig.builder: {cfg.builder!r}")
    return build_hierarchy_device(n, src, dst, w, cfg)
