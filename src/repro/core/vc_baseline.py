"""VC-Index-style baseline (paper Table 8 comparator, Cheng et al. [11]).

Structural observation (and the reason this lives here): the complement
of a vertex cover is an independent set, so a *one-level* IS-LABEL
hierarchy (k=2, peel a maximal IS, keep the reduced graph G_2
explicitly) IS the vertex-cover reduced-graph construction of VC-Index:
non-cover vertices store their (augmented) adjacency into the cover,
and queries run a search over the reduced graph seeded from those
entries. We therefore implement the baseline *faithfully as that
special case* — same code path, hierarchy truncated at k=2 with the
degree cap lifted so the peel is a maximal independent set — and let
benchmarks measure what the paper's Table 6/8 claims: multi-level
IS-LABEL beats the one-level vertex-cover scheme because each extra
level shrinks the search graph further.
"""
from __future__ import annotations

import dataclasses

from repro.core.config import IndexConfig
from repro.core.index import ISLabelIndex


def vc_index_config(base: IndexConfig = IndexConfig()) -> IndexConfig:
    """One-level (vertex-cover-equivalent) configuration."""
    return dataclasses.replace(base, k_force=2, d_cap=64)


def build_vc_index(n, src, dst, w, base: IndexConfig = IndexConfig()):
    """Build the VC-style baseline index (k=2)."""
    return ISLabelIndex.build(n, src, dst, w, vc_index_config(base))
