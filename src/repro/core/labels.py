"""Label compression codecs (``IndexConfig.label_dtype``).

The query hot path is memory-bound on the label planes: every batch
gathers four ``[Q, l_cap]`` rows (two id rows, two distance rows) out of
HBM before any compute happens. Pruned Landmark Labeling and Hop
Doubling both report label size as the binding constraint at scale, so
the index can store the planes compressed and let the kernels decode
in-register:

``delta16`` id codec
    Sorted ancestor-id rows become one ``int32`` base (the first id)
    plus ``int16`` forward deltas — 2 bytes/entry instead of 4.
    Padding slots (id == n sentinel) are marked in-band with a
    ``-1`` delta; decode maps every slot at or after the first marker
    back to the sentinel, so decoded rows stay sorted (the searchsorted
    reference still works) and the ``ids < n`` masks behave identically.
    Rows whose real-entry deltas exceed ``int16`` don't fit — the codec
    refuses (``label_dtype="compressed"`` raises; ``"auto"`` falls back
    to fp32).

``int32`` distance codec
    When every finite label distance is a non-negative integer below
    2**24, distances are stored as ``int32`` (``-1`` marks +inf pads)
    and decoded by exact int->fp32 conversion — **bitwise** identical
    to the uncompressed pipeline, not merely ULP-close. Non-integral
    weights keep fp32 distances (ids still compress); then the decoded
    values are the original fp32 bits anyway, so end-to-end answers
    remain bitwise too. The ULP gate in tests exists as the contract
    for future lossy codecs; delta16/int32 are exact by construction.

Decode (``decode_ids``/``decode_rows``) is pure jnp so the same code
runs inside the Pallas ``label_intersect`` kernel, the interpret
backend, the jnp reference, and the seed scatter of stage 2.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "LabelRows", "LabelCompressionError", "encode_labels",
    "try_encode_labels", "decode_ids", "decode_d", "decode_rows",
    "encoded_nbytes",
]

DELTA_MAX = np.int64(2 ** 15 - 1)     # int16 ceiling for a real delta
D_INT_MAX = float(2 ** 24)            # int32 -> fp32 conversion stays exact
PAD_DELTA = -1                        # in-band pad marker (real deltas >= 0)
PAD_D = -1                            # +inf distance marker in int32 planes


class LabelCompressionError(ValueError):
    """The label planes don't fit the requested codec (delta overflow,
    unsorted rows, or non-integral distances under d_dtype=int32)."""


class LabelRows(NamedTuple):
    """Gathered label rows as the dispatch layer consumes them.

    codec "none":    ids int32[..., L], base None,         d float32
    codec "delta16": ids int16[..., L] (deltas), base int32[...],
                     d int32 (integral weights) or float32
    """
    ids: jnp.ndarray
    base: jnp.ndarray | None
    d: jnp.ndarray


# --------------------------------------------------------------- encode
def encode_labels(ids, d, n_sentinel: int, d_dtype: str | None = None):
    """Host-side delta16 encode of ``[..., L]`` label planes.

    Returns ``(delta int16, base int32, d_enc int32|float32)``.
    ``d_dtype``: None infers int32 vs float32 from the data; "int32" /
    "float32" pin the distance plane dtype (families need a fixed
    dtype across versions) and raise if the data doesn't fit.
    """
    ids = np.asarray(ids)
    d = np.asarray(d, np.float32)
    if ids.shape != d.shape or ids.shape[-1] == 0:
        raise LabelCompressionError(f"bad label plane shape {ids.shape}")
    real = ids < n_sentinel
    # rows must be [real entries..., pads] — the layout labeling.py and
    # every host mutator maintain
    if (real[..., 1:] & ~real[..., :-1]).any():
        raise LabelCompressionError("non-contiguous pad slots in a row")
    step = np.diff(ids.astype(np.int64), axis=-1)
    realpair = real[..., 1:]            # contiguity: real[j] => real[j-1]
    if realpair.any():
        real_steps = step[realpair]
        if real_steps.min(initial=0) < 0:
            raise LabelCompressionError("unsorted label row")
        if real_steps.max(initial=0) > DELTA_MAX:
            raise LabelCompressionError(
                f"ancestor-id delta {int(real_steps.max())} exceeds int16")
    delta = np.full(ids.shape, PAD_DELTA, np.int16)
    delta[..., 0] = np.where(real[..., 0], 0, PAD_DELTA)
    delta[..., 1:] = np.where(realpair, step, PAD_DELTA).astype(np.int16)
    base = np.where(real[..., 0], ids[..., 0], 0).astype(np.int32)

    vals = d[real]
    integral = (vals.size == 0 or
                (np.isfinite(vals).all() and (vals >= 0).all()
                 and (vals < D_INT_MAX).all()
                 and (vals == np.round(vals)).all()))
    if d_dtype == "int32" and not integral:
        raise LabelCompressionError(
            "non-integral/oversized distance under pinned int32 codec")
    if d_dtype == "float32" or (d_dtype is None and not integral):
        d_enc = d.copy()
    else:
        d_enc = np.where(real, d, float(PAD_D)).astype(np.int32)
    return delta, base, d_enc


def try_encode_labels(ids, d, n_sentinel: int, d_dtype: str | None = None):
    """``encode_labels`` or None when the planes don't fit the codec."""
    try:
        return encode_labels(ids, d, n_sentinel, d_dtype)
    except LabelCompressionError:
        return None


def encoded_nbytes(delta, base, d_enc) -> int:
    return int(np.asarray(delta).nbytes + np.asarray(base).nbytes
               + np.asarray(d_enc).nbytes)


# --------------------------------------------------------------- decode
def decode_ids(delta, base, n_sentinel: int):
    """int16 deltas + int32 base -> sorted int32 ids (pads -> sentinel).

    Pure jnp (cumsum over the last axis) so it runs unchanged inside
    the Pallas kernel body, the interpret backend, and the reference.
    """
    pad = jnp.cumsum((delta < 0).astype(jnp.int32), axis=-1) > 0
    steps = jnp.where(pad, 0, delta.astype(jnp.int32))
    ids = base[..., None].astype(jnp.int32) + jnp.cumsum(steps, axis=-1)
    return jnp.where(pad, jnp.int32(n_sentinel), ids)


def decode_d(d_enc):
    """int32 distance plane -> float32 (exact below 2**24); fp32 planes
    pass through untouched."""
    if d_enc.dtype == jnp.float32:
        return d_enc
    return jnp.where(d_enc < 0, jnp.inf, d_enc.astype(jnp.float32))


def decode_rows(rows: LabelRows, n_sentinel: int, codec: str):
    """(ids int32, d float32) for either codec — the seed scatter and
    the reference backend consume this."""
    if codec == "none":
        return rows.ids, rows.d
    return decode_ids(rows.ids, rows.base, n_sentinel), decode_d(rows.d)
