"""Parallel independent-set selection (the paper's Alg. 2, TPU-native).

The paper peels vertices one at a time in ascending degree order. That
is a serial chain, so we use the classic parallel alternative: Luby-style
rounds with a *degree-biased* priority key — vertex v enters the set iff
its key is a strict local minimum among still-undecided eligible
neighbors. The degree bias preserves the paper's min-degree greedy
spirit (small labels); random low bits break ties; vertex id breaks the
rest, making the key a strict total order so every round makes progress.

The key is the lexicographic pair ``(deg, perm)`` where ``perm`` is a
random permutation of [0, n): unique per vertex, so the order is strict.
It is compared as *two words* (a high-word segment-min on deg, then a
low-word segment-min on perm restricted to neighbors achieving the deg
minimum). Earlier revisions packed the pair into one uint32
(``deg * n + perm``), which capped builds at ``(d_cap+2)*(n+1) < 2^32``
— about 250M key states, hit long before the paper's million-vertex
graphs at realistic ``d_cap``. The two-word compare has no width limit
and is order-identical to the packed key wherever the packed key was
valid, so fixed-seed hierarchies are bitwise-unchanged.

Vertices with degree > d_cap are ineligible this level — under
min-degree greedy they would be picked last anyway, and the cap is what
bounds the augmenting-edge self-join (paper §4.1: the whole point of
vertex independence is the 2-hop-bounded join).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs import segment_ops as sops

_HI_INF = jnp.int32(2 ** 31 - 1)   # ineligible / empty-segment high word
_LO_INF = jnp.int32(2 ** 31 - 1)


def mis_key_words(deg, perm, d_cap):
    """The two-word priority key ``(hi, lo) = (min(deg, d_cap+1), perm)``.

    Lexicographic order over the words reproduces the retired packed key
    ``deg * n + perm`` exactly (``perm < n`` makes the low word a strict
    tie-break), with no ``(d_cap+2)*(n+1) < 2^32`` width limit."""
    hi = jnp.minimum(deg, d_cap + 1).astype(jnp.int32)
    lo = perm.astype(jnp.int32)
    return hi, lo


def lex_less(a_hi, a_lo, b_hi, b_lo):
    """Strict lexicographic (hi, lo) < (hi, lo) — elementwise."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


@partial(jax.jit, static_argnames=("n",))
def independent_set(src, dst, valid, active, key_rng, n: int, d_cap: int):
    """One level's independent set.

    Args:
      src, dst: int32[e_cap] current edge list (sentinel-padded with id n).
      valid:    bool[e_cap].
      active:   bool[n] — vertex still present in G_i.
      key_rng:  PRNG key for tie-breaking.
      d_cap:    eligibility degree cap.

    Returns (in_is bool[n], rounds int32).
    """
    deg = sops.count_per_segment(src, n + 1, mask=valid)[:n]
    perm = jax.random.permutation(key_rng, n)
    key_hi, key_lo = mis_key_words(deg, perm, d_cap)
    eligible = active & (deg <= d_cap)
    key_hi = jnp.where(eligible, key_hi, _HI_INF)
    key_lo = jnp.where(eligible, key_lo, _LO_INF)

    def body(state):
        pool, in_is, rounds = state
        # two-word min key over pool-neighbors, per vertex: high-word
        # segment-min, then low-word segment-min among edges achieving it
        on = pool[src] & valid
        c_hi = jnp.where(on, key_hi[src], _HI_INF)
        nbr_hi = sops.segment_min(c_hi, dst, n + 1)
        at_min = on & (c_hi == nbr_hi[dst])
        c_lo = jnp.where(at_min, key_lo[src], _LO_INF)
        nbr_lo = sops.segment_min(c_lo, dst, n + 1)
        winners = pool & lex_less(key_hi, key_lo, nbr_hi[:n], nbr_lo[:n])
        # remove winners and their neighbors from the pool
        w_nbr = sops.segment_max(
            jnp.where(winners[src] & valid, 1, 0), dst, n + 1)[:n] > 0
        pool = pool & ~winners & ~w_nbr
        return pool, in_is | winners, rounds + 1

    def cond(state):
        pool, _, _ = state
        return jnp.any(pool)

    pool0 = eligible
    _, in_is, rounds = jax.lax.while_loop(cond, body, (pool0, jnp.zeros(n, bool),
                                                       jnp.int32(0)))
    return in_is, rounds
