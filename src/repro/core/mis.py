"""Parallel independent-set selection (the paper's Alg. 2, TPU-native).

The paper peels vertices one at a time in ascending degree order. That
is a serial chain, so we use the classic parallel alternative: Luby-style
rounds with a *degree-biased* priority key — vertex v enters the set iff
its key is a strict local minimum among still-undecided eligible
neighbors. The degree bias preserves the paper's min-degree greedy
spirit (small labels); random low bits break ties; vertex id breaks the
rest, making the key a strict total order so every round makes progress.

Vertices with degree > d_cap are ineligible this level — under
min-degree greedy they would be picked last anyway, and the cap is what
bounds the augmenting-edge self-join (paper §4.1: the whole point of
vertex independence is the 2-hop-bounded join).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs import segment_ops as sops

_INF_KEY = jnp.uint32(0xFFFFFFFF)


def _priority_key(deg, perm, n, d_cap):
    """uint32 key = deg * n + random-permutation rank.

    ``perm`` is a permutation of [0, n), so keys of eligible vertices are
    *unique* — a strict total order, hence every Luby round removes at
    least one vertex and the loop terminates. Requires (d_cap+2)*n < 2^32
    (checked by the caller)."""
    d = jnp.minimum(deg, d_cap + 1).astype(jnp.uint32)
    return d * jnp.uint32(n) + perm.astype(jnp.uint32)


@partial(jax.jit, static_argnames=("n",))
def independent_set(src, dst, valid, active, key_rng, n: int, d_cap: int):
    """One level's independent set.

    Args:
      src, dst: int32[e_cap] current edge list (sentinel-padded with id n).
      valid:    bool[e_cap].
      active:   bool[n] — vertex still present in G_i.
      key_rng:  PRNG key for tie-breaking.
      d_cap:    eligibility degree cap.

    Returns (in_is bool[n], rounds int32).
    """
    deg = sops.count_per_segment(src, n + 1, mask=valid)[:n]
    perm = jax.random.permutation(key_rng, n)
    key = _priority_key(deg, perm, n, d_cap)
    eligible = active & (deg <= d_cap)
    key = jnp.where(eligible, key, _INF_KEY)

    def body(state):
        pool, in_is, rounds = state
        # min key over pool-neighbors, per vertex
        contrib = jnp.where(pool[src] & valid, key[src], _INF_KEY)
        nbr_min = sops.segment_min(contrib, dst, n + 1)[:n]
        winners = pool & (key < nbr_min)
        # remove winners and their neighbors from the pool
        w_nbr = sops.segment_max(
            jnp.where(winners[src] & valid, 1, 0), dst, n + 1)[:n] > 0
        pool = pool & ~winners & ~w_nbr
        return pool, in_is | winners, rounds + 1

    def cond(state):
        pool, _, _ = state
        return jnp.any(pool)

    pool0 = eligible
    _, in_is, rounds = jax.lax.while_loop(cond, body, (pool0, jnp.zeros(n, bool),
                                                       jnp.int32(0)))
    return in_is, rounds
