"""Top-down vertex labeling (paper §6.1.4, Algorithm 4).

Corollary 1: label(v) = {(v,0)} ∪ merge of label(u) (+ edge weight) over
v's up-neighbors u in G_{ℓ(v)}. Processing levels k-1 → 1 guarantees
every up-neighbor's label is final before it is consumed.

The paper's block-nested-loop join becomes a vectorized *min-plus label
join*: gather up-neighbor label blocks, add the connecting edge weight,
then per-row sort by (ancestor id, distance) + first-occurrence compact
— the fixed-shape analogue of the disk merge. Rows are chunked so the
working set stays bounded (the chunk is the VMEM-resident tile of the
BNL join).

Sync model (docs/CONSTRUCTION.md): the chunk loop is sync-free. The
per-chunk l_cap overflow flag used to be read back (`bool(overflow)`)
after every chunk — one host stall per 4096 vertices; it now
accumulates into a per-level device vector inside the donated chunk
step, and the host checks it in one deferred read every
``cfg.sync_every`` levels (and once after the loop). On overflow the
build still raises with the offending level, exactly as the eager check
did; labels-in-progress are discarded with the raise, so no corrupted
state escapes.

Label rows are kept sorted by ancestor id — queries rely on this for the
merge-intersection.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sync as hsync
from repro.core.config import IndexConfig
from repro.core.hierarchy import Hierarchy


@partial(jax.jit, static_argnames=("l_cap",),
         donate_argnames=("lbl_ids", "lbl_d", "lbl_pred", "ovf"))
def label_chunk_step(lbl_ids, lbl_d, lbl_pred, ovf, up_ids, up_w, verts,
                     lvl, l_cap: int):
    """Label one chunk of same-level vertices.

    lbl_*: [n+1, l_cap] global label arrays (row n = sentinel).
    ovf:   int32[k+1] per-level overflow accumulator (device-resident;
           slot ``lvl`` ORs in this chunk's l_cap overflow flag).
    up_*:  [n+1, d_cap] up-neighbor matrix.
    verts: int32[chunk] vertex ids of this level (padded with n).
    lvl:   int32 traced level index (for the overflow accumulator).
    """
    n = lbl_ids.shape[0] - 1
    c = verts.shape[0]
    u = up_ids[verts]                       # [c, d]
    w = up_w[verts]                         # [c, d]
    d_cap = u.shape[1]

    cand_ids = lbl_ids[u].reshape(c, d_cap * l_cap)
    cand_d = (w[:, :, None] + lbl_d[u]).reshape(c, d_cap * l_cap)
    cand_pred = jnp.broadcast_to(u[:, :, None],
                                 (c, d_cap, l_cap)).reshape(c, d_cap * l_cap)
    # the up-neighbor itself is an ancestor: it appears as (u, 0) in its own
    # label (self entry), so (u, w + 0) is generated automatically.
    self_ok = verts < n
    ids = jnp.concatenate([jnp.where(self_ok, verts, n)[:, None], cand_ids], 1)
    d = jnp.concatenate([jnp.where(self_ok, 0.0, jnp.inf)[:, None], cand_d], 1)
    pred = jnp.concatenate([jnp.full((c, 1), -1, jnp.int32), cand_pred], 1)
    d = jnp.where(ids >= n, jnp.inf, d)
    ids = jnp.where(jnp.isinf(d) & (pred >= 0), n, ids)  # drop dead candidates

    # sort rows by (id asc, d asc): stable sort by d, then stable by id
    o1 = jnp.argsort(d, axis=1, stable=True)
    ids = jnp.take_along_axis(ids, o1, 1)
    d = jnp.take_along_axis(d, o1, 1)
    pred = jnp.take_along_axis(pred, o1, 1)
    o2 = jnp.argsort(ids, axis=1, stable=True)
    ids = jnp.take_along_axis(ids, o2, 1)
    d = jnp.take_along_axis(d, o2, 1)
    pred = jnp.take_along_axis(pred, o2, 1)

    is_first = jnp.concatenate(
        [jnp.ones((c, 1), bool), ids[:, 1:] != ids[:, :-1]], 1) & (ids < n)
    posn = jnp.cumsum(is_first.astype(jnp.int32), axis=1) - 1
    overflow = jnp.any(is_first & (posn >= l_cap))
    ovf = ovf.at[lvl].max(overflow.astype(jnp.int32))

    rows_ids = jnp.full((c, l_cap + 1), n, jnp.int32)
    rows_d = jnp.full((c, l_cap + 1), jnp.inf, jnp.float32)
    rows_pred = jnp.full((c, l_cap + 1), -1, jnp.int32)
    col = jnp.where(is_first, jnp.minimum(posn, l_cap), l_cap)
    ridx = jnp.broadcast_to(jnp.arange(c)[:, None], col.shape)
    rows_ids = rows_ids.at[ridx, col].set(jnp.where(is_first, ids, n),
                                          mode="drop")[:, :l_cap]
    rows_d = rows_d.at[ridx, col].set(jnp.where(is_first, d, jnp.inf),
                                      mode="drop")[:, :l_cap]
    rows_pred = rows_pred.at[ridx, col].set(jnp.where(is_first, pred, -1),
                                            mode="drop")[:, :l_cap]

    # write back (pad rows write the sentinel row with sentinel values — safe)
    lbl_ids = lbl_ids.at[verts].set(rows_ids)
    lbl_d = lbl_d.at[verts].set(rows_d)
    lbl_pred = lbl_pred.at[verts].set(rows_pred)
    return lbl_ids, lbl_d, lbl_pred, ovf


def _check_overflow(ovf, cfg: IndexConfig):
    """Deferred l_cap overflow check: one blocking read of the per-level
    accumulator. Reports the *highest* flagged level — levels are labeled
    k-1 → 1, so that is the first chunk that overflowed chronologically,
    matching the retired eager per-chunk check."""
    flags = hsync.host_read(ovf)
    hit = np.flatnonzero(flags)
    if len(hit):
        raise RuntimeError(
            f"label capacity overflow at level {int(hit.max())}: raise "
            f"IndexConfig.l_cap (currently {cfg.l_cap})")


def build_labels(hier: Hierarchy, cfg: IndexConfig):
    """Run Algorithm 4 over the hierarchy. Returns device label arrays
    ``(lbl_ids, lbl_d, lbl_pred)``; blocking syncs are limited to the
    deferred overflow checks (⌈k / sync_every⌉ + 1 total)."""
    n, k = hier.n, hier.k
    l_cap, chunk = cfg.l_cap, cfg.label_chunk
    sync_every = max(1, cfg.sync_every)

    lbl_ids = np.full((n + 1, l_cap), n, np.int32)
    lbl_d = np.full((n + 1, l_cap), np.inf, np.float32)
    core = np.flatnonzero(hier.level == k)
    lbl_ids[core, 0] = core
    lbl_d[core, 0] = 0.0

    lbl_ids = jnp.asarray(lbl_ids)
    lbl_d = jnp.asarray(lbl_d)
    lbl_pred = jnp.full((n + 1, l_cap), -1, jnp.int32)
    ovf = jnp.zeros(k + 1, jnp.int32)
    up_ids = jnp.asarray(hier.up_ids)
    up_w = jnp.asarray(hier.up_w)

    levels_done = 0
    for i in range(k - 1, 0, -1):
        verts = np.flatnonzero(hier.level == i)
        for lo in range(0, len(verts), chunk):
            part = verts[lo:lo + chunk]
            pad = np.full(chunk, n, np.int64)
            pad[:len(part)] = part
            lbl_ids, lbl_d, lbl_pred, ovf = label_chunk_step(
                lbl_ids, lbl_d, lbl_pred, ovf, up_ids, up_w,
                jnp.asarray(pad, jnp.int32), jnp.int32(i), l_cap)
        levels_done += 1
        if levels_done % sync_every == 0:
            _check_overflow(ovf, cfg)
    _check_overflow(ovf, cfg)
    return lbl_ids, lbl_d, lbl_pred
