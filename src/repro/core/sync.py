"""Blocking device→host sync accounting for the construction path.

Every device→host read the builders perform goes through ``host_read``
so the per-level sync budget — the tentpole constraint of the
device-resident build (docs/CONSTRUCTION.md) — is *measured*, not
asserted: ``bench_construction`` snapshots the counter around a build
and gates ``syncs_per_level <= 1``. ``jax.device_get`` blocks until the
dependency cone of its operand has executed, so each call counted here
is one real host stall.
"""
from __future__ import annotations

import jax

_COUNT = 0


def host_read(x):
    """Blocking device→host transfer, counted. Returns numpy."""
    global _COUNT
    _COUNT += 1
    return jax.device_get(x)


def sync_count() -> int:
    return _COUNT


class sync_span:
    """Context manager reporting the syncs issued inside its scope."""

    def __enter__(self):
        self._start = _COUNT
        return self

    def __exit__(self, *exc):
        self.count = _COUNT - self._start
        return False

    @property
    def so_far(self) -> int:
        return _COUNT - self._start
