"""Kernel dispatch layer for the query hot path.

This module is the single seam between the paper-level query algebra
(`repro.core.query`) and the hardware kernels (`repro.kernels.*`). Both
stages of Algorithm 1 route through here:

  stage 1 — Equation 1 label intersection:
      ``label_intersect_dispatch`` -> ``kernels.label_intersect.ops``
      (tiled equality-join Pallas kernel on TPU, interpret-mode parity
      fallback off-TPU, searchsorted-merge jnp reference).

  stage 2 — label-seeded bidirectional core relaxation:
      ``CoreRelaxer`` — reference backend keeps the COO scatter-min
      wavefront (``core_relax``, bit-identical to the pre-dispatch
      engine); kernel backends pick one of three routes at dispatch
      time (``CoreRelaxer.mode``, see docs/KERNELS.md):

      "fused"    — the default: one ``fused_relax_kernel`` launch runs
                   ALL rounds with both stacked frontiers resident in
                   VMEM and the fixed-point exit inside the kernel.
      "dense"    — small dense cores (density >= ISLABEL_DENSE_THRESHOLD
                   and n_core <= dense_cap) relax via the
                   ``minplus_matmul`` kernel against a 0-diagonal dense
                   adjacency: one tropical GEMM per round.
      "ell_loop" — fallback when the fused working set would blow the
                   VMEM budget: the legacy one-``spmv_relax``-launch-
                   per-round ``lax.while_loop``.

Every route computes the same per-round fixed point (synchronous Jacobi
Bellman-Ford over G_k), so answers agree bitwise: each round takes a min
over the identical multiset of candidate sums regardless of whether the
edges are visited scatter-wise (COO), gather-wise (ELL), or as a dense
min-plus product (the 0 diagonal supplies the keep-old term; parallel
edges dedup exactly because fp add is monotone in w). Rows relax
independently, so per-block fixed points freeze bitwise and
``max(block rounds) == loop rounds``.

Query chunking lives one level up (``QueryEngine.query``): the batch is
tiled into fixed-size chunks so a 10k-query batch never materializes a
dense ``[Q, n_core+1]`` frontier per direction in one launch — peak
frontier memory is ``O(query_chunk * n_core)`` instead of
``O(Q * n_core)``.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.labels import LabelRows
from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.label_intersect import ops as li_ops
from repro.kernels.minplus_matmul.kernel import minplus_matmul_kernel
from repro.kernels.spmv_relax.kernel import (
    fused_relax_kernel, fused_vmem_bytes, spmv_relax_kernel)
from repro.kernels.spmv_relax.ops import coo_to_ell

# VMEM budget for the fused kernel's per-grid-step working set; above
# this the dispatcher falls back to the per-round launch loop.
FUSED_VMEM_BUDGET = 12 * 2 ** 20


@partial(jax.jit, static_argnames=("n_sentinel", "backend"))
def label_intersect_dispatch(ids_s, d_s, ids_t, d_t, n_sentinel: int,
                             backend: str):
    """Equation 1 μ via the resolved kernel backend. Returns float32[Q]."""
    # named_scope threads through to XLA HLO metadata, so profiler
    # traces (jax.profiler / --profile-dir) attribute device time to
    # the paper's stages (docs/OBSERVABILITY.md)
    with jax.named_scope("islabel.label_intersect"):
        return li_ops.label_intersect(ids_s, d_s, ids_t, d_t, n_sentinel,
                                      backend=backend)


@partial(jax.jit, static_argnames=("n_sentinel", "codec", "backend"))
def label_intersect_rows_dispatch(rows_s: LabelRows, rows_t: LabelRows,
                                  n_sentinel: int, codec: str,
                                  backend: str):
    """Equation 1 μ over gathered ``LabelRows`` in either codec — the
    compressed path fuses decode into the join kernel."""
    with jax.named_scope("islabel.label_intersect"):
        return li_ops.label_intersect_rows(rows_s, rows_t, n_sentinel,
                                           codec=codec, backend=backend)


@partial(jax.jit, static_argnames=("n_core", "max_rounds"))
def core_relax(seed_s, seed_t, ce_src, ce_dst, ce_w, mu,
               n_core: int, max_rounds: int):
    """Reference bidirectional label-seeded relaxation on G_k (Alg. 1
    stage 2) — COO scatter-min wavefront rounds.

    seed_s/seed_t: [Q, n_core+1] initial distance vectors (+inf default,
    label distances scattered in, sentinel column n_core).
    Returns (ans [Q], ds, dt, rounds) with ans = min(μ, min_v ds+dt).
    """
    def body(state):
        ds, dt, it, _ = state
        cs = ds[:, ce_src] + ce_w[None, :]
        ds2 = ds.at[:, ce_dst].min(cs)
        ct = dt[:, ce_src] + ce_w[None, :]
        dt2 = dt.at[:, ce_dst].min(ct)
        improved = jnp.any(ds2 < ds) | jnp.any(dt2 < dt)
        return ds2, dt2, it + 1, improved

    def cond(state):
        _, _, it, improved = state
        return improved & (it < max_rounds)

    with jax.named_scope("islabel.core_relax"):
        ds, dt, rounds, _ = jax.lax.while_loop(
            cond, body, (seed_s, seed_t, jnp.int32(0), jnp.bool_(True)))
        # the sentinel column n_core parks non-core label entries —
        # exclude it
        through_core = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
        return jnp.minimum(mu, through_core), ds, dt, rounds


@partial(jax.jit,
         static_argnames=("n_core", "max_rounds", "interpret", "bq", "bv"))
def _core_relax_ell(seed_s, seed_t, nbr_ids, nbr_w, mu, n_core: int,
                    max_rounds: int, interpret: bool, bq: int, bv: int):
    """Kernel-path relaxation: both frontiers stacked into one [2Q, Vp]
    matrix, one ``spmv_relax`` launch per wavefront round."""
    q, v = seed_s.shape
    vp = nbr_ids.shape[0]                     # V padded to a bv multiple
    rows = 2 * q
    rp = -(-rows // bq) * bq
    d0 = jnp.concatenate([seed_s, seed_t], axis=0)
    d0 = jnp.pad(d0, ((0, rp - rows), (0, vp - v)), constant_values=jnp.inf)

    def body(state):
        d, it, _ = state
        d2 = spmv_relax_kernel(d, nbr_ids, nbr_w, bq=bq, bv=bv,
                               interpret=interpret)
        return d2, it + 1, jnp.any(d2 < d)

    def cond(state):
        _, it, improved = state
        return improved & (it < max_rounds)

    with jax.named_scope("islabel.core_relax_ell"):
        d, rounds, _ = jax.lax.while_loop(
            cond, body, (d0, jnp.int32(0), jnp.bool_(True)))
        ds = d[:q, :v]
        dt = d[q:rows, :v]
        through_core = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
        return jnp.minimum(mu, through_core), ds, dt, rounds


@partial(jax.jit,
         static_argnames=("n_core", "max_rounds", "interpret", "bq"))
def _core_relax_fused(seed_s, seed_t, nbr_ids, nbr_w, mu, n_core: int,
                      max_rounds: int, interpret: bool, bq: int):
    """Fused relaxation: both frontiers stacked, ALL rounds in one
    ``fused_relax_kernel`` launch with the fixed-point exit in-kernel.
    Batch rounds = max over per-block rounds (all-pad blocks settle in
    one round, real blocks freeze bitwise at their own fixed point)."""
    q, v = seed_s.shape
    vp = nbr_ids.shape[0]
    rows = 2 * q
    rp = -(-rows // bq) * bq
    d0 = jnp.concatenate([seed_s, seed_t], axis=0)
    d0 = jnp.pad(d0, ((0, rp - rows), (0, vp - v)), constant_values=jnp.inf)

    with jax.named_scope("islabel.core_relax_fused"):
        d, blk_rounds = fused_relax_kernel(d0, nbr_ids, nbr_w,
                                           max_rounds=max_rounds, bq=bq,
                                           interpret=interpret)
        rounds = jnp.max(blk_rounds, initial=0).astype(jnp.int32)
        ds = d[:q, :v]
        dt = d[q:rows, :v]
        through_core = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
        return jnp.minimum(mu, through_core), ds, dt, rounds


@partial(jax.jit,
         static_argnames=("n_core", "max_rounds", "interpret", "bm"))
def _core_relax_dense(seed_s, seed_t, adj, mu, n_core: int,
                      max_rounds: int, interpret: bool, bm: int = 8):
    """Dense-core relaxation: one ``minplus_matmul`` tropical GEMM per
    round against the 0-diagonal adjacency (the diagonal supplies the
    keep-old term, so ``minplus(d, adj)`` IS the synchronous round)."""
    q, v = seed_s.shape
    vp = adj.shape[0]
    rows = 2 * q
    rp = -(-rows // bm) * bm
    d0 = jnp.concatenate([seed_s, seed_t], axis=0)
    d0 = jnp.pad(d0, ((0, rp - rows), (0, vp - v)), constant_values=jnp.inf)

    def body(state):
        d, it, _ = state
        d2 = minplus_matmul_kernel(d, adj, bm=bm, interpret=interpret)
        return d2, it + 1, jnp.any(d2 < d)

    def cond(state):
        _, it, improved = state
        return improved & (it < max_rounds)

    with jax.named_scope("islabel.core_relax_dense"):
        d, rounds, _ = jax.lax.while_loop(
            cond, body, (d0, jnp.int32(0), jnp.bool_(True)))
        ds = d[:q, :v]
        dt = d[q:rows, :v]
        through_core = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
        return jnp.minimum(mu, through_core), ds, dt, rounds


class CoreRelaxer:
    """Backend-dispatched stage-2 relaxation over the local core graph.

    Holds the COO edge arrays (local indices in [0, n_core), weights)
    and lazily derives the kernel-side layouts: the ELL planes the
    per-round and fused kernels consume, and (for dense cores) the
    0-diagonal dense adjacency for ``minplus_matmul`` — each built once
    per index on first kernel-path query, padded to lane-aligned vertex
    counts so launches need no reshaping.

    Kernel-route selection (``.mode``) happens at dispatch time:
    density >= ``dense_threshold`` (env ``ISLABEL_DENSE_THRESHOLD``)
    with n_core <= ``dense_cap`` -> "dense"; else "fused" when the fused
    working set fits the VMEM budget; else "ell_loop". Set env
    ``ISLABEL_FUSED_RELAX=0`` to force the legacy per-round loop.
    """

    def __init__(self, ce_src, ce_dst, ce_w, n_core: int, *,
                 bq: int = 8, bv: int = 128, d_width: int = 16,
                 fused: bool | None = None,
                 dense_threshold: float | None = None,
                 dense_cap: int = 2048,
                 vmem_budget: int = FUSED_VMEM_BUDGET):
        self.ce_src = ce_src
        self.ce_dst = ce_dst
        self.ce_w = ce_w
        self.n_core = n_core
        self.bq = bq
        self.bv = bv
        self.d_width = d_width
        if fused is None:
            fused = os.environ.get("ISLABEL_FUSED_RELAX", "1") != "0"
        self.fused = fused
        if dense_threshold is None:
            dense_threshold = float(
                os.environ.get("ISLABEL_DENSE_THRESHOLD", "0.05"))
        self.dense_threshold = dense_threshold
        self.dense_cap = dense_cap
        self.vmem_budget = vmem_budget
        self.density = (len(ce_src) / (n_core * n_core)) if n_core else 0.0
        self._ell = None
        self._adj = None
        self._mode = None

    @property
    def mode(self) -> str:
        """Kernel route: "dense" | "fused" | "ell_loop" (reference
        backend bypasses this entirely)."""
        if self._mode is None:
            if (0 < self.n_core <= self.dense_cap
                    and self.density >= self.dense_threshold):
                self._mode = "dense"
            elif self.fused:
                nbr_ids, _ = self.ell()
                vp, width = nbr_ids.shape
                fits = fused_vmem_bytes(vp, width, self.bq) \
                    <= self.vmem_budget
                self._mode = "fused" if fits else "ell_loop"
            else:
                self._mode = "ell_loop"
        return self._mode

    def dense_adj(self):
        """[Vp, Vp] float32 dense adjacency: adj[src, dst] = min edge
        weight (parallel edges dedup exactly — fp add is monotone in w),
        +inf elsewhere, diagonal min'd with 0 on ALL rows including the
        sentinel and lane padding so parked values survive each round."""
        if self._adj is None:
            v = self.n_core + 1
            vp = -(-v // self.bv) * self.bv
            adj = np.full((vp, vp), np.inf, np.float32)
            src = np.asarray(self.ce_src)
            dst = np.asarray(self.ce_dst)
            if len(src):
                np.minimum.at(adj, (src, dst),
                              np.asarray(self.ce_w, np.float32))
            idx = np.arange(vp)
            adj[idx, idx] = np.minimum(adj[idx, idx], 0.0)
            # lazily built, possibly first reached inside a jit /
            # shard_map trace — keep the cached array a concrete device
            # constant, never a tracer
            with jax.ensure_compile_time_eval():
                self._adj = jnp.asarray(adj)
        return self._adj

    def ell(self):
        """(nbr_ids [Vp, D], nbr_w [Vp, D]) with Vp = n_core+1 rounded up
        to a multiple of bv (sentinel column included, padding rows
        edgeless)."""
        if self._ell is None:
            v = self.n_core + 1
            vp = -(-v // self.bv) * self.bv
            with jax.ensure_compile_time_eval():
                ids, ws = coo_to_ell(v, np.asarray(self.ce_src),
                                     np.asarray(self.ce_dst),
                                     np.asarray(self.ce_w),
                                     d_width=self.d_width)
                ids = jnp.pad(ids, ((0, vp - v), (0, 0)))
                ws = jnp.pad(ws, ((0, vp - v), (0, 0)),
                             constant_values=jnp.inf)
            self._ell = (ids, ws)
        return self._ell

    def run(self, seed_s, seed_t, mu, max_rounds: int, backend=None):
        """Relax to convergence. Returns (ans, ds, dt, rounds) with
        ds/dt of shape [Q, n_core+1] (matching ``core_relax``)."""
        backend = resolve_backend(backend)
        if backend == "reference":
            return core_relax(seed_s, seed_t, self.ce_src, self.ce_dst,
                              self.ce_w, mu, self.n_core, max_rounds)
        interpret = pallas_interpret(backend)
        mode = self.mode
        if mode == "dense":
            return _core_relax_dense(seed_s, seed_t, self.dense_adj(), mu,
                                     self.n_core, max_rounds, interpret,
                                     self.bq)
        nbr_ids, nbr_w = self.ell()
        if mode == "fused":
            return _core_relax_fused(seed_s, seed_t, nbr_ids, nbr_w, mu,
                                     self.n_core, max_rounds, interpret,
                                     self.bq)
        return _core_relax_ell(
            seed_s, seed_t, nbr_ids, nbr_w, mu, self.n_core, max_rounds,
            interpret, self.bq, self.bv)
