"""Kernel dispatch layer for the query hot path.

This module is the single seam between the paper-level query algebra
(`repro.core.query`) and the hardware kernels (`repro.kernels.*`). Both
stages of Algorithm 1 route through here:

  stage 1 — Equation 1 label intersection:
      ``label_intersect_dispatch`` -> ``kernels.label_intersect.ops``
      (tiled equality-join Pallas kernel on TPU, interpret-mode parity
      fallback off-TPU, searchsorted-merge jnp reference).

  stage 2 — label-seeded bidirectional core relaxation:
      ``CoreRelaxer`` — reference backend keeps the COO scatter-min
      wavefront (``core_relax``, bit-identical to the pre-dispatch
      engine); pallas/interpret backends run the ``spmv_relax`` ELL
      min-plus kernel with both frontiers *stacked* into one [2Q, V]
      launch so each round is a single kernel invocation.

Every backend computes the same per-round fixed point (synchronous
Bellman-Ford over G_k), so answers agree bitwise: each round takes a min
over the identical multiset of candidate sums regardless of whether the
edges are visited scatter-wise (COO) or gather-wise (ELL).

Query chunking lives one level up (``QueryEngine.query``): the batch is
tiled into fixed-size chunks so a 10k-query batch never materializes a
dense ``[Q, n_core+1]`` frontier per direction in one launch — peak
frontier memory is ``O(query_chunk * n_core)`` instead of
``O(Q * n_core)``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import pallas_interpret, resolve_backend
from repro.kernels.label_intersect import ops as li_ops
from repro.kernels.spmv_relax.kernel import spmv_relax_kernel
from repro.kernels.spmv_relax.ops import coo_to_ell


@partial(jax.jit, static_argnames=("n_sentinel", "backend"))
def label_intersect_dispatch(ids_s, d_s, ids_t, d_t, n_sentinel: int,
                             backend: str):
    """Equation 1 μ via the resolved kernel backend. Returns float32[Q]."""
    # named_scope threads through to XLA HLO metadata, so profiler
    # traces (jax.profiler / --profile-dir) attribute device time to
    # the paper's stages (docs/OBSERVABILITY.md)
    with jax.named_scope("islabel.label_intersect"):
        return li_ops.label_intersect(ids_s, d_s, ids_t, d_t, n_sentinel,
                                      backend=backend)


@partial(jax.jit, static_argnames=("n_core", "max_rounds"))
def core_relax(seed_s, seed_t, ce_src, ce_dst, ce_w, mu,
               n_core: int, max_rounds: int):
    """Reference bidirectional label-seeded relaxation on G_k (Alg. 1
    stage 2) — COO scatter-min wavefront rounds.

    seed_s/seed_t: [Q, n_core+1] initial distance vectors (+inf default,
    label distances scattered in, sentinel column n_core).
    Returns (ans [Q], ds, dt, rounds) with ans = min(μ, min_v ds+dt).
    """
    def body(state):
        ds, dt, it, _ = state
        cs = ds[:, ce_src] + ce_w[None, :]
        ds2 = ds.at[:, ce_dst].min(cs)
        ct = dt[:, ce_src] + ce_w[None, :]
        dt2 = dt.at[:, ce_dst].min(ct)
        improved = jnp.any(ds2 < ds) | jnp.any(dt2 < dt)
        return ds2, dt2, it + 1, improved

    def cond(state):
        _, _, it, improved = state
        return improved & (it < max_rounds)

    with jax.named_scope("islabel.core_relax"):
        ds, dt, rounds, _ = jax.lax.while_loop(
            cond, body, (seed_s, seed_t, jnp.int32(0), jnp.bool_(True)))
        # the sentinel column n_core parks non-core label entries —
        # exclude it
        through_core = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
        return jnp.minimum(mu, through_core), ds, dt, rounds


@partial(jax.jit,
         static_argnames=("n_core", "max_rounds", "interpret", "bq", "bv"))
def _core_relax_ell(seed_s, seed_t, nbr_ids, nbr_w, mu, n_core: int,
                    max_rounds: int, interpret: bool, bq: int, bv: int):
    """Kernel-path relaxation: both frontiers stacked into one [2Q, Vp]
    matrix, one ``spmv_relax`` launch per wavefront round."""
    q, v = seed_s.shape
    vp = nbr_ids.shape[0]                     # V padded to a bv multiple
    rows = 2 * q
    rp = -(-rows // bq) * bq
    d0 = jnp.concatenate([seed_s, seed_t], axis=0)
    d0 = jnp.pad(d0, ((0, rp - rows), (0, vp - v)), constant_values=jnp.inf)

    def body(state):
        d, it, _ = state
        d2 = spmv_relax_kernel(d, nbr_ids, nbr_w, bq=bq, bv=bv,
                               interpret=interpret)
        return d2, it + 1, jnp.any(d2 < d)

    def cond(state):
        _, it, improved = state
        return improved & (it < max_rounds)

    with jax.named_scope("islabel.core_relax_ell"):
        d, rounds, _ = jax.lax.while_loop(
            cond, body, (d0, jnp.int32(0), jnp.bool_(True)))
        ds = d[:q, :v]
        dt = d[q:rows, :v]
        through_core = jnp.min(ds[:, :n_core] + dt[:, :n_core], axis=1)
        return jnp.minimum(mu, through_core), ds, dt, rounds


class CoreRelaxer:
    """Backend-dispatched stage-2 relaxation over the local core graph.

    Holds the COO edge arrays (local indices in [0, n_core), weights)
    and lazily derives the ELL layout the ``spmv_relax`` kernel consumes
    — built once per index on first kernel-path query, padded to a
    lane-aligned vertex count so per-round launches need no reshaping.
    """

    def __init__(self, ce_src, ce_dst, ce_w, n_core: int, *,
                 bq: int = 8, bv: int = 128, d_width: int = 16):
        self.ce_src = ce_src
        self.ce_dst = ce_dst
        self.ce_w = ce_w
        self.n_core = n_core
        self.bq = bq
        self.bv = bv
        self.d_width = d_width
        self._ell = None

    def ell(self):
        """(nbr_ids [Vp, D], nbr_w [Vp, D]) with Vp = n_core+1 rounded up
        to a multiple of bv (sentinel column included, padding rows
        edgeless)."""
        if self._ell is None:
            v = self.n_core + 1
            vp = -(-v // self.bv) * self.bv
            ids, ws = coo_to_ell(v, np.asarray(self.ce_src),
                                 np.asarray(self.ce_dst),
                                 np.asarray(self.ce_w),
                                 d_width=self.d_width)
            ids = jnp.pad(ids, ((0, vp - v), (0, 0)))
            ws = jnp.pad(ws, ((0, vp - v), (0, 0)), constant_values=jnp.inf)
            self._ell = (ids, ws)
        return self._ell

    def run(self, seed_s, seed_t, mu, max_rounds: int, backend=None):
        """Relax to convergence. Returns (ans, ds, dt, rounds) with
        ds/dt of shape [Q, n_core+1] (matching ``core_relax``)."""
        backend = resolve_backend(backend)
        if backend == "reference":
            return core_relax(seed_s, seed_t, self.ce_src, self.ce_dst,
                              self.ce_w, mu, self.n_core, max_rounds)
        nbr_ids, nbr_w = self.ell()
        ans, ds, dt, rounds = _core_relax_ell(
            seed_s, seed_t, nbr_ids, nbr_w, mu, self.n_core, max_rounds,
            pallas_interpret(backend), self.bq, self.bv)
        return ans, ds, dt, rounds
