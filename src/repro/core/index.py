"""ISLabelIndex — the public API of the paper's contribution.

  idx = ISLabelIndex.build(n, src, dst, w, IndexConfig())
  d = idx.query(s_batch, t_batch)           # exact distances, batched
  path = idx.shortest_path(s, t)            # §8.1 path reconstruction
  idx.save(dir); ISLabelIndex.load(dir)
  idx.insert_vertex(u, nbrs, ws) / idx.delete_vertex(u)   # §8.3
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import BuildStats, IndexConfig
from repro.core.hierarchy import Hierarchy, build_hierarchy
from repro.core.labeling import build_labels
from repro.core.query import QueryEngine


def live_device_bytes() -> int:
    """Sum of live device-array bytes — the sampled 'peak device bytes'
    probe of the construction bench (backend memory_stats when the
    platform reports them, else the live-array walk; CPU reports none)."""
    try:
        stats = jax.local_devices()[0].memory_stats()
        if stats and "peak_bytes_in_use" in stats:
            return int(stats["peak_bytes_in_use"])
    except Exception:
        pass
    return int(sum(x.nbytes for x in jax.live_arrays()))


@dataclasses.dataclass
class ISLabelIndex:
    n: int
    k: int
    cfg: IndexConfig
    level: np.ndarray            # int32[n]
    # device label arrays [n+1, l_cap]
    lbl_ids: jnp.ndarray
    lbl_d: jnp.ndarray
    lbl_pred: jnp.ndarray
    # up-edge matrix (host, for paths/updates) [n+1, d_cap]
    up_ids: np.ndarray
    up_w: np.ndarray
    up_via: np.ndarray
    # core graph: global-id COO + local-index device copy
    core_ids: np.ndarray         # int32[n_core]
    core_pos_host: np.ndarray    # int32[n+1]
    core_src: np.ndarray
    core_dst: np.ndarray
    core_w: np.ndarray
    core_via: np.ndarray
    engine: QueryEngine
    stats: BuildStats
    # lazy caches (hoisted out of the per-call path of the host oracle
    # so it is usable as the audit reference inside loadgen replays;
    # invalidated by _refresh_device on every in-place mutation)
    _host_labels: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _core_adj: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _paths: object = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(n, src, dst, w, cfg: IndexConfig = IndexConfig()) -> "ISLabelIndex":
        from repro.core import sync as hsync
        t0 = time.perf_counter()
        syncs0 = hsync.sync_count()
        hier = build_hierarchy(n, src, dst, w, cfg)
        t1 = time.perf_counter()
        lbl_ids, lbl_d, lbl_pred = build_labels(hier, cfg)
        jax.block_until_ready(lbl_ids)
        t2 = time.perf_counter()
        idx = ISLabelIndex._assemble(n, hier, lbl_ids, lbl_d, lbl_pred, cfg,
                                     m_input=len(src))
        idx.stats.build_seconds = time.perf_counter() - t0
        idx.stats.peel_seconds = t1 - t0
        idx.stats.label_seconds = t2 - t1
        idx.stats.host_syncs = hsync.sync_count() - syncs0
        idx.stats.peak_device_bytes = live_device_bytes()
        return idx

    @staticmethod
    def _assemble(n, hier: Hierarchy, lbl_ids, lbl_d, lbl_pred,
                  cfg: IndexConfig, m_input: int) -> "ISLabelIndex":
        core_ids = np.flatnonzero(hier.level == hier.k).astype(np.int32)
        n_core = len(core_ids)
        core_pos = np.full(n + 1, n_core, np.int32)
        core_pos[core_ids] = np.arange(n_core, dtype=np.int32)
        ce_src = core_pos[hier.core_src]
        ce_dst = core_pos[hier.core_dst]
        engine = QueryEngine(
            lbl_ids, lbl_d, jnp.asarray(core_pos),
            (jnp.asarray(ce_src), jnp.asarray(ce_dst),
             jnp.asarray(hier.core_w, jnp.float32)),
            n=n, n_core=n_core, max_rounds=cfg.max_relax_rounds,
            backend=cfg.query_backend, query_chunk=cfg.query_chunk,
            label_dtype=cfg.label_dtype)
        ids_h = np.asarray(lbl_ids)
        entries = int((ids_h[:n] < n).sum())
        stats = BuildStats(
            n=n, m=m_input, k=hier.k, n_core=n_core,
            m_core=len(hier.core_src), level_sizes=hier.level_sizes,
            graph_sizes=hier.graph_sizes, label_entries=entries,
            label_bytes=entries * 8, mis_rounds=hier.mis_rounds,
            peel_loop_syncs=hier.host_syncs, peel_iters=hier.peel_iters)
        return ISLabelIndex(
            n=n, k=hier.k, cfg=cfg, level=hier.level, lbl_ids=lbl_ids,
            lbl_d=lbl_d, lbl_pred=lbl_pred, up_ids=hier.up_ids, up_w=hier.up_w,
            up_via=hier.up_via, core_ids=core_ids, core_pos_host=core_pos,
            core_src=hier.core_src, core_dst=hier.core_dst, core_w=hier.core_w,
            core_via=hier.core_via, engine=engine, stats=stats)

    # ------------------------------------------------------------------ query
    def query(self, s, t):
        """Exact batched distances (float32[Q])."""
        return self.engine.query(s, t)

    def query_host(self, s, t) -> np.ndarray:
        return np.asarray(self.query(np.atleast_1d(s), np.atleast_1d(t)))

    def query_types(self, s, t):
        return self.engine.classify(s, t, self.level, self.k)

    # ------------------------------------------------------------- §8.1 paths
    def _label_host(self):
        """Cached host copies of the label arrays (ids, d, pred).

        Hoisted out of the per-call path: ``shortest_path`` used to
        re-materialize device rows via ``jnp.array([s])`` on every
        invocation, which made the oracle unusable as the audit
        reference inside loadgen replays."""
        if self._host_labels is None:
            self._host_labels = (np.asarray(self.lbl_ids),
                                 np.asarray(self.lbl_d),
                                 np.asarray(self.lbl_pred))
        return self._host_labels

    def _core_adjacency(self):
        """Cached src-sorted core adjacency (indptr, dst, w, via) —
        previously re-sorted inside every ``_core_path`` call."""
        if self._core_adj is None:
            from repro.core.ref import sorted_adjacency
            self._core_adj = sorted_adjacency(
                self.n, self.core_src, self.core_dst, self.core_w,
                self.core_via)
        return self._core_adj

    def path_engine(self):
        """Batched device-side path reconstruction (``repro.paths``,
        docs/PATHS.md). Memoized per index generation — in-place
        mutations invalidate it alongside the query engine."""
        if self._paths is None:
            from repro.paths import PathEngine
            self._paths = PathEngine.from_index(self)
        return self._paths

    def shortest_paths(self, s, t, hop_cap: int = 256,
                       backend: str | None = None):
        """Batched shortest paths through the jitted ``PathEngine`` —
        the serving-rate replacement for the scalar ``shortest_path``
        oracle. Returns ``(dist float32[Q], list of vertex lists,
        ok bool[Q])``; hop_cap escalates automatically on overflow."""
        return self.path_engine().paths(s, t, hop_cap=hop_cap,
                                        backend=backend)

    def _up_slot(self, v: int, u: int):
        row = self.up_ids[v]
        slots = np.flatnonzero(row == u)
        return int(slots[0]) if len(slots) else -1

    def _expand_edge(self, a: int, b: int, via: int) -> list[int]:
        """Expand an (augmenting) edge into original-graph vertices
        [a..b) — recursion over the `via` bookkeeping (§8.1)."""
        if via < 0:
            return [a]
        # via c was removed below both a and b; its up-adjacency contains both
        sa = self._up_slot(via, a)
        sb = self._up_slot(via, b)
        if sa < 0 or sb < 0:     # should not happen on a consistent index
            return [a]
        left = self._expand_edge(a, via, int(self.up_via[via, sa]))
        right = self._expand_edge(via, b, int(self.up_via[via, sb]))
        return left + right

    def _label_path(self, v: int, x: int) -> list[int]:
        """Path v -> x following the label pred chain (x an ancestor of v)."""
        if v == x:
            return [v]
        ids_h, _, pred_h = self._label_host()
        row = ids_h[v]
        j = np.searchsorted(row, x)
        if j >= len(row) or row[j] != x:
            raise ValueError(f"{x} is not an ancestor of {v}")
        u = int(pred_h[v][j])
        if u < 0:
            raise ValueError("inconsistent pred chain")
        slot = self._up_slot(v, u)
        hop = self._expand_edge(v, u, int(self.up_via[v, slot]))
        return hop + self._label_path(u, x)

    def shortest_path(self, s: int, t: int):
        """Return (distance, [s..t] vertex list in the original graph)."""
        dist = float(self.query_host([s], [t])[0])
        if not np.isfinite(dist):
            return dist, []
        # meeting vertex: best label-intersection ancestor, or best core
        # pair — host-side over the cached label copies (Equation 1)
        from repro.core.ref import host_meet
        ids_h, d_h, _ = self._label_host()
        mu, w = host_meet(ids_h[s], d_h[s], ids_h[t], d_h[t], self.n)
        if mu <= dist + 1e-6 and w >= 0:
            left = self._label_path(s, w)
            right = self._label_path(t, w)
            return dist, left + right[::-1][1:]
        # path passes through the core: host Dijkstra on G_k with label seeds
        path = self._core_path(s, t, dist)
        return dist, path

    def _core_path(self, s: int, t: int, dist: float):
        from repro.core.ref import seeded_sssp
        ids_h, d_h, _ = self._label_host()
        seeds = {}
        for side, v in ((0, s), (1, t)):
            row_i, row_d = ids_h[v], d_h[v]
            sd = {}
            for i, u in enumerate(row_i):
                u = int(u)
                if u < self.n and self.level[u] == self.k:
                    sd[u] = float(row_d[i])
            seeds[side] = sd
        # adjacency of core in global ids (cached, src-sorted);
        # undirected core: the same adjacency serves both directions
        adj = self._core_adjacency()
        ds, ps = seeded_sssp(seeds[0], *adj)
        dt, pt = seeded_sssp(seeds[1], *adj)
        meet = min((ds.get(u, np.inf) + dt.get(u, np.inf), u) for u in ds)[1]

        def unwind(par, sd, v, side):
            out = [v]
            while par[v][0] is not None:
                u, via = par[v]
                # expand (u -> v) into original vertices, then continue from u
                out = self._expand_edge(u, v, via) + out
                v = u
            # label path from the query endpoint to the seed vertex
            endpoint = s if side == 0 else t
            head = self._label_path(endpoint, v)
            return head[:-1] + out
        left = unwind(ps, seeds[0], meet, 0)
        right = unwind(pt, seeds[1], meet, 1)
        return left + right[::-1][1:]

    # ------------------------------------------------------ §8.3 maintenance
    def _descendants(self, v: int):
        """Vertices whose label contains v (BFS over reversed up-edges)."""
        rev = {}
        nz = np.argwhere(self.up_ids[:self.n] < self.n)
        for a, slot in nz:
            rev.setdefault(int(self.up_ids[a, slot]), []).append(int(a))
        out, frontier = set(), [v]
        while frontier:
            u = frontier.pop()
            for c in rev.get(u, []):
                if c not in out:
                    out.add(c)
                    frontier.append(c)
        return out

    def insert_vertex(self, u: int, nbrs, ws) -> np.ndarray:
        """§8.3 lazy insert: u joins G_k; label entries (u, d) pushed to the
        descendants of its non-core neighbors. Host-side, rebuild-free.
        Returns the touched label rows (sorted vertex ids)."""
        ids_h = np.array(self.lbl_ids)          # writable host copies
        d_h = np.array(self.lbl_d)
        pred_h = np.array(self.lbl_pred)
        rows = apply_insert_host(self, ids_h, d_h, pred_h, u, nbrs, ws)
        self._refresh_device(ids_h, d_h, pred_h)
        return rows

    def delete_vertex(self, u: int) -> np.ndarray:
        """§8.3 lazy delete: drop u's core edges and its entries in the
        labels of all descendants. Returns the touched label rows."""
        ids_h = np.array(self.lbl_ids)          # writable host copies
        d_h = np.array(self.lbl_d)
        pred_h = np.array(self.lbl_pred)
        rows = apply_delete_host(self, ids_h, d_h, pred_h, u)
        self._refresh_device(ids_h, d_h, pred_h)
        return rows

    def _refresh_device(self, ids_h, d_h, pred_h):
        """Upload mutated host label arrays and rebuild the engine. The
        fresh host copies seed the host-label cache (they ARE the new
        labels — no device round trip on the next oracle call)."""
        self._install_labels(jnp.asarray(ids_h), jnp.asarray(d_h),
                             jnp.asarray(pred_h), host=(ids_h, d_h, pred_h))

    def _install_labels(self, lbl_ids, lbl_d, lbl_pred, host=None):
        """Install new device label arrays + rebuild the core maps and
        the query engine. ``host`` (matching host copies) seeds the
        hoisted host-label cache; the core-adjacency and path-engine
        caches are always dropped — the core edge arrays may have
        changed alongside the labels."""
        self.lbl_ids = lbl_ids
        self.lbl_d = lbl_d
        self.lbl_pred = lbl_pred
        self._host_labels = host
        self._core_adj = None
        self._paths = None
        core_ids = np.flatnonzero(self.level == self.k).astype(np.int32)
        n_core = len(core_ids)
        core_pos = np.full(self.n + 1, n_core, np.int32)
        core_pos[core_ids] = np.arange(n_core, dtype=np.int32)
        self.core_ids, self.core_pos_host = core_ids, core_pos
        self.engine = QueryEngine(
            self.lbl_ids, self.lbl_d, jnp.asarray(core_pos),
            (jnp.asarray(core_pos[self.core_src]),
             jnp.asarray(core_pos[self.core_dst]),
             jnp.asarray(self.core_w, jnp.float32)),
            n=self.n, n_core=n_core, max_rounds=self.cfg.max_relax_rounds,
            backend=self.cfg.query_backend, query_chunk=self.cfg.query_chunk,
            label_dtype=self.cfg.label_dtype)

    # ------------------------------------------------------------------ io
    def save(self, path):
        p = Path(path)
        p.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(
            p / "index.npz", level=self.level, lbl_ids=np.asarray(self.lbl_ids),
            lbl_d=np.asarray(self.lbl_d), lbl_pred=np.asarray(self.lbl_pred),
            up_ids=self.up_ids, up_w=self.up_w, up_via=self.up_via,
            core_src=self.core_src, core_dst=self.core_dst,
            core_w=self.core_w, core_via=self.core_via)
        meta = {"n": self.n, "k": self.k,
                "cfg": dataclasses.asdict(self.cfg),
                "stats": dataclasses.asdict(self.stats)}
        (p / "meta.json").write_text(json.dumps(meta))

    @staticmethod
    def load(path) -> "ISLabelIndex":
        p = Path(path)
        meta = json.loads((p / "meta.json").read_text())
        z = np.load(p / "index.npz")
        cfg = IndexConfig(**meta["cfg"])
        hier = Hierarchy(
            n=meta["n"], k=meta["k"], level=z["level"], up_ids=z["up_ids"],
            up_w=z["up_w"], up_via=z["up_via"], core_src=z["core_src"],
            core_dst=z["core_dst"], core_w=z["core_w"], core_via=z["core_via"],
            level_sizes=[], graph_sizes=[], mis_rounds=[])
        idx = ISLabelIndex._assemble(
            meta["n"], hier, jnp.asarray(z["lbl_ids"]), jnp.asarray(z["lbl_d"]),
            jnp.asarray(z["lbl_pred"]), cfg, m_input=meta["stats"]["m"])
        idx.stats = BuildStats(**meta["stats"])
        return idx


# ------------------------------------------------------------------------
# §8.3 host mutators, shared by ISLabelIndex (in-place), the versioned
# serving store (repro.serve.versions — copy-on-write apply), and
# ShardedIndex.apply_mutations. ``st`` is any object carrying the graph
# structure the lazy update rules read and rewrite:
#   n, k, level (mutated), up_ids, up_w (read),
#   core_src/core_dst/core_w/core_via, core_ids (rebound, never mutated).
# The label arrays are writable host copies, mutated in place. Both
# functions return the touched label rows (sorted int64 vertex ids) so
# callers can propagate the change incrementally (device scatter /
# per-shard block update) instead of re-uploading the full table.


def _children_of_host(st, v):
    """(child, w) pairs over up-edges into v — label(child) merges
    label(v) + w, so a pushed entry relaxes down the same edges."""
    out = []
    rows, slots = np.nonzero(st.up_ids[:st.n] == v)
    for r, sl in zip(rows, slots):
        out.append((int(r), float(st.up_w[r, sl])))
    return out


def _set_label_entry_host(st, ids_h, d_h, pred_h, v, u, d, pred,
                          touched) -> bool:
    row = ids_h[v]
    j = np.searchsorted(row, u)
    if j < row.shape[0] and row[j] == u:
        if d_h[v, j] <= d:
            return False
        d_h[v, j] = d
        pred_h[v, j] = pred
        touched.add(int(v))
        return True
    if row[-1] < st.n:
        raise RuntimeError("label row full: raise l_cap and rebuild")
    ids_h[v] = np.insert(row, j, u)[:-1]
    d_h[v] = np.insert(d_h[v], j, d)[:-1]
    pred_h[v] = np.insert(pred_h[v], j, pred)[:-1]
    touched.add(int(v))
    return True


def _push_entry_host(st, ids_h, d_h, pred_h, v, u, d, pred, touched):
    """Insert/improve (u, d) in label(v), then relax v's descendants."""
    if not _set_label_entry_host(st, ids_h, d_h, pred_h, v, u, d, pred,
                                 touched):
        return
    for child, wc in _children_of_host(st, v):
        _push_entry_host(st, ids_h, d_h, pred_h, child, u, d + wc, v, touched)


def apply_insert_host(st, ids_h, d_h, pred_h, u: int, nbrs, ws,
                      touched: set | None = None) -> np.ndarray:
    """§8.3 lazy insert on host label copies; returns touched rows."""
    assert u < st.n, "grow n before inserting (id must be preallocated)"
    touched = set() if touched is None else touched
    st.level[u] = st.k
    new_core_edges = ([], [], [])
    # u itself becomes a core vertex with self label
    _set_label_entry_host(st, ids_h, d_h, pred_h, u, u, 0.0, -1, touched)
    for v, wv in zip(nbrs, ws):
        v = int(v)
        if st.level[v] == st.k:
            new_core_edges[0].extend([u, v])
            new_core_edges[1].extend([v, u])
            new_core_edges[2].extend([float(wv), float(wv)])
        else:
            # add (u, w) to label(v) and propagate to v's descendants
            _push_entry_host(st, ids_h, d_h, pred_h, v, u, float(wv), v,
                             touched)
    if new_core_edges[0]:
        st.core_src = np.concatenate(
            [st.core_src, np.asarray(new_core_edges[0], np.int32)])
        st.core_dst = np.concatenate(
            [st.core_dst, np.asarray(new_core_edges[1], np.int32)])
        st.core_w = np.concatenate(
            [st.core_w, np.asarray(new_core_edges[2], np.float32)])
        st.core_via = np.concatenate(
            [st.core_via, np.full(len(new_core_edges[0]), -1, np.int32)])
    if st.level[u] == st.k and u not in set(st.core_ids.tolist()):
        st.core_ids = np.concatenate(
            [st.core_ids, np.asarray([u], np.int32)])
    return np.asarray(sorted(touched), np.int64)


def apply_delete_host(st, ids_h, d_h, pred_h, u: int,
                      touched: set | None = None) -> np.ndarray:
    """§8.3 lazy delete on host label copies; returns touched rows.

    Exact inverse of ``apply_insert_host`` when u was previously
    inserted (every mutated entry carries ancestor id u); conservative
    — never under-reports a distance — for build-time vertices (see
    tests/test_paths_updates.py and docs/MUTATION.md)."""
    touched = set() if touched is None else touched
    keep = (st.core_src != u) & (st.core_dst != u)
    st.core_src, st.core_dst = st.core_src[keep], st.core_dst[keep]
    st.core_w, st.core_via = st.core_w[keep], st.core_via[keep]
    rows = np.unique(np.nonzero(ids_h[:st.n] == u)[0])
    for v in rows:
        j = np.searchsorted(ids_h[v], u)
        ids_h[v] = np.concatenate([np.delete(ids_h[v], j), [st.n]])
        d_h[v] = np.concatenate([np.delete(d_h[v], j), [np.inf]])
        pred_h[v] = np.concatenate([np.delete(pred_h[v], j), [-1]])
        touched.add(int(v))
    st.level[u] = st.k  # orphaned; queries fall back to core/∞
    return np.asarray(sorted(touched), np.int64)
