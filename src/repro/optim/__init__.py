from repro.optim.adamw import adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedule import warmup_cosine
