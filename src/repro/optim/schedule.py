"""LR schedules (multiplier-valued: pass as Optimizer(schedule=...))."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup_steps: int, total_steps: int, min_ratio=0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup_steps, 1))
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn


def constant():
    return lambda step: jnp.float32(1.0)
