"""Adafactor (Shazeer & Stern, arXiv:1804.04235) — factored second
moments. The memory-feasible optimizer for the 1T-param kimi-k2 cells:
for an [a, b] matrix the state is a+b floats instead of a*b (plus no
first moment), ~2 bytes/param total vs AdamW's 8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer, clip_by_global_norm, global_norm


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_norm=1.0,
              weight_decay=0.0, schedule=None) -> Optimizer:
    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr if schedule is None else schedule(step) * lr

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                prec = jax.lax.rsqrt(
                    jnp.maximum(rfac[..., None] * vc[..., None, :], eps))
                u = g * prec
                news = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                news = {"v": v}
            # update-norm clipping (Adafactor's d=1.0 rule, simplified)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms)
            newp = p - lr_t * (u + weight_decay * p).astype(p.dtype)
            return newp, news

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = tdef.unflatten([o[1] for o in outs])
        return new_params, new_state, gnorm

    return Optimizer(init=init, update=update)
