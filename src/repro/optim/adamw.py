"""AdamW with global-norm clipping. Optax-style (init/update) minimal
implementation — state shards exactly like params (ZeRO: the sharding
rules put optimizer state on the same mesh axes as the weights).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable      # (grads, state, params, step) -> (updates, state)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw(lr=1e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm=1.0, schedule=None) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(jnp.zeros_like, params),
            "nu": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params, step):
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr if schedule is None else schedule(step) * lr
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state["nu"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            step_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return p - lr_t * (step_ + weight_decay * p)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu}, gnorm

    return Optimizer(init=init, update=update)
