import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Performance hillclimbing driver (§Perf): compile named VARIANTS of a
cell and record the roofline-term deltas.

  PYTHONPATH=src python -m repro.launch.perf --cell qwen2-72b:train_4k
  PYTHONPATH=src python -m repro.launch.perf --cell qwen2-moe:train_4k:mp
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.launch.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   collective_bytes, cost_dict)
from repro.launch.dryrun import _compile_costs, _probe_specs
from repro.launch.mesh import make_production_mesh

# variant = (model_cfg field overrides, bundle overrides, spec overrides)
VARIANTS = {
    "qwen2-72b:train_4k": {
        "baseline": ({}, {}, {}),
        "iota_ce": ({"ce_impl": "iota"}, {}, {}),
        "iota+accum4": ({"ce_impl": "iota"}, {"grad_accum": 4}, {}),
        "iota+accum8": ({"ce_impl": "iota"}, {"grad_accum": 8}, {}),
        "iota+accum4+actshard": ({"ce_impl": "iota", "act_shard": True},
                                 {"grad_accum": 4}, {}),
        # with temp headroom from accum+actshard, buy back the remat
        # recompute (saves ~2ND fwd flops + its traffic)
        "accum8+actshard+dots": ({"ce_impl": "iota", "act_shard": True,
                                  "remat_policy": "dots"},
                                 {"grad_accum": 8}, {}),
        "accum8+actshard+noremat": ({"ce_impl": "iota", "act_shard": True,
                                     "remat": False},
                                    {"grad_accum": 8}, {}),
    },
    "qwen2-moe-a2.7b:train_4k:mp": {
        "baseline": ({}, {}, {}),
        "iota_ce": ({"ce_impl": "iota"}, {}, {}),
        "disp_shard": ({"moe": {"dispatch_shard": True}}, {}, {}),
        "disp_shard+cf1": ({"moe": {"dispatch_shard": True,
                                    "capacity_factor": 1.0}}, {}, {}),
        "disp_shard+accum4": ({"moe": {"dispatch_shard": True}},
                              {"grad_accum": 4}, {}),
        # pad 60 -> 64 experts: true EP over the model axis (local expert
        # GEMMs; dispatch becomes all-to-all instead of buffer all-reduce)
        "ep_pad64": ({"moe": {"ep_pad": 64}}, {}, {}),
        "ep_pad64+accum4": ({"moe": {"ep_pad": 64}}, {"grad_accum": 4}, {}),
        "ep_pad64+scatter": ({"moe": {"ep_pad": 64,
                                      "combine_impl": "scatter"}}, {}, {}),
        # int8_pods (shard_map over pod + auto axes) hits an XLA SPMD
        # partitioner CHECK-failure at 512 devices (b/433785288-class);
        # the compression path is validated at 8 devices in
        # tests/test_distributed.py instead.
    },
    "kimi-k2-1t-a32b:train_4k:mp": {
        "baseline": ({}, {}, {}),
        "iota_ce": ({"ce_impl": "iota"}, {}, {}),
        "iota+accum4": ({"ce_impl": "iota"}, {"grad_accum": 4}, {}),
        "iota+accum4+actshard": ({"ce_impl": "iota", "act_shard": True},
                                 {"grad_accum": 4}, {}),
    },
    "islabel:serve_128m": {
        "baseline": ({}, {}, {}),
        "chunked_relax": ({}, {"relax_chunks": 64}, {}),
        "bf16_labels": ({}, {"lbl_dtype": "bfloat16"}, {}),
        "chunked+bf16": ({}, {"relax_chunks": 64,
                              "lbl_dtype": "bfloat16"}, {}),
        "chunked+bf16+r6": ({}, {"relax_chunks": 64,
                                 "lbl_dtype": "bfloat16",
                                 "relax_rounds": 6}, {}),
        "chunked256": ({}, {"relax_chunks": 256}, {}),
        "chunked1024": ({}, {"relax_chunks": 1024}, {}),
    },
    "dimenet:ogb_products": {
        "baseline": ({}, {}, {}),
    },
}


def run_variant(arch, shape, multi_pod, model_over, bundle_over, spec_over,
                name, out_dir: Path):
    from repro.train.steps import build_bundle
    spec = registry.get_spec(arch)
    if model_over:
        mo = dict(model_over)
        cfg = spec.model_cfg
        if "moe" in mo:                       # nested MoE overrides
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **mo.pop("moe")))
        spec = dataclasses.replace(
            spec, model_cfg=dataclasses.replace(cfg, **mo))
    if spec_over:
        spec = dataclasses.replace(spec, **spec_over)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape, "variant": name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "model_over": model_over, "bundle_over": bundle_over}
    try:
        t0 = time.perf_counter()
        with mesh:
            compiled = build_bundle(spec, shape, mesh,
                                    overrides=bundle_over).lower().compile()
        cost = cost_dict(compiled)
        mem = compiled.memory_analysis()
        coll = collective_bytes(compiled.as_text())
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        pr = _probe_specs(spec)
        if pr is not None:
            lo, hi, d_lo, d_hi, d_real = pr

            probe_over = dict(bundle_over, accum_unroll=True)

            def _with(s):
                from repro.train.steps import build_bundle as bb
                with mesh:
                    c = bb(s, shape, mesh, overrides=probe_over) \
                        .lower().compile()
                return (float(cost_dict(c).get("flops", 0)),
                        float(cost_dict(c).get("bytes accessed", 0)),
                        collective_bytes(c.as_text()))
            f_lo, b_lo, c_lo = _with(lo)
            f_hi, b_hi, c_hi = _with(hi)
            sc = (d_real - d_lo) / (d_hi - d_lo)
            flops = f_lo + sc * (f_hi - f_lo)
            byts = b_lo + sc * (b_hi - b_lo)
            coll = {k: c_lo.get(k, 0) + sc * (c_hi.get(k, 0) - c_lo.get(k, 0))
                    for k in set(c_lo) | set(c_hi)}
        rec.update(
            ok=True, compile_s=round(time.perf_counter() - t0, 1),
            flops_per_device=flops, bytes_per_device=byts,
            collective_bytes_per_device=coll,
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            arg_bytes=getattr(mem, "argument_size_in_bytes", None),
            t_compute_s=flops / PEAK_FLOPS, t_memory_s=byts / HBM_BW,
            t_collective_s=coll["total"] / ICI_BW)
        dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
                  key=lambda k: rec[k])
        rec["dominant"] = dom.replace("t_", "").replace("_s", "")
        print(f"[{name}] temp={rec['temp_bytes']} "
              f"t_mem={rec['t_memory_s']:.2f} t_coll={rec['t_collective_s']:.2f} "
              f"t_comp={rec['t_compute_s']:.2f} dom={rec['dominant']}")
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-3000:])
        print(f"[{name}] FAIL {rec['error'][:200]}")
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = "multipod" if multi_pod else "singlepod"
    (out_dir / f"{arch}__{shape}__{tag}__{name}.json").write_text(
        json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    parts = args.cell.split(":")
    arch, shape = parts[0], parts[1]
    multi = len(parts) > 2 and parts[2] == "mp"
    variants = VARIANTS[args.cell]
    if args.variant:
        variants = {args.variant: variants[args.variant]}
    for name, (mo, bo, so) in variants.items():
        run_variant(arch, shape, multi, mo, bo, so, name, Path(args.out))


if __name__ == "__main__":
    main()
