"""Training launcher: real training on whatever devices exist.

Wires together the full substrate: config registry -> step bundle on a
host mesh -> synthetic data pipeline (prefetch) -> fault-tolerant runner
(async checkpoints, NaN rollback, preemption handling, stragglers).

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt

``--smoke`` swaps in the reduced config (same structure, tiny dims) so a
step runs on CPU; on a real fleet drop the flag and pass the mesh shape.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ArchSpec
from repro.data import synthetic
from repro.fault import FaultTolerantRunner, RunnerConfig
from repro.launch.mesh import make_host_mesh
from repro.train.steps import build_bundle, make_optimizer


def smoke_spec(spec: ArchSpec) -> ArchSpec:
    """Reduced-config spec with smoke shapes (CPU-runnable)."""
    from repro.configs import shapes as SH
    cfg = spec.smoke_cfg_fn()
    if spec.family == "lm":
        shp = {"train_4k": SH.LMShape("train_4k", "train", 64, 4)}
    elif spec.family == "gnn":
        shp = {"full_graph_sm": SH.GNNShape("full_graph_sm", "full", 200, 600,
                                            cfg.d_in if hasattr(cfg, "d_in")
                                            else 8, n_classes=4),
               "molecule": SH.GNNShape("molecule", "molecule", 8, 12,
                                       cfg.d_in if hasattr(cfg, "d_in")
                                       else 8, batch_graphs=4, n_classes=1)}
    elif spec.family == "recsys":
        shp = {"train_batch": SH.RecShape("train_batch", "train", 32)}
    else:
        raise KeyError(spec.family)
    return dataclasses.replace(spec, model_cfg=cfg, shapes=shp)


def init_state(spec: ArchSpec, mesh, bundle):
    """Materialize real params + optimizer state with the bundle's
    shardings (abstract trees stay abstract in the dry-run path only)."""
    from repro.models import dien as DM
    from repro.models.transformer import init_lm
    from repro.train.steps import _gnn_init
    key = jax.random.PRNGKey(0)
    cfg = bundle.static_meta.get("cfg", spec.model_cfg)
    if spec.family == "lm":
        params = init_lm(key, cfg)[0]
    elif spec.family == "gnn":
        params = _gnn_init(cfg, key)[0]
    else:
        params = DM.init_dien(key, cfg)[0]
    opt = make_optimizer(spec.optimizer)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    sh = bundle.in_shardings[0]
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)


def make_batch_fn(spec: ArchSpec, shape_name: str, seed: int = 0):
    shp = spec.shape(shape_name)
    cfg = spec.model_cfg
    specs = spec.input_specs(shape_name)
    if spec.family == "lm":
        return lambda step: synthetic.lm_batch(
            seed, step, shp.global_batch, shp.seq_len, cfg.vocab)
    if spec.family == "recsys":
        return lambda step: synthetic.dien_batch(
            seed, step, shp.batch, cfg.seq_len, cfg.n_items, cfg.n_cats,
            cfg.n_users)
    # gnn
    n_pad = specs["feats"].shape[0]
    e_pad = specs["edge_src"].shape[0]
    with_coords = "coords" in specs
    if shp.kind == "molecule":
        t_cap = specs["trip_kj"].shape[0] if "trip_kj" in specs else 0
        batch = synthetic.molecule_batch(seed, shp.batch_graphs, shp.n_nodes,
                                         shp.n_edges, shp.d_feat, n_pad,
                                         e_pad, t_cap)
    else:
        batch = synthetic.gnn_full_batch(seed, shp.n_nodes, 4.0, shp.d_feat,
                                         shp.n_classes, n_pad, e_pad,
                                         with_coords)
        if "atom_z" in specs:
            batch["atom_z"] = np.minimum(
                np.abs(batch["feats"][:, 0] * 10).astype(np.int32), 94)
        if "trip_kj" in specs:
            from repro.models.dimenet import build_triplets
            t_cap = specs["trip_kj"].shape[0]
            valid = batch["edge_src"] < shp.n_nodes
            tkj, tji = build_triplets(batch["edge_src"][valid],
                                      batch["edge_dst"][valid],
                                      shp.n_nodes, t_cap)
            nv = int(valid.sum())
            batch["trip_kj"] = np.where(tkj == nv, e_pad, tkj)
            batch["trip_ji"] = np.where(tji == nv, e_pad, tji)
    return lambda step: batch      # static graph, new step indices irrelevant


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    spec = registry.get_spec(args.arch)
    if args.smoke:
        spec = smoke_spec(spec)
    shape_name = args.shape or next(iter(spec.shapes))
    mesh = make_host_mesh(args.model_parallel)

    with mesh:
        bundle = build_bundle(spec, shape_name, mesh)
        step_fn = bundle.jitted()
        state = init_state(spec, mesh, bundle)
    make_batch = make_batch_fn(spec, shape_name)

    runner = FaultTolerantRunner(
        lambda st, b: step_fn(st, b), state, make_batch,
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every))
    if args.resume:
        start = runner.restore()
        print(f"resumed at step {start}")

    t0 = time.time()
    losses = []
    runner.run(args.steps, on_metrics=lambda s, m: losses.append(
        (s, float(np.asarray(m["loss"])))))
    dt = time.time() - t0
    print(f"[{spec.arch_id}/{shape_name}] {args.steps} steps in {dt:.1f}s "
          f"({dt / max(args.steps, 1):.3f}s/step)")
    for s, l in losses[:3] + losses[-3:]:
        print(f"  step {s}: loss {l:.4f}")
    if losses and len(losses) > 5:
        assert losses[-1][1] < losses[0][1] * 1.5, "loss diverged"
    print("done")


if __name__ == "__main__":
    main()
