"""Serving launcher — two serving modes:

* ``--mode lm``: prefill + decode loop for a (smoke) LM config: batched
  requests, KV-cache reuse, tokens/s report.
* ``--mode distance``: the paper's workload, served through the
  ``repro.serve`` subsystem (docs/SERVING.md): build or load an
  IS-LABEL index, register it, replay a scenario trace from the load
  generator through the micro-batching/routing/caching engine, audit
  every served answer, and print the metrics snapshot as JSON.

  PYTHONPATH=src python -m repro.launch.serve --mode distance \
      --scenario hotspot --n 4096 --queries 4096 --buckets 64,256,1024

  ``--shards N`` serves a ``repro.shard.ShardedIndex`` instead: the
  label table is partitioned over N devices and every batch runs the
  shard_map query path (docs/SHARDING.md). On CPU, simulate devices
  with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. The
  audit then checks the sharded serving path against the *unsharded*
  index, end to end.

  ``--audit index`` (default) checks bitwise equality of every served
  answer against a direct ``ISLabelIndex.query`` pass; ``--audit
  dijkstra`` additionally checks a sample against the host Dijkstra
  oracle (``core/ref.py``) — the CI smoke step runs the latter on a
  tiny graph. The process exits nonzero on any mismatch or zero QPS.

* ``--mode path``: the shortest-*path* retrieval workload
  (docs/PATHS.md): the same loadgen replay served through the path
  lane (``--hop-caps`` shape tiers). Every served path is validated
  edge by edge against the original graph — correct endpoints, real
  edges, weight sum equal to the served distance — and the distances
  are audited exactly like ``--mode distance``. Nonzero exit on any
  invalid path (the CI path smoke step).

  PYTHONPATH=src python -m repro.launch.serve --mode path \
      --graph er --n 512 --queries 512 --audit dijkstra

* ``--mode mutate``: live §8.3 mutation under traffic (docs/MUTATION.md):
  a *versioned* server replays a ``readwrite`` trace — reads micro-batch
  as usual, write rows apply insert/delete batches copy-on-write and
  hot-swap the published index version between micro-batches. The run
  asserts the compiled-shape counts did not grow across the whole
  replay (zero recompiles under writes). ``--audit rebuild`` replays
  the mutation log against from-scratch index rebuilds and demands
  every served read be bitwise-equal to the rebuilt index's answer for
  the exact version that served it. Nonzero exit on any mismatch,
  recompile, or zero QPS (the CI mutation smoke step).

  PYTHONPATH=src python -m repro.launch.serve --mode mutate \
      --graph er --n 256 --queries 512 --write-ratio 0.06 \
      --spares 12 --audit rebuild

* ``--mode http``: the same workloads served over the wire
  (docs/SERVICE.md): an asyncio HTTP front end (``repro.serve
  frontend``) wraps the registry, the loadgen trace replays through a
  real HTTP client (``/query``/``/mutate``), and the identical bitwise
  audits run on the answers that crossed the network. ``--replicas N``
  puts a ``ReplicaSet`` behind the front end (straggler health);
  ``--scenario straggler`` injects a synthetic stall into one replica
  and the run asserts the latency SLO burn-rate alert *fired*, while
  every clean scenario asserts the alerts stayed *quiet*. ``--sse-out``
  captures the live ``/events`` stream and ``--prom-out`` the final
  ``/metrics`` Prometheus exposition (the CI http smoke artifacts).

  PYTHONPATH=src python -m repro.launch.serve --mode http \
      --graph er --n 512 --queries 512 --scenario straggler \
      --replicas 2 --audit index
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


class _ObsSession:
    """Observability wiring shared by the serving modes
    (docs/OBSERVABILITY.md): request tracing (``--trace-out``), the
    compile-event watcher (always on — it is the exported form of the
    zero-recompile guarantee), a JSON-lines event log (``--events-out``)
    and the registry dump (``--metrics-out``). Construct *before* the
    server so warmup compiles are attributed to the warmup region."""

    def __init__(self, args, mode: str):
        from repro.obs import CompileWatcher, EventLog, NULL_TRACER, Tracer
        self.args = args
        self.mode = mode
        self.tracer = (Tracer(f"repro.serve[{mode}]") if args.trace_out
                       else NULL_TRACER)
        self.watcher = CompileWatcher().start()
        self.log = EventLog(args.events_out or None)
        self.log.log("start", mode=mode, graph=args.graph, n=args.n,
                     queries=args.queries, scenario=args.scenario)

    def profiled(self):
        """``jax.profiler`` session over the replay (``--profile-dir``);
        no-op without the flag."""
        from repro.obs import profiler_session
        return profiler_session(self.args.profile_dir or None)

    def finish(self, server, require_zero_read_compiles: bool = False
               ) -> int:
        """Write every requested sink; returns audit failures (trace
        coverage below 99%, or — in mutate mode — any XLA backend
        compile counted on the read path after warmup)."""
        from repro.obs import (device_memory_gauges, version_family_gauges,
                               write_chrome_trace, write_metrics)
        args = self.args
        failures = 0
        self.watcher.stop()
        device_memory_gauges()
        if server.versions is not None:
            version_family_gauges(server.versions, server=server.name)
        if self.watcher.supported:
            by_region = self.watcher.snapshot()
            print(f"  xla compiles by region: {by_region}")
            reads = self.watcher.count("serve_read")
            if require_zero_read_compiles:
                if reads:
                    print(f"  AUDIT FAIL: {reads} XLA backend compiles on "
                          f"the read path after warmup")
                    failures += 1
                else:
                    print("  audit[compile-events]: 0 backend compiles in "
                          "region serve_read across the replay")
        if self.tracer.enabled:
            cov = self.tracer.request_coverage()
            print(f"  trace: {len(self.tracer.finished())} spans; request "
                  f"coverage min={cov['min']:.4f} mean={cov['mean']:.4f} "
                  f"over {cov['requests']} request(s)")
            p = write_chrome_trace(args.trace_out, self.tracer)
            print(f"  trace written to {p} (chrome://tracing / "
                  f"ui.perfetto.dev)")
            if cov["requests"] and cov["min"] < 0.99:
                print("  AUDIT FAIL: request spans cover <99% of measured "
                      "request time")
                failures += 1
            self.log.log("trace_written", path=str(p), **cov)
        if args.metrics_out:
            p = write_metrics(args.metrics_out, mode=self.mode,
                              server=server.name)
            print(f"  metrics registry written to {p}")
        self.log.log("finish", mode=self.mode, failures=failures)
        self.log.close()
        return failures


def serve_lm(args):
    from repro.configs import registry
    from repro.launch.train import smoke_spec
    from repro.models.transformer import decode_step, init_lm, prefill
    spec = smoke_spec(registry.get_spec(args.arch))
    cfg = spec.model_cfg
    params = init_lm(jax.random.PRNGKey(0), cfg)[0]
    b, prompt_len, gen_len = args.batch, 16, args.gen_len
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab, (b, prompt_len)).astype(np.int32)
    pf = jax.jit(lambda p, t: prefill(p, cfg, t, prompt_len + gen_len))
    dc = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    t0 = time.time()
    logits, cache = pf(params, toks)
    out = [jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)]
    for _ in range(gen_len - 1):
        logits, cache = dc(params, cache, out[-1])
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    total = b * gen_len
    dt = time.time() - t0
    print(f"[serve-lm {spec.arch_id}] {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")


def _build_graph(args):
    from repro.graphs import generators as gen
    if args.graph == "rmat":
        return gen.rmat_graph(int(np.log2(args.n)), avg_deg=6.0, seed=1)
    if args.graph == "er":
        return gen.er_graph(args.n, avg_deg=2.2, seed=1)
    return gen.grid_graph(int(np.sqrt(args.n)), seed=1)


def _audit_paths(src, dst, w, trace, served, path_list, valid) -> int:
    """Validate every served path through the shared exactness gate
    (``repro.paths.validate``); returns the failure count (0 = ok)."""
    from repro.paths import (check_vertex_path, edge_weight_map,
                             integral_weights)
    failures = 0
    if not valid.all():
        print(f"  AUDIT FAIL: {int((~valid).sum())} served paths invalid "
              f"(hop_cap overflow unresolved)")
        failures += 1
    if src is None:
        print("  audit[paths]: edge validation SKIPPED — no edge list "
              "with --load (distance audits below still run)")
        return failures
    edges = edge_weight_map(src, dst, w)
    exact = integral_weights(edges)
    violations = []
    for i, p in enumerate(path_list):
        violations += check_vertex_path(edges, int(trace.s[i]),
                                        int(trace.t[i]), float(served[i]),
                                        p, exact=exact)
    if violations:
        print(f"  AUDIT FAIL: {len(violations)} path violations, e.g. "
              f"{violations[:3]}")
        failures += 1
    else:
        print(f"  audit[paths]: {len(path_list)}/{len(path_list)} served "
              f"paths valid (edges, endpoints, weight sum == distance)")
    return failures


def serve_distance(args, paths: bool = False) -> int:
    from repro.core import ISLabelIndex, IndexConfig, ref
    from repro.serve import IndexRegistry, make_trace

    obs = _ObsSession(args, "path" if paths else "distance")
    if args.load:
        idx = ISLabelIndex.load(args.load)
        n = idx.n
        src = dst = w = None
        print(f"[serve-distance] loaded index: {idx.stats.summary()}")
    else:
        n, src, dst, w = _build_graph(args)
        print(f"[serve-distance] graph {args.graph} n={n} m={len(src)}")
        t0 = time.time()
        idx = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=args.l_cap))
        print(f"  index built in {time.time() - t0:.1f}s: "
              f"{idx.stats.summary()}")
        if args.save:
            idx.save(args.save)

    serve_idx = idx
    if args.shards:
        from repro.shard import ShardedIndex
        serve_idx = ShardedIndex.from_index(
            idx, args.shards, strategy=args.shard_strategy)
        print(f"[serve-distance] sharded over {args.shards} device(s), "
              f"strategy={args.shard_strategy}, "
              f"entries/shard={serve_idx.shard_entry_counts().tolist()}")

    registry = IndexRegistry()
    server = registry.register(
        args.index_name, serve_idx,
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        max_wait_ms=args.max_wait_ms, cache_size=args.cache,
        backend=args.backend or None,
        path_hop_caps=(tuple(int(h) for h in args.hop_caps.split(","))
                       if paths else None),
        tracer=obs.tracer)
    print(f"  warmed {server.compile_cache_sizes()} shapes "
          f"in {server.warmup_seconds:.1f}s")

    trace = make_trace(args.scenario, n=n, num_requests=args.queries,
                       rate_qps=args.rate, seed=args.seed)
    failures = 0
    with obs.profiled():
        if paths:
            served, path_list, valid = server.serve_path_trace(trace)
        else:
            served = server.serve_trace(trace)
    if paths:
        failures += _audit_paths(src, dst, w, trace, served, path_list,
                                 valid)
    stats = server.stats()
    print(json.dumps(stats, indent=2, sort_keys=True))

    if args.audit in ("index", "dijkstra"):
        want = np.asarray(idx.query(trace.s, trace.t), np.float32)
        bad = int((~((served == want)
                     | (np.isnan(served) & np.isnan(want)))).sum())
        if bad:
            print(f"  AUDIT FAIL: {bad} served answers differ from "
                  f"ISLabelIndex.query")
            failures += 1
        else:
            print(f"  audit[index]: {len(trace)}/{len(trace)} served answers "
                  f"bitwise-equal to ISLabelIndex.query")
    if args.audit == "dijkstra" and src is None:
        print("  audit[dijkstra]: SKIPPED — no edge list with --load "
              "(index-equality audit above still ran)")
    if args.audit == "dijkstra" and src is not None:
        k = min(len(trace), args.audit_sample)
        srcs, inv = np.unique(trace.s[:k], return_inverse=True)
        oracle = ref.dijkstra_oracle(n, src, dst, w, srcs)
        want = oracle[inv, trace.t[:k]].astype(np.float32)
        ok = np.isfinite(want)
        if not (np.allclose(served[:k][ok], want[ok])
                and np.all(~np.isfinite(served[:k][~ok]))):
            print("  AUDIT FAIL: served answers differ from Dijkstra oracle")
            failures += 1
        else:
            print(f"  audit[dijkstra]: {k} answers match the oracle")
    if stats["qps_compute"] <= 0:
        print("  AUDIT FAIL: zero QPS")
        failures += 1
    failures += obs.finish(server)
    return failures


def _audit_rebuild(args, n, src, dst, w, trace, served, vids) -> int:
    """Differential rebuild audit for ``--mode mutate``: walk the trace
    in order, mirror every write batch into an edge-list model of the
    evolving graph, and for each version segment that served reads,
    rebuild an index from scratch on the mirrored graph and demand
    bitwise equality with the served answers."""
    from repro.core import ISLabelIndex, IndexConfig
    cur_src = [int(a) for a in src]
    cur_dst = [int(b) for b in dst]
    cur_w = [float(x) for x in w]
    bad = rebuilds = audited = 0
    seg: list[int] = []

    def flush(seg):
        nonlocal bad, rebuilds, audited
        if not seg:
            return
        rebuilds += 1
        ref_idx = ISLabelIndex.build(
            n, np.asarray(cur_src, np.int32), np.asarray(cur_dst, np.int32),
            np.asarray(cur_w, np.float32),
            IndexConfig(l_cap=args.l_cap, label_chunk=args.label_chunk))
        s = trace.s[seg]
        t = trace.t[seg]
        want = np.asarray(ref_idx.engine.query(
            s, t, backend=args.backend or None), np.float32)
        got = served[seg]
        bad += int((~((got == want)
                      | (np.isinf(got) & np.isinf(want)))).sum())
        audited += len(seg)

    for i in range(len(trace)):
        if trace.writes[i] is None:
            seg.append(i)
            continue
        flush(seg)
        seg = []
        for op in trace.writes[i]:
            u = int(op.u)
            if op.kind == "insert":
                for v, wv in zip(op.nbrs, op.ws):
                    cur_src += [u, int(v)]
                    cur_dst += [int(v), u]
                    cur_w += [float(wv), float(wv)]
            else:
                keep = [j for j in range(len(cur_src))
                        if cur_src[j] != u and cur_dst[j] != u]
                cur_src = [cur_src[j] for j in keep]
                cur_dst = [cur_dst[j] for j in keep]
                cur_w = [cur_w[j] for j in keep]
    flush(seg)
    if bad:
        print(f"  AUDIT FAIL: {bad}/{audited} served reads differ from "
              f"the from-scratch rebuild of their version")
        return 1
    print(f"  audit[rebuild]: {audited} served reads bitwise-equal to "
          f"{rebuilds} from-scratch rebuilds across "
          f"{int(vids.max()) + 1} versions")
    return 0


def serve_mutate(args) -> int:
    from repro.core import ISLabelIndex, IndexConfig
    from repro.serve import IndexRegistry, make_trace

    obs = _ObsSession(args, "mutate")
    n_base, src, dst, w = _build_graph(args)
    n = n_base + args.spares
    print(f"[serve-mutate] graph {args.graph} n={n_base} "
          f"(+{args.spares} spares) m={len(src)}")
    t0 = time.time()
    idx = ISLabelIndex.build(
        n, src, dst, w,
        IndexConfig(l_cap=args.l_cap, label_chunk=args.label_chunk))
    print(f"  index built in {time.time() - t0:.1f}s: {idx.stats.summary()}")

    registry = IndexRegistry()
    server = registry.register(
        args.index_name, idx,
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        max_wait_ms=args.max_wait_ms, cache_size=args.cache,
        backend=args.backend or None, versioned=True,
        tracer=obs.tracer)
    print(f"  warmed {server.compile_cache_sizes()} shapes "
          f"in {server.warmup_seconds:.1f}s")

    trace = make_trace("readwrite", n=n, num_requests=args.queries,
                       rate_qps=args.rate, seed=args.seed,
                       write_ratio=args.write_ratio, n_read=n_base,
                       spares=range(n_base, n), attach_to=idx.core_ids)
    print(f"  trace: {trace.meta}")
    shapes_before = server.compile_cache_sizes()
    with obs.profiled():
        served, vids = server.serve_readwrite_trace(trace)
    shapes_after = server.compile_cache_sizes()
    stats = server.stats()
    print(json.dumps(stats, indent=2, sort_keys=True))

    failures = 0
    if shapes_after != shapes_before:
        print(f"  AUDIT FAIL: compiled shapes grew under writes: "
              f"{shapes_before} -> {shapes_after}")
        failures += 1
    else:
        print(f"  audit[compile]: zero recompiles across "
              f"{stats['mutations']} version swaps")
    if args.audit == "rebuild":
        failures += _audit_rebuild(args, n, src, dst, w, trace, served,
                                   vids)
    if stats["qps_compute"] <= 0:
        print("  AUDIT FAIL: zero QPS")
        failures += 1
    # compile events are the exported twin of the cache-size audit:
    # the watcher must have counted zero serve_read region compiles
    failures += obs.finish(server, require_zero_read_compiles=True)
    return failures


def serve_http(args) -> int:
    """Serve over the asyncio HTTP front end and audit the answers that
    actually crossed the wire (docs/SERVICE.md)."""
    import threading

    from repro.core import ISLabelIndex, IndexConfig
    from repro.obs import (SLOEngine, compiles_source, default_serving_slos,
                           latency_source)
    from repro.serve import (DistanceServer, HttpClient, IndexRegistry,
                             ReplicaSet, SSEReader, ServiceFrontend,
                             make_trace, replay_http)

    obs = _ObsSession(args, "http")
    readwrite = args.scenario == "readwrite"
    straggler = args.scenario == "straggler"
    replicas = args.replicas
    if straggler and replicas < 2:
        replicas = 2
        print("[serve-http] straggler scenario: forcing --replicas 2")
    n_base, src, dst, w = _build_graph(args)
    n = n_base + (args.spares if readwrite else 0)
    print(f"[serve-http] graph {args.graph} n={n_base}"
          + (f" (+{args.spares} spares)" if readwrite else "")
          + f" m={len(src)}")
    t0 = time.time()
    idx = ISLabelIndex.build(
        n, src, dst, w,
        IndexConfig(l_cap=args.l_cap, label_chunk=args.label_chunk))
    print(f"  index built in {time.time() - t0:.1f}s: {idx.stats.summary()}")

    registry = IndexRegistry()
    common = dict(buckets=tuple(int(b) for b in args.buckets.split(",")),
                  max_wait_ms=args.max_wait_ms, cache_size=args.cache,
                  backend=args.backend or None)
    if readwrite:
        holder = registry.register(args.index_name, idx, versioned=True,
                                   tracer=obs.tracer, **common)
        server_names = [args.index_name]
    elif replicas > 1:
        holder = ReplicaSet(idx, replicas, name=args.index_name, **common)
        registry.install(args.index_name, holder)
        server_names = holder.server_names
    else:
        holder = registry.register(args.index_name, idx,
                                   tracer=obs.tracer, **common)
        server_names = [args.index_name]
    print(f"  serving {args.index_name!r}"
          + (f" over {replicas} replicas" if replicas > 1 else ""))

    slo_thresh_s = args.slo_latency_ms * 1e-3
    slo = SLOEngine(default_serving_slos(latency_threshold_s=slo_thresh_s),
                    log=obs.log)
    slo.attach("latency", latency_source(slo_thresh_s, servers=server_names))
    slo.attach("read_compiles", compiles_source(obs.watcher))

    fe = ServiceFrontend(registry, slo=slo, log=obs.log)
    host, port = fe.start_background()
    print(f"  front end listening on http://{host}:{port}")

    # live /events capture (the CI smoke's SSE artifact)
    sse_records: list = []
    sse_stop = threading.Event()

    def _pump_sse():
        reader = SSEReader(host, port, timeout_s=1.0)
        while not sse_stop.is_set():
            sse_records.extend(reader.read_events(max_events=256,
                                                  max_s=0.5))
        reader.close()

    sse_thread = None
    if args.sse_out:
        sse_thread = threading.Thread(target=_pump_sse, daemon=True)
        sse_thread.start()

    if readwrite:
        trace = make_trace("readwrite", n=n, num_requests=args.queries,
                           rate_qps=args.rate, seed=args.seed,
                           write_ratio=args.write_ratio, n_read=n_base,
                           spares=range(n_base, n),
                           attach_to=idx.core_ids)
    elif straggler:
        trace = make_trace("straggler", n=n, num_requests=args.queries,
                           rate_qps=args.rate, seed=args.seed,
                           stall_replica=args.stall_replica,
                           stall_s=args.stall_s)
        holder.apply_injection(trace.meta)
        print(f"  injected: replica {args.stall_replica} stalls "
              f"{args.stall_s}s per batch (accounting-only)")
    else:
        trace = make_trace(args.scenario, n=n, num_requests=args.queries,
                           rate_qps=args.rate, seed=args.seed)

    client = HttpClient(host, port, graph=args.index_name)
    t0 = time.time()
    with obs.profiled():
        if readwrite:
            served, vids = replay_http(client, trace)
        else:
            served = replay_http(client, trace, batch=args.http_batch)
    wire_s = time.time() - t0
    print(f"  replayed {len(trace)} requests over HTTP in {wire_s:.2f}s "
          f"({len(trace) / wire_s:.0f} req/s on the wire)")

    failures = 0
    if readwrite:
        # the COW lane never mutates the original index, so a second
        # versioned server over the same idx replays the identical
        # version sequence in-process for the differential audit
        ref_srv = DistanceServer(idx, versioned=True, **common)
        want, want_vids = ref_srv.serve_readwrite_trace(trace)
        ref_srv.drain()
        reads = ~np.isnan(want)
        n_bad = int((served[reads] != want[reads]).sum())
        n_bad += int((vids[reads] != want_vids[reads]).sum())
        if n_bad:
            print(f"  AUDIT FAIL: {n_bad} HTTP-served reads differ from "
                  f"the in-process versioned replay (answers or versions)")
            failures += 1
        else:
            print(f"  audit[http-readwrite]: {int(reads.sum())} reads over "
                  f"{int(vids.max()) + 1} versions bitwise-equal to the "
                  f"in-process replay")
        slo.record("exactness", fe._now(), good=int(reads.sum()) - n_bad,
                   bad=n_bad)
    else:
        want = np.asarray(idx.query(trace.s, trace.t), np.float32)
        n_bad = int((~((served == want)
                       | (np.isnan(served) & np.isnan(want)))).sum())
        if n_bad:
            print(f"  AUDIT FAIL: {n_bad} HTTP-served answers differ from "
                  f"ISLabelIndex.query")
            failures += 1
        else:
            print(f"  audit[http-index]: {len(trace)}/{len(trace)} answers "
                  f"that crossed the wire bitwise-equal to "
                  f"ISLabelIndex.query")
        slo.record("exactness", fe._now(), good=len(trace) - n_bad,
                   bad=n_bad)

    time.sleep(4 * fe.slo_interval_s)      # let the pump task step the SLO
    breaches = slo.breach_summary()
    fired = set(breaches["fired"])
    print(f"  slo: fired={sorted(fired)} "
          f"burns={json.dumps(breaches['slos'], sort_keys=True)}")
    if straggler:
        if "latency" not in fired:
            print("  AUDIT FAIL: straggler injection did not fire the "
                  "latency burn-rate alert")
            failures += 1
        else:
            print("  audit[slo-fire]: latency burn-rate alert fired under "
                  "straggler injection")
    else:
        noisy = fired & {"latency", "availability", "exactness",
                         "read_compiles"}
        if noisy:
            print(f"  AUDIT FAIL: alerts fired on a clean run: "
                  f"{sorted(noisy)}")
            failures += 1
        else:
            print("  audit[slo-quiet]: no alert fired on the clean run")

    stats = client.stats()
    g = stats["graphs"][args.index_name]
    print(f"  served={g['served']} p99={g['latency_ms']['p99']:.3f}ms "
          f"cache_hits={g['cache_hits']}")
    if args.prom_out:
        text = client.metrics_text()
        from pathlib import Path
        p = Path(args.prom_out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
        print(f"  prometheus exposition ({len(text.splitlines())} lines) "
              f"written to {p}")
    client.close()
    if sse_thread is not None:
        sse_stop.set()
        sse_thread.join(timeout=10)
        from pathlib import Path
        p = Path(args.sse_out)
        p.parent.mkdir(parents=True, exist_ok=True)
        with open(p, "w", encoding="utf-8") as fh:
            for event, data in sse_records:
                fh.write(json.dumps({"event": event, "data": data}) + "\n")
        n_alerts = sum(1 for e, _ in sse_records if e == "slo_alert")
        print(f"  sse stream ({len(sse_records)} frames, {n_alerts} "
              f"alert(s)) written to {p}")
        if straggler and not n_alerts:
            print("  AUDIT FAIL: no slo_alert frame crossed the /events "
                  "stream")
            failures += 1
    fe.stop()
    n_reads = (len(trace) if trace.writes is None
               else sum(1 for ops in trace.writes if ops is None))
    if g["served"] < n_reads:
        print(f"  AUDIT FAIL: front end served {g['served']} < "
              f"{n_reads} offered reads")
        failures += 1
    failures += obs.finish(holder, require_zero_read_compiles=True)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "distance", "path", "mutate",
                                       "http"],
                    default="distance")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--gen-len", type=int, default=32)
    # -- distance serving (thin CLI over repro.serve) ----------------------
    ap.add_argument("--graph", choices=["rmat", "er", "grid"], default="rmat")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--l-cap", type=int, default=512)
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--scenario", default="uniform",
                    help="uniform | hotspot | bursty | repeated")
    ap.add_argument("--rate", type=float, default=50000.0,
                    help="offered load, requests/s on the trace clock")
    ap.add_argument("--buckets", default="64,256,1024")
    ap.add_argument("--hop-caps", default="64,256",
                    help="path-lane hop_cap tiers (--mode path): escalate "
                         "through these pre-warmed shapes on overflow")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--cache", type=int, default=65536)
    ap.add_argument("--backend", default="",
                    help="kernel backend override (auto if empty)")
    ap.add_argument("--audit", choices=["index", "dijkstra", "rebuild",
                                        "none"],
                    default="index",
                    help="rebuild (--mode mutate): per-version "
                         "from-scratch rebuild differential audit")
    ap.add_argument("--write-ratio", type=float, default=0.05,
                    help="--mode mutate: fraction of requests that are "
                         "§8.3 write batches")
    ap.add_argument("--spares", type=int, default=16,
                    help="--mode mutate: preallocated vertex ids for "
                         "live inserts")
    ap.add_argument("--label-chunk", type=int, default=128,
                    help="--mode mutate: IndexConfig.label_chunk for the "
                         "served index and the rebuild-audit indexes "
                         "(small keeps the repeated tiny rebuilds cheap)")
    ap.add_argument("--audit-sample", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=0,
                    help=">0: serve a repro.shard.ShardedIndex over this "
                         "many devices (simulate on CPU with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--shard-strategy", choices=["level", "hash"],
                    default="level")
    ap.add_argument("--index-name", default="default")
    # -- http front end (--mode http, docs/SERVICE.md) ---------------------
    ap.add_argument("--replicas", type=int, default=1,
                    help="--mode http: DistanceServer replicas behind the "
                         "front end (straggler health needs >= 2)")
    ap.add_argument("--slo-latency-ms", type=float, default=1000.0,
                    help="--mode http: latency SLO good-event threshold")
    ap.add_argument("--stall-s", type=float, default=5.0,
                    help="--scenario straggler: synthetic per-batch stall "
                         "charged to the injected replica")
    ap.add_argument("--stall-replica", type=int, default=0)
    ap.add_argument("--http-batch", type=int, default=16,
                    help="--mode http: pairs per /query request for "
                         "read-only replays (readwrite is always "
                         "sequential single-pair)")
    ap.add_argument("--sse-out", default="",
                    help="--mode http: capture the /events SSE stream "
                         "as JSON lines (CI artifact)")
    ap.add_argument("--prom-out", default="",
                    help="--mode http: write the final /metrics "
                         "Prometheus exposition here (CI artifact)")
    ap.add_argument("--save", default="")
    ap.add_argument("--load", default="")
    # -- observability sinks (docs/OBSERVABILITY.md) -----------------------
    ap.add_argument("--trace-out", default="",
                    help="write request-lifecycle spans as Chrome "
                         "trace-event JSON (open in Perfetto)")
    ap.add_argument("--metrics-out", default="",
                    help="dump the process metric registry (every "
                         "labeled series) as JSON after the replay")
    ap.add_argument("--events-out", default="",
                    help="append JSON-lines structured events here")
    ap.add_argument("--profile-dir", default="",
                    help="wrap the replay in jax.profiler.trace writing "
                         "to this directory (TensorBoard/Perfetto)")
    args = ap.parse_args()
    if args.mode == "lm":
        serve_lm(args)
    elif args.mode == "mutate":
        raise SystemExit(serve_mutate(args))
    elif args.mode == "http":
        raise SystemExit(serve_http(args))
    else:
        raise SystemExit(serve_distance(args, paths=args.mode == "path"))


if __name__ == "__main__":
    main()
