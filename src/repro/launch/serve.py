"""Serving launcher — two serving modes:

* ``--mode lm``: prefill + decode loop for a (smoke) LM config: batched
  requests, KV-cache reuse, tokens/s report.
* ``--mode distance``: the paper's workload — build an IS-LABEL index
  over a synthetic graph and serve batched P2P distance queries
  (continuous batching: requests accumulate into fixed-size query
  batches; Type-1 fast path via labels only).

  PYTHONPATH=src python -m repro.launch.serve --mode distance \
      --n 20000 --queries 5000 --batch 512
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_lm(args):
    from repro.configs import registry
    from repro.launch.train import smoke_spec
    from repro.models.transformer import decode_step, init_lm, prefill
    spec = smoke_spec(registry.get_spec(args.arch))
    cfg = spec.model_cfg
    params = init_lm(jax.random.PRNGKey(0), cfg)[0]
    b, prompt_len, gen_len = args.batch, 16, args.gen_len
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab, (b, prompt_len)).astype(np.int32)
    pf = jax.jit(lambda p, t: prefill(p, cfg, t, prompt_len + gen_len))
    dc = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    t0 = time.time()
    logits, cache = pf(params, toks)
    out = [jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)]
    for _ in range(gen_len - 1):
        logits, cache = dc(params, cache, out[-1])
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    total = b * gen_len
    dt = time.time() - t0
    print(f"[serve-lm {spec.arch_id}] {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")


def serve_distance(args):
    from repro.core import ISLabelIndex, IndexConfig
    from repro.graphs import generators as gen
    n, src, dst, w = gen.rmat_graph(int(np.log2(args.n)), avg_deg=6.0,
                                    seed=1)
    print(f"[serve-distance] graph n={n} m={len(src)}")
    t0 = time.time()
    idx = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=512))
    print(f"  index built in {time.time() - t0:.1f}s: {idx.stats.summary()}")

    rng = np.random.default_rng(0)
    total, t_q = 0, 0.0
    lat = []
    pending_s, pending_t = [], []
    for _ in range(args.queries):
        pending_s.append(rng.integers(0, n))
        pending_t.append(rng.integers(0, n))
        if len(pending_s) == args.batch:        # continuous batching window
            s = np.asarray(pending_s, np.int32)
            t = np.asarray(pending_t, np.int32)
            t1 = time.time()
            d = idx.query(s, t)
            jax.block_until_ready(d)
            dt = time.time() - t1
            lat.append(dt)
            total += len(s)
            t_q += dt
            pending_s, pending_t = [], []
    qps = total / t_q if t_q else 0
    print(f"  served {total} queries at {qps:.0f} q/s "
          f"(batch={args.batch}, p50={np.median(lat) * 1e3:.1f}ms, "
          f"p99={np.quantile(lat, 0.99) * 1e3:.1f}ms incl. compile)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "distance"], default="distance")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--queries", type=int, default=4096)
    args = ap.parse_args()
    if args.mode == "lm":
        serve_lm(args)
    else:
        serve_distance(args)


if __name__ == "__main__":
    main()
