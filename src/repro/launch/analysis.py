"""Side-effect-free compile-artifact analysis (shared by dryrun/perf and
importable from tests WITHOUT touching jax device state).

v5e hardware model (per chip): 197 TF/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def cost_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions: older
    releases return a dict, newer ones a list with one dict per module."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _tuple_shapes(type_str: str):
    """Parse all array types out of an HLO result type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in optimized HLO.

    The text is the *partitioned per-device* module, so sizes are
    per-device; multiply by device count for global traffic."""
    per_kind = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%?([\w.\-]*)\s*=\s*(.*?)\s*"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        kind = m.group(3)
        size = sum(_tuple_shapes(m.group(2)))
        per_kind[kind] = per_kind.get(kind, 0) + size
    per_kind["total"] = sum(per_kind.values())
    return per_kind
