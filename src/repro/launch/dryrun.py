import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on
the production mesh, record memory/cost/collective analysis.

MUST be executed as its own process (`python -m repro.launch.dryrun`) so
the XLA_FLAGS above take effect before jax initializes. Everything else
(tests, benchmarks) sees the real device count.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod both] [--out experiments/dryrun]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.launch.mesh import make_production_mesh

from repro.launch.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,  # noqa: E402
                                   collective_bytes, cost_dict)


def _probe_specs(spec):
    """XLA cost analysis counts while/scan bodies ONCE, not x trip-count.
    For depth-scanned families we compile two small *unrolled* probes and
    extrapolate linearly in depth (layers / time steps): exact for
    homogeneous stacks. Returns None when costs are already exact
    (python-loop models)."""
    import dataclasses as dc
    cfg = spec.model_cfg
    if spec.family == "lm":
        lo = dc.replace(spec, model_cfg=dc.replace(cfg, n_layers=2,
                                                   unroll=True))
        hi = dc.replace(spec, model_cfg=dc.replace(cfg, n_layers=3,
                                                   unroll=True))
        return lo, hi, 2, 3, cfg.n_layers
    if spec.family == "recsys":
        lo = dc.replace(spec, model_cfg=dc.replace(cfg, seq_len=4,
                                                   unroll=True))
        hi = dc.replace(spec, model_cfg=dc.replace(cfg, seq_len=8,
                                                   unroll=True))
        return lo, hi, 4, 8, cfg.seq_len
    return None


def _compile_costs(spec, shape, mesh):
    from repro.train.steps import build_bundle
    with mesh:
        compiled = build_bundle(spec, shape, mesh).lower().compile()
    cost = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             verbose: bool = True, probes: bool = True) -> dict:
    from repro.train.steps import build_bundle
    spec = registry.get_spec(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec = {"arch": arch, "shape": shape,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "axes": list(mesh.axis_names), "devices": n_dev}
    t0 = time.perf_counter()
    try:
        with mesh:
            bundle = build_bundle(spec, shape, mesh)
            lowered = bundle.lower()
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        mem = compiled.memory_analysis()
        cost = cost_dict(compiled)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))

        pr = _probe_specs(spec)
        if probes and pr is not None:
            lo_spec, hi_spec, d_lo, d_hi, d_real = pr
            f_lo, b_lo, c_lo = _compile_costs(lo_spec, shape, mesh)
            f_hi, b_hi, c_hi = _compile_costs(hi_spec, shape, mesh)
            scale = (d_real - d_lo) / (d_hi - d_lo)
            flops = f_lo + scale * (f_hi - f_lo)
            bytes_acc = b_lo + scale * (b_hi - b_lo)
            coll = {k: c_lo.get(k, 0) + scale * (c_hi.get(k, 0) -
                                                 c_lo.get(k, 0))
                    for k in set(c_lo) | set(c_hi)}
            rec["probe"] = {"depths": [d_lo, d_hi, d_real],
                            "flops_lo_hi": [f_lo, f_hi],
                            "scan_reported_flops": float(
                                cost.get("flops", 0.0))}
        rec.update(
            ok=True, step=bundle.name,
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            flops_per_device=flops, bytes_per_device=bytes_acc,
            collective_bytes_per_device=coll,
            mem={k: getattr(mem, k, None) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes")} if mem is not None else None,
            t_compute_s=flops / PEAK_FLOPS,
            t_memory_s=bytes_acc / HBM_BW,
            t_collective_s=coll["total"] / ICI_BW,
        )
        dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
                  key=lambda k: rec[k])
        rec["dominant"] = dom.replace("t_", "").replace("_s", "")
        if verbose:
            mm = rec["mem"] or {}
            print(f"[{arch}/{shape}/{rec['mesh']}] ok "
                  f"compile={rec['compile_s']}s flops/dev={flops:.3e} "
                  f"bytes/dev={bytes_acc:.3e} coll/dev={coll['total']:.3e} "
                  f"args={mm.get('argument_size_in_bytes')} "
                  f"temp={mm.get('temp_size_in_bytes')} dom={rec['dominant']}")
    except Exception as e:   # record failures — they are bugs to fix
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{arch}/{shape}/{rec['mesh']}] FAIL {rec['error']}")
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = "multipod" if multi_pod else "singlepod"
    (out_dir / f"{arch}__{shape}__{tag}.json").write_text(
        json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-islabel", action="store_true")
    ap.add_argument("--multipod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out = Path(args.out)

    cells = (registry.all_cells(include_islabel=args.include_islabel)
             if args.all else [(args.arch, args.shape)])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.multipod]
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, out)
            n_fail += 0 if rec.get("ok") else 1
    print(f"dry-run complete: {len(cells) * len(meshes)} cells, "
          f"{n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
