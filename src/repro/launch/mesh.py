"""Production mesh builders.

A function, not a module-level constant — importing this module never
touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel mesh axes (includes 'pod' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)
