"""Fault-tolerant checkpointing.

Design points for 1000+-node runs:
  * **Atomic**: write to ``step_<N>.tmp`` then ``os.rename`` — a crash
    mid-save never corrupts the latest checkpoint.
  * **Integrity**: a manifest (tree structure, shapes, dtypes, per-array
    crc32) is verified on restore; corrupt/partial checkpoints are
    skipped and the previous step is used.
  * **Async**: ``save_async`` snapshots to host then writes on a worker
    thread — the train loop only blocks for the device->host copy.
  * **Elastic**: arrays are stored as *global* host arrays, so a restore
    may target a different mesh/device count — ``restore_checkpoint``
    re-shards onto whatever shardings the new topology asks for
    (multi-host runs would store per-shard files keyed by global offset;
    single-process semantics are identical).
  * **Retention**: keep the last ``keep`` checkpoints, delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path), leaf) for path, leaf in flat], treedef


def save_checkpoint(ckpt_dir, step: int, state, keep: int = 3) -> Path:
    """Synchronous atomic save. Returns the final directory path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step:09d}.tmp"
    final = ckpt_dir / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, _ = _flatten_with_paths(state)
    manifest = {"step": step, "arrays": {}}
    arrays = {}
    for name, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        manifest["arrays"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        }
    np.savez(tmp / "arrays.npz",
             **{k.replace("/", "__"): v for k, v in arrays.items()})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    return steps[-1] if steps else None


def _verify(d: Path) -> bool:
    try:
        manifest = json.loads((d / "manifest.json").read_text())
        z = np.load(d / "arrays.npz")
        for name, meta in manifest["arrays"].items():
            arr = z[name.replace("/", "__")]
            if list(arr.shape) != meta["shape"]:
                return False
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                return False
        return True
    except Exception:
        return False


def restore_checkpoint(ckpt_dir, state_like, step: int | None = None,
                       shardings=None):
    """Restore the newest valid checkpoint into the structure of
    ``state_like`` (abstract or concrete). ``shardings`` (same tree
    structure, optional) re-shards for elastic restarts. Returns
    (state, step) or (None, None) when nothing valid exists."""
    ckpt_dir = Path(ckpt_dir)
    candidates = sorted((p for p in ckpt_dir.glob("step_*") if p.is_dir()
                         and not p.name.endswith(".tmp")), reverse=True)
    if step is not None:
        candidates = [p for p in candidates
                      if int(p.name.split("_")[1]) == step]
    for d in candidates:
        if not _verify(d):
            continue
        z = np.load(d / "arrays.npz")
        flat, treedef = _flatten_with_paths(state_like)
        leaves = []
        ok = True
        for name, like in flat:
            key = name.replace("/", "__")
            if key not in z.files:
                ok = False
                break
            leaves.append(z[key])
        if not ok:
            continue
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), state, shardings)
        return state, int(d.name.split("_")[1])
    return None, None


class CheckpointManager:
    """Async checkpointing + restore-latest for the fault-tolerant runner."""

    def __init__(self, ckpt_dir, keep: int = 3, every: int = 100):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.every = every
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def maybe_save(self, step: int, state, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            save_checkpoint(self.dir, step, host_state, keep=self.keep)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, state_like, shardings=None):
        self.wait()
        return restore_checkpoint(self.dir, state_like, shardings=shardings)
