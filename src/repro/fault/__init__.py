from repro.fault.runner import FaultTolerantRunner, RunnerConfig
from repro.fault.stragglers import StragglerMonitor
