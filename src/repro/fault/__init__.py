from repro.fault.runner import FaultTolerantRunner, RunnerConfig
from repro.fault.stragglers import HostTimingAggregator, StragglerMonitor

__all__ = ["FaultTolerantRunner", "RunnerConfig", "HostTimingAggregator",
           "StragglerMonitor"]
