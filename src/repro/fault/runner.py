"""Fault-tolerant training runner.

Wraps any (state, batch) -> (state, metrics) step with the failure
semantics large fleets need:

  * periodic async checkpoints (CheckpointManager);
  * NaN/Inf loss -> rollback to the last checkpoint and *skip* the bad
    data window (data iterator is seekable by step);
  * exceptions from the step (device loss on real fleets, injected
    faults in tests) -> bounded retries with rollback;
  * SIGTERM/preemption -> final checkpoint before exit;
  * straggler monitor hook (per-step wall time EMA).

Elasticity: checkpoints store global host arrays; on restart with a
different topology, ``restore`` re-shards onto the new mesh (see
checkpoint.py). The runner itself is topology-agnostic.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Iterator

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.fault.stragglers import StragglerMonitor
from repro.obs.registry import REGISTRY


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    max_retries: int = 3
    nan_tolerance: int = 0          # consecutive non-finite losses allowed
    handle_sigterm: bool = True


class FaultTolerantRunner:
    def __init__(self, step_fn: Callable, state, make_batch: Callable[[int], object],
                 cfg: RunnerConfig, shardings=None):
        """make_batch(step) must be deterministic/seekable so that replay
        after rollback re-reads the same data (or skips it)."""
        self.step_fn = step_fn
        self.state = state
        self.make_batch = make_batch
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep,
                                      every=cfg.ckpt_every)
        self.shardings = shardings
        self.monitor = StragglerMonitor()
        self.step = 0
        self.events: list[tuple] = []    # (step, kind, info) audit log
        # every audit event also counts into the process registry
        # (fault.events{kind=...}), so the serving stack's stats()
        # surfaces training-side fault state (docs/OBSERVABILITY.md)
        self._event_counter = REGISTRY.counter(
            "fault.events", "fault-tolerance audit events by kind")
        self._steps_counter = REGISTRY.counter(
            "fault.steps", "training steps completed")
        self._preempted = False
        if cfg.handle_sigterm:
            try:
                signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                pass                      # non-main thread (tests)

    def _on_sigterm(self, *_):
        self._preempted = True

    def _event(self, step: int, kind: str, info=None) -> None:
        self.events.append((step, kind, info))
        self._event_counter.inc(1, kind=kind)

    def restore(self):
        state, step = self.ckpt.restore_latest(self.state,
                                               shardings=self.shardings)
        if state is not None:
            self.state, self.step = state, step
            self._event(step, "restored")
        return self.step

    def run(self, n_steps: int, on_metrics: Callable | None = None):
        retries = 0
        bad_streak = 0
        while self.step < n_steps:
            if self._preempted:
                self.ckpt.maybe_save(self.step, self.state, force=True)
                self.ckpt.wait()
                self._event(self.step, "preempted")
                return self.state
            t0 = time.perf_counter()
            try:
                batch = self.make_batch(self.step)
                new_state, metrics = self.step_fn(self.state, batch)
                loss = float(np.asarray(jax.device_get(metrics["loss"])))
                if not np.isfinite(loss):
                    bad_streak += 1
                    self._event(self.step, "nan_loss", loss)
                    if bad_streak > self.cfg.nan_tolerance:
                        self._rollback(skip_past=self.step + 1)
                        bad_streak = 0
                        continue
                else:
                    bad_streak = 0
                self.state = new_state
                self.step += 1
                retries = 0
                self._steps_counter.inc(1)
                self.monitor.record(time.perf_counter() - t0)
                self.ckpt.maybe_save(self.step, self.state)
                if on_metrics:
                    on_metrics(self.step, metrics)
            except FloatingPointError:
                raise
            except Exception as e:     # device failure / injected fault
                retries += 1
                self._event(self.step, "step_failure", repr(e))
                if retries > self.cfg.max_retries:
                    self.ckpt.wait()
                    raise
                self._rollback()
        self.ckpt.maybe_save(self.step, self.state, force=True)
        self.ckpt.wait()
        return self.state

    def _rollback(self, skip_past: int | None = None):
        state, step = self.ckpt.restore_latest(self.state,
                                               shardings=self.shardings)
        if state is not None:
            self.state = state
            self.step = max(step, skip_past or 0)
        elif skip_past is not None:
            self.step = skip_past        # no checkpoint yet: just skip data
        self._event(self.step, "rollback")
