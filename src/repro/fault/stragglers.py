"""Straggler detection & mitigation hooks.

On a real fleet each host reports step wall-time; the controller compares
against the EMA and flags hosts persistently above ``threshold`` x the
fleet median (SPMD steps are synchronous, so one slow host gates all).
Mitigations wired here: (1) alert hook, (2) data re-balancing hint
(shrink the flagged host's shard of the next data window), (3) eviction
recommendation after ``evict_after`` consecutive flags — the elastic
restart path (checkpoint + re-mesh) then removes the host.

Single-process builds exercise the same logic with simulated timings
(tests/test_fault.py).

Both classes report into the process metric registry
(``repro.obs.REGISTRY``, ``fault.*`` series), which the serving stack
surfaces through ``DistanceServer.stats()["fault"]`` — one place to
read training-side straggler state next to the serving metrics.
"""
from __future__ import annotations

import dataclasses

from repro.obs.registry import REGISTRY


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2              # EMA coefficient
    threshold: float = 1.5          # x EMA -> flagged
    evict_after: int = 5
    ema: float | None = None
    flags: int = 0
    history: list = dataclasses.field(default_factory=list)
    host: str = "local"             # registry series label

    def record(self, step_seconds: float) -> dict:
        verdict = {"straggler": False, "evict": False,
                   "ratio": 1.0}
        if self.ema is None:
            self.ema = step_seconds
        else:
            ratio = step_seconds / max(self.ema, 1e-9)
            verdict["ratio"] = ratio
            if ratio > self.threshold:
                self.flags += 1
                verdict["straggler"] = True
                if self.flags >= self.evict_after:
                    verdict["evict"] = True
            else:
                self.flags = 0
                # only fold non-straggler steps into the EMA
                self.ema = (1 - self.alpha) * self.ema \
                    + self.alpha * step_seconds
        self.history.append((step_seconds, dict(verdict)))
        if verdict["straggler"]:
            REGISTRY.counter("fault.straggler_flags",
                             "steps flagged above the EMA threshold").inc(
                1, host=self.host)
        g = REGISTRY.gauge
        g("fault.step_seconds_ema", "per-host step wall-time EMA").set(
            self.ema, host=self.host)
        g("fault.straggler_streak",
          "consecutive flagged steps (evict at evict_after)").set(
            self.flags, host=self.host)
        return verdict


@dataclasses.dataclass
class HostTimingAggregator:
    """Fleet-level view: per-host EMAs + median comparison (the controller
    side of straggler mitigation)."""
    threshold: float = 1.3
    hosts: dict = dataclasses.field(default_factory=dict)

    def record(self, host: str, step_seconds: float):
        mon = self.hosts.setdefault(host, StragglerMonitor(host=host))
        return mon.record(step_seconds)

    def stragglers(self):
        import numpy as np
        emas = {h: m.ema for h, m in self.hosts.items() if m.ema}
        if not emas:
            return []
        med = float(np.median(list(emas.values())))
        out = [h for h, e in emas.items() if e > self.threshold * med]
        REGISTRY.gauge("fault.fleet_stragglers",
                       "hosts above threshold x fleet-median EMA").set(
            len(out))
        return out
