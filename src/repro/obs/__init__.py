# repro.obs — the observability layer every serving component reports
# through (docs/OBSERVABILITY.md): span-based request tracing with a
# Chrome/Perfetto trace exporter, a process-wide metric registry
# (counters / gauges / labeled fixed-bucket histograms), JAX compile
# and device-memory visibility, structured JSON-lines event logging,
# and regression gating over the committed BENCH_*.json trajectory.
from repro.obs.export import EventLog, write_chrome_trace, write_metrics
from repro.obs.profiler import (CompileWatcher, compile_region,
                                current_region, device_memory_gauges,
                                profiler_session, version_family_gauges)
from repro.obs.registry import (REGISTRY, Counter, Gauge, Histogram,
                                MetricRegistry, default_latency_buckets)
from repro.obs.slo import (AlertState, SLOEngine, SLOSpec, compiles_source,
                           counter_source, default_serving_slos,
                           latency_source)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "EventLog", "write_chrome_trace", "write_metrics",
    "CompileWatcher", "compile_region", "current_region",
    "device_memory_gauges", "profiler_session", "version_family_gauges",
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricRegistry",
    "default_latency_buckets",
    "AlertState", "SLOEngine", "SLOSpec", "compiles_source",
    "counter_source", "default_serving_slos", "latency_source",
    "NULL_TRACER", "NullTracer", "Span", "Tracer",
]
