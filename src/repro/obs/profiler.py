"""JAX-level observability: compile-event watching, device-memory /
live-buffer gauges, and ``jax.profiler`` session wrapping.

Compile watching turns the serving stack's zero-recompile discipline
(docs/SERVING.md, docs/MUTATION.md) from a test-time assertion into an
exported counter: ``CompileWatcher`` registers a ``jax.monitoring``
duration listener and counts XLA backend compiles into
``obs.xla_compiles`` — labeled by *region*, because not every compile
is equal. The serving engine tags its execution windows with
``compile_region``:

  warmup       pre-warming the bucketed entry points (compiles expected)
  serve_read   the distance hot path          — MUST stay 0 after warmup
  serve_path   pre-warmed path tiers (+ the metered host fallback,
               which is documented to compile at unwarmed shapes)
  mutation     COW apply / state build (eager scatters may compile
               small executables; never on the read path)
  other        anything untagged

``launch/serve.py --mode mutate`` exits nonzero if ``serve_read``
compiles are ever counted after warmup.

On JAX builds without ``jax.monitoring`` listener support the watcher
degrades to inactive (``supported = False``) — the cache-size probes in
``DistanceServer.compile_cache_sizes()`` remain the fallback gate.
"""
from __future__ import annotations

import contextlib
import threading

import jax

from repro.obs.registry import REGISTRY

__all__ = ["CompileWatcher", "compile_region", "current_region",
           "device_memory_gauges", "version_family_gauges",
           "profiler_session"]

# Duration events jax._src.dispatch emits per XLA backend compile (the
# jaxpr-trace event fires on cache *misses* at the jit layer too, which
# is why backend_compile is the recompile signal).
BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_region = threading.local()


def current_region() -> str:
    return getattr(_region, "name", "other")


@contextlib.contextmanager
def compile_region(name: str):
    """Tag compiles triggered inside this block with ``name``."""
    prev = current_region()
    _region.name = name
    try:
        yield
    finally:
        _region.name = prev


class CompileWatcher:
    """Counts XLA backend compiles per region into the registry.

    Use as a context manager or ``start()``/``stop()``. Counters:
      obs.xla_compiles{region=...}          compile count
      obs.xla_compile_seconds{region=...}   summed compile wall time
    """

    def __init__(self, registry=None):
        self.registry = registry if registry is not None else REGISTRY
        self.compiles = self.registry.counter(
            "obs.xla_compiles", "XLA backend compiles by region")
        self.compile_seconds = self.registry.counter(
            "obs.xla_compile_seconds", "XLA backend compile wall time")
        self.supported = False
        self._active = False

    # ------------------------------------------------------- listener
    def _on_event(self, event: str, duration: float, **kw) -> None:
        if not self._active or event != BACKEND_COMPILE_EVENT:
            return
        region = current_region()
        self.compiles.inc(1, region=region)
        self.compile_seconds.inc(float(duration), region=region)

    def start(self) -> "CompileWatcher":
        if self._active:
            return self
        try:
            jax.monitoring.register_event_duration_secs_listener(
                self._on_event)
            self.supported = True
        except Exception:
            self.supported = False
        self._active = True
        return self

    def stop(self) -> None:
        if not self._active:
            return
        self._active = False
        if self.supported:
            try:
                from jax._src import monitoring as _mon
                _mon._unregister_event_duration_listener_by_callback(
                    self._on_event)
            except Exception:
                pass      # listener stays registered but inert (_active)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -------------------------------------------------------- queries
    def count(self, region: str | None = None) -> int:
        if region is not None:
            return int(self.compiles.value(region=region))
        return int(self.compiles.total())

    def snapshot(self) -> dict:
        return {dict(k)["region"]: int(s[0])
                for k, s in self.compiles._series.items()}


# ------------------------------------------------------------- memory
def device_memory_gauges(registry=None) -> dict:
    """Sample process-wide live-buffer and device-memory gauges.

      obs.live_buffers                live jax.Array count
      obs.live_buffer_bytes           their summed nbytes
      obs.device_bytes_in_use{device} allocator stats where the backend
                                      exposes them (TPU/GPU; CPU: absent)
    """
    reg = registry if registry is not None else REGISTRY
    arrs = jax.live_arrays()
    nbytes = sum(int(getattr(a, "nbytes", 0)) for a in arrs)
    reg.gauge("obs.live_buffers", "live jax.Array count").set(len(arrs))
    reg.gauge("obs.live_buffer_bytes", "live jax.Array bytes").set(nbytes)
    out = {"live_buffers": len(arrs), "live_buffer_bytes": nbytes}
    g = reg.gauge("obs.device_bytes_in_use", "allocator bytes in use")
    for dev in jax.devices():
        try:
            stats = dev.memory_stats()
        except Exception:
            stats = None
        if stats and "bytes_in_use" in stats:
            g.set(stats["bytes_in_use"], device=str(dev.id))
            out[f"device{dev.id}_bytes_in_use"] = int(stats["bytes_in_use"])
    return out


def version_family_gauges(manager, registry=None, server: str = "default"
                          ) -> dict:
    """Per-version-family device footprint (docs/MUTATION.md):

      versions.live{server}         live version count
      versions.state_bytes{server}  summed device bytes of live
                                    ``VersionState`` pytrees (COW-shared
                                    leaves counted once, by id)
      versions.current_vid{server}
    """
    reg = registry if registry is not None else REGISTRY
    seen: set = set()
    nbytes = 0
    for vid in manager.live_versions():
        state = manager._versions[vid].state
        if state is None:
            continue
        for leaf in jax.tree_util.tree_leaves(state):
            if id(leaf) not in seen:
                seen.add(id(leaf))
                nbytes += int(getattr(leaf, "nbytes", 0))
    live = len(manager.live_versions())
    reg.gauge("versions.live", "live index versions").set(live,
                                                          server=server)
    reg.gauge("versions.state_bytes",
              "device bytes pinned by live version states").set(
        nbytes, server=server)
    reg.gauge("versions.current_vid", "published version id").set(
        manager.current.vid, server=server)
    return {"live": live, "state_bytes": nbytes,
            "current_vid": manager.current.vid}


# ------------------------------------------------------------ profiler
@contextlib.contextmanager
def profiler_session(log_dir: str | None):
    """``jax.profiler.trace`` wrapper: a no-op when ``log_dir`` is falsy
    or this JAX build lacks the profiler, so call sites need no
    branching. The written trace opens in TensorBoard / Perfetto and
    carries the ``jax.named_scope`` annotations the kernel dispatch
    layer emits (islabel.label_intersect / islabel.core_relax*)."""
    if not log_dir:
        yield False
        return
    try:
        ctx = jax.profiler.trace(str(log_dir))
    except Exception:
        yield False
        return
    with ctx:
        yield True
