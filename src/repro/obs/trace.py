"""Span-based request tracing with a Chrome trace-event exporter.

The serving stack is clock-driven (docs/SERVING.md): queue waits live on
the trace's simulated clock while device execution is measured wall
time, charged as an interval starting at the flush instant. Spans here
therefore carry caller-supplied timestamps (seconds on the serving
timeline) rather than reading a wall clock, which keeps traces exactly
reproducible for replayed loadgen traces — and works unchanged for a
wall-clock front end that passes ``time.perf_counter()``.

Span model (docs/OBSERVABILITY.md):

  request lane    request ── queue_wait ── device_exec
  path lane       request ── queue_wait ── tier:h<cap>* ── host_fallback?
  mutation lane   mutation ── flush_pending ── cow_apply ── swap_publish
                           ── retire

Every span has a ``trace_id`` (the request id for request-lifecycle
spans) and a ``span_id``; children carry ``parent_id``. ``chrome()``
exports the standard Chrome trace-event JSON (``traceEvents`` with
``ph: "X"`` complete events, microsecond timestamps) that
``chrome://tracing`` and https://ui.perfetto.dev open directly.

``NULL_TRACER`` is a no-op sink: call sites instrument unconditionally
and the disabled path costs one attribute lookup plus a no-op call.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]


@dataclasses.dataclass
class Span:
    name: str
    cat: str
    t0: float                    # seconds on the serving timeline
    span_id: int
    trace_id: int = 0
    parent_id: int | None = None
    t1: float | None = None      # None while open
    track: str | None = None     # Chrome "thread" row; defaults to cat
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    @property
    def open(self) -> bool:
        return self.t1 is None


class Tracer:
    """Collects spans and instant events on a shared timeline."""

    enabled = True

    def __init__(self, process: str = "repro.serve"):
        self.process = process
        self.spans: list[Span] = []
        self.events: list[dict] = []
        self._next_id = 1

    # ------------------------------------------------------------ record
    def start(self, name: str, now: float, *, cat: str = "serve",
              trace_id: int = 0, parent: Span | None = None,
              track: str | None = None, **args) -> Span:
        span = Span(name=name, cat=cat, t0=float(now),
                    span_id=self._next_id, trace_id=int(trace_id),
                    parent_id=None if parent is None else parent.span_id,
                    track=track, args=args)
        self._next_id += 1
        self.spans.append(span)
        return span

    def end(self, span: Span, now: float, **args) -> Span:
        if span.t1 is not None:
            raise ValueError(f"span {span.name!r} already ended")
        if float(now) < span.t0:
            raise ValueError(f"span {span.name!r} ends at {now} before "
                             f"its start {span.t0}")
        span.t1 = float(now)
        span.args.update(args)
        return span

    def add(self, name: str, t0: float, t1: float, *, cat: str = "serve",
            trace_id: int = 0, parent: Span | None = None,
            track: str | None = None, **args) -> Span:
        """Record an already-measured interval in one call."""
        span = self.start(name, t0, cat=cat, trace_id=trace_id,
                          parent=parent, track=track, **args)
        return self.end(span, t1)

    def event(self, name: str, now: float, *, cat: str = "serve",
              trace_id: int = 0, track: str | None = None, **args) -> None:
        """Instant event (Chrome ``ph: "i"``)."""
        self.events.append({"name": name, "cat": cat, "ts": float(now),
                            "trace_id": int(trace_id), "track": track,
                            "args": args})

    # ----------------------------------------------------------- queries
    def finished(self) -> list[Span]:
        return [s for s in self.spans if s.t1 is not None]

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def children(self, span: Span) -> list[Span]:
        return [s for s in self.spans if s.parent_id == span.span_id]

    def request_coverage(self) -> dict:
        """Fraction of each request span covered by its child spans —
        the acceptance probe: children must account for (almost) all of
        the request's measured wall time. Returns summary stats."""
        fracs = []
        for s in self.finished():
            if s.cat != "request" or s.duration <= 0:
                continue
            covered = sum(c.duration for c in self.children(s)
                          if c.t1 is not None)
            fracs.append(min(covered / s.duration, 1.0))
        if not fracs:
            return {"requests": 0, "min": 0.0, "mean": 0.0}
        return {"requests": len(fracs), "min": min(fracs),
                "mean": sum(fracs) / len(fracs)}

    # ------------------------------------------------------------ export
    def chrome(self) -> dict:
        """Chrome trace-event JSON object format (Perfetto-loadable)."""
        tracks = {}

        def tid(track: str) -> int:
            return tracks.setdefault(track, len(tracks) + 1)

        ev = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
               "args": {"name": self.process}}]
        for s in self.spans:
            if s.t1 is None:
                continue
            ev.append({
                "ph": "X", "pid": 1, "tid": tid(s.track or s.cat),
                "name": s.name, "cat": s.cat,
                "ts": s.t0 * 1e6, "dur": s.duration * 1e6,
                "args": {"trace_id": s.trace_id, "span_id": s.span_id,
                         **({"parent_id": s.parent_id}
                            if s.parent_id is not None else {}),
                         **s.args},
            })
        for e in self.events:
            ev.append({
                "ph": "i", "pid": 1, "tid": tid(e["track"] or e["cat"]),
                "name": e["name"], "cat": e["cat"], "ts": e["ts"] * 1e6,
                "s": "t",
                "args": {"trace_id": e["trace_id"], **e["args"]},
            })
        for track, t in sorted(tracks.items(), key=lambda kv: kv[1]):
            ev.append({"ph": "M", "pid": 1, "tid": t, "name": "thread_name",
                       "args": {"name": track}})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def write_chrome(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.chrome()) + "\n")
        return p


class NullTracer(Tracer):
    """No-op sink for the uninstrumented hot path."""

    enabled = False

    def __init__(self):
        super().__init__()

    def start(self, name, now, **kw):
        return _NULL_SPAN

    def end(self, span, now, **args):
        return _NULL_SPAN

    def add(self, name, t0, t1, **kw):
        return _NULL_SPAN

    def event(self, name, now, **kw):
        return None


_NULL_SPAN = Span(name="", cat="", t0=0.0, span_id=0, t1=0.0)
NULL_TRACER = NullTracer()
