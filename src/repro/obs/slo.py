"""SLO burn-rate engine: declarative objectives over the serving
stack, evaluated as rolling multi-window burn rates with fire/resolve
hysteresis (docs/SERVICE.md).

An ``SLOSpec`` states an objective — the target fraction of *good*
events (availability, requests under a latency bound, exactness-audit
passes, zero ``serve_read`` compiles) — and the engine tracks how fast
the error budget ``1 - objective`` is being consumed:

    burn rate = (bad events / total events over a window) / budget

following the multi-window multi-burn-rate alerting policy (Google SRE
workbook ch. 5): an alert **fires** only when the *fast* window (a
5-minute-equivalent on the serving clock) and the *slow* window (a
1-hour-equivalent) both burn strictly above their thresholds — the fast
window gives low detection latency, the slow window keeps one transient
spike from paging. Windows are expressed on the *serving clock*: wall
seconds behind the HTTP front end, simulated trace seconds in a
deterministic replay (the engine never reads a wall clock itself).

Observations enter two ways:

  * **push** — ``record(name, now, good=, bad=)`` from call sites that
    witness events directly (the front end's availability accounting,
    exactness audits);
  * **poll** — ``attach(name, probe)`` registers a cumulative
    ``() -> (good_total, total)`` source sampled at every
    ``poll(now)``; built-ins below read the metric registry
    (``latency_source``), counter pairs (``counter_source``) and the
    compile watcher (``compiles_source``), so the engine wires onto the
    existing serving stack without touching its hot path.

State machine per SLO: ``ok -> firing`` when both windows burn strictly
above threshold (ties do NOT fire; a burn rate exactly at threshold is
budget-neutral), ``firing -> ok`` only after the fire condition has
been continuously false for ``resolve_hold_s`` (hysteresis — a flapping
burn rate holds the alert). Every transition emits a structured
``slo_alert`` event into the ``EventLog`` (JSON-lines / SSE-streamable)
and updates ``slo.*`` registry series; ``breach_summary()`` is the
machine-readable digest CI gates on.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.obs.registry import REGISTRY

__all__ = ["SLOSpec", "SLOEngine", "AlertState", "latency_source",
           "counter_source", "compiles_source", "default_serving_slos"]


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One declarative objective.

    ``objective`` is the target good fraction (0 < objective < 1); the
    error budget is ``1 - objective``. ``fast_window_s``/``slow_window_s``
    are the two rolling windows on the serving clock, ``fast_burn``/
    ``slow_burn`` their fire thresholds (both must be exceeded
    *strictly*). ``min_events`` guards empty/thin windows: fewer total
    events than this in the fast window can never fire. ``resolve_hold_s``
    is the hysteresis hold: the fire condition must stay false this
    long before the alert resolves.
    """
    name: str
    objective: float = 0.999
    fast_window_s: float = 300.0          # 5m-equivalent
    slow_window_s: float = 3600.0         # 1h-equivalent
    fast_burn: float = 14.0
    slow_burn: float = 6.0
    resolve_hold_s: float = 120.0
    min_events: int = 1
    description: str = ""

    def __post_init__(self):
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"slo {self.name!r}: objective must be in "
                             f"(0, 1), got {self.objective}")
        if self.fast_window_s > self.slow_window_s:
            raise ValueError(f"slo {self.name!r}: fast window "
                             f"{self.fast_window_s} exceeds slow window "
                             f"{self.slow_window_s}")

    @property
    def budget(self) -> float:
        return 1.0 - self.objective


@dataclasses.dataclass
class AlertState:
    """Mutable per-SLO evaluation state."""
    spec: SLOSpec
    samples: deque = dataclasses.field(default_factory=deque)
    good: int = 0                  # push-path cumulative tallies
    bad: int = 0
    firing: bool = False
    fires: int = 0
    resolves: int = 0
    fired_ever: bool = False
    last_true_ts: float | None = None   # last eval where condition held
    burn_fast: float = 0.0
    burn_slow: float = 0.0
    max_burn_fast: float = 0.0
    max_burn_slow: float = 0.0

    def window_rate(self, now: float, window_s: float):
        """(bad fraction, total events) across the trailing window:
        delta between the newest sample and the newest sample at or
        before ``now - window_s`` (the earliest retained sample when
        the run is younger than the window)."""
        if not self.samples:
            return 0.0, 0
        newest = self.samples[-1]
        base = None
        cutoff = now - window_s
        for s in self.samples:          # deque is ts-ordered
            if s[0] <= cutoff:
                base = s
            else:
                break
        if base is None:
            base = (self.samples[0][0], 0, 0)   # run younger than window
        d_good = newest[1] - base[1]
        d_total = newest[2] - base[2]
        if d_total <= 0:
            return 0.0, 0
        return (d_total - d_good) / d_total, d_total


class SLOEngine:
    """Evaluates a set of ``SLOSpec``s over push/poll observations and
    drives the fire/resolve state machine."""

    def __init__(self, specs, *, log=None, registry=None):
        self.specs = {s.name: s for s in specs}
        if len(self.specs) != len(list(specs)):
            raise ValueError("duplicate SLO names")
        self.log = log
        self.registry = registry if registry is not None else REGISTRY
        self.states = {n: AlertState(spec=s) for n, s in self.specs.items()}
        self._probes: dict[str, object] = {}
        self._burn_g = self.registry.gauge(
            "slo.burn_rate", "error-budget burn rate per window")
        self._firing_g = self.registry.gauge(
            "slo.firing", "1 while the SLO alert is firing")
        self._alerts_c = self.registry.counter(
            "slo.alerts", "fire/resolve transitions")

    # -------------------------------------------------------- ingestion
    def attach(self, name: str, probe) -> None:
        """Register a cumulative ``() -> (good_total, total)`` source
        sampled at every ``poll``."""
        if name not in self.specs:
            raise KeyError(f"unknown SLO {name!r}; have "
                           f"{sorted(self.specs)}")
        self._probes[name] = probe

    def record(self, name: str, now: float, good: int = 0,
               bad: int = 0) -> None:
        """Push ``good``/``bad`` events observed at ``now``."""
        st = self.states[name]
        st.good += int(good)
        st.bad += int(bad)
        self._push_sample(st, now, st.good, st.good + st.bad)

    def poll(self, now: float) -> None:
        """Sample every attached cumulative source at ``now``."""
        for name, probe in self._probes.items():
            good, total = probe()
            self._push_sample(self.states[name], now, int(good),
                              int(total))

    def _push_sample(self, st: AlertState, now: float, good: int,
                     total: int) -> None:
        now = float(now)
        if st.samples and now < st.samples[-1][0]:
            raise ValueError(
                f"slo {st.spec.name!r}: sample at {now} precedes newest "
                f"{st.samples[-1][0]} (the serving clock is monotonic)")
        st.samples.append((now, good, total))
        horizon = now - 2.0 * st.spec.slow_window_s
        while len(st.samples) > 2 and st.samples[1][0] <= horizon:
            st.samples.popleft()

    # ------------------------------------------------------- evaluation
    def evaluate(self, now: float) -> list:
        """Advance every SLO's state machine to ``now``; returns the
        alert events emitted by this call (also appended to ``log``)."""
        now = float(now)
        events = []
        for name, st in self.states.items():
            spec = st.spec
            rate_f, n_f = st.window_rate(now, spec.fast_window_s)
            rate_s, _ = st.window_rate(now, spec.slow_window_s)
            st.burn_fast = rate_f / spec.budget
            st.burn_slow = rate_s / spec.budget
            st.max_burn_fast = max(st.max_burn_fast, st.burn_fast)
            st.max_burn_slow = max(st.max_burn_slow, st.burn_slow)
            self._burn_g.set(st.burn_fast, slo=name, window="fast")
            self._burn_g.set(st.burn_slow, slo=name, window="slow")
            condition = (n_f >= spec.min_events
                         and st.burn_fast > spec.fast_burn
                         and st.burn_slow > spec.slow_burn)
            if condition:
                st.last_true_ts = now
            if condition and not st.firing:
                st.firing = st.fired_ever = True
                st.fires += 1
                events.append(self._emit(now, st, "fire"))
            elif (st.firing and not condition
                  and st.last_true_ts is not None
                  and now - st.last_true_ts >= spec.resolve_hold_s):
                st.firing = False
                st.resolves += 1
                events.append(self._emit(now, st, "resolve"))
            self._firing_g.set(1.0 if st.firing else 0.0, slo=name)
        return events

    def step(self, now: float) -> list:
        """poll + evaluate in one call (the front end's cadence hook)."""
        self.poll(now)
        return self.evaluate(now)

    def _emit(self, now: float, st: AlertState, state: str) -> dict:
        spec = st.spec
        self._alerts_c.inc(1, slo=spec.name, state=state)
        fields = {
            "slo": spec.name, "state": state,
            "objective": spec.objective,
            "burn_fast": round(st.burn_fast, 4),
            "burn_slow": round(st.burn_slow, 4),
            "fast_window_s": spec.fast_window_s,
            "slow_window_s": spec.slow_window_s,
            "fast_burn_threshold": spec.fast_burn,
            "slow_burn_threshold": spec.slow_burn,
        }
        if self.log is not None:
            return self.log.log("slo_alert", ts=now, **fields)
        return {"ts": now, "kind": "slo_alert", **fields}

    # ----------------------------------------------------------- status
    def snapshot(self) -> dict:
        """Live per-SLO state — the ``/events`` metrics-frame section
        and the ``/stats`` ``slo`` block."""
        return {name: {
            "firing": st.firing,
            "burn_fast": st.burn_fast,
            "burn_slow": st.burn_slow,
            "fires": st.fires,
            "resolves": st.resolves,
            "objective": st.spec.objective,
        } for name, st in self.states.items()}

    def breach_summary(self) -> dict:
        """Machine-readable run digest for CI gating: which SLOs ever
        fired, which are still firing, and the worst burn observed."""
        return {
            "fired": sorted(n for n, st in self.states.items()
                            if st.fired_ever),
            "firing": sorted(n for n, st in self.states.items()
                             if st.firing),
            "slos": {name: {
                "fires": st.fires,
                "resolves": st.resolves,
                "max_burn_fast": st.max_burn_fast,
                "max_burn_slow": st.max_burn_slow,
            } for name, st in self.states.items()},
        }


# --------------------------------------------------------------- sources
def latency_source(threshold_s: float, *, registry=None,
                   metric: str = "serve.latency_seconds",
                   servers=None):
    """Cumulative (good, total) over the serving latency histogram:
    good = requests at or under ``threshold_s``. ``servers`` restricts
    to series whose ``server`` label is in the set (None = all) — a
    ``ReplicaSet`` passes its replica names so one SLO covers the whole
    group."""
    reg = registry if registry is not None else REGISTRY
    allowed = None if servers is None else {str(s) for s in servers}

    def probe():
        h = reg.get(metric)
        if h is None:
            return 0, 0
        good = total = 0
        for labels in h.labels_seen():
            if allowed is not None and labels.get("server") not in allowed:
                continue
            total += h.count(**labels)
            good += h.count_le(threshold_s, **labels)
        return good, total
    return probe


def counter_source(good_metric: str, bad_metric: str, *, registry=None):
    """Cumulative (good, total) from a pair of counters (availability:
    answered requests vs front-end errors)."""
    reg = registry if registry is not None else REGISTRY

    def probe():
        g = reg.get(good_metric)
        b = reg.get(bad_metric)
        good = g.total() if g is not None else 0.0
        bad = b.total() if b is not None else 0.0
        return int(good), int(good + bad)
    return probe


def compiles_source(watcher, region: str = "serve_read"):
    """Zero-tolerance source over the compile watcher: every XLA
    backend compile counted in ``region`` is a bad event (and there are
    no good ones), so any compile inside the window burns at rate 1."""
    def probe():
        bad = int(watcher.count(region)) if watcher.supported else 0
        return 0, bad
    return probe


def default_serving_slos(*, latency_threshold_s: float = 0.1,
                         latency_objective: float = 0.999,
                         availability_objective: float = 0.999,
                         fast_window_s: float = 300.0,
                         slow_window_s: float = 3600.0,
                         resolve_hold_s: float = 120.0) -> list:
    """The standing serving SLOs (docs/SERVICE.md): availability,
    read-lane latency, exactness-audit pass rate, and zero serve_read
    compiles. Window sizes scale with the serving clock — a trace
    replay passes windows sized to its simulated span."""
    kw = dict(fast_window_s=fast_window_s, slow_window_s=slow_window_s,
              resolve_hold_s=resolve_hold_s)
    return [
        SLOSpec("availability", objective=availability_objective,
                description="answered / (answered + errors)", **kw),
        SLOSpec("latency", objective=latency_objective,
                description=f"requests <= {latency_threshold_s * 1e3:g}ms",
                **kw),
        SLOSpec("exactness", objective=0.9999, min_events=1,
                description="audit passes / audited answers", **kw),
        SLOSpec("read_compiles", objective=0.5, min_events=1,
                fast_burn=0.0, slow_burn=0.0,
                description="zero XLA compiles in region serve_read",
                **kw),
    ]
