"""Process-wide metric registry: counters, gauges, fixed-bucket
histograms, all supporting labeled series.

One registry instance (the module-level ``REGISTRY`` by default) is
shared by every component of the serving stack — ``DistanceServer``,
``VersionManager``, ``ShardedQueryEngine``, ``PathEngine``,
``repro.fault`` — so a single ``snapshot()`` (or ``launch/serve.py
--metrics-out``) captures the whole process. ``ServeMetrics`` keeps its
historical per-server snapshot shape but is a *view* over series held
here (docs/OBSERVABILITY.md).

Naming scheme: dotted ``<component>.<metric>`` names (``serve.served``,
``versions.swaps``, ``fault.retries``); unit suffixes where the value is
not a plain count (``_seconds``, ``_bytes``, ``_ratio``). Series within
a metric are keyed by their sorted ``(label, value)`` items, so
``counter.inc(server="g", lane="mu")`` and a later
``inc(lane="mu", server="g")`` hit the same series.

Histograms keep the fixed cumulative-bucket counts *and* (by default)
the raw observations, so percentile export stays exactly the numpy
quantile of what was observed — bucket interpolation is only used once
a series overflows ``raw_cap`` (set ``raw_cap=0`` to never retain).
"""
from __future__ import annotations

import contextlib
import json
import re
import threading

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricRegistry", "REGISTRY",
           "default_latency_buckets"]


def default_latency_buckets() -> tuple:
    """Seconds-scale log buckets: 100µs .. ~100s, 4 per decade."""
    return tuple(float(f"{10 ** (e / 4):.3g}") * 1e-4
                 for e in range(0, 25))


def _key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Metric:
    """Shared labeled-series plumbing. Subclasses define the per-series
    state (``_new_series``) and its snapshot form."""

    kind = "metric"

    def __init__(self, name: str, help: str = "", registry=None):
        self.name = name
        self.help = help
        self._series: dict = {}
        self._lock = threading.Lock()

    def _get(self, labels: dict):
        k = _key(labels)
        s = self._series.get(k)
        if s is None:
            with self._lock:
                s = self._series.setdefault(k, self._new_series())
        return s

    def labels_seen(self) -> list:
        return [dict(k) for k in self._series]

    def snapshot(self) -> dict:
        return {
            "kind": self.kind,
            "help": self.help,
            "series": [{"labels": dict(k), **self._series_snapshot(s)}
                       for k, s in sorted(self._series.items())],
        }


class Counter(_Metric):
    """Monotonic float counter."""

    kind = "counter"

    def _new_series(self):
        return [0.0]

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self._get(labels)[0] += amount

    def value(self, **labels) -> float:
        return self._get(labels)[0]

    def total(self) -> float:
        return sum(s[0] for s in self._series.values())

    def _series_snapshot(self, s) -> dict:
        return {"value": s[0]}


class Gauge(_Metric):
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def _new_series(self):
        return [0.0]

    def set(self, value: float, **labels) -> None:
        self._get(labels)[0] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        self._get(labels)[0] += amount

    def value(self, **labels) -> float:
        return self._get(labels)[0]

    def _series_snapshot(self, s) -> dict:
        return {"value": s[0]}


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "raw")

    def __init__(self, n_buckets: int):
        self.counts = np.zeros(n_buckets + 1, np.int64)  # +overflow
        self.sum = 0.0
        self.count = 0
        self.raw: list | None = []


class Histogram(_Metric):
    """Fixed-bucket histogram with exact-percentile raw retention.

    ``buckets`` are the (sorted, strictly increasing) upper bounds;
    observation ``v`` lands in the first bucket with ``v <= bound``,
    past the last bound in the overflow bucket. ``quantile`` returns
    the numpy linear-interpolation quantile over the retained raw
    values; once ``raw_cap`` is exceeded the series drops its raw list
    and quantiles fall back to within-bucket linear interpolation.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets=None,
                 raw_cap: int = 1 << 20, registry=None):
        super().__init__(name, help)
        b = tuple(float(x) for x in (buckets if buckets is not None
                                     else default_latency_buckets()))
        if list(b) != sorted(set(b)):
            raise ValueError(f"histogram {name}: buckets must be sorted "
                             f"strictly increasing, got {b!r}")
        if not b:
            raise ValueError(f"histogram {name}: need at least one bucket")
        self.buckets = b
        self.raw_cap = int(raw_cap)
        self._bounds = np.asarray(b, np.float64)

    def _new_series(self):
        return _HistSeries(len(self.buckets))

    def observe(self, value: float, **labels) -> None:
        s = self._get(labels)
        v = float(value)
        s.counts[int(np.searchsorted(self._bounds, v, side="left"))] += 1
        s.sum += v
        s.count += 1
        if s.raw is not None:
            if len(s.raw) < self.raw_cap:
                s.raw.append(v)
            else:
                s.raw = None          # overflow: bucket estimates only

    def values(self, **labels) -> list:
        """The retained raw observations (empty once dropped)."""
        s = self._get(labels)
        return list(s.raw) if s.raw is not None else []

    def count(self, **labels) -> int:
        return self._get(labels).count

    def sum(self, **labels) -> float:
        return self._get(labels).sum

    def mean(self, **labels) -> float:
        s = self._get(labels)
        return s.sum / s.count if s.count else 0.0

    def quantile(self, q: float, **labels) -> float:
        """Exact (numpy ``quantile``) while raw values are retained,
        within-bucket linear interpolation afterwards."""
        s = self._get(labels)
        if s.count == 0:
            return 0.0
        if s.raw is not None:
            return float(np.quantile(np.asarray(s.raw, np.float64), q))
        rank = q * (s.count - 1)
        cum = np.cumsum(s.counts)
        i = int(np.searchsorted(cum, rank + 1))
        lo = 0.0 if i == 0 else self.buckets[i - 1]
        hi = self.buckets[min(i, len(self.buckets) - 1)]
        prev = 0 if i == 0 else int(cum[i - 1])
        width = max(int(s.counts[i]), 1)
        return lo + (hi - lo) * min((rank + 1 - prev) / width, 1.0)

    def max(self, **labels) -> float:
        s = self._get(labels)
        if s.count == 0:
            return 0.0
        if s.raw is not None:
            return float(np.max(s.raw))
        top = int(np.flatnonzero(s.counts)[-1])
        return self.buckets[min(top, len(self.buckets) - 1)]

    def count_le(self, bound: float, **labels) -> int:
        """Observations with value <= ``bound`` — exact while raw values
        are retained; after raw overflow, the cumulative count of every
        bucket whose upper bound is <= ``bound`` (an underestimate when
        ``bound`` falls inside a bucket). The SLO latency source reads
        good-event counts through this."""
        s = self._get(labels)
        if s.count == 0:
            return 0
        if s.raw is not None:
            return int(np.count_nonzero(
                np.asarray(s.raw, np.float64) <= float(bound)))
        i = int(np.searchsorted(self._bounds, float(bound), side="right"))
        return int(s.counts[:i].sum())

    def _series_snapshot(self, s) -> dict:
        return {
            "count": int(s.count),
            "sum": float(s.sum),
            "buckets": {str(b): int(c)
                        for b, c in zip(self.buckets, s.counts)},
            "overflow": int(s.counts[-1]),
        }


class MetricRegistry:
    """Name → metric map. ``counter``/``gauge``/``histogram`` are
    get-or-create (idempotent; conflicting re-registration raises), so
    call sites simply ask for the metric where they use it."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _register(self, cls, name, help, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls(name, help, **kw))
        if not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "", buckets=None,
                  raw_cap: int = 1 << 20) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets,
                              raw_cap=raw_cap)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list:
        return sorted(self._metrics)

    def clear(self) -> None:
        self._metrics.clear()

    def snapshot(self, prefix: str = "") -> dict:
        """{name: metric snapshot} for every metric under ``prefix``."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())
                if name.startswith(prefix)}

    def section(self, prefix: str) -> dict:
        """Flat {name: value} view of one component's scalar series —
        counters/gauges only, labels folded into the key — the compact
        form ``DistanceServer.stats()`` embeds."""
        out = {}
        for name, m in sorted(self._metrics.items()):
            if not name.startswith(prefix) or m.kind == "histogram":
                continue
            for k, s in sorted(m._series.items()):
                tag = ",".join(f"{lk}={lv}" for lk, lv in k)
                out[f"{name}{{{tag}}}" if tag else name] = s[0]
        return out

    def to_json(self, prefix: str = "", **extra) -> str:
        return json.dumps({"metrics": self.snapshot(prefix), **extra},
                          indent=2, sort_keys=True)

    # ------------------------------------------------ test isolation
    def reset(self) -> None:
        """Drop every registered metric. Components holding direct
        metric references keep recording into their (now detached)
        objects; fresh ``counter``/``gauge``/``histogram`` calls start
        clean — the between-tests isolation point (tests construct
        their servers after the reset)."""
        with self._lock:
            self._metrics = {}

    @contextlib.contextmanager
    def isolated(self):
        """Run a block against an empty metric map, restoring the
        previous one afterwards. Because call sites import the module-
        level ``REGISTRY`` object (never a copy), swapping its internal
        map is enough: nothing recorded inside the block leaks out, and
        nothing from outside is visible inside."""
        with self._lock:
            saved, self._metrics = self._metrics, {}
        try:
            yield self
        finally:
            with self._lock:
                self._metrics = saved

    # ------------------------------------------- Prometheus exposition
    def render_prometheus(self, prefix: str = "") -> str:
        """Prometheus text exposition (format version 0.0.4) of every
        metric under ``prefix`` — the front end's ``/metrics`` body.

        Rules (so real Prometheus scrapers and the round-trip parser in
        tests both accept the output): metric names are sanitized to
        ``[a-zA-Z_:][a-zA-Z0-9_:]*`` (dots become underscores), labels
        are emitted in sorted-key order, label values escape ``\\``,
        ``\"`` and newlines, HELP text escapes ``\\`` and newlines, and
        histograms expose cumulative ``_bucket{le=...}`` series ending
        in ``le="+Inf"`` plus ``_sum`` and ``_count``.
        """
        lines: list[str] = []
        for name, m in sorted(self._metrics.items()):
            if not name.startswith(prefix):
                continue
            pname = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pname} {_prom_escape_help(m.help)}")
            lines.append(f"# TYPE {pname} {m.kind}")
            for key, s in sorted(m._series.items()):
                labels = dict(key)
                if m.kind == "histogram":
                    cum = 0
                    for bound, cnt in zip(m.buckets, s.counts):
                        cum += int(cnt)
                        lines.append(_prom_line(
                            pname + "_bucket",
                            {**labels, "le": _prom_float(bound)}, cum))
                    lines.append(_prom_line(
                        pname + "_bucket", {**labels, "le": "+Inf"},
                        int(s.count)))
                    lines.append(_prom_line(pname + "_sum", labels, s.sum))
                    lines.append(_prom_line(pname + "_count", labels,
                                            int(s.count)))
                else:
                    lines.append(_prom_line(pname, labels, s[0]))
        return "\n".join(lines) + ("\n" if lines else "")


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    out = _PROM_BAD.sub("_", name)
    if not out or out[0].isdigit():
        out = "_" + out
    return out


def _prom_escape_label(v) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _prom_escape_help(v: str) -> str:
    return str(v).replace("\\", r"\\").replace("\n", r"\n")


def _prom_float(v: float) -> str:
    """Shortest exact decimal for a bucket bound / sample value."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f)) + ".0"
    return repr(f)


def _prom_line(name: str, labels: dict, value) -> str:
    lbl = ",".join(f'{k}="{_prom_escape_label(v)}"'
                   for k, v in sorted(labels.items()))
    val = (_prom_float(value) if isinstance(value, float)
           else str(int(value)))
    return f"{name}{{{lbl}}} {val}" if lbl else f"{name} {val}"


# The process-wide default registry every component reports through.
REGISTRY = MetricRegistry()
