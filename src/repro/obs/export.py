"""Structured export: JSON-lines event log + metrics/trace file writers.

``EventLog`` is the append-only structured log the serving launcher and
the (planned) HTTP/SSE front end stream from: one JSON object per line,
each stamped with a monotonically increasing sequence number and the
caller's timestamp. Lines are flushed per event so a tailing consumer
(``tail -f`` / SSE relay) sees them immediately.

``write_metrics`` / ``write_chrome_trace`` are the ``launch/serve.py
--metrics-out`` / ``--trace-out`` sinks (docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.obs.registry import REGISTRY

__all__ = ["EventLog", "write_metrics", "write_chrome_trace"]


class EventLog:
    """JSON-lines structured event log.

    ``path=None`` keeps events in memory only (tests, SSE buffers);
    otherwise every event is appended and flushed to the file as one
    line. Events are plain dicts: ``{"seq": n, "ts": t, "kind": k, ...}``.
    """

    def __init__(self, path=None, keep: int = 4096):
        self.path = Path(path) if path else None
        self.keep = int(keep)
        self.recent: list[dict] = []
        self._seq = 0
        self._fh = None
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")

    def log(self, kind: str, ts: float = 0.0, **fields) -> dict:
        ev = {"seq": self._seq, "ts": float(ts), "kind": str(kind),
              **fields}
        self._seq += 1
        self.recent.append(ev)
        if len(self.recent) > self.keep:
            del self.recent[:len(self.recent) - self.keep]
        if self._fh:
            self._fh.write(json.dumps(ev, sort_keys=True,
                                      default=_jsonable) + "\n")
            self._fh.flush()
        return ev

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    @staticmethod
    def read(path) -> list[dict]:
        """Load every event line of a log file (skips blank lines)."""
        out = []
        for line in Path(path).read_text().splitlines():
            if line.strip():
                out.append(json.loads(line))
        return out


def _jsonable(o):
    try:
        import numpy as np
        if isinstance(o, np.generic):
            return o.item()
        if isinstance(o, np.ndarray):
            return o.tolist()
    except Exception:
        pass
    return str(o)


def write_metrics(path, registry=None, **extra) -> Path:
    """Dump a registry snapshot (every metric, every labeled series)
    as one JSON document — the ``--metrics-out`` sink."""
    reg = registry if registry is not None else REGISTRY
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(reg.to_json(**extra) + "\n")
    return p


def write_chrome_trace(path, tracer) -> Path:
    """Write a tracer's spans as Chrome trace-event JSON — the
    ``--trace-out`` sink (open in chrome://tracing or Perfetto)."""
    return tracer.write_chrome(path)
