"""Bench-trajectory regression gating over the committed
``BENCH_*.json`` files (the cross-PR perf trajectory).

``compare_docs`` diffs a fresh benchmark document against the committed
baseline of the same table and classifies every shared metric:

  timing metrics    (``us_per_call`` rows, serving ``qps_compute`` /
                    ``latency_ms`` cells) — machine- and load-dependent,
                    gated at the *timing* tolerance (CI passes a loose
                    one; see .github/workflows/ci.yml).
  behavior metrics  (``cache_hit_rate``, ``batch_fill_ratio``, lane
                    request counts, plus any derived row field whose key
                    names a correctness/behavior quantity — exactness
                    flags, parity bits, fill ratios, relaxation round
                    counts, overflow counts) — deterministic given the
                    same trace/preset, gated at the tight *behavior*
                    tolerance: a drift here is a real serving-logic
                    regression, not noise.

Tolerances are relative: a lower-is-better metric regresses when
``fresh > base * (1 + tol)``; higher-is-better when
``fresh < base * (1 - tol)``. Metrics missing from the fresh run are
reported as regressions (coverage loss); metrics new in the fresh run
are ignored (the next commit of the baseline picks them up).

``scripts/obs_report.py`` is the CLI over this module.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

__all__ = ["Metric", "Regression", "extract_metrics", "compare_docs",
           "compare_dirs", "format_report"]

# Baseline values at or below these floors are noise (a 3µs row
# doubling is scheduler jitter, not a regression) — skipped.
TIMING_FLOOR_US = 20.0
QPS_FLOOR = 1.0


@dataclasses.dataclass(frozen=True)
class Metric:
    name: str            # stable key, e.g. "row:uniform-b32:us_per_call"
    value: float
    higher_better: bool
    kind: str            # "timing" | "behavior"


@dataclasses.dataclass
class Regression:
    table: str
    metric: str
    kind: str
    baseline: float
    fresh: float | None          # None = missing from the fresh run
    tolerance: float

    @property
    def ratio(self) -> float:
        if self.fresh is None or self.baseline == 0:
            return float("inf")
        return self.fresh / self.baseline

    def describe(self) -> str:
        if self.fresh is None:
            return (f"[{self.table}] {self.metric}: missing from fresh "
                    f"run (baseline {self.baseline:g})")
        return (f"[{self.table}] {self.metric} ({self.kind}): baseline "
                f"{self.baseline:g} -> fresh {self.fresh:g} "
                f"(x{self.ratio:.2f}, tolerance ±{self.tolerance:.0%})")


# Derived row keys matching these fragments are deterministic behavior
# metrics (same code + preset => same value): exactness/parity flags and
# fill ratios must not drop; round counts, overflow counts, and host-sync
# counts (the construction suite's syncs_per_level — the device-resident
# build promises <= 1) must not grow. Everything else in a row stays
# timing-or-ignored.
BEHAVIOR_KEY_FRAGMENTS = (
    ("exact", True), ("parity", True), ("bitwise", True), ("fill", True),
    ("hit", True), ("rounds", False), ("overflow", False), ("sync", False),
)


def _behavior_direction(key: str) -> bool | None:
    """higher_better for a behavior-classified row key, None otherwise."""
    k = key.lower()
    for frag, higher_better in BEHAVIOR_KEY_FRAGMENTS:
        if frag in k:
            return higher_better
    return None


def _row_metrics(doc: dict) -> list[Metric]:
    out = []
    for r in doc.get("rows", []):
        name, us = r.get("name"), r.get("us_per_call")
        if name is None or us is None or name == "ERROR":
            continue
        if float(us) > TIMING_FLOOR_US:
            out.append(Metric(f"row:{name}:us_per_call", float(us),
                              higher_better=False, kind="timing"))
        for key, val in r.items():
            if key in ("table", "name", "us_per_call"):
                continue
            if not isinstance(val, (int, float)) or isinstance(val, bool):
                continue
            hb = _behavior_direction(key)
            if hb is not None:
                out.append(Metric(f"row:{name}:{key}", float(val),
                                  higher_better=hb, kind="behavior"))
    return out


def _serving_metrics(doc: dict) -> list[Metric]:
    out = []
    for cell in doc.get("results", []):
        tag = (f"{cell.get('scenario', '?')}-b"
               + "x".join(str(b) for b in cell.get("buckets", [])))
        qps = cell.get("qps_compute", 0.0)
        if qps and qps > QPS_FLOOR:
            out.append(Metric(f"cell:{tag}:qps_compute", float(qps),
                              higher_better=True, kind="timing"))
        p99 = cell.get("latency_ms", {}).get("p99")
        if p99:
            out.append(Metric(f"cell:{tag}:latency_p99_ms", float(p99),
                              higher_better=False, kind="timing"))
        for key in ("cache_hit_rate", "batch_fill_ratio"):
            if key in cell:
                out.append(Metric(f"cell:{tag}:{key}", float(cell[key]),
                                  higher_better=True, kind="behavior"))
        for lane, ln in sorted(cell.get("lanes", {}).items()):
            if ln.get("requests", 0) > 0:
                out.append(Metric(f"cell:{tag}:lane_{lane}_requests",
                                  float(ln["requests"]),
                                  higher_better=True, kind="behavior"))
    return out


def extract_metrics(doc: dict) -> dict:
    """{metric name: Metric} for one BENCH document. Serving-style
    documents (``results`` cells) get the cell metrics on top of the
    generic ``us_per_call`` rows every table emits."""
    metrics = _row_metrics(doc)
    if "results" in doc:
        metrics += _serving_metrics(doc)
    return {m.name: m for m in metrics}


def compare_docs(table: str, baseline: dict, fresh: dict, *,
                 timing_tolerance: float = 0.5,
                 behavior_tolerance: float = 0.05) -> list[Regression]:
    """Every baseline metric the fresh run regressed on (or dropped)."""
    base_m = extract_metrics(baseline)
    fresh_m = extract_metrics(fresh)
    out = []
    for name, bm in sorted(base_m.items()):
        tol = (behavior_tolerance if bm.kind == "behavior"
               else timing_tolerance)
        fm = fresh_m.get(name)
        if fm is None:
            out.append(Regression(table, name, bm.kind, bm.value, None,
                                  tol))
            continue
        if bm.higher_better:
            bad = fm.value < bm.value * (1.0 - tol)
        else:
            bad = fm.value > bm.value * (1.0 + tol)
        if bad:
            out.append(Regression(table, name, bm.kind, bm.value,
                                  fm.value, tol))
    return out


def compare_dirs(baseline_dir, fresh_dir, *, tables=None,
                 timing_tolerance: float = 0.5,
                 behavior_tolerance: float = 0.05):
    """Diff every ``BENCH_<table>.json`` present in both directories.

    Returns ``(regressions, compared_tables, skipped_tables)`` —
    skipped = baseline tables with no fresh counterpart (not a failure:
    partial bench runs are normal; pass ``tables`` to require a set).
    """
    baseline_dir, fresh_dir = Path(baseline_dir), Path(fresh_dir)
    regs, compared, skipped = [], [], []
    for bpath in sorted(baseline_dir.glob("BENCH_*.json")):
        table = bpath.stem[len("BENCH_"):]
        if tables and table not in tables:
            continue
        fpath = fresh_dir / bpath.name
        if not fpath.exists():
            skipped.append(table)
            continue
        regs += compare_docs(table, json.loads(bpath.read_text()),
                             json.loads(fpath.read_text()),
                             timing_tolerance=timing_tolerance,
                             behavior_tolerance=behavior_tolerance)
        compared.append(table)
    if tables:
        missing = sorted(set(tables) - set(compared))
        for table in missing:
            regs.append(Regression(table, "<table>", "coverage", 1.0,
                                   None, 0.0))
    return regs, compared, skipped


def format_report(regs, compared, skipped, *, timing_tolerance,
                  behavior_tolerance) -> str:
    lines = [f"bench-regression report: {len(compared)} table(s) "
             f"compared ({', '.join(compared) or 'none'}), "
             f"{len(skipped)} skipped ({', '.join(skipped) or 'none'}), "
             f"tolerances timing ±{timing_tolerance:.0%} / "
             f"behavior ±{behavior_tolerance:.0%}"]
    if not regs:
        lines.append("OK: no metric regressed beyond tolerance")
    else:
        lines.append(f"FAIL: {len(regs)} regression(s)")
        lines += ["  " + r.describe() for r in regs]
    return "\n".join(lines)
