"""GNN zoo: GCN (spectral), GraphSAGE (sampled mean-agg), EGNN (E(n)-
equivariant). All message passing is ``gather -> elementwise ->
segment_sum/mean`` over explicit edge indices — JAX has no sparse SpMM,
so the segment formulation IS the kernel (see kernel_taxonomy §GNN).

Edge conventions match repro.graphs.csr: sentinel-padded fixed shapes;
padding edges point at row ``n`` which is sliced away after aggregation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.graphs import segment_ops as sops
from repro.models import layers as L


# ------------------------------------------------------------------- GCN
@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int
    n_classes: int
    norm: str = "sym"


def init_gcn(key, cfg: GCNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, len(dims) - 1)
    p, a = {}, {}
    for i, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"w{i}"] = L._dense_init(keys[i], (di, do))
        a[f"w{i}"] = ("gnn_in", "gnn_hidden")
    return p, a


def gcn_forward(p, cfg: GCNConfig, x, edge_src, edge_dst, deg):
    """x: [n+1, d_in] (sentinel row 0s); edges sentinel-padded to n.
    deg: [n+1] degrees (>=1). Symmetric normalization D^-1/2 A D^-1/2."""
    n1 = x.shape[0]
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(deg.astype(jnp.float32), 1.0))
    for i in range(cfg.n_layers):
        h = x @ p[f"w{i}"]
        msg = h[edge_src] * inv_sqrt[edge_src][:, None]
        agg = sops.segment_sum(msg, edge_dst, n1)
        x = agg * inv_sqrt[:, None]
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


# --------------------------------------------------------------- GraphSAGE
@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int
    n_classes: int
    aggregator: str = "mean"
    fanouts: tuple = (25, 10)


def init_sage(key, cfg: SAGEConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    p, a = {}, {}
    keys = jax.random.split(key, len(dims) - 1)
    for i, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
        # W_self and W_neigh (concat formulation)
        p[f"self{i}"] = L._dense_init(keys[i], (di, do))
        p[f"nbr{i}"] = L._dense_init(jax.random.fold_in(keys[i], 1), (di, do))
        a[f"self{i}"] = ("gnn_in", "gnn_hidden")
        a[f"nbr{i}"] = ("gnn_in", "gnn_hidden")
    return p, a


def sage_layer(p, i, x_src, x_dst, edge_src, edge_dst, n_dst1, aggregator):
    msg = x_src[edge_src]
    if aggregator == "mean":
        agg = sops.segment_mean(msg, edge_dst, n_dst1)
    else:
        agg = sops.segment_max(msg, edge_dst, n_dst1)
        agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
    return x_dst @ p[f"self{i}"] + agg @ p[f"nbr{i}"]


def sage_forward_full(p, cfg: SAGEConfig, x, edge_src, edge_dst):
    """Full-graph SAGE (ogb_products-style full-batch)."""
    n1 = x.shape[0]
    for i in range(cfg.n_layers):
        x = sage_layer(p, i, x, x, edge_src, edge_dst, n1, cfg.aggregator)
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


def sage_forward_blocks(p, cfg: SAGEConfig, x_outer, blocks):
    """Minibatch SAGE over sampler blocks (outermost first). ``blocks`` is
    a list of dicts with edge_src/edge_dst (local) + n_dst +
    map_dst: index of each dst node within the src node set."""
    x = x_outer
    for i, blk in enumerate(blocks):
        x_pad = jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], 0)
        sentinel = jnp.asarray([x_pad.shape[0] - 1], jnp.int32)
        map_dst = jnp.concatenate([blk["map_dst"].astype(jnp.int32),
                                   sentinel])       # row for the pad segment
        x_dst = x_pad[jnp.minimum(map_dst, x_pad.shape[0] - 1)]
        x = sage_layer(p, i, x_pad, x_dst, blk["edge_src"], blk["edge_dst"],
                       blk["n_dst"] + 1, cfg.aggregator)[: blk["n_dst"]]
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


# -------------------------------------------------------------------- EGNN
@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int
    n_out: int = 1


def init_egnn(key, cfg: EGNNConfig):
    p, a = {}, {}
    k0, key = jax.random.split(key)
    p["embed"] = L._dense_init(k0, (cfg.d_in, cfg.d_hidden))
    a["embed"] = ("gnn_in", "gnn_hidden")
    h = cfg.d_hidden
    for i in range(cfg.n_layers):
        ke, kx, kh, key = jax.random.split(key, 4)
        p[f"phi_e{i}"], a[f"phi_e{i}"] = L.init_mlp(ke, [2 * h + 1, h, h])
        p[f"phi_x{i}"], a[f"phi_x{i}"] = L.init_mlp(kx, [h, h, 1])
        p[f"phi_h{i}"], a[f"phi_h{i}"] = L.init_mlp(kh, [2 * h, h, h])
    ko, _ = jax.random.split(key)
    p["out"], a["out"] = L.init_mlp(ko, [h, h, cfg.n_out])
    return p, a


def egnn_forward(p, cfg: EGNNConfig, h_feat, coords, edge_src, edge_dst):
    """h_feat: [n+1, d_in]; coords: [n+1, 3]; edges sentinel-padded.
    Returns (node_out [n+1, n_out], node feats h) — callers pool for
    graph-level targets (segment_sum over graph_ids)."""
    n1 = h_feat.shape[0]
    h = h_feat @ p["embed"]
    x = coords
    act = jax.nn.silu
    for i in range(cfg.n_layers):
        diff = x[edge_src] - x[edge_dst]
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = L.mlp(p[f"phi_e{i}"], jnp.concatenate(
            [h[edge_src], h[edge_dst], d2], -1), act=act)
        # coordinate update (E(n)-equivariant)
        cx = L.mlp(p[f"phi_x{i}"], m, act=act)
        x = x + sops.segment_mean(diff * cx, edge_dst, n1)
        # feature update
        agg = sops.segment_sum(m, edge_dst, n1)
        h = h + L.mlp(p[f"phi_h{i}"], jnp.concatenate([h, agg], -1), act=act)
    node_out = L.mlp(p["out"], h, act=act)
    return node_out, h
