"""Sharded embedding tables + EmbeddingBag.

JAX has no nn.EmbeddingBag and no CSR sparse: the lookup is
``jnp.take`` + ``segment_sum`` (multi-hot bags). Two distribution
strategies for the huge recsys tables (10^6-10^9 rows):

* ``gspmd``: plain take on a row-sharded table; GSPMD partitions the
  gather into shard-local lookups + all-reduce (its sharded-gather pass
  emits the same mask/psum pattern as the manual version).
* ``shard_map``: explicit mod-sharding — row r lives on shard r % S at
  local index r // S; each shard looks up the rows it owns, masks the
  rest, and one psum over the embedding axis combines. Deterministic
  collective footprint: one [B, D] psum per lookup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.graphs import segment_ops as sops


def init_table(key, n_rows: int, dim: int, scale: float = 0.01):
    p = {"table": jax.random.normal(key, (n_rows, dim), jnp.float32) * scale}
    return p, {"table": ("table_rows", "table_dim")}


def lookup(table, ids):
    """Replicated/GSPMD lookup: [..] int32 -> [.., D]."""
    return jnp.take(table, ids, axis=0)


def lookup_mod_sharded(table, ids, mesh, axis: str = "model"):
    """Explicit mod-sharded lookup via shard_map (table sharded on rows)."""
    from jax import shard_map
    n_shards = mesh.shape[axis]

    def local_lookup(tbl_local, ids_rep):
        shard = jax.lax.axis_index(axis)
        owner = ids_rep % n_shards
        local_idx = ids_rep // n_shards
        vals = jnp.take(tbl_local, local_idx, axis=0)
        vals = jnp.where((owner == shard)[..., None], vals, 0.0)
        return jax.lax.psum(vals, axis)

    spec_tbl = P(axis, None)
    return shard_map(local_lookup, mesh=mesh, in_specs=(spec_tbl, P()),
                     out_specs=P(), check_vma=False,
                     axis_names=frozenset({axis}))(table, ids)


def embedding_bag(table, ids, segment_ids, n_bags: int, mode: str = "sum"):
    """Multi-hot bag: ids int32[nnz], segment_ids int32[nnz] -> [n_bags, D].
    Sentinel-padded nnz entries must carry segment_id == n_bags."""
    vals = jnp.take(table, ids, axis=0)
    if mode == "sum":
        return sops.segment_sum(vals, segment_ids, n_bags + 1)[:n_bags]
    if mode == "mean":
        return sops.segment_mean(vals, segment_ids, n_bags + 1)[:n_bags]
    out = sops.segment_max(vals, segment_ids, n_bags + 1)[:n_bags]
    return jnp.where(jnp.isfinite(out), out, 0.0)
