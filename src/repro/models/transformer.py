"""Decoder-only LM (dense or MoE) with scan-over-layers + remat.

Covers the five assigned LM archs: llama-style (granite/yi), qwen2
(QKV bias), qwen2-moe (shared+routed experts), kimi-k2 (384-expert MoE).
Layer params are stacked on a leading ``layers`` axis and folded with
``jax.lax.scan`` (keeps HLO small enough to AOT-compile 80-layer models
on the 512-device dry-run) with ``jax.checkpoint`` for activation remat.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import (AttnConfig, causal_attention,
                                    decode_attention, init_attention)
from repro.models.moe import MoEConfig, init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    q_chunk: int = 512
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "none"       # none=nothing_saveable | dots | off
    unroll: bool = False             # dry-run probes: unroll layer scans
    tie_embeddings: bool = False
    ce_impl: str = "gather"          # "iota" = vocab-sharding-safe CE
    act_shard: bool = False          # sharding constraints on residuals

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                          self.hd, self.rope_theta, self.qkv_bias)

    def param_count(self) -> int:
        e, f, v, nl = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = e * (self.n_heads * self.hd) * 2 + \
            e * (self.n_kv_heads * self.hd) * 2
        if self.moe:
            m = self.moe
            ff = m.n_experts * 3 * e * m.d_expert_ff + e * m.n_experts
            if m.n_shared:
                ff += 3 * e * (m.d_shared_ff or m.n_shared * m.d_expert_ff)
        else:
            ff = 3 * e * f
        return nl * (attn + ff + 2 * e) + v * e * (1 if self.tie_embeddings
                                                   else 2)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        e, nl = self.d_model, self.n_layers
        m = self.moe
        attn = e * (self.n_heads * self.hd) * 2 + \
            e * (self.n_kv_heads * self.hd) * 2
        ff = m.top_k * 3 * e * m.d_expert_ff + e * m.n_experts
        if m.n_shared:
            ff += 3 * e * (m.d_shared_ff or m.n_shared * m.d_expert_ff)
        return nl * (attn + ff + 2 * e) + self.vocab * e * 2


# --------------------------------------------------------------------- init
def init_layer(key, cfg: LMConfig):
    ka, kf, kn = jax.random.split(key, 3)
    p, a = {}, {}
    p["attn"], a["attn"] = init_attention(ka, cfg.attn_cfg())
    if cfg.moe:
        p["ffn"], a["ffn"] = init_moe(kf, cfg.d_model, cfg.moe)
    else:
        p["ffn"], a["ffn"] = L.init_swiglu(kf, cfg.d_model, cfg.d_ff)
    p["ln1"], a["ln1"] = L.init_rmsnorm(cfg.d_model)
    p["ln2"], a["ln2"] = L.init_rmsnorm(cfg.d_model)
    return p, a


def tiny_like(cfg: LMConfig) -> LMConfig:
    """Structurally-identical config with tiny dims (axes-tree derivation
    and smoke tests — the param-tree *structure* only depends on flags)."""
    moe = None
    if cfg.moe:
        moe = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_expert_ff=16,
            d_shared_ff=16 if (cfg.moe.n_shared or cfg.moe.d_shared_ff) else 0)
    return dataclasses.replace(
        cfg, n_layers=2, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
        vocab=64, head_dim=8, moe=moe, q_chunk=8)


def lm_axes(cfg: LMConfig):
    """Logical-axis tree without allocating real-size params."""
    return init_lm(jax.random.PRNGKey(0), tiny_like(cfg))[1]


def init_lm(key, cfg: LMConfig):
    """Returns (params, axes). Layer params stacked on axis 0 ("layers")."""
    ke, kl, ko = jax.random.split(key, 3)
    layer_a = init_layer(jax.random.PRNGKey(0), tiny_like(cfg))[1]
    stacked = jax.vmap(lambda k: init_layer(k, cfg)[0])(
        jax.random.split(kl, cfg.n_layers))
    stacked_a = jax.tree.map(lambda ax: ("layers",) + ax, layer_a,
                             is_leaf=lambda x: isinstance(x, tuple))
    p = {"embed": L._dense_init(ke, (cfg.vocab, cfg.d_model)),
         "blocks": stacked,
         "ln_f": L.init_rmsnorm(cfg.d_model)[0]}
    a = {"embed": ("vocab", "embed"),
         "blocks": stacked_a,
         "ln_f": {"scale": ("embed",)}}
    if not cfg.tie_embeddings:
        p["unembed"] = L._dense_init(ko, (cfg.d_model, cfg.vocab))
        a["unembed"] = ("embed", "vocab")
    return p, a


def abstract_params(cfg: LMConfig):
    """ShapeDtypeStruct param tree — dry-run init without allocation."""
    return jax.eval_shape(lambda k: init_lm(k, cfg)[0],
                          jax.random.PRNGKey(0))


# ------------------------------------------------------------------ forward
_ACT_MESH = [None]          # set by steps.py when cfg.act_shard is on


def set_act_shard_mesh(mesh):
    _ACT_MESH[0] = mesh


def _block(cfg: LMConfig, p, x, dtype):
    h, _ = causal_attention(p["attn"], cfg.attn_cfg(),
                            L.rmsnorm(p["ln1"], x), q_chunk=cfg.q_chunk,
                            dtype=dtype)
    x = x + h
    if cfg.moe:
        f, aux = moe_ffn(p["ffn"], cfg.moe, L.rmsnorm(p["ln2"], x),
                         dtype=dtype)
    else:
        f = L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x), dtype)
        aux = jnp.float32(0)
    return x + f, aux


def forward(params, cfg: LMConfig, tokens):
    """tokens int32[B, S] -> logits f32[B, S, V] (+ aux loss)."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dtype)[tokens]

    def body(carry, lp):
        x, aux = carry
        if cfg.act_shard and _ACT_MESH[0] is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            mesh = _ACT_MESH[0]
            dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, "model")))
        block = lambda lp_, x_: _block(cfg, lp_, x_, dtype)  # noqa: E731
        if cfg.remat and cfg.remat_policy != "off":
            policy = (jax.checkpoint_policies.dots_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            block = jax.checkpoint(block, policy=policy)
        x, a = block(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), params["blocks"],
                               unroll=cfg.unroll)
    x = L.rmsnorm(params["ln_f"], x)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(dtype)
    logits = (x @ unembed).astype(jnp.float32)
    return logits, aux


def lm_loss(params, cfg: LMConfig, tokens, targets, mask=None):
    logits, aux = forward(params, cfg, tokens)
    loss = L.softmax_cross_entropy(logits, targets, impl=cfg.ce_impl)
    if mask is not None:
        loss = jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(loss)
    return loss + aux


# ------------------------------------------------------------------ serving
def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def abstract_cache(cfg: LMConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32)}


def prefill(params, cfg: LMConfig, tokens, max_len: int):
    """Full-sequence forward that also materializes the KV cache."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = params["embed"].astype(dtype)[tokens]
    cache = init_cache(cfg, b, max_len, dtype)

    def body(carry, lp):
        x = carry
        h, (k, v) = causal_attention(lp["attn"], cfg.attn_cfg(),
                                     L.rmsnorm(lp["ln1"], x),
                                     q_chunk=cfg.q_chunk, dtype=dtype)
        x = x + h
        if cfg.moe:
            f, _ = moe_ffn(lp["ffn"], cfg.moe, L.rmsnorm(lp["ln2"], x),
                           dtype=dtype)
        else:
            f = L.swiglu(lp["ffn"], L.rmsnorm(lp["ln2"], x), dtype)
        return x + f, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"], unroll=cfg.unroll)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(dtype), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(dtype), 0, axis=2)
    cache["len"] = jnp.int32(s)
    x = L.rmsnorm(params["ln_f"], x[:, -1:])
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(dtype)
    return (x @ unembed).astype(jnp.float32), cache


def decode_step(params, cfg: LMConfig, cache, last_tokens):
    """One-token decode. last_tokens: int32[B, 1]. Returns (logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dtype)[last_tokens]

    def body(x, inputs):
        lp, ck, cv = inputs
        h, nk, nv = decode_attention(lp["attn"], cfg.attn_cfg(),
                                     L.rmsnorm(lp["ln1"], x), ck, cv,
                                     cache["len"], dtype=dtype)
        x = x + h
        if cfg.moe:
            f, _ = moe_ffn(lp["ffn"], cfg.moe, L.rmsnorm(lp["ln2"], x),
                           dtype=dtype)
        else:
            f = L.swiglu(lp["ffn"], L.rmsnorm(lp["ln2"], x), dtype)
        return x + f, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(body, x,
                                 (params["blocks"], cache["k"], cache["v"]),
                                 unroll=cfg.unroll)
    cache = dict(cache, k=nks, v=nvs, len=cache["len"] + 1)
    x = L.rmsnorm(params["ln_f"], x)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(dtype)
    return (x @ unembed).astype(jnp.float32), cache
