"""DIEN — Deep Interest Evolution Network (arXiv:1809.03672).

Structure per the paper: sparse embeddings (item + category + user
profile) -> interest *extraction* GRU over the behavior sequence (with
the auxiliary next-behavior loss) -> interest *evolution* AUGRU (GRU
whose update gate is scaled by attention against the target item) ->
MLP head [200, 80] -> CTR logit.

Embedding lookup is the hot path: tables are row-sharded (model axis);
the sequence GRUs run under ``lax.scan``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.embedding import init_table, lookup


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple = (200, 80)
    n_items: int = 1 << 26       # 67M rows — recsys-scale sparse table
    n_cats: int = 10000
    n_users: int = 1 << 22
    aux_weight: float = 1.0
    unroll: bool = False             # dry-run probes: unroll time scans

    @property
    def d_behavior(self) -> int:      # item + category embedding concat
        return 2 * self.embed_dim


def _init_gru(key, d_in, d_h, prefix):
    k = jax.random.split(key, 3)
    p = {"wz": L._dense_init(k[0], (d_in + d_h, d_h)),
         "wr": L._dense_init(k[1], (d_in + d_h, d_h)),
         "wh": L._dense_init(k[2], (d_in + d_h, d_h)),
         "bz": jnp.zeros((d_h,)), "br": jnp.zeros((d_h,)),
         "bh": jnp.zeros((d_h,))}
    a = {"wz": (f"{prefix}_in", f"{prefix}_h"),
         "wr": (f"{prefix}_in", f"{prefix}_h"),
         "wh": (f"{prefix}_in", f"{prefix}_h"),
         "bz": (f"{prefix}_h",), "br": (f"{prefix}_h",),
         "bh": (f"{prefix}_h",)}
    return p, a


def _gru_cell(p, x, h, att=None):
    xh = jnp.concatenate([x, h], -1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    hc = jnp.tanh(jnp.concatenate([x, r * h], -1) @ p["wh"] + p["bh"])
    if att is not None:                      # AUGRU: attentional update gate
        z = z * att[:, None]
    return (1 - z) * h + z * hc


def init_dien(key, cfg: DIENConfig):
    ki, kc, ku, k1, k2, ka, km = jax.random.split(key, 7)
    p, a = {}, {}
    p["item"], a["item"] = init_table(ki, cfg.n_items, cfg.embed_dim)
    p["cat"], a["cat"] = init_table(kc, cfg.n_cats, cfg.embed_dim)
    p["user"], a["user"] = init_table(ku, cfg.n_users, cfg.embed_dim)
    p["gru1"], a["gru1"] = _init_gru(k1, cfg.d_behavior, cfg.gru_dim, "gru")
    p["augru"], a["augru"] = _init_gru(k2, cfg.gru_dim, cfg.gru_dim, "gru")
    p["att"], a["att"] = L.init_mlp(ka, [2 * cfg.gru_dim + cfg.d_behavior,
                                         80, 1])
    d_head = cfg.gru_dim + 2 * cfg.d_behavior + cfg.embed_dim
    p["head"], a["head"] = L.init_mlp(
        km, [d_head, cfg.mlp_dims[0], cfg.mlp_dims[1], 1])
    return p, a


def _behavior_embed(p, item_ids, cat_ids):
    return jnp.concatenate([lookup(p["item"]["table"], item_ids),
                            lookup(p["cat"]["table"], cat_ids)], -1)


def dien_forward(p, cfg: DIENConfig, batch):
    """batch: dict with user int32[B], hist_items int32[B,S],
    hist_cats [B,S], hist_mask f32[B,S], target_item [B], target_cat [B].
    Returns (logit [B], aux_loss)."""
    hist = _behavior_embed(p, batch["hist_items"], batch["hist_cats"])
    mask = batch["hist_mask"]
    target = _behavior_embed(p, batch["target_item"], batch["target_cat"])
    user = lookup(p["user"]["table"], batch["user"])

    # ---- interest extraction GRU (scan over time) -----------------------
    b = hist.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), hist.dtype)

    def step1(h, xm):
        x, m = xm
        h2 = _gru_cell(p["gru1"], x, h)
        h2 = jnp.where(m[:, None] > 0, h2, h)
        return h2, h2
    _, states = jax.lax.scan(step1, h0, (jnp.moveaxis(hist, 1, 0),
                                         jnp.moveaxis(mask, 1, 0)),
                             unroll=cfg.unroll)
    states = jnp.moveaxis(states, 0, 1)               # [B, S, H]

    # ---- auxiliary loss: h_t should predict behavior_{t+1} --------------
    # (negatives = shifted batch — standard sampled approximation)
    h_t = states[:, :-1]
    e_pos = hist[:, 1:]
    e_neg = jnp.roll(e_pos, 1, axis=0)
    m_t = mask[:, 1:]

    def binlog(h, e):
        sim = jnp.sum(h[..., : e.shape[-1]] * e, -1)
        return jax.nn.log_sigmoid(sim)
    aux = -(binlog(h_t, e_pos) + jnp.log1p(
        -jnp.clip(jnp.exp(binlog(h_t, e_neg)), 0, 1 - 1e-6)))
    aux = jnp.sum(aux * m_t) / jnp.maximum(jnp.sum(m_t), 1.0)

    # ---- attention scores vs target --------------------------------------
    tgt = jnp.broadcast_to(target[:, None, :], hist.shape)
    att_in = jnp.concatenate([states, tgt, states], -1)[
        ..., : 2 * cfg.gru_dim + cfg.d_behavior]
    scores = L.mlp(p["att"], att_in)[..., 0]
    scores = jnp.where(mask > 0, scores, -1e9)
    att = jax.nn.softmax(scores, axis=1)              # [B, S]

    # ---- interest evolution AUGRU ----------------------------------------
    def step2(h, xam):
        x, a_t, m = xam
        h2 = _gru_cell(p["augru"], x, h, att=a_t)
        h2 = jnp.where(m[:, None] > 0, h2, h)
        return h2, None
    h_final, _ = jax.lax.scan(step2, h0, (jnp.moveaxis(states, 1, 0),
                                          jnp.moveaxis(att, 1, 0),
                                          jnp.moveaxis(mask, 1, 0)),
                              unroll=cfg.unroll)

    # ---- head -------------------------------------------------------------
    hist_sum = jnp.sum(hist * mask[..., None], 1) / jnp.maximum(
        jnp.sum(mask, 1, keepdims=True), 1.0)
    feat = jnp.concatenate([h_final, target, hist_sum, user], -1)
    logit = L.mlp(p["head"], feat)[..., 0]
    return logit, cfg.aux_weight * aux


def dien_loss(p, cfg: DIENConfig, batch):
    logit, aux = dien_forward(p, cfg, batch)
    y = batch["label"].astype(jnp.float32)
    bce = -jnp.mean(y * jax.nn.log_sigmoid(logit) +
                    (1 - y) * jax.nn.log_sigmoid(-logit))
    return bce + aux


def retrieval_scores(p, cfg: DIENConfig, batch):
    """retrieval_cand shape: one query state scored against C candidates
    as a batched dot (no loop): score = <W_u·interest, item_emb>."""
    hist = _behavior_embed(p, batch["hist_items"], batch["hist_cats"])
    user_vec = jnp.mean(hist * batch["hist_mask"][..., None], axis=1)  # [B, 2D]
    cand = lookup(p["item"]["table"], batch["cand_items"])             # [C, D]
    u = user_vec[..., : cfg.embed_dim]                                 # [B, D]
    return u @ cand.T                                                  # [B, C]
