"""Mixture-of-Experts FFN: top-k routing with sort-based capacity
dispatch (GShard/Switch-style) + optional shared experts
(DeepSeekMoE/Qwen-MoE/Kimi style).

Dispatch is fixed-shape: token-expert assignments are sorted by expert,
ranked within expert, and scattered into an [n_exp * capacity, E]
buffer; overflow beyond the capacity factor is dropped (standard). The
expert dim is the EP sharding axis; GSPMD turns the scatter/gather into
all-to-all when tokens are batch-sharded and experts model-sharded.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0
    d_shared_ff: int = 0          # 0 -> n_shared * d_expert_ff
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    dispatch_shard: bool = False   # constrain dispatch buffers:
                                   # experts->model, capacity->dp
    ep_pad: int = 0                # pad expert count (e.g. 60->64) so EP
                                   # divides the model axis; padded experts
                                   # get no routed tokens
    combine_impl: str = "gather"   # "scatter": segment-sum combine avoids
                                   # materializing the [T, k, E] gather-back

    @property
    def n_total(self) -> int:
        return max(self.ep_pad, self.n_experts)


_DISPATCH_MESH = [None]      # set by steps.py when dispatch_shard is on


def set_dispatch_mesh(mesh):
    _DISPATCH_MESH[0] = mesh


def _constrain_dispatch(xe):
    """[n_exp, cap, E] dispatch buffer: experts -> model axis, capacity ->
    dp axes. Keeps expert GEMMs expert-parallel and turns the global
    gather into mostly-local traffic + an all-to-all."""
    mesh = _DISPATCH_MESH[0]
    if mesh is None:
        return xe
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    ex = "model" if xe.shape[0] % mesh.shape["model"] == 0 else None
    return jax.lax.with_sharding_constraint(
        xe, NamedSharding(mesh, P(ex, dp, None)))


def init_moe(key, d_model: int, cfg: MoEConfig):
    kr, ke, ks = jax.random.split(key, 3)
    n, f = cfg.n_total, cfg.d_expert_ff
    p = {
        "router": L._dense_init(kr, (d_model, cfg.n_experts)),
        "w_gate": L._dense_init(ke, (n, d_model, f)),
        "w_up": L._dense_init(jax.random.fold_in(ke, 1), (n, d_model, f)),
        "w_down": L._dense_init(jax.random.fold_in(ke, 2), (n, f, d_model)),
    }
    a = {
        "router": ("embed", "experts_router"),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared:
        dsf = cfg.d_shared_ff or cfg.n_shared * cfg.d_expert_ff
        p["shared"], a["shared"] = L.init_swiglu(ks, d_model, dsf)
    return p, a


def moe_ffn(p, cfg: MoEConfig, x, *, dtype=jnp.bfloat16):
    """x: [B, S, E] -> ([B, S, E], aux_loss)."""
    b, s, e = x.shape
    t = b * s
    xf = x.reshape(t, e)
    logits = (xf @ p["router"].astype(dtype)).astype(jnp.float32)  # [T, N]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_v, top_i = jax.lax.top_k(probs, cfg.top_k)                # [T, K]
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    n, k = cfg.n_total, cfg.top_k
    cap = int(cfg.capacity_factor * k * t / cfg.n_experts + 1)

    flat_e = top_i.reshape(-1)                                     # [T*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(t * k, dtype=jnp.int32)
    first = jax.ops.segment_min(idx, sorted_e, num_segments=n)  # n_total segs
    rank = idx - first[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, n * cap)         # drop row
    token_of = order // k

    buf = jnp.zeros((n * cap + 1, e), dtype)
    buf = buf.at[slot].set(xf[token_of].astype(dtype), mode="drop")
    xe = buf[:-1].reshape(n, cap, e)
    if cfg.dispatch_shard:
        xe = _constrain_dispatch(xe)

    g = jnp.einsum(" nce,nef->ncf", xe, p["w_gate"].astype(dtype))
    u = jnp.einsum("nce,nef->ncf", xe, p["w_up"].astype(dtype))
    he = jnp.einsum("ncf,nfe->nce", jax.nn.silu(g) * u,
                    p["w_down"].astype(dtype))
    he_flat = jnp.concatenate([he.reshape(n * cap, e),
                               jnp.zeros((1, e), dtype)], 0)

    if cfg.combine_impl == "scatter":
        # combine by scattering buffer rows to their tokens: no [T, k, E]
        # intermediate — each buffer row knows its token and gate weight
        gate_sorted = gate_v.reshape(-1)[order]                 # [T*K]
        tok_slot = jnp.full((n * cap + 1,), t, jnp.int32).at[slot].set(
            token_of.astype(jnp.int32), mode="drop")
        gate_slot = jnp.zeros((n * cap + 1,), jnp.float32).at[slot].set(
            gate_sorted, mode="drop")
        weighted = he_flat * gate_slot[:, None].astype(dtype)
        y = jax.ops.segment_sum(weighted, tok_slot, num_segments=t + 1)[:t]
    else:
        # gather back: contribution of assignment (t, k) lives at `slot`
        slot_by_assign = jnp.zeros((t * k,), jnp.int32).at[order].set(
            jnp.where(keep, slot, n * cap).astype(jnp.int32))
        contrib = he_flat[slot_by_assign].reshape(t, k, e)
        y = jnp.sum(contrib * gate_v[..., None].astype(dtype), axis=1)

    if cfg.n_shared:
        y = y + L.swiglu(p["shared"], xf.astype(dtype), dtype)

    # Switch-style load-balance auxiliary loss (over REAL experts)
    me = jnp.mean(probs, axis=0)                                   # [N]
    ce = jnp.mean(jax.nn.one_hot(top_i[:, 0], cfg.n_experts), axis=0)
    aux = cfg.router_aux_weight * cfg.n_experts * jnp.sum(me * ce)
    return y.reshape(b, s, e), aux
