"""GQA attention: chunked-causal training/prefill + KV-cache decode.

Memory design: full [S, S] logits at 32k+ context don't fit, so the
training/prefill path scans over query chunks (flash-style outer loop;
the per-chunk [B, H, qc, S] score tile is the bounded working set).
Decode attends one new token against the cache — O(S) per step.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False


def init_attention(key, cfg: AttnConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h, kv, dh, e = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {"wq": L._dense_init(k1, (e, h * dh)),
         "wk": L._dense_init(k2, (e, kv * dh)),
         "wv": L._dense_init(k3, (e, kv * dh)),
         "wo": L._dense_init(k4, (h * dh, e))}
    a = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
         "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((h * dh,)), bk=jnp.zeros((kv * dh,)),
                 bv=jnp.zeros((kv * dh,)))
        a.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    return p, a


def _project_qkv(p, cfg: AttnConfig, x, positions, dtype):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: [B, qc, H, Dh], k: [B, S, KV, Dh] -> [B, H, qc, S] (H = G*KV)."""
    b, qc, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, qc, kv, g, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(dh).astype(q.dtype)
    return s.reshape(b, h, qc, k.shape[1])


def _gqa_combine(w, v):
    """w: [B, H, qc, S], v: [B, S, KV, Dh] -> [B, qc, H, Dh]."""
    b, h, qc, s = w.shape
    kv = v.shape[2]
    g = h // kv
    wg = w.reshape(b, kv, g, qc, s)
    o = jnp.einsum("bkgqs,bskd->bqkgd", wg, v)
    return o.reshape(b, qc, h, v.shape[3])


def causal_attention(p, cfg: AttnConfig, x, *, q_chunk: int = 512,
                     dtype=jnp.bfloat16):
    """Training/prefill attention. x: [B, S, E]. Returns ([B,S,E], kv)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, cfg, x, positions, dtype)
    qc = min(q_chunk, s)
    while s % qc:           # largest chunk <= q_chunk dividing s
        qc -= 1
    nchunks = s // qc

    def chunk_fn(carry, qi):
        q_dyn = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        scores = _gqa_scores(q_dyn, k).astype(jnp.float32)  # [B,H,qc,S]
        qpos = qi * qc + jnp.arange(qc)
        mask = qpos[:, None] >= jnp.arange(s)[None, :]
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(dtype)
        return carry, _gqa_combine(w, v)

    _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(nchunks))
    o = jnp.moveaxis(outs, 0, 1).reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = o @ p["wo"].astype(dtype)
    return y, (k, v)


def decode_attention(p, cfg: AttnConfig, x, cache_k, cache_v, cache_len,
                     *, dtype=jnp.bfloat16):
    """One-token decode. x: [B, 1, E]; cache_[kv]: [B, Smax, KV, Dh];
    cache_len: int32[] tokens already in cache. Returns (y, new_k, new_v)."""
    b, _, _ = x.shape
    positions = jnp.broadcast_to(cache_len[None, None], (b, 1))
    q, k, v = _project_qkv(p, cfg, x, positions, dtype)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), cache_len, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), cache_len, axis=1)
    scores = _gqa_scores(q, cache_k.astype(dtype)).astype(jnp.float32)
    smax = cache_k.shape[1]
    mask = jnp.arange(smax)[None, None, None, :] <= cache_len
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(dtype)
    o = _gqa_combine(w, cache_v.astype(dtype))
    y = o.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["wo"].astype(dtype)
    return y, cache_k, cache_v
