"""Shared neural net layers (pure-jnp, param dicts + logical-axis trees).

Every ``init_*`` returns ``(params, axes)`` where ``axes`` mirrors the
param tree with tuples of *logical* axis names; distributed/sharding.py
maps logical names -> mesh axes per model family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(key, shape, in_axis=-2):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in))


def init_linear(key, d_in, d_out, axes=("embed", "mlp"), bias=False):
    kw, kb = jax.random.split(key)
    p = {"w": _dense_init(kw, (d_in, d_out))}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
        a["b"] = (axes[1],)
    return p, a


def linear(p, x, dtype=None):
    w = p["w"] if dtype is None else p["w"].astype(dtype)
    y = x @ w
    if "b" in p:
        y = y + (p["b"] if dtype is None else p["b"].astype(dtype))
    return y


def init_rmsnorm(d, axis="embed"):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": (axis,)}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


def init_layernorm(d, axis="embed"):
    return ({"scale": jnp.ones((d,), jnp.float32),
             "bias": jnp.zeros((d,), jnp.float32)},
            {"scale": (axis,), "bias": (axis,)})


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def init_swiglu(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_gate": _dense_init(k1, (d_model, d_ff)),
         "w_up": _dense_init(k2, (d_model, d_ff)),
         "w_down": _dense_init(k3, (d_ff, d_model))}
    a = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
         "w_down": ("mlp", "embed")}
    return p, a


def swiglu(p, x, dtype=jnp.bfloat16):
    g = x @ p["w_gate"].astype(dtype)
    u = x @ p["w_up"].astype(dtype)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(dtype)


def init_mlp(key, dims, axes_prefix="mlp", bias=True, final_bias=True):
    """Plain MLP tower (recsys heads, GNN blocks)."""
    keys = jax.random.split(key, len(dims) - 1)
    p, a = {}, {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        use_b = bias if i < len(dims) - 2 else final_bias
        p[f"l{i}"], a[f"l{i}"] = init_linear(
            keys[i], din, dout, axes=(f"{axes_prefix}_in", f"{axes_prefix}_out"),
            bias=use_b)
    return p, a


def mlp(p, x, act=jax.nn.relu, dtype=None):
    n = len(p)
    for i in range(n):
        x = linear(p[f"l{i}"], x, dtype)
        if i < n - 1:
            x = act(x)
    return x


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, Dh]; positions: broadcastable [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                     # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(ang)[..., None, :]                  # [..., S, 1, Dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def softmax_cross_entropy(logits, labels, z_loss: float = 0.0,
                          impl: str = "gather"):
    """logits [..., V] f32; labels int32 [...]. Returns per-token loss.

    impl="gather": take_along_axis — simple, but under vocab (TP)
    sharding GSPMD all-gathers the full logits to serve the gather.
    impl="iota": select the label logit with an elementwise
    iota-compare + sum — partitions cleanly along the sharded vocab dim
    (no all-gather; one scalar psum). Same math.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    if impl == "iota":
        v = logits.shape[-1]
        onehot = labels[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    else:
        ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss
