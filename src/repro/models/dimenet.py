"""DimeNet (directional message passing, arXiv:2003.03123).

Kernel regime: *triplet gather* — messages live on directed edges
(j -> i) and are updated from incoming messages (k -> j) modulated by an
angular basis over the (k, j, i) triplet. Not expressible as SpMM; the
triplet index lists are explicit inputs (host-precomputed for real runs,
ShapeDtypeStruct stand-ins for the dry-run).

Basis functions: radial Bessel-style envelope RBF (n_radial) and a
separable radial x angular SBF (n_spherical x n_radial) using cos(l*θ)
Chebyshev angular modes — structurally faithful to the paper's
bilinear interaction block (n_bilinear down-projection), with the
spherical-Bessel zeros simplified to integer frequencies (documented
deviation; identical compute/memory shape).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.graphs import segment_ops as sops
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_out: int = 1
    envelope_p: int = 6


def rbf_basis(d, cfg: DimeNetConfig):
    """[E] -> [E, n_radial] Bessel RBF with polynomial envelope."""
    x = d / cfg.cutoff
    p = cfg.envelope_p
    env = (1.0 - (p + 1) * (p + 2) / 2 * x ** p + p * (p + 2) * x ** (p + 1)
           - p * (p + 1) / 2 * x ** (p + 2))
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cfg.cutoff) * jnp.sin(
        n[None, :] * jnp.pi * x[:, None]) / jnp.maximum(d[:, None], 1e-9)
    return basis * env[:, None]


def sbf_basis(d, angle, cfg: DimeNetConfig):
    """[T],[T] -> [T, n_spherical * n_radial] separable angular basis."""
    rad = rbf_basis(d, cfg)                                # [T, R]
    l = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    ang = jnp.cos(l[None, :] * angle[:, None])             # [T, S]
    return (ang[:, :, None] * rad[:, None, :]).reshape(
        d.shape[0], cfg.n_spherical * cfg.n_radial)


def init_dimenet(key, cfg: DimeNetConfig):
    h, r, s, b = cfg.d_hidden, cfg.n_radial, cfg.n_spherical, cfg.n_bilinear
    p, a = {}, {}
    k0, k1, k2, key = jax.random.split(key, 4)
    p["emb_atom"] = L._dense_init(k0, (95, h))           # atomic numbers
    a["emb_atom"] = ("gnn_in", "gnn_hidden")
    p["emb_rbf"], a["emb_rbf"] = L.init_linear(k1, r, h)
    p["emb_msg"], a["emb_msg"] = L.init_mlp(k2, [3 * h, h])
    for i in range(cfg.n_blocks):
        ka, kb, kc, kd, ke, key = jax.random.split(key, 6)
        p[f"blk{i}"] = {
            "w_rbf": L.init_linear(ka, r, h)[0],
            "w_sbf": L.init_linear(kb, s * r, b)[0],
            "w_kj": L.init_linear(kc, h, h)[0],
            "w_ji": L.init_linear(kd, h, h)[0],
            "bilinear": jax.random.normal(ke, (b, h, h), jnp.float32) / h,
            "mlp": L.init_mlp(jax.random.fold_in(ke, 1), [h, h, h])[0],
        }
        a[f"blk{i}"] = {
            "w_rbf": {"w": ("rbf", "gnn_hidden")},
            "w_sbf": {"w": ("sbf", "bilinear")},
            "w_kj": {"w": ("gnn_hidden", "gnn_hidden")},
            "w_ji": {"w": ("gnn_hidden", "gnn_hidden")},
            "bilinear": ("bilinear", "gnn_hidden", "gnn_hidden"),
            "mlp": L.init_mlp(jax.random.fold_in(ke, 2), [h, h, h])[1],
        }
        ko, key = jax.random.split(key)
        p[f"out{i}"], a[f"out{i}"] = L.init_mlp(ko, [h, h, cfg.n_out])
    return p, a


def dimenet_forward(p, cfg: DimeNetConfig, z, coords, edge_src, edge_dst,
                    trip_kj, trip_ji):
    """z: int32[n+1] atomic numbers; coords: [n+1, 3].
    edge_*: int32[E] (sentinel n). trip_kj/trip_ji: int32[T] indices into
    the edge list: message (k->j) feeds message (j->i) (sentinel E).
    Returns (node_out [n+1, n_out], messages) — callers pool."""
    n1 = z.shape[0]
    e = edge_src.shape[0]
    act = jax.nn.silu

    diff = coords[edge_src] - coords[edge_dst]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, -1), 1e-12))
    rbf = rbf_basis(dist, cfg)                              # [E, R]

    # triplet angle between edge (k->j) and (j->i)
    d1 = diff[jnp.minimum(trip_kj, e - 1)]
    d2 = -diff[jnp.minimum(trip_ji, e - 1)]
    cosang = jnp.sum(d1 * d2, -1) / jnp.maximum(
        jnp.linalg.norm(d1, axis=-1) * jnp.linalg.norm(d2, axis=-1), 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1 + 1e-7, 1 - 1e-7))
    d_kj = dist[jnp.minimum(trip_kj, e - 1)]
    sbf = sbf_basis(d_kj, angle, cfg)                       # [T, S*R]
    trip_ok = (trip_kj < e) & (trip_ji < e)
    sbf = jnp.where(trip_ok[:, None], sbf, 0.0)

    hz = p["emb_atom"][jnp.minimum(z, 94)]
    m = L.mlp(p["emb_msg"], jnp.concatenate(
        [hz[edge_src], hz[edge_dst], L.linear(p["emb_rbf"], rbf)], -1),
        act=act)                                            # [E, H]

    out = jnp.zeros((n1, cfg.n_out), jnp.float32)
    for i in range(cfg.n_blocks):
        blk = p[f"blk{i}"]
        # directional interaction: m_kj -> (j->i), modulated by sbf
        m_kj = (m @ blk["w_kj"]["w"])[jnp.minimum(trip_kj, e - 1)]  # [T, H]
        sb = sbf @ blk["w_sbf"]["w"]                        # [T, B]
        inter = jnp.einsum("tb,bhg,th->tg", sb, blk["bilinear"], m_kj)
        agg = sops.segment_sum(
            jnp.where(trip_ok[:, None], inter, 0.0),
            jnp.minimum(trip_ji, e), e + 1)[:e]             # [E, H]
        m = act(m @ blk["w_ji"]["w"] + agg * (rbf @ blk["w_rbf"]["w"]))
        m = m + L.mlp(blk["mlp"], m, act=act)
        # per-block output: aggregate messages to atoms
        atom = sops.segment_sum(m, edge_dst, n1)
        out = out + L.mlp(p[f"out{i}"], atom, act=act)
    return out, m


def build_triplets(edge_src, edge_dst, n, t_cap: int):
    """Host helper: triplet indices (k->j, j->i) with k != i.
    Returns (trip_kj, trip_ji) int32[t_cap], sentinel = len(edges)."""
    import numpy as np
    e = len(edge_src)
    by_dst = {}
    for idx in range(e):
        by_dst.setdefault(int(edge_dst[idx]), []).append(idx)
    kj, ji = [], []
    for idx in range(e):
        j = int(edge_src[idx])          # edge (j -> i)
        for kidx in by_dst.get(j, []):
            if int(edge_src[kidx]) != int(edge_dst[idx]):   # k != i
                kj.append(kidx)
                ji.append(idx)
    kj, ji = kj[:t_cap], ji[:t_cap]
    pad = t_cap - len(kj)
    return (np.asarray(kj + [e] * pad, np.int32),
            np.asarray(ji + [e] * pad, np.int32))
