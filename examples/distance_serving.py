"""End-to-end serving driver (the paper's workload): an IS-LABEL
distance-query service with continuous batching, latency percentiles,
and an exactness audit — the serving analogue of 'serve a small model
with batched requests'.

  PYTHONPATH=src python examples/distance_serving.py [n_pow] [n_requests]
"""
import sys
import time

import jax
import numpy as np

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.graphs import generators as gen

n_pow = int(sys.argv[1]) if len(sys.argv) > 1 else 13
n_req = int(sys.argv[2]) if len(sys.argv) > 2 else 8192
BATCH = 512

n, src, dst, w = gen.rmat_graph(n_pow, avg_deg=6.0, seed=3)
print(f"[build] n={n} m={len(src) // 2}")
t0 = time.time()
idx = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=512))
print(f"[build] {time.time() - t0:.1f}s  {idx.stats.summary()}")

# simulated request stream with continuous batching
rng = np.random.default_rng(0)
reqs = rng.integers(0, n, (n_req, 2)).astype(np.int32)
lat, served = [], 0
answers = np.zeros(n_req, np.float32)
t_serve = time.time()
for lo in range(0, n_req, BATCH):
    s_b = reqs[lo:lo + BATCH, 0]
    t_b = reqs[lo:lo + BATCH, 1]
    t1 = time.time()
    d = idx.query(s_b, t_b)
    jax.block_until_ready(d)
    lat.append(time.time() - t1)
    answers[lo:lo + BATCH] = np.asarray(d)
    served += len(s_b)
wall = time.time() - t_serve
print(f"[serve] {served} requests in {wall:.2f}s -> "
      f"{served / wall:.0f} q/s | per-batch p50 {np.median(lat) * 1e3:.1f}ms "
      f"p99 {np.quantile(lat, 0.99) * 1e3:.1f}ms (batch={BATCH})")

# audit a sample against Dijkstra
k = 64
want = ref.dijkstra_oracle(n, src, dst, w, reqs[:k, 0])[np.arange(k),
                                                        reqs[:k, 1]]
fin = np.isfinite(want)
assert (np.isfinite(answers[:k]) == fin).all()
assert np.allclose(answers[:k][fin], want[fin])
print(f"[audit] {k} sampled answers exact vs Dijkstra")

# query-type mix (paper Table 5)
types = idx.query_types(reqs[:, 0], reqs[:, 1])
u, c = np.unique(types, return_counts=True)
print("[mix] endpoint types:", dict(zip(u.tolist(), c.tolist())))

# sharded lane (docs/SHARDING.md): partition the label table over the
# available devices — one pmin collective per batch, answers bitwise
from repro.shard import ShardedIndex

n_shards = min(len(jax.devices()), 4)
sidx = ShardedIndex.from_index(idx, n_shards)
d_sh, _ = sidx.engine.batch_fn()(reqs[:BATCH, 0], reqs[:BATCH, 1])
assert np.array_equal(np.asarray(d_sh), answers[:BATCH])
print(f"[shard] {n_shards} shard(s), "
      f"entries/shard={sidx.shard_entry_counts().tolist()}, "
      f"one batch bitwise-equal to the unsharded index")
if n_shards == 1:
    print("[shard] hint: XLA_FLAGS=--xla_force_host_platform_device_count=4 "
          "simulates 4 devices on CPU")

# path serving (docs/PATHS.md): full shortest-path retrieval at batch
# rates — every served path is edge-validated and its weight sum equals
# the served distance
from repro.paths import check_path_batch, edge_weight_map

p_s, p_t = reqs[:BATCH, 0], reqs[:BATCH, 1]
t2 = time.time()
out = idx.path_engine().path_batch_fn(hop_cap=128)(p_s, p_t)
out = jax.block_until_ready(out)
rep = check_path_batch(edge_weight_map(src, dst, w), p_s, p_t, out)
assert not rep["violations"], rep["violations"][:3]
print(f"[paths] {rep['checked']} shortest paths reconstructed + validated "
      f"in {time.time() - t2:.2f}s ({rep['overflowed']} over hop_cap)")
