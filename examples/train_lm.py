"""Train a ~100M-param dense LM for a few hundred steps on synthetic
data with the full substrate: sharded step, prefetch pipeline, async
checkpoints, fault-tolerant runner.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import shapes as SH
from repro.configs.base import ArchSpec
from repro.data import synthetic
from repro.data.pipeline import PrefetchPipeline
from repro.fault import FaultTolerantRunner, RunnerConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.train import init_state
from repro.models.transformer import LMConfig
from repro.train.steps import build_bundle

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

# ~100M params: 12L x 768d (GPT2-small-ish) with GQA + SwiGLU
cfg = LMConfig("lm100m", n_layers=12, d_model=768, n_heads=12,
               n_kv_heads=4, d_ff=2048, vocab=32768, q_chunk=128)
print(f"params: {cfg.param_count() / 1e6:.1f}M")
spec = ArchSpec(
    arch_id="lm100m", family="lm", model_cfg=cfg,
    shapes={"train": SH.LMShape("train", "train", args.seq, args.batch)})

mesh = make_host_mesh(1)
with mesh:
    bundle = build_bundle(spec, "train", mesh)
    step = bundle.jitted()
    state = init_state(spec, mesh, bundle)

pipe = PrefetchPipeline(
    lambda s: synthetic.lm_batch(0, s, args.batch, args.seq, cfg.vocab),
    depth=2)
runner = FaultTolerantRunner(
    lambda st, b: step(st, b), state, pipe,
    RunnerConfig(ckpt_dir="/tmp/lm100m_ckpt", ckpt_every=100))

hist = []
t0 = time.time()
runner.run(args.steps, on_metrics=lambda s, m: (
    hist.append(float(np.asarray(m["loss"]))),
    print(f"step {s:4d} loss {hist[-1]:.4f} "
          f"({(time.time() - t0) / s:.2f}s/step)") if s % 25 == 0 else None))
pipe.stop()
print(f"final loss {hist[-1]:.4f} (from {hist[0]:.4f}); "
      f"{args.steps} steps in {time.time() - t0:.0f}s")
assert hist[-1] < hist[0], "loss should decrease"
