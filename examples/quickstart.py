"""Quickstart: build an IS-LABEL index, query distances, reconstruct a
path, save + reload.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.graphs import generators as gen

# 1. a weighted undirected graph (power-law, ~4k vertices)
n, src, dst, w = gen.rmat_graph(12, avg_deg=6.0, seed=7)
print(f"graph: {n} vertices, {len(src) // 2} edges")

# 2. build the index (vertex hierarchy -> labels -> core graph)
idx = ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=512))
print("built:", idx.stats.summary())
print("levels:", idx.stats.level_sizes)

# 3. batched exact distance queries
rng = np.random.default_rng(0)
s = rng.integers(0, n, 256).astype(np.int32)
t = rng.integers(0, n, 256).astype(np.int32)
d = idx.query_host(s, t)
print(f"query batch of 256: median distance "
      f"{np.median(d[np.isfinite(d)]):.0f}, "
      f"{np.isinf(d).sum()} disconnected pairs")

# 4. verify against Dijkstra
want = ref.dijkstra_oracle(n, src, dst, w, s[:32])[np.arange(32), t[:32]]
assert np.allclose(np.where(np.isfinite(d[:32]), d[:32], -1),
                   np.where(np.isfinite(want), want, -1))
print("exactness verified on 32 queries")

# 5. an actual shortest path (paper §8.1)
qi = int(np.flatnonzero(np.isfinite(d))[0])
dist, path = idx.shortest_path(int(s[qi]), int(t[qi]))
print(f"path {s[qi]} -> {t[qi]} (len {dist:.0f}): {path}")

# 6. persistence
idx.save("/tmp/quickstart_index")
idx2 = ISLabelIndex.load("/tmp/quickstart_index")
assert np.allclose(idx2.query_host(s[:8], t[:8]), d[:8])
print("save/load roundtrip ok")
