"""EGNN molecular-property regression on batched synthetic molecules —
the GNN-family example (segment-ops message passing + equivariant
coordinate updates).

  PYTHONPATH=src python examples/gnn_molecules.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.graphs import segment_ops as sops
from repro.models.gnn import EGNNConfig, egnn_forward, init_egnn
from repro.optim import adamw

cfg = EGNNConfig("egnn-mol", n_layers=4, d_hidden=64, d_in=16, n_out=1)
params = init_egnn(jax.random.PRNGKey(0), cfg)[0]
opt = adamw(lr=1e-3)
opt_state = opt.init(params)

B, ATOMS, EDGES = 32, 12, 24
N_PAD, E_PAD = B * ATOMS + 16, 2 * B * EDGES + 16


def loss_fn(p, batch):
    node_out, _ = egnn_forward(p, cfg, batch["feats"], batch["coords"],
                               batch["edge_src"], batch["edge_dst"])
    pooled = sops.segment_sum(node_out[..., 0], batch["graph_ids"],
                              B + 1)[:B]
    # synthetic target: molecule radius (equivariance-meaningful)
    return jnp.mean(jnp.square(pooled - batch["targets"]))


@jax.jit
def train_step(p, st, step, batch):
    loss, g = jax.value_and_grad(loss_fn)(p, batch)
    p, st, _ = opt.update(g, st, p, step)
    return p, st, loss


t0 = time.time()
losses = []
step_ct = jnp.int32(0)
for i in range(200):
    b = synthetic.molecule_batch(i, B, ATOMS, EDGES, 16, N_PAD, E_PAD)
    # physical target = mean squared atom distance from centroid
    coords = b["coords"][:B * ATOMS].reshape(B, ATOMS, 3)
    b["targets"] = np.mean(np.sum(
        (coords - coords.mean(1, keepdims=True)) ** 2, -1), 1).astype(
        np.float32)
    batch = {k: jnp.asarray(v) for k, v in b.items()
             if k in ("feats", "coords", "edge_src", "edge_dst",
                      "graph_ids", "targets")}
    params, opt_state, loss = train_step(params, opt_state, step_ct + i,
                                         batch)
    losses.append(float(loss))
    if i % 40 == 0:
        print(f"step {i:3d} mse {losses[-1]:.4f}")
print(f"final mse {np.mean(losses[-10:]):.4f} (from {losses[0]:.4f}) "
      f"in {time.time() - t0:.0f}s")
assert np.mean(losses[-10:]) < losses[0]
