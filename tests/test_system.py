"""End-to-end behaviour tests for the paper's system: build -> serve ->
maintain, mirroring the paper's workflow (Table 3 build, Table 4 query
serving, §8 maintenance) at CPU scale."""
import numpy as np

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.graphs import generators as gen


def test_end_to_end_paper_workflow(tmp_path):
    # 1. build (Table 3 regime: power-law graph)
    n, src, dst, w = gen.rmat_graph(10, avg_deg=6.0, seed=42)
    cfg = IndexConfig(sigma=0.95, l_cap=512, label_chunk=512)
    idx = ISLabelIndex.build(n, src, dst, w, cfg)
    st = idx.stats
    assert st.k >= 2 and st.n_core < n
    assert st.label_entries > 0
    # the hierarchy shrank the graph (the point of the paper)
    assert st.graph_sizes[-1] < st.graph_sizes[0]

    # 2. serve a 1000-query batch (Table 4 regime), validate vs oracle
    r = np.random.default_rng(0)
    s = r.integers(0, n, 1000).astype(np.int32)
    t = r.integers(0, n, 1000).astype(np.int32)
    got = idx.query_host(s, t)
    want = ref.dijkstra_oracle(n, src, dst, w, s[:100])[
        np.arange(100), t[:100]]
    fin = np.isfinite(want)
    assert (np.isfinite(got[:100]) == fin).all()
    np.testing.assert_allclose(got[:100][fin], want[fin], rtol=1e-5)

    # 3. type breakdown exists (Table 5 regime)
    types = idx.query_types(s, t)
    assert len(types) == 1000

    # 4. persist + reload serves identically
    idx.save(tmp_path / "ix")
    idx2 = ISLabelIndex.load(tmp_path / "ix")
    np.testing.assert_allclose(idx2.query_host(s[:50], t[:50]), got[:50])

    # 5. maintenance: attach an isolated vertex and query through it
    deg = np.zeros(n, np.int64)
    np.add.at(deg, src, 1)
    isolated = np.flatnonzero(deg == 0)
    if len(isolated):
        u = int(isolated[0])
        v0 = int(s[0])
        idx2.insert_vertex(u, [v0], [2.0])
        d = float(idx2.query_host([u], [u])[0])
        assert d == 0.0
        d2 = float(idx2.query_host([u], [v0])[0])
        assert abs(d2 - 2.0) < 1e-5


def test_serving_engine_batch_sizes():
    """Query engine handles varying batch sizes and returns consistent
    answers across batch splits."""
    n, src, dst, w = gen.er_graph(500, 3.0, seed=9)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=256, label_chunk=256))
    r = np.random.default_rng(1)
    s = r.integers(0, n, 64).astype(np.int32)
    t = r.integers(0, n, 64).astype(np.int32)
    full = idx.query_host(s, t)
    for bs in (1, 7, 32):
        part = idx.query_host(s[:bs], t[:bs])
        np.testing.assert_allclose(part, full[:bs])


def test_build_determinism():
    n, src, dst, w = gen.er_graph(200, 3.0, seed=3)
    cfg = IndexConfig(l_cap=256, label_chunk=128, seed=5)
    a = ISLabelIndex.build(n, src, dst, w, cfg)
    b = ISLabelIndex.build(n, src, dst, w, cfg)
    assert a.k == b.k
    np.testing.assert_array_equal(a.level, b.level)
    np.testing.assert_array_equal(np.asarray(a.lbl_ids),
                                  np.asarray(b.lbl_ids))
