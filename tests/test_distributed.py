"""Distribution tests that need >1 device: run in subprocesses with
``--xla_force_host_platform_device_count=8`` (tests themselves must see
the real 1-CPU world, per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n_dev: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_mesh_shapes():
    out = run_with_devices("""
        import jax
        from repro.launch.mesh import make_production_mesh, make_host_mesh
        m = make_host_mesh(2)
        assert m.shape == {"data": 4, "model": 2}, m.shape
        print("ok", m.axis_names)
    """)
    assert "ok" in out


def test_small_dryrun_cell_on_8_devices():
    """End-to-end: lower+compile a tiny LM train step on a 4x2 mesh with
    the production sharding rules, assert collectives appear."""
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import registry
        from repro.launch.train import smoke_spec
        from repro.launch.mesh import make_host_mesh
        from repro.train.steps import build_bundle
        from repro.launch.analysis import collective_bytes
        spec = smoke_spec(registry.get_spec("granite-8b"))
        mesh = make_host_mesh(2)
        with mesh:
            b = build_bundle(spec, "train_4k", mesh)
            compiled = b.lower().compile()
        coll = collective_bytes(compiled.as_text())
        assert coll["total"] > 0, coll
        from repro.launch.analysis import cost_dict
        cost = cost_dict(compiled)
        assert cost.get("flops", 0) > 0
        print("ok", coll)
    """)
    assert "ok" in out


def test_real_sharded_train_step_runs():
    """Actually execute a sharded train step on 8 devices and check the
    loss decreases (data+model parallel numerics are right)."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.configs import registry
        from repro.launch.train import smoke_spec, init_state, make_batch_fn
        from repro.launch.mesh import make_host_mesh
        from repro.train.steps import build_bundle
        spec = smoke_spec(registry.get_spec("qwen2-moe-a2.7b"))
        mesh = make_host_mesh(2)
        with mesh:
            bundle = build_bundle(spec, "train_4k", mesh,
                                  overrides={"warmup": 1})
            step = bundle.jitted()
            state = init_state(spec, mesh, bundle)
            batch = make_batch_fn(spec, "train_4k")(0)
            losses = []
            for i in range(8):
                state, m = step(state, batch)
                losses.append(float(np.asarray(m["loss"])))
        assert losses[-1] < losses[0], losses
        print("ok", [round(x, 3) for x in losses])
    """)
    assert "ok" in out


def test_sharded_matches_single_device():
    """Same seed, same batch: 8-way sharded step == 1-device step."""
    code_tpl = """
        import jax, numpy as np
        from repro.configs import registry
        from repro.launch.train import smoke_spec, init_state, make_batch_fn
        from repro.launch.mesh import make_host_mesh
        from repro.train.steps import build_bundle
        spec = smoke_spec(registry.get_spec("granite-8b"))
        mesh = make_host_mesh({mp})
        with mesh:
            bundle = build_bundle(spec, "train_4k", mesh)
            step = bundle.jitted()
            state = init_state(spec, mesh, bundle)
            batch = make_batch_fn(spec, "train_4k")(0)
            state, m = step(state, batch)
        print("LOSS", float(np.asarray(m["loss"])))
    """
    l8 = run_with_devices(code_tpl.format(mp=2), n_dev=8)
    l1 = run_with_devices(code_tpl.format(mp=1), n_dev=1)
    v8 = float(l8.split("LOSS")[1])
    v1 = float(l1.split("LOSS")[1])
    assert abs(v8 - v1) < 5e-2, (v8, v1)


def test_compressed_crosspod_reduction():
    """int8 error-feedback cross-pod psum ≈ fp32 mean within quant error,
    and the error-feedback state absorbs the residual."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import (compressed_psum_pod,
                                                   init_error_feedback)
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        g_global = rng.standard_normal((2, 64)).astype(np.float32)

        def f(gs, es):
            return compressed_psum_pod({"g": gs}, {"g": es}, mesh)

        fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P(), P("pod")), check_rep=False)
        out, new_err = fn(jnp.asarray(g_global),
                          jnp.zeros_like(jnp.asarray(g_global)))
        want = g_global.mean(0)
        got = np.asarray(out["g"])[0]
        scale = np.abs(g_global).max() / 127
        assert np.abs(got - want).max() < scale, (got[:4], want[:4])
        # 4x fewer cross-pod bytes than fp32 ring allreduce at P=2
        print("ok maxerr", float(np.abs(got - want).max()))
    """)
    assert "ok" in out


def test_elastic_restore_across_meshes():
    """Elastic restart: checkpoint written under a (4,2) mesh restores
    onto a (2,4) mesh with resharded state and identical values."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        d = tempfile.mkdtemp()
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        state = {"w": jax.device_put(
                     jnp.arange(32.0).reshape(8, 4),
                     NamedSharding(mesh_a, P("data", "model"))),
                 "step": jnp.int32(7)}
        save_checkpoint(d, 7, state)
        # new topology: swap axis sizes
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        sh = {"w": NamedSharding(mesh_b, P("data", "model")),
              "step": NamedSharding(mesh_b, P())}
        got, step = restore_checkpoint(d, state, shardings=sh)
        assert step == 7
        assert got["w"].sharding.mesh.shape == {"data": 2, "model": 4}
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(32.0).reshape(8, 4))
        print("ok")
    """)
    assert "ok" in out


def test_islabel_query_sharded_matches_local():
    """The paper's query engine under the production sharding returns the
    same distances as the single-device engine."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ISLabelIndex, IndexConfig
        from repro.graphs import generators as gen
        n, src, dst, w = gen.er_graph(400, 3.0, seed=5)
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=128, label_chunk=128))
        r = np.random.default_rng(0)
        s = r.integers(0, n, 64).astype(np.int32)
        t = r.integers(0, n, 64).astype(np.int32)
        want = np.asarray(idx.query(s, t))
        # shard the label table + queries across 8 devices
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        from jax.sharding import NamedSharding, PartitionSpec as P
        with mesh:
            lbl_ids = jax.device_put(idx.lbl_ids,
                                     NamedSharding(mesh, P(None, None)))
            sq = jax.device_put(jnp.asarray(s), NamedSharding(mesh, P("data")))
            tq = jax.device_put(jnp.asarray(t), NamedSharding(mesh, P("data")))
            got = np.asarray(idx.engine.query(sq, tq))
        fin = np.isfinite(want)
        assert (np.isfinite(got) == fin).all()
        np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)
        print("ok")
    """)
    assert "ok" in out
