"""Kernel dispatch layer: backend resolution, Pallas-vs-reference parity
on random graphs, and end-to-end regression of QueryEngine answers
against the core/ref.py Dijkstra oracle across backends and chunking."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.core.dispatch import CoreRelaxer, core_relax
from repro.graphs import generators as gen
from repro.kernels.backend import ENV_VAR, resolve_backend

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def small_index():
    n, src, dst, w = gen.er_graph(260, 3.0, seed=11)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=128, label_chunk=64))
    assert idx.stats.n_core > 0          # stage 2 must actually run
    s = RNG.integers(0, n, 96).astype(np.int32)
    t = RNG.integers(0, n, 96).astype(np.int32)
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(96), t]
    return idx, s, t, want


def _assert_same(got, want, rtol=0.0):
    got, want = np.asarray(got), np.asarray(want)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    if rtol:
        np.testing.assert_allclose(got[fin], want[fin], rtol=rtol)
    else:
        np.testing.assert_array_equal(got[fin], want[fin].astype(np.float32))


# ------------------------------------------------------------ resolution
def test_resolve_backend_explicit():
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("interpret") == "interpret"
    assert resolve_backend("reference") == "reference"
    with pytest.raises(ValueError):
        resolve_backend("cuda")


def test_resolve_backend_env(monkeypatch):
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert resolve_backend(None) == "interpret"
    assert resolve_backend("auto") == "interpret"
    # explicit request still beats the env override
    assert resolve_backend("reference") == "reference"
    monkeypatch.delenv(ENV_VAR)
    assert resolve_backend(None) in ("pallas", "reference")


# ------------------------------------------------- stage-wise parity
def test_mu_backend_parity(small_index):
    idx, s, t, _ = small_index
    mu_ref = idx.engine.query_mu_only(s, t, backend="reference")
    mu_ker = idx.engine.query_mu_only(s, t, backend="interpret")
    assert np.array_equal(np.asarray(mu_ref), np.asarray(mu_ker))


def test_core_relaxer_matches_reference_relax(small_index):
    """CoreRelaxer kernel path == legacy COO core_relax on real seeds."""
    idx, s, t, _ = small_index
    eng = idx.engine
    ids_s, d_s = eng.lbl_ids[jnp.asarray(s)], eng.lbl_d[jnp.asarray(s)]
    ids_t, d_t = eng.lbl_ids[jnp.asarray(t)], eng.lbl_d[jnp.asarray(t)]
    seed_s, seed_t = eng._seed(ids_s, d_s), eng._seed(ids_t, d_t)
    mu = eng.query_mu_only(s, t, backend="reference")
    a_ref, ds_r, dt_r, r_ref = core_relax(
        seed_s, seed_t, eng.ce_src, eng.ce_dst, eng.ce_w, mu,
        eng.n_core, eng.max_rounds)
    a_ker, ds_k, dt_k, r_ker = eng.relaxer.run(
        seed_s, seed_t, mu, eng.max_rounds, backend="interpret")
    assert int(r_ref) == int(r_ker)
    for a, b in ((a_ref, a_ker), (ds_r, ds_k), (dt_r, dt_k)):
        a, b = np.asarray(a), np.asarray(b)
        fin = np.isfinite(a)
        assert (np.isfinite(b) == fin).all()
        np.testing.assert_array_equal(a[fin], b[fin])


def test_relaxer_on_random_graphs():
    """Pallas interpret vs jnp reference relaxation on raw random cores."""
    for seed in (0, 3):
        r = np.random.default_rng(seed)
        v, e, q = 97, 400, 13
        ce_s = jnp.asarray(r.integers(0, v, e).astype(np.int32))
        ce_d = jnp.asarray(r.integers(0, v, e).astype(np.int32))
        ce_w = jnp.asarray(r.integers(1, 5, e).astype(np.float32))
        relaxer = CoreRelaxer(ce_s, ce_d, ce_w, v)
        seed_s = np.full((q, v + 1), np.inf, np.float32)
        seed_t = np.full((q, v + 1), np.inf, np.float32)
        seed_s[np.arange(q), r.integers(0, v, q)] = 0.0
        seed_t[np.arange(q), r.integers(0, v, q)] = 0.0
        mu = jnp.full((q,), jnp.inf, jnp.float32)
        a_ref, *_ = relaxer.run(jnp.asarray(seed_s), jnp.asarray(seed_t),
                                mu, v, backend="reference")
        a_ker, *_ = relaxer.run(jnp.asarray(seed_s), jnp.asarray(seed_t),
                                mu, v, backend="interpret")
        _assert_same(np.asarray(a_ker), np.asarray(a_ref))


# ------------------------------------- end-to-end regression vs Dijkstra
@pytest.mark.parametrize("backend", ["reference", "interpret"])
def test_query_matches_dijkstra(small_index, backend):
    idx, s, t, want = small_index
    got = idx.engine.query(s, t, backend=backend)
    _assert_same(got, want, rtol=1e-5)


@pytest.mark.parametrize("backend", ["reference", "interpret"])
def test_chunked_equals_unchunked(small_index, backend):
    idx, s, t, _ = small_index
    full = np.asarray(idx.engine.query(s, t, backend=backend))
    # 96 queries, chunk 37 -> two full chunks + padded tail
    chunked = np.asarray(idx.engine.query(s, t, backend=backend,
                                          query_chunk=37))
    assert np.array_equal(np.nan_to_num(full, posinf=-1.0),
                          np.nan_to_num(chunked, posinf=-1.0))


def test_config_chunk_and_backend_plumbed():
    """query_backend/query_chunk reach the engine through IndexConfig and
    survive save/load."""
    n, src, dst, w = gen.er_graph(140, 3.0, seed=4)
    cfg = IndexConfig(l_cap=128, label_chunk=64, query_backend="reference",
                      query_chunk=19)
    idx = ISLabelIndex.build(n, src, dst, w, cfg)
    assert idx.engine.backend == "reference"
    assert idx.engine.query_chunk == 19
    s = RNG.integers(0, n, 50).astype(np.int32)
    t = RNG.integers(0, n, 50).astype(np.int32)
    got = np.asarray(idx.query(s, t))
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(50), t]
    _assert_same(got, want, rtol=1e-5)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        idx.save(d)
        idx2 = ISLabelIndex.load(d)
        assert idx2.engine.query_chunk == 19
        assert np.array_equal(np.nan_to_num(np.asarray(idx2.query(s, t)),
                                            posinf=-1.0),
                              np.nan_to_num(got, posinf=-1.0))


# ----------------------------------------------- kernel-route selection
def _random_core(v, e, seed=0):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.integers(0, v, e).astype(np.int32)),
            jnp.asarray(r.integers(0, v, e).astype(np.int32)),
            jnp.asarray(r.integers(1, 5, e).astype(np.float32)))


def test_dispatch_density_routing():
    """Route selection: density >= threshold with a small core picks the
    minplus dense route; sparse cores pick the fused kernel; the VMEM
    budget and the fused kill-switch both fall back to the launch loop."""
    v = 100
    dense_edges = _random_core(v, int(0.1 * v * v))
    sparse_edges = _random_core(v, 2 * v, seed=1)
    assert CoreRelaxer(*dense_edges, v).mode == "dense"
    assert CoreRelaxer(*sparse_edges, v).mode == "fused"
    # threshold raised above the actual density -> no dense route
    assert CoreRelaxer(*dense_edges, v,
                       dense_threshold=0.5).mode == "fused"
    # core too big for the dense route even when dense enough
    assert CoreRelaxer(*dense_edges, v, dense_cap=50).mode == "fused"
    # fused kill-switch -> legacy per-round loop
    assert CoreRelaxer(*sparse_edges, v, fused=False,
                       dense_threshold=2.0).mode == "ell_loop"
    # fused working set over the VMEM budget -> loop fallback
    assert CoreRelaxer(*sparse_edges, v, dense_threshold=2.0,
                       vmem_budget=1).mode == "ell_loop"


def test_dispatch_env_overrides(monkeypatch):
    v = 100
    dense_edges = _random_core(v, int(0.1 * v * v))
    monkeypatch.setenv("ISLABEL_FUSED_RELAX", "0")
    monkeypatch.setenv("ISLABEL_DENSE_THRESHOLD", "0.5")
    assert CoreRelaxer(*dense_edges, v).mode == "ell_loop"
    monkeypatch.delenv("ISLABEL_DENSE_THRESHOLD")
    monkeypatch.delenv("ISLABEL_FUSED_RELAX")
    assert CoreRelaxer(*dense_edges, v).mode == "dense"


@pytest.mark.parametrize("force", ["dense", "fused", "ell_loop"])
def test_all_kernel_routes_bitwise_equal_reference(force):
    """Every kernel route (dense minplus GEMM, fused all-rounds kernel,
    per-round launch loop) == the COO reference bitwise, with the same
    round count."""
    v, e, q = 120, 1450, 9           # density ~0.1: dense-eligible
    edges = _random_core(v, e, seed=2)
    kw = {"dense": dict(),
          "fused": dict(dense_threshold=2.0),
          "ell_loop": dict(dense_threshold=2.0, fused=False)}[force]
    relaxer = CoreRelaxer(*edges, v, **kw)
    assert relaxer.mode == force
    r = np.random.default_rng(3)
    seed_s = np.full((q, v + 1), np.inf, np.float32)
    seed_t = np.full((q, v + 1), np.inf, np.float32)
    seed_s[np.arange(q), r.integers(0, v, q)] = 0.0
    seed_t[np.arange(q), r.integers(0, v, q)] = 0.0
    seed_s[q - 1, :] = np.inf            # empty frontier row
    mu = jnp.full((q,), jnp.inf, jnp.float32)
    a_ref, ds_r, dt_r, r_ref = relaxer.run(
        jnp.asarray(seed_s), jnp.asarray(seed_t), mu, v,
        backend="reference")
    a_k, ds_k, dt_k, r_k = relaxer.run(
        jnp.asarray(seed_s), jnp.asarray(seed_t), mu, v,
        backend="interpret")
    assert int(r_ref) == int(r_k)
    for a, b in ((a_ref, a_k), (ds_r, ds_k), (dt_r, dt_k)):
        _assert_same(b, np.asarray(a))
    assert np.isinf(np.asarray(ds_k)[q - 1]).all()
