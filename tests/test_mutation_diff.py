"""Differential exactness harness for §8.3 live mutation
(docs/MUTATION.md): randomized interleaved insert/delete/query
sequences through the versioned copy-on-write lane, each epoch checked
**bitwise** against an ``ISLabelIndex.build`` from scratch over the
mutated edge set — distances on both kernel backends, reconstructed
paths (valid in the mutated graph, weight-sum == distance), and the
sharded lane at shard counts {1, 4} (P=4 under forced host devices,
per the dry-run isolation rule).

The deterministic sweep replays >= 200 mutation steps per config;
hypothesis (optional, requirements-dev) layers randomized short
sequences on top via the same generator.

Weights are integer-valued float32 so path sums are exact and bitwise
equality is a fair demand.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import ISLabelIndex, IndexConfig
from repro.graphs import generators as gen
from repro.serve import MutationOp, VersionManager

N_BASE, SPARES = 140, 16
N = N_BASE + SPARES
CFG = IndexConfig(l_cap=256, label_chunk=128)
EPOCHS, OPS_PER_EPOCH, Q = 8, 25, 96
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _base_graph():
    return gen.er_graph(N_BASE, 2.4, seed=5)


def _op_schedule(rng, core_ids, spares, epochs, ops_per_epoch):
    """Interleaved strict-domain §8.3 ops: inserts attach only to the
    initial core + currently-live inserted spares; deletes target only
    live inserted spares (the rebuild-exact domain)."""
    pool, live = list(spares), []
    core_ids = [int(c) for c in core_ids]
    sched = []
    for _ in range(epochs):
        ops = []
        for _ in range(ops_per_epoch):
            if pool and (not live or rng.random() < 0.55):
                u = pool.pop(int(rng.integers(len(pool))))
                cands = core_ids + live
                deg = int(rng.integers(1, min(3, len(cands)) + 1))
                picks = rng.choice(len(cands), size=deg, replace=False)
                ops.append(MutationOp(
                    "insert", u, tuple(cands[j] for j in picks),
                    tuple(float(x) for x in rng.integers(1, 9, deg))))
                live.append(u)
            else:
                u = live.pop(int(rng.integers(len(live))))
                ops.append(MutationOp("delete", u))
                pool.append(u)
        sched.append(ops)
    return sched


def _mirror_edges(src, dst, w, flat_ops):
    """Host mirror of the mutated undirected edge set."""
    es = [int(x) for x in src] + [int(x) for x in dst]
    ed = [int(x) for x in dst] + [int(x) for x in src]
    ew = [float(x) for x in w] * 2
    for op in flat_ops:
        if op.kind == "insert":
            for v, wt in zip(op.nbrs, op.ws):
                es += [op.u, int(v)]
                ed += [int(v), op.u]
                ew += [float(wt), float(wt)]
        else:
            keep = [i for i in range(len(es))
                    if es[i] != op.u and ed[i] != op.u]
            es = [es[i] for i in keep]
            ed = [ed[i] for i in keep]
            ew = [ew[i] for i in keep]
    return (np.asarray(es, np.int32), np.asarray(ed, np.int32),
            np.asarray(ew, np.float32))


@pytest.fixture(scope="module")
def sweep():
    """Run the full deterministic sweep once: apply each epoch through
    the version manager AND rebuild from scratch, recording everything
    the per-backend / path / sharded assertions need."""
    nb, src, dst, w = _base_graph()
    idx = ISLabelIndex.build(N, src, dst, w, CFG)
    mgr = VersionManager.from_index(idx)
    rng = np.random.default_rng(11)
    sched = _op_schedule(rng, idx.core_ids, range(N_BASE, N),
                         EPOCHS, OPS_PER_EPOCH)
    assert sum(len(ops) for ops in sched) >= 200

    records, flat, live = [], [], set()
    for ops in sched:
        version = mgr.apply(ops)
        flat += list(ops)
        for op in ops:
            (live.add if op.kind == "insert" else live.discard)(op.u)
        es, ed, ew = _mirror_edges(src, dst, w, flat)
        scratch = ISLabelIndex.build(N, es, ed, ew, CFG)
        ids = np.concatenate([np.arange(N_BASE),
                              np.asarray(sorted(live))]).astype(np.int32)
        qs = ids[rng.integers(0, len(ids), Q)]
        qt = ids[rng.integers(0, len(ids), Q)]
        want = np.asarray(scratch.engine.query(qs, qt), np.float32)
        records.append({"ops": ops, "version": version, "qs": qs,
                        "qt": qt, "want": want, "scratch": scratch,
                        "edges": (es, ed, ew), "live": sorted(live)})
    return {"idx": idx, "mgr": mgr, "graph": (src, dst, w),
            "sched": sched, "records": records}


# ------------------------------------------------- distances, per backend
@pytest.mark.parametrize("backend", ["reference", "interpret"])
def test_versioned_distances_bitwise_vs_scratch(sweep, backend):
    fn = sweep["mgr"].family.full_fn(backend)
    for i, rec in enumerate(sweep["records"]):
        ans, _ = fn(rec["version"].state, rec["qs"], rec["qt"])
        ans = np.asarray(ans, np.float32)
        assert np.array_equal(ans, rec["want"]), \
            f"epoch {i} ({backend}): versioned != scratch rebuild"


def test_host_oracle_matches_scratch(sweep):
    """The mutated host index (the audit oracle) agrees bitwise too."""
    for i, rec in enumerate(sweep["records"]):
        got = np.asarray(rec["version"].index.query(rec["qs"], rec["qt"]),
                         np.float32)
        assert np.array_equal(got, rec["want"]), f"epoch {i}: host oracle"


# ------------------------------------------------------------------ paths
def _edge_weight_map(es, ed, ew):
    m: dict = {}
    for a, b, x in zip(es.tolist(), ed.tolist(), ew.tolist()):
        key = (a, b)
        if key not in m or x < m[key]:
            m[key] = x
    return m


def _check_paths(engine, qs, qt, want, emap, tag):
    dist, paths, ok = engine.paths(qs, qt)
    dist = np.asarray(dist, np.float32)
    assert np.array_equal(dist, want), f"{tag}: path-lane distances"
    assert np.asarray(ok).all(), f"{tag}: reconstruction overflowed hop_cap"
    for j in range(len(qs)):
        p = paths[j]
        if not np.isfinite(want[j]):
            assert p == [], f"{tag}: unreachable pair got a path"
            continue
        assert p[0] == qs[j] and p[-1] == qt[j], f"{tag}: endpoints"
        total = np.float32(0.0)
        for a, b in zip(p, p[1:]):
            assert (a, b) in emap, f"{tag}: edge ({a},{b}) not in graph"
            total = np.float32(total + np.float32(emap[(a, b)]))
        assert total == want[j], f"{tag}: weight sum != distance"


@pytest.mark.parametrize("epoch", [0, EPOCHS // 2, EPOCHS - 1])
def test_paths_valid_and_equal_vs_scratch(sweep, epoch):
    from repro.paths import PathEngine
    rec = sweep["records"][epoch]
    qs, qt = rec["qs"][:20], rec["qt"][:20]
    want = rec["want"][:20]
    emap = _edge_weight_map(*rec["edges"])
    _check_paths(PathEngine.from_index(rec["version"].index), qs, qt,
                 want, emap, f"epoch {epoch} mutated")
    _check_paths(PathEngine.from_index(rec["scratch"]), qs, qt,
                 want, emap, f"epoch {epoch} scratch")


# ---------------------------------------------------------------- sharded
def test_sharded_p1_matches_scratch(sweep):
    from repro.shard import ShardedIndex
    sidx = ShardedIndex.from_index(sweep["idx"], 1)
    for i, rec in enumerate(sweep["records"]):
        sidx, info = sidx.apply_mutations(rec["ops"])
        got = np.asarray(sidx.query(rec["qs"], rec["qt"]), np.float32)
        assert np.array_equal(got, rec["want"]), f"epoch {i}: sharded P=1"
    assert sorted(info) == ["inserted", "touched_rows", "touched_shards"]


def test_sharded_p4_matches_scratch(sweep, tmp_path):
    """Same sweep at P=4 in a subprocess with 4 forced host devices."""
    np.savez(tmp_path / "q.npz",
             qs=np.stack([r["qs"] for r in sweep["records"]]),
             qt=np.stack([r["qt"] for r in sweep["records"]]),
             want=np.stack([r["want"] for r in sweep["records"]]))
    (tmp_path / "sched.json").write_text(json.dumps(
        [[[op.kind, int(op.u), [int(v) for v in op.nbrs],
           [float(x) for x in op.ws]] for op in ops]
         for ops in sweep["sched"]]))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent(f"""
        import json
        import numpy as np
        from repro.core import ISLabelIndex, IndexConfig
        from repro.graphs import generators as gen
        from repro.serve import MutationOp
        from repro.shard import ShardedIndex

        nb, src, dst, w = gen.er_graph({N_BASE}, 2.4, seed=5)
        idx = ISLabelIndex.build({N}, src, dst, w,
                                 IndexConfig(l_cap=256, label_chunk=128))
        sidx = ShardedIndex.from_index(idx, 4)
        data = np.load({str(tmp_path / 'q.npz')!r})
        sched = json.loads(open({str(tmp_path / 'sched.json')!r}).read())
        for i, ops in enumerate(sched):
            ops = [MutationOp(k, u, tuple(nb_), tuple(ws))
                   for k, u, nb_, ws in ops]
            sidx, _ = sidx.apply_mutations(ops)
            got = np.asarray(sidx.query(data['qs'][i], data['qt'][i]),
                             np.float32)
            assert np.array_equal(got, data['want'][i]), f"epoch {{i}}"
        print("P4-OK", len(sched))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert f"P4-OK {EPOCHS}" in r.stdout


# ------------------------------------------------------- strict domain
def test_strict_mode_rejects_out_of_domain_ops(sweep):
    mgr = sweep["mgr"]
    leaf = int(np.flatnonzero(
        np.asarray(sweep["idx"].level[:N_BASE]) < sweep["idx"].k)[0])
    with pytest.raises(ValueError, match="non-core"):
        mgr.apply([MutationOp("insert", N_BASE, (leaf,), (1.0,))])
    with pytest.raises(ValueError, match="build-time"):
        mgr.apply([MutationOp("delete", leaf)])
    # failed batches leave the manager untouched
    assert mgr.current is sweep["records"][-1]["version"]


def test_delete_then_reinsert_restores_bitwise(sweep):
    """Id reuse: delete a live spare whose (last) insertion attached
    only to the initial core, then replay that exact insertion — every
    answer returns to the pre-delete version's, bitwise. (Spares whose
    attachments were themselves deleted later can't round-trip this
    way: those edges are legitimately gone from the final state.)"""
    mgr = sweep["mgr"]
    rec = sweep["records"][-1]
    ins = {op.u: op for ops in sweep["sched"] for op in ops
           if op.kind == "insert"}           # last insertion per id
    core = {int(c) for c in sweep["idx"].core_ids}
    cands = [u for u in rec["live"]
             if all(int(v) in core for v in ins[u].nbrs)]
    if not cands:
        pytest.skip("no purely core-attached live spare in this schedule")
    u = cands[0]
    v_del = mgr.apply([MutationOp("delete", u)])
    v_re = mgr.apply([ins[u]])
    fn = mgr.family.full_fn("reference")
    before, _ = fn(rec["version"].state, rec["qs"], rec["qt"])
    after, _ = fn(v_re.state, rec["qs"], rec["qt"])
    assert np.array_equal(np.asarray(before), np.asarray(after))
    assert v_del.vid < v_re.vid == mgr.current.vid


# ------------------------------------------------- hypothesis (optional)
if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(5, 30))
    def test_random_sequences_bitwise_vs_scratch(sweep, seed, n_ops):
        idx = sweep["idx"]
        src, dst, w = sweep["graph"]
        rng = np.random.default_rng(seed)
        [ops] = _op_schedule(rng, idx.core_ids, range(N_BASE, N), 1, n_ops)
        mgr = VersionManager.from_index(idx)
        version = mgr.apply(ops)
        es, ed, ew = _mirror_edges(src, dst, w, ops)
        scratch = ISLabelIndex.build(N, es, ed, ew, CFG)
        live = sorted({op.u for op in ops if op.kind == "insert"}
                      - {op.u for op in ops if op.kind == "delete"})
        ids = np.concatenate([np.arange(N_BASE),
                              np.asarray(live, np.int64)]).astype(np.int32)
        qs = ids[rng.integers(0, len(ids), 64)]
        qt = ids[rng.integers(0, len(ids), 64)]
        want = np.asarray(scratch.engine.query(qs, qt), np.float32)
        ans, _ = mgr.family.full_fn("reference")(version.state, qs, qt)
        assert np.array_equal(np.asarray(ans, np.float32), want)
        mgr.retire(version)
