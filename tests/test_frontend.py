"""Tier-1 tests for the HTTP front end (``repro.serve.frontend``):
bitwise-exact answers over the wire (single, batched, and through the
versioned mutation lane), HTTP error mapping, the ``/metrics``
Prometheus exposition round-tripped through a strict text-format
parser, ``/stats`` with the SLO block, and SSE framing — metrics
frames on change, heartbeat comments when idle, live ``slo_alert``
relay from the ``EventLog``.

One real front end runs for the whole module on a background loop
thread (port 0 → ephemeral), over a versioned ``DistanceServer`` so
the mutation lane is exercised end to end.
"""
from __future__ import annotations

import http.client
import json
import re
import time

import numpy as np
import pytest

from repro.core import IndexConfig, ISLabelIndex
from repro.graphs import generators as gen
from repro.obs import REGISTRY, EventLog, SLOEngine, default_serving_slos
from repro.serve import (HttpClient, IndexRegistry, MutationOp,
                         ServiceFrontend, SSEReader)

# ---------------------------------------------------------- prometheus
_PROM_LINE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"            # metric name
    r"(?:\{((?:[a-zA-Z_][a-zA-Z0-9_]*=\"(?:[^\"\\\n]|\\.)*\",?)*)\})?"
    r" (\S+)$")
_PROM_LABEL = re.compile(r"([a-zA-Z_][a-zA-Z0-9_]*)=\"((?:[^\"\\\n]|\\.)*)\"")


def parse_prometheus(text: str):
    """Strict parse of the text exposition format (0.0.4): returns
    ``(types, samples)`` where ``samples[(name, labelitems)] -> float``.
    Raises on any line that is not a comment, blank, or a well-formed
    sample — the round-trip gate for ``render_prometheus``."""
    types: dict[str, str] = {}
    samples: dict[tuple, float] = {}
    for line in text.splitlines():
        if not line or line.startswith("# HELP"):
            continue
        if line.startswith("# TYPE"):
            _, _, name, kind = line.split(None, 3)
            assert kind in ("counter", "gauge", "histogram"), kind
            types[name] = kind
            continue
        m = _PROM_LINE.match(line)
        assert m, f"malformed exposition line: {line!r}"
        name, raw_labels, value = m.groups()
        labels = tuple(sorted(
            (k, v.encode().decode("unicode_escape"))
            for k, v in _PROM_LABEL.findall(raw_labels or "")))
        key = (name, labels)
        assert key not in samples, f"duplicate sample {key}"
        samples[key] = float(value)
    return types, samples


# -------------------------------------------------------------- fixture
@pytest.fixture(scope="module")
def stack():
    with REGISTRY.isolated():
        n, src, dst, w = gen.er_graph(120, 2.4, seed=5)
        idx = ISLabelIndex.build(n + 6, src, dst, w,
                                 IndexConfig(l_cap=96, label_chunk=64))
        registry = IndexRegistry()
        registry.register("default", idx, buckets=(8, 32),
                          max_wait_ms=1.0, versioned=True)
        log = EventLog()
        slo = SLOEngine(
            default_serving_slos(latency_threshold_s=1.0,
                                 fast_window_s=2.0, slow_window_s=8.0,
                                 resolve_hold_s=1.0),
            log=log)
        fe = ServiceFrontend(registry, slo=slo, log=log,
                             sse_interval_s=0.05, heartbeat_s=0.3)
        host, port = fe.start_background()
        yield {"fe": fe, "host": host, "port": port, "idx": idx,
               "log": log, "slo": slo}
        fe.stop()


@pytest.fixture()
def client(stack):
    with HttpClient(stack["host"], stack["port"]) as c:
        yield c


def _far_pair(idx, min_d=2.0, max_d=9.0):
    """A core pair whose distance a unit bridge provably shortens."""
    core = np.asarray(idx.core_ids, np.int32)
    aa, bb = np.meshgrid(core, core, indexing="ij")
    d = np.asarray(idx.query(aa.ravel(), bb.ravel()), np.float32)
    j = np.flatnonzero((d > min_d) & (d < max_d))
    assert len(j), "no bridgeable pair in fixture graph"
    return int(aa.ravel()[j[0]]), int(bb.ravel()[j[0]]), d[j[0]]


# ----------------------------------------------------------- endpoints
def test_healthz_and_unknown_route(stack, client):
    out = client.healthz()
    assert out["ok"] is True and out["uptime_s"] >= 0.0
    with pytest.raises(RuntimeError, match="404"):
        client._call("GET", "/nope")


def test_query_single_and_batch_are_bitwise_exact(stack, client):
    idx = stack["idx"]
    r = np.random.default_rng(7)
    core = np.asarray(idx.core_ids, np.int32)
    s = r.choice(core, 24)
    t = r.choice(core, 24)
    want = np.asarray(idx.query(s, t), np.float32)
    got_one = np.asarray([client.query(int(a), int(b))[0]
                          for a, b in zip(s, t)], np.float32)
    got_batch = client.query_batch(list(zip(s.tolist(), t.tolist())))
    fin = np.isfinite(want)
    for got in (got_one, got_batch):
        assert got.dtype == np.float32
        assert (np.isfinite(got) == fin).all()
        np.testing.assert_array_equal(got[fin], want[fin])


def test_bad_requests_map_to_http_errors(stack, client):
    with pytest.raises(RuntimeError, match="400"):
        client._call("POST", "/query", {"s": 1})          # missing "t"
    with pytest.raises(RuntimeError, match="404"):
        client._call("POST", "/query", {"graph": "nope", "s": 0, "t": 1})
    with pytest.raises(RuntimeError, match="400"):
        client._call("POST", "/mutate", {"ops": []})
    with pytest.raises(RuntimeError, match="400"):        # versioned: no
        client._call("POST", "/path", {"s": 0, "t": 1})   # path lane
    conn = http.client.HTTPConnection(stack["host"], stack["port"],
                                      timeout=10)
    conn.request("POST", "/query", body=b"{not json",
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    assert resp.status == 400
    assert "bad JSON" in json.loads(resp.read())["error"]
    conn.close()


def test_mutate_advances_version_and_reads_observe_it(stack, client):
    idx = stack["idx"]
    a, b, d_old = _far_pair(idx)
    u = idx.n - 1                                  # last spare, not core
    ans0, vid0 = client.query(a, b)
    assert ans0 == d_old
    vid1 = client.mutate([MutationOp("insert", u, (a, b), (1.0, 1.0))])
    assert vid1 == vid0 + 1
    ans1, vid_now = client.query(a, b)
    assert vid_now == vid1
    assert ans1 == np.float32(2.0) and ans1 != ans0    # bridge took
    vid2 = client.mutate([MutationOp("delete", u)])
    ans2, _ = client.query(a, b)
    assert vid2 == vid1 + 1 and ans2 == d_old


def test_stats_exposes_graphs_and_slo_block(stack, client):
    out = client.stats()
    assert out["uptime_s"] > 0.0
    assert "default" in out["graphs"]
    assert set(out["slo"]) == {"availability", "latency", "exactness",
                               "read_compiles"}
    assert out["slo_breaches"]["fired"] == []


def test_metrics_round_trips_through_prometheus_parser(stack, client):
    text = client.metrics_text()
    types, samples = parse_prometheus(text)
    assert types["http_requests"] == "counter"
    assert types["serve_latency_seconds"] == "histogram"
    # the /query traffic from earlier tests is on the books
    total = sum(v for (name, labels), v in samples.items()
                if name == "http_requests"
                and dict(labels).get("route") == "/query")
    assert total > 0
    # histogram invariants: cumulative buckets end at _count
    buckets = sorted(
        ((dict(labels)["le"], v) for (name, labels), v in samples.items()
         if name == "serve_latency_seconds_bucket"),
        key=lambda kv: float("inf") if kv[0] == "+Inf" else float(kv[0]))
    assert buckets and buckets[-1][0] == "+Inf"
    counts = [v for _, v in buckets]
    assert counts == sorted(counts)
    count = sum(v for (name, _), v in samples.items()
                if name == "serve_latency_seconds_count")
    assert counts[-1] == count > 0


# ------------------------------------------------------------------ SSE
def test_sse_emits_metrics_frames_then_heartbeats(stack, client):
    reader = SSEReader(stack["host"], stack["port"], timeout_s=10.0)
    try:
        client.query(0, 1)                 # perturb the metrics frame
        events = reader.read_events(max_events=8, max_s=5.0)
        frames = [d for e, d in events if e == "metrics"]
        assert frames, f"no metrics frame in {events}"
        g = frames[0]["graphs"]["default"]
        assert g["served"] > 0 and "batches" in g and "cache_hits" in g
        assert "slo" in frames[0] and "ts" in frames[0]
        # idle stream: heartbeat comments keep the connection alive
        more = reader.read_events(max_events=24, max_s=3.0)
        assert ("comment", None) in more
    finally:
        reader.close()


def test_sse_relays_slo_alerts_live(stack, client):
    fe, slo = stack["fe"], stack["slo"]
    reader = SSEReader(stack["host"], stack["port"], timeout_s=10.0)
    try:
        # inject exactness failures on the loop thread (it owns the
        # engine) — burn saturates and the next slo step fires
        fe._loop.call_soon_threadsafe(
            lambda: slo.record("exactness", fe._now(), bad=5))
        deadline = time.monotonic() + 8.0
        alerts = []
        while not alerts and time.monotonic() < deadline:
            alerts = [d for e, d in reader.read_events(max_events=8,
                                                       max_s=2.0)
                      if e == "slo_alert"]
        assert alerts, "no slo_alert frame arrived over /events"
        assert alerts[0]["slo"] == "exactness"
        assert alerts[0]["state"] == "fire"
        assert "exactness" in slo.breach_summary()["fired"]
    finally:
        reader.close()
