"""Per-architecture smoke tests (deliverable f): instantiate a REDUCED
config of each assigned arch's family and run one forward/train step on
CPU, asserting output shapes and finiteness. Full configs are exercised
only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs import shapes as SH
from repro.data import synthetic
from repro.launch.train import make_batch_fn, smoke_spec
from repro.train.steps import build_bundle, make_optimizer

ARCHS = registry.ASSIGNED


def _host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    spec = smoke_spec(registry.get_spec(arch))
    shape_name = next(iter(spec.shapes))
    mesh = _host_mesh()
    with mesh:
        bundle = build_bundle(spec, shape_name, mesh)
        step = bundle.jitted()
        from repro.launch.train import init_state
        state = init_state(spec, mesh, bundle)
        batch = make_batch_fn(spec, shape_name)(0)
        new_state, metrics = step(state, batch)
    loss = float(np.asarray(metrics["loss"]))
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert int(np.asarray(new_state["step"])) == 1
    # params actually changed
    p0 = jax.tree.leaves(state["params"] if "params" not in dir(state)
                         else state["params"])
    # state donated — compare a fresh init against updated
    assert np.isfinite(float(np.asarray(metrics["gnorm"])))


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2-moe-a2.7b"])
def test_smoke_lm_serving(arch):
    """Reduced-config prefill + decode agree with teacher-forced forward."""
    from repro.models.transformer import (decode_step, forward, init_lm,
                                          prefill)
    spec = smoke_spec(registry.get_spec(arch))
    cfg = spec.model_cfg
    params = init_lm(jax.random.PRNGKey(0), cfg)[0]
    toks = np.random.default_rng(0).integers(0, cfg.vocab,
                                             (2, 12)).astype(np.int32)
    logits_f, _ = forward(params, cfg, jnp.asarray(toks))
    logits_p, cache = prefill(params, cfg, jnp.asarray(toks), 16)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(logits_f[:, -1]), rtol=5e-2,
                               atol=5e-2)
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, cache = decode_step(params, cfg, cache, nxt)
    assert np.isfinite(np.asarray(logits_d)).all()
    assert int(cache["len"]) == 13


def test_smoke_loss_decreases_lm():
    """A few steps of real training on the tiny LM reduce the loss."""
    spec = smoke_spec(registry.get_spec("granite-8b"))
    mesh = _host_mesh()
    with mesh:
        bundle = build_bundle(spec, "train_4k", mesh,
                              overrides={"warmup": 1})
        step = bundle.jitted()
        from repro.launch.train import init_state
        state = init_state(spec, mesh, bundle)
    mk = make_batch_fn(spec, "train_4k")
    batch = mk(0)        # overfit one batch
    losses = []
    for i in range(8):
        state, m = step(state, batch)
        losses.append(float(np.asarray(m["loss"])))
    assert losses[-1] < losses[0], losses


def test_smoke_retrieval_shapes():
    spec = smoke_spec(registry.get_spec("dien"))
    spec = dataclasses.replace(
        spec, shapes={"retrieval_cand": SH.RecShape("retrieval_cand",
                                                    "retrieval", 1, 512)})
    mesh = _host_mesh()
    with mesh:
        bundle = build_bundle(spec, "retrieval_cand", mesh)
        from repro.models.dien import init_dien
        params = init_dien(jax.random.PRNGKey(0), spec.model_cfg)[0]
        cfg = spec.model_cfg
        r = np.random.default_rng(0)
        batch = {"user": r.integers(0, 10, 1).astype(np.int32),
                 "hist_items": r.integers(0, 100, (1, cfg.seq_len)).astype(np.int32),
                 "hist_cats": r.integers(0, 10, (1, cfg.seq_len)).astype(np.int32),
                 "hist_mask": np.ones((1, cfg.seq_len), np.float32),
                 "target_item": r.integers(0, 100, 1).astype(np.int32),
                 "target_cat": r.integers(0, 10, 1).astype(np.int32),
                 "cand_items": r.integers(0, 100, 512).astype(np.int32)}
        scores = bundle.jitted()(params, batch)
    assert scores.shape == (1, 512)
    assert np.isfinite(np.asarray(scores)).all()


def test_smoke_sage_minibatch_blocks():
    """GraphSAGE with the real neighbor sampler (blocks formulation)."""
    from repro.graphs import generators as gen
    from repro.graphs.sampler import HostCSR, sample_blocks
    from repro.models.gnn import SAGEConfig, init_sage, sage_forward_blocks
    n, src, dst, w = gen.er_graph(300, 5.0, seed=3)
    csr = HostCSR.from_coo(n, src, dst)
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, n, 32).astype(np.int32)
    blocks = sample_blocks(csr, seeds, [3, 2], rng)
    cfg = SAGEConfig("s", 2, 16, 8, 4, fanouts=(3, 2))
    params = init_sage(jax.random.PRNGKey(0), cfg)[0]
    feats = rng.standard_normal((n, 8)).astype(np.float32)
    outer = blocks[0].src_ids
    x = np.zeros((len(outer), 8), np.float32)
    x[outer >= 0] = feats[outer[outer >= 0]]
    blk_args = []
    for b in blocks:
        lut = {int(g): i for i, g in enumerate(b.src_ids) if g >= 0}
        map_dst = np.asarray([lut.get(int(g), b.n_src_cap)
                              for g in b.dst_ids], np.int32)
        blk_args.append({"edge_src": jnp.asarray(b.edge_src),
                         "edge_dst": jnp.asarray(b.edge_dst),
                         "map_dst": jnp.asarray(map_dst),
                         "n_dst": b.n_dst_cap})
    out = sage_forward_blocks(params, cfg, jnp.asarray(x), blk_args)
    assert out.shape == (32, 4)
    assert np.isfinite(np.asarray(out)).all()
