"""Tier-1 tests for ``repro.obs``: the metric registry (bucket
boundaries, exact-numpy percentiles, labeled series, kind conflicts),
span tracing (nesting/ordering invariants, request coverage, Chrome
trace-event export), the compile-event watcher (region attribution and
the zero-recompile guarantee across version swaps), the bench-regression
gate, and the fault-metrics wiring into ``DistanceServer.stats()``.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IndexConfig, ISLabelIndex
from repro.graphs import generators as gen
from repro.obs import (NULL_TRACER, REGISTRY, CompileWatcher, EventLog,
                       MetricRegistry, Tracer, compile_region,
                       write_chrome_trace, write_metrics)
from repro.obs.regression import (Regression, compare_dirs, compare_docs,
                                  extract_metrics)
from repro.serve import DistanceServer, make_trace
from repro.serve.metrics import KNOWN_LANES, ServeMetrics


# ----------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def index():
    """Small ER graph with 6 preallocated spare ids (mutation lane)."""
    n, src, dst, w = gen.er_graph(140, 2.4, seed=3)
    return ISLabelIndex.build(n + 6, src, dst, w,
                              IndexConfig(l_cap=128, label_chunk=64))


# ----------------------------------------------------------- registry
def test_counter_labeled_series_total_and_monotonic():
    reg = MetricRegistry()
    c = reg.counter("t.requests", "help text")
    c.inc(2, lane="mu")
    c.inc(3, lane="full")
    c.inc(1, lane="mu")
    assert c.value(lane="mu") == 3 and c.value(lane="full") == 3
    assert c.total() == 6
    # label order never creates a second series
    c.inc(1, lane="mu")
    assert c.value(lane="mu") == 4
    assert len(c.labels_seen()) == 2
    with pytest.raises(ValueError):
        c.inc(-1, lane="mu")


def test_gauge_set_and_inc():
    reg = MetricRegistry()
    g = reg.gauge("t.depth")
    g.set(5.0, q="a")
    g.inc(2.0, q="a")
    g.set(1.0, q="b")
    assert g.value(q="a") == 7.0 and g.value(q="b") == 1.0


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricRegistry()
    a = reg.counter("t.x")
    assert reg.counter("t.x") is a          # idempotent
    with pytest.raises(ValueError):
        reg.gauge("t.x")                    # same name, different kind
    with pytest.raises(ValueError):
        reg.histogram("t.x")


def test_registry_section_folds_labels():
    reg = MetricRegistry()
    reg.counter("f.events").inc(2, kind="rollback")
    reg.gauge("f.ema").set(0.5)
    reg.histogram("f.lat").observe(1.0)     # histograms excluded
    reg.counter("other.c").inc(1)           # prefix excluded
    sec = reg.section("f.")
    assert sec == {"f.events{kind=rollback}": 2.0, "f.ema": 0.5}


# ---------------------------------------------------------- histogram
def test_histogram_bucket_boundaries_are_inclusive_upper():
    reg = MetricRegistry()
    h = reg.histogram("t.h", buckets=(1.0, 2.0, 4.0), raw_cap=0)
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 5.0):
        h.observe(v)
    snap = h.snapshot()["series"][0]
    # v lands in the first bucket with v <= bound (searchsorted "left")
    assert snap["buckets"] == {"1.0": 2, "2.0": 2, "4.0": 1}
    assert snap["overflow"] == 1
    assert snap["count"] == 6 and snap["sum"] == pytest.approx(14.0)


def test_histogram_percentiles_match_numpy_exactly():
    reg = MetricRegistry()
    h = reg.histogram("t.lat", buckets=(0.25, 0.5, 1.0, 2.0))
    rng = np.random.default_rng(0)
    vals = rng.exponential(0.4, size=257)
    for v in vals:
        h.observe(v, server="s")
    for q in (0.0, 0.1, 0.5, 0.95, 0.99, 1.0):
        assert h.quantile(q, server="s") == pytest.approx(
            float(np.quantile(vals, q)), abs=0.0)
    assert h.mean(server="s") == pytest.approx(float(vals.mean()))
    assert h.max(server="s") == pytest.approx(float(vals.max()))
    assert h.count(server="s") == 257


def test_histogram_raw_overflow_falls_back_to_buckets():
    reg = MetricRegistry()
    h = reg.histogram("t.small", buckets=(1.0, 2.0, 8.0), raw_cap=8)
    vals = [0.5] * 6 + [1.5] * 6 + [3.0] * 4
    for v in vals:
        h.observe(v)
    assert h.values() == []                 # raw dropped past the cap
    assert h.count() == len(vals)
    # bucket interpolation stays inside the surrounding bucket bounds
    p50 = h.quantile(0.5)
    assert 1.0 <= p50 <= 2.0
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)
    assert h.max() == 8.0                   # top non-empty bucket bound


def test_histogram_rejects_bad_buckets():
    reg = MetricRegistry()
    with pytest.raises(ValueError):
        reg.histogram("t.b1", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        reg.histogram("t.b2", buckets=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("t.b3", buckets=())


# -------------------------------------------------------------- spans
def test_span_nesting_ids_and_ordering_invariants():
    tr = Tracer("t")
    req = tr.start("request", 1.0, cat="request", trace_id=7)
    wait = tr.start("queue_wait", 1.0, cat="wait", parent=req)
    tr.end(wait, 1.5)
    ex = tr.add("device_exec", 1.5, 2.0, cat="exec", parent=req)
    tr.end(req, 2.0, lane="mu")
    assert [c.name for c in tr.children(req)] == ["queue_wait",
                                                  "device_exec"]
    assert wait.parent_id == req.span_id and ex.parent_id == req.span_id
    assert req.trace_id == 7 and req.duration == pytest.approx(1.0)
    assert req.args["lane"] == "mu"
    assert len({s.span_id for s in tr.spans}) == 3   # ids unique
    with pytest.raises(ValueError):
        tr.end(req, 3.0)                   # double end
    bad = tr.start("x", 5.0)
    with pytest.raises(ValueError):
        tr.end(bad, 4.0)                   # ends before it starts
    assert bad.open and bad not in tr.finished()


def test_request_coverage_math():
    tr = Tracer()
    full = tr.start("request", 0.0, cat="request")
    tr.add("queue_wait", 0.0, 0.75, parent=full)
    tr.add("device_exec", 0.75, 1.0, parent=full)
    tr.end(full, 1.0)
    half = tr.start("request", 2.0, cat="request")
    tr.add("queue_wait", 2.0, 2.5, parent=half)
    tr.end(half, 3.0)
    cov = tr.request_coverage()
    assert cov["requests"] == 2
    assert cov["min"] == pytest.approx(0.5)
    assert cov["mean"] == pytest.approx(0.75)


def test_chrome_export_is_well_formed():
    tr = Tracer("proc-name")
    s = tr.start("request", 0.010, cat="request", trace_id=3,
                 track="lane:mu")
    tr.add("device_exec", 0.010, 0.0115, parent=s, track="lane:mu")
    tr.end(s, 0.0115)
    tr.event("cache_hit", 0.02, cat="request", trace_id=4,
             track="lane:cache")
    doc = json.loads(json.dumps(tr.chrome()))   # JSON round-trip
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} <= {"X", "i", "M"}
    xs = [e for e in evs if e["ph"] == "X"]
    req = next(e for e in xs if e["name"] == "request")
    assert req["ts"] == pytest.approx(10_000.0)       # µs
    assert req["dur"] == pytest.approx(1_500.0)
    assert req["args"]["trace_id"] == 3
    child = next(e for e in xs if e["name"] == "device_exec")
    assert child["args"]["parent_id"] == req["args"]["span_id"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {e["name"]: e["args"]["name"] for e in meta
             if e["name"] == "process_name"}
    assert names["process_name"] == "proc-name"
    threads = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert {"lane:mu", "lane:cache"} <= threads
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["name"] == "cache_hit" and inst["s"] == "t"


def test_chrome_trace_file_roundtrip(tmp_path):
    tr = Tracer()
    tr.add("request", 0.0, 0.001, cat="request")
    p = write_chrome_trace(tmp_path / "sub" / "trace.json", tr)
    doc = json.loads(p.read_text())
    assert any(e.get("name") == "request" for e in doc["traceEvents"])


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    s = NULL_TRACER.start("x", 1.0)
    NULL_TRACER.end(s, 2.0)
    NULL_TRACER.add("y", 0.0, 1.0)
    NULL_TRACER.event("z", 0.0)
    assert NULL_TRACER.spans == [] and NULL_TRACER.events == []


# ----------------------------------------------------------- eventlog
def test_event_log_roundtrip_and_ring(tmp_path):
    path = tmp_path / "events.jsonl"
    with EventLog(path, keep=2) as log:
        log.log("start", ts=1.0, mode="mutate")
        log.log("swap", ts=2.0, vid=1)
        log.log("finish", ts=3.0)
        assert [e["kind"] for e in log.recent] == ["swap", "finish"]
    back = EventLog.read(path)
    assert [e["kind"] for e in back] == ["start", "swap", "finish"]
    assert [e["seq"] for e in back] == [0, 1, 2]
    assert back[0]["mode"] == "mutate" and back[1]["vid"] == 1


def test_write_metrics_snapshot(tmp_path):
    reg = MetricRegistry()
    reg.counter("w.c").inc(4, lane="mu")
    p = write_metrics(tmp_path / "m.json", reg, run="t")
    doc = json.loads(p.read_text())
    assert doc["run"] == "t"
    series = doc["metrics"]["w.c"]["series"]
    assert series == [{"labels": {"lane": "mu"}, "value": 4.0}]


# ------------------------------------------------------- serve metrics
def test_serve_metrics_lane_set_derives_from_observed_batches():
    m = ServeMetrics(server="lane-t")
    assert set(m.snapshot()["lanes"]) == set(KNOWN_LANES)  # idle default
    m.record_batch("mu", 8, 8, 1e-4, rounds=0)
    m.record_batch("aux", 16, 12, 2e-4, rounds=3)          # novel lane
    lanes = m.snapshot()["lanes"]
    assert set(lanes) == set(KNOWN_LANES) | {"aux"}
    assert lanes["aux"]["requests"] == 12
    assert lanes["aux"]["fill_ratio"] == pytest.approx(0.75)
    assert lanes["path"]["batches"] == 0                   # idle stays


def test_serve_metrics_instances_do_not_alias():
    a = ServeMetrics(server="same-name")
    b = ServeMetrics(server="same-name")   # same server label, new sid
    a.record_cache_hit()
    a.record_batch("mu", 8, 5, 1e-4, rounds=0)
    assert a.served == 6 and a.cache_hits == 1
    assert b.served == 0 and b.cache_hits == 0
    assert b.snapshot()["qps_compute"] == 0.0


# ----------------------------------------------------- regression gate
def _bench_doc(qps=1000.0, p99=2.0, hit=0.5, us=100.0, lane_mu=90):
    return {
        "rows": [{"name": "uniform-b32", "us_per_call": us},
                 {"name": "tiny", "us_per_call": 3.0}],   # under floor
        "results": [{
            "scenario": "uniform", "buckets": [32],
            "qps_compute": qps, "latency_ms": {"p99": p99},
            "cache_hit_rate": hit, "batch_fill_ratio": 0.8,
            "lanes": {"mu": {"requests": lane_mu},
                      "path": {"requests": 0}},            # idle: skipped
        }],
    }


def test_extract_metrics_kinds_and_floors():
    m = extract_metrics(_bench_doc())
    assert m["row:uniform-b32:us_per_call"].kind == "timing"
    assert "row:tiny:us_per_call" not in m        # noise floor
    assert m["cell:uniform-b32:qps_compute"].higher_better
    assert m["cell:uniform-b32:cache_hit_rate"].kind == "behavior"
    assert "cell:uniform-b32:lane_path_requests" not in m  # zero lane


def test_compare_docs_pass_fail_and_missing():
    base = _bench_doc()
    assert compare_docs("serving", base, _bench_doc()) == []
    regs = compare_docs("serving", base,
                        _bench_doc(qps=400.0, hit=0.2, us=300.0))
    names = {r.metric: r for r in regs}
    assert names["cell:uniform-b32:qps_compute"].kind == "timing"
    assert names["cell:uniform-b32:cache_hit_rate"].kind == "behavior"
    assert names["row:uniform-b32:us_per_call"].ratio == pytest.approx(3.0)
    # behavior drift beyond 5% trips even when timing tolerance is loose
    regs = compare_docs("serving", base, _bench_doc(hit=0.46),
                        timing_tolerance=10.0)
    assert [r.metric for r in regs] == ["cell:uniform-b32:cache_hit_rate"]
    # a metric that vanished from the fresh run is a regression
    fresh = _bench_doc()
    del fresh["results"][0]["cache_hit_rate"]
    regs = compare_docs("serving", base, fresh)
    assert [(r.metric, r.fresh) for r in regs] == \
        [("cell:uniform-b32:cache_hit_rate", None)]
    assert "missing" in regs[0].describe()


def test_compare_dirs_requires_named_tables(tmp_path):
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    (basedir / "BENCH_serving.json").write_text(json.dumps(_bench_doc()))
    # fresh run missing entirely: skipped without --tables...
    regs, compared, skipped = compare_dirs(basedir, freshdir)
    assert not regs and compared == [] and skipped == ["serving"]
    # ...but a required table missing is a coverage regression
    regs, _, _ = compare_dirs(basedir, freshdir, tables=["serving"])
    assert len(regs) == 1 and regs[0].kind == "coverage"
    (freshdir / "BENCH_serving.json").write_text(json.dumps(_bench_doc()))
    regs, compared, _ = compare_dirs(basedir, freshdir, tables=["serving"])
    assert not regs and compared == ["serving"]


# ------------------------------------------------------ compile watcher
def test_compile_watcher_attributes_regions():
    with CompileWatcher() as w:
        if not w.supported:
            pytest.skip("jax.monitoring listeners unavailable")
        before = w.count("obs-test-zone")

        def f(x):
            return x * 2 + 1

        jf = jax.jit(f)
        with compile_region("obs-test-zone"):
            jf(jnp.arange(7)).block_until_ready()
        first = w.count("obs-test-zone") - before
        assert first >= 1                      # cold call compiled
        with compile_region("obs-test-zone"):
            jf(jnp.arange(7)).block_until_ready()
        assert w.count("obs-test-zone") - before == first  # cached: no new
    # stopped watcher is inert
    with compile_region("obs-test-zone"):
        jax.jit(lambda x: x - 3)(jnp.arange(5)).block_until_ready()
    assert w.count("obs-test-zone") - before == first


def test_zero_serve_read_compiles_across_version_swaps(index):
    """The exported zero-recompile guarantee: a readwrite replay with
    live version swaps never counts a backend compile in region
    ``serve_read`` (eager mutation scatters may compile — they land in
    region ``mutation``, never on the read path)."""
    with CompileWatcher() as w:
        if not w.supported:
            pytest.skip("jax.monitoring listeners unavailable")
        read0 = w.count("serve_read")
        srv = DistanceServer(index, versioned=True, buckets=(8, 32),
                             max_wait_ms=1.0, cache_size=1024)
        srv.warmup()
        warm = w.count("warmup")
        nb = index.n - 6
        tr = make_trace("readwrite", n=index.n, num_requests=240,
                        rate_qps=5e4, seed=1, write_ratio=0.05,
                        n_read=nb, spares=range(nb, index.n),
                        attach_to=index.core_ids)
        ans, vids = srv.serve_readwrite_trace(tr)
        assert srv.metrics.mutations == tr.meta["writes"] > 0
        assert vids.max() == tr.meta["writes"]     # swaps really happened
        assert w.count("serve_read") - read0 == 0  # the guarantee
        assert warm > 0                            # warmup was attributed
        srv.drain()


# ------------------------------------------------- engine tracer wiring
def test_traced_serve_full_request_coverage(index, tmp_path):
    tracer = Tracer("test-serve")
    srv = DistanceServer(index, buckets=(8, 32), max_wait_ms=1.0,
                         cache_size=1024, tracer=tracer)
    tr = make_trace("repeated", n=index.n, num_requests=150, pool=40,
                    seed=2, rate_qps=2e4)
    got = srv.serve_trace(tr)
    want = np.asarray(index.query(np.asarray(tr.s), np.asarray(tr.t)),
                      np.float32)
    assert np.array_equal(got.astype(np.float32), want)
    snap = srv.stats()
    reqs = tracer.by_name("request")
    # every device-path request has a span; cache hits are instants
    assert len(reqs) == snap["served"] - snap["cache_hits"]
    hits = [e for e in tracer.events if e["name"] == "cache_hit"]
    assert len(hits) == snap["cache_hits"] > 0
    cov = tracer.request_coverage()
    assert cov["requests"] == len(reqs)
    assert cov["min"] >= 0.99                  # acceptance bound
    # span duration is exactly the recorded latency for that request
    by_rid = {s.trace_id: s for s in reqs}
    assert len(by_rid) == len(reqs)
    # the export opens: well-formed JSON with the expected tracks
    doc = json.loads(tracer.write_chrome(tmp_path / "t.json").read_text())
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e.get("name") == "thread_name"}
    assert any(t.startswith("lane:") for t in tracks)


# ------------------------------------------------- fault registry wiring
def test_fault_events_surface_in_registry_and_server_stats(index,
                                                           tmp_path):
    from repro.fault import (FaultTolerantRunner, HostTimingAggregator,
                             RunnerConfig)
    ev = REGISTRY.counter("fault.events")
    fail_before = ev.value(kind="step_failure")
    rb_before = ev.value(kind="rollback")
    steps_before = REGISTRY.counter("fault.steps").total()

    fail_plan = {2: 1}                        # step 2 raises once

    def make_batch(step):
        return float(step + 1)

    def step_fn(state, batch):
        step = int(batch) - 1
        if fail_plan.get(step, 0) > 0:
            fail_plan[step] -= 1
            raise RuntimeError("injected")
        return ({"x": state["x"] + batch}, {"loss": np.float32(1.0)})

    cfg = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                       handle_sigterm=False)
    runner = FaultTolerantRunner(step_fn, {"x": np.float64(0.0)},
                                 make_batch, cfg)
    runner.run(4)
    assert ev.value(kind="step_failure") - fail_before == 1
    assert ev.value(kind="rollback") - rb_before == 1
    assert REGISTRY.counter("fault.steps").total() - steps_before >= 4

    agg = HostTimingAggregator(threshold=1.3)
    for _ in range(4):
        # two fast hosts pin the fleet median at 1.0; h1 is persistently
        # 1.4x slower (below its own flag threshold, so the slowness
        # folds into its EMA rather than being discarded as a spike)
        agg.record("h0", 1.0), agg.record("h2", 1.0)
        agg.record("h1", 1.4)
    agg.record("h1", 10.0)                    # spike: flagged, not folded
    assert agg.stragglers() == ["h1"]
    assert REGISTRY.counter("fault.straggler_flags").value(host="h1") >= 1
    assert REGISTRY.gauge("fault.fleet_stragglers").value() == 1.0

    # ...and the serving stack surfaces the same section in stats()
    srv = DistanceServer(index, buckets=(8,), max_wait_ms=1.0)
    fault = srv.stats()["fault"]
    assert any(k.startswith("fault.events") for k in fault)
    assert any(k.startswith("fault.step_seconds_ema") for k in fault)


# ---------------------------------------------- registry: new surfaces
def test_histogram_count_le_exact_then_bucketed():
    reg = MetricRegistry()
    h = reg.histogram("t.le", buckets=(1.0, 2.0, 8.0), raw_cap=8)
    for v in (0.5, 1.0, 1.5, 3.0):
        h.observe(v)
    # raw retained: exact at arbitrary bounds, boundary inclusive
    assert h.count_le(0.0) == 0
    assert h.count_le(1.0) == 2
    assert h.count_le(1.2) == 2
    assert h.count_le(100.0) == 4
    for v in [0.5] * 6:                       # push past raw_cap
        h.observe(v)
    assert h.values() == []
    # bucketed: cumulative count of buckets with bound <= the query
    # (an underestimate inside a bucket, never an overestimate)
    assert h.count_le(1.0) == 8
    assert h.count_le(1.9) == 8               # 1.5 now invisible
    assert h.count_le(2.0) == 9
    assert h.count_le(7.0) == 9


def test_registry_reset_detaches_old_metrics():
    reg = MetricRegistry()
    c = reg.counter("t.c", "")
    c.inc(5)
    reg.reset()
    assert reg.get("t.c") is None
    c2 = reg.counter("t.c", "")
    assert c2 is not c and c2.total() == 0
    c.inc(1)                                  # old handle records into a
    assert c2.total() == 0                    # detached object only


def test_registry_isolated_blocks_leaks_both_ways():
    reg = MetricRegistry()
    outer = reg.counter("t.out", "")
    outer.inc(3)
    with reg.isolated():
        assert reg.get("t.out") is None       # outside not visible
        reg.counter("t.in", "").inc(7)
        assert reg.get("t.in").total() == 7
    assert reg.get("t.in") is None            # inside did not leak
    assert reg.get("t.out").total() == 3      # restored intact


def test_render_prometheus_round_trip():
    from tests.test_frontend import parse_prometheus
    reg = MetricRegistry()
    reg.counter("serve.requests", "help with\nnewline").inc(
        3, server="a/r0", code="200")
    reg.gauge("obs.up", "").set(1.5)
    h = reg.histogram("serve.lat.seconds", "", buckets=(0.1, 1.0))
    h.observe(0.05, server='we"ird\\name')
    h.observe(0.5, server='we"ird\\name')
    h.observe(5.0, server='we"ird\\name')
    types, samples = parse_prometheus(reg.render_prometheus())
    # dotted names sanitize to underscores; kinds survive
    assert types == {"serve_requests": "counter", "obs_up": "gauge",
                     "serve_lat_seconds": "histogram"}
    assert samples[("serve_requests",
                    (("code", "200"), ("server", "a/r0")))] == 3.0
    assert samples[("obs_up", ())] == 1.5
    lbl = ("server", 'we"ird\\name')          # escapes round-trip
    assert samples[("serve_lat_seconds_bucket",
                    (("le", "0.1"), lbl))] == 1.0
    assert samples[("serve_lat_seconds_bucket",
                    (("le", "1.0"), lbl))] == 2.0
    assert samples[("serve_lat_seconds_bucket",
                    (("le", "+Inf"), lbl))] == 3.0
    assert samples[("serve_lat_seconds_count", (lbl,))] == 3.0
    assert samples[("serve_lat_seconds_sum",
                    (lbl,))] == pytest.approx(5.55)
    # prefix filter narrows the exposition
    assert "obs_up" not in reg.render_prometheus(prefix="serve.")
