"""Tier-1 tests for ``repro.fault``: straggler detection (EMA verdicts,
flag streaks, eviction, fleet median view) and the fault-tolerant
runner (injected-fault retries with rollback, retry accounting, NaN
rollback that skips the bad data window, bounded-retry failure).

Faults are injected deterministically — a scripted timing sequence or
a step-indexed failure plan — so every assertion here is exact.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.fault import (FaultTolerantRunner, HostTimingAggregator,
                         RunnerConfig, StragglerMonitor)


# ----------------------------------------------------------- monitor
def test_monitor_first_step_seeds_ema_without_verdict():
    mon = StragglerMonitor()
    v = mon.record(0.25)
    assert mon.ema == 0.25
    assert v == {"straggler": False, "evict": False, "ratio": 1.0}


def test_monitor_flags_streak_then_evicts():
    mon = StragglerMonitor(alpha=0.2, threshold=1.5, evict_after=3)
    mon.record(1.0)                       # seed EMA
    verdicts = [mon.record(2.0) for _ in range(3)]
    assert [v["straggler"] for v in verdicts] == [True, True, True]
    assert [v["evict"] for v in verdicts] == [False, False, True]
    # straggler steps never fold into the EMA, so the ratio is stable
    assert mon.ema == 1.0
    assert all(v["ratio"] == 2.0 for v in verdicts)


def test_monitor_flag_streak_resets_on_recovery():
    mon = StragglerMonitor(alpha=0.5, threshold=1.5, evict_after=3)
    mon.record(1.0)
    mon.record(2.0), mon.record(2.0)      # two flags
    assert mon.flags == 2
    v = mon.record(1.0)                   # recovery step
    assert not v["straggler"] and mon.flags == 0
    assert mon.ema == pytest.approx(1.0)  # 0.5*1.0 + 0.5*1.0
    # the streak starts over: two more slow steps still don't evict
    assert not mon.record(2.0)["evict"] and not mon.record(2.0)["evict"]
    assert mon.record(2.0)["evict"]


def test_monitor_ema_update_is_exact_and_history_complete():
    mon = StragglerMonitor(alpha=0.25, threshold=10.0)
    times = [1.0, 2.0, 1.0, 4.0]
    for s in times:
        mon.record(s)
    ema = 1.0
    for s in times[1:]:
        ema = 0.75 * ema + 0.25 * s
    assert mon.ema == pytest.approx(ema)
    assert [h[0] for h in mon.history] == times


def test_monitor_scripted_timings_are_deterministic():
    script = [1.0, 1.1, 3.0, 0.9, 3.0, 3.0, 1.0]
    runs = []
    for _ in range(2):
        mon = StragglerMonitor(evict_after=2)
        runs.append([mon.record(s) for s in script])
    assert runs[0] == runs[1]


def test_aggregator_flags_host_above_fleet_median():
    agg = HostTimingAggregator(threshold=1.3)
    for _ in range(4):
        for h, s in [("h0", 1.0), ("h1", 1.0), ("h2", 1.0), ("h3", 2.0)]:
            agg.record(h, s)
    assert agg.stragglers() == ["h3"]


def test_aggregator_empty_and_uniform_fleets():
    agg = HostTimingAggregator()
    assert agg.stragglers() == []
    for h in ("a", "b"):
        agg.record(h, 1.0)
    assert agg.stragglers() == []


# ------------------------------------------------------------ runner
def _mk_runner(tmp_path, fail_plan=None, nan_steps=(), **cfg_kw):
    """A tiny deterministic training loop: state = {'x': sum of batch
    values consumed so far}. fail_plan maps step -> number of times
    that step raises before succeeding."""
    fail_plan = dict(fail_plan or {})
    nan_steps = set(nan_steps)
    calls = {"n": 0}

    def make_batch(step):
        return float(step + 1)

    def step_fn(state, batch):
        calls["n"] += 1
        step = int(batch) - 1
        if fail_plan.get(step, 0) > 0:
            fail_plan[step] -= 1
            raise RuntimeError(f"injected fault @ step {step}")
        loss = np.nan if step in nan_steps else 1.0 / batch
        return {"x": state["x"] + batch}, {"loss": np.float32(loss)}

    cfg = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                       handle_sigterm=False, **cfg_kw)
    runner = FaultTolerantRunner(step_fn, {"x": np.float64(0.0)},
                                 make_batch, cfg)
    return runner, calls


def test_runner_clean_run_accumulates_and_checkpoints(tmp_path):
    runner, calls = _mk_runner(tmp_path)
    state = runner.run(6)
    assert float(state["x"]) == sum(range(1, 7))
    assert calls["n"] == 6 and runner.events == []
    # a fresh runner restores the final forced checkpoint
    fresh, _ = _mk_runner(tmp_path)
    assert fresh.restore() == 6
    assert float(fresh.state["x"]) == sum(range(1, 7))


def test_runner_retries_injected_fault_with_rollback_accounting(tmp_path):
    runner, calls = _mk_runner(tmp_path, fail_plan={3: 2}, max_retries=3)
    state = runner.run(5)
    assert float(state["x"]) == sum(range(1, 6))    # replay is exact
    kinds = [k for _, k, _ in runner.events]
    assert kinds == ["step_failure", "rollback", "step_failure", "rollback"]
    # steps 0..2 ran once, step 3 ran 3x (2 faults + success), 4 once;
    # rollback restored step 2's checkpoint so step 2 replayed twice
    assert calls["n"] == 5 + 2 + 2


def test_runner_raises_after_max_retries(tmp_path):
    runner, _ = _mk_runner(tmp_path, fail_plan={2: 99}, max_retries=2)
    with pytest.raises(RuntimeError, match="injected fault @ step 2"):
        runner.run(4)
    failures = [e for e in runner.events if e[1] == "step_failure"]
    assert len(failures) == 3                       # initial + 2 retries
    assert all(e[0] == 2 for e in failures)


def test_runner_nan_loss_rolls_back_and_skips_window(tmp_path):
    runner, _ = _mk_runner(tmp_path, nan_steps={3})
    state = runner.run(6)
    kinds = [k for _, k, _ in runner.events]
    assert kinds == ["nan_loss", "rollback"]
    # rollback restores the step-2 checkpoint (x = 1+2) and skip_past
    # jumps straight to step 4: both the bad window (batch 4.0) and the
    # committed-but-uncheckpointed window (batch 3.0) are dropped
    assert float(state["x"]) == sum(range(1, 7)) - 4.0 - 3.0
    assert runner.step == 6


def test_runner_nan_tolerance_allows_transient_spike(tmp_path):
    runner, _ = _mk_runner(tmp_path, nan_steps={3}, nan_tolerance=1)
    runner.run(6)
    kinds = [k for _, k, _ in runner.events]
    assert kinds == ["nan_loss"]                    # tolerated: no rollback
    assert runner.step == 6


def test_runner_straggler_monitor_sees_every_committed_step(tmp_path):
    runner, _ = _mk_runner(tmp_path, fail_plan={3: 1})
    runner.run(4)
    # only committed steps reach the monitor (failed attempts don't);
    # the rollback to step 2's checkpoint replays step 2 once
    assert len(runner.monitor.history) == 4 + 1
