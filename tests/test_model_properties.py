"""Model-invariant property tests: attention causality, RoPE relative
encoding, MoE dispatch conservation, GQA grouping."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.attention import AttnConfig, causal_attention, init_attention
from repro.models.moe import MoEConfig, init_moe, moe_ffn


def test_attention_is_causal():
    """Changing future tokens must not change past outputs."""
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p, _ = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y1, _ = causal_attention(p, cfg, x, q_chunk=4, dtype=jnp.float32)
    x2 = x.at[:, 10:].set(jax.random.normal(jax.random.PRNGKey(2),
                                            (2, 6, 32)))
    y2, _ = causal_attention(p, cfg, x2, q_chunk=4, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y1[:, :10]),
                               np.asarray(y2[:, :10]), rtol=1e-5, atol=1e-5)


def test_q_chunking_invariance():
    """Chunked attention == unchunked attention."""
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8)
    p, _ = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32), jnp.float32)
    outs = [np.asarray(causal_attention(p, cfg, x, q_chunk=c,
                                        dtype=jnp.float32)[0])
            for c in (24, 8, 3)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-5)


def test_rope_is_relative():
    """RoPE'd dot products depend only on relative distance."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))

    def score(pos_q, pos_k):
        qq = L.apply_rope(q, jnp.array([[pos_q]]))
        kk = L.apply_rope(k, jnp.array([[pos_k]]))
        return float(jnp.sum(qq * kk))

    assert abs(score(5, 3) - score(105, 103)) < 1e-4
    assert abs(score(5, 3) - score(6, 3)) > 1e-6   # sanity: not constant


def test_moe_dispatch_conserves_tokens():
    """With ample capacity, every (token, k) assignment is dispatched:
    the MoE output equals the gate-weighted sum of per-expert FFNs
    computed densely."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert_ff=16,
                    capacity_factor=4.0)
    p, _ = init_moe(jax.random.PRNGKey(0), 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8), jnp.float32)
    y, _ = moe_ffn(p, cfg, x, dtype=jnp.float32)

    # dense reference: every expert on every token, gate-weighted
    xf = x.reshape(-1, 8)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ti = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for e in range(4):
        g = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        he = g @ p["w_down"][e]
        w = jnp.where(ti == e, gv, 0.0).sum(-1)
        ref = ref + he * w[:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 8)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_moe_ep_pad_equivalence():
    """Padding the expert count must not change the math."""
    base = MoEConfig(n_experts=6, top_k=2, d_expert_ff=16,
                     capacity_factor=4.0)
    padded = dataclasses.replace(base, ep_pad=8)
    p_b, _ = init_moe(jax.random.PRNGKey(0), 8, base)
    p_p, _ = init_moe(jax.random.PRNGKey(0), 8, padded)
    # share the real-expert weights
    for k in ("w_gate", "w_up", "w_down"):
        p_p[k] = p_p[k].at[:6].set(p_b[k])
    p_p["router"] = p_b["router"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8), jnp.float32)
    y_b, _ = moe_ffn(p_b, base, x, dtype=jnp.float32)
    y_p, _ = moe_ffn(p_p, padded, x, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_p),
                               rtol=2e-4, atol=2e-4)


def test_gqa_reduces_to_mha():
    """n_kv_heads == n_heads reproduces standard multi-head attention
    (grouping logic is an identity then)."""
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8)
    p, _ = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.float32)
    y, (kc, vc) = causal_attention(p, cfg, x, q_chunk=8, dtype=jnp.float32)
    assert kc.shape == (1, 8, 4, 8)
    assert np.isfinite(np.asarray(y)).all()
