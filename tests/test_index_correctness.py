"""The paper's central claim: IS-LABEL answers every P2P distance query
exactly. Checked against a Dijkstra oracle across graph families,
weights, thresholds, and disconnected inputs — plus hypothesis
property tests on random graphs.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.graphs import generators as gen


def _check_graph(n, src, dst, w, cfg, n_q=120, seed=0):
    idx = ISLabelIndex.build(n, src, dst, w, cfg)
    r = np.random.default_rng(seed)
    s = r.integers(0, n, n_q).astype(np.int32)
    t = r.integers(0, n, n_q).astype(np.int32)
    got = idx.query_host(s, t)
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(n_q), t]
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all(), "connectivity mismatch"
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)
    return idx


@pytest.mark.parametrize("maker,kwargs", [
    (gen.er_graph, dict(n=300, avg_deg=3.0, seed=1)),
    (gen.er_graph, dict(n=500, avg_deg=1.2, seed=2)),    # many components
    (gen.rmat_graph, dict(n_pow=9, avg_deg=6.0, seed=3)),
    (gen.grid_graph, dict(side=15, seed=4)),
    (gen.caveman_graph, dict(n_communities=10, size=8, seed=5)),
])
def test_exact_vs_oracle(maker, kwargs):
    n, src, dst, w = maker(**kwargs)
    _check_graph(n, src, dst, w, IndexConfig(l_cap=256, label_chunk=256))


def test_unweighted():
    n, src, dst, w = gen.unit_weights(*gen.er_graph(250, 3.0, seed=7))
    _check_graph(n, src, dst, w, IndexConfig(l_cap=256, label_chunk=256))


@pytest.mark.parametrize("sigma", [0.5, 0.9, 0.95, 1.0])
def test_sigma_thresholds(sigma):
    """Paper §5.1/Table 6-7: any k-truncation point gives exact answers."""
    n, src, dst, w = gen.er_graph(220, 3.0, seed=11)
    _check_graph(n, src, dst, w,
                 IndexConfig(sigma=sigma, l_cap=256, label_chunk=256))


@pytest.mark.parametrize("d_cap", [4, 8, 32])
def test_degree_caps(d_cap):
    n, src, dst, w = gen.rmat_graph(8, avg_deg=5.0, seed=13)
    _check_graph(n, src, dst, w,
                 IndexConfig(d_cap=d_cap, l_cap=512, label_chunk=256))


def test_self_and_disconnected():
    n, src, dst, w = gen.er_graph(300, 0.8, seed=17)   # heavily disconnected
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=256, label_chunk=256))
    d_self = idx.query_host([5, 17], [5, 17])
    np.testing.assert_allclose(d_self, 0.0)
    # find two vertices in different components via oracle
    orc = ref.dijkstra_oracle(n, src, dst, w, [0])[0]
    far = int(np.flatnonzero(~np.isfinite(orc))[0])
    assert not np.isfinite(idx.query_host([0], [far])[0])


def test_query_types_reported():
    n, src, dst, w = gen.rmat_graph(8, avg_deg=6.0, seed=19)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=256, label_chunk=256))
    r = np.random.default_rng(0)
    s = r.integers(0, n, 64)
    t = r.integers(0, n, 64)
    types = idx.query_types(s, t)
    assert set(np.unique(types)).issubset({1, 2, 3})


def _random_graph_case(n, avg, maxw, seed):
    """Exactness holds on arbitrary random sparse graphs."""
    n, src, dst, w = gen.er_graph(n, avg_deg=avg, max_w=maxw, seed=seed)
    cfg = IndexConfig(l_cap=128, label_chunk=64, d_cap=8)
    idx = ISLabelIndex.build(n, src, dst, w, cfg)
    r = np.random.default_rng(seed)
    s = r.integers(0, n, 40).astype(np.int32)
    t = r.integers(0, n, 40).astype(np.int32)
    got = idx.query_host(s, t)
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(40), t]
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(24, 80), avg=st.floats(1.0, 4.0),
           maxw=st.integers(1, 9), seed=st.integers(0, 1000))
    def test_property_random_graphs(n, avg, maxw, seed):
        _random_graph_case(n, avg, maxw, seed)
else:
    @pytest.mark.parametrize("n,avg,maxw,seed",
                             [(24, 1.0, 1, 0), (50, 2.0, 4, 77),
                              (66, 3.3, 9, 512), (80, 4.0, 2, 999)])
    def test_property_random_graphs(n, avg, maxw, seed):
        _random_graph_case(n, avg, maxw, seed)


def test_matches_bidijkstra_baseline():
    """IS-LABEL and the paper's IM-DIJ baseline agree query-by-query."""
    n, src, dst, w = gen.er_graph(150, 3.0, seed=23)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=256, label_chunk=128))
    r = np.random.default_rng(1)
    for _ in range(25):
        s, t = int(r.integers(0, n)), int(r.integers(0, n))
        a = float(idx.query_host([s], [t])[0])
        b = ref.bidijkstra(n, src, dst, w, s, t)
        assert (np.isinf(a) and np.isinf(b)) or abs(a - b) < 1e-4
