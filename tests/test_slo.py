"""Tier-1 tests for the SLO burn-rate engine (``repro.obs.slo``):
window-rate math on cumulative samples, the strictly-above fire rule
(a burn exactly at threshold is budget-neutral), the ``min_events``
thin-window guard, fire/resolve hysteresis, monotonic-clock
enforcement, the poll sources over the metric registry, alert-event
emission into the ``EventLog``, and the ``breach_summary`` digest CI
gates on.

Every test passes an explicit ``MetricRegistry`` so nothing touches
the process-wide ``REGISTRY``.
"""
from __future__ import annotations

import json

import pytest

from repro.obs import (EventLog, MetricRegistry, SLOEngine, SLOSpec,
                       compiles_source, counter_source,
                       default_serving_slos, latency_source)

# objective 0.75 -> budget exactly 0.25 in binary; a 50% bad rate burns
# at exactly 2.0, so threshold ties are representable without rounding
EXACT = dict(objective=0.75, fast_window_s=10.0, slow_window_s=40.0,
             fast_burn=2.0, slow_burn=0.5, resolve_hold_s=5.0)


def _engine(*specs, log=None):
    return SLOEngine(specs, log=log, registry=MetricRegistry())


# ------------------------------------------------------------ spec rules
def test_spec_validation_and_budget():
    with pytest.raises(ValueError, match="objective"):
        SLOSpec("x", objective=1.0)
    with pytest.raises(ValueError, match="objective"):
        SLOSpec("x", objective=0.0)
    with pytest.raises(ValueError, match="fast window"):
        SLOSpec("x", fast_window_s=60.0, slow_window_s=30.0)
    assert SLOSpec("x", objective=0.75).budget == 0.25
    with pytest.raises(ValueError, match="duplicate"):
        _engine(SLOSpec("a"), SLOSpec("a"))


# --------------------------------------------------------- window rates
def test_empty_window_never_fires():
    eng = _engine(SLOSpec("a", **EXACT))
    assert eng.evaluate(100.0) == []
    st = eng.states["a"]
    assert not st.firing and st.burn_fast == 0.0 and st.burn_slow == 0.0


def test_window_rate_is_delta_over_trailing_window():
    eng = _engine(SLOSpec("a", **EXACT))
    st = eng.states["a"]
    # cumulative samples: 10 good by t=0, then 10 bad by t=20
    eng.record("a", 0.0, good=10)
    eng.record("a", 20.0, bad=10)
    # fast window [10, 20] starts at the t=0 sample (newest <= cutoff):
    # delta is the 10 bad events -> rate 1.0
    rate_f, n_f = st.window_rate(20.0, 10.0)
    assert rate_f == 1.0 and n_f == 10
    # whole-run window sees 10 bad / 20 total
    rate_s, n_s = st.window_rate(20.0, 40.0)
    assert rate_s == 0.5 and n_s == 20


def test_min_events_guards_thin_windows():
    eng = _engine(SLOSpec("a", min_events=10, **EXACT))
    eng.record("a", 1.0, bad=5)              # 100% bad but only 5 events
    assert eng.evaluate(1.0) == []
    assert not eng.states["a"].firing
    eng.record("a", 2.0, bad=5)              # now 10 events in window
    events = eng.evaluate(2.0)
    assert [e["state"] for e in events] == ["fire"]


def test_burn_exactly_at_threshold_does_not_fire():
    eng = _engine(SLOSpec("a", **EXACT))
    # 2 bad / 4 total -> rate 0.5 -> burn exactly fast_burn == 2.0
    eng.record("a", 1.0, good=2, bad=2)
    assert eng.evaluate(1.0) == []
    st = eng.states["a"]
    assert st.burn_fast == 2.0 and not st.firing
    # one more bad tips strictly above: 3/5 -> burn 2.4
    eng.record("a", 2.0, bad=1)
    assert [e["state"] for e in eng.evaluate(2.0)] == ["fire"]
    assert st.firing and st.fires == 1


def test_both_windows_must_burn():
    # a long-clean history keeps the slow window quiet: no fire even
    # when the fast window saturates
    eng = _engine(SLOSpec("a", **EXACT))
    eng.record("a", 0.0, good=1000)
    eng.record("a", 35.0, bad=4)      # fast: 4/4 bad; slow: 4/1004
    assert eng.evaluate(35.0) == []
    st = eng.states["a"]
    assert st.burn_fast == 4.0 and st.burn_slow < 0.5 and not st.firing


def test_fire_resolve_hysteresis_holds_through_flap():
    eng = _engine(SLOSpec("a", **EXACT))
    eng.record("a", 1.0, bad=4)
    assert [e["state"] for e in eng.evaluate(1.0)] == ["fire"]
    st = eng.states["a"]
    # burn falls back under threshold as good traffic arrives, but the
    # alert holds until the condition has been false for resolve_hold_s
    # measured from the last evaluation where it held (t=1.0)
    eng.record("a", 2.0, good=100)
    assert eng.evaluate(2.0) == [] and st.firing
    assert eng.evaluate(5.9) == [] and st.firing      # hold not elapsed
    events = eng.evaluate(6.0)                        # 5s after t=1
    assert [e["state"] for e in events] == ["resolve"]
    assert not st.firing and st.resolves == 1
    # no duplicate fire/resolve events on further quiet evaluations
    assert eng.evaluate(8.0) == []


def test_refire_after_resolve_counts_again():
    eng = _engine(SLOSpec("a", **EXACT))
    eng.record("a", 1.0, bad=4)
    eng.evaluate(1.0)
    eng.record("a", 2.0, good=100)
    eng.evaluate(7.0)
    # fresh burst: everything in the fast window [t-10, t] is bad again
    eng.record("a", 30.0, bad=400)
    assert [e["state"] for e in eng.evaluate(30.0)] == ["fire"]
    st = eng.states["a"]
    assert st.fires == 2 and st.resolves == 1


def test_serving_clock_must_be_monotonic():
    eng = _engine(SLOSpec("a", **EXACT))
    eng.record("a", 10.0, good=1)
    with pytest.raises(ValueError, match="monotonic"):
        eng.record("a", 5.0, good=1)


def test_attach_unknown_slo_raises():
    eng = _engine(SLOSpec("a", **EXACT))
    with pytest.raises(KeyError, match="unknown SLO"):
        eng.attach("nope", lambda: (0, 0))


# -------------------------------------------------------------- sources
def test_counter_source_reads_good_bad_pair():
    reg = MetricRegistry()
    ok = reg.counter("t.ok", "")
    err = reg.counter("t.err", "")
    probe = counter_source("t.ok", "t.err", registry=reg)
    assert probe() == (0, 0)                 # metrics may not exist yet
    ok.inc(7)
    err.inc(3)
    assert probe() == (7, 10)


def test_latency_source_threshold_and_server_filter():
    reg = MetricRegistry()
    h = reg.histogram("serve.latency_seconds", "",
                      buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5):
        h.observe(v, server="a", sid="1")
    h.observe(5.0, server="b", sid="1")
    all_servers = latency_source(0.1, registry=reg)
    assert all_servers() == (2, 4)           # <= 0.1s over both servers
    only_a = latency_source(0.1, registry=reg, servers=["a"])
    assert only_a() == (2, 3)
    assert latency_source(0.1, registry=reg, metric="missing")() == (0, 0)


def test_compiles_source_counts_every_compile_as_bad():
    class FakeWatcher:
        supported = True

        def count(self, region):
            return {"serve_read": 2}.get(region, 0)

    assert compiles_source(FakeWatcher())() == (0, 2)
    assert compiles_source(FakeWatcher(), region="other")() == (0, 0)
    FakeWatcher.supported = False
    assert compiles_source(FakeWatcher())() == (0, 0)


def test_poll_path_fires_from_attached_source():
    eng = _engine(SLOSpec("a", min_events=1, **EXACT))
    bad = {"n": 0}
    eng.attach("a", lambda: (0, bad["n"]))
    assert eng.step(1.0) == []               # empty source: no events
    bad["n"] = 4
    events = eng.step(2.0)
    assert [e["state"] for e in events] == ["fire"]


# ----------------------------------------------------- events + digests
def test_alert_events_land_in_event_log_as_json_lines(tmp_path):
    log = EventLog()
    eng = _engine(SLOSpec("a", **EXACT), log=log)
    eng.record("a", 1.0, bad=4)
    (ev,) = eng.evaluate(1.0)
    assert ev["kind"] == "slo_alert" and ev["slo"] == "a"
    assert ev["state"] == "fire" and ev["burn_fast"] == 4.0
    assert ev["fast_burn_threshold"] == 2.0
    assert log.recent[-1] is ev
    # JSON-lines round trip (the SSE stream sends exactly these dicts)
    line = json.dumps(ev)
    assert json.loads(line) == ev


def test_burn_gauges_and_alert_counter_update():
    reg = MetricRegistry()
    eng = SLOEngine([SLOSpec("a", **EXACT)], registry=reg)
    eng.record("a", 1.0, bad=4)
    eng.evaluate(1.0)
    g = reg.get("slo.burn_rate")
    assert g.value(slo="a", window="fast") == 4.0
    assert reg.get("slo.firing").value(slo="a") == 1.0
    assert reg.get("slo.alerts").total() == 1


def test_breach_summary_digest():
    eng = _engine(SLOSpec("a", **EXACT), SLOSpec("b", **EXACT))
    eng.record("a", 1.0, bad=4)
    eng.evaluate(1.0)
    eng.record("a", 2.0, good=100)
    eng.evaluate(7.0)                        # resolved, but fired_ever
    out = eng.breach_summary()
    assert out["fired"] == ["a"] and out["firing"] == []
    assert out["slos"]["a"]["fires"] == 1
    assert out["slos"]["a"]["max_burn_fast"] == 4.0
    assert out["slos"]["b"] == {"fires": 0, "resolves": 0,
                                "max_burn_fast": 0.0, "max_burn_slow": 0.0}
    snap = eng.snapshot()
    assert snap["a"]["fires"] == 1 and not snap["a"]["firing"]


def test_default_serving_slos_cover_the_standing_objectives():
    specs = default_serving_slos(fast_window_s=1.0, slow_window_s=4.0)
    assert [s.name for s in specs] == ["availability", "latency",
                                      "exactness", "read_compiles"]
    eng = SLOEngine(specs, registry=MetricRegistry())

    class OneCompile:
        supported = True

        def count(self, region):
            return 1

    eng.attach("read_compiles", compiles_source(OneCompile()))
    # a single serve_read compile is an instant page (zero thresholds)
    events = eng.step(0.5)
    assert [(e["slo"], e["state"]) for e in events] == \
        [("read_compiles", "fire")]
