"""Construction-path coverage (docs/CONSTRUCTION.md): capacity-overflow
semantics, the two-word MIS key, and dual-builder determinism.

The overflow contract is load-bearing for the deferred-sync design: the
device builder batches its capacity checks into the per-level stats read
(and the labeler into one read per ``sync_every`` levels), but a tripped
cap must still raise an actionable RuntimeError naming the offending
level — and must never let a truncated index escape (the raise discards
the build; a rebuild with a bigger cap is bitwise-clean).
"""
import numpy as np
import pytest

import jax

from repro.core import ISLabelIndex, IndexConfig, build_hierarchy
from repro.core.hierarchy import (build_hierarchy_device,
                                  build_hierarchy_host)
from repro.core.labeling import build_labels
from repro.core.mis import independent_set, lex_less, mis_key_words
from repro.graphs import generators as gen


# ---------------------------------------------------------------- overflow

def test_e_cap_overflow_raises_actionable():
    """Densifying peel blows the edge buffer (augmentation outpaces the
    removals on a deg-6 ER graph): the deferred stats read still raises,
    naming the level and the knob to turn."""
    n, src, dst, w = gen.er_graph(300, 6.0, seed=3)
    with pytest.raises(RuntimeError,
                       match=r"edge capacity overflow at level \d+.*"
                             r"e_cap_factor"):
        build_hierarchy(n, src, dst, w,
                        IndexConfig(e_cap_factor=1.2, aug_cap_factor=8.0,
                                    d_cap=16))


def test_aug_cap_overflow_raises_actionable():
    n, src, dst, w = gen.er_graph(300, 6.0, seed=3)
    with pytest.raises(RuntimeError,
                       match=r"augmentation buffer overflow at level \d+"
                             r".*aug_cap_factor"):
        build_hierarchy(n, src, dst, w,
                        IndexConfig(e_cap_factor=8.0, aug_cap_factor=0.2,
                                    d_cap=16))


def test_l_cap_overflow_raises_actionable():
    """The labeler's check is deferred sync_every levels — it must still
    raise, and name l_cap."""
    n, src, dst, w = gen.caveman_graph(6, 10, seed=7)
    cfg = IndexConfig(l_cap=2, label_chunk=32, e_cap_factor=8.0,
                      aug_cap_factor=4.0, sync_every=64)
    h = build_hierarchy(n, src, dst, w, cfg)
    with pytest.raises(RuntimeError,
                       match=r"label capacity overflow at level \d+.*"
                             r"l_cap \(currently 2\)"):
        build_labels(h, cfg)


def test_overflow_leaves_no_corrupted_state():
    """A tripped cap discards the build; retrying with an adequate cap
    yields an index bitwise-identical to one never preceded by the
    failure (no donated-buffer or cache pollution)."""
    n, src, dst, w = gen.caveman_graph(6, 10, seed=7)
    good = IndexConfig(l_cap=256, label_chunk=32, e_cap_factor=8.0,
                       aug_cap_factor=4.0, d_cap=32)
    ref_idx = ISLabelIndex.build(n, src, dst, w, good)
    with pytest.raises(RuntimeError):
        ISLabelIndex.build(n, src, dst, w,
                           IndexConfig(l_cap=2, label_chunk=32,
                                       e_cap_factor=8.0, aug_cap_factor=4.0,
                                       d_cap=32))
    retry = ISLabelIndex.build(n, src, dst, w, good)
    assert retry.k == ref_idx.k
    np.testing.assert_array_equal(retry.level, ref_idx.level)
    np.testing.assert_array_equal(np.asarray(retry.lbl_ids),
                                  np.asarray(ref_idx.lbl_ids))
    np.testing.assert_array_equal(np.asarray(retry.lbl_d),
                                  np.asarray(ref_idx.lbl_d))
    np.testing.assert_array_equal(retry.core_src, ref_idx.core_src)


def test_unknown_builder_rejected():
    n, src, dst, w = gen.er_graph(64, 2.0, seed=0)
    with pytest.raises(ValueError, match="builder"):
        build_hierarchy(n, src, dst, w, IndexConfig(builder="gpu"))


# ------------------------------------------------------------ two-word key

def test_lex_less_matches_packed_key_order():
    """The (deg, perm) two-word compare must order exactly like the
    retired packed key deg*n + perm computed in unbounded python ints —
    including above the old (d_cap+2)*(n+1) < 2^32 ceiling."""
    rng = np.random.default_rng(0)
    n = 2 ** 31 - 2            # far beyond any packable width
    d_cap = 16
    deg = np.concatenate([rng.integers(0, d_cap + 2, 500),
                          [0, 0, d_cap + 1, d_cap + 1]]).astype(np.int32)
    perm = np.concatenate([rng.integers(0, n, 500),
                           [0, n - 1, 0, n - 1]]).astype(np.int64)
    hi, lo = mis_key_words(jax.numpy.asarray(deg), jax.numpy.asarray(perm),
                           d_cap)
    hi = np.asarray(hi).astype(np.int64)
    lo = np.asarray(lo).astype(np.int64)
    packed = deg.astype(object) * (n + 1) + perm.astype(object)
    a = rng.integers(0, len(deg), 4000)
    b = rng.integers(0, len(deg), 4000)
    got = np.asarray(lex_less(hi[a], lo[a], hi[b], lo[b]))
    want = packed[a] < packed[b]
    np.testing.assert_array_equal(got, want.astype(bool))


def _reference_is(n, src, dst, deg, perm, eligible):
    """Serial greedy over ascending (deg, perm): the fixed point the
    parallel rounds must reproduce (strict total order => unique MIS)."""
    order = sorted(range(n), key=lambda v: (deg[v], perm[v]))
    adj = {}
    for s, d in zip(src, dst):
        if s < n and d < n:
            adj.setdefault(int(d), set()).add(int(s))
    chosen, blocked = set(), set()
    for v in order:
        if eligible[v] and v not in blocked:
            chosen.add(v)
            blocked |= adj.get(v, set())
            blocked.add(v)
    return chosen


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_independent_set_matches_serial_greedy(seed):
    """Luby rounds with the two-word key land on the same IS as the
    serial min-(deg, perm) greedy: maximal, independent, identical."""
    n, src, dst, w = gen.er_graph(120, 3.0, seed=seed)
    d_cap = 8
    valid = src < n
    deg = np.bincount(src[valid], minlength=n)
    rng = jax.random.PRNGKey(seed)
    in_is, rounds = independent_set(
        jax.numpy.asarray(src), jax.numpy.asarray(dst),
        jax.numpy.asarray(valid), jax.numpy.ones(n, bool), rng, n, d_cap)
    in_is = np.asarray(in_is)
    perm = np.asarray(jax.random.permutation(rng, n))
    eligible = deg <= d_cap
    want = _reference_is(n, src, dst, deg, perm, eligible)
    assert set(np.flatnonzero(in_is).tolist()) == want
    assert int(rounds) >= 1


# ----------------------------------------------------------- determinism

GRAPHS = [("er", lambda: gen.er_graph(500, 3.0, seed=1)),
          ("rmat", lambda: gen.rmat_graph(9, 8.0, seed=2)),
          ("grid", lambda: gen.grid_graph(20, seed=3))]


def _hier_fields(h):
    return (h.k, h.level, h.up_ids, h.up_w, h.up_via, h.core_src,
            h.core_dst, h.core_w, h.core_via, np.asarray(h.level_sizes),
            np.asarray(h.graph_sizes), np.asarray(h.mis_rounds))


@pytest.mark.parametrize("name,mk", GRAPHS)
def test_device_and_host_builders_bitwise_equal(name, mk):
    n, src, dst, w = mk()
    cfg = IndexConfig(l_cap=256, label_chunk=128)
    hd = build_hierarchy_device(n, src, dst, w, cfg)
    hh = build_hierarchy_host(n, src, dst, w, cfg)
    for a, b in zip(_hier_fields(hd), _hier_fields(hh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ld = build_labels(hd, cfg)
    lh = build_labels(hh, cfg)
    for a, b in zip(ld, lh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_device_builder_sync_budget():
    """<= 1 blocking host read per level-loop iteration."""
    n, src, dst, w = gen.er_graph(500, 3.0, seed=1)
    h = build_hierarchy_device(n, src, dst, w, IndexConfig())
    assert h.peel_iters >= 1
    assert h.host_syncs <= h.peel_iters


def test_fixed_seed_build_is_deterministic():
    """Same seed, same graph => bitwise-identical index across repeated
    builds in one process (jit cache warm vs cold)."""
    n, src, dst, w = gen.er_graph(300, 3.0, seed=5)
    cfg = IndexConfig(l_cap=256, label_chunk=64)
    a = ISLabelIndex.build(n, src, dst, w, cfg)
    b = ISLabelIndex.build(n, src, dst, w, cfg)
    np.testing.assert_array_equal(a.level, b.level)
    np.testing.assert_array_equal(np.asarray(a.lbl_ids),
                                  np.asarray(b.lbl_ids))
    np.testing.assert_array_equal(np.asarray(a.lbl_d), np.asarray(b.lbl_d))
