"""Tier-1 tests for the ``repro.paths`` subsystem (docs/PATHS.md).

Covers: batched reconstruction validity (endpoints, real edges, weight
sum bitwise-equal to the served distance), bitwise distance agreement
with the query hot path, s == t and disconnected pairs, paths entirely
inside the core, hop_cap overflow + escalation, kernel-backend parity,
the serving path lane, sharded path answers (blocks gathered from the
owning shards, bitwise vs unsharded, P in {1, 4}), directed-graph path
reconstruction, and a hypothesis/fallback property sweep. hypothesis is
optional (requirements-dev): without it the sweep falls back to fixed
seeds.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import ISLabelIndex, IndexConfig
from repro.graphs import generators as gen
from repro.paths import (PathEngine, check_path_batch, edge_weight_map)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n_dev: int = 4, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="module")
def graph():
    return gen.rmat_graph(8, avg_deg=5.0, seed=2)


@pytest.fixture(scope="module")
def index(graph):
    n, src, dst, w = graph
    return ISLabelIndex.build(n, src, dst, w,
                              IndexConfig(l_cap=256, label_chunk=128))


@pytest.fixture(scope="module")
def edges(graph):
    n, src, dst, w = graph
    return edge_weight_map(src, dst, w)


@pytest.fixture(scope="module")
def batch(graph, index):
    n = graph[0]
    r = np.random.default_rng(3)
    s = r.integers(0, n, 96).astype(np.int32)
    t = r.integers(0, n, 96).astype(np.int32)
    out = index.path_engine().path_batch_fn(128)(s, t)
    return s, t, out


# ----------------------------------------------------------- validity
def test_batched_paths_valid_and_distance_bitwise(graph, index, edges,
                                                  batch):
    n, src, dst, w = graph
    s, t, out = batch
    want = np.asarray(index.query(s, t), np.float32)
    assert np.array_equal(np.asarray(out.dist), want, equal_nan=True)
    rep = check_path_batch(edges, s, t, out)
    assert rep["overflowed"] == 0
    assert rep["violations"] == []
    assert rep["checked"] == len(s)


def test_matches_scalar_oracle_distances(index, batch):
    s, t, out = batch
    dist = np.asarray(out.dist)
    lens = np.asarray(out.lens)
    for i in range(0, 24):
        d, p = index.shortest_path(int(s[i]), int(t[i]))
        if np.isfinite(d):
            # same distance; path lengths may differ (ties), both valid
            assert float(dist[i]) == d
            assert lens[i] >= 2 or s[i] == t[i]
        else:
            assert not np.isfinite(dist[i]) and lens[i] == 0


def test_s_equals_t(index):
    s = np.asarray([5, 17, 0], np.int32)
    out = index.path_engine().path_batch_fn(64)(s, s)
    assert np.array_equal(np.asarray(out.dist), np.zeros(3, np.float32))
    assert np.array_equal(np.asarray(out.lens), np.ones(3, np.int32))
    verts = np.asarray(out.verts)
    assert np.array_equal(verts[:, 0], s)
    assert np.asarray(out.ok).all()


def test_disconnected_pairs_empty_path():
    # sparse ER has small components: some pairs are unreachable
    n, src, dst, w = gen.er_graph(300, 1.5, seed=7)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=128, label_chunk=64))
    r = np.random.default_rng(0)
    s = r.integers(0, n, 64).astype(np.int32)
    t = r.integers(0, n, 64).astype(np.int32)
    out = idx.path_engine().path_batch_fn(64)(s, t)
    dist = np.asarray(out.dist)
    lens = np.asarray(out.lens)
    assert (~np.isfinite(dist)).any(), "fixture should have unreachable pairs"
    assert np.array_equal(lens == 0, ~np.isfinite(dist))
    assert np.asarray(out.ok).all()


def test_paths_entirely_inside_the_core(graph, index, edges):
    # both endpoints core vertices: label chases are empty, the whole
    # path is the predecessor-tracked core segment
    core = index.core_ids
    assert len(core) >= 8
    s = core[:8].astype(np.int32)
    t = core[-8:][::-1].copy().astype(np.int32)
    out = index.path_engine().path_batch_fn(128)(s, t)
    rep = check_path_batch(edges, s, t, out)
    assert rep["violations"] == [] and rep["overflowed"] == 0
    verts = np.asarray(out.verts)
    lens = np.asarray(out.lens)
    dist = np.asarray(out.dist)
    lvl = index.level
    for i in range(len(s)):
        if np.isfinite(dist[i]) and s[i] != t[i]:
            assert lens[i] >= 2
            # every vertex of a core-to-core shortest path stays in
            # levels reachable from the core expansion; endpoints core
            assert lvl[verts[i, 0]] == index.k
            assert lvl[verts[i, lens[i] - 1]] == index.k


def test_hop_cap_overflow_flags_and_escalation(graph, index):
    n = graph[0]
    r = np.random.default_rng(5)
    s = r.integers(0, n, 64).astype(np.int32)
    t = r.integers(0, n, 64).astype(np.int32)
    tiny = index.path_engine().path_batch_fn(4)(s, t)
    ok = np.asarray(tiny.ok)
    dist = np.asarray(tiny.dist)
    # distances stay exact even when the path overflows
    want = np.asarray(index.query(s, t), np.float32)
    assert np.array_equal(dist, want, equal_nan=True)
    assert not ok.all(), "hop_cap=4 should overflow some paths"
    d2, paths, ok2 = index.shortest_paths(s, t, hop_cap=4)
    assert ok2.all()
    assert np.array_equal(d2, want, equal_nan=True)
    for i, p in enumerate(paths):
        if np.isfinite(want[i]):
            assert p[0] == s[i] and p[-1] == t[i]


@pytest.mark.parametrize("backend", ["reference", "interpret"])
def test_backend_parity_bitwise(graph, index, batch, backend):
    s, t, ref_out = batch
    out = index.path_engine().path_batch_fn(128, backend)(s, t)
    for field in ("dist", "verts", "weights", "lens", "ok"):
        a = np.asarray(getattr(ref_out, field))
        b = np.asarray(getattr(out, field))
        assert np.array_equal(a, b, equal_nan=True), (backend, field)


# ------------------------------------------------------------- serving
def test_serving_path_lane_end_to_end(graph, index, edges):
    from repro.serve import DistanceServer, make_trace
    n = graph[0]
    srv = DistanceServer(index, buckets=(8, 32), max_wait_ms=1.0,
                         path_hop_caps=(16, 128))
    tr = make_trace("uniform", n=n, num_requests=200, rate_qps=2e4, seed=9)
    dist, paths, valid = srv.serve_path_trace(tr)
    assert valid.all()
    want = np.asarray(index.query(tr.s, tr.t), np.float32)
    assert np.array_equal(dist, want, equal_nan=True)
    for i, p in enumerate(paths):
        if not np.isfinite(dist[i]):
            assert p == []
            continue
        assert p[0] == tr.s[i] and p[-1] == tr.t[i]
        total = sum(edges[(a, b)] for a, b in zip(p[:-1], p[1:]))
        assert np.float32(total) == dist[i]
    snap = srv.stats()
    assert snap["lanes"]["path"]["requests"] + snap["cache_hits"] >= 200
    # distance lanes unaffected
    got = srv.serve_trace(make_trace("hotspot", n=n, num_requests=100,
                                     rate_qps=2e4, seed=10))
    assert len(got) == 100


def test_serving_path_cache_hits(graph, index):
    from repro.serve import DistanceServer, make_trace
    n = graph[0]
    srv = DistanceServer(index, buckets=(8,), max_wait_ms=1.0,
                         path_hop_caps=(64,))
    tr = make_trace("repeated", n=n, num_requests=150, pool=20, seed=11)
    dist, paths, valid = srv.serve_path_trace(tr)
    assert valid.all()
    assert srv.stats()["cache_hit_rate"] > 0.5


def test_path_cache_never_symmetric(graph, index):
    # distances commute on undirected graphs but a path list is
    # directional: a symmetric distance cache must not make a (t, s)
    # path request return the (s, t) vertex list
    from repro.serve import DistanceServer
    n = graph[0]
    srv = DistanceServer(index, buckets=(8,), max_wait_ms=1.0,
                         cache_symmetric=True, path_hop_caps=(64,))
    want = np.asarray(index.query(np.arange(n, dtype=np.int32),
                                  np.zeros(n, np.int32)))
    s = int(np.flatnonzero(np.isfinite(want) & (np.arange(n) != 0))[0])
    r1 = srv.submit_path(s, 0, now=0.0)
    srv.pump(now=1.0, force=True)
    a1 = srv.take_result(r1)
    r2 = srv.submit_path(0, s, now=2.0)
    srv.pump(now=3.0, force=True)
    a2 = srv.take_result(r2)
    assert a1.path[0] == s and a1.path[-1] == 0
    assert a2.path[0] == 0 and a2.path[-1] == s


def test_submit_path_requires_enabled_lane(index):
    from repro.serve import DistanceServer
    srv = DistanceServer(index, buckets=(8,), max_wait_ms=1.0,
                         warmup=False)
    with pytest.raises(ValueError):
        srv.submit_path(1, 2, now=0.0)


# ------------------------------------------------------------- sharded
def test_sharded_paths_bitwise_p1(graph, index):
    from repro.shard import ShardedIndex
    n = graph[0]
    sidx = ShardedIndex.from_index(index, 1)
    r = np.random.default_rng(13)
    s = r.integers(0, n, 48).astype(np.int32)
    t = r.integers(0, n, 48).astype(np.int32)
    a = index.path_engine().path_batch_fn(128)(s, t)
    b = sidx.path_engine().path_batch_fn(128)(s, t)
    for field in ("dist", "verts", "weights", "lens", "ok"):
        assert np.array_equal(np.asarray(getattr(a, field)),
                              np.asarray(getattr(b, field)),
                              equal_nan=True), field


def test_sharded_paths_bitwise_p4_subprocess():
    run_with_devices("""
        import numpy as np
        from repro.core import ISLabelIndex, IndexConfig
        from repro.graphs import generators as gen
        from repro.paths import check_path_batch, edge_weight_map
        from repro.shard import ShardedIndex

        n, src, dst, w = gen.er_graph(400, 2.5, seed=5)
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=128, label_chunk=128))
        sidx = ShardedIndex.from_index(idx, 4, strategy="level")
        r = np.random.default_rng(1)
        s = r.integers(0, n, 64).astype(np.int32)
        t = r.integers(0, n, 64).astype(np.int32)
        a = idx.path_engine().path_batch_fn(128)(s, t)
        b = sidx.path_engine().path_batch_fn(128)(s, t)
        for f in ("dist", "verts", "weights", "lens", "ok"):
            assert np.array_equal(np.asarray(getattr(a, f)),
                                  np.asarray(getattr(b, f)),
                                  equal_nan=True), f
        rep = check_path_batch(edge_weight_map(src, dst, w), s, t, b)
        assert rep["violations"] == [], rep["violations"][:5]
        print("P4 path parity OK")
    """)


# ------------------------------------------------------------ directed
def test_directed_paths_valid():
    from repro.core.directed import DiISLabelIndex
    rng = np.random.default_rng(4)
    n = 150
    src = rng.integers(0, n, 600).astype(np.int32)
    dst = rng.integers(0, n, 600).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = rng.integers(1, 5, len(src)).astype(np.float32)
    idx = DiISLabelIndex.build(n, src, dst, w,
                               IndexConfig(l_cap=256, label_chunk=128))
    ed = edge_weight_map(src, dst, w)
    checked = 0
    for _ in range(40):
        s, t = int(rng.integers(0, n)), int(rng.integers(0, n))
        d, path = idx.shortest_path(s, t)
        if not np.isfinite(d):
            assert path == []
            continue
        checked += 1
        assert path[0] == s and path[-1] == t
        total = 0.0
        for a, b in zip(path[:-1], path[1:]):
            assert (a, b) in ed, f"directed path uses non-edge {(a, b)}"
            total += ed[(a, b)]
        assert abs(total - d) < 1e-4
    assert checked > 10


# -------------------------------------------- property sweep (weights)
def _path_property_case(seed, n):
    n_, src, dst, w = gen.er_graph(n, 2.5, seed=seed)
    idx = ISLabelIndex.build(n_, src, dst, w,
                             IndexConfig(l_cap=128, label_chunk=64,
                                         d_cap=8))
    edges = edge_weight_map(src, dst, w)
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n_, 32).astype(np.int32)
    t = rng.integers(0, n_, 32).astype(np.int32)
    dist, paths, ok = idx.shortest_paths(s, t, hop_cap=64)
    assert ok.all()
    want = np.asarray(idx.query(s, t), np.float32)
    assert np.array_equal(dist, want, equal_nan=True)
    for i, p in enumerate(paths):
        if not np.isfinite(dist[i]):
            assert p == []
            continue
        total = sum(edges[(a, b)] for a, b in zip(p[:-1], p[1:]))
        # integer weights: the float32 sum is exact, so bitwise
        assert np.float32(total) == dist[i], (i, total, dist[i])


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 500), n=st.integers(40, 120))
    def test_path_weight_sum_property(seed, n):
        _path_property_case(seed, n)
else:
    @pytest.mark.parametrize("seed,n", [(0, 40), (17, 77), (101, 120)])
    def test_path_weight_sum_property(seed, n):
        _path_property_case(seed, n)
