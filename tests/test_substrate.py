"""Substrate tests: checkpointing (atomic/async/corruption/elastic),
fault-tolerant runner (NaN rollback, failure retry, preemption),
straggler monitor, data pipeline determinism, optimizers, compression."""
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import synthetic
from repro.data.pipeline import PrefetchPipeline
from repro.fault import FaultTolerantRunner, RunnerConfig
from repro.fault.stragglers import HostTimingAggregator, StragglerMonitor
from repro.optim import adafactor, adamw


# ------------------------------------------------------------- checkpoint
def _state(x=0.0):
    return {"params": {"w": jnp.full((4, 3), x), "b": jnp.zeros(3)},
            "step": jnp.int32(0)}


def test_checkpoint_roundtrip(tmp_path):
    st = _state(1.5)
    save_checkpoint(tmp_path, 10, st)
    got, step = restore_checkpoint(tmp_path, st)
    assert step == 10
    np.testing.assert_allclose(got["params"]["w"], 1.5)


def test_checkpoint_retention_and_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, _state(s), keep=2)
    assert latest_step(tmp_path) == 5
    dirs = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(dirs) == 2


def test_checkpoint_corruption_detected(tmp_path):
    save_checkpoint(tmp_path, 1, _state(1.0))
    save_checkpoint(tmp_path, 2, _state(2.0))
    # corrupt newest
    victim = tmp_path / "step_000000002" / "arrays.npz"
    data = bytearray(victim.read_bytes())
    data[len(data) // 2] ^= 0xFF
    victim.write_bytes(bytes(data))
    got, step = restore_checkpoint(tmp_path, _state())
    assert step == 1           # fell back past the corrupted checkpoint
    np.testing.assert_allclose(got["params"]["w"], 1.0)


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto explicit shardings (elastic restart path)."""
    st = _state(3.0)
    save_checkpoint(tmp_path, 7, st)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    got, step = restore_checkpoint(tmp_path, st, shardings=sh)
    assert step == 7
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())


def test_async_checkpoint_manager(tmp_path):
    mgr = CheckpointManager(tmp_path, every=2)
    for s in range(1, 7):
        mgr.maybe_save(s, _state(float(s)))
    mgr.wait()
    assert latest_step(tmp_path) == 6


# ------------------------------------------------------------------ fault
def _toy_step(fail_at=(), nan_batches=()):
    """NaN is a property of the *data window* (like real corrupt data);
    injected failures key off the state step (like real device loss)."""
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        s = int(state["step"])
        data_id = int(batch["x"][0]) - 1          # window index
        if s in fail_at and calls.setdefault(f"f{s}", 0) == 0:
            calls[f"f{s}"] = 1
            raise RuntimeError(f"injected device failure at {s}")
        loss = jnp.float32(np.nan) if data_id in nan_batches else \
            jnp.float32(1.0 / (s + 1.0)) + 0.0 * batch["x"].sum()
        return dict(state, step=state["step"] + 1,
                    w=state["w"] + batch["x"].mean()), {"loss": loss}
    return step, calls


def _mk_batch(step):
    return {"x": jnp.full((4,), float(step + 1))}


def test_runner_recovers_from_failure(tmp_path):
    step, calls = _toy_step(fail_at=(5,))
    st = {"w": jnp.zeros(()), "step": jnp.int32(0)}
    r = FaultTolerantRunner(step, st, _mk_batch,
                            RunnerConfig(str(tmp_path), ckpt_every=2,
                                         handle_sigterm=False))
    out = r.run(10)
    assert int(out["step"]) == 10
    kinds = [k for _, k, _ in r.events]
    assert "step_failure" in kinds and "rollback" in kinds


def test_runner_nan_rollback_skips_bad_window(tmp_path):
    step, _ = _toy_step(nan_batches=(4,))
    st = {"w": jnp.zeros(()), "step": jnp.int32(0)}
    r = FaultTolerantRunner(step, st, _mk_batch,
                            RunnerConfig(str(tmp_path), ckpt_every=2,
                                         handle_sigterm=False))
    out = r.run(8)
    assert r.step == 8                       # data cursor covered all windows
    # state replayed from ckpt@4 and skipped exactly the bad window
    assert int(out["step"]) == 7
    assert any(k == "nan_loss" for _, k, _ in r.events)


def test_runner_resume_across_restart(tmp_path):
    step, _ = _toy_step()
    st = {"w": jnp.zeros(()), "step": jnp.int32(0)}
    r1 = FaultTolerantRunner(step, st, _mk_batch,
                             RunnerConfig(str(tmp_path), ckpt_every=2,
                                          handle_sigterm=False))
    r1.run(6)
    # simulate new process: fresh runner restores
    r2 = FaultTolerantRunner(step, st, _mk_batch,
                             RunnerConfig(str(tmp_path), ckpt_every=2,
                                          handle_sigterm=False))
    resumed = r2.restore()
    assert resumed == 6
    out = r2.run(9)
    assert int(out["step"]) == 9


def test_straggler_monitor_flags_and_evicts():
    m = StragglerMonitor(evict_after=3)
    for _ in range(10):
        m.record(0.1)
    verdicts = [m.record(0.5) for _ in range(3)]
    assert verdicts[0]["straggler"]
    assert verdicts[-1]["evict"]


def test_host_aggregator_median():
    agg = HostTimingAggregator()
    for t in range(20):
        for h in ("h0", "h1", "h2", "h3"):
            agg.record(h, 0.1 if h != "h3" else 0.25)
    assert agg.stragglers() == ["h3"]


# ------------------------------------------------------------------- data
def test_synthetic_deterministic_and_seekable():
    a = synthetic.lm_batch(0, 5, 4, 16, 100)
    b = synthetic.lm_batch(0, 5, 4, 16, 100)
    c = synthetic.lm_batch(0, 6, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()


def test_prefetch_pipeline_order_and_seek():
    pipe = PrefetchPipeline(lambda s: {"x": np.full(3, s)}, depth=2,
                            device_put=False)
    try:
        for s in range(4):
            assert pipe(s)["x"][0] == s
        # seek backwards (rollback replay)
        assert pipe(2)["x"][0] == 2
        assert pipe(3)["x"][0] == 3
    finally:
        pipe.stop()


# -------------------------------------------------------------- optimizers
@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=0.05, clip_norm=1.0),
                                      lambda: adafactor(lr=0.05)])
def test_optimizers_reduce_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((2, 2))}
    st = opt.init(params)
    step = jnp.int32(0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    l0 = float(loss(params))
    for i in range(60):
        g = jax.grad(loss)(params)
        params, st, _ = opt.update(g, st, params, step + i)
    assert float(loss(params)) < l0 * 0.5


def test_adafactor_state_is_factored():
    opt = adafactor()
    p = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((7,))}
    st = opt.init(p)
    assert st["big"]["vr"].shape == (64,)
    assert st["big"]["vc"].shape == (32,)
    assert st["vec"]["v"].shape == (7,)


# ------------------------------------------------------------ compression
def test_int8_error_feedback_quantization():
    from repro.distributed.compression import (dequantize_int8,
                                               quantize_int8)
    g = np.random.default_rng(0).standard_normal(1000).astype(np.float32)
    scale = np.abs(g).max() / 127.0
    q = quantize_int8(jnp.asarray(g), scale)
    deq = np.asarray(dequantize_int8(q, scale))
    err = g - deq
    assert np.abs(err).max() <= scale * 0.5 + 1e-6
    # error feedback: quantizing (g + err) recovers most of the residual
    q2 = quantize_int8(jnp.asarray(g + err), scale)
    deq2 = np.asarray(dequantize_int8(q2, scale))
    assert np.abs(g + err - deq2).max() <= scale * 0.5 + 1e-6


def test_compressed_psum_pod_two_pods():
    """shard_map int8 cross-pod reduction ≈ fp32 mean, with error
    feedback shrinking the residual over rounds."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices (run via subprocess suite)")
