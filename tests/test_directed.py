"""Paper §8.2: directed graphs via in/out labels (+ the reachability
claim from the conclusion). hypothesis is optional (requirements-dev):
without it the property sweep falls back to fixed seeds."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import IndexConfig, ref
from repro.core.directed import DiISLabelIndex


def _digraph(n, e, seed, maxw=5):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    keep = src != dst
    w = rng.integers(1, maxw, keep.sum()).astype(np.float32)
    return src[keep], dst[keep], w


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_directed_exact(seed):
    n = 180
    src, dst, w = _digraph(n, 700, seed)
    idx = DiISLabelIndex.build(n, src, dst, w,
                               IndexConfig(l_cap=256, label_chunk=128))
    rng = np.random.default_rng(seed + 100)
    s = rng.integers(0, n, 120).astype(np.int32)
    t = rng.integers(0, n, 120).astype(np.int32)
    got = idx.query_host(s, t)
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(120), t]
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)


def test_asymmetry_preserved():
    """dist(s->t) != dist(t->s) must be answered per direction."""
    # a directed cycle: 0->1->2->0 with distinct weights
    src = np.asarray([0, 1, 2], np.int32)
    dst = np.asarray([1, 2, 0], np.int32)
    w = np.asarray([1.0, 2.0, 4.0], np.float32)
    idx = DiISLabelIndex.build(3, src, dst, w,
                               IndexConfig(l_cap=16, label_chunk=8))
    assert float(idx.query_host([0], [1])[0]) == 1.0
    assert float(idx.query_host([1], [0])[0]) == 6.0


def test_reachability():
    """Directed IS-LABEL answers reachability (paper conclusion)."""
    # two directed chains with a one-way bridge
    src = np.asarray([0, 1, 5, 6, 2], np.int32)
    dst = np.asarray([1, 2, 6, 7, 5], np.int32)
    w = np.ones(5, np.float32)
    idx = DiISLabelIndex.build(8, src, dst, w,
                               IndexConfig(l_cap=16, label_chunk=8))
    assert idx.reachable([0], [7])[0]            # 0->1->2->5->6->7
    assert not idx.reachable([7], [0])[0]


def _directed_property_case(seed, n):
    src, dst, w = _digraph(n, n * 4, seed)
    if len(src) == 0:
        return
    idx = DiISLabelIndex.build(n, src, dst, w,
                               IndexConfig(l_cap=128, label_chunk=64,
                                           d_cap=8))
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, 30).astype(np.int32)
    t = rng.integers(0, n, 30).astype(np.int32)
    got = idx.query_host(s, t)
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(30), t]
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 500), n=st.integers(20, 60))
    def test_directed_property(seed, n):
        _directed_property_case(seed, n)
else:
    @pytest.mark.parametrize("seed,n", [(0, 20), (17, 33), (101, 48),
                                        (404, 60)])
    def test_directed_property(seed, n):
        _directed_property_case(seed, n)
