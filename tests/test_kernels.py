"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes. ``backend="interpret"`` is passed
explicitly: the wrappers' default resolves to the jnp reference off-TPU,
and these tests exist to exercise the Pallas program itself. hypothesis
is optional (requirements-dev); without it the property sweeps fall back
to fixed parametrized cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.label_intersect.ops import label_intersect
from repro.kernels.label_intersect.ref import label_intersect_ref
from repro.kernels.minplus_matmul.ops import minplus_matmul
from repro.kernels.minplus_matmul.ref import minplus_matmul_ref
from repro.kernels.spmv_relax.ops import coo_to_ell, spmv_relax
from repro.kernels.spmv_relax.ref import spmv_relax_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (128, 128, 128), (1, 1, 1),
                                   (100, 37, 250), (130, 260, 5),
                                   (256, 512, 128)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_minplus_shapes(m, k, n, dtype):
    a = RNG.random((m, k)).astype(dtype) * 10
    b = RNG.random((k, n)).astype(dtype) * 10
    a[RNG.random(a.shape) < 0.3] = np.inf        # sparse-as-inf pattern
    got = minplus_matmul(jnp.asarray(a), jnp.asarray(b), backend="interpret")
    want = minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_minplus_block_shapes():
    a = RNG.random((64, 96)).astype(np.float32)
    b = RNG.random((96, 160)).astype(np.float32)
    for bm, bn, bk in [(32, 32, 32), (64, 128, 32), (16, 16, 96)]:
        got = minplus_matmul(jnp.asarray(a), jnp.asarray(b),
                             bm=bm, bn=bn, bk=bk, backend="interpret")
        want = minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)


def test_minplus_is_apsp_step():
    """(min,+) self-product squares path lengths: two products give
    4-hop-exact distances on a small graph."""
    n = 24
    adj = np.full((n, n), np.inf, np.float32)
    np.fill_diagonal(adj, 0)
    for _ in range(60):
        a, b = RNG.integers(0, n, 2)
        w = float(RNG.integers(1, 5))
        adj[a, b] = min(adj[a, b], w)
        adj[b, a] = min(adj[b, a], w)
    d2 = np.asarray(minplus_matmul(jnp.asarray(adj), jnp.asarray(adj),
                                   backend="interpret"))
    d4 = np.asarray(minplus_matmul(jnp.asarray(d2), jnp.asarray(d2),
                                   backend="interpret"))
    import scipy.sparse.csgraph as csg
    import scipy.sparse as sp
    full = csg.shortest_path(sp.csr_matrix(np.where(np.isfinite(adj), adj, 0)))
    reach4 = full.copy()
    # d4 >= true distance, equal where hop-count <= 4
    fin = np.isfinite(d4)
    assert (d4[fin] >= full[fin] - 1e-4).all()


@pytest.mark.parametrize("q,l,n_sent", [(1, 8, 50), (37, 100, 1000),
                                        (64, 256, 10_000), (5, 513, 300)])
def test_label_intersect_shapes(q, l, n_sent):
    def rows():
        out = np.full((q, l), n_sent, np.int32)
        for i in range(q):
            sz = RNG.integers(1, min(l, n_sent) + 1)
            out[i, :sz] = np.sort(RNG.choice(n_sent, sz, replace=False))
        return out
    ids_s, ids_t = rows(), rows()
    d_s = (RNG.random((q, l)) * 9).astype(np.float32)
    d_t = (RNG.random((q, l)) * 9).astype(np.float32)
    got = np.asarray(label_intersect(
        jnp.asarray(ids_s), jnp.asarray(d_s), jnp.asarray(ids_t),
        jnp.asarray(d_t), n_sent, backend="interpret"))
    want = np.asarray(label_intersect_ref(
        jnp.asarray(ids_s), jnp.asarray(d_s), jnp.asarray(ids_t),
        jnp.asarray(d_t), n_sent))
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-6)


def _label_intersect_property_case(q, l, seed):
    r = np.random.default_rng(seed)
    n_sent = 200
    ids_s = np.sort(np.stack([r.choice(n_sent, l, replace=False)
                              for _ in range(q)])).astype(np.int32)
    ids_t = np.sort(np.stack([r.choice(n_sent, l, replace=False)
                              for _ in range(q)])).astype(np.int32)
    d_s = r.random((q, l)).astype(np.float32)
    d_t = r.random((q, l)).astype(np.float32)
    got = np.asarray(label_intersect(jnp.asarray(ids_s), jnp.asarray(d_s),
                                     jnp.asarray(ids_t), jnp.asarray(d_t),
                                     n_sent, backend="interpret"))
    want = np.asarray(label_intersect_ref(jnp.asarray(ids_s),
                                          jnp.asarray(d_s),
                                          jnp.asarray(ids_t),
                                          jnp.asarray(d_t), n_sent))
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(q=st.integers(1, 16), l=st.integers(1, 64), seed=st.integers(0, 99))
    def test_label_intersect_property(q, l, seed):
        _label_intersect_property_case(q, l, seed)
else:
    @pytest.mark.parametrize("q,l,seed", [(1, 1, 0), (3, 17, 1), (16, 64, 7),
                                          (5, 33, 42)])
    def test_label_intersect_property(q, l, seed):
        _label_intersect_property_case(q, l, seed)


@pytest.mark.parametrize("v,e,q", [(20, 60, 3), (200, 900, 13),
                                   (513, 2000, 8)])
def test_spmv_relax_shapes(v, e, q):
    src = RNG.integers(0, v, e)
    dst = RNG.integers(0, v, e)
    w = RNG.integers(1, 5, e).astype(np.float32)
    ids, ws = coo_to_ell(v, src, dst, w)
    dist = np.full((q, v), np.inf, np.float32)
    dist[np.arange(q), RNG.integers(0, v, q)] = 0.0
    got = spmv_relax(jnp.asarray(dist), ids, ws, backend="interpret")
    want = spmv_relax_ref(jnp.asarray(dist), ids, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_spmv_relax_converges_to_sssp():
    """Iterating the kernel converges to single-source distances."""
    from repro.core.ref import dijkstra_oracle
    v, e = 60, 200
    src = RNG.integers(0, v, e)
    dst = RNG.integers(0, v, e)
    w = RNG.integers(1, 5, e).astype(np.float32)
    ids, ws = coo_to_ell(v, src, dst, w)
    dist = np.full((4, v), np.inf, np.float32)
    srcs = [0, 5, 10, 20]
    dist[np.arange(4), srcs] = 0.0
    d = jnp.asarray(dist)
    for _ in range(v):
        d = spmv_relax(d, ids, ws, backend="interpret")
    # duplicate (src,dst) pairs must keep min weight — use the dedup
    # oracle (scipy's COO->CSR sums duplicates)
    want = dijkstra_oracle(v, src, dst, w, srcs)
    got = np.asarray(d)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)


def test_kernel_engine_equivalence():
    """The Pallas label_intersect kernel returns the same μ as the
    production engine's searchsorted path on a real index."""
    from repro.core import ISLabelIndex, IndexConfig
    from repro.core.query import label_intersect_mu
    from repro.graphs import generators as gen
    n, src, dst, w = gen.er_graph(200, 3.0, seed=31)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=128, label_chunk=64))
    r = np.random.default_rng(0)
    s = r.integers(0, n, 32).astype(np.int32)
    t = r.integers(0, n, 32).astype(np.int32)
    ids_s, d_s = idx.lbl_ids[s], idx.lbl_d[s]
    ids_t, d_t = idx.lbl_ids[t], idx.lbl_d[t]
    mu_engine, _ = label_intersect_mu(ids_s, d_s, ids_t, d_t, n, 128)
    mu_kernel = label_intersect(ids_s, d_s, ids_t, d_t, n,
                                backend="interpret")
    a, b = np.asarray(mu_engine), np.asarray(mu_kernel)
    fin = np.isfinite(a)
    assert (np.isfinite(b) == fin).all()
    np.testing.assert_allclose(a[fin], b[fin], rtol=1e-6)


# ------------------------------------------------ minplus inf-padding
def test_minplus_inf_padding_edges():
    """inf is the (min,+) additive zero: all-inf rows/cols (the exact
    shape padding the dispatch layer feeds the kernel) must survive
    bitwise — inf rows stay inf, finite results never contaminated."""
    m, k, n = 32, 48, 64
    a = (RNG.integers(1, 9, (m, k))).astype(np.float32)
    b = (RNG.integers(1, 9, (k, n))).astype(np.float32)
    a[5, :] = np.inf                      # unreachable source row
    a[:, 7] = np.inf                      # dead intermediate (a-side)
    b[7, :] = np.inf                      # dead intermediate (b-side)
    b[:, 9] = np.inf                      # unreachable target col
    a[11, :] = np.inf
    b[:, 11] = np.inf
    got = np.asarray(minplus_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=8, bn=16, bk=16,
                                    backend="interpret"))
    want = np.asarray(minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    # integer weights: sums are exact, equality is bitwise
    np.testing.assert_array_equal(got[fin], want[fin])
    assert np.isinf(got[5]).all() and np.isinf(got[:, 9]).all()


def test_minplus_all_inf_block():
    a = np.full((16, 16), np.inf, np.float32)
    b = (RNG.integers(1, 9, (16, 16))).astype(np.float32)
    got = np.asarray(minplus_matmul(jnp.asarray(a), jnp.asarray(b),
                                    backend="interpret"))
    assert np.isinf(got).all()


# ------------------------------------------------ fused relax kernel
def _ell_graph(v, e, seed=0):
    r = np.random.default_rng(seed)
    src = r.integers(0, v, e)
    dst = r.integers(0, v, e)
    w = r.integers(1, 5, e).astype(np.float32)
    from repro.kernels.spmv_relax.ops import coo_to_ell as _c
    return _c(v, src, dst, w)


def test_fused_relax_matches_iterated_spmv():
    """One fused launch == the per-round spmv loop run to its fixed
    point: bitwise distances AND the same round count (reported as the
    max over per-block in-kernel exit rounds)."""
    from repro.kernels.spmv_relax.kernel import fused_relax_kernel
    v, q = 128, 16
    ids, ws = _ell_graph(v, 400)
    dist = np.full((q, v), np.inf, np.float32)
    dist[np.arange(q), RNG.integers(0, v, q)] = 0.0
    dist[q - 1, :] = np.inf               # all-inf row settles immediately
    d = jnp.asarray(dist)
    rounds_loop = 0
    while True:
        d2 = spmv_relax(d, ids, ws, backend="interpret")
        rounds_loop += 1
        if bool(jnp.all(~(d2 < d))):
            d = d2
            break
        d = d2
        assert rounds_loop < v
    out, blk_rounds = fused_relax_kernel(jnp.asarray(dist), ids, ws,
                                         max_rounds=v, bq=8,
                                         interpret=True)
    got, want = np.asarray(out), np.asarray(d)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_array_equal(got[fin], want[fin])
    assert int(np.max(np.asarray(blk_rounds))) == rounds_loop
    assert np.isinf(got[q - 1]).all()


def test_fused_relax_respects_max_rounds():
    """max_rounds truncates the fixed-point loop exactly like the
    launch-per-round path: k fused rounds == k spmv launches."""
    from repro.kernels.spmv_relax.kernel import fused_relax_kernel
    v, q = 128, 8
    ids, ws = _ell_graph(v, 300, seed=3)
    dist = np.full((q, v), np.inf, np.float32)
    dist[np.arange(q), RNG.integers(0, v, q)] = 0.0
    d = jnp.asarray(dist)
    for _ in range(2):
        d = spmv_relax(d, ids, ws, backend="interpret")
    out, blk_rounds = fused_relax_kernel(jnp.asarray(dist), ids, ws,
                                         max_rounds=2, bq=8,
                                         interpret=True)
    got, want = np.asarray(out), np.asarray(d)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_array_equal(got[fin], want[fin])
    assert int(np.max(np.asarray(blk_rounds))) <= 2


def test_fused_vmem_model_is_monotone():
    from repro.kernels.spmv_relax.kernel import fused_vmem_bytes
    assert fused_vmem_bytes(1024, 16) < fused_vmem_bytes(2048, 16)
    assert fused_vmem_bytes(1024, 16) < fused_vmem_bytes(1024, 32)
    # exact accounting: dist in+out blocks + ELL ids/w + gathered cand
    v, dw, bq = 512, 16, 8
    assert fused_vmem_bytes(v, dw, bq) == \
        4 * (2 * bq * v + 2 * v * dw + bq * v * dw)


# ----------------------------------------- packed (delta16) intersect
def test_label_intersect_packed_matches_plain():
    """Fused decode+join kernel == plain kernel on the decoded planes,
    bitwise, for both distance codecs (int32 integral / fp32 pass-
    through), including rows that are all pads."""
    from repro.core.labels import LabelRows, encode_labels
    from repro.kernels.label_intersect.ops import label_intersect_rows
    q, l, n = 24, 32, 5000
    r = np.random.default_rng(5)
    ids = (r.integers(0, 200, (q, 1))
           + np.cumsum(r.integers(1, 64, (q, l)), axis=1)).astype(np.int32)
    ids[::3, l - 5:] = n                 # pad tails
    ids[7, :] = n                        # fully padded row
    for d_plane in (r.integers(0, 50, (q, l)).astype(np.float32),
                    (r.random((q, l)) * 9).astype(np.float32)):
        d = np.where(ids < n, d_plane, np.inf).astype(np.float32)
        ids_t = np.roll(ids, 1, axis=0)
        d_t = np.roll(d, 1, axis=0)
        enc_s = encode_labels(ids, d, n)
        enc_t = encode_labels(ids_t, d_t, n)
        want = np.asarray(label_intersect(
            jnp.asarray(ids), jnp.asarray(d), jnp.asarray(ids_t),
            jnp.asarray(d_t), n, backend="interpret"))
        got = np.asarray(label_intersect_rows(
            LabelRows(*(jnp.asarray(x) for x in enc_s)),
            LabelRows(*(jnp.asarray(x) for x in enc_t)),
            n, codec="delta16", backend="interpret"))
        fin = np.isfinite(want)
        assert (np.isfinite(got) == fin).all()
        np.testing.assert_array_equal(got[fin], want[fin])
        assert np.isinf(got[7])
