"""Tier-1 tests for the ``repro.shard`` subsystem (docs/SHARDING.md).

Covers: shard assignment invariants, partition round-trip (reassembled
labels == original), bitwise sharded-vs-unsharded query equality across
backends × shard counts {1, 2, 4} × strategies, the single-collective
guarantee, sharded save→load→serve, zero-compiles-after-warmup on the
sharded lane, and a mixed sharded/unsharded registry.

Multi-shard cases need >1 device: they run in subprocesses under
``--xla_force_host_platform_device_count=4`` (this process must keep
seeing the real 1-CPU world, per the dry-run isolation rule); the
P=1 paths run in-process on the real device.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import ISLabelIndex, IndexConfig
from repro.graphs import generators as gen
from repro.shard import (REPLICATED, ShardedIndex, assign_shards,
                         partition_labels, unpartition_labels)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n_dev: int = 4, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="module")
def index():
    n, src, dst, w = gen.er_graph(400, 2.5, seed=5)
    return ISLabelIndex.build(n, src, dst, w,
                              IndexConfig(l_cap=128, label_chunk=128))


# ------------------------------------------------------------ assignment
@pytest.mark.parametrize("strategy", ["hash", "level"])
def test_assign_shards_invariants(index, strategy):
    so = assign_shards(index.level, index.k, 4, strategy=strategy)
    assert so.shape == (index.n + 1,) and so.dtype == np.int32
    # top level (the core) and the sentinel row are replicated
    assert np.all(so[:index.n][index.level == index.k] == REPLICATED)
    assert so[index.n] == REPLICATED
    movable = so[:index.n][index.level < index.k]
    assert movable.min(initial=0) >= 0 and movable.max(initial=0) < 4
    # deterministic
    again = assign_shards(index.level, index.k, 4, strategy=strategy)
    assert np.array_equal(so, again)


def test_assign_shards_level_strategy_balances_each_level(index):
    so = assign_shards(index.level, index.k, 2, strategy="level")
    for lv in np.unique(index.level[index.level < index.k]):
        counts = np.bincount(so[:index.n][index.level == lv], minlength=2)
        assert abs(int(counts[0]) - int(counts[1])) <= 1, (lv, counts)


def test_assign_shards_replicate_top_widens_replication(index):
    so = assign_shards(index.level, index.k, 2, replicate_top=index.k)
    assert np.all(so == REPLICATED)    # every level replicated


def test_assign_shards_rejects_bad_args(index):
    with pytest.raises(ValueError):
        assign_shards(index.level, index.k, 0)
    with pytest.raises(ValueError):
        assign_shards(index.level, index.k, 2, strategy="nope")
    with pytest.raises(ValueError):
        assign_shards(index.level, index.k, 2, replicate_top=0)


# ------------------------------------------------------- partition logic
@pytest.mark.parametrize("strategy", ["hash", "level"])
@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_partition_round_trip(index, strategy, num_shards):
    """unpartition(partition(labels)) == labels, bit for bit."""
    so = assign_shards(index.level, index.k, num_shards, strategy=strategy)
    blocks = partition_labels(index.lbl_ids, index.lbl_d, index.lbl_pred,
                              index.n, so, num_shards)
    assert blocks.ids.shape[0] == num_shards
    assert blocks.cap % 8 == 0
    ids, d, pred = unpartition_labels(blocks, index.n, index.cfg.l_cap)
    assert np.array_equal(ids, np.asarray(index.lbl_ids))
    assert np.array_equal(d, np.asarray(index.lbl_d))
    assert np.array_equal(pred, np.asarray(index.lbl_pred))


def test_partition_blocks_keep_rows_sorted_and_core_replicated(index):
    so = assign_shards(index.level, index.k, 2)
    blocks = partition_labels(index.lbl_ids, index.lbl_d, index.lbl_pred,
                              index.n, so, 2)
    core = set(np.flatnonzero(index.level == index.k).tolist())
    full = np.asarray(index.lbl_ids)
    for p in range(2):
        blk = blocks.ids[p]
        # id-sorted with the sentinel n padding the tail of each row
        assert np.all(np.diff(blk.astype(np.int64), axis=1) >= 0)
        # every core ancestor of every row is present in every shard
        for v in range(0, index.n, 37):
            row_core = {int(u) for u in full[v] if int(u) in core}
            blk_core = {int(u) for u in blk[v] if int(u) in core}
            assert row_core == blk_core, (p, v)


# ----------------------------------------- single device (P=1) in-process
def test_sharded_index_single_shard_bitwise(index):
    sidx = ShardedIndex.from_index(index, 1)
    r = np.random.default_rng(0)
    s = r.integers(0, index.n, 64).astype(np.int32)
    t = r.integers(0, index.n, 64).astype(np.int32)
    want_ans, want_rounds = index.engine.batch_fn()(s, t)
    got_ans, got_rounds = sidx.engine.batch_fn()(s, t)
    assert np.array_equal(np.asarray(got_ans), np.asarray(want_ans))
    assert int(got_rounds) == int(want_rounds)
    assert np.array_equal(np.asarray(sidx.engine.mu_batch_fn()(s, t)),
                          np.asarray(index.engine.mu_batch_fn()(s, t)))
    assert sidx.engine.collective_count() == 1


def test_sharded_index_save_load_round_trip(index, tmp_path):
    sidx = ShardedIndex.from_index(index, 1, strategy="hash")
    sidx.save(tmp_path / "sh")
    again = ShardedIndex.load(tmp_path / "sh")
    assert again.num_shards == 1 and again.strategy == "hash"
    assert np.array_equal(np.asarray(again.lbl_ids),
                          np.asarray(sidx.lbl_ids))
    r = np.random.default_rng(1)
    s = r.integers(0, index.n, 32).astype(np.int32)
    t = r.integers(0, index.n, 32).astype(np.int32)
    assert np.array_equal(np.asarray(again.query(s, t)),
                          np.asarray(index.query(s, t)))


def test_mesh_larger_than_devices_rejected(index):
    import jax
    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError):
        ShardedIndex.from_index(index, too_many)


# --------------------------------- multi-device (forced 4-CPU) subprocess
def test_sharded_query_bitwise_across_backends_and_shards():
    """ans/rounds/μ bitwise vs QueryEngine for P ∈ {1,2,4} × backends ×
    strategies, under forced 4-device CPU; exactly one collective."""
    out = run_with_devices("""
        import numpy as np
        from repro.core import ISLabelIndex, IndexConfig
        from repro.graphs import generators as gen
        from repro.shard import ShardedIndex
        n, src, dst, w = gen.er_graph(400, 2.5, seed=5)
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=128, label_chunk=128))
        r = np.random.default_rng(0)
        s = r.integers(0, n, 64).astype(np.int32)
        t = r.integers(0, n, 64).astype(np.int32)
        for backend in ("reference", "interpret"):
            want_ans, want_rounds = idx.engine.batch_fn(backend)(s, t)
            want_mu = idx.engine.mu_batch_fn(backend)(s, t)
            for strategy in ("level", "hash"):
                for P in (1, 2, 4):
                    sidx = ShardedIndex.from_index(idx, P, strategy=strategy)
                    ans, rounds = sidx.engine.batch_fn(backend)(s, t)
                    tag = (backend, strategy, P)
                    assert np.array_equal(np.asarray(ans),
                                          np.asarray(want_ans)), tag
                    assert int(rounds) == int(want_rounds), tag
                    mu = sidx.engine.mu_batch_fn(backend)(s, t)
                    assert np.array_equal(np.asarray(mu),
                                          np.asarray(want_mu)), tag
                    assert sidx.engine.collective_count(
                        backend=backend) == 1, tag
        print("ok")
    """)
    assert "ok" in out


def test_sharded_save_load_serve_and_zero_compiles():
    """save→load→DistanceServer over 4 shards: served answers bitwise ==
    the unsharded index, zero compiles after warmup on the sharded lane,
    and a registry hosts sharded + unsharded side by side."""
    out = run_with_devices("""
        import numpy as np, tempfile
        from repro.core import ISLabelIndex, IndexConfig
        from repro.graphs import generators as gen
        from repro.serve import DistanceServer, IndexRegistry, make_trace
        from repro.shard import ShardedIndex
        n, src, dst, w = gen.er_graph(400, 2.5, seed=5)
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=128, label_chunk=128))
        d = tempfile.mkdtemp()
        ShardedIndex.from_index(idx, 4).save(d)
        sidx = ShardedIndex.load(d)
        assert sidx.num_shards == 4
        srv = DistanceServer(sidx, buckets=(8, 32), max_wait_ms=1.0,
                             cache_size=4096)
        sizes = srv.compile_cache_sizes()
        tr = make_trace("hotspot", n=n, num_requests=300, rate_qps=2e4,
                        seed=4)
        got = srv.serve_trace(tr)
        want = np.asarray(idx.query(tr.s, tr.t), np.float32)
        assert np.array_equal(got, want)
        if -1 not in sizes.values():
            assert srv.compile_cache_sizes() == sizes   # zero new compiles
        assert srv.stats()["graph"]["shards"] == 4
        # mixed registry: sharded and unsharded side by side
        reg = IndexRegistry()
        reg.register("flat", idx, buckets=(8, 32), warmup=False)
        reg.register("sharded", sidx, buckets=(8, 32), warmup=False)
        tr2 = make_trace("uniform", n=n, num_requests=120, rate_qps=2e4,
                         seed=6)
        a = reg.get("flat").serve_trace(tr2)
        b = reg.get("sharded").serve_trace(tr2)
        assert np.array_equal(a, b)
        print("ok")
    """)
    assert "ok" in out
