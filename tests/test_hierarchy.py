"""Structural invariants of the vertex hierarchy (paper Definitions 1+4,
Lemmas 1-3) + hypothesis property tests.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import IndexConfig, build_hierarchy, ref
from repro.core.labeling import build_labels
from repro.graphs import generators as gen


def _edge_sets_per_level(n, src, dst, w, cfg):
    """Re-run peeling, keeping each level's graph for invariant checks."""
    from repro.core.hierarchy import peel_level
    import jax
    from repro.graphs import csr as gcsr
    e_cap = cfg.e_cap(len(src))
    g = gcsr.from_host_edges(src, dst, w, n, e_cap)
    return g


def test_levels_partition_vertices():
    n, src, dst, w = gen.er_graph(300, 3.0, seed=1)
    cfg = IndexConfig()
    h = build_hierarchy(n, src, dst, w, cfg)
    assert h.level.min() >= 1 and h.level.max() == h.k
    assert sum(h.level_sizes) + (h.level == h.k).sum() == n


def test_independence_property():
    """No edge of G_i connects two level-i vertices (vertex independence):
    equivalently, no up-edge of v points to a same-level vertex."""
    n, src, dst, w = gen.rmat_graph(9, avg_deg=6.0, seed=2)
    h = build_hierarchy(n, src, dst, w, IndexConfig())
    for v in range(n):
        if h.level[v] == h.k:
            continue
        nbrs = h.up_ids[v][h.up_ids[v] < n]
        assert (h.level[nbrs] > h.level[v]).all(), \
            f"vertex {v} level {h.level[v]} has non-ascending up-edge"


def test_up_edges_within_cap():
    n, src, dst, w = gen.er_graph(400, 4.0, seed=3)
    cfg = IndexConfig(d_cap=8)
    h = build_hierarchy(n, src, dst, w, cfg)
    assert h.up_ids.shape[1] == 8
    deg = (h.up_ids[:n] < n).sum(1)
    assert (deg[h.level < h.k] <= 8).all()


def test_core_distance_preservation():
    """Lemma 1/2: distances between core vertices in G_k equal distances
    in G (the augmenting edges preserve them exactly)."""
    n, src, dst, w = gen.er_graph(200, 3.0, seed=4)
    h = build_hierarchy(n, src, dst, w, IndexConfig())
    core = np.flatnonzero(h.level == h.k)
    if len(core) < 2 or len(h.core_src) == 0:
        pytest.skip("graph fully peeled")
    # distances in G_k (its own edge list)
    pos = {int(v): i for i, v in enumerate(core)}
    ls = np.asarray([pos[int(x)] for x in h.core_src])
    ld = np.asarray([pos[int(x)] for x in h.core_dst])
    sub = ref.dijkstra_oracle(len(core), ls, ld, h.core_w,
                              np.arange(min(20, len(core))))
    full = ref.dijkstra_oracle(n, src, dst, w, core[:20])
    for i in range(min(20, len(core))):
        want = full[i][core]
        got = sub[i]
        fin = np.isfinite(want)
        np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)
        assert (np.isfinite(got) == fin).all()


def test_label_ancestor_distances_are_upper_bounds():
    """Def. 3: label distances are upper bounds on true distances."""
    n, src, dst, w = gen.er_graph(150, 3.0, seed=5)
    cfg = IndexConfig(l_cap=256, label_chunk=64)
    h = build_hierarchy(n, src, dst, w, cfg)
    ids, d, _ = build_labels(h, cfg)
    ids = np.asarray(ids)[:n]
    d = np.asarray(d)[:n]
    oracle = ref.dijkstra_oracle(n, src, dst, w, np.arange(n))
    for v in range(0, n, 7):
        row = ids[v]
        ok = row < n
        assert (d[v][ok] >= oracle[v][row[ok]] - 1e-4).all()
        # self entry present with d=0
        j = np.searchsorted(row, v)
        assert row[j] == v and d[v][j] == 0.0


def test_label_rows_sorted_unique():
    n, src, dst, w = gen.rmat_graph(8, avg_deg=5.0, seed=6)
    cfg = IndexConfig(l_cap=256, label_chunk=128)
    h = build_hierarchy(n, src, dst, w, cfg)
    ids, _, _ = build_labels(h, cfg)
    ids = np.asarray(ids)[:n]
    for v in range(0, n, 11):
        row = ids[v][ids[v] < n]
        assert (np.diff(row) > 0).all(), "label row not sorted/unique"


def _hierarchy_invariants_case(seed, deg):
    n, src, dst, w = gen.er_graph(80, avg_deg=deg, seed=seed)
    h = build_hierarchy(n, src, dst, w, IndexConfig(d_cap=8))
    # partition + ascending levels along up-edges
    assert sum(h.level_sizes) + (h.level == h.k).sum() == n
    for v in range(n):
        if h.level[v] < h.k:
            nbrs = h.up_ids[v][h.up_ids[v] < n]
            assert (h.level[nbrs] > h.level[v]).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), deg=st.floats(1.0, 5.0))
    def test_property_hierarchy_invariants(seed, deg):
        _hierarchy_invariants_case(seed, deg)
else:
    @pytest.mark.parametrize("seed,deg", [(0, 1.0), (42, 2.5), (7, 3.7),
                                          (9001, 5.0)])
    def test_property_hierarchy_invariants(seed, deg):
        _hierarchy_invariants_case(seed, deg)


def test_overflow_detection():
    n, src, dst, w = gen.caveman_graph(6, 10, seed=7)
    with pytest.raises(RuntimeError, match="label capacity|edge capacity"):
        cfg = IndexConfig(l_cap=2, label_chunk=32)
        h = build_hierarchy(n, src, dst, w, cfg)
        build_labels(h, cfg)
