"""VC-Index baseline (Table 8 comparator): exactness + the paper's
hierarchy-value claim (multi-level peeling shrinks the search core far
below the one-level vertex-cover construction)."""
import numpy as np

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.core.vc_baseline import build_vc_index
from repro.graphs import generators as gen


def test_vc_baseline_exact():
    n, src, dst, w = gen.rmat_graph(9, avg_deg=6.0, seed=3)
    idx = build_vc_index(n, src, dst, w,
                         IndexConfig(l_cap=512, label_chunk=256))
    assert idx.k == 2
    r = np.random.default_rng(0)
    s = r.integers(0, n, 100).astype(np.int32)
    t = r.integers(0, n, 100).astype(np.int32)
    got = idx.query_host(s, t)
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(100), t]
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)


def test_hierarchy_beats_one_level():
    """Paper Tables 6/8: the multi-level hierarchy leaves a (much)
    smaller core than the one-level vertex-cover scheme — the mechanism
    behind IS-LABEL's query-time win."""
    n, src, dst, w = gen.rmat_graph(10, avg_deg=6.0, seed=5)
    cfg = IndexConfig(l_cap=512, label_chunk=512)
    multi = ISLabelIndex.build(n, src, dst, w, cfg)
    one = build_vc_index(n, src, dst, w, cfg)
    assert multi.k > 2
    assert multi.stats.n_core < one.stats.n_core
    # both exact on the same queries
    r = np.random.default_rng(1)
    s = r.integers(0, n, 50).astype(np.int32)
    t = r.integers(0, n, 50).astype(np.int32)
    np.testing.assert_allclose(multi.query_host(s, t), one.query_host(s, t))
