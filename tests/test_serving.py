"""Tier-1 tests for the ``repro.serve`` subsystem (docs/SERVING.md).

Covers: micro-batcher bucket/deadline mechanics, LRU cache, load
generator scenarios, metrics export, serving exactness vs
``ISLabelIndex.query`` (bitwise, per scenario), zero-compiles-after-
warmup, μ-lane routing soundness, the index registry, and the
save/load → serve round trip across kernel backends.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import ISLabelIndex, IndexConfig
from repro.graphs import generators as gen
from repro.serve import (DistanceServer, IndexRegistry, LRUCache,
                         MicroBatcher, PendingRequest, make_trace,
                         mu_exact_mask)

BUCKETS = (8, 32)


@pytest.fixture(scope="module")
def graph():
    # sparse ER: the BTC-like regime — small components exist, so the
    # μ-only fast lane sees real traffic (routing is exercised).
    return gen.er_graph(700, 2.2, seed=2)


@pytest.fixture(scope="module")
def index(graph):
    n, src, dst, w = graph
    return ISLabelIndex.build(n, src, dst, w, IndexConfig(l_cap=256))


@pytest.fixture(scope="module")
def server(index):
    return DistanceServer(index, buckets=BUCKETS, max_wait_ms=1.0,
                          cache_size=4096)


# --------------------------------------------------------------- batcher
def _reqs(ts):
    return [PendingRequest(i, i, i, t) for i, t in enumerate(ts)]


def test_batcher_full_bucket_flush():
    mb = MicroBatcher(buckets=(4, 8), max_wait_s=1.0)
    for r in _reqs([0.0] * 9):
        mb.add(r)
    b = mb.drain(now=0.0)
    assert b.bucket == 8 and len(b.requests) == 8 and b.fill == 1.0
    # remainder is below every bucket and inside the deadline: waits
    assert mb.drain(now=0.0) is None and len(mb) == 1


def test_batcher_deadline_flush_pads_to_smallest_bucket():
    mb = MicroBatcher(buckets=(4, 8), max_wait_s=0.010)
    for r in _reqs([0.0, 0.001, 0.002]):
        mb.add(r)
    assert mb.drain(now=0.005) is None          # deadline not reached
    b = mb.drain(now=0.011)
    assert b is not None and b.bucket == 4 and len(b.requests) == 3
    assert b.t_flush == pytest.approx(0.010)    # flush fired at deadline
    assert mb.drain(now=1.0) is None            # queue drained


def test_batcher_force_flush_and_bucket_choice():
    mb = MicroBatcher(buckets=(4, 8), max_wait_s=10.0)
    for r in _reqs([0.0] * 6):
        mb.add(r)
    b = mb.drain(now=0.0, force=True)
    assert b.bucket == 8 and len(b.requests) == 6   # smallest bucket >= 6
    assert mb.next_deadline() is None


def test_batcher_rejects_bad_buckets():
    with pytest.raises(ValueError):
        MicroBatcher(buckets=())
    with pytest.raises(ValueError):
        MicroBatcher(buckets=(0, 4))


# ----------------------------------------------------------------- cache
def test_lru_cache_eviction_and_hit_rate():
    c = LRUCache(2)
    c.put(1, 2, 5.0)
    c.put(3, 4, 7.0)
    assert c.get(1, 2) == 5.0           # refreshes (1,2)
    c.put(5, 6, 9.0)                    # evicts (3,4)
    assert c.get(3, 4) is None
    assert c.get(1, 2) == 5.0 and c.get(5, 6) == 9.0
    assert c.hits == 3 and c.misses == 1 and len(c) == 2


def test_lru_cache_symmetric_and_disabled():
    c = LRUCache(8, symmetric=True)
    c.put(2, 1, 3.0)
    assert c.get(1, 2) == 3.0
    off = LRUCache(0)
    off.put(1, 2, 3.0)
    assert off.get(1, 2) is None and len(off) == 0


# --------------------------------------------------------------- loadgen
@pytest.mark.parametrize("scenario", ["uniform", "hotspot", "bursty",
                                      "repeated"])
def test_loadgen_traces_well_formed(scenario):
    tr = make_trace(scenario, n=500, num_requests=300, rate_qps=1e4, seed=1)
    assert len(tr) == 300 and tr.name == scenario
    assert np.all(np.diff(tr.arrival_s) >= 0) and tr.arrival_s[0] >= 0
    for arr in (tr.s, tr.t):
        assert arr.dtype == np.int32
        assert arr.min() >= 0 and arr.max() < 500


def test_loadgen_scenario_shapes():
    hot = make_trace("hotspot", n=2000, num_requests=1000, seed=1)
    uni = make_trace("uniform", n=2000, num_requests=1000, seed=1)
    # zipf endpoints concentrate: far fewer distinct sources than uniform
    assert len(np.unique(hot.s)) < 0.5 * len(np.unique(uni.s))
    rep = make_trace("repeated", n=2000, num_requests=1000, pool=64, seed=1)
    pairs = {(int(a), int(b)) for a, b in zip(rep.s, rep.t)}
    assert len(pairs) <= 64
    with pytest.raises(ValueError):
        make_trace("nope", n=10, num_requests=1)


# --------------------------------------------------- serving exactness
@pytest.mark.parametrize("scenario", ["uniform", "hotspot", "bursty",
                                      "repeated"])
def test_serve_trace_matches_index_bitwise(index, server, scenario):
    tr = make_trace(scenario, n=index.n, num_requests=300, rate_qps=2e4,
                    seed=4)
    got = server.serve_trace(tr)
    want = np.asarray(index.query(tr.s, tr.t), np.float32)
    assert np.array_equal(got, want), scenario


def test_zero_compiles_after_warmup(index, server):
    # warmup compiled (at least) one executable per (lane, bucket)
    # shape; the jit caches are shared per (engine, backend), so other
    # servers over the same index may have added shapes — the serving
    # guarantee is the delta, not the absolute count.
    sizes = server.compile_cache_sizes()
    if -1 in sizes.values():
        pytest.skip("this jax does not expose jit cache sizes")
    assert all(n >= len(BUCKETS) for n in sizes.values())
    tr = make_trace("bursty", n=index.n, num_requests=400, rate_qps=5e4,
                    seed=5)
    server.serve_trace(tr)
    # serving any trace triggers no further compiles.
    assert server.compile_cache_sizes() == sizes


def test_zero_compiles_exact_counts_on_private_index():
    # on an index served by exactly one server the counts are exact:
    # one compiled shape per (lane, bucket).
    n, src, dst, w = gen.er_graph(200, 3.0, seed=4)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=128, label_chunk=64))
    srv = DistanceServer(idx, buckets=(8, 16), max_wait_ms=1.0)
    sizes = srv.compile_cache_sizes()
    if -1 in sizes.values():
        pytest.skip("this jax does not expose jit cache sizes")
    assert sizes == {"mu": 2, "full": 2}
    srv.serve_trace(make_trace("uniform", n=n, num_requests=150, seed=5))
    assert srv.compile_cache_sizes() == {"mu": 2, "full": 2}


def test_cache_hits_on_repeated_traffic(index):
    srv = DistanceServer(index, buckets=BUCKETS, max_wait_ms=1.0,
                         cache_size=4096)
    tr = make_trace("repeated", n=index.n, num_requests=400, pool=50, seed=6)
    got = srv.serve_trace(tr)
    snap = srv.metrics.snapshot()
    assert snap["cache_hit_rate"] > 0.5
    want = np.asarray(index.query(tr.s, tr.t), np.float32)
    assert np.array_equal(got, want)


def test_routing_sends_mu_exact_traffic_to_fast_lane(index, server):
    no_core = mu_exact_mask(index)
    # the sparse ER graph has small components that never reach the core
    assert no_core[:index.n].any() and not no_core[:index.n].all()
    s = np.flatnonzero(no_core[:index.n])[:4].astype(np.int64)
    t = np.full_like(s, int(np.flatnonzero(~no_core[:index.n])[0]))
    assert list(server.route(s, t)) == ["mu"] * len(s)
    # both-core-reaching pairs must take the full path
    cs = np.flatnonzero(~no_core[:index.n])[:4].astype(np.int64)
    assert list(server.route(cs, cs[::-1])) == ["full"] * len(cs)


def test_serve_metrics_snapshot_and_json(index, server):
    tr = make_trace("uniform", n=index.n, num_requests=200, rate_qps=2e4,
                    seed=7)
    server.serve_trace(tr)
    snap = server.metrics.snapshot()
    for key in ("served", "qps_compute", "qps_offered", "latency_ms",
                "batch_fill_ratio", "cache_hit_rate", "lanes",
                "bucket_counts"):
        assert key in snap
    assert snap["served"] > 0 and snap["qps_compute"] > 0
    assert 0 < snap["batch_fill_ratio"] <= 1
    assert set(snap["lanes"]) == {"mu", "full", "path"}
    doc = json.loads(server.metrics.to_json(extra_field=1))
    assert doc["extra_field"] == 1 and doc["served"] == snap["served"]


def test_submit_pump_low_level_api(index):
    srv = DistanceServer(index, buckets=BUCKETS, max_wait_ms=1.0,
                         cache_size=16)
    r1 = srv.submit(1, 2, now=0.0)
    assert srv.take_result(r1) is None          # still queued
    assert srv.pump(now=0.0) == 0               # inside the deadline
    assert srv.pump(now=0.002) == 1             # deadline expired
    v1 = srv.take_result(r1)
    assert v1 is not None
    r2 = srv.submit(1, 2, now=0.003)            # cache hit: immediate
    assert srv.take_result(r2) == v1


# -------------------------------------------------------------- registry
def test_registry_hosts_multiple_named_indexes(index, tmp_path):
    index.save(tmp_path / "g")
    reg = IndexRegistry()
    reg.register("live", index, buckets=BUCKETS, warmup=False)
    reg.register("loaded", ISLabelIndex.load(tmp_path / "g"),
                 buckets=BUCKETS, warmup=False)
    assert reg.names() == ["live", "loaded"] and len(reg) == 2
    tr = make_trace("uniform", n=index.n, num_requests=60, rate_qps=2e4,
                    seed=8)
    a = reg.get("live").serve_trace(tr)
    b = reg.get("loaded").serve_trace(tr)
    assert np.array_equal(a, b)
    stats = reg.stats()
    assert stats["live"]["served"] == stats["loaded"]["served"] == 60
    reg.unregister("loaded")
    assert "loaded" not in reg
    with pytest.raises(KeyError):
        reg.get("loaded")


# ------------------------------------- save/load round trip × backends
@pytest.mark.parametrize("backend", ["reference", "interpret"])
def test_save_load_serve_round_trip_across_backends(index, tmp_path,
                                                    backend):
    """A loaded index served through the subsystem returns answers
    bitwise-identical to the freshly built one, on every backend."""
    index.save(tmp_path / "idx")
    loaded = ISLabelIndex.load(tmp_path / "idx")
    tr = make_trace("hotspot", n=index.n, num_requests=120, rate_qps=2e4,
                    seed=9)
    fresh = DistanceServer(index, buckets=(16,), max_wait_ms=1.0,
                           backend=backend)
    again = DistanceServer(loaded, buckets=(16,), max_wait_ms=1.0,
                           backend=backend)
    a = fresh.serve_trace(tr)
    b = again.serve_trace(tr)
    assert np.array_equal(a, b)
    want = np.asarray(index.query(tr.s, tr.t), np.float32)
    assert np.array_equal(a, want)


def test_refresh_after_index_mutation(tmp_path):
    # own tiny index: §8.3 mutators change it in place
    n, src, dst, w = gen.er_graph(200, 3.0, seed=3)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=128, label_chunk=64))
    srv = DistanceServer(idx, buckets=(16,), max_wait_ms=1.0,
                         cache_size=1024)
    tr = make_trace("repeated", n=n, num_requests=80, pool=30, seed=10)
    srv.serve_trace(tr)                      # populates the cache
    u = int(np.flatnonzero(idx.level < idx.k)[0])
    idx.delete_vertex(u)
    srv.refresh()                            # drop cache, remask, rebind
    assert len(srv.cache) == 0
    got = srv.serve_trace(tr)
    want = np.asarray(idx.query(tr.s, tr.t), np.float32)
    assert np.array_equal(got, want)


def test_wall_clock_pump_never_records_negative_latency(index):
    srv = DistanceServer(index, buckets=(8,), max_wait_ms=1.0,
                         cache_size=0)
    srv.submit(1, 2, now=0.0)
    srv.submit(3, 4, now=0.005)   # arrives after the oldest's deadline
    assert srv.pump(now=0.005, force=True) == 2
    assert all(lat >= 0 for lat in srv.metrics.latencies)


def test_classify_accepts_scalars_and_device_arrays(index):
    import jax.numpy as jnp
    eng = index.engine
    host = eng.classify(np.array([0, 1]), np.array([2, 3]), index.level,
                        index.k)
    dev = eng.classify(jnp.array([0, 1]), jnp.array([2, 3]),
                       jnp.asarray(index.level), index.k)
    assert np.array_equal(host, dev)
    one = eng.classify(0, 2, index.level, index.k)
    assert one.shape == (1,) and one[0] == host[0]
    assert set(np.unique(host)) <= {1, 2, 3}


# --------------------------------------- versioned mutation lane (§8.3)
@pytest.fixture(scope="module")
def vindex():
    """Base graph plus 8 preallocated spare ids for live inserts."""
    n, src, dst, w = gen.er_graph(180, 2.4, seed=4)
    return ISLabelIndex.build(n + 8, src, dst, w,
                              IndexConfig(l_cap=128, label_chunk=64))


def _vserver(vindex, **kw):
    kw.setdefault("buckets", (8, 32))
    kw.setdefault("max_wait_ms", 1.0)
    return DistanceServer(vindex, versioned=True, **kw)


def _bridge(vindex, max_w=9.0):
    """A spare u plus two core endpoints whose distance a unit-weight
    bridge through u provably shortens (d > 2)."""
    core = np.asarray(vindex.core_ids, np.int32)
    u = vindex.n - 1                              # last spare, never core
    aa, bb = np.meshgrid(core, core, indexing="ij")
    d = np.asarray(vindex.query(aa.ravel(), bb.ravel()), np.float32)
    j = np.flatnonzero((d > 2.0) & (d < max_w))
    if not len(j):
        raise RuntimeError("no bridgeable core pair in fixture graph")
    return u, int(aa.ravel()[j[0]]), int(bb.ravel()[j[0]]), d[j[0]]


def test_versioned_readwrite_serves_exact_with_zero_compiles(vindex):
    srv = _vserver(vindex, cache_size=1024)
    srv.warmup()
    pre = srv.compile_cache_sizes()
    nb = vindex.n - 8
    tr = make_trace("readwrite", n=vindex.n, num_requests=400,
                    rate_qps=5e4, seed=1, write_ratio=0.05, n_read=nb,
                    spares=range(nb, vindex.n), attach_to=vindex.core_ids)
    ans, vids = srv.serve_readwrite_trace(tr)
    assert srv.compile_cache_sizes() == pre     # zero recompiles
    reads = np.asarray([i for i in range(len(tr)) if tr.writes[i] is None])
    writes = np.asarray([i for i in range(len(tr))
                         if tr.writes[i] is not None])
    assert np.isnan(ans[writes]).all() and not np.isnan(ans[reads]).any()
    # reads answered on the final version match the mutated host oracle
    seg = reads[vids[reads] == vids.max()]
    want = np.asarray(srv.index.query(tr.s[seg], tr.t[seg]), np.float32)
    assert np.array_equal(ans[seg].astype(np.float32), want)
    snap = srv.stats()
    assert snap["mutations"] == tr.meta["writes"]
    assert snap["versions"]["current"] == tr.meta["writes"]
    assert snap["versions"]["live"] == [tr.meta["writes"]]
    srv.drain()


def test_per_version_cache_isolation_no_stale_hits(vindex):
    from repro.serve import MutationOp
    srv = _vserver(vindex, cache_size=256)
    u, a, b, d_old = _bridge(vindex)
    r1 = srv.submit(a, b, now=0.0)
    srv.pump(now=0.0, force=True)
    assert srv.take_result(r1) == d_old
    r2 = srv.submit(a, b, now=0.001)             # same version: cache hit
    assert srv.take_result(r2) == d_old
    assert srv.metrics.cache_hits == 1
    srv.submit_mutation([MutationOp("insert", u, (a, b), (1.0, 1.0))],
                        now=0.002)
    assert len(srv.cache) == 0                   # swap clears the cache
    r3 = srv.submit(a, b, now=0.003)
    srv.pump(now=0.003, force=True)
    got = srv.take_result(r3)
    assert got == np.float32(2.0) and got != d_old   # not the stale value
    assert srv.metrics.cache_hits == 1           # r3 was computed, not hit
    srv.drain()


def test_swap_atomicity_inflight_batch_completes_on_old_version(vindex):
    from repro.serve import MutationOp
    srv = _vserver(vindex, cache_size=0, max_wait_ms=1e6)
    u, a, b, d_old = _bridge(vindex)
    rid = srv.submit(a, b, now=0.0)              # queued, deadline far off
    assert srv.take_result(rid) is None
    v = srv.submit_mutation([MutationOp("insert", u, (a, b), (1.0, 1.0))],
                            now=0.0)
    # the swap force-flushed the in-flight read on its submit-time
    # version: it sees the pre-mutation distance
    assert srv.take_result(rid) == d_old
    rid2 = srv.submit(a, b, now=0.1)
    srv.pump(now=0.1, force=True)
    assert srv.take_result(rid2) == np.float32(2.0)
    assert srv.versions.current is v
    srv.drain()


def test_versioned_mode_guards(vindex):
    srv = _vserver(vindex)
    with pytest.raises(ValueError, match="submit_mutation"):
        srv.refresh()
    with pytest.raises(ValueError):
        DistanceServer(vindex, versioned=True, path_hop_caps=(32,))
    srv.drain()


def test_registry_replacement_goes_through_drain(vindex):
    """Regression: ``register`` on a taken name used to silently drop
    the old server with its queued requests and pinned versions."""
    from repro.serve import MutationOp
    reg = IndexRegistry()
    old = reg.register("g", vindex, buckets=(8,), max_wait_ms=1e6,
                       warmup=False, versioned=True)
    u, a, b, d_old = _bridge(vindex)
    old.submit_mutation([MutationOp("insert", u, (a, b), (1.0, 1.0))],
                        now=0.0)
    rid = old.submit(a, b, now=0.0)              # left queued
    new = reg.register("g", vindex, buckets=(8,), warmup=False,
                       versioned=True)
    assert reg.get("g") is new and new is not old and len(reg) == 1
    # replacement drained the old holder: its queued read was answered
    # (on the old server's mutated current version), versions released
    assert old.take_result(rid) == np.float32(2.0)
    assert old.versions.live_versions() == [old.versions.current.vid]
    reg.unregister("g")


# ------------------------------------------------------- replica groups
def _rset(index, **kw):
    from repro.obs import MetricRegistry
    from repro.serve import ReplicaSet
    kw.setdefault("buckets", BUCKETS)
    kw.setdefault("max_wait_ms", 1.0)
    kw.setdefault("registry", MetricRegistry())
    return ReplicaSet(index, kw.pop("n_replicas", 2), **kw)


def test_replicaset_serves_bitwise_and_spreads_load(index):
    rs = _rset(index)
    tr = make_trace("uniform", index.n, 192, rate_qps=50_000.0, seed=4)
    got = rs.serve_trace(tr)
    want = np.asarray(index.query(tr.s, tr.t), np.float32)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_array_equal(got[fin], want[fin])
    per = [srv.metrics.served for srv in rs.replicas]
    assert sum(per) == len(tr) and min(per) > 0     # both took traffic
    st = rs.stats()
    assert st["served"] == len(tr)
    assert all(r["healthy"] for r in st["replicas"].values())
    assert st["fleet_stragglers"] == []
    assert rs.registry.get("serve.replica_evictions").total() == 0


def test_replicaset_evicts_injected_straggler_and_fires_slo(index):
    from repro.obs import SLOEngine, default_serving_slos, latency_source
    rs = _rset(index, evict_after=3)
    tr = make_trace("straggler", index.n, 256, rate_qps=20_000.0,
                    seed=5, stall_replica=1, stall_s=5.0)
    span = float(tr.span_s)
    slo = SLOEngine(default_serving_slos(
        latency_threshold_s=1.0, fast_window_s=max(span, 1e-3),
        slow_window_s=4 * max(span, 1e-3)), registry=rs.registry)
    slo.attach("latency", latency_source(1.0, registry=rs.registry,
                                         servers=rs.server_names))
    got = rs.serve_trace(tr, slo=slo)
    want = np.asarray(index.query(tr.s, tr.t), np.float32)
    fin = np.isfinite(want)
    np.testing.assert_array_equal(got[fin], want[fin])  # exact under fault
    stalled, clean = rs.replicas[1].name, rs.replicas[0].name
    assert rs.healthy == [True, False]
    assert rs.stats()["replicas"][stalled]["healthy"] is False
    ev = rs.registry.get("serve.replica_evictions")
    assert ev.value(replica=stalled) == 1 and ev.value(replica=clean) == 0
    assert rs.registry.get("serve.replica_healthy").value(
        replica=clean) == 1.0
    assert "latency" in slo.breach_summary()["fired"]


def test_replicaset_clean_replay_is_alert_quiet(index):
    from repro.obs import SLOEngine, default_serving_slos, latency_source
    rs = _rset(index)
    tr = make_trace("uniform", index.n, 192, rate_qps=20_000.0, seed=6)
    span = float(tr.span_s)
    slo = SLOEngine(default_serving_slos(
        latency_threshold_s=1.0, fast_window_s=max(span, 1e-3),
        slow_window_s=4 * max(span, 1e-3)), registry=rs.registry)
    slo.attach("latency", latency_source(1.0, registry=rs.registry,
                                         servers=rs.server_names))
    rs.serve_trace(tr, slo=slo)
    assert slo.breach_summary()["fired"] == []
    assert rs.healthy == [True, True]
    assert rs.stats()["fleet_stragglers"] == []
