"""Label compression (``IndexConfig.label_dtype``, core/labels.py) end
to end: codec roundtrip exactness and rejection modes, compressed
QueryEngine bitwise vs fp32 across backends and vs the Dijkstra oracle,
auto-mode fallbacks, sharded compressed serving (subprocess, forced
2-device CPU), and versioned mutation — compressed blocks must flow
through COW swaps with zero new compiles on the read path.

delta16 ids + int32 distances are *bitwise*-exact by construction
(int->fp32 conversion below 2**24 is exact); the assertions here are
plain array_equal, the strictest version of the ULP gate.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.core.labels import (LabelCompressionError, LabelRows,
                               decode_rows, encode_labels,
                               try_encode_labels)
from repro.core.query import QueryEngine
from repro.graphs import generators as gen
from repro.serve import MutationOp, VersionManager

SRC = str(Path(__file__).resolve().parents[1] / "src")
RNG = np.random.default_rng(17)


def _bitwise(got, want, tag=""):
    got, want = np.asarray(got), np.asarray(want)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all(), tag
    np.testing.assert_array_equal(got[fin], want[fin], err_msg=tag)


# --------------------------------------------------------------- codec
def _encodable_planes(q=20, l=24, n=4000, integral=True):
    ids = (RNG.integers(0, 300, (q, 1))
           + np.cumsum(RNG.integers(1, 40, (q, l)), axis=1)).astype(np.int32)
    ids[::4, l - 3:] = n
    ids[3, :] = n                               # fully padded row
    d = (RNG.integers(0, 90, (q, l)).astype(np.float32) if integral
         else (RNG.random((q, l)) * 9).astype(np.float32))
    d = np.where(ids < n, d, np.inf).astype(np.float32)
    return ids, d, n


@pytest.mark.parametrize("integral", [True, False])
def test_roundtrip_exact(integral):
    ids, d, n = _encodable_planes(integral=integral)
    delta, base, d_enc = encode_labels(ids, d, n)
    assert delta.dtype == np.int16
    assert d_enc.dtype == (np.int32 if integral else np.float32)
    got_ids, got_d = decode_rows(
        LabelRows(jnp.asarray(delta), jnp.asarray(base),
                  jnp.asarray(d_enc)), n, "delta16")
    np.testing.assert_array_equal(np.asarray(got_ids), ids)
    _bitwise(got_d, d)


def test_encode_rejections():
    ids, d, n = _encodable_planes()
    bad = ids.copy()
    bad[0, 0], bad[0, 1] = bad[0, 1], bad[0, 0]          # unsorted
    with pytest.raises(LabelCompressionError):
        encode_labels(bad, d, n)
    big = ids.copy().astype(np.int32)
    big[1, -4] = 3_000_000                               # delta > int16
    with pytest.raises(LabelCompressionError):
        encode_labels(big, d, 4_000_000)
    assert try_encode_labels(big, d, 4_000_000) is None
    holes = ids.copy()
    holes[2, 5] = n                                      # pad mid-row
    if holes[2, 6] < n:
        with pytest.raises(LabelCompressionError):
            encode_labels(holes, d, n)
    frac = d.copy()
    frac[0, 0] = 1.5
    with pytest.raises(LabelCompressionError):
        encode_labels(ids, frac, n, d_dtype="int32")     # pinned codec
    # pinned float32 always fits and keeps the plane verbatim
    _, _, d_enc = encode_labels(ids, d, n, d_dtype="float32")
    assert d_enc.dtype == np.float32


# --------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def graph_and_index():
    n, src, dst, w = gen.er_graph(240, 2.6, seed=9)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=128, label_chunk=64))
    s = RNG.integers(0, n, 64).astype(np.int32)
    t = RNG.integers(0, n, 64).astype(np.int32)
    want = ref.dijkstra_oracle(n, src, dst, w, s)[np.arange(64), t]
    return (n, src, dst, w), idx, s, t, want


def _compressed_twin(eng, label_dtype="compressed"):
    return QueryEngine(eng.lbl_ids, eng.lbl_d, eng.core_pos,
                       (eng.ce_src, eng.ce_dst, eng.ce_w), eng.n,
                       eng.n_core, label_dtype=label_dtype)


@pytest.mark.parametrize("backend", ["reference", "interpret"])
def test_engine_compressed_bitwise(graph_and_index, backend):
    """Compressed engine == fp32 engine bitwise (μ-only and full path)
    and exact vs the Dijkstra oracle, on both backends."""
    _, idx, s, t, want = graph_and_index
    ceng = _compressed_twin(idx.engine)
    assert ceng.codec == "delta16"
    assert ceng.enc_d.dtype == jnp.int32       # er_graph weights integral
    _bitwise(ceng.query_mu_only(s, t, backend=backend),
             idx.engine.query_mu_only(s, t, backend=backend), "mu")
    got = ceng.query(s, t, backend=backend)
    _bitwise(got, idx.engine.query(s, t, backend=backend), "full")
    _bitwise(got, want.astype(np.float32), "oracle")


def test_config_plumbs_label_dtype(graph_and_index):
    (n, src, dst, w), idx, s, t, _ = graph_and_index
    cidx = ISLabelIndex.build(
        n, src, dst, w,
        IndexConfig(l_cap=128, label_chunk=64, label_dtype="compressed"))
    assert cidx.engine.codec == "delta16"
    _bitwise(cidx.query(s, t), idx.query(s, t))


def test_auto_fallback_modes(graph_and_index):
    """auto: fractional weights keep a float32 distance plane (ids still
    delta16); planes that don't fit the id codec fall back to fp32
    wholesale, while "compressed" raises on them."""
    (n, src, dst, w), idx, s, t, _ = graph_and_index
    half = ISLabelIndex.build(
        n, src, dst, w * np.float32(0.5),
        IndexConfig(l_cap=128, label_chunk=64, label_dtype="auto"))
    assert half.engine.codec == "delta16"
    assert half.engine.enc_d.dtype == jnp.float32
    _bitwise(half.query(s, t), 0.5 * np.asarray(idx.query(s, t)))

    eng = idx.engine
    wide_ids = np.asarray(eng.lbl_ids).astype(np.int64)
    wide_ids[wide_ids < eng.n] *= 40_000       # deltas overflow int16
    wide_n = int(wide_ids.max()) + 1
    auto = QueryEngine(jnp.asarray(wide_ids.astype(np.int32)), eng.lbl_d,
                       eng.core_pos, (eng.ce_src, eng.ce_dst, eng.ce_w),
                       wide_n, eng.n_core, label_dtype="auto")
    assert auto.codec == "none"
    with pytest.raises(LabelCompressionError):
        QueryEngine(jnp.asarray(wide_ids.astype(np.int32)), eng.lbl_d,
                    eng.core_pos, (eng.ce_src, eng.ce_dst, eng.ce_w),
                    wide_n, eng.n_core, label_dtype="compressed")
    with pytest.raises(ValueError):
        _compressed_twin(eng, label_dtype="zstd")


# -------------------------------------------------------------- sharded
def test_sharded_compressed_bitwise_subprocess():
    """Compressed blocks shard row-locally: sharded compressed answers ==
    unsharded fp32 bitwise on 2 forced CPU devices, one collective."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent("""
        import numpy as np
        from repro.core import ISLabelIndex, IndexConfig
        from repro.graphs import generators as gen
        from repro.shard import ShardedIndex
        n, src, dst, w = gen.er_graph(300, 2.5, seed=9)
        idx = ISLabelIndex.build(n, src, dst, w,
                                 IndexConfig(l_cap=128, label_chunk=128))
        cidx = ISLabelIndex.build(
            n, src, dst, w,
            IndexConfig(l_cap=128, label_chunk=128,
                        label_dtype="compressed"))
        sidx = ShardedIndex.from_index(cidx, 2)
        assert sidx.engine.codec == "delta16", sidx.engine.codec
        r = np.random.default_rng(0)
        s = r.integers(0, n, 48).astype(np.int32)
        t = r.integers(0, n, 48).astype(np.int32)
        for backend in ("reference", "interpret"):
            want_ans, want_rounds = idx.engine.batch_fn(backend)(s, t)
            ans, rounds = sidx.engine.batch_fn(backend)(s, t)
            assert np.array_equal(np.asarray(ans), np.asarray(want_ans))
            assert int(rounds) == int(want_rounds)
            mu = sidx.engine.mu_batch_fn(backend)(s, t)
            assert np.array_equal(
                np.asarray(mu),
                np.asarray(idx.engine.mu_batch_fn(backend)(s, t)))
            assert sidx.engine.collective_count(backend=backend) == 1
        print("ok")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ok" in r.stdout


# ------------------------------------------------------------ versioned
def test_versioned_compressed_mutation_zero_recompiles():
    """A compressed family carries encoded planes through COW swaps:
    answers bitwise-equal an uncompressed family and a from-scratch
    rebuild, with zero new (entry point, shape) compiles after the
    first query — mutated versions reuse the same jitted family fns."""
    n_base, spares = 150, 8
    n = n_base + spares
    nb, src, dst, w = gen.er_graph(n_base, 2.4, seed=5)
    cfg = IndexConfig(l_cap=256, label_chunk=128)
    idx = ISLabelIndex.build(n, src, dst, w, cfg)
    cidx = ISLabelIndex.build(
        n, src, dst, w,
        IndexConfig(l_cap=256, label_chunk=128, label_dtype="compressed"))
    mgr = VersionManager.from_index(idx)
    cmgr = VersionManager.from_index(cidx)
    assert cmgr.family.codec == "delta16"
    fn = mgr.family.full_fn("interpret")
    cfn = cmgr.family.full_fn("interpret")

    r = np.random.default_rng(2)
    s = r.integers(0, n_base, 32).astype(np.int32)
    t = r.integers(0, n_base, 32).astype(np.int32)
    ans0, r0 = fn(mgr.current.state, s, t)
    cans0, cr0 = cfn(cmgr.current.state, s, t)
    _bitwise(cans0, ans0, "v0")
    assert int(cr0) == int(r0)
    sizes0 = cmgr.family.cache_sizes("interpret")

    core_u = int(idx.core_ids[0])
    ops = [MutationOp("insert", n_base, (core_u,), (1.0,))]
    ver = mgr.apply(ops)
    cver = cmgr.apply(ops)
    qs = np.concatenate([s[:16], np.full(16, n_base)]).astype(np.int32)
    qt = np.concatenate([np.full(16, n_base), t[:16]]).astype(np.int32)
    ans1, r1 = fn(ver.state, qs, qt)
    cans1, cr1 = cfn(cver.state, qs, qt)
    _bitwise(cans1, ans1, "v1")
    assert int(cr1) == int(r1)
    # zero-recompile guarantee: the swap added no compiled shapes
    # (the qs/qt batch is the same 32-shape as the warm call)
    assert cmgr.family.cache_sizes("interpret") == sizes0

    es = np.concatenate([src, [core_u, n_base]])
    ed = np.concatenate([dst, [n_base, core_u]])
    ew = np.concatenate([w, [1.0, 1.0]]).astype(np.float32)
    scratch = ISLabelIndex.build(n, es, ed, ew, cfg)
    _bitwise(cans1, scratch.query(qs, qt), "rebuild")

    # delete restores v0 answers bitwise; deleted vertex reads +inf
    cver2 = cmgr.apply([MutationOp("delete", n_base)])
    cans2, _ = cfn(cver2.state, s, t)
    _bitwise(cans2, cans0, "delete-restore")
    gone, _ = cfn(cver2.state, qs[:32], qt[:32])
    assert np.isinf(np.asarray(gone)[np.asarray(qs[:32]) == n_base]).all()
    assert cmgr.family.cache_sizes("interpret") == sizes0
