"""CI perf-trajectory gates: behavior-metric extraction from bench rows,
the ``scripts/obs_report.py`` gate policies (--fail-on any|behavior,
--report-out), and ``benchmarks/run.py``'s empty-suite failure — a
suite that silently emits zero rows must exit nonzero rather than let
the downstream bench-gate diff go vacuously green.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from repro.obs.regression import compare_docs, extract_metrics

REPO = Path(__file__).resolve().parents[1]


def _doc(exact=1, rounds=8, us=500.0, fill=0.9):
    return {"rows": [
        {"table": "kernels", "name": "fused_relax_kernel[q64,v4096]",
         "us_per_call": us, "relax_rounds": rounds,
         "exact_vs_dijkstra": exact, "batch_fill_ratio": fill,
         "backend": "interpret"},
        {"table": "kernels", "name": "tiny_row", "us_per_call": 3.0},
    ]}


# ----------------------------------------------- behavior row metrics
def test_row_behavior_metrics_extracted():
    m = extract_metrics(_doc())
    key = "row:fused_relax_kernel[q64,v4096]"
    assert m[f"{key}:us_per_call"].kind == "timing"
    assert m[f"{key}:exact_vs_dijkstra"].kind == "behavior"
    assert m[f"{key}:exact_vs_dijkstra"].higher_better
    assert m[f"{key}:relax_rounds"].kind == "behavior"
    assert not m[f"{key}:relax_rounds"].higher_better
    assert m[f"{key}:batch_fill_ratio"].higher_better
    # non-behavior derived keys (backend string) are not metrics; rows
    # under the timing floor contribute no timing metric
    assert "row:tiny_row:us_per_call" not in m


def test_compare_docs_gates_behavior_rows():
    base = _doc()
    # timing drift within a loose tolerance: clean
    assert compare_docs("kernels", base, _doc(us=600.0)) == []
    # exactness flag dropping is a behavior regression
    regs = compare_docs("kernels", base, _doc(exact=0))
    assert [r.kind for r in regs] == ["behavior"]
    # round-count growth is a behavior regression too
    regs = compare_docs("kernels", base, _doc(rounds=12))
    assert any("relax_rounds" in r.metric and r.kind == "behavior"
               for r in regs)


# ------------------------------------------------- obs_report policies
def _gate(baseline, fresh, *extra):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "obs_report.py"),
         "--baseline", str(baseline), "--fresh", str(fresh),
         "--timing-tolerance", "0.5", *extra],
        capture_output=True, text=True, timeout=120)


def _write(d, doc):
    d.mkdir(exist_ok=True)
    (d / "BENCH_kernels.json").write_text(json.dumps(doc))


def test_obs_report_fail_on_policies(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write(base, _doc())

    # timing-only regression: gates under 'any', warns under 'behavior'
    _write(fresh, _doc(us=5000.0))
    assert _gate(base, fresh).returncode == 1
    r = _gate(base, fresh, "--fail-on", "behavior")
    assert r.returncode == 0 and "WARN" in r.stdout, r.stdout

    # injected behavior regression (exactness flag drops): gates under
    # BOTH policies — this is the bench-gate acceptance scenario
    _write(fresh, _doc(exact=0))
    assert _gate(base, fresh, "--fail-on", "behavior").returncode == 1
    assert _gate(base, fresh).returncode == 1

    # clean run passes and --report-out writes the artifact
    _write(fresh, _doc())
    report = tmp_path / "out" / "report.txt"
    r = _gate(base, fresh, "--fail-on", "behavior",
              "--report-out", str(report))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in report.read_text()

    # required-table coverage loss gates even under --fail-on behavior
    r = _gate(base, fresh, "--fail-on", "behavior",
              "--tables", "kernels,serving")
    assert r.returncode == 1 and "serving" in r.stdout


# ------------------------------------------------- run.py empty suites
def test_run_py_fails_on_empty_suite(tmp_path):
    """roofline with no kernel rows available (fresh cwd, no
    BENCH_kernels.json anywhere) emits zero rows -> EmptySuite error
    row and nonzero exit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO), str(REPO / "src"), env.get("PYTHONPATH", "")])
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "roofline",
         "--out", str(tmp_path / "out")],
        capture_output=True, text=True, timeout=300, env=env,
        cwd=tmp_path)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "EmptySuite" in r.stdout
