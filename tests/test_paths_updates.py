"""Paper §8: shortest-path reconstruction, update maintenance, and the
index save/load roundtrip."""
import numpy as np
import pytest

from repro.core import ISLabelIndex, IndexConfig, ref
from repro.graphs import generators as gen


@pytest.fixture(scope="module")
def built():
    n, src, dst, w = gen.rmat_graph(8, avg_deg=5.0, seed=2)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=256, label_chunk=128))
    ed = {}
    for a, b, ww in zip(src, dst, w):
        ed[(int(a), int(b))] = min(ed.get((int(a), int(b)), np.inf),
                                   float(ww))
    return n, src, dst, w, idx, ed


def test_paths_valid_and_tight(built):
    n, src, dst, w, idx, ed = built
    r = np.random.default_rng(3)
    checked = 0
    for _ in range(40):
        s, t = int(r.integers(0, n)), int(r.integers(0, n))
        d, path = idx.shortest_path(s, t)
        if not np.isfinite(d):
            assert path == []
            continue
        checked += 1
        assert path[0] == s and path[-1] == t
        length = 0.0
        for a, b in zip(path[:-1], path[1:]):
            assert (a, b) in ed, f"path uses non-edge {(a, b)}"
            length += ed[(a, b)]
        assert abs(length - d) < 1e-4, (length, d)
    assert checked > 10


def test_host_oracle_caches_hoisted_and_invalidated(built):
    """The satellite fix: host label copies and the sorted core
    adjacency are computed once, reused across calls, and dropped on
    in-place mutation (so the oracle never serves stale structure)."""
    n, src, dst, w, idx, ed = built
    idx.shortest_path(0, 1)
    labels = idx._label_host()
    adj = idx._core_adjacency()
    # second call reuses the identical cached objects
    idx.shortest_path(2, 3)
    assert idx._label_host() is labels
    assert idx._core_adjacency() is adj


def test_oracle_valid_after_delete():
    n, src, dst, w = gen.grid_graph(8, seed=13)
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=256, label_chunk=64))
    d0, p0 = idx.shortest_path(0, 63)           # warm the caches
    labels0 = idx._label_host()
    u = 27
    touched = idx.delete_vertex(u)
    # the stale host-label cache is replaced by the fresh mutated
    # copies (never served stale) and the core adjacency is dropped
    assert idx._core_adj is None
    assert idx._label_host()[0] is not labels0[0]
    assert (idx._label_host()[0] == np.asarray(idx.lbl_ids)).all()
    # the mutator reports exactly the rows it rewrote
    assert u in touched.tolist()
    diff = np.nonzero((labels0[0] != idx._label_host()[0]).any(axis=1))[0]
    assert set(diff.tolist()) <= set(touched.tolist())
    d1, p1 = idx.shortest_path(0, 63)
    assert np.isfinite(d1) and u not in p1
    ed = {}
    for a, b, ww in zip(src, dst, w):
        if u not in (int(a), int(b)):
            ed[(int(a), int(b))] = float(ww)
    total = sum(ed[(a, b)] for a, b in zip(p1[:-1], p1[1:]))
    assert abs(total - d1) < 1e-4


def test_save_load_roundtrip(tmp_path, built):
    n, src, dst, w, idx, _ = built
    idx.save(tmp_path / "idx")
    idx2 = ISLabelIndex.load(tmp_path / "idx")
    r = np.random.default_rng(5)
    s = r.integers(0, n, 50).astype(np.int32)
    t = r.integers(0, n, 50).astype(np.int32)
    np.testing.assert_allclose(idx.query_host(s, t), idx2.query_host(s, t))
    assert idx2.k == idx.k and idx2.stats.m == idx.stats.m


def test_insert_vertex():
    """§8.3: lazy insert keeps queries exact wrt the updated graph."""
    n, src, dst, w = gen.er_graph(120, 3.0, seed=9)
    # hold out the last vertex: build on edges not touching u
    u = n - 1
    keep = (src != u) & (dst != u)
    idx = ISLabelIndex.build(n, src[keep], dst[keep], w[keep],
                             IndexConfig(l_cap=256, label_chunk=64))
    nbrs = dst[(src == u)]
    ws = w[(src == u)]
    if len(nbrs) == 0:
        pytest.skip("isolated holdout")
    idx.insert_vertex(u, nbrs.tolist(), ws.tolist())
    r = np.random.default_rng(11)
    s = np.full(30, u, np.int32)
    t = r.integers(0, n, 30).astype(np.int32)
    got = idx.query_host(s, t)
    want = ref.dijkstra_oracle(n, src, dst, w, [u])[0][t]
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)


def test_delete_vertex():
    """§8.3: lazy delete — distances never report paths through u."""
    n, src, dst, w = gen.grid_graph(8, seed=13)     # deletion splits paths
    idx = ISLabelIndex.build(n, src, dst, w,
                             IndexConfig(l_cap=256, label_chunk=64))
    u = 27
    idx.delete_vertex(u)
    keep = (src != u) & (dst != u)
    r = np.random.default_rng(13)
    s = r.integers(0, n, 40).astype(np.int32)
    t = r.integers(0, n, 40).astype(np.int32)
    mask = (s != u) & (t != u)
    got = idx.query_host(s[mask], t[mask])
    want = ref.dijkstra_oracle(n, src[keep], dst[keep], w[keep],
                               s[mask])[np.arange(mask.sum()), t[mask]]
    # lazy deletion is conservative: answers must never be SHORTER than
    # the truth (never route through the deleted vertex) and must match
    # wherever the remaining label/core structure covers the pair.
    fin = np.isfinite(got)
    assert (got[fin] >= want[fin] - 1e-4).all()
    cover = fin & np.isfinite(want)
    assert (np.abs(got[cover] - want[cover]) < 1e-4).mean() > 0.8
